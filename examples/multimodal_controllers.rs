//! E1 demo — the §3.1 controller-bottleneck scenario with real bytes:
//! multimodal rollouts routed through one controller vs sharded across
//! parallel controllers.  Prints the E1 table plus the paper's 2k-image
//! extrapolation (1024 samples × 32 images × 2k² → hundreds of GB on a
//! single controller; per-controller residency shrinks linearly with N).
//!
//!     cargo run --release --example multimodal_controllers
//!     GCORE_E1_FULL=1 cargo run --release --example multimodal_controllers

use gcore::data::payload::PayloadSpec;
use gcore::experiments;

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("GCORE_E1_FULL").is_err();

    let paper = PayloadSpec::paper_2k();
    println!("paper §3.1 arithmetic check:");
    println!(
        "  one sample  = {} images × {}×{} px = {:.2} GB",
        paper.images_per_sample,
        paper.width,
        paper.height,
        paper.bytes_per_sample() as f64 / 1e9
    );
    println!(
        "  1024-sample rollout = {:.0} GB on ONE controller (the paper's ≥768 GB wall)",
        paper.rollout_bytes(1024) as f64 / 1e9
    );

    let t = experiments::e1_controller_scaling(quick);
    t.print();

    println!("\n(real bytes moved through real threads; scaled image size, \
              with the @paper-2k column extrapolating per-controller residency)");
    Ok(())
}
