//! E10 — the end-to-end validation run (DESIGN.md §4): train a byte-level
//! transformer policy with the full G-Core stack — SFT warm-start, then
//! GRPO with ground-truth rewards across parallel controllers — and log
//! the loss/reward/accuracy curves recorded in EXPERIMENTS.md.
//!
//!     cargo run --release --example rlhf_e2e                 # quickstart set
//!     RLHF_CONFIG=e2e RLHF_STEPS=200 cargo run --release --example rlhf_e2e
//!
//! Environment knobs: RLHF_CONFIG (artifact set), RLHF_STEPS, RLHF_SFT,
//! RLHF_WORLD, RLHF_DAPO=1, RLHF_CKPT_DIR.

use gcore::config::RunConfig;
use gcore::launch;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let cfg = RunConfig {
        artifacts: std::env::var("RLHF_CONFIG").unwrap_or_else(|_| "tiny".into()),
        world: env_usize("RLHF_WORLD", 2),
        steps: env_usize("RLHF_STEPS", 150),
        sft_steps: env_usize("RLHF_SFT", 260),
        sft_lr: 1.5e-3,
        group_size: 4,
        lr: env_usize("RLHF_LR_E6", 200) as f32 * 1e-6,
        kl_coef: 0.05,
        temperature: env_usize("RLHF_TEMP_E2", 50) as f32 / 100.0,
        top_k: 16,
        dynamic_sampling: std::env::var("RLHF_DAPO").is_ok(),
        max_resample_rounds: 3,
        tasks: std::env::var("RLHF_TASKS")
            .unwrap_or_else(|_| "copy".into())
            .split(',')
            .map(String::from)
            .collect(),
        checkpoint_dir: std::env::var("RLHF_CKPT_DIR").ok(),
        checkpoint_every: 20,
        ..RunConfig::default()
    };
    println!(
        "[rlhf_e2e] artifacts={} world={} sft={} steps={} dapo={}",
        cfg.artifacts, cfg.world, cfg.sft_steps, cfg.steps, cfg.dynamic_sampling
    );

    let t0 = std::time::Instant::now();
    let report = launch::run_training(&cfg)?;
    let wall = t0.elapsed().as_secs_f64();

    println!("\n## E10 — end-to-end RLHF training curve\n");
    println!("SFT loss: first {:.3} → last {:.3} over {} steps",
        report.sft_losses.first().unwrap_or(&f32::NAN),
        report.sft_losses.last().unwrap_or(&f32::NAN),
        report.sft_losses.len());
    println!("\n| step | loss | kl | entropy | clipfrac | reward | accuracy | gen_len | rounds |");
    println!("|---|---|---|---|---|---|---|---|---|");
    let stride = (report.steps.len() / 20).max(1);
    for s in report.steps.iter().step_by(stride) {
        println!(
            "| {} | {:+.4} | {:.4} | {:.3} | {:.3} | {:.3} | {:.3} | {:.1} | {:.1} |",
            s.step, s.loss, s.kl, s.entropy, s.clipfrac, s.mean_reward, s.accuracy,
            s.mean_gen_len, s.gen_rounds
        );
    }
    if let Some(last) = report.steps.last() {
        if stride > 1 {
            println!(
                "| {} | {:+.4} | {:.4} | {:.3} | {:.3} | {:.3} | {:.3} | {:.1} | {:.1} |",
                last.step, last.loss, last.kl, last.entropy, last.clipfrac,
                last.mean_reward, last.accuracy, last.mean_gen_len, last.gen_rounds
            );
        }
    }

    let first_r = report.steps.first().map(|s| s.mean_reward).unwrap_or(0.0);
    let last_r = report.steps.last().map(|s| s.mean_reward).unwrap_or(0.0);
    println!("\ntrain reward: {first_r:.3} → {last_r:.3}");
    println!(
        "held-out greedy accuracy: {:.3} (post-SFT) → {:.3} (post-RLHF)",
        report.eval_before, report.eval_after
    );
    println!("total wallclock: {wall:.0}s\n\nstage timers:\n{}", report.timers_markdown);

    if last_r <= first_r {
        eprintln!("WARNING: reward did not improve — inspect the curve above");
    }
    Ok(())
}
