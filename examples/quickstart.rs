//! Quickstart: the smallest end-to-end tour of the G-Core reproduction.
//!
//! Loads the `tiny` artifact set (run `make artifacts` first), warm-starts
//! the policy with a few SFT steps, generates some responses, scores them,
//! and takes one GRPO step — all through the public API.
//!
//!     cargo run --release --example quickstart

use std::sync::Arc;

use gcore::config::RunConfig;
use gcore::coordinator::collective::Collective;
use gcore::coordinator::controller::Controller;
use gcore::data::tokenizer;
use gcore::reward::Rewarder;
use gcore::runtime::{init_policy, Engine};

fn main() -> anyhow::Result<()> {
    // 1. load the AOT artifact set (JAX/Pallas → HLO text → PJRT)
    let engine = Arc::new(Engine::load("tiny")?);
    let dims = engine.manifest().dims.clone();
    println!(
        "loaded '{}': {:.2}M-param byte-transformer, batch={}, seq={}",
        dims.name,
        engine.manifest().param_count as f64 / 1e6,
        dims.batch,
        dims.max_seq
    );

    // 2. one controller, ground-truth rewards
    let cfg = RunConfig {
        steps: 5,
        sft_steps: 500,
        temperature: 0.5,
        tasks: vec!["copy".into()],
        ..RunConfig::default()
    };
    let policy = init_policy(&engine, cfg.seed as u32)?;
    let mut controller = Controller::new(
        0,
        engine.clone(),
        Collective::new(1),
        cfg,
        policy,
        Rewarder::ground_truth(),
    )?;

    // 3. SFT warm-start on task demonstrations
    print!("SFT warm-start: ");
    for step in 0..500 {
        let loss = controller.sft_step()?;
        if step % 100 == 0 {
            print!("{loss:.3} ");
        }
    }
    println!();
    controller.freeze_reference();

    // 4. a rollout: generate + ground-truth reward
    let batch = controller.collect_rollout()?;
    println!("\nsample rollouts:");
    for i in 0..3.min(batch.gen.rows.len()) {
        let prompt = batch.tasks[i].prompt.clone();
        let response = tokenizer::extract_response(&batch.gen.rows[i], dims.prompt_len);
        println!(
            "  '{prompt}' -> '{response}'  (want '{}', reward {})",
            batch.tasks[i].answer, batch.rewards[i]
        );
    }

    // 5. GRPO steps
    println!("\nRLHF (GRPO, ground-truth reward):");
    for step in 0..5 {
        let s = controller.rlhf_step(step)?;
        println!(
            "  step {step}: loss {:+.4}  reward {:.3}  accuracy {:.3}  gen_len {:.1}",
            s.loss, s.mean_reward, s.accuracy, s.mean_gen_len
        );
    }

    println!("\nstage timers:\n{}", controller.timers.report());
    Ok(())
}
