//! Dynamic sampling (DAPO) demo — the workload §3.2's dynamic placement
//! exists for.  Runs the real RLHF loop with the DAPO filter on and off,
//! showing (a) uninformative groups being filtered and regenerated locally
//! (the parallel-controller "local state transition"), and (b) how the
//! resample-round count — the swap multiplier under co-location — evolves
//! as the policy sharpens.  Then projects the measured round counts through
//! the placement simulator to show the co-locate vs dynamic-placement gap.
//!
//!     cargo run --release --example dynamic_sampling

use gcore::config::RunConfig;
use gcore::launch;
use gcore::placement::{run_colocate, run_dynamic, PlacementSpec};

fn main() -> anyhow::Result<()> {
    let base = RunConfig {
        artifacts: "tiny".into(),
        world: 1,
        steps: 12,
        sft_steps: 500,
        sft_lr: 1.5e-3,
        lr: 3e-4,
        group_size: 4,
        temperature: 0.5,
        tasks: vec!["copy".into()],
        ..RunConfig::default()
    };

    println!("=== DAPO off ===");
    let plain = launch::run_training(&base)?;
    println!("=== DAPO on (max 3 rounds) ===");
    let dapo_cfg = RunConfig {
        dynamic_sampling: true,
        max_resample_rounds: 3,
        ..base.clone()
    };
    let dapo = launch::run_training(&dapo_cfg)?;

    println!("\n| step | plain acc | dapo acc | plain rounds | dapo rounds |");
    println!("|---|---|---|---|---|");
    let mut mean_rounds = 0.0;
    for (p, d) in plain.steps.iter().zip(&dapo.steps) {
        println!(
            "| {} | {:.3} | {:.3} | {:.1} | {:.1} |",
            p.step, p.accuracy, d.accuracy, p.gen_rounds, d.gen_rounds
        );
        mean_rounds += d.gen_rounds;
    }
    mean_rounds /= dapo.steps.len().max(1) as f64;
    println!("\nmean DAPO generation rounds/step: {mean_rounds:.2}");

    // Project the measured resample multiplier through the placement sim:
    // this is exactly the §3.2 argument — each extra round is two extra
    // model swaps under co-location, zero under dynamic placement.
    let mut spec = PlacementSpec::paper_like();
    spec.steps = 12;
    spec.n_devices = 16;
    spec.batch = 128;
    spec.dynamic_sampling = true;
    // calibrate the acceptance model so expected rounds ≈ measured
    spec.accept.p0 = (1.0 / mean_rounds).clamp(0.15, 0.95);
    spec.accept.floor = spec.accept.p0 * 0.8;
    let colo = run_colocate(&spec);
    let dynp = run_dynamic(&spec).report;
    println!("\nprojected on the 16-GPU cluster sim at {mean_rounds:.1} rounds/step:");
    println!(
        "  co-locate: makespan {:.0}s, swap overhead {:.0} dev-s, util {:.1}%",
        colo.makespan_s,
        colo.swap_s,
        colo.utilization * 100.0
    );
    println!(
        "  dynamic  : makespan {:.0}s, swap overhead {:.0} dev-s, util {:.1}%  ({:.2}× faster)",
        dynp.makespan_s,
        dynp.swap_s,
        dynp.utilization * 100.0,
        colo.makespan_s / dynp.makespan_s
    );
    Ok(())
}
