//! E6 — the paper's evaluation components 1 & 2 (§5): RLHF with a
//! traditional Bradley-Terry reward model vs a **generative reward model**
//! (verifier LM, verdict via next-token prediction + regex matching, §3.2),
//! with ground-truth reward as the oracle upper bound.
//!
//! Reports reward-model quality, then the policy-improvement curves under
//! each reward source on the same tasks/seed.  Recorded in EXPERIMENTS.md.
//!
//!     cargo run --release --example genrm_vs_bt
//!
//! Env: GENRM_CONFIG (default tiny), GENRM_STEPS, GENRM_SFT.

use gcore::config::RunConfig;
use gcore::launch;
use gcore::reward::{RewardKind, VerdictMode};

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let base = RunConfig {
        artifacts: std::env::var("GENRM_CONFIG").unwrap_or_else(|_| "tiny".into()),
        world: 1,
        steps: env_usize("GENRM_STEPS", 60),
        sft_steps: env_usize("GENRM_SFT", 500),
        sft_lr: 1.5e-3,
        lr: 3e-4,
        temperature: 0.5,
        group_size: 4,
        kl_coef: 0.05,
        tasks: vec!["copy".into()],
        bt_train_steps: env_usize("GENRM_RM_STEPS", 150),
        verifier_sft_steps: env_usize("GENRM_RM_STEPS", 300),
        verdict_mode: VerdictMode::Logit,
        ..RunConfig::default()
    };

    let mut rows = Vec::new();
    for (label, kind) in [
        ("ground-truth (oracle)", RewardKind::GroundTruth),
        ("Bradley-Terry RM", RewardKind::BradleyTerry),
        ("generative RM (verifier)", RewardKind::Generative),
    ] {
        let cfg = RunConfig { reward: kind, ..base.clone() };
        println!("\n=== training with {label} ===");
        let t0 = std::time::Instant::now();
        let report = launch::run_training(&cfg)?;
        let wall = t0.elapsed().as_secs_f64();
        let first = report.steps.first().cloned().unwrap_or_default();
        let last = report.steps.last().cloned().unwrap_or_default();
        println!(
            "  rm quality {:.3} | reward {:.3}→{:.3} | gt accuracy {:.3}→{:.3} | eval {:.3}→{:.3} ({wall:.0}s)",
            report.reward_model_metric,
            first.mean_reward,
            last.mean_reward,
            first.accuracy,
            last.accuracy,
            report.eval_before,
            report.eval_after,
        );
        rows.push((
            label,
            report.reward_model_metric,
            first.accuracy,
            last.accuracy,
            report.eval_before,
            report.eval_after,
        ));
    }

    println!("\n## E6 — BT vs generative reward modeling (paper §5)\n");
    println!("| reward source | RM quality | gt-acc first step | gt-acc last step | eval before | eval after |");
    println!("|---|---|---|---|---|---|");
    for (label, rm, a0, a1, e0, e1) in &rows {
        println!("| {label} | {rm:.3} | {a0:.3} | {a1:.3} | {e0:.3} | {e1:.3} |");
    }
    println!("\nShape check (paper): both learned RMs should improve the policy;\n\
              the generative verifier keeps the LM's text interface (verdict =\n\
              next-token prediction + regex), the BT head a scalar.");
    Ok(())
}
