//! Workload balancing (paper §4.4): sort-by-simulated-workload bucketing.
//!
//! With long sequences the training cost is attention-dominated (~s²), so
//! packing-by-count leaves ranks wildly imbalanced.  The paper's simple
//! alternative to combinatorial packing:
//!
//! 1. compute each sample's *simulated workload* (α·s + β·s²),
//! 2. **bucket** the epoch into global batches first (bucket = global
//!    batch), **sort by workload inside**, then **shuffle the buckets** to
//!    kill the length-sorted distribution bias,
//! 3. deal sorted samples across ranks so every rank gets a near-equal
//!    workload share.
//!
//! `waste_fraction` measures the claim: "the proportion of wasted compute
//! is less than 10%" vs naive random assignment.

use anyhow::{bail, Result};

use crate::cluster::workload::TrainTimeModel;
use crate::util::rng::Rng;

/// Assignment strategy for one global batch.  Parsed up front so an
/// unknown name surfaces as a config error on the CLI error path instead
/// of a panic mid-epoch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// Random deal-by-count (the baseline the paper improves on).
    Naive,
    /// Sort-by-simulated-workload dealing (paper §4.4).
    Balanced,
}

impl Strategy {
    pub fn parse(name: &str) -> Result<Strategy> {
        match name {
            "naive" => Ok(Strategy::Naive),
            "balanced" => Ok(Strategy::Balanced),
            other => bail!(
                "unknown balance strategy '{other}' (expected 'naive' or 'balanced')"
            ),
        }
    }
}

/// Simulated workload of one sequence (seconds on the reference model).
pub fn simulated_workload(model: &TrainTimeModel, len: usize) -> f64 {
    model.seq_cost(len)
}

/// Assignment of one global batch: per-rank lists of sample indices.
#[derive(Debug, Clone)]
pub struct RankAssignment {
    pub per_rank: Vec<Vec<usize>>,
}

impl RankAssignment {
    /// Per-rank total workload.
    pub fn rank_costs(&self, costs: &[f64]) -> Vec<f64> {
        self.per_rank
            .iter()
            .map(|idxs| idxs.iter().map(|&i| costs[i]).sum())
            .collect()
    }

    /// Wasted compute fraction of a synchronous step: ranks finish at the
    /// max; everything under it idles.  waste = 1 − mean/max.
    pub fn waste_fraction(&self, costs: &[f64]) -> f64 {
        let rc = self.rank_costs(costs);
        let max = rc.iter().cloned().fold(0.0, f64::max);
        if max == 0.0 {
            return 0.0;
        }
        let mean = rc.iter().sum::<f64>() / rc.len() as f64;
        1.0 - mean / max
    }
}

/// Naive baseline: random order dealt round-robin across ranks.
pub fn assign_naive(batch: &[usize], n_ranks: usize, rng: &mut Rng) -> RankAssignment {
    let mut order = batch.to_vec();
    rng.shuffle(&mut order);
    let mut per_rank = vec![Vec::new(); n_ranks];
    for (i, idx) in order.into_iter().enumerate() {
        per_rank[i % n_ranks].push(idx);
    }
    RankAssignment { per_rank }
}

/// G-Core balanced assignment: sort the batch by workload (descending) and
/// greedily place each sequence on the least-loaded rank that still has
/// capacity — LPT with equal rank sizes.  Needs only a sort + a scan; no
/// combinatorial packing (the paper's simplicity point).
pub fn assign_balanced(batch: &[usize], costs: &[f64], n_ranks: usize) -> RankAssignment {
    let cap = batch.len().div_ceil(n_ranks);
    let mut order = batch.to_vec();
    order.sort_by(|&a, &b| costs[b].partial_cmp(&costs[a]).unwrap());
    let mut per_rank = vec![Vec::new(); n_ranks];
    let mut loads = vec![0.0f64; n_ranks];
    for idx in order {
        let rank = (0..n_ranks)
            .filter(|&r| per_rank[r].len() < cap)
            .min_by(|&a, &b| loads[a].partial_cmp(&loads[b]).unwrap())
            .expect("capacity always available");
        per_rank[rank].push(idx);
        loads[rank] += costs[idx];
    }
    RankAssignment { per_rank }
}

/// Grace budget for long-tail rollout cancellation (paper §3.2): once the
/// dynamic-sampling round has enough finished sequences, the stragglers'
/// remaining decode steps are pure tail cost — the same waste
/// `waste_fraction` measures for training steps.  Scale the configured
/// grace window by the live fraction of the decode batch: a nearly-full
/// batch amortizes each lockstep step well (generous grace), a nearly
/// empty one pays full price per straggler token (cancel promptly).
pub fn cancel_grace_steps(grace: usize, live: usize, batch: usize) -> usize {
    if batch == 0 || live == 0 {
        return 0;
    }
    let frac = (live as f64 / batch as f64).min(1.0);
    (grace as f64 * frac).ceil() as usize
}

/// Epoch plan: bucket → shuffle (paper's distribution-bias fix).
/// Returns the sequence of global batches (each a list of sample indices).
pub fn plan_epoch(
    n_samples: usize,
    global_batch: usize,
    rng: &mut Rng,
) -> Vec<Vec<usize>> {
    // random permutation of the epoch, cut into buckets of one global batch
    let mut order: Vec<usize> = (0..n_samples).collect();
    rng.shuffle(&mut order);
    let mut buckets: Vec<Vec<usize>> = order
        .chunks(global_batch)
        .filter(|c| c.len() == global_batch)
        .map(|c| c.to_vec())
        .collect();
    // shuffle bucket order (paper: "shuffle the buckets to ensure data is
    // randomly distributed")
    rng.shuffle(&mut buckets);
    buckets
}

/// Non-uniform bucket splitting (the paper's "reduce this waste even
/// further"): split each sorted bucket at workload quantiles so the heavy
/// tail concentrates in fewer, smaller micro-groups.
/// Returns per-rank micro-batched indices with ≤ `max_micro` sequences each.
pub fn assign_balanced_nonuniform(
    batch: &[usize],
    costs: &[f64],
    n_ranks: usize,
    max_micro: usize,
) -> Vec<RankAssignment> {
    let mut order = batch.to_vec();
    order.sort_by(|&a, &b| costs[b].partial_cmp(&costs[a]).unwrap());
    // cut into micro-groups of up to n_ranks*max_micro, heaviest first
    order
        .chunks(n_ranks * max_micro)
        .map(|chunk| assign_balanced(chunk, costs, n_ranks))
        .collect()
}

/// Summary row for the E4 table.
#[derive(Debug, Clone)]
pub struct BalanceReport {
    pub strategy: String,
    pub mean_waste: f64,
    pub p95_waste: f64,
    pub max_waste: f64,
}

/// Evaluate a strategy over an epoch of length samples.  An unknown
/// strategy name is a config error, not a panic.
pub fn evaluate_epoch(
    strategy: &str,
    lens: &[usize],
    model: &TrainTimeModel,
    global_batch: usize,
    n_ranks: usize,
    seed: u64,
) -> Result<BalanceReport> {
    let strategy_kind = Strategy::parse(strategy)?;
    let costs: Vec<f64> = lens.iter().map(|&l| simulated_workload(model, l)).collect();
    let mut rng = Rng::new(seed);
    let buckets = plan_epoch(lens.len(), global_batch, &mut rng);
    let mut wastes = Vec::with_capacity(buckets.len());
    for bucket in &buckets {
        let a = match strategy_kind {
            Strategy::Naive => assign_naive(bucket, n_ranks, &mut rng),
            Strategy::Balanced => assign_balanced(bucket, &costs, n_ranks),
        };
        wastes.push(a.waste_fraction(&costs));
    }
    wastes.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = wastes.len();
    Ok(BalanceReport {
        strategy: strategy.to_string(),
        mean_waste: wastes.iter().sum::<f64>() / n as f64,
        p95_waste: wastes[(n as f64 * 0.95) as usize % n],
        max_waste: wastes[n - 1],
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::workload::GenLenModel;
    use crate::util::prop;

    fn longtail_lens(n: usize, seed: u64) -> Vec<usize> {
        let m = GenLenModel::reasoning_default();
        let mut rng = Rng::new(seed);
        m.sample_batch(&mut rng, 0, n)
    }

    #[test]
    fn balanced_beats_naive() {
        let lens = longtail_lens(1024, 1);
        let model = TrainTimeModel::default_7b();
        let naive = evaluate_epoch("naive", &lens, &model, 128, 8, 2).unwrap();
        let bal = evaluate_epoch("balanced", &lens, &model, 128, 8, 2).unwrap();
        assert!(
            bal.mean_waste < naive.mean_waste * 0.5,
            "balanced {:?} vs naive {:?}",
            bal.mean_waste,
            naive.mean_waste
        );
    }

    #[test]
    fn paper_claim_under_10_percent() {
        let lens = longtail_lens(2048, 3);
        let model = TrainTimeModel::default_7b();
        let bal = evaluate_epoch("balanced", &lens, &model, 256, 8, 4).unwrap();
        assert!(bal.mean_waste < 0.10, "mean waste {}", bal.mean_waste);
    }

    #[test]
    fn unknown_strategy_is_a_config_error_not_a_panic() {
        let lens = longtail_lens(256, 6);
        let model = TrainTimeModel::default_7b();
        let err = evaluate_epoch("frobnicate", &lens, &model, 64, 4, 1)
            .unwrap_err()
            .to_string();
        assert!(
            err.contains("unknown balance strategy 'frobnicate'"),
            "error should name the bad strategy and the valid set: {err}"
        );
        assert!(err.contains("naive") && err.contains("balanced"), "{err}");
        assert_eq!(Strategy::parse("naive").unwrap(), Strategy::Naive);
        assert_eq!(Strategy::parse("balanced").unwrap(), Strategy::Balanced);
    }

    #[test]
    fn assignment_partitions_batch() {
        prop::check("balance-partition", |rng| {
            let n = 8 * (1 + rng.below(16));
            let batch: Vec<usize> = (0..n).collect();
            let costs: Vec<f64> = (0..n).map(|_| rng.range(0.1, 10.0)).collect();
            let ranks = [2, 4, 8][rng.below(3)];
            for a in [
                assign_balanced(&batch, &costs, ranks),
                assign_naive(&batch, ranks, rng),
            ] {
                let mut all: Vec<usize> = a.per_rank.iter().flatten().copied().collect();
                all.sort_unstable();
                crate::prop_assert!(
                    all == batch,
                    "assignment must partition the batch exactly"
                );
                let sizes: Vec<usize> = a.per_rank.iter().map(|r| r.len()).collect();
                let (mn, mx) = (
                    *sizes.iter().min().unwrap(),
                    *sizes.iter().max().unwrap(),
                );
                crate::prop_assert!(mx - mn <= 1, "rank sizes unbalanced: {sizes:?}");
            }
            Ok(())
        });
    }

    #[test]
    fn buckets_partition_and_shuffle() {
        prop::check("bucket-partition", |rng| {
            let gb = 16;
            let n = gb * (2 + rng.below(6));
            let buckets = plan_epoch(n, gb, rng);
            crate::prop_assert!(buckets.len() == n / gb, "bucket count");
            let mut all: Vec<usize> = buckets.iter().flatten().copied().collect();
            all.sort_unstable();
            crate::prop_assert!(
                all == (0..n).collect::<Vec<_>>(),
                "buckets must partition the epoch"
            );
            Ok(())
        });
    }

    #[test]
    fn bucket_shuffle_kills_sorted_bias() {
        // mean length per bucket should not be monotone in bucket order
        let lens = longtail_lens(1024, 9);
        let mut rng = Rng::new(10);
        let buckets = plan_epoch(lens.len(), 128, &mut rng);
        let means: Vec<f64> = buckets
            .iter()
            .map(|b| b.iter().map(|&i| lens[i] as f64).sum::<f64>() / b.len() as f64)
            .collect();
        let monotone = means.windows(2).all(|w| w[0] <= w[1])
            || means.windows(2).all(|w| w[0] >= w[1]);
        assert!(!monotone, "bucket order must be shuffled: {means:?}");
    }

    #[test]
    fn cancel_grace_scales_with_utilization() {
        // full batch: full grace; half batch: half grace (ceil); an idle
        // or degenerate batch cancels immediately
        assert_eq!(cancel_grace_steps(8, 4, 4), 8);
        assert_eq!(cancel_grace_steps(8, 2, 4), 4);
        assert_eq!(cancel_grace_steps(8, 1, 4), 2);
        assert_eq!(cancel_grace_steps(7, 1, 3), 3); // ceil(7/3)
        assert_eq!(cancel_grace_steps(8, 0, 4), 0);
        assert_eq!(cancel_grace_steps(8, 1, 0), 0);
        assert_eq!(cancel_grace_steps(0, 3, 4), 0);
        // live > batch is clamped, not amplified
        assert_eq!(cancel_grace_steps(8, 9, 4), 8);
    }

    #[test]
    fn nonuniform_reduces_waste_further() {
        let lens = longtail_lens(1024, 5);
        let model = TrainTimeModel::default_7b();
        let costs: Vec<f64> =
            lens.iter().map(|&l| simulated_workload(&model, l)).collect();
        let batch: Vec<usize> = (0..lens.len()).collect();
        let uniform = assign_balanced(&batch, &costs, 8).waste_fraction(&costs);
        let micro = assign_balanced_nonuniform(&batch, &costs, 8, 16);
        // waste of the non-uniform plan = weighted by micro-group max
        let mut total_max = 0.0;
        let mut total_mean = 0.0;
        for a in &micro {
            let rc = a.rank_costs(&costs);
            total_max += rc.iter().cloned().fold(0.0, f64::max);
            total_mean += rc.iter().sum::<f64>() / rc.len() as f64;
        }
        let waste = 1.0 - total_mean / total_max;
        // micro-grouping keeps waste in the same (small) band while bounding
        // per-micro memory; both are far under the paper's 10% bound
        assert!(waste <= uniform + 0.01, "nonuniform {waste} vs uniform {uniform}");
        assert!(waste < 0.05, "{waste}");
    }
}
