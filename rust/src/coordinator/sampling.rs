//! Advantage estimation + DAPO dynamic sampling (paper §3.2).
//!
//! * `grpo_advantages` — group-relative normalisation (mirrors the Python
//!   oracle `kernels/ref.py::grpo_advantage_ref`; cross-checked in tests).
//! * `gae` — generalised advantage estimation for the PPO/critic path
//!   (mirrors `gae_ref`).
//! * `dapo_filter` — "[39] proposes to filter out prompts with the accuracy
//!   equal to 1 and 0 ... and trigger re-sampling": groups whose rewards
//!   are all-max or all-min carry no gradient signal under GRPO and are
//!   dropped; the workflow regenerates until the batch is full.

use anyhow::{bail, Result};

/// Group-relative advantages: (r - mean) / (std + eps) within contiguous
/// groups of `group_size`.  Returns per-sequence advantages.
pub fn grpo_advantages(rewards: &[f32], group_size: usize) -> Result<Vec<f32>> {
    if group_size == 0 || rewards.len() % group_size != 0 {
        bail!("rewards len {} not divisible by group {group_size}", rewards.len());
    }
    let mut out = Vec::with_capacity(rewards.len());
    for group in rewards.chunks(group_size) {
        let n = group.len() as f32;
        let mean: f32 = group.iter().sum::<f32>() / n;
        let var: f32 = group.iter().map(|r| (r - mean) * (r - mean)).sum::<f32>() / n;
        let std = var.sqrt();
        for &r in group {
            out.push((r - mean) / (std + 1e-6));
        }
    }
    Ok(out)
}

/// Broadcast per-sequence advantages over the generated-token mask:
/// adv_token[b][t] = adv_seq[b] * mask[b][t].
pub fn broadcast_advantages(adv_seq: &[f32], masks: &[Vec<f32>]) -> Vec<Vec<f32>> {
    adv_seq
        .iter()
        .zip(masks)
        .map(|(&a, m)| m.iter().map(|&mk| a * mk).collect())
        .collect()
}

/// GAE over [B][S] token rewards/values (PPO path).
/// Returns (advantages, returns).
pub fn gae(
    rewards: &[Vec<f32>],
    values: &[Vec<f32>],
    masks: &[Vec<f32>],
    gamma: f32,
    lam: f32,
) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
    let mut advs = Vec::with_capacity(rewards.len());
    let mut rets = Vec::with_capacity(rewards.len());
    for ((r, v), m) in rewards.iter().zip(values).zip(masks) {
        let s = r.len();
        let mut adv = vec![0.0f32; s];
        let mut next_adv = 0.0f32;
        let mut next_val = 0.0f32;
        for t in (0..s).rev() {
            let delta = r[t] + gamma * next_val * m[t] - v[t];
            next_adv = delta + gamma * lam * next_adv * m[t];
            adv[t] = next_adv;
            next_val = v[t];
        }
        let ret: Vec<f32> = adv
            .iter()
            .zip(v)
            .zip(m)
            .map(|((a, vv), mm)| (a + vv) * mm)
            .collect();
        let adv: Vec<f32> = adv.iter().zip(m).map(|(a, mm)| a * mm).collect();
        advs.push(adv);
        rets.push(ret);
    }
    (advs, rets)
}

/// DAPO group filter: indices of groups that carry signal (not all-equal
/// reward — covers both "accuracy 1" and "accuracy 0" on binary rewards).
pub fn dapo_filter(rewards: &[f32], group_size: usize) -> Result<Vec<usize>> {
    if group_size == 0 || rewards.len() % group_size != 0 {
        bail!("rewards len {} not divisible by group {group_size}", rewards.len());
    }
    Ok(rewards
        .chunks(group_size)
        .enumerate()
        .filter(|(_, g)| {
            let first = g[0];
            g.iter().any(|&r| (r - first).abs() > 1e-6)
        })
        .map(|(i, _)| i)
        .collect())
}

/// DAPO filter aware of long-tail cancellation: a group containing a
/// rollout the scheduler preempted (`CancelPolicy`) has truncated,
/// unscoreable members — it is excluded outright, on top of the usual
/// no-signal filter.  Keeps acceptance decisions and straggler
/// preemption composable: cancelling never *adds* a group to the batch.
pub fn dapo_filter_with_cancelled(
    rewards: &[f32],
    group_size: usize,
    cancelled: &[bool],
) -> Result<Vec<usize>> {
    if cancelled.len() != rewards.len() {
        bail!(
            "cancelled flags len {} != rewards len {}",
            cancelled.len(),
            rewards.len()
        );
    }
    let keep = dapo_filter(rewards, group_size)?;
    Ok(keep
        .into_iter()
        .filter(|&g| !cancelled[g * group_size..(g + 1) * group_size].iter().any(|&c| c))
        .collect())
}

/// Whiten advantages batch-wide (optional PPO stabiliser).
pub fn whiten(adv: &mut [f32]) {
    let n = adv.len() as f32;
    if n < 2.0 {
        return;
    }
    let mean: f32 = adv.iter().sum::<f32>() / n;
    let var: f32 = adv.iter().map(|a| (a - mean) * (a - mean)).sum::<f32>() / n;
    let std = var.sqrt() + 1e-8;
    for a in adv {
        *a = (*a - mean) / std;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn grpo_matches_python_oracle_case() {
        // mirrored in python/tests/test_losses.py::test_grpo_advantage_zero_mean_unit_std
        let r = [1.0, 2.0, 3.0, 4.0, 10.0, 10.0, 10.0, 14.0];
        let adv = grpo_advantages(&r, 4).unwrap();
        for g in adv.chunks(4) {
            let mean: f32 = g.iter().sum::<f32>() / 4.0;
            assert!(mean.abs() < 1e-5);
        }
        // exact value check against numpy: group1 std = sqrt(1.25)
        let expected0 = (1.0f32 - 2.5) / (1.25f32.sqrt() + 1e-6);
        assert!((adv[0] - expected0).abs() < 1e-5, "{} vs {expected0}", adv[0]);
    }

    #[test]
    fn grpo_constant_group_zero() {
        let adv = grpo_advantages(&[5.0; 4], 4).unwrap();
        assert!(adv.iter().all(|a| a.abs() < 1e-3));
    }

    #[test]
    fn grpo_properties() {
        prop::check("grpo-zero-mean", |rng| {
            let gs = 2 + rng.below(6);
            let ngroups = 1 + rng.below(4);
            let rewards: Vec<f32> = (0..gs * ngroups)
                .map(|_| rng.range(-5.0, 5.0) as f32)
                .collect();
            let adv = grpo_advantages(&rewards, gs).unwrap();
            for g in adv.chunks(gs) {
                let mean: f32 = g.iter().sum::<f32>() / gs as f32;
                crate::prop_assert!(mean.abs() < 1e-4, "group mean {mean}");
            }
            Ok(())
        });
    }

    #[test]
    fn broadcast_respects_mask() {
        let adv = broadcast_advantages(&[2.0, -1.0], &[vec![0.0, 1.0], vec![1.0, 1.0]]);
        assert_eq!(adv, vec![vec![0.0, 2.0], vec![-1.0, -1.0]]);
    }

    #[test]
    fn gae_terminal_reward_decays() {
        // mirrors python test_gae_terminal_only_reward
        let (gamma, lam) = (0.9f32, 0.8f32);
        let rewards = vec![vec![0.0, 0.0, 0.0, 0.0, 1.0]];
        let values = vec![vec![0.0; 5]];
        let masks = vec![vec![1.0; 5]];
        let (adv, ret) = gae(&rewards, &values, &masks, gamma, lam);
        for t in 0..5 {
            let expected = (gamma * lam).powi((4 - t) as i32);
            assert!((adv[0][t] - expected).abs() < 1e-5, "t={t}");
        }
        assert_eq!(adv, ret);
    }

    #[test]
    fn gae_perfect_critic_zero_adv() {
        let rewards = vec![vec![0.0, 0.0, 0.0, 2.0]];
        let values = vec![vec![2.0; 4]];
        let masks = vec![vec![1.0; 4]];
        let (adv, _) = gae(&rewards, &values, &masks, 1.0, 1.0);
        assert!(adv[0].iter().all(|a| a.abs() < 1e-5), "{adv:?}");
    }

    #[test]
    fn dapo_drops_uninformative_groups() {
        // groups: mixed, all-correct, all-wrong, mixed
        let rewards = [1.0, 0.0, 1.0, 1.0, 1.0, 1.0, 0.0, 0.0, 0.0, 0.0, 1.0, 0.0];
        let keep = dapo_filter(&rewards, 3).unwrap();
        assert_eq!(keep, vec![0, 3]);
    }

    #[test]
    fn dapo_all_informative_keeps_all() {
        let rewards = [1.0, 0.0, 0.0, 1.0];
        assert_eq!(dapo_filter(&rewards, 2).unwrap(), vec![0, 1]);
    }

    #[test]
    fn dapo_with_cancelled_excludes_preempted_groups() {
        // groups: mixed, mixed-but-cancelled-member, all-equal, mixed
        let rewards = [1.0, 0.0, 0.0, 1.0, 1.0, 1.0, 1.0, 0.0];
        let cancelled = [false, false, true, false, false, false, false, false];
        let keep = dapo_filter_with_cancelled(&rewards, 2, &cancelled).unwrap();
        assert_eq!(keep, vec![0, 3]);
        // no cancellations: identical to the plain filter
        let none = [false; 8];
        assert_eq!(
            dapo_filter_with_cancelled(&rewards, 2, &none).unwrap(),
            dapo_filter(&rewards, 2).unwrap()
        );
        // flags length must match
        assert!(dapo_filter_with_cancelled(&rewards, 2, &[false; 3]).is_err());
    }

    #[test]
    fn whiten_normalises() {
        let mut a = vec![1.0, 2.0, 3.0, 4.0];
        whiten(&mut a);
        let mean: f32 = a.iter().sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-6);
    }

    #[test]
    fn invalid_group_sizes_rejected() {
        assert!(grpo_advantages(&[1.0; 5], 2).is_err());
        assert!(dapo_filter(&[1.0; 5], 0).is_err());
    }
}
