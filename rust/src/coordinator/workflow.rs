//! The 4-stage RLHF workflow (paper §2.2) as an explicit state machine.
//!
//! The workflow definition is shared by the real training loop
//! (`launch::run_training`) and the placement simulators (`placement::*`):
//! stages, their model roles, and the legal transitions — including the
//! *local* Generation↔Rewarding loop dynamic sampling needs (§3.1's "local
//! state transitions").

use crate::cluster::device::ModelRole;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    Generation,
    Rewarding,
    Preparation,
    Training,
}

impl Stage {
    pub fn name(&self) -> &'static str {
        match self {
            Stage::Generation => "generation",
            Stage::Rewarding => "rewarding",
            Stage::Preparation => "preparation",
            Stage::Training => "training",
        }
    }

    /// Roles that must be resident for this stage.
    pub fn roles(&self) -> &'static [ModelRole] {
        match self {
            Stage::Generation => &[ModelRole::PolicyGen],
            Stage::Rewarding => &[ModelRole::RewardGen],
            Stage::Preparation => &[ModelRole::PolicyTrain, ModelRole::Reference],
            Stage::Training => &[ModelRole::PolicyTrain],
        }
    }

    /// Legal successors.  Rewarding → Generation is the DAPO resample loop.
    pub fn next(&self) -> &'static [Stage] {
        match self {
            Stage::Generation => &[Stage::Rewarding],
            Stage::Rewarding => &[Stage::Generation, Stage::Preparation],
            Stage::Preparation => &[Stage::Training],
            Stage::Training => &[Stage::Generation],
        }
    }

    pub fn can_transition(&self, to: Stage) -> bool {
        self.next().contains(&to)
    }
}

/// Tracks a controller's stage + transition counts (telemetry / invariants).
#[derive(Debug, Clone)]
pub struct WorkflowState {
    pub stage: Stage,
    pub resample_loops: u64,
    pub steps_completed: u64,
}

impl Default for WorkflowState {
    fn default() -> Self {
        WorkflowState { stage: Stage::Training, resample_loops: 0, steps_completed: 0 }
    }
}

impl WorkflowState {
    pub fn transition(&mut self, to: Stage) -> anyhow::Result<()> {
        if !self.stage.can_transition(to) {
            anyhow::bail!("illegal transition {:?} -> {to:?}", self.stage);
        }
        if self.stage == Stage::Rewarding && to == Stage::Generation {
            self.resample_loops += 1;
        }
        if to == Stage::Training {
            self.steps_completed += 1;
        }
        self.stage = to;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canonical_cycle_is_legal() {
        let mut w = WorkflowState::default();
        for s in [Stage::Generation, Stage::Rewarding, Stage::Preparation, Stage::Training] {
            w.transition(s).unwrap();
        }
        assert_eq!(w.steps_completed, 1);
        assert_eq!(w.resample_loops, 0);
    }

    #[test]
    fn dapo_loop_counts_resamples() {
        let mut w = WorkflowState::default();
        w.transition(Stage::Generation).unwrap();
        w.transition(Stage::Rewarding).unwrap();
        w.transition(Stage::Generation).unwrap(); // resample
        w.transition(Stage::Rewarding).unwrap();
        w.transition(Stage::Preparation).unwrap();
        assert_eq!(w.resample_loops, 1);
    }

    #[test]
    fn illegal_transitions_rejected() {
        let mut w = WorkflowState::default();
        assert!(w.transition(Stage::Preparation).is_err());
        w.transition(Stage::Generation).unwrap();
        assert!(w.transition(Stage::Training).is_err());
    }

    #[test]
    fn stage_roles_cover_workflow() {
        assert!(Stage::Generation.roles().contains(&ModelRole::PolicyGen));
        assert!(Stage::Rewarding.roles().contains(&ModelRole::RewardGen));
        assert!(Stage::Training.roles().contains(&ModelRole::PolicyTrain));
    }
}
