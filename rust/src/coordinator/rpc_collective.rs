//! RPC-backed collectives (paper §3.1 + §4.2): the byte-level all-gather of
//! `CollectiveBackend` mapped onto the exactly-once RPC stack, so the
//! unchanged `Controller` code runs across OS processes.
//!
//! Topology: rank 0's process hosts a [`RendezvousHost`] service on an
//! `RpcServer` (exposed over TCP by `TcpRpcHost`, or in-proc for tests).
//! Every rank drives rounds through its own `RpcClient`:
//!
//! 1. `collective.offer` — contribute this rank's payload for round `seq`
//!    (idempotent per `(seq, rank)`, so client-level retries and duplicate
//!    deliveries can never double-contribute);
//! 2. `collective.poll` — poll until the round is complete; the reply
//!    carries every rank's payload in rank order.
//!
//! Both calls ride the retry-until-cached protocol of `rpc::client`: a lost
//! response is re-fetched from the server-side result cache under the same
//! request id, so the host's handler runs exactly once per delivered call
//! even through the fault-injecting transport.  A tag mismatch between
//! ranks (a collective-order bug) poisons the round: every participant gets
//! a hard server error, which the coordinator escalates into job
//! termination (the paper's fail-fast rule).
//!
//! Rounds are garbage-collected once every rank has received the result;
//! the host holds at most a handful of rounds at a time in lockstep
//! operation.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::coordinator::collective::CollectiveBackend;
use crate::rpc::client::{RetryPolicy, RpcClient};
use crate::rpc::server::{RpcServer, Service};
use crate::rpc::transport::Transport;
use crate::rpc::wire::{GatherFrame, GatherReply, HeartbeatFrame, LivenessReply, PollFrame};

pub const METHOD_OFFER: &str = "collective.offer";
pub const METHOD_POLL: &str = "collective.poll";
/// Renew a rank's liveness lease (see [`RendezvousHost::with_lease_ttl`]).
pub const METHOD_HEARTBEAT: &str = "collective.heartbeat";
/// Read the group's liveness verdict without renewing any lease.
pub const METHOD_ALIVE: &str = "collective.alive";

/// Typed collective status, replacing substring matching on error text.
///
/// Server-side failures cross the RPC boundary as error strings (the `Err`
/// payload of `rpc::wire::Response`), so each status embeds a stable
/// `[COLLECTIVE:…]` marker that survives the wire; [`CollectiveStatus::classify`]
/// parses it back out on the client side.  `launch` matches on the enum to
/// pick worker exit codes, and `train-dist` decodes those exit codes back
/// into a human-readable reason — no stringly-typed plumbing in between.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CollectiveStatus {
    /// A lockstep violation poisoned the round for every participant.
    Poisoned,
    /// Rank/world disagreement between a worker and the host.
    WorldMismatch,
    /// A peer never arrived; the round timed out (fail-fast, §4.2).
    RoundTimeout,
    /// Malformed protocol use (poll for a drained round, rank out of range).
    ProtocolViolation,
    /// A peer's heartbeat lease expired at the rendezvous host — abort
    /// fanout in milliseconds instead of waiting out the round timeout.
    /// The rank travels as `rank=N` text right after the marker (exit
    /// codes cannot carry it, so `from_exit_code` recovers rank 0).
    PeerDead { rank: u32 },
    /// A frame from a pre-recovery rendezvous generation was rejected
    /// (stale traffic from before a crash-restart, like a tombstoned RPC).
    StaleEpoch,
}

impl CollectiveStatus {
    pub const ALL: [CollectiveStatus; 6] = [
        CollectiveStatus::Poisoned,
        CollectiveStatus::WorldMismatch,
        CollectiveStatus::RoundTimeout,
        CollectiveStatus::ProtocolViolation,
        CollectiveStatus::PeerDead { rank: 0 },
        CollectiveStatus::StaleEpoch,
    ];

    /// The stable wire marker embedded in error text.
    pub fn marker(self) -> &'static str {
        match self {
            CollectiveStatus::Poisoned => "[COLLECTIVE:poisoned]",
            CollectiveStatus::WorldMismatch => "[COLLECTIVE:world-mismatch]",
            CollectiveStatus::RoundTimeout => "[COLLECTIVE:timeout]",
            CollectiveStatus::ProtocolViolation => "[COLLECTIVE:protocol]",
            CollectiveStatus::PeerDead { .. } => "[COLLECTIVE:peer-dead]",
            CollectiveStatus::StaleEpoch => "[COLLECTIVE:stale-epoch]",
        }
    }

    pub fn describe(self) -> &'static str {
        match self {
            CollectiveStatus::Poisoned => "round poisoned by a collective lockstep violation",
            CollectiveStatus::WorldMismatch => "world-size mismatch with the rendezvous host",
            CollectiveStatus::RoundTimeout => "collective round timed out (dead peer)",
            CollectiveStatus::ProtocolViolation => "collective protocol violation",
            CollectiveStatus::PeerDead { .. } => "a peer's heartbeat lease expired (rank dead)",
            CollectiveStatus::StaleEpoch => "stale rendezvous epoch (pre-recovery frame)",
        }
    }

    /// Process exit code a `train-worker` reports for this status (the
    /// parent decodes it with [`CollectiveStatus::from_exit_code`]).
    pub fn exit_code(self) -> i32 {
        match self {
            CollectiveStatus::Poisoned => 65,
            CollectiveStatus::WorldMismatch => 66,
            CollectiveStatus::RoundTimeout => 67,
            CollectiveStatus::ProtocolViolation => 68,
            CollectiveStatus::PeerDead { .. } => 69,
            CollectiveStatus::StaleEpoch => 70,
        }
    }

    pub fn from_exit_code(code: i32) -> Option<CollectiveStatus> {
        Self::ALL.into_iter().find(|s| s.exit_code() == code)
    }

    /// Recover the typed status from error text that crossed the RPC wire.
    /// `PeerDead` additionally parses the casualty rank out of the
    /// `rank=N` text the marker is always followed by.
    pub fn classify(text: &str) -> Option<CollectiveStatus> {
        let status = Self::ALL.into_iter().find(|s| text.contains(s.marker()))?;
        Some(match status {
            CollectiveStatus::PeerDead { .. } => {
                let after = &text[text.find(status.marker()).unwrap() + status.marker().len()..];
                let rank = after
                    .find("rank=")
                    .map(|ix| {
                        after[ix + "rank=".len()..]
                            .chars()
                            .take_while(char::is_ascii_digit)
                            .collect::<String>()
                    })
                    .and_then(|d| d.parse().ok())
                    .unwrap_or(0);
                CollectiveStatus::PeerDead { rank }
            }
            other => other,
        })
    }

    /// `classify` over a full anyhow error chain.
    pub fn classify_error(err: &anyhow::Error) -> Option<CollectiveStatus> {
        Self::classify(&format!("{err:#}"))
    }
}

struct Round {
    tag: String,
    parts: Vec<Option<Vec<u8>>>,
    /// encoded Ready reply, built once when the round completes (the parts
    /// are moved into it — no per-rank re-encode on the gradient hot path)
    ready_reply: Option<Vec<u8>>,
    /// ranks that have received the completed result (round GC)
    collected: Vec<bool>,
    n_collected: usize,
    /// set on a lockstep violation; every later participant fails fast
    poisoned: Option<String>,
}

impl Round {
    fn new(world: usize, tag: &str) -> Round {
        Round {
            tag: tag.to_string(),
            parts: vec![None; world],
            ready_reply: None,
            collected: vec![false; world],
            n_collected: 0,
            poisoned: None,
        }
    }
}

/// Per-rank heartbeat leases.  A lease starts at a rank's FIRST heartbeat
/// (slow process startup can never read as death) and lapses when no
/// renewal arrives within the TTL; the first lapse latches that rank as
/// dead for the lifetime of the host, so every later offer/poll/probe
/// from any rank fails immediately with the `PeerDead` marker.
struct LeaseTable {
    ttl: Duration,
    last_beat: HashMap<u32, Instant>,
    dead: Option<u32>,
}

impl LeaseTable {
    /// Latched liveness check: returns the first expired rank, forever.
    fn check(&mut self) -> Option<u32> {
        if self.dead.is_some() {
            return self.dead;
        }
        let now = Instant::now();
        self.dead = self
            .last_beat
            .iter()
            .filter(|(_, &t)| now.duration_since(t) > self.ttl)
            .map(|(&r, _)| r)
            .min();
        self.dead
    }
}

/// The rank-0 rendezvous service: accumulates per-round contributions and
/// hands the gathered payloads back to every rank.  Optionally (multi-
/// process launches) it also runs heartbeat leases and stamps every frame
/// with a recovery generation (`epoch`).
pub struct RendezvousHost {
    world: usize,
    /// recovery generation this host serves; frames from other epochs are
    /// rejected with `StaleEpoch`
    epoch: u64,
    rounds: Mutex<HashMap<u64, Round>>,
    leases: Option<Mutex<LeaseTable>>,
}

impl RendezvousHost {
    pub fn new(world: usize) -> RendezvousHost {
        assert!(world >= 1, "world must be >= 1");
        RendezvousHost {
            world,
            epoch: 0,
            rounds: Mutex::new(HashMap::new()),
            leases: None,
        }
    }

    /// Serve a specific recovery generation (supervisor respawns bump this).
    pub fn with_epoch(mut self, epoch: u64) -> Self {
        self.epoch = epoch;
        self
    }

    /// Enable heartbeat leases with the given TTL.
    pub fn with_lease_ttl(mut self, ttl: Duration) -> Self {
        self.leases = Some(Mutex::new(LeaseTable {
            ttl,
            last_beat: HashMap::new(),
            dead: None,
        }));
        self
    }

    /// Convenience: the host already wrapped in an `RpcServer`, ready for
    /// `TcpRpcHost::spawn` or `InProcTransport::new`.
    pub fn serve(world: usize) -> Arc<RpcServer<RendezvousHost>> {
        Arc::new(RpcServer::new(RendezvousHost::new(world)))
    }

    pub fn world_size(&self) -> usize {
        self.world
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Rounds currently buffered (0 once all ranks drained — test hook).
    pub fn open_rounds(&self) -> usize {
        self.rounds.lock().unwrap().len()
    }

    /// The latched liveness verdict (None with leases disabled).
    pub fn dead_rank(&self) -> Option<u32> {
        self.leases.as_ref().and_then(|l| l.lock().unwrap().check())
    }

    /// Fail fast when any lease has lapsed — the gate in front of every
    /// offer/poll, which is what turns one rank's death into millisecond
    /// abort fanout across all survivors (they poll every ~200 µs).
    fn check_liveness(&self) -> Result<()> {
        if let Some(rank) = self.dead_rank() {
            bail!(
                "{} rank={rank} heartbeat lease expired — peer declared dead; \
                 aborting the collective (fail-fast, §4.2)",
                CollectiveStatus::PeerDead { rank }.marker()
            );
        }
        Ok(())
    }

    fn check_epoch(&self, frame_epoch: u64) -> Result<()> {
        if frame_epoch != self.epoch {
            bail!(
                "{} frame from rendezvous epoch {frame_epoch} rejected: host \
                 serves epoch {} (stale pre-recovery traffic)",
                CollectiveStatus::StaleEpoch.marker(),
                self.epoch
            );
        }
        Ok(())
    }

    fn heartbeat(&self, frame: HeartbeatFrame) -> Result<Vec<u8>> {
        self.check_epoch(frame.epoch)?;
        if let Some(leases) = &self.leases {
            let mut t = leases.lock().unwrap();
            t.last_beat.insert(frame.rank, Instant::now());
            return Ok(LivenessReply { dead: t.check() }.encode());
        }
        Ok(LivenessReply { dead: None }.encode())
    }

    fn alive(&self, frame: HeartbeatFrame) -> Result<Vec<u8>> {
        self.check_epoch(frame.epoch)?;
        Ok(LivenessReply { dead: self.dead_rank() }.encode())
    }

    fn offer(&self, frame: GatherFrame) -> Result<Vec<u8>> {
        self.check_epoch(frame.epoch)?;
        self.check_liveness()?;
        if frame.world as usize != self.world {
            bail!(
                "{} world mismatch: rank {} believes world={}, host has {}",
                CollectiveStatus::WorldMismatch.marker(),
                frame.rank,
                frame.world,
                self.world
            );
        }
        let rank = frame.rank as usize;
        if rank >= self.world {
            bail!(
                "{} rank {rank} out of range for world {}",
                CollectiveStatus::ProtocolViolation.marker(),
                self.world
            );
        }
        let mut rounds = self.rounds.lock().unwrap();
        let round = rounds
            .entry(frame.seq)
            .or_insert_with(|| Round::new(self.world, &frame.tag));
        if let Some(msg) = round.poisoned.clone() {
            bail!("{msg}");
        }
        if round.tag != frame.tag {
            let msg = format!(
                "{} collective lockstep violation at round {}: host opened '{}', \
                 rank {rank} offered '{}'",
                CollectiveStatus::Poisoned.marker(),
                frame.seq,
                round.tag,
                frame.tag
            );
            round.poisoned = Some(msg.clone());
            bail!("{msg}");
        }
        // idempotent per (seq, rank): re-offers never double-contribute
        if round.parts[rank].is_none() {
            round.parts[rank] = Some(frame.payload);
        }
        Ok(Self::reply(&mut rounds, frame.seq, rank, self.world))
    }

    fn poll(&self, frame: PollFrame) -> Result<Vec<u8>> {
        self.check_epoch(frame.epoch)?;
        self.check_liveness()?;
        let rank = frame.rank as usize;
        if rank >= self.world {
            bail!(
                "{} rank {rank} out of range for world {}",
                CollectiveStatus::ProtocolViolation.marker(),
                self.world
            );
        }
        let mut rounds = self.rounds.lock().unwrap();
        match rounds.get(&frame.seq) {
            None => bail!(
                "{} poll for unknown or already-drained collective round {} \
                 (protocol violation)",
                CollectiveStatus::ProtocolViolation.marker(),
                frame.seq
            ),
            Some(round) => {
                if let Some(msg) = round.poisoned.clone() {
                    bail!("{msg}");
                }
            }
        }
        Ok(Self::reply(&mut rounds, frame.seq, rank, self.world))
    }

    fn reply(rounds: &mut HashMap<u64, Round>, seq: u64, rank: usize, world: usize) -> Vec<u8> {
        let round = rounds.get_mut(&seq).expect("round exists under lock");
        if round.ready_reply.is_none() {
            if round.parts.iter().any(|p| p.is_none()) {
                return GatherReply::Pending.encode();
            }
            // round complete: encode once, moving the parts out of the map
            let parts: Vec<Vec<u8>> =
                round.parts.iter_mut().map(|p| p.take().unwrap()).collect();
            round.ready_reply = Some(GatherReply::Ready(parts).encode());
        }
        if !round.collected[rank] {
            round.collected[rank] = true;
            round.n_collected += 1;
        }
        let reply = round.ready_reply.clone().unwrap();
        if round.n_collected == world {
            rounds.remove(&seq);
        }
        reply
    }
}

impl Service for RendezvousHost {
    fn handle(&self, method: &str, payload: &[u8]) -> Result<Vec<u8>> {
        match method {
            METHOD_OFFER => self.offer(GatherFrame::decode(payload)?),
            METHOD_POLL => self.poll(PollFrame::decode(payload)?),
            METHOD_HEARTBEAT => self.heartbeat(HeartbeatFrame::decode(payload)?),
            METHOD_ALIVE => self.alive(HeartbeatFrame::decode(payload)?),
            other => bail!("unknown collective method '{other}'"),
        }
    }
}

/// A worker's background heartbeat: renews this rank's lease at the
/// rendezvous host every `interval` until dropped.  Best-effort by design —
/// a send failure here never kills training (the collective path carries
/// the authoritative errors); what matters is that a LIVE rank keeps its
/// lease fresh and a dead one simply stops.
pub struct Heartbeat {
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Heartbeat {
    pub fn start<T: Transport + Send + 'static>(
        client: RpcClient<T>,
        rank: u32,
        epoch: u64,
        interval: Duration,
    ) -> Heartbeat {
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::spawn(move || {
            let frame = HeartbeatFrame { rank, epoch }.encode();
            while !stop2.load(Ordering::Relaxed) {
                let _ = client.call(METHOD_HEARTBEAT, frame.clone());
                // sleep in short slices so drop doesn't block a full interval
                let deadline = Instant::now() + interval;
                while Instant::now() < deadline && !stop2.load(Ordering::Relaxed) {
                    std::thread::sleep(interval.min(Duration::from_millis(10)));
                }
            }
        });
        Heartbeat { stop, handle: Some(handle) }
    }
}

impl Drop for Heartbeat {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// A rank's read-only view of the group's liveness verdict — what the ring
/// backend (which never talks to the rendezvous host on its data path)
/// polls between chunk waits so a dead peer surfaces in milliseconds
/// instead of the full ring round timeout.
pub struct LivenessProbe {
    client: Box<dyn Fn() -> Result<LivenessReply> + Send + Sync>,
    /// floor between actual probes: callers may invoke `check` per chunk
    /// wait slice; probes cheaper than this floor short-circuit to Ok
    min_interval: Duration,
    last_probe: Mutex<Option<Instant>>,
}

impl LivenessProbe {
    pub fn new<T: Transport + Send + Sync + 'static>(
        client: RpcClient<T>,
        rank: u32,
        epoch: u64,
        min_interval: Duration,
    ) -> LivenessProbe {
        let frame = HeartbeatFrame { rank, epoch }.encode();
        LivenessProbe {
            client: Box::new(move || {
                LivenessReply::decode(&client.call(METHOD_ALIVE, frame.clone())?)
            }),
            min_interval,
            last_probe: Mutex::new(None),
        }
    }

    /// Errors with the `PeerDead` marker when the host has latched a death;
    /// probe failures themselves are swallowed (the data path will time out
    /// on its own if the coordinator is truly gone).
    pub fn check(&self) -> Result<()> {
        {
            let mut last = self.last_probe.lock().unwrap();
            match *last {
                Some(t) if t.elapsed() < self.min_interval => return Ok(()),
                _ => *last = Some(Instant::now()),
            }
        }
        match (self.client)() {
            Ok(LivenessReply { dead: Some(rank) }) => bail!(
                "{} rank={rank} heartbeat lease expired — peer declared dead; \
                 aborting the ring collective (fail-fast, §4.2)",
                CollectiveStatus::PeerDead { rank }.marker()
            ),
            _ => Ok(()),
        }
    }
}

/// A rank's view of the group: `CollectiveBackend` implemented as RPC
/// rounds against the rank-0 [`RendezvousHost`].
pub struct RpcCollective<T: Transport> {
    client: RpcClient<T>,
    world: usize,
    /// recovery generation stamped on every frame (must match the host's)
    epoch: u64,
    next_seq: AtomicU64,
    /// sleep between result polls
    pub poll_interval: Duration,
    /// give up on a round after this long (a dead peer can never arrive;
    /// erroring here is the fail-fast signal — §4.2)
    pub round_timeout: Duration,
}

impl<T: Transport> RpcCollective<T> {
    pub fn new(transport: T, world: usize) -> RpcCollective<T> {
        let client = RpcClient::new(transport)
            .with_retry(RetryPolicy::exponential(64, Duration::from_micros(50)));
        RpcCollective {
            client,
            world,
            epoch: 0,
            next_seq: AtomicU64::new(0),
            poll_interval: Duration::from_micros(200),
            round_timeout: Duration::from_secs(300),
        }
    }

    pub fn with_epoch(mut self, epoch: u64) -> Self {
        self.epoch = epoch;
        self
    }

    pub fn with_retry(mut self, retry: RetryPolicy) -> Self {
        self.client.retry = retry;
        self
    }

    /// Constructor for one rank of a MULTI-PROCESS group: pins the RPC
    /// request-id namespace to the rank, because the default per-process
    /// counter would collide across workers sharing the rendezvous host.
    pub fn for_rank(transport: T, world: usize, rank: usize) -> RpcCollective<T> {
        assert!(rank < world, "rank {rank} out of range for world {world}");
        let mut c = Self::new(transport, world);
        // high bit keeps rank namespaces disjoint from in-process CLIENT_SEQ
        // bases (which grow from 1 << 40)
        c.client = c.client.with_id_base((1u64 << 63) | ((rank as u64) << 40));
        c
    }

    pub fn with_round_timeout(mut self, timeout: Duration) -> Self {
        self.round_timeout = timeout;
        self
    }

    pub fn client(&self) -> &RpcClient<T> {
        &self.client
    }
}

impl<T: Transport> CollectiveBackend for RpcCollective<T> {
    fn world_size(&self) -> usize {
        self.world
    }

    fn exchange(&self, rank: usize, tag: &str, payload: Vec<u8>) -> Result<Vec<Vec<u8>>> {
        let seq = self.next_seq.fetch_add(1, Ordering::SeqCst);
        let offer = GatherFrame {
            seq,
            rank: rank as u32,
            world: self.world as u32,
            epoch: self.epoch,
            tag: tag.to_string(),
            payload,
        }
        .encode();
        let t0 = Instant::now();
        let mut reply = self
            .client
            .call(METHOD_OFFER, offer)
            .with_context(|| format!("offering collective round {seq} ('{tag}')"))?;
        loop {
            match GatherReply::decode(&reply)? {
                GatherReply::Ready(parts) => return Ok(parts),
                GatherReply::Pending => {
                    if t0.elapsed() > self.round_timeout {
                        bail!(
                            "{} collective round {seq} ('{tag}') timed out after \
                             {:.0?} — a peer is likely dead; failing fast (§4.2)",
                            CollectiveStatus::RoundTimeout.marker(),
                            self.round_timeout
                        );
                    }
                    std::thread::sleep(self.poll_interval);
                }
            }
            let poll = PollFrame { seq, rank: rank as u32, epoch: self.epoch }.encode();
            reply = self
                .client
                .call(METHOD_POLL, poll)
                .with_context(|| format!("polling collective round {seq} ('{tag}')"))?;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::collective::Collective;
    use crate::rpc::transport::{FlakyTransport, InProcTransport};

    fn group(world: usize) -> (Arc<RpcServer<RendezvousHost>>, Vec<Arc<Collective>>) {
        let server = RendezvousHost::serve(world);
        let cols = (0..world)
            .map(|_| {
                Collective::with_backend(Arc::new(RpcCollective::new(
                    InProcTransport::new(server.clone()),
                    world,
                )))
            })
            .collect();
        (server, cols)
    }

    #[test]
    fn world_of_one_completes_immediately() {
        let (_server, cols) = group(1);
        assert_eq!(cols[0].mean_scalars(0, vec![7.0]).unwrap(), vec![7.0]);
        cols[0].barrier(0).unwrap();
    }

    #[test]
    fn scalars_mean_across_ranks_and_rounds() {
        let (server, cols) = group(3);
        let handles: Vec<_> = cols
            .into_iter()
            .enumerate()
            .map(|(rank, col)| {
                std::thread::spawn(move || -> Result<Vec<Vec<f64>>> {
                    (0..5)
                        .map(|round| {
                            col.mean_scalars(rank, vec![(rank * 3 + round) as f64])
                        })
                        .collect()
                })
            })
            .collect();
        let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap().unwrap()).collect();
        for round in 0..5 {
            // mean over ranks of (3*rank + round) = 3 + round
            for r in &results {
                assert_eq!(r[round], vec![3.0 + round as f64]);
            }
        }
        assert_eq!(server.service().open_rounds(), 0, "rounds must be GC'd");
    }

    #[test]
    fn duplicate_deliveries_never_double_contribute() {
        let world = 2;
        let server = RendezvousHost::serve(world);
        let cols: Vec<_> = (0..world)
            .map(|rank| {
                // every request delivered twice
                let flaky =
                    FlakyTransport::new(InProcTransport::new(server.clone()), 11 + rank as u64)
                        .with_probs(0.0, 0.0, 1.0);
                Collective::with_backend(Arc::new(RpcCollective::new(flaky, world)))
            })
            .collect();
        let handles: Vec<_> = cols
            .into_iter()
            .enumerate()
            .map(|(rank, col)| {
                std::thread::spawn(move || col.mean_scalars(rank, vec![rank as f64 * 2.0]))
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap().unwrap(), vec![1.0]);
        }
        assert!(
            server.stats().duplicates_served > 0,
            "test must actually exercise duplicate delivery"
        );
    }

    #[test]
    fn tag_mismatch_poisons_round_for_all_ranks() {
        let (_server, cols) = group(2);
        let col1 = cols[1].clone();
        let h = std::thread::spawn(move || col1.mean_scalars(1, vec![1.0]));
        // rank 0 runs a params all-reduce while rank 1 runs mean_scalars:
        // both must fail fast rather than exchange mismatched bytes
        let set = crate::runtime::params::ParamSet::new(vec![
            crate::runtime::tensor::Tensor::f32(vec![1], vec![1.0]),
        ]);
        let r0 = cols[0].all_reduce_mean(0, &set);
        let r1 = h.join().unwrap();
        assert!(r0.is_err() && r1.is_err(), "both ranks must fail fast");
        let err = r0.unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("lockstep"), "{msg}");
        // the poison travels as a TYPED status, not just prose
        assert_eq!(
            CollectiveStatus::classify_error(&err),
            Some(CollectiveStatus::Poisoned)
        );
        assert_eq!(
            CollectiveStatus::classify_error(&r1.unwrap_err()),
            Some(CollectiveStatus::Poisoned)
        );
    }

    #[test]
    fn typed_statuses_roundtrip_markers_and_exit_codes() {
        for s in CollectiveStatus::ALL {
            assert_eq!(CollectiveStatus::classify(s.marker()), Some(s), "{s:?}");
            assert_eq!(
                CollectiveStatus::classify(&format!("prefix {} suffix", s.marker())),
                Some(s)
            );
            assert_eq!(CollectiveStatus::from_exit_code(s.exit_code()), Some(s));
        }
        assert_eq!(CollectiveStatus::classify("plain worker error"), None);
        assert_eq!(CollectiveStatus::from_exit_code(1), None);
        assert_eq!(CollectiveStatus::from_exit_code(0), None);
    }

    #[test]
    fn world_mismatch_rejected() {
        let server = RendezvousHost::serve(2);
        let col = Collective::with_backend(Arc::new(RpcCollective::new(
            InProcTransport::new(server),
            3, // lies about world size
        )));
        assert!(col.barrier(0).is_err());
    }

    #[test]
    fn lease_expiry_latches_death_and_fails_offers_with_peer_dead() {
        let server = Arc::new(RpcServer::new(
            RendezvousHost::new(2).with_lease_ttl(Duration::from_millis(30)),
        ));
        let client = RpcClient::new(InProcTransport::new(server.clone()));
        // before any heartbeat: nobody holds a lease, nobody can be dead
        std::thread::sleep(Duration::from_millis(60));
        assert_eq!(server.service().dead_rank(), None, "no lease, no death");

        // rank 1 beats once, then goes silent past the TTL
        let beat = HeartbeatFrame { rank: 1, epoch: 0 }.encode();
        let reply = LivenessReply::decode(&client.call(METHOD_HEARTBEAT, beat).unwrap()).unwrap();
        assert_eq!(reply.dead, None);
        std::thread::sleep(Duration::from_millis(80));
        assert_eq!(server.service().dead_rank(), Some(1));

        // every collective call now fails immediately with the typed status
        let offer = GatherFrame {
            seq: 0,
            rank: 0,
            world: 2,
            epoch: 0,
            tag: "barrier".into(),
            payload: vec![],
        }
        .encode();
        let err = client.call(METHOD_OFFER, offer).unwrap_err();
        assert_eq!(
            CollectiveStatus::classify_error(&err),
            Some(CollectiveStatus::PeerDead { rank: 1 }),
            "{err:#}"
        );

        // a late heartbeat from the casualty cannot resurrect it (latched)
        let beat = HeartbeatFrame { rank: 1, epoch: 0 }.encode();
        let reply = LivenessReply::decode(&client.call(METHOD_HEARTBEAT, beat).unwrap()).unwrap();
        assert_eq!(reply.dead, Some(1), "death must latch");
    }

    #[test]
    fn heartbeats_within_ttl_keep_everyone_alive() {
        let server = Arc::new(RpcServer::new(
            RendezvousHost::new(2).with_lease_ttl(Duration::from_millis(100)),
        ));
        let client = RpcClient::new(InProcTransport::new(server.clone()));
        for _ in 0..10 {
            for rank in 0..2u32 {
                let beat = HeartbeatFrame { rank, epoch: 0 }.encode();
                let r =
                    LivenessReply::decode(&client.call(METHOD_HEARTBEAT, beat).unwrap()).unwrap();
                assert_eq!(r.dead, None);
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        assert_eq!(server.service().dead_rank(), None);
    }

    #[test]
    fn stale_epoch_frames_are_rejected() {
        let server = Arc::new(RpcServer::new(RendezvousHost::new(1).with_epoch(3)));
        let client = RpcClient::new(InProcTransport::new(server.clone()));
        let offer = GatherFrame {
            seq: 0,
            rank: 0,
            world: 1,
            epoch: 2, // pre-recovery generation
            tag: "barrier".into(),
            payload: vec![],
        }
        .encode();
        let err = client.call(METHOD_OFFER, offer).unwrap_err();
        assert_eq!(
            CollectiveStatus::classify_error(&err),
            Some(CollectiveStatus::StaleEpoch),
            "{err:#}"
        );
        // the matching epoch sails through
        let col = Collective::with_backend(Arc::new(
            RpcCollective::new(InProcTransport::new(server), 1).with_epoch(3),
        ));
        col.barrier(0).unwrap();
    }

    #[test]
    fn liveness_probe_reports_latched_death() {
        let server = Arc::new(RpcServer::new(
            RendezvousHost::new(2).with_lease_ttl(Duration::from_millis(20)),
        ));
        let beat_client = RpcClient::new(InProcTransport::new(server.clone()));
        let beat = HeartbeatFrame { rank: 0, epoch: 0 }.encode();
        beat_client.call(METHOD_HEARTBEAT, beat).unwrap();
        let probe = LivenessProbe::new(
            RpcClient::new(InProcTransport::new(server.clone())),
            1,
            0,
            Duration::from_millis(1),
        );
        assert!(probe.check().is_ok(), "alive while the lease is fresh");
        std::thread::sleep(Duration::from_millis(60));
        let err = probe.check().unwrap_err();
        assert_eq!(
            CollectiveStatus::classify_error(&err),
            Some(CollectiveStatus::PeerDead { rank: 0 })
        );
    }

    #[test]
    fn dead_peer_times_out_fail_fast() {
        let server = RendezvousHost::serve(2);
        let backend = RpcCollective::new(InProcTransport::new(server), 2)
            .with_round_timeout(Duration::from_millis(20));
        let col = Collective::with_backend(Arc::new(backend));
        // rank 1 never arrives
        let err = col.barrier(0).unwrap_err().to_string();
        assert!(err.contains("timed out"), "{err}");
    }
}
