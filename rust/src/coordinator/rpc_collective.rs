//! RPC-backed collectives (paper §3.1 + §4.2): the byte-level all-gather of
//! `CollectiveBackend` mapped onto the exactly-once RPC stack, so the
//! unchanged `Controller` code runs across OS processes.
//!
//! Topology: rank 0's process hosts a [`RendezvousHost`] service on an
//! `RpcServer` (exposed over TCP by `TcpRpcHost`, or in-proc for tests).
//! Every rank drives rounds through its own `RpcClient`:
//!
//! 1. `collective.offer` — contribute this rank's payload for round `seq`
//!    (idempotent per `(seq, rank)`, so client-level retries and duplicate
//!    deliveries can never double-contribute);
//! 2. `collective.poll` — poll until the round is complete; the reply
//!    carries every rank's payload in rank order.
//!
//! Both calls ride the retry-until-cached protocol of `rpc::client`: a lost
//! response is re-fetched from the server-side result cache under the same
//! request id, so the host's handler runs exactly once per delivered call
//! even through the fault-injecting transport.  A tag mismatch between
//! ranks (a collective-order bug) poisons the round: every participant gets
//! a hard server error, which the coordinator escalates into job
//! termination (the paper's fail-fast rule).
//!
//! Rounds are garbage-collected once every rank has received the result;
//! the host holds at most a handful of rounds at a time in lockstep
//! operation.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::coordinator::collective::CollectiveBackend;
use crate::rpc::client::{RetryPolicy, RpcClient};
use crate::rpc::server::{RpcServer, Service};
use crate::rpc::transport::Transport;
use crate::rpc::wire::{GatherFrame, GatherReply, PollFrame};

pub const METHOD_OFFER: &str = "collective.offer";
pub const METHOD_POLL: &str = "collective.poll";

/// Typed collective status, replacing substring matching on error text.
///
/// Server-side failures cross the RPC boundary as error strings (the `Err`
/// payload of `rpc::wire::Response`), so each status embeds a stable
/// `[COLLECTIVE:…]` marker that survives the wire; [`CollectiveStatus::classify`]
/// parses it back out on the client side.  `launch` matches on the enum to
/// pick worker exit codes, and `train-dist` decodes those exit codes back
/// into a human-readable reason — no stringly-typed plumbing in between.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CollectiveStatus {
    /// A lockstep violation poisoned the round for every participant.
    Poisoned,
    /// Rank/world disagreement between a worker and the host.
    WorldMismatch,
    /// A peer never arrived; the round timed out (fail-fast, §4.2).
    RoundTimeout,
    /// Malformed protocol use (poll for a drained round, rank out of range).
    ProtocolViolation,
}

impl CollectiveStatus {
    pub const ALL: [CollectiveStatus; 4] = [
        CollectiveStatus::Poisoned,
        CollectiveStatus::WorldMismatch,
        CollectiveStatus::RoundTimeout,
        CollectiveStatus::ProtocolViolation,
    ];

    /// The stable wire marker embedded in error text.
    pub fn marker(self) -> &'static str {
        match self {
            CollectiveStatus::Poisoned => "[COLLECTIVE:poisoned]",
            CollectiveStatus::WorldMismatch => "[COLLECTIVE:world-mismatch]",
            CollectiveStatus::RoundTimeout => "[COLLECTIVE:timeout]",
            CollectiveStatus::ProtocolViolation => "[COLLECTIVE:protocol]",
        }
    }

    pub fn describe(self) -> &'static str {
        match self {
            CollectiveStatus::Poisoned => "round poisoned by a collective lockstep violation",
            CollectiveStatus::WorldMismatch => "world-size mismatch with the rendezvous host",
            CollectiveStatus::RoundTimeout => "collective round timed out (dead peer)",
            CollectiveStatus::ProtocolViolation => "collective protocol violation",
        }
    }

    /// Process exit code a `train-worker` reports for this status (the
    /// parent decodes it with [`CollectiveStatus::from_exit_code`]).
    pub fn exit_code(self) -> i32 {
        match self {
            CollectiveStatus::Poisoned => 65,
            CollectiveStatus::WorldMismatch => 66,
            CollectiveStatus::RoundTimeout => 67,
            CollectiveStatus::ProtocolViolation => 68,
        }
    }

    pub fn from_exit_code(code: i32) -> Option<CollectiveStatus> {
        Self::ALL.into_iter().find(|s| s.exit_code() == code)
    }

    /// Recover the typed status from error text that crossed the RPC wire.
    pub fn classify(text: &str) -> Option<CollectiveStatus> {
        Self::ALL.into_iter().find(|s| text.contains(s.marker()))
    }

    /// `classify` over a full anyhow error chain.
    pub fn classify_error(err: &anyhow::Error) -> Option<CollectiveStatus> {
        Self::classify(&format!("{err:#}"))
    }
}

struct Round {
    tag: String,
    parts: Vec<Option<Vec<u8>>>,
    /// encoded Ready reply, built once when the round completes (the parts
    /// are moved into it — no per-rank re-encode on the gradient hot path)
    ready_reply: Option<Vec<u8>>,
    /// ranks that have received the completed result (round GC)
    collected: Vec<bool>,
    n_collected: usize,
    /// set on a lockstep violation; every later participant fails fast
    poisoned: Option<String>,
}

impl Round {
    fn new(world: usize, tag: &str) -> Round {
        Round {
            tag: tag.to_string(),
            parts: vec![None; world],
            ready_reply: None,
            collected: vec![false; world],
            n_collected: 0,
            poisoned: None,
        }
    }
}

/// The rank-0 rendezvous service: accumulates per-round contributions and
/// hands the gathered payloads back to every rank.
pub struct RendezvousHost {
    world: usize,
    rounds: Mutex<HashMap<u64, Round>>,
}

impl RendezvousHost {
    pub fn new(world: usize) -> RendezvousHost {
        assert!(world >= 1, "world must be >= 1");
        RendezvousHost { world, rounds: Mutex::new(HashMap::new()) }
    }

    /// Convenience: the host already wrapped in an `RpcServer`, ready for
    /// `TcpRpcHost::spawn` or `InProcTransport::new`.
    pub fn serve(world: usize) -> Arc<RpcServer<RendezvousHost>> {
        Arc::new(RpcServer::new(RendezvousHost::new(world)))
    }

    pub fn world_size(&self) -> usize {
        self.world
    }

    /// Rounds currently buffered (0 once all ranks drained — test hook).
    pub fn open_rounds(&self) -> usize {
        self.rounds.lock().unwrap().len()
    }

    fn offer(&self, frame: GatherFrame) -> Result<Vec<u8>> {
        if frame.world as usize != self.world {
            bail!(
                "{} world mismatch: rank {} believes world={}, host has {}",
                CollectiveStatus::WorldMismatch.marker(),
                frame.rank,
                frame.world,
                self.world
            );
        }
        let rank = frame.rank as usize;
        if rank >= self.world {
            bail!(
                "{} rank {rank} out of range for world {}",
                CollectiveStatus::ProtocolViolation.marker(),
                self.world
            );
        }
        let mut rounds = self.rounds.lock().unwrap();
        let round = rounds
            .entry(frame.seq)
            .or_insert_with(|| Round::new(self.world, &frame.tag));
        if let Some(msg) = round.poisoned.clone() {
            bail!("{msg}");
        }
        if round.tag != frame.tag {
            let msg = format!(
                "{} collective lockstep violation at round {}: host opened '{}', \
                 rank {rank} offered '{}'",
                CollectiveStatus::Poisoned.marker(),
                frame.seq,
                round.tag,
                frame.tag
            );
            round.poisoned = Some(msg.clone());
            bail!("{msg}");
        }
        // idempotent per (seq, rank): re-offers never double-contribute
        if round.parts[rank].is_none() {
            round.parts[rank] = Some(frame.payload);
        }
        Ok(Self::reply(&mut rounds, frame.seq, rank, self.world))
    }

    fn poll(&self, frame: PollFrame) -> Result<Vec<u8>> {
        let rank = frame.rank as usize;
        if rank >= self.world {
            bail!(
                "{} rank {rank} out of range for world {}",
                CollectiveStatus::ProtocolViolation.marker(),
                self.world
            );
        }
        let mut rounds = self.rounds.lock().unwrap();
        match rounds.get(&frame.seq) {
            None => bail!(
                "{} poll for unknown or already-drained collective round {} \
                 (protocol violation)",
                CollectiveStatus::ProtocolViolation.marker(),
                frame.seq
            ),
            Some(round) => {
                if let Some(msg) = round.poisoned.clone() {
                    bail!("{msg}");
                }
            }
        }
        Ok(Self::reply(&mut rounds, frame.seq, rank, self.world))
    }

    fn reply(rounds: &mut HashMap<u64, Round>, seq: u64, rank: usize, world: usize) -> Vec<u8> {
        let round = rounds.get_mut(&seq).expect("round exists under lock");
        if round.ready_reply.is_none() {
            if round.parts.iter().any(|p| p.is_none()) {
                return GatherReply::Pending.encode();
            }
            // round complete: encode once, moving the parts out of the map
            let parts: Vec<Vec<u8>> =
                round.parts.iter_mut().map(|p| p.take().unwrap()).collect();
            round.ready_reply = Some(GatherReply::Ready(parts).encode());
        }
        if !round.collected[rank] {
            round.collected[rank] = true;
            round.n_collected += 1;
        }
        let reply = round.ready_reply.clone().unwrap();
        if round.n_collected == world {
            rounds.remove(&seq);
        }
        reply
    }
}

impl Service for RendezvousHost {
    fn handle(&self, method: &str, payload: &[u8]) -> Result<Vec<u8>> {
        match method {
            METHOD_OFFER => self.offer(GatherFrame::decode(payload)?),
            METHOD_POLL => self.poll(PollFrame::decode(payload)?),
            other => bail!("unknown collective method '{other}'"),
        }
    }
}

/// A rank's view of the group: `CollectiveBackend` implemented as RPC
/// rounds against the rank-0 [`RendezvousHost`].
pub struct RpcCollective<T: Transport> {
    client: RpcClient<T>,
    world: usize,
    next_seq: AtomicU64,
    /// sleep between result polls
    pub poll_interval: Duration,
    /// give up on a round after this long (a dead peer can never arrive;
    /// erroring here is the fail-fast signal — §4.2)
    pub round_timeout: Duration,
}

impl<T: Transport> RpcCollective<T> {
    pub fn new(transport: T, world: usize) -> RpcCollective<T> {
        let client = RpcClient::new(transport).with_retry(RetryPolicy {
            max_attempts: 64,
            backoff: Duration::from_micros(50),
        });
        RpcCollective {
            client,
            world,
            next_seq: AtomicU64::new(0),
            poll_interval: Duration::from_micros(200),
            round_timeout: Duration::from_secs(300),
        }
    }

    pub fn with_retry(mut self, retry: RetryPolicy) -> Self {
        self.client.retry = retry;
        self
    }

    /// Constructor for one rank of a MULTI-PROCESS group: pins the RPC
    /// request-id namespace to the rank, because the default per-process
    /// counter would collide across workers sharing the rendezvous host.
    pub fn for_rank(transport: T, world: usize, rank: usize) -> RpcCollective<T> {
        assert!(rank < world, "rank {rank} out of range for world {world}");
        let mut c = Self::new(transport, world);
        // high bit keeps rank namespaces disjoint from in-process CLIENT_SEQ
        // bases (which grow from 1 << 40)
        c.client = c.client.with_id_base((1u64 << 63) | ((rank as u64) << 40));
        c
    }

    pub fn with_round_timeout(mut self, timeout: Duration) -> Self {
        self.round_timeout = timeout;
        self
    }

    pub fn client(&self) -> &RpcClient<T> {
        &self.client
    }
}

impl<T: Transport> CollectiveBackend for RpcCollective<T> {
    fn world_size(&self) -> usize {
        self.world
    }

    fn exchange(&self, rank: usize, tag: &str, payload: Vec<u8>) -> Result<Vec<Vec<u8>>> {
        let seq = self.next_seq.fetch_add(1, Ordering::SeqCst);
        let offer = GatherFrame {
            seq,
            rank: rank as u32,
            world: self.world as u32,
            tag: tag.to_string(),
            payload,
        }
        .encode();
        let t0 = Instant::now();
        let mut reply = self
            .client
            .call(METHOD_OFFER, offer)
            .with_context(|| format!("offering collective round {seq} ('{tag}')"))?;
        loop {
            match GatherReply::decode(&reply)? {
                GatherReply::Ready(parts) => return Ok(parts),
                GatherReply::Pending => {
                    if t0.elapsed() > self.round_timeout {
                        bail!(
                            "{} collective round {seq} ('{tag}') timed out after \
                             {:.0?} — a peer is likely dead; failing fast (§4.2)",
                            CollectiveStatus::RoundTimeout.marker(),
                            self.round_timeout
                        );
                    }
                    std::thread::sleep(self.poll_interval);
                }
            }
            let poll = PollFrame { seq, rank: rank as u32 }.encode();
            reply = self
                .client
                .call(METHOD_POLL, poll)
                .with_context(|| format!("polling collective round {seq} ('{tag}')"))?;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::collective::Collective;
    use crate::rpc::transport::{FlakyTransport, InProcTransport};

    fn group(world: usize) -> (Arc<RpcServer<RendezvousHost>>, Vec<Arc<Collective>>) {
        let server = RendezvousHost::serve(world);
        let cols = (0..world)
            .map(|_| {
                Collective::with_backend(Arc::new(RpcCollective::new(
                    InProcTransport::new(server.clone()),
                    world,
                )))
            })
            .collect();
        (server, cols)
    }

    #[test]
    fn world_of_one_completes_immediately() {
        let (_server, cols) = group(1);
        assert_eq!(cols[0].mean_scalars(0, vec![7.0]).unwrap(), vec![7.0]);
        cols[0].barrier(0).unwrap();
    }

    #[test]
    fn scalars_mean_across_ranks_and_rounds() {
        let (server, cols) = group(3);
        let handles: Vec<_> = cols
            .into_iter()
            .enumerate()
            .map(|(rank, col)| {
                std::thread::spawn(move || -> Result<Vec<Vec<f64>>> {
                    (0..5)
                        .map(|round| {
                            col.mean_scalars(rank, vec![(rank * 3 + round) as f64])
                        })
                        .collect()
                })
            })
            .collect();
        let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap().unwrap()).collect();
        for round in 0..5 {
            // mean over ranks of (3*rank + round) = 3 + round
            for r in &results {
                assert_eq!(r[round], vec![3.0 + round as f64]);
            }
        }
        assert_eq!(server.service().open_rounds(), 0, "rounds must be GC'd");
    }

    #[test]
    fn duplicate_deliveries_never_double_contribute() {
        let world = 2;
        let server = RendezvousHost::serve(world);
        let cols: Vec<_> = (0..world)
            .map(|rank| {
                // every request delivered twice
                let flaky =
                    FlakyTransport::new(InProcTransport::new(server.clone()), 11 + rank as u64)
                        .with_probs(0.0, 0.0, 1.0);
                Collective::with_backend(Arc::new(RpcCollective::new(flaky, world)))
            })
            .collect();
        let handles: Vec<_> = cols
            .into_iter()
            .enumerate()
            .map(|(rank, col)| {
                std::thread::spawn(move || col.mean_scalars(rank, vec![rank as f64 * 2.0]))
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap().unwrap(), vec![1.0]);
        }
        assert!(
            server.stats().duplicates_served > 0,
            "test must actually exercise duplicate delivery"
        );
    }

    #[test]
    fn tag_mismatch_poisons_round_for_all_ranks() {
        let (_server, cols) = group(2);
        let col1 = cols[1].clone();
        let h = std::thread::spawn(move || col1.mean_scalars(1, vec![1.0]));
        // rank 0 runs a params all-reduce while rank 1 runs mean_scalars:
        // both must fail fast rather than exchange mismatched bytes
        let set = crate::runtime::params::ParamSet::new(vec![
            crate::runtime::tensor::Tensor::f32(vec![1], vec![1.0]),
        ]);
        let r0 = cols[0].all_reduce_mean(0, &set);
        let r1 = h.join().unwrap();
        assert!(r0.is_err() && r1.is_err(), "both ranks must fail fast");
        let err = r0.unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("lockstep"), "{msg}");
        // the poison travels as a TYPED status, not just prose
        assert_eq!(
            CollectiveStatus::classify_error(&err),
            Some(CollectiveStatus::Poisoned)
        );
        assert_eq!(
            CollectiveStatus::classify_error(&r1.unwrap_err()),
            Some(CollectiveStatus::Poisoned)
        );
    }

    #[test]
    fn typed_statuses_roundtrip_markers_and_exit_codes() {
        for s in CollectiveStatus::ALL {
            assert_eq!(CollectiveStatus::classify(s.marker()), Some(s), "{s:?}");
            assert_eq!(
                CollectiveStatus::classify(&format!("prefix {} suffix", s.marker())),
                Some(s)
            );
            assert_eq!(CollectiveStatus::from_exit_code(s.exit_code()), Some(s));
        }
        assert_eq!(CollectiveStatus::classify("plain worker error"), None);
        assert_eq!(CollectiveStatus::from_exit_code(1), None);
        assert_eq!(CollectiveStatus::from_exit_code(0), None);
    }

    #[test]
    fn world_mismatch_rejected() {
        let server = RendezvousHost::serve(2);
        let col = Collective::with_backend(Arc::new(RpcCollective::new(
            InProcTransport::new(server),
            3, // lies about world size
        )));
        assert!(col.barrier(0).is_err());
    }

    #[test]
    fn dead_peer_times_out_fail_fast() {
        let server = RendezvousHost::serve(2);
        let backend = RpcCollective::new(InProcTransport::new(server), 2)
            .with_round_timeout(Duration::from_millis(20));
        let col = Collective::with_backend(Arc::new(backend));
        // rank 1 never arrives
        let err = col.barrier(0).unwrap_err().to_string();
        assert!(err.contains("timed out"), "{err}");
    }
}
