//! The parallel controller (paper §3.1) — the core system contribution.
//!
//! Each controller is one SPMD rank: it owns a shard of the data stream
//! and drives the full 4-stage RLHF workflow (§2.2) over its shard —
//! Generation → Rewarding → Preparation → Training — coordinating with its
//! peers only through collectives (gradient all-reduce, metric reduction).
//! There is **no central data plane**: rollouts, rewards and multimodal
//! payloads never leave their controller, which is exactly what removes
//! the single-controller memory/bandwidth wall (E1).
//!
//! Local state transitions (§3.1's motivation): because each controller
//! owns its shard end-to-end, a controller can loop Generation↔Rewarding
//! rounds for DAPO dynamic sampling *locally* while peers do the same,
//! without a global stage barrier — the collectives only appear at the
//! Training stage.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::config::RunConfig;
use crate::coordinator::collective::Collective;
use crate::coordinator::generation::{self, GenOutput, SamplerConfig};
use crate::coordinator::rollout;
use crate::coordinator::sampling;
use crate::data::tasks::{Task, TaskGen};
use crate::data::tokenizer;
use crate::metrics::StageTimers;
use crate::reward::Rewarder;
use crate::runtime::engine::Engine;
use crate::runtime::params::{ParamSet, TrainState};
use crate::runtime::tensor::Tensor;
use crate::util::rng::Rng;

/// Per-step telemetry (mean-reduced across controllers).
#[derive(Debug, Clone, Default)]
pub struct StepStats {
    pub step: usize,
    pub loss: f64,
    pub kl: f64,
    pub entropy: f64,
    pub clipfrac: f64,
    pub mean_reward: f64,
    /// ground-truth accuracy of the policy's responses
    pub accuracy: f64,
    pub mean_gen_len: f64,
    /// generation rounds used this step (dynamic sampling > 1)
    pub gen_rounds: f64,
}

/// One accepted rollout batch, ready for preparation/training.
pub struct RolloutBatch {
    pub tasks: Vec<Task>,
    pub gen: GenOutput,
    pub rewards: Vec<f32>,
    pub rounds: usize,
}

pub struct Controller {
    pub rank: usize,
    pub engine: Arc<Engine>,
    pub collective: Arc<Collective>,
    pub cfg: RunConfig,
    pub state: TrainState,
    pub ref_params: ParamSet,
    pub rewarder: Rewarder,
    pub taskgen: TaskGen,
    pub rng: Rng,
    pub timers: Arc<StageTimers>,
}

impl Controller {
    pub fn new(
        rank: usize,
        engine: Arc<Engine>,
        collective: Arc<Collective>,
        cfg: RunConfig,
        policy: ParamSet,
        rewarder: Rewarder,
    ) -> Result<Controller> {
        let dims = engine.manifest().dims.clone();
        if dims.batch % cfg.group_size != 0 {
            bail!(
                "group_size {} must divide artifact batch {}",
                cfg.group_size,
                dims.batch
            );
        }
        let tree = engine.manifest().policy_tree.clone();
        let mut root = Rng::new(cfg.seed);
        let rng = root.fork(rank as u64 + 1);
        let taskgen = TaskGen::new(
            cfg.task_kinds()?,
            cfg.seed ^ (rank as u64).wrapping_mul(0x9E3779B97F4A7C15),
        );
        Ok(Controller {
            rank,
            ref_params: policy.clone(),
            state: TrainState::new(policy, &tree),
            engine,
            collective,
            cfg,
            rewarder,
            taskgen,
            rng,
            timers: Arc::new(StageTimers::new()),
        })
    }

    fn sampler_cfg(&self) -> SamplerConfig {
        SamplerConfig {
            temperature: self.cfg.temperature,
            top_k: self.cfg.top_k,
            stop_at_eos: true,
        }
    }

    /// Scheduler options derived from the run config (page geometry +
    /// pool size for the paged KV cache).
    fn rollout_opts(&self, cancel: Option<rollout::CancelPolicy>) -> rollout::RolloutOptions {
        rollout::RolloutOptions {
            page_size: self.cfg.kv_page_size,
            pool_pages: self.cfg.kv_cache_pages,
            cancel,
            ..rollout::RolloutOptions::default()
        }
    }

    /// Freeze the current policy as the KL reference (post-SFT).
    pub fn freeze_reference(&mut self) {
        self.ref_params = self.state.params.clone();
    }

    // -----------------------------------------------------------------
    // SFT warm-start (demonstrations → cross-entropy)
    // -----------------------------------------------------------------

    pub fn sft_step(&mut self) -> Result<f32> {
        let dims = self.engine.manifest().dims.clone();
        let (b, s, p) = (dims.batch, dims.max_seq, dims.prompt_len);
        let mut rows = Vec::with_capacity(b);
        let mut masks = Vec::with_capacity(b);
        for _ in 0..b {
            let task = self.taskgen.sample();
            let (row, mask) = task.demonstration(p, s)?;
            rows.push(row);
            masks.push(mask);
        }
        let rows_t = generation::rows_tensor(&rows);
        let masks_t = generation::masks_tensor(&masks);
        let mut inputs: Vec<&Tensor> = self.state.params.tensors.iter().collect();
        inputs.push(&rows_t);
        inputs.push(&masks_t);
        let mut out = self.engine.run_refs("sft_grad", &inputs)?;
        let loss = out.pop().unwrap().scalar_value_f32()?;
        let grads = ParamSet::new(out);
        let grads = if self.collective.world_size() > 1 {
            self.collective.all_reduce_mean_bucketed(
                self.rank,
                grads,
                self.cfg.allreduce_bucket_bytes,
            )?
        } else {
            grads
        };
        self.state
            .apply_grads(&self.engine, "adam_policy", &grads, self.cfg.sft_lr)?;
        Ok(loss)
    }

    // -----------------------------------------------------------------
    // Stages 1+2: generation + rewarding (with local DAPO resampling)
    // -----------------------------------------------------------------

    /// One generation+rewarding round over a fresh prompt batch.
    fn rollout_round(&mut self) -> Result<(Vec<Task>, GenOutput, Vec<f32>)> {
        let dims = self.engine.manifest().dims.clone();
        let (b, p, g) = (dims.batch, dims.prompt_len, self.cfg.group_size);
        // B/g distinct prompts, each repeated g times (GRPO groups)
        let n_groups = b / g;
        let mut tasks = Vec::with_capacity(b);
        for _ in 0..n_groups {
            let t = self.taskgen.sample();
            for _ in 0..g {
                tasks.push(t.clone());
            }
        }
        let prompts: Vec<Vec<i32>> = tasks
            .iter()
            .map(|t| t.prompt_tokens(p))
            .collect::<Result<_>>()?;
        let engine = self.engine.clone();
        let scfg = self.sampler_cfg();
        let gen = self.timers.time("1_generation", || {
            generation::generate(&engine, &self.state.params, &prompts, &scfg, &mut self.rng)
        })?;
        let rewards = self.timers.time("2_rewarding", || {
            self.rewarder.score(&engine, &tasks, &gen)
        })?;
        Ok((tasks, gen, rewards))
    }

    /// One generation+rewarding round through the rollout scheduler with
    /// long-tail preemption armed: once `needed_rows` sequences finish,
    /// stragglers get a utilization-scaled grace window and are then
    /// cancelled, their KV pages reclaimed.  Returns per-row cancelled
    /// flags so DAPO can exclude preempted groups.
    #[allow(clippy::type_complexity)]
    fn rollout_round_cancel(
        &mut self,
        needed_rows: usize,
    ) -> Result<(Vec<Task>, GenOutput, Vec<f32>, Vec<bool>)> {
        let dims = self.engine.manifest().dims.clone();
        let (b, p, g) = (dims.batch, dims.prompt_len, self.cfg.group_size);
        let n_groups = b / g;
        let mut tasks = Vec::with_capacity(b);
        for _ in 0..n_groups {
            let t = self.taskgen.sample();
            for _ in 0..g {
                tasks.push(t.clone());
            }
        }
        let requests: Vec<rollout::RolloutRequest> = tasks
            .iter()
            .enumerate()
            .map(|(id, t)| {
                Ok(rollout::RolloutRequest { id, prompt: t.prompt_tokens(p)? })
            })
            .collect::<Result<_>>()?;
        let opts = self.rollout_opts(Some(rollout::CancelPolicy {
            needed: needed_rows.min(b),
            grace_steps: self.cfg.rollout_cancel_grace,
        }));
        let engine = self.engine.clone();
        let scfg = self.sampler_cfg();
        let run = self.timers.time("1_generation", || {
            rollout::run(&engine, &self.state.params, &requests, &scfg, &mut self.rng, &opts)
        })?;
        let cancelled: Vec<bool> = run.results.iter().map(|r| r.cancelled).collect();
        let gen = generation::gen_output_from(run.results);
        let rewards = self.timers.time("2_rewarding", || {
            self.rewarder.score(&engine, &tasks, &gen)
        })?;
        Ok((tasks, gen, rewards, cancelled))
    }

    /// Stages 1-2 with DAPO dynamic sampling: locally regenerate until a
    /// full batch of informative groups is collected (paper §3.2) or the
    /// round budget is exhausted (then pad with the freshest groups).
    pub fn collect_rollout(&mut self) -> Result<RolloutBatch> {
        let dims = self.engine.manifest().dims.clone();
        let (b, g) = (dims.batch, self.cfg.group_size);

        if !self.cfg.dynamic_sampling {
            let (tasks, gen, rewards) = self.rollout_round()?;
            return Ok(RolloutBatch { tasks, gen, rewards, rounds: 1 });
        }

        let mut acc_tasks: Vec<Task> = Vec::new();
        let mut acc_rows: Vec<Vec<i32>> = Vec::new();
        let mut acc_masks: Vec<Vec<f32>> = Vec::new();
        let mut acc_lens: Vec<usize> = Vec::new();
        let mut acc_rewards: Vec<f32> = Vec::new();
        let mut last_round: Option<(Vec<Task>, GenOutput, Vec<f32>)> = None;
        let mut rounds = 0;

        while acc_tasks.len() < b && rounds < self.cfg.max_resample_rounds {
            rounds += 1;
            let (tasks, gen, rewards, keep) = if self.cfg.rollout_cancel {
                // long-tail preemption: stop decoding stragglers once the
                // round has produced the rows this batch still needs;
                // preempted groups are excluded from acceptance
                let needed = b - acc_tasks.len();
                let (tasks, gen, rewards, cancelled) = self.rollout_round_cancel(needed)?;
                let keep = sampling::dapo_filter_with_cancelled(&rewards, g, &cancelled)?;
                (tasks, gen, rewards, keep)
            } else {
                let (tasks, gen, rewards) = self.rollout_round()?;
                let keep = sampling::dapo_filter(&rewards, g)?;
                (tasks, gen, rewards, keep)
            };
            for &gi in &keep {
                if acc_tasks.len() >= b {
                    break;
                }
                let lo = gi * g;
                for i in lo..lo + g {
                    acc_tasks.push(tasks[i].clone());
                    acc_rows.push(gen.rows[i].clone());
                    acc_masks.push(gen.masks[i].clone());
                    acc_lens.push(gen.gen_lens[i]);
                    acc_rewards.push(rewards[i]);
                }
            }
            last_round = Some((tasks, gen, rewards));
        }

        // pad with (possibly uninformative) groups from the last round so
        // the fixed-shape batch is always full
        if acc_tasks.len() < b {
            let (tasks, gen, rewards) = last_round.context("no rollout rounds ran")?;
            let mut gi = 0;
            while acc_tasks.len() < b {
                let lo = gi * g;
                for i in lo..lo + g {
                    acc_tasks.push(tasks[i].clone());
                    acc_rows.push(gen.rows[i].clone());
                    acc_masks.push(gen.masks[i].clone());
                    acc_lens.push(gen.gen_lens[i]);
                    acc_rewards.push(rewards[i]);
                }
                gi += 1;
            }
        }
        acc_tasks.truncate(b);
        acc_rows.truncate(b);
        acc_masks.truncate(b);
        acc_lens.truncate(b);
        acc_rewards.truncate(b);

        Ok(RolloutBatch {
            tasks: acc_tasks,
            gen: GenOutput { rows: acc_rows, gen_lens: acc_lens, masks: acc_masks },
            rewards: acc_rewards,
            rounds,
        })
    }

    // -----------------------------------------------------------------
    // Stages 3+4: preparation + training
    // -----------------------------------------------------------------

    fn logprob(&self, params: &ParamSet, tokens: &Tensor) -> Result<Tensor> {
        let mut inputs: Vec<&Tensor> = params.tensors.iter().collect();
        inputs.push(tokens);
        Ok(self.engine.run_refs("logprob", &inputs)?.remove(0))
    }

    /// One full RLHF step.  Returns stats mean-reduced across controllers.
    pub fn rlhf_step(&mut self, step: usize) -> Result<StepStats> {
        let dims = self.engine.manifest().dims.clone();
        let (b, s) = (dims.batch, dims.max_seq);
        let batch = self.collect_rollout()?;

        // ---- Stage 3: preparation ------------------------------------
        let tokens = generation::rows_tensor(&batch.gen.rows);
        let mask = generation::masks_tensor(&batch.gen.masks);
        let (old_logp, ref_logp) = self.timers.time("3_preparation", || {
            let old = self.logprob(&self.state.params, &tokens)?;
            let rf = self.logprob(&self.ref_params, &tokens)?;
            anyhow::Ok((old, rf))
        })?;
        let adv_seq = sampling::grpo_advantages(&batch.rewards, self.cfg.group_size)?;
        let adv_rows = sampling::broadcast_advantages(&adv_seq, &batch.gen.masks);
        let adv = Tensor::f32(vec![b, s], adv_rows.iter().flatten().copied().collect());

        // ---- Stage 4: training ---------------------------------------
        let timers = self.timers.clone();
        let (loss, kl, entropy, clipfrac) = timers.time("4_training", || {
            self.train_on(&tokens, &mask, &adv, &old_logp, &ref_logp)
        })?;

        // ---- telemetry (reduced) ---------------------------------------
        let responses: Vec<String> = batch
            .gen
            .rows
            .iter()
            .map(|r| tokenizer::extract_response(r, dims.prompt_len))
            .collect();
        let correct = batch
            .tasks
            .iter()
            .zip(&responses)
            .filter(|(t, r)| t.check(r))
            .count() as f64;
        let local = vec![
            loss as f64,
            kl as f64,
            entropy as f64,
            clipfrac as f64,
            batch.rewards.iter().map(|&r| r as f64).sum::<f64>() / b as f64,
            correct / b as f64,
            batch.gen.gen_lens.iter().sum::<usize>() as f64 / b as f64,
            batch.rounds as f64,
        ];
        let reduced = if self.collective.world_size() > 1 {
            self.collective.mean_scalars(self.rank, local)?
        } else {
            local
        };
        Ok(StepStats {
            step,
            loss: reduced[0],
            kl: reduced[1],
            entropy: reduced[2],
            clipfrac: reduced[3],
            mean_reward: reduced[4],
            accuracy: reduced[5],
            mean_gen_len: reduced[6],
            gen_rounds: reduced[7],
        })
    }

    /// Stage-4 body: fused fast path at world=1, grad + all-reduce + adam
    /// otherwise (verified equivalent in runtime_integration tests).
    fn train_on(
        &mut self,
        tokens: &Tensor,
        mask: &Tensor,
        adv: &Tensor,
        old_logp: &Tensor,
        ref_logp: &Tensor,
    ) -> Result<(f32, f32, f32, f32)> {
        let n = self.state.params.tensors.len();
        if self.collective.world_size() == 1 {
            self.state.step += 1;
            let step_t = Tensor::scalar_f32(self.state.step as f32);
            let lr_t = Tensor::scalar_f32(self.cfg.lr);
            let clip_t = Tensor::scalar_f32(self.cfg.clip_eps);
            let kl_t = Tensor::scalar_f32(self.cfg.kl_coef);
            let ent_t = Tensor::scalar_f32(self.cfg.ent_coef);
            let mut inputs: Vec<&Tensor> = Vec::with_capacity(3 * n + 10);
            inputs.extend(self.state.params.tensors.iter());
            inputs.extend(self.state.m.tensors.iter());
            inputs.extend(self.state.v.tensors.iter());
            inputs.extend([tokens, mask, adv, old_logp, ref_logp]);
            inputs.extend([&step_t, &lr_t, &clip_t, &kl_t, &ent_t]);
            let mut out = self.engine.run_refs("train_step", &inputs)?;
            let clipfrac = out.pop().unwrap().scalar_value_f32()?;
            let entropy = out.pop().unwrap().scalar_value_f32()?;
            let kl = out.pop().unwrap().scalar_value_f32()?;
            let loss = out.pop().unwrap().scalar_value_f32()?;
            let v = out.split_off(2 * n);
            let m = out.split_off(n);
            self.state.params = ParamSet::new(out);
            self.state.m = ParamSet::new(m);
            self.state.v = ParamSet::new(v);
            Ok((loss, kl, entropy, clipfrac))
        } else {
            let clip_t = Tensor::scalar_f32(self.cfg.clip_eps);
            let kl_t = Tensor::scalar_f32(self.cfg.kl_coef);
            let ent_t = Tensor::scalar_f32(self.cfg.ent_coef);
            let mut inputs: Vec<&Tensor> = self.state.params.tensors.iter().collect();
            inputs.extend([tokens, mask, adv, old_logp, ref_logp]);
            inputs.extend([&clip_t, &kl_t, &ent_t]);
            let mut out = self.engine.run_refs("policy_grad", &inputs)?;
            let clipfrac = out.pop().unwrap().scalar_value_f32()?;
            let entropy = out.pop().unwrap().scalar_value_f32()?;
            let kl = out.pop().unwrap().scalar_value_f32()?;
            let loss = out.pop().unwrap().scalar_value_f32()?;
            let grads = ParamSet::new(out);
            // bucketed + overlapped: bucket k is on the wire (communicator
            // thread) while bucket k+1 serializes and finished buckets
            // decode/scale in the grads' own storage — bit-identical to the
            // monolithic reduce
            let bucket_bytes = self.cfg.allreduce_bucket_bytes;
            let grads = self.timers.time("4_grad_allreduce", || {
                self.collective
                    .all_reduce_mean_bucketed(self.rank, grads, bucket_bytes)
            })?;
            self.state
                .apply_grads(&self.engine, "adam_policy", &grads, self.cfg.lr)?;
            Ok((loss, kl, entropy, clipfrac))
        }
    }

    /// Greedy-decoded accuracy on held-out tasks (evaluation).
    pub fn evaluate(&mut self, n_batches: usize) -> Result<f64> {
        let dims = self.engine.manifest().dims.clone();
        let scfg = SamplerConfig { temperature: 0.0, top_k: 1, stop_at_eos: true };
        let mut correct = 0usize;
        let mut total = 0usize;
        let mut eval_gen = TaskGen::new(self.cfg.task_kinds()?, 0xEAA1 + self.rank as u64);
        for _ in 0..n_batches {
            let tasks: Vec<Task> = eval_gen.sample_n(dims.batch);
            let prompts: Vec<Vec<i32>> = tasks
                .iter()
                .map(|t| t.prompt_tokens(dims.prompt_len))
                .collect::<Result<_>>()?;
            let gen = generation::generate(
                &self.engine,
                &self.state.params,
                &prompts,
                &scfg,
                &mut self.rng,
            )?;
            for (t, row) in tasks.iter().zip(&gen.rows) {
                let resp = tokenizer::extract_response(row, dims.prompt_len);
                if t.check(&resp) {
                    correct += 1;
                }
                total += 1;
            }
        }
        Ok(correct as f64 / total.max(1) as f64)
    }
}
