//! Reward-model pre-training utilities (single-engine, used before the
//! RLHF loop starts):
//!
//! * `train_bt` — Bradley-Terry reward model on synthetic preference pairs
//!   (the paper's "traditional Bradley-Terry reward model" baseline, §5);
//! * `train_verifier` — generative verifier SFT on labelled verification
//!   strings (the paper's generative-reward path, §3.2 / [48]).

use anyhow::Result;

use crate::coordinator::generation;
use crate::data::tasks::{preference_pair, verifier_example, TaskGen, TaskKind};
use crate::runtime::engine::Engine;
use crate::runtime::params::{init_policy, init_scalar, ParamSet, TrainState};
use crate::runtime::tensor::Tensor;

pub struct PretrainReport {
    pub losses: Vec<f32>,
    /// final training-batch metric: pairwise accuracy (BT) or label
    /// accuracy (verifier)
    pub final_metric: f32,
}

/// Train a Bradley-Terry reward model.  Returns (params, report).
pub fn train_bt(
    engine: &Engine,
    kinds: Vec<TaskKind>,
    steps: usize,
    lr: f32,
    seed: u64,
) -> Result<(ParamSet, PretrainReport)> {
    let dims = engine.manifest().dims.clone();
    let (b, s, p) = (dims.batch, dims.max_seq, dims.prompt_len);
    let tree = engine.manifest().scalar_tree.clone();
    let mut state = TrainState::new(init_scalar(engine, seed as u32)?, &tree);
    let mut gen = TaskGen::new(kinds, seed);
    let mut losses = Vec::with_capacity(steps);
    let mut acc = 0.0f32;
    let n = state.params.tensors.len();
    for _ in 0..steps {
        let mut chosen = Vec::with_capacity(b * s);
        let mut rejected = Vec::with_capacity(b * s);
        let mut cidx = Vec::with_capacity(b);
        let mut ridx = Vec::with_capacity(b);
        for _ in 0..b {
            let pair = preference_pair(&mut gen, p, s)?;
            chosen.extend(pair.chosen);
            rejected.extend(pair.rejected);
            cidx.push(pair.chosen_idx as i32);
            ridx.push(pair.rejected_idx as i32);
        }
        let mut inputs = state.params.tensors.clone();
        inputs.push(Tensor::i32(vec![b, s], chosen));
        inputs.push(Tensor::i32(vec![b, s], rejected));
        inputs.push(Tensor::i32(vec![b], cidx));
        inputs.push(Tensor::i32(vec![b], ridx));
        let mut out = engine.run("bt_grad", &inputs)?;
        acc = out.pop().unwrap().scalar_value_f32()?;
        let loss = out.pop().unwrap().scalar_value_f32()?;
        out.truncate(n);
        let grads = ParamSet::new(out);
        state.apply_grads(engine, "adam_scalar", &grads, lr)?;
        losses.push(loss);
    }
    Ok((state.params, PretrainReport { losses, final_metric: acc }))
}

/// SFT-train a generative verifier LM.  Returns (params, report).
pub fn train_verifier(
    engine: &Engine,
    kinds: Vec<TaskKind>,
    steps: usize,
    lr: f32,
    seed: u64,
) -> Result<(ParamSet, PretrainReport)> {
    let dims = engine.manifest().dims.clone();
    let (b, s, p) = (dims.batch, dims.max_seq, dims.prompt_len);
    let tree = engine.manifest().policy_tree.clone();
    let mut state = TrainState::new(init_policy(engine, seed as u32)?, &tree);
    let mut gen = TaskGen::new(kinds.clone(), seed);
    let mut losses = Vec::with_capacity(steps);
    let n = state.params.tensors.len();
    for _ in 0..steps {
        let mut rows = Vec::with_capacity(b);
        let mut masks = Vec::with_capacity(b);
        for _ in 0..b {
            let (row, mask, _correct) = verifier_example(&mut gen, p, s)?;
            rows.push(row);
            masks.push(mask);
        }
        let mut inputs = state.params.tensors.clone();
        inputs.push(generation::rows_tensor(&rows));
        inputs.push(generation::masks_tensor(&masks));
        let mut out = engine.run("sft_grad", &inputs)?;
        let loss = out.pop().unwrap().scalar_value_f32()?;
        out.truncate(n);
        let grads = ParamSet::new(out);
        state.apply_grads(engine, "adam_policy", &grads, lr)?;
        losses.push(loss);
    }
    // measure verdict accuracy on fresh labelled examples
    let metric = verifier_accuracy(engine, &state.params, kinds, seed + 1)?;
    Ok((state.params, PretrainReport { losses, final_metric: metric }))
}

/// Label accuracy of a verifier on fresh (task, answer, label) examples
/// using the single-token y/n decision.
pub fn verifier_accuracy(
    engine: &Engine,
    params: &ParamSet,
    kinds: Vec<TaskKind>,
    seed: u64,
) -> Result<f32> {
    let dims = engine.manifest().dims.clone();
    let (b, s, p, v) = (dims.batch, dims.max_seq, dims.prompt_len, dims.vocab);
    let mut gen = TaskGen::new(kinds, seed);
    let mut correct = 0usize;
    let mut total = 0usize;
    for _ in 0..4 {
        let mut rows = Vec::with_capacity(b);
        let mut qends = Vec::with_capacity(b);
        let mut labels = Vec::with_capacity(b);
        for _ in 0..b {
            let (row, mask, label) = verifier_example(&mut gen, p, s)?;
            // the verdict starts where the mask starts; q end is one before
            let vstart = mask.iter().position(|&m| m == 1.0).unwrap();
            rows.push(row);
            qends.push(vstart - 1);
            labels.push(label);
        }
        // blank out each row's verdict tokens so the model can't copy them
        let blanked: Vec<Vec<i32>> = rows
            .iter()
            .zip(&qends)
            .map(|(r, &q)| {
                let mut r = r.clone();
                for x in r.iter_mut().skip(q + 1) {
                    *x = 0;
                }
                r
            })
            .collect();
        let mut inputs = params.tensors.clone();
        inputs.push(generation::rows_tensor(&blanked));
        let logits = engine.run("fwd_logits", &inputs)?.remove(0);
        let ld = logits.as_f32()?;
        for i in 0..b {
            let base = i * s * v + qends[i] * v;
            let yes = ld[base + b'y' as usize] > ld[base + b'n' as usize];
            if yes == labels[i] {
                correct += 1;
            }
            total += 1;
        }
    }
    Ok(correct as f32 / total as f32)
}
