//! Block-allocated paged KV cache (paper §2.2 data plane): fixed-size
//! pages off a free list, per-sequence page tables, refcounted
//! prefix-sharing across prompts with a common prefix, and a reservation
//! protocol so admission can *block* on pool pressure instead of a
//! mid-decode allocation failure.
//!
//! The allocator is engine-agnostic: it hands out page buffers laid out
//! `[layers, heads, page_size, d_head]` (K and V separately) and tracks
//! ownership; the scheduler in `rollout::` does the gather/scatter between
//! pages and the dense `[L,B,H,S,D]` caches the `prefill`/`decode_step`
//! artifacts exchange.

use std::collections::HashMap;
use std::fmt;

use anyhow::{bail, Result};

/// Typed pool-exhaustion error: a held reservation could not be honored
/// because the free list drained and every cached shared page was pinned
/// (refs > 0) between `try_reserve` and `alloc_reserved` — reachable when
/// later admissions map shared prefixes onto pages an earlier reservation
/// counted as evictable.  Surfaced as an error so the scheduler can fail
/// the wave cleanly instead of panicking mid-rollout.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolExhausted {
    pub capacity: usize,
    pub in_use: usize,
    pub reserved: usize,
}

impl fmt::Display for PoolExhausted {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "kv page pool exhausted: all {} pages pinned ({} mapped, {} still \
             reserved) — no free or evictable page to honor a reservation; \
             raise kv_cache_pages or reduce prefix sharing pressure",
            self.capacity, self.in_use, self.reserved
        )
    }
}

impl std::error::Error for PoolExhausted {}

/// Geometry of one sequence's KV store, derived from the `decode_step`
/// artifact's cache operands (`Engine::kv_cache_spec`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvSpec {
    pub layers: usize,
    pub heads: usize,
    pub max_seq: usize,
    pub d_head: usize,
    /// token positions per page
    pub page_size: usize,
}

impl KvSpec {
    /// f32 elements in one page's K buffer (V is the same size).
    pub fn page_elems(&self) -> usize {
        self.layers * self.heads * self.page_size * self.d_head
    }

    /// Pages needed to hold a sequence decoded out to `max_seq`.
    pub fn pages_per_seq(&self) -> usize {
        self.max_seq.div_ceil(self.page_size)
    }

    /// Element offset of position `off` for `(layer, head)` within a page.
    pub fn page_offset(&self, layer: usize, head: usize, off: usize) -> usize {
        ((layer * self.heads + head) * self.page_size + off) * self.d_head
    }
}

#[derive(Debug)]
struct Page {
    k: Vec<f32>,
    v: Vec<f32>,
    /// sequences currently mapping this page
    refs: usize,
    /// token prefix this page is registered under in the share index
    /// (`None` for private generation/tail pages)
    key: Option<Vec<i32>>,
}

#[derive(Debug, Clone, Default)]
pub struct PageStats {
    pub capacity: usize,
    /// high-water mark of pages with refs > 0
    pub peak_in_use: usize,
    /// admissions that mapped an already-resident shared prompt page
    pub shared_hits: usize,
    /// cached (refs == 0) shared pages reclaimed under pool pressure
    pub evictions: usize,
}

/// The page pool.  Invariant: every page is exactly one of
/// free-listed, cached-in-index (refs == 0, evictable), or mapped
/// (refs > 0).  `reserved` pages are spoken for by admitted sequences but
/// not yet allocated; `try_reserve` is the admission gate, but shared-page
/// pins taken after a reservation can still starve `alloc_reserved`
/// (→ [`PoolExhausted`]).
#[derive(Debug)]
pub struct PagedKvCache {
    spec: KvSpec,
    pages: Vec<Page>,
    free: Vec<usize>,
    index: HashMap<Vec<i32>, usize>,
    reserved: usize,
    in_use: usize,
    stats: PageStats,
}

impl PagedKvCache {
    pub fn new(spec: KvSpec, capacity_pages: usize) -> Result<PagedKvCache> {
        if spec.page_size == 0 {
            bail!("kv page_size must be >= 1");
        }
        if capacity_pages < spec.pages_per_seq() {
            bail!(
                "page pool of {capacity_pages} pages cannot hold one worst-case \
                 sequence ({} pages of {} positions for max_seq {})",
                spec.pages_per_seq(),
                spec.page_size,
                spec.max_seq
            );
        }
        let elems = spec.page_elems();
        let pages = (0..capacity_pages)
            .map(|_| Page { k: vec![0.0; elems], v: vec![0.0; elems], refs: 0, key: None })
            .collect();
        Ok(PagedKvCache {
            spec,
            pages,
            free: (0..capacity_pages).rev().collect(),
            index: HashMap::new(),
            reserved: 0,
            in_use: 0,
            stats: PageStats { capacity: capacity_pages, ..PageStats::default() },
        })
    }

    pub fn spec(&self) -> &KvSpec {
        &self.spec
    }

    pub fn stats(&self) -> &PageStats {
        &self.stats
    }

    /// Pages currently mapped by at least one sequence.
    pub fn in_use(&self) -> usize {
        self.in_use
    }

    /// Pages obtainable right now: free-listed plus evictable cached pages,
    /// minus outstanding reservations.
    pub fn available(&self) -> usize {
        let evictable = self.pages.iter().filter(|p| p.refs == 0 && p.key.is_some()).count();
        (self.free.len() + evictable).saturating_sub(self.reserved)
    }

    /// Admission gate: reserve `n` pages for a sequence about to start.
    /// Returns false (caller must wait for retirements) when the pool
    /// cannot cover the worst case.
    pub fn try_reserve(&mut self, n: usize) -> bool {
        if self.available() < n {
            return false;
        }
        self.reserved += n;
        true
    }

    /// Return unused reservations (early EOS, better-than-predicted
    /// prefix sharing).
    pub fn unreserve(&mut self, n: usize) {
        debug_assert!(n <= self.reserved);
        self.reserved = self.reserved.saturating_sub(n);
    }

    fn bump(&mut self) {
        self.in_use += 1;
        self.stats.peak_in_use = self.stats.peak_in_use.max(self.in_use);
    }

    /// Map an already-resident shared page for `prefix` (refcount + 1).
    pub fn lookup_shared(&mut self, prefix: &[i32]) -> Option<usize> {
        let id = *self.index.get(prefix)?;
        self.pages[id].refs += 1;
        if self.pages[id].refs == 1 {
            self.bump();
        }
        self.stats.shared_hits += 1;
        Some(id)
    }

    /// Whether `prefix` is resident (no refcount change) — used by
    /// admission to predict how many new pages a sequence needs.
    pub fn is_resident(&self, prefix: &[i32]) -> bool {
        self.index.contains_key(prefix)
    }

    /// Allocate one page against a held reservation.  Reservations count
    /// cached shared pages as obtainable, but a later `lookup_shared` can
    /// pin those pages before this call runs — so exhaustion here is a
    /// reportable runtime condition ([`PoolExhausted`]), not a panic.
    pub fn alloc_reserved(&mut self) -> Result<usize, PoolExhausted> {
        debug_assert!(self.reserved > 0, "alloc without reservation");
        self.reserved = self.reserved.saturating_sub(1);
        let id = match self.free.pop().or_else(|| self.evict()) {
            Some(id) => id,
            None => {
                return Err(PoolExhausted {
                    capacity: self.pages.len(),
                    in_use: self.in_use,
                    reserved: self.reserved,
                })
            }
        };
        let page = &mut self.pages[id];
        page.refs = 1;
        page.key = None;
        self.bump();
        Ok(id)
    }

    /// Reclaim some cached (refs == 0) shared page.
    fn evict(&mut self) -> Option<usize> {
        let key = self
            .index
            .iter()
            .find(|(_, &id)| self.pages[id].refs == 0)
            .map(|(k, _)| k.clone())?;
        let id = self.index.remove(&key)?;
        self.pages[id].key = None;
        self.stats.evictions += 1;
        Some(id)
    }

    /// Publish a (fully written) prompt page for reuse by later sequences
    /// with the same token prefix.
    pub fn register_shared(&mut self, id: usize, prefix: &[i32]) {
        if self.index.contains_key(prefix) {
            return; // first writer wins; keep the existing mapping
        }
        self.pages[id].key = Some(prefix.to_vec());
        self.index.insert(prefix.to_vec(), id);
    }

    /// Drop one sequence's mapping.  Shared pages stay cached (evictable);
    /// private pages go straight back to the free list.
    pub fn release(&mut self, id: usize) {
        let page = &mut self.pages[id];
        debug_assert!(page.refs > 0);
        page.refs -= 1;
        if page.refs == 0 {
            self.in_use -= 1;
            if page.key.is_none() {
                self.free.push(id);
            }
        }
    }

    pub fn page(&self, id: usize) -> (&[f32], &[f32]) {
        (&self.pages[id].k, &self.pages[id].v)
    }

    pub fn page_mut(&mut self, id: usize) -> (&mut [f32], &mut [f32]) {
        let p = &mut self.pages[id];
        (&mut p.k, &mut p.v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> KvSpec {
        KvSpec { layers: 2, heads: 2, max_seq: 8, d_head: 3, page_size: 4 }
    }

    #[test]
    fn geometry() {
        let s = spec();
        assert_eq!(s.page_elems(), 2 * 2 * 4 * 3);
        assert_eq!(s.pages_per_seq(), 2);
        assert_eq!(s.page_offset(1, 1, 2), ((4 + 1) * 4 + 2) * 3);
        let odd = KvSpec { max_seq: 9, ..s };
        assert_eq!(odd.pages_per_seq(), 3);
    }

    #[test]
    fn pool_must_fit_one_sequence() {
        assert!(PagedKvCache::new(spec(), 1).is_err());
        assert!(PagedKvCache::new(spec(), 2).is_ok());
    }

    #[test]
    fn reserve_alloc_release_cycle() {
        let mut c = PagedKvCache::new(spec(), 4).unwrap();
        assert_eq!(c.available(), 4);
        assert!(c.try_reserve(3));
        assert_eq!(c.available(), 1);
        assert!(!c.try_reserve(2), "over-reservation must be refused");
        let a = c.alloc_reserved().unwrap();
        let b = c.alloc_reserved().unwrap();
        c.unreserve(1); // sequence finished early, one reservation unused
        assert_eq!(c.in_use(), 2);
        c.release(a);
        c.release(b);
        assert_eq!(c.in_use(), 0);
        assert_eq!(c.available(), 4);
        assert_eq!(c.stats().peak_in_use, 2);
    }

    #[test]
    fn shared_pages_cache_and_evict() {
        let mut c = PagedKvCache::new(spec(), 2).unwrap();
        assert!(c.try_reserve(1));
        let p0 = c.alloc_reserved().unwrap();
        c.register_shared(p0, &[1, 2, 3, 4]);
        assert!(c.lookup_shared(&[9, 9]).is_none());
        let hit = c.lookup_shared(&[1, 2, 3, 4]).unwrap();
        assert_eq!(hit, p0);
        assert_eq!(c.stats().shared_hits, 1);
        // two mappings of the same page: one physical page in use
        assert_eq!(c.in_use(), 1);
        c.release(p0);
        c.release(p0);
        // cached but evictable: still obtainable capacity
        assert_eq!(c.in_use(), 0);
        assert!(c.is_resident(&[1, 2, 3, 4]));
        assert_eq!(c.available(), 2);
        // exhaust the free list; the cached page gets evicted
        assert!(c.try_reserve(2));
        let _x = c.alloc_reserved().unwrap();
        let _y = c.alloc_reserved().unwrap();
        assert_eq!(c.stats().evictions, 1);
        assert!(!c.is_resident(&[1, 2, 3, 4]));
    }

    #[test]
    fn mapped_shared_pages_are_not_evictable() {
        let mut c = PagedKvCache::new(spec(), 2).unwrap();
        assert!(c.try_reserve(1));
        let p0 = c.alloc_reserved().unwrap();
        c.register_shared(p0, &[7]);
        // still mapped (refs 1): only the one free page is obtainable
        assert_eq!(c.available(), 1);
        assert!(!c.try_reserve(2));
    }

    #[test]
    fn all_pages_pinned_by_shared_prefixes_is_an_error_not_a_panic() {
        // Regression: a reservation counts cached (refs == 0) shared pages
        // as obtainable, but lookup_shared pins taken AFTER the
        // reservation can consume them.  alloc_reserved must then report
        // PoolExhausted, not hit an evict().expect panic.
        let mut c = PagedKvCache::new(spec(), 2).unwrap();
        assert!(c.try_reserve(2));
        let a = c.alloc_reserved().unwrap();
        let b = c.alloc_reserved().unwrap();
        c.register_shared(a, &[1, 2, 3, 4]);
        c.register_shared(b, &[5, 6, 7, 8]);
        c.release(a);
        c.release(b);
        // both pages cached + evictable: a 1-page reservation is granted
        assert_eq!(c.available(), 2);
        assert!(c.try_reserve(1));
        // ...but refcounted shared mappings then pin BOTH pages
        assert_eq!(c.lookup_shared(&[1, 2, 3, 4]), Some(a));
        assert_eq!(c.lookup_shared(&[5, 6, 7, 8]), Some(b));
        let err = c.alloc_reserved().unwrap_err();
        assert_eq!(err, PoolExhausted { capacity: 2, in_use: 2, reserved: 0 });
        assert!(err.to_string().contains("kv page pool exhausted"), "{err}");
        // releasing a pin makes the pool usable again (page is evicted on
        // the next allocation rather than leaked)
        c.release(a);
        assert!(c.try_reserve(1));
        let again = c.alloc_reserved().unwrap();
        assert_eq!(again, a);
        assert_eq!(c.stats().evictions, 1);
    }

    #[test]
    fn page_buffers_are_stable_across_alloc() {
        let mut c = PagedKvCache::new(spec(), 2).unwrap();
        assert!(c.try_reserve(1));
        let id = c.alloc_reserved().unwrap();
        c.page_mut(id).0[0] = 42.0;
        c.page_mut(id).1[1] = -1.0;
        let (k, v) = c.page(id);
        assert_eq!(k[0], 42.0);
        assert_eq!(v[1], -1.0);
    }
}
