//! Continuous-batching rollout scheduler over the paged KV cache (the
//! generation data plane the paper's dynamic-sampling and long-tail
//! claims ride on; OpenRLHF / HybridFlow bolt on vLLM for the same job).
//!
//! The `prefill`/`decode_step` artifacts fix `[batch]` and share one
//! scalar `pos` across the batch, so scheduling is *wave-granular at
//! admission* (up to `batch` sequences prefill together) and
//! *token-granular at retirement*: a row that hits EOS is retired
//! immediately — its pages are reclaimed mid-wave, it stops consuming
//! RNG draws, and the long-tail cancellation policy can preempt the
//! stragglers that remain (see `CancelPolicy`).  A per-row-position
//! `decode_step` variant that would let fresh sequences join a wave
//! mid-flight is deliberately deferred (ROADMAP).
//!
//! Bit-identity contract: with an ample pool and no cancellation, a run
//! over exactly `batch` requests consumes the RNG in the same order and
//! produces the same rows as `generation::generate_stepwise` — pinned by
//! the differential tests in rust/tests/rollout_integration.rs.

pub mod paged;

use std::collections::VecDeque;

use anyhow::{bail, Result};

use crate::balance;
use crate::data::tokenizer::{EOS, PAD};
use crate::runtime::engine::Engine;
use crate::runtime::params::ParamSet;
use crate::runtime::tensor::Tensor;
use crate::util::rng::Rng;

use super::generation::SamplerConfig;
use paged::{KvSpec, PagedKvCache};

/// Token positions per KV page when the caller does not size it
/// (`RunConfig::kv_page_size` mirrors this default).
pub const DEFAULT_PAGE_SIZE: usize = 16;

#[derive(Debug, Clone)]
pub struct RolloutRequest {
    /// caller-visible identity; results come back in request order
    pub id: usize,
    pub prompt: Vec<i32>,
}

#[derive(Debug, Clone)]
pub struct RolloutResult {
    pub id: usize,
    /// [max_seq] prompt + generated + PAD
    pub row: Vec<i32>,
    pub gen_len: usize,
    /// loss mask over [max_seq]: 1.0 on generated tokens
    pub mask: Vec<f32>,
    /// preempted by the cancellation policy before finishing
    pub cancelled: bool,
}

/// Long-tail straggler preemption (paper §3.2): once `needed` sequences
/// have finished, surviving rows get a grace window — scaled down by
/// `balance::cancel_grace_steps` as batch utilization drops — and are
/// then cancelled, their pages reclaimed.
#[derive(Debug, Clone, Copy)]
pub struct CancelPolicy {
    pub needed: usize,
    pub grace_steps: usize,
}

#[derive(Debug, Clone)]
pub struct RolloutOptions {
    /// token positions per page
    pub page_size: usize,
    /// page-pool capacity; 0 = auto-size so a full wave never blocks
    pub pool_pages: usize,
    /// reuse resident prompt pages across requests with a common prefix
    pub share_prefixes: bool,
    /// feed `decode_step` caches gathered from pages instead of passing
    /// the engine's dense output straight back — proves the paged store
    /// is the source of truth (differential tests run both modes)
    pub paged_feedback: bool,
    pub cancel: Option<CancelPolicy>,
}

impl Default for RolloutOptions {
    fn default() -> Self {
        RolloutOptions {
            page_size: DEFAULT_PAGE_SIZE,
            pool_pages: 0,
            share_prefixes: true,
            paged_feedback: false,
            cancel: None,
        }
    }
}

#[derive(Debug, Clone, Default)]
pub struct SchedulerStats {
    pub waves: usize,
    pub prefill_calls: usize,
    pub decode_calls: usize,
    /// slot-steps where the slot held a live (not yet retired) sequence
    pub live_slot_steps: usize,
    /// total slot-steps paid (batch × decode calls) — the lockstep cost
    pub slot_steps: usize,
    pub generated_tokens: usize,
    pub finished: usize,
    pub cancelled: usize,
    /// admissions deferred to a later wave by page-pool pressure
    pub admission_waits: usize,
    pub peak_pages: usize,
    pub shared_page_hits: usize,
    pub page_evictions: usize,
}

pub struct RolloutRun {
    /// one per request, in request order
    pub results: Vec<RolloutResult>,
    pub stats: SchedulerStats,
}

/// Per-slot in-flight sequence state.
struct Slot {
    req: usize,
    row: Vec<i32>,
    gen_len: usize,
    done: bool,
    cancelled: bool,
    /// page table: page ids for page-slots 0..pages.len()
    pages: Vec<usize>,
    /// leading pages mapped from the share index (read-only)
    shared: usize,
    /// reserved-but-unallocated pages
    reserved: usize,
    /// positions written into the paged store
    written: usize,
}

/// Engine dense-cache layout [L, B, H, S, D] (row-major).
struct DenseLayout {
    batch: usize,
    spec: KvSpec,
}

impl DenseLayout {
    fn col_offset(&self, layer: usize, row: usize, head: usize, pos: usize) -> usize {
        (((layer * self.batch + row) * self.spec.heads + head) * self.spec.max_seq + pos)
            * self.spec.d_head
    }
}

/// Copy dense columns `[start_pos, start_pos + n)` of `row` into a page.
fn scatter_cols(
    cache: &mut PagedKvCache,
    lay: &DenseLayout,
    page: usize,
    row: usize,
    start_pos: usize,
    n: usize,
    dense: (&[f32], &[f32]),
) {
    let spec = *cache.spec();
    let d = spec.d_head;
    let (pk, pv) = cache.page_mut(page);
    for l in 0..spec.layers {
        for h in 0..spec.heads {
            for i in 0..n {
                let pos = start_pos + i;
                let po = spec.page_offset(l, h, pos % spec.page_size);
                let co = lay.col_offset(l, row, h, pos);
                pk[po..po + d].copy_from_slice(&dense.0[co..co + d]);
                pv[po..po + d].copy_from_slice(&dense.1[co..co + d]);
            }
        }
    }
}

/// Rebuild one sequence's dense cache columns from its page table.
fn gather_seq(
    cache: &PagedKvCache,
    lay: &DenseLayout,
    slot: &Slot,
    row: usize,
    dense: (&mut [f32], &mut [f32]),
) {
    let spec = *cache.spec();
    let d = spec.d_head;
    for pos in 0..slot.written {
        let (pk, pv) = cache.page(slot.pages[pos / spec.page_size]);
        for l in 0..spec.layers {
            for h in 0..spec.heads {
                let po = spec.page_offset(l, h, pos % spec.page_size);
                let co = lay.col_offset(l, row, h, pos);
                dense.0[co..co + d].copy_from_slice(&pk[po..po + d]);
                dense.1[co..co + d].copy_from_slice(&pv[po..po + d]);
            }
        }
    }
}

/// Run requests to completion through admission waves.  Results come back
/// in request order; when a `CancelPolicy` fires, preempted and
/// never-admitted requests are returned with `cancelled: true`.
pub fn run(
    engine: &Engine,
    params: &ParamSet,
    requests: &[RolloutRequest],
    cfg: &SamplerConfig,
    rng: &mut Rng,
    opts: &RolloutOptions,
) -> Result<RolloutRun> {
    let dims = engine.manifest().dims.clone();
    let (b, p, s, v) = (dims.batch, dims.prompt_len, dims.max_seq, dims.vocab);
    if requests.iter().any(|r| r.prompt.len() != p) {
        bail!("rollout prompts must each be prompt_len={p} tokens");
    }
    let kv = engine.kv_cache_spec()?;
    let spec = KvSpec {
        layers: kv.layers,
        heads: kv.heads,
        max_seq: s,
        d_head: kv.d_head,
        page_size: opts.page_size.max(1),
    };
    let pps = spec.pages_per_seq();
    let pool = if opts.pool_pages == 0 { b * pps } else { opts.pool_pages };
    let mut cache = PagedKvCache::new(spec, pool)?;
    let lay = DenseLayout { batch: b, spec };

    let mut stats = SchedulerStats::default();
    let mut results: Vec<Option<RolloutResult>> = (0..requests.len()).map(|_| None).collect();
    let mut queue: VecDeque<usize> = (0..requests.len()).collect();
    let mut finished_total = 0usize;
    let mut preempt_all = false;

    while !queue.is_empty() && !preempt_all {
        // ---- admission: fill up to `b` slots, blocking on pool pressure --
        let mut slots: Vec<Option<Slot>> = (0..b).map(|_| None).collect();
        let mut admitted = 0usize;
        for slot in slots.iter_mut() {
            let Some(&req) = queue.front() else { break };
            let prompt = &requests[req].prompt;
            // map resident shared prompt pages up front (refs pin them
            // against eviction until this sequence retires)
            let full_prompt_pages = p / spec.page_size;
            let mut shared_pages = Vec::new();
            if opts.share_prefixes {
                for k in 0..full_prompt_pages {
                    let prefix = &prompt[..(k + 1) * spec.page_size];
                    if shared_pages.len() == k && cache.is_resident(prefix) {
                        if let Some(id) = cache.lookup_shared(prefix) {
                            shared_pages.push(id);
                        }
                    }
                }
            }
            let need = pps - shared_pages.len();
            if !cache.try_reserve(need) {
                // blocked: undo the shared mappings, wait for retirements
                for &id in &shared_pages {
                    cache.release(id);
                }
                stats.admission_waits += 1;
                break;
            }
            queue.pop_front();
            let shared = shared_pages.len();
            *slot = Some(Slot {
                req,
                row: prompt.clone(),
                gen_len: 0,
                done: false,
                cancelled: false,
                pages: shared_pages,
                shared,
                reserved: need,
                written: 0,
            });
            admitted += 1;
        }
        if admitted == 0 {
            bail!(
                "rollout admission deadlock: pool of {pool} pages cannot admit a \
                 sequence needing {pps} pages (capacity check should have caught this)"
            );
        }
        stats.waves += 1;

        // ---- prefill the wave (empty slots ride along as PAD rows) ------
        let flat: Vec<i32> = slots
            .iter()
            .flat_map(|slot| match slot {
                Some(sl) => sl.row[..p].to_vec(),
                None => vec![PAD; p],
            })
            .collect();
        let rows_t = Tensor::i32(vec![b, p], flat);
        let mut inputs: Vec<&Tensor> = params.tensors.iter().collect();
        inputs.push(&rows_t);
        let mut out = engine.run_refs("prefill", &inputs)?;
        drop(inputs);
        let mut logits = out.remove(0);
        let mut ck = out.remove(0);
        let mut cv = out.remove(0);
        stats.prefill_calls += 1;

        // ---- write prompt KV into pages; publish full pages for reuse ---
        for (si, slot) in slots.iter_mut().enumerate() {
            let Some(sl) = slot else { continue };
            let dense = (ck.as_f32()?, cv.as_f32()?);
            let full_prompt_pages = p / spec.page_size;
            for k in sl.shared..full_prompt_pages {
                let id = cache.alloc_reserved()?;
                sl.reserved -= 1;
                scatter_cols(&mut cache, &lay, id, si, k * spec.page_size, spec.page_size, dense);
                if opts.share_prefixes {
                    cache.register_shared(id, &sl.row[..(k + 1) * spec.page_size]);
                }
                sl.pages.push(id);
            }
            let tail = p % spec.page_size;
            if tail > 0 {
                let id = cache.alloc_reserved()?;
                sl.reserved -= 1;
                scatter_cols(&mut cache, &lay, id, si, full_prompt_pages * spec.page_size, tail, dense);
                sl.pages.push(id);
            }
            sl.written = p;
        }

        // ---- lockstep decode with token-granular retirement --------------
        // one seed draw per wave; the counter stream is keyed by
        // (position, slot row), mirroring the fused graph's sampler so a
        // single-wave run is bit-identical to the fused/stepwise paths
        let mut sample_base = crate::util::rng::sampler_base(rng.next_u64() as u32);
        let mut grace: Option<usize> = None;
        for pos in p..s {
            let ld = logits.as_f32()?;
            let mut step_tokens = vec![PAD; b];
            for (si, slot) in slots.iter_mut().enumerate() {
                let Some(sl) = slot else { continue };
                if sl.done {
                    sl.row.push(PAD);
                    continue;
                }
                let slice = &ld[si * v..(si + 1) * v];
                let tok = crate::util::rng::counter_sample_logits(
                    slice,
                    cfg.temperature,
                    cfg.top_k,
                    sample_base,
                    si,
                ) as i32;
                sl.gen_len += 1;
                stats.generated_tokens += 1;
                if cfg.stop_at_eos && tok == EOS {
                    // retire immediately: reclaim pages mid-wave
                    sl.done = true;
                    finished_total += 1;
                    release_slot_pages(&mut cache, sl);
                }
                sl.row.push(tok);
                step_tokens[si] = tok;
            }
            // the fused graph advances the counter for every row each
            // step, finished or not
            sample_base = sample_base.wrapping_add((b * v) as u32);
            let live = slots
                .iter()
                .flatten()
                .filter(|sl| !sl.done)
                .count();

            // long-tail preemption: arm the (utilization-scaled) grace
            // window once enough sequences have finished, then cancel
            if let Some(pol) = &opts.cancel {
                if grace.is_none() && finished_total >= pol.needed {
                    grace = Some(balance::cancel_grace_steps(pol.grace_steps, live, b));
                }
                if let Some(g) = grace {
                    if g == 0 && live > 0 {
                        for slot in slots.iter_mut() {
                            let Some(sl) = slot else { continue };
                            if !sl.done {
                                sl.done = true;
                                sl.cancelled = true;
                                stats.cancelled += 1;
                                release_slot_pages(&mut cache, sl);
                            }
                        }
                        preempt_all = true;
                    } else {
                        grace = Some(g.saturating_sub(1));
                    }
                }
            }

            if slots.iter().flatten().all(|sl| sl.done) || pos == s - 1 {
                break;
            }

            // decode the next position; dense passthrough by default,
            // page-gathered caches when proving the paged data plane
            let (gk, gv);
            let (ck_in, cv_in): (&Tensor, &Tensor) = if opts.paged_feedback {
                let mut dk = Tensor::zeros_f32(ck.shape.clone());
                let mut dv = Tensor::zeros_f32(cv.shape.clone());
                for (si, slot) in slots.iter().enumerate() {
                    let Some(sl) = slot else { continue };
                    if sl.done {
                        continue;
                    }
                    gather_seq(&cache, &lay, sl, si, (dk.as_f32_mut()?, dv.as_f32_mut()?));
                }
                (gk, gv) = (dk, dv);
                (&gk, &gv)
            } else {
                (&ck, &cv)
            };
            let step_t = Tensor::i32(vec![b], step_tokens);
            let pos_t = Tensor::scalar_i32(pos as i32);
            let mut inputs: Vec<&Tensor> = params.tensors.iter().collect();
            inputs.push(ck_in);
            inputs.push(cv_in);
            inputs.push(&step_t);
            inputs.push(&pos_t);
            let mut out = engine.run_refs("decode_step", &inputs)?;
            drop(inputs);
            logits = out.remove(0);
            ck = out.remove(0);
            cv = out.remove(0);
            stats.decode_calls += 1;
            stats.slot_steps += b;
            stats.live_slot_steps += live;

            // scatter the column decode_step just wrote (position `pos`)
            for (si, slot) in slots.iter_mut().enumerate() {
                let Some(sl) = slot else { continue };
                if sl.done {
                    continue;
                }
                let page_slot = pos / spec.page_size;
                if page_slot == sl.pages.len() {
                    let id = cache.alloc_reserved()?;
                    sl.reserved -= 1;
                    sl.pages.push(id);
                }
                let dense = (ck.as_f32()?, cv.as_f32()?);
                scatter_cols(&mut cache, &lay, sl.pages[page_slot], si, pos, 1, dense);
                sl.written = pos + 1;
            }
        }

        // ---- finalize the wave ------------------------------------------
        for slot in slots.iter_mut() {
            let Some(sl) = slot else { continue };
            if !sl.done {
                // hit the length cap: finished, just without EOS
                sl.done = true;
                finished_total += 1;
            }
            release_slot_pages(&mut cache, sl);
            sl.row.resize(s, PAD);
            let mut mask = vec![0.0f32; s];
            for x in mask.iter_mut().skip(p).take(sl.gen_len) {
                *x = 1.0;
            }
            if !sl.cancelled {
                stats.finished += 1;
            }
            results[sl.req] = Some(RolloutResult {
                id: requests[sl.req].id,
                row: std::mem::take(&mut sl.row),
                gen_len: sl.gen_len,
                mask,
                cancelled: sl.cancelled,
            });
        }
    }

    // requests preempted before admission
    while let Some(req) = queue.pop_front() {
        let mut row = requests[req].prompt.clone();
        row.resize(s, PAD);
        stats.cancelled += 1;
        results[req] = Some(RolloutResult {
            id: requests[req].id,
            row,
            gen_len: 0,
            mask: vec![0.0; s],
            cancelled: true,
        });
    }

    let st = cache.stats();
    stats.peak_pages = st.peak_in_use;
    stats.shared_page_hits = st.shared_hits;
    stats.page_evictions = st.evictions;
    let results = results
        .into_iter()
        .map(|r| r.expect("every request resolves to a result"))
        .collect();
    Ok(RolloutRun { results, stats })
}

/// Release every page a slot still maps and drop unused reservations.
fn release_slot_pages(cache: &mut PagedKvCache, sl: &mut Slot) {
    for id in sl.pages.drain(..) {
        cache.release(id);
    }
    cache.unreserve(sl.reserved);
    sl.reserved = 0;
    sl.shared = 0;
    sl.written = 0;
}
