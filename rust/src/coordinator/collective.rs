//! Inter-controller collectives (paper §3.1): "we further decompose the
//! top-level controller and use collective communication to coordinate
//! among controllers."
//!
//! `Rendezvous<T>` is the primitive: `exchange(rank, value)` blocks until
//! every controller of the group has contributed, then returns all values
//! to all ranks (all-gather semantics).  All-reduce, broadcast and barrier
//! are built on it.  Controllers are threads in-process; the same call
//! pattern maps onto the RPC transport for multi-process launches.

use std::sync::{Arc, Condvar, Mutex};

use anyhow::Result;

use crate::runtime::params::ParamSet;

struct Slots<T> {
    generation: u64,
    values: Vec<Option<T>>,
    /// completed generation's result, kept until every rank has taken it
    result: Option<(u64, Arc<Vec<T>>, usize)>,
}

/// N-way rendezvous usable repeatedly (lockstep rounds).
pub struct Rendezvous<T> {
    n: usize,
    slots: Mutex<Slots<T>>,
    cv: Condvar,
}

impl<T: Clone + Send> Rendezvous<T> {
    pub fn new(n: usize) -> Arc<Rendezvous<T>> {
        Arc::new(Rendezvous {
            n,
            slots: Mutex::new(Slots {
                generation: 0,
                values: (0..n).map(|_| None).collect(),
                result: None,
            }),
            cv: Condvar::new(),
        })
    }

    pub fn world_size(&self) -> usize {
        self.n
    }

    /// Contribute `value` for this round; returns every rank's value
    /// (indexed by rank) once all have arrived.
    pub fn exchange(&self, rank: usize, value: T) -> Vec<T> {
        assert!(rank < self.n, "rank {rank} out of range {}", self.n);
        let mut slots = self.slots.lock().unwrap();
        // wait for the previous round's result to be fully drained
        while slots.result.is_some() && slots.values[rank].is_some() {
            slots = self.cv.wait(slots).unwrap();
        }
        // if a completed result is pending and we already contributed to it,
        // the loop above handles it; otherwise contribute to current round
        assert!(slots.values[rank].is_none(), "rank {rank} double-contributed");
        slots.values[rank] = Some(value);
        let filled = slots.values.iter().filter(|v| v.is_some()).count();
        if filled == self.n {
            // last arriver publishes the result
            let gen = slots.generation;
            let vals: Vec<T> = slots.values.iter_mut().map(|v| v.take().unwrap()).collect();
            slots.result = Some((gen, Arc::new(vals), 0));
            slots.generation += 1;
            self.cv.notify_all();
        }
        // wait for this round's result
        let my_gen = {
            match &slots.result {
                Some((g, _, _)) if slots.values[rank].is_none() => *g,
                _ => slots.generation, // our round not yet complete
            }
        };
        loop {
            if let Some((g, vals, taken)) = &mut slots.result {
                if *g == my_gen {
                    let out = vals.as_ref().clone();
                    *taken += 1;
                    if *taken == self.n {
                        slots.result = None;
                        self.cv.notify_all();
                    }
                    return out;
                }
            }
            slots = self.cv.wait(slots).unwrap();
        }
    }
}

/// The full collective set one controller group shares.
pub struct Collective {
    pub params: Arc<Rendezvous<ParamSet>>,
    pub scalars: Arc<Rendezvous<Vec<f64>>>,
    pub bytes: Arc<Rendezvous<Vec<u8>>>,
    pub tokens: Arc<Rendezvous<Vec<Vec<i32>>>>,
}

impl Collective {
    pub fn new(world: usize) -> Arc<Collective> {
        Arc::new(Collective {
            params: Rendezvous::new(world),
            scalars: Rendezvous::new(world),
            bytes: Rendezvous::new(world),
            tokens: Rendezvous::new(world),
        })
    }

    pub fn world_size(&self) -> usize {
        self.params.world_size()
    }

    /// Mean-reduce a parameter/gradient set across controllers.
    pub fn all_reduce_mean(&self, rank: usize, set: &ParamSet) -> Result<ParamSet> {
        let all = self.params.exchange(rank, set.clone());
        let refs: Vec<&ParamSet> = all.iter().collect();
        ParamSet::average(&refs)
    }

    /// Mean of per-rank scalar vectors (loss/metric aggregation).
    pub fn mean_scalars(&self, rank: usize, vals: Vec<f64>) -> Vec<f64> {
        let all = self.scalars.exchange(rank, vals);
        let n = all.len() as f64;
        let len = all[0].len();
        (0..len)
            .map(|i| all.iter().map(|v| v[i]).sum::<f64>() / n)
            .collect()
    }

    /// Gather every rank's token rows (sample exchange across controllers).
    pub fn gather_tokens(&self, rank: usize, rows: Vec<Vec<i32>>) -> Vec<Vec<Vec<i32>>> {
        self.tokens.exchange(rank, rows)
    }

    pub fn barrier(&self, rank: usize) {
        self.bytes.exchange(rank, Vec::new());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::tensor::Tensor;

    #[test]
    fn exchange_returns_all_values() {
        let rdv = Rendezvous::<usize>::new(4);
        let handles: Vec<_> = (0..4)
            .map(|rank| {
                let rdv = rdv.clone();
                std::thread::spawn(move || rdv.exchange(rank, rank * 10))
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), vec![0, 10, 20, 30]);
        }
    }

    #[test]
    fn repeated_rounds_stay_in_lockstep() {
        let rdv = Rendezvous::<u64>::new(3);
        let handles: Vec<_> = (0..3)
            .map(|rank| {
                let rdv = rdv.clone();
                std::thread::spawn(move || {
                    let mut sums = Vec::new();
                    for round in 0..50u64 {
                        let vals = rdv.exchange(rank, round * 100 + rank as u64);
                        sums.push(vals.iter().sum::<u64>());
                    }
                    sums
                })
            })
            .collect();
        let results: Vec<Vec<u64>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        // every rank saw identical, round-consistent sums
        assert_eq!(results[0], results[1]);
        assert_eq!(results[1], results[2]);
        for (round, sum) in results[0].iter().enumerate() {
            assert_eq!(*sum, (round as u64) * 300 + 3);
        }
    }

    #[test]
    fn all_reduce_mean_matches_sequential() {
        let col = Collective::new(2);
        let a = ParamSet::new(vec![Tensor::f32(vec![2], vec![1.0, 2.0])]);
        let b = ParamSet::new(vec![Tensor::f32(vec![2], vec![3.0, 6.0])]);
        let col2 = col.clone();
        let h = std::thread::spawn(move || col2.all_reduce_mean(1, &b).unwrap());
        let r0 = col.all_reduce_mean(0, &a).unwrap();
        let r1 = h.join().unwrap();
        assert_eq!(r0, r1);
        assert_eq!(r0.tensors[0].as_f32().unwrap(), &[2.0, 4.0]);
    }

    #[test]
    fn world_of_one_is_identity() {
        let col = Collective::new(1);
        let a = ParamSet::new(vec![Tensor::f32(vec![1], vec![5.0])]);
        let r = col.all_reduce_mean(0, &a).unwrap();
        assert_eq!(r, a);
        col.barrier(0);
    }

    #[test]
    fn mean_scalars_aggregates_metrics() {
        let col = Collective::new(2);
        let col2 = col.clone();
        let h = std::thread::spawn(move || col2.mean_scalars(1, vec![2.0, 20.0]));
        let r0 = col.mean_scalars(0, vec![4.0, 40.0]);
        let r1 = h.join().unwrap();
        assert_eq!(r0, vec![3.0, 30.0]);
        assert_eq!(r0, r1);
    }
}
