//! Inter-controller collectives (paper §3.1): "we further decompose the
//! top-level controller and use collective communication to coordinate
//! among controllers."
//!
//! Two layers:
//!
//! * [`CollectiveBackend`] — the byte-level collectives everything is built
//!   on: `exchange(rank, tag, bytes)` blocks until all ranks of the group
//!   have contributed, then returns all payloads in rank order (all-gather);
//!   `all_reduce(rank, tag, bytes, op)` returns the rank-order [`ReduceOp`]
//!   fold of every rank's payload.  The default `all_reduce` is exchange +
//!   local fold; backends with a cheaper data path (the ring) override it.
//!   Implementations: [`InProcBackend`] (a `Condvar` rendezvous between
//!   controller threads, below),
//!   [`crate::coordinator::rpc_collective::RpcCollective`] (request/response
//!   rounds against a rank-0 rendezvous service over the exactly-once RPC
//!   stack — `InProcTransport`, TCP, or the fault-injecting wrapper), and
//!   [`crate::coordinator::ring_collective::RingCollective`] (chunked
//!   streaming frames around a ring of peer-hosted RPC services — O(payload)
//!   bytes per rank, independent of world size).
//! * [`Collective`] — the typed facade the `Controller` calls: all-reduce of
//!   `ParamSet` gradients, mean of scalar metric vectors, token-row gather,
//!   barrier.  Reduced values travel as flat element-aligned buffers and are
//!   folded in strict rank order — (…(v₀ ⊕ v₁) ⊕ v₂…) — on EVERY backend,
//!   so results are bit-identical across backends whether the fold happens
//!   locally (exchange-based backends) or distributed around the ring
//!   (asserted by `tests/collective_properties.rs`).
//!
//! `Rendezvous<T>` remains the in-process primitive: `exchange(rank, value)`
//! blocks until every controller of the group has contributed, then returns
//! all values to all ranks (all-gather semantics).

use std::collections::HashMap;
use std::ops::Range;
use std::sync::{mpsc, Arc, Condvar, Mutex};

use anyhow::{anyhow, bail, Result};

use crate::runtime::params::ParamSet;
use crate::runtime::tensor::Tensor;
use crate::util::codec::{Reader, Writer};
use crate::util::pod;

struct Slots<T> {
    generation: u64,
    values: Vec<Option<T>>,
    /// completed generation's result, kept until every rank has taken it
    result: Option<(u64, Arc<Vec<T>>, usize)>,
}

/// N-way rendezvous usable repeatedly (lockstep rounds).
pub struct Rendezvous<T> {
    n: usize,
    slots: Mutex<Slots<T>>,
    cv: Condvar,
}

impl<T: Clone + Send> Rendezvous<T> {
    pub fn new(n: usize) -> Arc<Rendezvous<T>> {
        Arc::new(Rendezvous {
            n,
            slots: Mutex::new(Slots {
                generation: 0,
                values: (0..n).map(|_| None).collect(),
                result: None,
            }),
            cv: Condvar::new(),
        })
    }

    pub fn world_size(&self) -> usize {
        self.n
    }

    /// Contribute `value` for this round; returns every rank's value
    /// (indexed by rank) once all have arrived.
    pub fn exchange(&self, rank: usize, value: T) -> Vec<T> {
        assert!(rank < self.n, "rank {rank} out of range {}", self.n);
        let mut slots = self.slots.lock().unwrap();
        // wait for the previous round's result to be fully drained
        while slots.result.is_some() && slots.values[rank].is_some() {
            slots = self.cv.wait(slots).unwrap();
        }
        // if a completed result is pending and we already contributed to it,
        // the loop above handles it; otherwise contribute to current round
        assert!(slots.values[rank].is_none(), "rank {rank} double-contributed");
        slots.values[rank] = Some(value);
        let filled = slots.values.iter().filter(|v| v.is_some()).count();
        if filled == self.n {
            // last arriver publishes the result
            let gen = slots.generation;
            let vals: Vec<T> = slots.values.iter_mut().map(|v| v.take().unwrap()).collect();
            slots.result = Some((gen, Arc::new(vals), 0));
            slots.generation += 1;
            self.cv.notify_all();
        }
        // wait for this round's result
        let my_gen = {
            match &slots.result {
                Some((g, _, _)) if slots.values[rank].is_none() => *g,
                _ => slots.generation, // our round not yet complete
            }
        };
        loop {
            if let Some((g, vals, taken)) = &mut slots.result {
                if *g == my_gen {
                    let out = vals.as_ref().clone();
                    *taken += 1;
                    if *taken == self.n {
                        slots.result = None;
                        self.cv.notify_all();
                    }
                    return out;
                }
            }
            slots = self.cv.wait(slots).unwrap();
        }
    }
}

// ---------------------------------------------------------------------------
// Backend abstraction
// ---------------------------------------------------------------------------

/// Elementwise reduction over flat little-endian element buffers.
///
/// The op is defined at the byte level so backends can stream and combine
/// bounded chunks without decoding whole payloads; chunk boundaries must be
/// multiples of [`ReduceOp::elem_bytes`].  Combination order is pinned to
/// rank order by every caller, so f32/f64 non-associativity never makes
/// backends diverge.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReduceOp {
    SumF32,
    SumF64,
}

impl ReduceOp {
    pub fn elem_bytes(self) -> usize {
        match self {
            ReduceOp::SumF32 => 4,
            ReduceOp::SumF64 => 8,
        }
    }

    /// `acc ⊕= incoming`, elementwise.  Both buffers must be the same length
    /// and a multiple of the element size.
    pub fn combine(self, acc: &mut [u8], incoming: &[u8]) -> Result<()> {
        if acc.len() != incoming.len() {
            bail!(
                "reduce operand length mismatch across ranks: {} vs {} bytes",
                acc.len(),
                incoming.len()
            );
        }
        if acc.len() % self.elem_bytes() != 0 {
            bail!(
                "reduce operand {} bytes is not a multiple of the {}-byte element",
                acc.len(),
                self.elem_bytes()
            );
        }
        match self {
            ReduceOp::SumF32 => {
                // aligned LE buffers sum as plain &[f32] slices (the SIMD-
                // friendly fast path); misaligned/BE falls back per element
                match (pod::bytes_as_f32_mut(acc), pod::bytes_as_f32(incoming)) {
                    (Some(a), Some(b)) => {
                        for (x, &y) in a.iter_mut().zip(b) {
                            *x += y;
                        }
                        return Ok(());
                    }
                    _ => {
                        for (a, b) in acc.chunks_exact_mut(4).zip(incoming.chunks_exact(4)) {
                            let s = f32::from_le_bytes([a[0], a[1], a[2], a[3]])
                                + f32::from_le_bytes([b[0], b[1], b[2], b[3]]);
                            a.copy_from_slice(&s.to_le_bytes());
                        }
                    }
                }
            }
            ReduceOp::SumF64 => {
                match (pod::bytes_as_f64_mut(acc), pod::bytes_as_f64(incoming)) {
                    (Some(a), Some(b)) => {
                        for (x, &y) in a.iter_mut().zip(b) {
                            *x += y;
                        }
                        return Ok(());
                    }
                    _ => {
                        for (a, b) in acc.chunks_exact_mut(8).zip(incoming.chunks_exact(8)) {
                            let s =
                                f64::from_le_bytes([a[0], a[1], a[2], a[3], a[4], a[5], a[6], a[7]])
                                    + f64::from_le_bytes([
                                        b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
                                    ]);
                            a.copy_from_slice(&s.to_le_bytes());
                        }
                    }
                }
            }
        }
        Ok(())
    }

    /// Rank-order fold — (…(parts[0] ⊕ parts[1]) ⊕ parts[2]…) — the
    /// reference reduction every backend must reproduce bit-for-bit.
    pub fn fold(self, parts: Vec<Vec<u8>>) -> Result<Vec<u8>> {
        let mut it = parts.into_iter();
        let mut acc = match it.next() {
            Some(p) => p,
            None => bail!("reduce over an empty group"),
        };
        for p in it {
            self.combine(&mut acc, &p)?;
        }
        Ok(acc)
    }
}

/// The byte-level collectives a controller group coordinates through.
///
/// Ranks call collectives in identical (SPMD lockstep) order; `tag` names
/// the logical channel so lockstep violations surface as hard errors
/// instead of silently exchanging mismatched values.
pub trait CollectiveBackend: Send + Sync {
    fn world_size(&self) -> usize;

    /// Contribute `payload` for this rank's next round; blocks until every
    /// rank has contributed and returns all payloads in rank order.
    fn exchange(&self, rank: usize, tag: &str, payload: Vec<u8>) -> Result<Vec<Vec<u8>>>;

    /// Reduce every rank's `payload` with `op` in rank order and return the
    /// reduced buffer to all ranks.  The default routes through `exchange`
    /// (all-gather, then a local fold); backends that can move fewer bytes
    /// (the ring's reduce-scatter/broadcast streams) override it — the
    /// result must stay bit-identical to the default.
    fn all_reduce(
        &self,
        rank: usize,
        tag: &str,
        payload: Vec<u8>,
        op: ReduceOp,
    ) -> Result<Vec<u8>> {
        op.fold(self.exchange(rank, tag, payload)?)
    }
}

/// In-process backend: controller threads meeting on a `Rendezvous`.
pub struct InProcBackend {
    rdv: Arc<Rendezvous<(String, Vec<u8>)>>,
}

impl InProcBackend {
    pub fn new(world: usize) -> Arc<InProcBackend> {
        Arc::new(InProcBackend { rdv: Rendezvous::new(world) })
    }
}

impl CollectiveBackend for InProcBackend {
    fn world_size(&self) -> usize {
        self.rdv.world_size()
    }

    fn exchange(&self, rank: usize, tag: &str, payload: Vec<u8>) -> Result<Vec<Vec<u8>>> {
        let all = self.rdv.exchange(rank, (tag.to_string(), payload));
        let mut out = Vec::with_capacity(all.len());
        for (peer_tag, bytes) in all {
            if peer_tag != tag {
                bail!(
                    "collective lockstep violation: rank {rank} is in '{tag}' \
                     while a peer is in '{peer_tag}'"
                );
            }
            out.push(bytes);
        }
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// Typed facade
// ---------------------------------------------------------------------------

/// Serialize a parameter/gradient set into one length-prefixed frame
/// (self-describing: shapes + dtypes travel with the data — checkpoints,
/// weight broadcast).
pub fn encode_param_set(set: &ParamSet) -> Vec<u8> {
    let mut w = Writer::new();
    w.tensors(&set.tensors);
    w.into_bytes()
}

pub fn decode_param_set(bytes: &[u8]) -> Result<ParamSet> {
    let mut r = Reader::new(bytes);
    let tensors = r.tensors()?;
    r.expect_end()?;
    Ok(ParamSet::new(tensors))
}

/// Flatten a gradient set into raw little-endian f32 bytes, no headers.
/// Tensor shapes are manifest-pinned and identical on every rank (SPMD), so
/// the reduce hot path ships only element data — and the buffer chunks
/// cleanly on element boundaries for streaming backends.
pub fn encode_param_flat(set: &ParamSet) -> Result<Vec<u8>> {
    let mut buf = Vec::with_capacity(set.num_elements() * 4);
    for t in &set.tensors {
        pod::extend_le_f32(&mut buf, t.as_f32()?);
    }
    Ok(buf)
}

/// Rebuild a set from flat f32 bytes using `like`'s shapes (the local
/// operand — all ranks share the same manifest-pinned shapes).
pub fn decode_param_flat(bytes: &[u8], like: &ParamSet) -> Result<ParamSet> {
    if bytes.len() != like.num_elements() * 4 {
        bail!(
            "flat param payload is {} bytes, local shapes need {}",
            bytes.len(),
            like.num_elements() * 4
        );
    }
    let mut pos = 0usize;
    let tensors = like
        .tensors
        .iter()
        .map(|t| {
            let n = t.len();
            let vals = pod::to_f32_vec(&bytes[pos..pos + 4 * n]);
            pos += 4 * n;
            Tensor::f32(t.shape.clone(), vals)
        })
        .collect();
    Ok(ParamSet::new(tensors))
}

/// In-place variant of [`decode_param_flat`]: overwrite `out`'s tensors
/// from flat f32 bytes without allocating.  (The bucketed reduce path does
/// the same per bucket via `Tensor::copy_from_le_f32_bytes`; this is the
/// whole-set primitive for callers that hold a reusable set.)
pub fn decode_param_flat_into(bytes: &[u8], out: &mut ParamSet) -> Result<()> {
    if bytes.len() != out.num_elements() * 4 {
        bail!(
            "flat param payload is {} bytes, local shapes need {}",
            bytes.len(),
            out.num_elements() * 4
        );
    }
    let mut pos = 0usize;
    for t in &mut out.tensors {
        let n = t.len() * 4;
        t.copy_from_le_f32_bytes(&bytes[pos..pos + n])?;
        pos += n;
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Bucketed, overlapped gradient reduction
// ---------------------------------------------------------------------------

/// One bucket of a [`plan_reduce_buckets`] partition: a contiguous run of
/// tensors (`tensors`) and its byte span in the flat wire layout (`bytes`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ReduceBucket {
    pub tensors: Range<usize>,
    pub bytes: Range<usize>,
}

/// Partition `set` into size-bounded buckets on tensor boundaries: tensors
/// pack greedily until adding the next one would exceed `bucket_bytes`
/// (a single tensor larger than the bound gets its own bucket).  The plan
/// is a pure function of the tensor shapes and the bound, so SPMD ranks —
/// which share manifest-pinned shapes and the `allreduce_bucket_bytes`
/// config — always compute identical plans.
pub fn plan_reduce_buckets(set: &ParamSet, bucket_bytes: usize) -> Vec<ReduceBucket> {
    let cap = bucket_bytes.max(4);
    let mut out = Vec::new();
    let (mut t0, mut b0, mut pos) = (0usize, 0usize, 0usize);
    for (i, t) in set.tensors.iter().enumerate() {
        let sz = t.len() * 4;
        if pos > b0 && pos - b0 + sz > cap {
            out.push(ReduceBucket { tensors: t0..i, bytes: b0..pos });
            t0 = i;
            b0 = pos;
        }
        pos += sz;
    }
    if t0 < set.tensors.len() || out.is_empty() {
        out.push(ReduceBucket { tensors: t0..set.tensors.len(), bytes: b0..pos });
    }
    out
}

/// One in-flight asynchronous reduction, issued through a rank's
/// communicator thread.  `wait` blocks until the reduced buffer is back.
pub struct ReduceHandle {
    rx: mpsc::Receiver<Result<Vec<u8>>>,
}

impl ReduceHandle {
    pub fn wait(self) -> Result<Vec<u8>> {
        match self.rx.recv() {
            Ok(res) => res,
            Err(_) => bail!("communicator thread dropped an in-flight reduction"),
        }
    }
}

/// A bucketed mean-reduce in flight: buckets were submitted in plan order
/// to the rank's communicator thread; `wait` drains them in the same order,
/// decoding + scaling each bucket while later buckets are still on the
/// wire.
pub struct ReduceMeanHandle {
    plan: Vec<ReduceBucket>,
    handles: Vec<ReduceHandle>,
    out: ParamSet,
    world: usize,
}

impl ReduceMeanHandle {
    pub fn buckets(&self) -> usize {
        self.plan.len()
    }

    pub fn wait(mut self) -> Result<ParamSet> {
        let scale = 1.0 / self.world as f32;
        for (bucket, handle) in self.plan.iter().zip(self.handles) {
            let summed = handle.wait()?;
            if summed.len() != bucket.bytes.len() {
                bail!(
                    "reduced bucket is {} bytes, expected {}",
                    summed.len(),
                    bucket.bytes.len()
                );
            }
            let mut pos = 0usize;
            for t in &mut self.out.tensors[bucket.tensors.clone()] {
                let n = t.len() * 4;
                t.copy_from_le_f32_bytes(&summed[pos..pos + n])?;
                pos += n;
                t.scale(scale)?;
            }
        }
        Ok(self.out)
    }
}

/// A job queued to a rank's communicator thread.
struct CommJob {
    rank: usize,
    tag: String,
    payload: Vec<u8>,
    op: ReduceOp,
    reply: mpsc::Sender<Result<Vec<u8>>>,
}

/// The full collective set one controller group shares.  All values travel
/// as codec frames through the backend, so the same call pattern runs over
/// threads, the in-proc RPC transport, or TCP between OS processes.
///
/// Each rank additionally gets a lazily-spawned **communicator thread**
/// (`all_reduce_async`): reductions submitted to it run strictly in
/// submission order while the rank's compute thread keeps working — the
/// overlap that makes bucketed gradient reduction pay.  While a rank has
/// async reductions in flight it must not issue other collectives (the
/// lockstep tag protocol still applies, it just runs on the communicator).
pub struct Collective {
    backend: Arc<dyn CollectiveBackend>,
    /// rank → job queue of that rank's communicator thread
    comms: Mutex<HashMap<usize, mpsc::Sender<CommJob>>>,
}

impl Collective {
    /// In-process group of `world` controller threads.
    pub fn new(world: usize) -> Arc<Collective> {
        Self::with_backend(InProcBackend::new(world))
    }

    /// Group coordinated by an explicit backend (e.g. `RpcCollective`).
    pub fn with_backend(backend: Arc<dyn CollectiveBackend>) -> Arc<Collective> {
        Arc::new(Collective { backend, comms: Mutex::new(HashMap::new()) })
    }

    pub fn world_size(&self) -> usize {
        self.backend.world_size()
    }

    /// The job queue of `rank`'s communicator thread, spawning it on first
    /// use.  The thread owns only the backend handle; it exits when the
    /// `Collective` (and with it every queue sender) is dropped.
    fn comm_sender(&self, rank: usize) -> mpsc::Sender<CommJob> {
        let mut comms = self.comms.lock().unwrap();
        comms
            .entry(rank)
            .or_insert_with(|| {
                let (tx, rx) = mpsc::channel::<CommJob>();
                let backend = self.backend.clone();
                std::thread::spawn(move || {
                    while let Ok(job) = rx.recv() {
                        let res = backend.all_reduce(job.rank, &job.tag, job.payload, job.op);
                        let _ = job.reply.send(res);
                    }
                });
                tx
            })
            .clone()
    }

    /// Submit one reduction to `rank`'s communicator thread and return
    /// immediately.  Jobs run strictly in submission order, so as long as
    /// every rank submits the same tag sequence the lockstep protocol is
    /// preserved exactly as for synchronous calls.
    pub fn all_reduce_async(
        &self,
        rank: usize,
        tag: &str,
        payload: Vec<u8>,
        op: ReduceOp,
    ) -> ReduceHandle {
        let (reply, rx) = mpsc::channel();
        let job = CommJob { rank, tag: tag.to_string(), payload, op, reply };
        if let Err(mpsc::SendError(job)) = self.comm_sender(rank).send(job) {
            // communicator thread died (panic): surface through the handle
            let _ = job
                .reply
                .send(Err(anyhow!("communicator thread for rank {rank} is gone")));
        }
        ReduceHandle { rx }
    }

    /// Mean-reduce a gradient set as size-bounded buckets streamed through
    /// the rank's communicator thread: bucket *k* is on the wire while
    /// bucket *k+1* serializes here, and `ReduceMeanHandle::wait` decodes +
    /// scales finished buckets while later ones are still in flight.  Each
    /// bucket is folded in strict rank order, so the result is bit-identical
    /// to the monolithic [`Collective::all_reduce_mean`] on every backend
    /// (asserted in tests/collective_properties.rs).  Takes `set` by value:
    /// every bucket's bytes are copied onto the wire before any reduced
    /// bucket lands, so the operand's own storage becomes the output — no
    /// second full-set allocation on the gradient hot path.
    pub fn all_reduce_mean_async(
        &self,
        rank: usize,
        set: ParamSet,
        bucket_bytes: usize,
    ) -> Result<ReduceMeanHandle> {
        let plan = plan_reduce_buckets(&set, bucket_bytes);
        let mut handles = Vec::with_capacity(plan.len());
        for (k, bucket) in plan.iter().enumerate() {
            let mut payload = Vec::with_capacity(bucket.bytes.len());
            for t in &set.tensors[bucket.tensors.clone()] {
                pod::extend_le_f32(&mut payload, t.as_f32()?);
            }
            handles.push(self.all_reduce_async(
                rank,
                &format!("params/b{k}"),
                payload,
                ReduceOp::SumF32,
            ));
        }
        Ok(ReduceMeanHandle { plan, handles, out: set, world: self.world_size() })
    }

    /// Synchronous facade over [`Collective::all_reduce_mean_async`] — the
    /// stage-4 gradient path (`allreduce_bucket_bytes` config knob).
    pub fn all_reduce_mean_bucketed(
        &self,
        rank: usize,
        set: ParamSet,
        bucket_bytes: usize,
    ) -> Result<ParamSet> {
        self.all_reduce_mean_async(rank, set, bucket_bytes)?.wait()
    }

    /// Broadcast `bytes` from `root` to every rank over the collective's
    /// byte channel (weight broadcast).  Implemented as an exchange in
    /// which only the root contributes a payload; on the ring backend the
    /// empty contributions travel as single empty frames, so per-rank cost
    /// stays O(payload).
    pub fn broadcast_bytes(&self, rank: usize, root: usize, bytes: Vec<u8>) -> Result<Vec<u8>> {
        if root >= self.world_size() {
            bail!("broadcast root {root} out of range for world {}", self.world_size());
        }
        let payload = if rank == root { bytes } else { Vec::new() };
        let mut parts = self.backend.exchange(rank, "bytes", payload)?;
        if parts.len() != self.world_size() {
            bail!(
                "broadcast exchange returned {} parts for world {}",
                parts.len(),
                self.world_size()
            );
        }
        Ok(parts.swap_remove(root))
    }

    /// Mean-reduce a parameter/gradient set across controllers.  The sum is
    /// folded in strict rank order on every backend, then scaled by 1/world
    /// locally — bit-identical to `ParamSet::average` over the rank-ordered
    /// operands (the PR 1 invariant).
    pub fn all_reduce_mean(&self, rank: usize, set: &ParamSet) -> Result<ParamSet> {
        let flat = encode_param_flat(set)?;
        let summed = self
            .backend
            .all_reduce(rank, "params", flat, ReduceOp::SumF32)?;
        let mut out = decode_param_flat(&summed, set)?;
        let scale = 1.0 / self.world_size() as f32;
        for t in &mut out.tensors {
            t.scale(scale)?;
        }
        Ok(out)
    }

    /// Mean of per-rank scalar vectors (loss/metric aggregation).
    pub fn mean_scalars(&self, rank: usize, vals: Vec<f64>) -> Result<Vec<f64>> {
        let mut buf = Vec::with_capacity(vals.len() * 8);
        for x in &vals {
            buf.extend_from_slice(&x.to_le_bytes());
        }
        let summed = self
            .backend
            .all_reduce(rank, "scalars", buf, ReduceOp::SumF64)?;
        if summed.len() != vals.len() * 8 {
            bail!("scalar vector length mismatch across ranks");
        }
        let n = self.world_size() as f64;
        Ok(summed
            .chunks_exact(8)
            .map(|c| {
                f64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]) / n
            })
            .collect())
    }

    /// Gather every rank's token rows (sample exchange across controllers).
    pub fn gather_tokens(&self, rank: usize, rows: Vec<Vec<i32>>) -> Result<Vec<Vec<Vec<i32>>>> {
        let mut w = Writer::new();
        w.token_rows(&rows);
        let parts = self.backend.exchange(rank, "tokens", w.into_bytes())?;
        parts
            .iter()
            .map(|b| {
                let mut r = Reader::new(b);
                let rows = r.token_rows()?;
                r.expect_end()?;
                Ok(rows)
            })
            .collect()
    }

    pub fn barrier(&self, rank: usize) -> Result<()> {
        self.backend.exchange(rank, "barrier", Vec::new())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::tensor::Tensor;

    #[test]
    fn exchange_returns_all_values() {
        let rdv = Rendezvous::<usize>::new(4);
        let handles: Vec<_> = (0..4)
            .map(|rank| {
                let rdv = rdv.clone();
                std::thread::spawn(move || rdv.exchange(rank, rank * 10))
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), vec![0, 10, 20, 30]);
        }
    }

    #[test]
    fn repeated_rounds_stay_in_lockstep() {
        let rdv = Rendezvous::<u64>::new(3);
        let handles: Vec<_> = (0..3)
            .map(|rank| {
                let rdv = rdv.clone();
                std::thread::spawn(move || {
                    let mut sums = Vec::new();
                    for round in 0..50u64 {
                        let vals = rdv.exchange(rank, round * 100 + rank as u64);
                        sums.push(vals.iter().sum::<u64>());
                    }
                    sums
                })
            })
            .collect();
        let results: Vec<Vec<u64>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        // every rank saw identical, round-consistent sums
        assert_eq!(results[0], results[1]);
        assert_eq!(results[1], results[2]);
        for (round, sum) in results[0].iter().enumerate() {
            assert_eq!(*sum, (round as u64) * 300 + 3);
        }
    }

    #[test]
    fn all_reduce_mean_matches_sequential() {
        let col = Collective::new(2);
        let a = ParamSet::new(vec![Tensor::f32(vec![2], vec![1.0, 2.0])]);
        let b = ParamSet::new(vec![Tensor::f32(vec![2], vec![3.0, 6.0])]);
        let col2 = col.clone();
        let h = std::thread::spawn(move || col2.all_reduce_mean(1, &b).unwrap());
        let r0 = col.all_reduce_mean(0, &a).unwrap();
        let r1 = h.join().unwrap();
        assert_eq!(r0, r1);
        assert_eq!(r0.tensors[0].as_f32().unwrap(), &[2.0, 4.0]);
    }

    #[test]
    fn world_of_one_is_identity() {
        let col = Collective::new(1);
        let a = ParamSet::new(vec![Tensor::f32(vec![1], vec![5.0])]);
        let r = col.all_reduce_mean(0, &a).unwrap();
        assert_eq!(r, a);
        col.barrier(0).unwrap();
    }

    #[test]
    fn mean_scalars_aggregates_metrics() {
        let col = Collective::new(2);
        let col2 = col.clone();
        let h = std::thread::spawn(move || col2.mean_scalars(1, vec![2.0, 20.0]).unwrap());
        let r0 = col.mean_scalars(0, vec![4.0, 40.0]).unwrap();
        let r1 = h.join().unwrap();
        assert_eq!(r0, vec![3.0, 30.0]);
        assert_eq!(r0, r1);
    }

    #[test]
    fn gather_tokens_returns_rank_order() {
        let col = Collective::new(2);
        let col2 = col.clone();
        let h = std::thread::spawn(move || {
            col2.gather_tokens(1, vec![vec![10, 11]]).unwrap()
        });
        let r0 = col.gather_tokens(0, vec![vec![0, 1], vec![2]]).unwrap();
        let r1 = h.join().unwrap();
        assert_eq!(r0, r1);
        assert_eq!(r0, vec![vec![vec![0, 1], vec![2]], vec![vec![10, 11]]]);
    }

    #[test]
    fn param_set_frame_roundtrip() {
        let set = ParamSet::new(vec![
            Tensor::f32(vec![2, 2], vec![1.0, -2.5, f32::MIN_POSITIVE, 4.0]),
            Tensor::i32(vec![3], vec![-1, 0, 1]),
        ]);
        assert_eq!(decode_param_set(&encode_param_set(&set)).unwrap(), set);
        assert!(decode_param_set(&[1, 2, 3]).is_err());
    }

    #[test]
    fn reduce_op_folds_in_rank_order() {
        // f32 sum
        let parts: Vec<Vec<u8>> = [[1.0f32, 2.0], [3.0, 4.0], [5.0, 6.0]]
            .iter()
            .map(|vs| vs.iter().flat_map(|v| v.to_le_bytes()).collect())
            .collect();
        let out = ReduceOp::SumF32.fold(parts).unwrap();
        assert_eq!(
            out,
            [9.0f32, 12.0].iter().flat_map(|v| v.to_le_bytes()).collect::<Vec<u8>>()
        );
        // f64 sum
        let parts64: Vec<Vec<u8>> = [[0.5f64], [0.25]]
            .iter()
            .map(|vs| vs.iter().flat_map(|v| v.to_le_bytes()).collect())
            .collect();
        let out64 = ReduceOp::SumF64.fold(parts64).unwrap();
        assert_eq!(out64, 0.75f64.to_le_bytes().to_vec());
        // errors: empty group, length mismatch, misaligned
        assert!(ReduceOp::SumF32.fold(vec![]).is_err());
        assert!(ReduceOp::SumF32.fold(vec![vec![0; 4], vec![0; 8]]).is_err());
        assert!(ReduceOp::SumF64.fold(vec![vec![0; 4], vec![0; 4]]).is_err());
    }

    #[test]
    fn param_flat_roundtrip_preserves_shapes_and_bits() {
        let set = ParamSet::new(vec![
            Tensor::f32(vec![2, 2], vec![1.0, -2.5, f32::MIN_POSITIVE, 4.0]),
            Tensor::f32(vec![3], vec![-0.0, 7.0, 1e-30]),
        ]);
        let flat = encode_param_flat(&set).unwrap();
        assert_eq!(flat.len(), set.num_elements() * 4);
        assert_eq!(decode_param_flat(&flat, &set).unwrap(), set);
        // wrong length rejected
        assert!(decode_param_flat(&flat[..flat.len() - 4], &set).is_err());
        // non-f32 tensors can't travel the reduce path
        let ints = ParamSet::new(vec![Tensor::i32(vec![1], vec![3])]);
        assert!(encode_param_flat(&ints).is_err());
    }

    #[test]
    fn decode_param_flat_into_reuses_storage() {
        let set = ParamSet::new(vec![
            Tensor::f32(vec![2, 2], vec![1.0, -2.5, f32::MIN_POSITIVE, 4.0]),
            Tensor::f32(vec![3], vec![-0.0, 7.0, 1e-30]),
        ]);
        let flat = encode_param_flat(&set).unwrap();
        let mut out = ParamSet::new(vec![
            Tensor::zeros_f32(vec![2, 2]),
            Tensor::zeros_f32(vec![3]),
        ]);
        decode_param_flat_into(&flat, &mut out).unwrap();
        assert_eq!(out, set);
        // wrong length rejected
        assert!(decode_param_flat_into(&flat[..flat.len() - 4], &mut out).is_err());
    }

    #[test]
    fn bucket_plan_splits_on_tensor_boundaries() {
        let set = ParamSet::new(vec![
            Tensor::zeros_f32(vec![4]),  // 16 bytes
            Tensor::zeros_f32(vec![2]),  // 8 bytes
            Tensor::zeros_f32(vec![10]), // 40 bytes (alone, exceeds 24)
            Tensor::zeros_f32(vec![1]),  // 4 bytes
        ]);
        let plan = plan_reduce_buckets(&set, 24);
        assert_eq!(
            plan,
            vec![
                ReduceBucket { tensors: 0..2, bytes: 0..24 },
                ReduceBucket { tensors: 2..3, bytes: 24..64 },
                ReduceBucket { tensors: 3..4, bytes: 64..68 },
            ]
        );
        // bound >= whole set: one bucket
        let whole = plan_reduce_buckets(&set, 1 << 20);
        assert_eq!(whole, vec![ReduceBucket { tensors: 0..4, bytes: 0..68 }]);
        // bound smaller than every tensor: one bucket per tensor
        let tiny = plan_reduce_buckets(&set, 4);
        assert_eq!(tiny.len(), 4);
        for (i, b) in tiny.iter().enumerate() {
            assert_eq!(b.tensors, i..i + 1);
        }
        // buckets tile the byte range exactly
        let mut pos = 0;
        for b in &plan {
            assert_eq!(b.bytes.start, pos);
            pos = b.bytes.end;
        }
        assert_eq!(pos, set.num_elements() * 4);
        // empty set still plans one (empty) bucket, mirroring the monolithic
        // path's single empty-payload round
        let empty = plan_reduce_buckets(&ParamSet::new(vec![]), 64);
        assert_eq!(empty, vec![ReduceBucket { tensors: 0..0, bytes: 0..0 }]);
    }

    #[test]
    fn bucketed_mean_matches_monolithic_inproc() {
        let col = Collective::new(2);
        let a = ParamSet::new(vec![
            Tensor::f32(vec![3], vec![1.0, 2.0, 3.0]),
            Tensor::f32(vec![2], vec![-1.0, 0.5]),
            Tensor::f32(vec![4], vec![0.25, -0.25, 8.0, 1e-20]),
        ]);
        let b = ParamSet::new(vec![
            Tensor::f32(vec![3], vec![0.5, -2.0, 1.0]),
            Tensor::f32(vec![2], vec![4.0, 4.0]),
            Tensor::f32(vec![4], vec![1.0, 1.0, 1.0, 1.0]),
        ]);
        // monolithic reference
        let (m0, m1) = {
            let col2 = col.clone();
            let b2 = b.clone();
            let h = std::thread::spawn(move || col2.all_reduce_mean(1, &b2).unwrap());
            (col.all_reduce_mean(0, &a).unwrap(), h.join().unwrap())
        };
        assert_eq!(m0, m1);
        // bucketed at 8 bytes (splits every tensor apart) must agree bitwise
        let (r0, r1) = {
            let col2 = col.clone();
            let b2 = b.clone();
            let h = std::thread::spawn(move || {
                col2.all_reduce_mean_bucketed(1, b2, 8).unwrap()
            });
            (col.all_reduce_mean_bucketed(0, a.clone(), 8).unwrap(), h.join().unwrap())
        };
        assert_eq!(r0, m0);
        assert_eq!(r1, m1);
        // and at a bound that swallows the whole set
        let (w0, w1) = {
            let col2 = col.clone();
            let h = std::thread::spawn(move || {
                col2.all_reduce_mean_bucketed(1, b, 1 << 20).unwrap()
            });
            (col.all_reduce_mean_bucketed(0, a, 1 << 20).unwrap(), h.join().unwrap())
        };
        assert_eq!(w0, m0);
        assert_eq!(w1, m1);
    }

    #[test]
    fn async_handles_overlap_and_resolve_in_order() {
        let col = Collective::new(2);
        let col2 = col.clone();
        let h = std::thread::spawn(move || {
            let ha = col2.all_reduce_async(1, "x", vec![0, 0, 128, 63], ReduceOp::SumF32);
            let hb = col2.all_reduce_async(1, "y", vec![0, 0, 0, 64], ReduceOp::SumF32);
            (ha.wait().unwrap(), hb.wait().unwrap())
        });
        // both rounds are in flight on the communicator before any wait
        let ha = col.all_reduce_async(0, "x", vec![0, 0, 128, 63], ReduceOp::SumF32);
        let hb = col.all_reduce_async(0, "y", vec![0, 0, 0, 64], ReduceOp::SumF32);
        let (a0, b0) = (ha.wait().unwrap(), hb.wait().unwrap());
        let (a1, b1) = h.join().unwrap();
        assert_eq!(a0, a1);
        assert_eq!(b0, b1);
        assert_eq!(a0, 2.0f32.to_le_bytes().to_vec()); // 1.0 + 1.0
        assert_eq!(b0, 4.0f32.to_le_bytes().to_vec()); // 2.0 + 2.0
    }

    #[test]
    fn broadcast_bytes_delivers_root_payload_to_all() {
        let col = Collective::new(3);
        let payload = vec![9u8, 8, 7, 6, 5];
        let handles: Vec<_> = (0..3)
            .map(|rank| {
                let col = col.clone();
                let p = payload.clone();
                std::thread::spawn(move || {
                    let mine = if rank == 1 { p } else { Vec::new() };
                    col.broadcast_bytes(rank, 1, mine).unwrap()
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), payload);
        }
        // out-of-range root rejected
        assert!(Collective::new(1).broadcast_bytes(0, 5, vec![]).is_err());
    }

    #[test]
    fn inproc_lockstep_violation_is_hard_error() {
        let backend = InProcBackend::new(2);
        let b2 = backend.clone();
        let h = std::thread::spawn(move || b2.exchange(1, "scalars", vec![]));
        let r0 = backend.exchange(0, "params", vec![]);
        let r1 = h.join().unwrap();
        assert!(r0.is_err() && r1.is_err(), "both ranks must fail fast");
        assert!(r0.unwrap_err().to_string().contains("lockstep"));
    }
}
