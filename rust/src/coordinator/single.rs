//! Single-controller data plane — the baseline the parallel-controller
//! architecture exists to beat (paper §3.1, Fig. 1).
//!
//! In the hybrid/single-controller design, every rollout's data (including
//! multimodal payloads) flows through ONE controller process: its memory
//! must hold the whole rollout and its RPC link must move every byte.  The
//! parallel design shards payloads across N controllers, each touching
//! only its slice.  `route_single` / `route_parallel` move **real bytes
//! through real threads and channels** so E1 measures actual memory and
//! wallclock, not a model.

use std::sync::mpsc;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::data::payload::{Payload, PayloadSpec};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct RouteReport {
    pub controllers: usize,
    pub samples: usize,
    pub total_bytes: usize,
    /// max bytes resident in any single controller at once
    pub peak_bytes_per_controller: usize,
    pub wall_secs: f64,
    pub throughput_gbps: f64,
}

/// Process one payload "in the controller": checksum every image buffer
/// (stands in for the controller-side packing/copy work §3.1 describes).
fn controller_work(p: &Payload) -> u64 {
    let mut acc = 0u64;
    for img in &p.images {
        // touch every 64th byte — bandwidth-bound, like a copy
        let mut i = 0;
        while i < img.len() {
            acc = acc.wrapping_add(img[i] as u64);
            i += 64;
        }
    }
    acc
}

/// Centralised routing: workers produce payloads, ONE controller receives,
/// holds and processes the entire rollout before releasing it downstream.
/// Errors with OOM when the resident set would exceed `mem_limit_bytes`.
pub fn route_single(
    spec: &PayloadSpec,
    samples: usize,
    mem_limit_bytes: usize,
    seed: u64,
) -> Result<RouteReport> {
    let (tx, rx) = mpsc::sync_channel::<Payload>(4);
    let spec2 = spec.clone();
    let producer = std::thread::spawn(move || {
        let mut rng = Rng::new(seed);
        for i in 0..samples {
            if tx.send(spec2.generate(i as u64, &mut rng)).is_err() {
                break;
            }
        }
    });

    let t0 = Instant::now();
    let mut held: Vec<Payload> = Vec::with_capacity(samples);
    let mut resident = 0usize;
    let mut peak = 0usize;
    let mut checksum = 0u64;
    let mut oom = false;
    for p in rx {
        resident += p.size_bytes();
        peak = peak.max(resident);
        if resident > mem_limit_bytes {
            oom = true;
            break; // drops the receiver; producer unblocks on send error
        }
        checksum = checksum.wrapping_add(controller_work(&p));
        // the single controller must HOLD the whole rollout until the stage
        // transition (the §3.1 memory wall)
        held.push(p);
    }
    producer.join().ok();
    if oom {
        bail!(
            "single controller OOM: resident {:.1} GB exceeds limit {:.1} GB \
             after {} samples (paper §3.1)",
            resident as f64 / 1e9,
            mem_limit_bytes as f64 / 1e9,
            held.len()
        );
    }
    let wall = t0.elapsed().as_secs_f64();
    let total: usize = held.iter().map(|p| p.size_bytes()).sum();
    std::hint::black_box(checksum);
    Ok(RouteReport {
        controllers: 1,
        samples,
        total_bytes: total,
        peak_bytes_per_controller: peak,
        wall_secs: wall,
        throughput_gbps: total as f64 / 1e9 / wall.max(1e-9),
    })
}

/// Parallel-controller routing: N controllers each own `samples / n`
/// samples end-to-end.  Peak residency per controller is its shard only.
pub fn route_parallel(
    spec: &PayloadSpec,
    samples: usize,
    n_controllers: usize,
    seed: u64,
) -> Result<RouteReport> {
    if n_controllers == 0 || samples % n_controllers != 0 {
        bail!("samples {samples} must divide across {n_controllers} controllers");
    }
    let per = samples / n_controllers;
    let t0 = Instant::now();
    let handles: Vec<_> = (0..n_controllers)
        .map(|rank| {
            let spec = spec.clone();
            std::thread::spawn(move || {
                let mut rng = Rng::new(seed ^ (rank as u64) << 32);
                let mut held = Vec::with_capacity(per);
                let mut resident = 0usize;
                let mut peak = 0usize;
                let mut checksum = 0u64;
                for i in 0..per {
                    let p = spec.generate((rank * per + i) as u64, &mut rng);
                    resident += p.size_bytes();
                    peak = peak.max(resident);
                    checksum = checksum.wrapping_add(controller_work(&p));
                    held.push(p);
                }
                std::hint::black_box(checksum);
                let total: usize = held.iter().map(|p| p.size_bytes()).sum();
                (peak, total)
            })
        })
        .collect();
    let mut peak = 0usize;
    let mut total = 0usize;
    for h in handles {
        let (p, t) = h.join().expect("controller thread panicked");
        peak = peak.max(p);
        total += t;
    }
    let wall = t0.elapsed().as_secs_f64();
    Ok(RouteReport {
        controllers: n_controllers,
        samples,
        total_bytes: total,
        peak_bytes_per_controller: peak,
        wall_secs: wall,
        throughput_gbps: total as f64 / 1e9 / wall.max(1e-9),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_spec() -> PayloadSpec {
        // 32 × 64×64×3 ≈ 390 KB per sample — fast enough for unit tests
        PayloadSpec::paper_2k().scaled(32)
    }

    #[test]
    fn parallel_peak_is_sharded() {
        let spec = small_spec();
        let single = route_single(&spec, 16, usize::MAX, 1).unwrap();
        let par = route_parallel(&spec, 16, 4, 1).unwrap();
        assert_eq!(single.total_bytes, par.total_bytes);
        // each of 4 controllers holds ~1/4 of the rollout
        assert!(
            par.peak_bytes_per_controller <= single.peak_bytes_per_controller / 3,
            "par {} vs single {}",
            par.peak_bytes_per_controller,
            single.peak_bytes_per_controller
        );
    }

    #[test]
    fn single_controller_ooms_at_limit() {
        let spec = small_spec();
        let limit = spec.bytes_per_sample() * 4; // only 4 samples fit
        let err = route_single(&spec, 16, limit, 2).unwrap_err().to_string();
        assert!(err.contains("OOM"), "{err}");
        // while 4 parallel controllers with the same per-controller budget fit
        let par = route_parallel(&spec, 16, 4, 2).unwrap();
        assert!(par.peak_bytes_per_controller <= limit);
    }

    #[test]
    fn reports_are_consistent() {
        let spec = small_spec();
        let r = route_parallel(&spec, 8, 2, 3).unwrap();
        assert_eq!(r.samples, 8);
        assert_eq!(r.total_bytes, spec.bytes_per_sample() * 8);
        assert!(r.wall_secs > 0.0 && r.throughput_gbps > 0.0);
    }

    #[test]
    fn indivisible_shard_rejected() {
        assert!(route_parallel(&small_spec(), 10, 3, 0).is_err());
    }
}
