//! Ring collectives over the exactly-once RPC stack (paper §3.1 + §4.2):
//! the third `CollectiveBackend`, built for controller-count scalability.
//!
//! The rendezvous backend funnels every payload through rank 0's
//! `RendezvousHost` — O(world²) bytes per round on one process, exactly the
//! single-controller bottleneck the paper's parallel-controller design
//! exists to avoid.  Here every rank instead hosts a tiny [`RingPeer`]
//! inbox service and streams bounded [`ChunkFrame`]s to its ring successor
//! (`(rank + 1) % world`), so per-rank traffic is O(payload) **independent
//! of world size** (measured in E8c):
//!
//! * `all_reduce` — a reduce sweep chains rank-order partial sums
//!   0 → 1 → … → N-1 chunk by chunk; the last rank finalizes each chunk and
//!   immediately streams it back around the ring (broadcast sweep).  Every
//!   rank sends each chunk at most twice.  Because partials accumulate in
//!   strict rank order — (…(v₀ ⊕ v₁) ⊕ v₂…) — the result is bit-identical
//!   to the in-proc backend's local fold (the PR 1 invariant, asserted by
//!   `tests/collective_properties.rs`).
//! * `exchange` — classic ring all-gather: at step `t` a rank forwards the
//!   payload it received at step `t-1`, so after world-1 steps every rank
//!   holds all payloads (token gathers, barriers, bootstrap rounds).
//!
//! Chunks ride the retry-until-cached RPC protocol, so drops, duplicate
//! deliveries and lost responses never double-insert a chunk (the peer's
//! `RpcServer` result cache absorbs them).  Each ack carries the receiver's
//! inbox backlog: reduce-stream senders HARD-wait past
//! [`RingCollective::window`] chunks (polling `ring.backlog`), so the
//! gradient-sized stream never buffers whole on a slow host; gather and
//! broadcast sends use a soft pause instead — a hard wait there would close
//! a blocking cycle around the ring, and those transients are bounded by
//! one payload (the size of the result buffer the rank allocates anyway).
//! Lockstep violations (tag mismatch) and
//! dead peers (chunk-wait timeout) surface as typed
//! [`CollectiveStatus`](crate::coordinator::rpc_collective::CollectiveStatus)
//! failures, same as the rendezvous backend.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::coordinator::collective::{CollectiveBackend, ReduceOp};
use crate::coordinator::rpc_collective::{CollectiveStatus, LivenessProbe};
use crate::rpc::client::{RetryPolicy, RpcClient};
use crate::rpc::server::{RpcServer, Service};
use crate::rpc::transport::Transport;
use crate::rpc::wire::{ChunkAck, ChunkFrame, PHASE_BCAST, PHASE_GATHER, PHASE_REDUCE};

pub const METHOD_RING_OFFER: &str = "ring.offer";
pub const METHOD_RING_BACKLOG: &str = "ring.backlog";

/// Default chunk size for streamed payloads (multiple of every element size).
pub const DEFAULT_CHUNK_BYTES: usize = 256 * 1024;

/// Default backlog (in chunks) past which a sender throttles.
pub const DEFAULT_WINDOW: usize = 16;

/// A chunk parked in a peer's inbox until the compute thread consumes it.
struct StoredChunk {
    tag: String,
    total: u32,
    payload: Vec<u8>,
}

/// Inbox contents, guarded by one mutex so the retired-round watermark and
/// the chunk map can never disagree (a check-then-insert race against
/// `retire_through` would park a stale chunk forever).
struct InboxState {
    /// (round, phase, origin, chunk) → stored chunk
    slots: HashMap<(u64, u8, u32, u32), StoredChunk>,
    /// rounds below this watermark are locally complete: late/duplicate
    /// chunks for them are acked but NOT (re-)inserted.  This keeps `offer`
    /// idempotent even past the RPC server's tombstone horizon (a
    /// re-delivered offer whose tombstone aged out re-executes the handler;
    /// without the watermark the stale chunk would park forever and inflate
    /// the backlog the credit window hard-waits on).
    retired_below: u64,
}

/// The per-rank chunk inbox: predecessor streams in via [`RingPeer`]'s RPC
/// handler, the rank's own compute thread blocks in [`RingInbox::take`].
pub struct RingInbox {
    state: Mutex<InboxState>,
    cv: Condvar,
}

impl RingInbox {
    #[allow(clippy::new_without_default)]
    pub fn new() -> Arc<RingInbox> {
        Arc::new(RingInbox {
            state: Mutex::new(InboxState { slots: HashMap::new(), retired_below: 0 }),
            cv: Condvar::new(),
        })
    }

    /// Chunks currently buffered (0 once a round is fully consumed — test
    /// hook and the backlog figure acked to senders).
    pub fn open_chunks(&self) -> usize {
        self.state.lock().unwrap().slots.len()
    }

    /// Mark every round up to and including `round` locally complete; their
    /// stray chunks are dropped on arrival from now on (and purged if a
    /// racing re-delivery slipped one in).  Rounds are strictly sequential
    /// per rank, so the backend retires each round as it returns.
    fn retire_through(&self, round: u64) {
        let mut state = self.state.lock().unwrap();
        if round + 1 > state.retired_below {
            state.retired_below = round + 1;
        }
        let watermark = state.retired_below;
        state.slots.retain(|key, _| key.0 >= watermark);
    }

    /// Park one delivered chunk.  Idempotent per key: the exactly-once RPC
    /// layer dedupes live requests, the retired-round watermark drops
    /// anything re-delivered after its round already completed, and a
    /// re-insert of the same live frame is a no-op.
    fn offer(&self, frame: ChunkFrame) -> Result<Vec<u8>> {
        let mut state = self.state.lock().unwrap();
        if frame.round >= state.retired_below {
            state
                .slots
                .entry((frame.round, frame.phase, frame.origin, frame.chunk))
                .or_insert_with(|| StoredChunk {
                    tag: frame.tag,
                    total: frame.total,
                    payload: frame.payload,
                });
        }
        let backlog = state.slots.len() as u32;
        self.cv.notify_all();
        Ok(ChunkAck { backlog }.encode())
    }

    /// Block until the chunk at `key` arrives (or `timeout` passes) and
    /// remove it from the inbox.
    fn take(&self, key: (u64, u8, u32, u32), timeout: Duration) -> Result<StoredChunk> {
        match self.try_take(key, timeout) {
            Some(chunk) => Ok(chunk),
            None => bail!(
                "{} ring chunk (round {} phase {} origin {} chunk {}) timed out — \
                 a peer is likely dead; failing fast (§4.2)",
                CollectiveStatus::RoundTimeout.marker(),
                key.0,
                key.1,
                key.2,
                key.3
            ),
        }
    }

    /// `take` without the typed error: `None` on timeout.  Lets the backend
    /// wait in bounded slices, probing coordinator liveness between them.
    fn try_take(&self, key: (u64, u8, u32, u32), timeout: Duration) -> Option<StoredChunk> {
        let deadline = Instant::now() + timeout;
        let mut state = self.state.lock().unwrap();
        loop {
            if let Some(chunk) = state.slots.remove(&key) {
                return Some(chunk);
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, _) = self.cv.wait_timeout(state, deadline - now).unwrap();
            state = guard;
        }
    }
}

/// The RPC service a rank exposes to its ring predecessor.
pub struct RingPeer {
    inbox: Arc<RingInbox>,
}

impl RingPeer {
    pub fn new(inbox: Arc<RingInbox>) -> RingPeer {
        RingPeer { inbox }
    }

    /// Convenience: the peer already wrapped in an `RpcServer`, ready for
    /// `TcpRpcHost::spawn` or `InProcTransport::new`.
    pub fn serve(inbox: Arc<RingInbox>) -> Arc<RpcServer<RingPeer>> {
        Arc::new(RpcServer::new(RingPeer::new(inbox)))
    }
}

impl Service for RingPeer {
    fn handle(&self, method: &str, payload: &[u8]) -> Result<Vec<u8>> {
        match method {
            METHOD_RING_OFFER => self.inbox.offer(ChunkFrame::decode(payload)?),
            // read-only backlog probe (sender-side flow control)
            METHOD_RING_BACKLOG => {
                Ok(ChunkAck { backlog: self.inbox.open_chunks() as u32 }.encode())
            }
            other => bail!("unknown ring method '{other}'"),
        }
    }
}

/// One rank's view of the ring: `CollectiveBackend` implemented as chunked
/// streams to the successor's [`RingPeer`] over any exactly-once transport.
pub struct RingCollective<T: Transport> {
    rank: usize,
    world: usize,
    /// this rank's inbox (fed by the predecessor through our own server)
    inbox: Arc<RingInbox>,
    /// exactly-once client to the successor's inbox service
    succ: RpcClient<T>,
    next_seq: AtomicU64,
    /// bytes per streamed chunk (rounded down to the reduce element size)
    pub chunk_bytes: usize,
    /// successor-backlog threshold past which sends throttle
    pub window: usize,
    /// throttle pause when the successor's inbox is over `window`
    pub poll_interval: Duration,
    /// give up waiting on a chunk after this long (fail-fast, §4.2)
    pub round_timeout: Duration,
    /// optional coordinator liveness probe: the ring's data path never
    /// touches the rendezvous host, so without this a dead peer only
    /// surfaces after `round_timeout`; with it, chunk waits are sliced and
    /// the lease verdict checked between slices (millisecond abort fanout)
    probe: Option<Arc<LivenessProbe>>,
    /// slice length for probed chunk waits
    probe_slice: Duration,
}

impl<T: Transport> RingCollective<T> {
    pub fn new(
        rank: usize,
        world: usize,
        inbox: Arc<RingInbox>,
        successor: T,
    ) -> RingCollective<T> {
        assert!(world >= 1, "world must be >= 1");
        assert!(rank < world, "rank {rank} out of range for world {world}");
        let succ = RpcClient::new(successor)
            .with_retry(RetryPolicy::exponential(64, Duration::from_micros(50)));
        RingCollective {
            rank,
            world,
            inbox,
            succ,
            next_seq: AtomicU64::new(0),
            chunk_bytes: DEFAULT_CHUNK_BYTES,
            window: DEFAULT_WINDOW,
            poll_interval: Duration::from_micros(200),
            round_timeout: Duration::from_secs(300),
            probe: None,
            probe_slice: Duration::from_millis(25),
        }
    }

    /// Attach a coordinator liveness probe (multi-process ring workers).
    pub fn with_probe(mut self, probe: Arc<LivenessProbe>) -> Self {
        self.probe = Some(probe);
        self
    }

    pub fn with_retry(mut self, retry: RetryPolicy) -> Self {
        self.succ.retry = retry;
        self
    }

    pub fn with_chunk_bytes(mut self, chunk_bytes: usize) -> Self {
        assert!(chunk_bytes >= 16, "chunk_bytes must be >= 16");
        self.chunk_bytes = chunk_bytes;
        self
    }

    pub fn with_window(mut self, window: usize) -> Self {
        assert!(window >= 1, "window must be >= 1");
        self.window = window;
        self
    }

    pub fn with_round_timeout(mut self, timeout: Duration) -> Self {
        self.round_timeout = timeout;
        self
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn client(&self) -> &RpcClient<T> {
        &self.succ
    }

    /// Ship one chunk to the successor, honouring the credit window.
    ///
    /// `wait_for_credit = true` (the REDUCE stream — the multi-GB gradient
    /// path) polls the successor's backlog until it drops to `window`, hard-
    /// bounding a slow rank's inbox.  This is deadlock-free ONLY for the
    /// reduce sweep: its consumption chain terminates at the last rank,
    /// whose broadcast sends never block.  Gather and broadcast sends pass
    /// `false` (a single soft pause) — a hard wait there closes a cycle
    /// around the ring, because those streams are consumed only after the
    /// receiver finishes its own sends.
    fn send_chunk(&self, frame: ChunkFrame, wait_for_credit: bool) -> Result<()> {
        let round = frame.round;
        let chunk = frame.chunk;
        let reply = self
            .succ
            .call(METHOD_RING_OFFER, frame.encode())
            .with_context(|| format!("streaming ring chunk {chunk} of round {round}"))?;
        let mut backlog = ChunkAck::decode(&reply)?.backlog as usize;
        if !wait_for_credit {
            if backlog > self.window {
                std::thread::sleep(self.poll_interval);
            }
            return Ok(());
        }
        let t0 = Instant::now();
        while backlog > self.window {
            if let Some(probe) = &self.probe {
                probe.check()?;
            }
            if t0.elapsed() > self.round_timeout {
                bail!(
                    "{} ring successor backlog stuck at {backlog} (> window {}) for \
                     {:.0?} after chunk {chunk} of round {round} — peer is likely \
                     wedged; failing fast (§4.2)",
                    CollectiveStatus::RoundTimeout.marker(),
                    self.window,
                    self.round_timeout
                );
            }
            std::thread::sleep(self.poll_interval);
            let reply = self
                .succ
                .call(METHOD_RING_BACKLOG, Vec::new())
                .with_context(|| format!("polling ring backlog in round {round}"))?;
            backlog = ChunkAck::decode(&reply)?.backlog as usize;
        }
        Ok(())
    }

    /// Stream a whole payload to the successor as `total` bounded chunks.
    fn send_payload(
        &self,
        round: u64,
        phase: u8,
        origin: u32,
        tag: &str,
        bytes: &[u8],
        chunk_bytes: usize,
    ) -> Result<()> {
        let total = crate::util::codec::chunk_count(bytes.len(), chunk_bytes) as u32;
        for c in 0..total {
            let (lo, hi) = crate::util::codec::chunk_range(bytes.len(), chunk_bytes, c as usize);
            self.send_chunk(
                ChunkFrame {
                    round,
                    phase,
                    origin,
                    chunk: c,
                    total,
                    tag: tag.to_string(),
                    payload: bytes[lo..hi].to_vec(),
                },
                false, // gather streams soft-throttle (see send_chunk docs)
            )?;
        }
        Ok(())
    }

    /// Take the expected chunk from our inbox, enforcing lockstep: a tag
    /// mismatch means the predecessor is in a different collective.
    fn recv_chunk(
        &self,
        round: u64,
        phase: u8,
        origin: u32,
        chunk: u32,
        tag: &str,
        deadline: Instant,
    ) -> Result<StoredChunk> {
        let key = (round, phase, origin, chunk);
        let stored = match &self.probe {
            // no probe: one blocking wait for the whole budget
            None => {
                let remaining = deadline.saturating_duration_since(Instant::now());
                self.inbox.take(key, remaining)?
            }
            // probed: wait in slices, checking the coordinator's lease
            // verdict between them — a latched peer death aborts the wait
            // in ~one slice instead of the full round timeout
            Some(probe) => loop {
                probe.check()?;
                let remaining = deadline.saturating_duration_since(Instant::now());
                if remaining.is_zero() {
                    // produce the canonical typed timeout error
                    break self.inbox.take(key, Duration::ZERO)?;
                }
                if let Some(found) = self.inbox.try_take(key, remaining.min(self.probe_slice)) {
                    break found;
                }
            },
        };
        if stored.tag != tag {
            bail!(
                "{} collective lockstep violation at ring round {round}: rank {} is in \
                 '{tag}' while its predecessor streamed '{}'",
                CollectiveStatus::Poisoned.marker(),
                self.rank,
                stored.tag
            );
        }
        Ok(stored)
    }

    /// Receive one whole payload (all chunks of `origin`) from the
    /// predecessor's stream.
    fn recv_payload(
        &self,
        round: u64,
        phase: u8,
        origin: u32,
        tag: &str,
        deadline: Instant,
    ) -> Result<Vec<u8>> {
        let first = self.recv_chunk(round, phase, origin, 0, tag, deadline)?;
        let total = first.total;
        let mut buf = first.payload;
        for c in 1..total {
            let next = self.recv_chunk(round, phase, origin, c, tag, deadline)?;
            if next.total != total {
                bail!(
                    "{} inconsistent chunk totals in ring round {round}: {} then {}",
                    CollectiveStatus::ProtocolViolation.marker(),
                    total,
                    next.total
                );
            }
            buf.extend_from_slice(&next.payload);
        }
        Ok(buf)
    }
}

impl<T: Transport> CollectiveBackend for RingCollective<T> {
    fn world_size(&self) -> usize {
        self.world
    }

    /// Ring all-gather: after `world - 1` forwarding steps every rank holds
    /// every origin's payload, in rank order.
    fn exchange(&self, rank: usize, tag: &str, payload: Vec<u8>) -> Result<Vec<Vec<u8>>> {
        debug_assert_eq!(rank, self.rank, "backend is bound to one rank");
        let round = self.next_seq.fetch_add(1, Ordering::SeqCst);
        if self.world == 1 {
            self.inbox.retire_through(round);
            return Ok(vec![payload]);
        }
        let deadline = Instant::now() + self.round_timeout;
        let mut parts: Vec<Option<Vec<u8>>> = (0..self.world).map(|_| None).collect();
        parts[self.rank] = Some(payload);
        for step in 0..self.world - 1 {
            // forward the origin received last step (own payload at step 0);
            // borrow, don't clone — the chunker copies only chunk-sized slices
            let send_origin = (self.rank + self.world - step) % self.world;
            let bytes = parts[send_origin]
                .as_deref()
                .expect("forwarded payload must have been received");
            let origin = send_origin as u32;
            self.send_payload(round, PHASE_GATHER, origin, tag, bytes, self.chunk_bytes)?;
            let recv_origin = (self.rank + self.world - step - 1) % self.world;
            parts[recv_origin] =
                Some(self.recv_payload(round, PHASE_GATHER, recv_origin as u32, tag, deadline)?);
        }
        self.inbox.retire_through(round);
        Ok(parts
            .into_iter()
            .map(|p| p.expect("all origins gathered after world-1 steps"))
            .collect())
    }

    /// Streaming ring all-reduce: rank-order partial sums flow 0 → … → N-1
    /// chunk by chunk (reduce sweep); the last rank finalizes each chunk and
    /// immediately streams it back around the ring (broadcast sweep).  Per
    /// rank: at most 2 × payload sent, regardless of world size.
    fn all_reduce(
        &self,
        rank: usize,
        tag: &str,
        payload: Vec<u8>,
        op: ReduceOp,
    ) -> Result<Vec<u8>> {
        debug_assert_eq!(rank, self.rank, "backend is bound to one rank");
        if self.world == 1 {
            let round = self.next_seq.fetch_add(1, Ordering::SeqCst);
            self.inbox.retire_through(round);
            return Ok(payload);
        }
        if payload.len() % op.elem_bytes() != 0 {
            bail!(
                "reduce payload {} bytes is not a multiple of the {}-byte element",
                payload.len(),
                op.elem_bytes()
            );
        }
        let round = self.next_seq.fetch_add(1, Ordering::SeqCst);
        let deadline = Instant::now() + self.round_timeout;
        // element-aligned chunks so combine() never splits a value
        let cb = {
            let aligned = self.chunk_bytes - self.chunk_bytes % op.elem_bytes();
            aligned.max(op.elem_bytes())
        };
        let total = crate::util::codec::chunk_count(payload.len(), cb) as u32;
        let last = self.world - 1;
        let mut result = vec![0u8; payload.len()];

        // reduce sweep; rank `last` starts the broadcast as chunks finalize
        for c in 0..total {
            let (lo, hi) = crate::util::codec::chunk_range(payload.len(), cb, c as usize);
            let mut acc = payload[lo..hi].to_vec();
            if self.rank > 0 {
                let partial = self.recv_chunk(round, PHASE_REDUCE, 0, c, tag, deadline)?;
                // rank-order accumulation: (v₀ ⊕ … ⊕ v_{rank-1}) ⊕ v_rank
                let mut sum = partial.payload;
                op.combine(&mut sum, &acc)?;
                acc = sum;
            }
            if self.rank < last {
                // hard credit window: bounds the successor's inbox on the
                // gradient-sized stream (deadlock-free — see send_chunk)
                self.send_chunk(
                    ChunkFrame {
                        round,
                        phase: PHASE_REDUCE,
                        origin: 0,
                        chunk: c,
                        total,
                        tag: tag.to_string(),
                        payload: acc,
                    },
                    true,
                )?;
            } else {
                result[lo..hi].copy_from_slice(&acc);
                self.send_chunk(
                    ChunkFrame {
                        round,
                        phase: PHASE_BCAST,
                        origin: 0,
                        chunk: c,
                        total,
                        tag: tag.to_string(),
                        payload: acc,
                    },
                    false,
                )?;
            }
        }

        // broadcast sweep: last → 0 → 1 → … → world-2
        if self.rank < last {
            for c in 0..total {
                let (lo, hi) = crate::util::codec::chunk_range(payload.len(), cb, c as usize);
                let reduced = self.recv_chunk(round, PHASE_BCAST, 0, c, tag, deadline)?;
                if reduced.payload.len() != hi - lo {
                    bail!(
                        "{} ring broadcast chunk {c} is {} bytes, expected {}",
                        CollectiveStatus::ProtocolViolation.marker(),
                        reduced.payload.len(),
                        hi - lo
                    );
                }
                if self.rank + 1 < last {
                    // successor still needs the reduced chunk
                    self.send_chunk(
                        ChunkFrame {
                            round,
                            phase: PHASE_BCAST,
                            origin: 0,
                            chunk: c,
                            total,
                            tag: tag.to_string(),
                            payload: reduced.payload.clone(),
                        },
                        false,
                    )?;
                }
                result[lo..hi].copy_from_slice(&reduced.payload);
            }
        }
        self.inbox.retire_through(round);
        Ok(result)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::collective::Collective;
    use crate::rpc::transport::{FlakyTransport, InProcTransport};
    use crate::runtime::params::ParamSet;
    use crate::runtime::tensor::Tensor;

    /// Wire up a full in-process ring: rank r's client talks to rank
    /// (r+1)%world's inbox server through `wrap`.
    fn ring_group<T, F>(world: usize, wrap: F) -> Vec<Arc<Collective>>
    where
        T: Transport + 'static,
        F: Fn(usize, Arc<RpcServer<RingPeer>>) -> T,
    {
        let inboxes: Vec<Arc<RingInbox>> = (0..world).map(|_| RingInbox::new()).collect();
        let servers: Vec<Arc<RpcServer<RingPeer>>> =
            inboxes.iter().map(|ib| RingPeer::serve(ib.clone())).collect();
        (0..world)
            .map(|rank| {
                let succ = wrap(rank, servers[(rank + 1) % world].clone());
                Collective::with_backend(Arc::new(
                    RingCollective::new(rank, world, inboxes[rank].clone(), succ)
                        .with_chunk_bytes(16) // force multi-chunk streaming
                        .with_window(2),
                ))
            })
            .collect()
    }

    fn plain_ring(world: usize) -> Vec<Arc<Collective>> {
        ring_group(world, |_, server| InProcTransport::new(server))
    }

    fn run_ranks<R: Send + 'static>(
        cols: Vec<Arc<Collective>>,
        body: impl Fn(usize, Arc<Collective>) -> R + Send + Sync + 'static,
    ) -> Vec<R> {
        let body = Arc::new(body);
        let handles: Vec<_> = cols
            .into_iter()
            .enumerate()
            .map(|(rank, col)| {
                let body = body.clone();
                std::thread::spawn(move || body(rank, col))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn world_of_one_is_identity() {
        let cols = plain_ring(1);
        let set = ParamSet::new(vec![Tensor::f32(vec![2], vec![1.5, -2.0])]);
        assert_eq!(cols[0].all_reduce_mean(0, &set).unwrap(), set);
        assert_eq!(cols[0].mean_scalars(0, vec![7.0]).unwrap(), vec![7.0]);
        cols[0].barrier(0).unwrap();
    }

    #[test]
    fn ring_all_reduce_means_across_ranks() {
        for world in [2usize, 3, 4] {
            let cols = plain_ring(world);
            let results = run_ranks(cols, move |rank, col| {
                // 9 f32s at 16-byte chunks → 3 chunks, last one partial
                let set = ParamSet::new(vec![Tensor::f32(
                    vec![9],
                    (0..9).map(|i| (rank * 9 + i) as f32).collect(),
                )]);
                col.all_reduce_mean(rank, &set).unwrap()
            });
            for r in &results[1..] {
                assert_eq!(r, &results[0], "world {world}: ranks must agree");
            }
            let expect: Vec<f32> = (0..9)
                .map(|i| {
                    (0..world).map(|r| (r * 9 + i) as f32).sum::<f32>() / world as f32
                })
                .collect();
            assert_eq!(results[0].tensors[0].as_f32().unwrap(), &expect[..], "world {world}");
        }
    }

    #[test]
    fn ring_gather_returns_rank_order_with_ragged_payloads() {
        let cols = plain_ring(3);
        let results = run_ranks(cols, |rank, col| {
            // ragged: rank r contributes r+1 rows
            let rows: Vec<Vec<i32>> = (0..rank + 1).map(|i| vec![rank as i32, i as i32]).collect();
            col.gather_tokens(rank, rows).unwrap()
        });
        for r in &results[1..] {
            assert_eq!(r, &results[0]);
        }
        assert_eq!(results[0].len(), 3);
        for (rank, rows) in results[0].iter().enumerate() {
            assert_eq!(rows.len(), rank + 1, "rank {rank} row count");
            assert_eq!(rows[0], vec![rank as i32, 0]);
        }
    }

    #[test]
    fn repeated_rounds_and_barriers_stay_in_lockstep() {
        let cols = plain_ring(3);
        let results = run_ranks(cols, |rank, col| {
            let mut out = Vec::new();
            for round in 0..10 {
                col.barrier(rank).unwrap();
                let m = col
                    .mean_scalars(rank, vec![(rank * 10 + round) as f64])
                    .unwrap();
                out.push(m[0]);
            }
            out
        });
        for r in &results[1..] {
            assert_eq!(r, &results[0]);
        }
        for (round, v) in results[0].iter().enumerate() {
            assert_eq!(*v, 10.0 + round as f64); // mean over ranks of 10r+round
        }
    }

    #[test]
    fn duplicate_deliveries_never_double_reduce() {
        // every chunk delivered twice: the peer's exactly-once cache must
        // absorb the duplicates or sums would double
        let cols = ring_group(2, |rank, server| {
            FlakyTransport::new(InProcTransport::new(server), 31 + rank as u64)
                .with_probs(0.0, 0.0, 1.0)
        });
        let results = run_ranks(cols, |rank, col| {
            col.mean_scalars(rank, vec![rank as f64 * 2.0]).unwrap()
        });
        assert_eq!(results[0], vec![1.0]);
        assert_eq!(results[1], vec![1.0]);
    }

    #[test]
    fn tag_mismatch_is_typed_lockstep_violation() {
        // short timeout: the rank that does NOT see the mismatched frame
        // waits for a broadcast that never comes and must fail fast too
        let inboxes: Vec<Arc<RingInbox>> = (0..2).map(|_| RingInbox::new()).collect();
        let servers: Vec<Arc<RpcServer<RingPeer>>> =
            inboxes.iter().map(|ib| RingPeer::serve(ib.clone())).collect();
        let cols: Vec<Arc<Collective>> = (0..2)
            .map(|rank| {
                Collective::with_backend(Arc::new(
                    RingCollective::new(
                        rank,
                        2,
                        inboxes[rank].clone(),
                        InProcTransport::new(servers[(rank + 1) % 2].clone()),
                    )
                    .with_round_timeout(Duration::from_millis(200)),
                ))
            })
            .collect();
        let col1 = cols[1].clone();
        let h = std::thread::spawn(move || col1.mean_scalars(1, vec![1.0]));
        let set = ParamSet::new(vec![Tensor::f32(vec![1], vec![1.0])]);
        let r0 = cols[0].all_reduce_mean(0, &set);
        let r1 = h.join().unwrap();
        // the receiving side detects the mismatch with the typed poison
        // status; the other fails fast on its (typed) round timeout
        let errs: Vec<anyhow::Error> = [r0.err(), r1.err()].into_iter().flatten().collect();
        assert_eq!(errs.len(), 2, "mismatched collectives must fail on both ranks");
        assert!(
            errs.iter()
                .any(|e| CollectiveStatus::classify_error(e) == Some(CollectiveStatus::Poisoned)),
            "expected a typed lockstep poison, got: {errs:?}"
        );
        assert!(
            errs.iter().all(|e| CollectiveStatus::classify_error(e).is_some()),
            "every failure must carry a typed status: {errs:?}"
        );
    }

    #[test]
    fn dead_peer_times_out_fail_fast() {
        let inboxes: Vec<Arc<RingInbox>> = (0..2).map(|_| RingInbox::new()).collect();
        let servers: Vec<Arc<RpcServer<RingPeer>>> =
            inboxes.iter().map(|ib| RingPeer::serve(ib.clone())).collect();
        // rank 1 never participates
        let succ = InProcTransport::new(servers[0].clone());
        let backend = RingCollective::new(1, 2, inboxes[1].clone(), succ)
            .with_round_timeout(Duration::from_millis(20));
        let err = backend
            .all_reduce(1, "params", vec![0; 4], ReduceOp::SumF32)
            .unwrap_err();
        assert_eq!(
            CollectiveStatus::classify_error(&err),
            Some(CollectiveStatus::RoundTimeout),
            "{err:#}"
        );
    }

    #[test]
    fn stale_redelivery_after_round_retired_is_dropped() {
        // A chunk re-executed past the RPC tombstone horizon must not park
        // forever in the inbox of a rank that already finished the round.
        let inbox = RingInbox::new();
        let peer = RingPeer::new(inbox.clone());
        let frame = ChunkFrame {
            round: 0,
            phase: PHASE_REDUCE,
            origin: 0,
            chunk: 0,
            total: 1,
            tag: "params".into(),
            payload: vec![1, 2, 3, 4],
        };
        peer.handle(METHOD_RING_OFFER, &frame.encode()).unwrap();
        assert_eq!(inbox.open_chunks(), 1);
        let got = inbox.take((0, PHASE_REDUCE, 0, 0), Duration::from_millis(10)).unwrap();
        assert_eq!(got.payload, vec![1, 2, 3, 4]);
        inbox.retire_through(0);
        // stale re-delivery of the consumed chunk: acked, NOT re-inserted
        peer.handle(METHOD_RING_OFFER, &frame.encode()).unwrap();
        assert_eq!(inbox.open_chunks(), 0, "retired-round chunk must be dropped");
        // later rounds still flow
        let next = ChunkFrame { round: 1, ..frame };
        peer.handle(METHOD_RING_OFFER, &next.encode()).unwrap();
        assert_eq!(inbox.open_chunks(), 1);
    }

    #[test]
    fn inboxes_drain_after_rounds() {
        let inboxes: Vec<Arc<RingInbox>> = (0..3).map(|_| RingInbox::new()).collect();
        let servers: Vec<Arc<RpcServer<RingPeer>>> =
            inboxes.iter().map(|ib| RingPeer::serve(ib.clone())).collect();
        let cols: Vec<Arc<Collective>> = (0..3)
            .map(|rank| {
                Collective::with_backend(Arc::new(
                    RingCollective::new(
                        rank,
                        3,
                        inboxes[rank].clone(),
                        InProcTransport::new(servers[(rank + 1) % 3].clone()),
                    )
                    .with_chunk_bytes(16),
                ))
            })
            .collect();
        let results = run_ranks(cols, |rank, col| {
            let set = ParamSet::new(vec![Tensor::f32(vec![8], vec![rank as f32; 8])]);
            col.all_reduce_mean(rank, &set).unwrap()
        });
        assert_eq!(results[0].tensors[0].as_f32().unwrap(), &[1.0; 8]);
        for (i, ib) in inboxes.iter().enumerate() {
            assert_eq!(ib.open_chunks(), 0, "inbox {i} must drain");
        }
    }
}
