//! Layer-3 coordinator: the paper's system contribution.
//!
//! * `controller` — the SPMD parallel controller (§3.1);
//! * `single` — the single-controller baseline data plane (§2.2/§3.1);
//! * `collective` — inter-controller collectives (§3.1): the
//!   `CollectiveBackend` abstraction plus the in-proc rendezvous backend;
//! * `rpc_collective` — the RPC-backed collective (rank-0 rendezvous
//!   service + per-rank clients) multi-process launches coordinate through;
//! * `ring_collective` — chunked streaming ring collectives over the same
//!   exactly-once RPC stack: O(payload) bytes per rank, independent of
//!   world size (no rank-0 bottleneck);
//! * `generation` — the stage-1 generation engine (KV-cached sampling);
//! * `rollout` — the continuous-batching rollout scheduler over a paged
//!   KV cache (admission waves, token-granular retirement, prefix reuse,
//!   long-tail cancellation);
//! * `sampling` — GRPO/GAE advantages + DAPO dynamic-sampling filter (§3.2);
//! * `pretrain` — BT-reward and generative-verifier pre-training (§5);
//! * `workflow` — the 4-stage RLHF workflow definition (§2.2).

pub mod collective;
pub mod controller;
pub mod generation;
pub mod pretrain;
pub mod ring_collective;
pub mod rollout;
pub mod rpc_collective;
pub mod sampling;
pub mod single;
pub mod workflow;

pub use collective::{Collective, CollectiveBackend, InProcBackend, ReduceOp, Rendezvous};
pub use ring_collective::{RingCollective, RingInbox, RingPeer};
pub use rpc_collective::{CollectiveStatus, RendezvousHost, RpcCollective};
pub use controller::{Controller, RolloutBatch, StepStats};
pub use generation::{generate, GenOutput, SamplerConfig};
