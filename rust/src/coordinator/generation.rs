//! Stage-1 generation engine (paper §2.2): batched auto-regressive
//! sampling over the KV-cached `prefill`/`decode_step` artifacts — the
//! vLLM/SGLang analogue the coordinator schedules.
//!
//! The whole batch decodes in lockstep (fixed artifact shapes); finished
//! rows keep feeding PAD but their sampled tokens are ignored.  Per-row
//! generation lengths come back alongside the padded token matrix — the
//! long-tail signal the placement experiments consume.

use anyhow::{bail, Result};

use crate::data::tokenizer::{EOS, PAD};
use crate::runtime::engine::Engine;

use super::rollout;
use crate::runtime::params::ParamSet;
use crate::runtime::tensor::Tensor;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct SamplerConfig {
    pub temperature: f32,
    pub top_k: usize,
    /// stop decoding a row at EOS
    pub stop_at_eos: bool,
}

impl Default for SamplerConfig {
    fn default() -> Self {
        SamplerConfig { temperature: 0.8, top_k: 16, stop_at_eos: true }
    }
}

#[derive(Debug, Clone)]
pub struct GenOutput {
    /// [B][S] full rows: prompt + generated + PAD
    pub rows: Vec<Vec<i32>>,
    /// per-row generated token count (incl. EOS when present)
    pub gen_lens: Vec<usize>,
    /// per-row loss mask over [S]: 1.0 on generated tokens
    pub masks: Vec<Vec<f32>>,
}

/// Generate responses for a batch of fixed-width prompts.
/// `prompts` must be exactly [batch][prompt_len] (the artifact contract).
///
/// Fast path: when the artifact set carries `generate_rollout` (the fused
/// prefill+scan+sample module — see EXPERIMENTS.md §Perf) and the sampler
/// is stochastic, the whole rollout is ONE engine call with no per-token
/// KV-cache round-trips.  The fused module bakes its sampler parameters
/// in at trace time; the manifest records them (`"sampler"` block) and a
/// `cfg` asking for anything else is an ERROR — silently decoding a
/// differently-distributed stepwise rollout is exactly the bug this gate
/// replaces.  Greedy (`temperature <= 0`) is an explicit argmax request
/// the stochastic fused module cannot express, so it always takes the
/// per-token path.
///
/// The per-token path runs on the continuous-batching rollout scheduler
/// (`coordinator::rollout`) over a paged KV cache — bit-identical to
/// [`generate_stepwise`] for the same seed (pinned by differential
/// tests).
pub fn generate(
    engine: &Engine,
    params: &ParamSet,
    prompts: &[Vec<i32>],
    cfg: &SamplerConfig,
    rng: &mut Rng,
) -> Result<GenOutput> {
    let manifest = engine.manifest();
    if cfg.temperature > 0.0 && manifest.artifacts.contains_key("generate_rollout") {
        let Some(baked) = manifest.sampler else {
            bail!(
                "artifact set '{}' carries generate_rollout but its manifest \
                 has no \"sampler\" block recording the baked sampler \
                 parameters — regenerate the set (aot.py records top_k / \
                 stop_at_eos now)",
                manifest.dims.name
            );
        };
        if cfg.top_k != baked.top_k || cfg.stop_at_eos != baked.stop_at_eos {
            bail!(
                "sampler config (top_k={}, stop_at_eos={}) does not match the \
                 parameters baked into this set's generate_rollout artifact \
                 (top_k={}, stop_at_eos={}); use the baked values, or decode \
                 greedily (temperature <= 0) for the per-token path",
                cfg.top_k,
                cfg.stop_at_eos,
                baked.top_k,
                baked.stop_at_eos
            );
        }
        return generate_fused(engine, params, prompts, cfg, rng);
    }
    generate_scheduled(engine, params, prompts, cfg, rng)
}

/// Route a fixed `[batch]` of prompts through the continuous-batching
/// rollout scheduler (paged KV cache, immediate EOS retirement).  Same
/// contract and same bits as [`generate_stepwise`].
fn generate_scheduled(
    engine: &Engine,
    params: &ParamSet,
    prompts: &[Vec<i32>],
    cfg: &SamplerConfig,
    rng: &mut Rng,
) -> Result<GenOutput> {
    let dims = engine.manifest().dims.clone();
    let (b, p) = (dims.batch, dims.prompt_len);
    if prompts.len() != b || prompts.iter().any(|r| r.len() != p) {
        bail!(
            "prompts must be [{b}][{p}], got [{}][{}]",
            prompts.len(),
            prompts.first().map(|r| r.len()).unwrap_or(0)
        );
    }
    let requests: Vec<rollout::RolloutRequest> = prompts
        .iter()
        .enumerate()
        .map(|(id, prompt)| rollout::RolloutRequest { id, prompt: prompt.clone() })
        .collect();
    let run = rollout::run(
        engine,
        params,
        &requests,
        cfg,
        rng,
        &rollout::RolloutOptions::default(),
    )?;
    Ok(gen_output_from(run.results))
}

/// Adapt scheduler results (request order) into the training-side
/// `GenOutput` layout.
pub fn gen_output_from(results: Vec<rollout::RolloutResult>) -> GenOutput {
    let mut rows = Vec::with_capacity(results.len());
    let mut gen_lens = Vec::with_capacity(results.len());
    let mut masks = Vec::with_capacity(results.len());
    for r in results {
        rows.push(r.row);
        gen_lens.push(r.gen_len);
        masks.push(r.mask);
    }
    GenOutput { rows, gen_lens, masks }
}

/// The glen/mask/PAD accounting rule every generation path must agree
/// on: the generated span runs to the first EOS inclusive (when stopping
/// at EOS), everything after it is PAD, and the loss mask covers exactly
/// the span.  The fused path derives its accounting with this; the
/// stepwise/scheduler paths account incrementally and are pinned against
/// it by tests.
pub fn account_row(row: &mut [i32], p: usize, stop_at_eos: bool) -> (usize, Vec<f32>) {
    let s = row.len();
    let glen = if stop_at_eos {
        match row[p..].iter().position(|&t| t == EOS) {
            Some(i) => i + 1,
            None => s - p,
        }
    } else {
        s - p
    };
    for x in row[p + glen..].iter_mut() {
        *x = PAD;
    }
    let mut mask = vec![0.0f32; s];
    for x in mask.iter_mut().skip(p).take(glen) {
        *x = 1.0;
    }
    (glen, mask)
}

/// One-call rollout via the fused `generate_rollout` artifact.
fn generate_fused(
    engine: &Engine,
    params: &ParamSet,
    prompts: &[Vec<i32>],
    cfg: &SamplerConfig,
    rng: &mut Rng,
) -> Result<GenOutput> {
    let dims = engine.manifest().dims.clone();
    let (b, p, s) = (dims.batch, dims.prompt_len, dims.max_seq);
    if prompts.len() != b || prompts.iter().any(|r| r.len() != p) {
        bail!("prompts must be [{b}][{p}]");
    }
    let flat: Vec<i32> = prompts.iter().flatten().copied().collect();
    let prompts_t = Tensor::i32(vec![b, p], flat);
    let seed_t = Tensor::scalar_u32(rng.next_u64() as u32);
    let temp_t = Tensor::scalar_f32(cfg.temperature);
    let mut inputs: Vec<&Tensor> = params.tensors.iter().collect();
    inputs.extend([&prompts_t, &seed_t, &temp_t]);
    let rows_t = engine.run_refs("generate_rollout", &inputs)?.remove(0);
    let data = rows_t.as_i32()?;
    let mut rows = Vec::with_capacity(b);
    let mut gen_lens = Vec::with_capacity(b);
    let mut masks = Vec::with_capacity(b);
    for row_i in 0..b {
        let mut row = data[row_i * s..(row_i + 1) * s].to_vec();
        // shared accounting rule: gen length = up to and including the
        // first EOS; the artifact emits PAD after EOS by construction
        let (glen, m) = account_row(&mut row, p, cfg.stop_at_eos);
        rows.push(row);
        gen_lens.push(glen);
        masks.push(m);
    }
    Ok(GenOutput { rows, gen_lens, masks })
}

/// Per-token decode loop (`prefill` + `decode_step`) over one monolithic
/// dense KV cache.  Kept public as the reference implementation the
/// scheduler's differential tests pin bit-identity against; production
/// traffic goes through `generate` → the rollout scheduler.
pub fn generate_stepwise(
    engine: &Engine,
    params: &ParamSet,
    prompts: &[Vec<i32>],
    cfg: &SamplerConfig,
    rng: &mut Rng,
) -> Result<GenOutput> {
    let dims = engine.manifest().dims.clone();
    let (b, p, s, v) = (dims.batch, dims.prompt_len, dims.max_seq, dims.vocab);
    if prompts.len() != b || prompts.iter().any(|r| r.len() != p) {
        bail!(
            "prompts must be [{b}][{p}], got [{}][{}]",
            prompts.len(),
            prompts.first().map(|r| r.len()).unwrap_or(0)
        );
    }

    // prefill — borrowed params: no clone of the multi-MB parameter set
    let flat: Vec<i32> = prompts.iter().flatten().copied().collect();
    let rows_t = Tensor::i32(vec![b, p], flat);
    let mut inputs: Vec<&Tensor> = params.tensors.iter().collect();
    inputs.push(&rows_t);
    let mut out = engine.run_refs("prefill", &inputs)?;
    let mut logits = out.remove(0);
    let mut ck = out.remove(0);
    let mut cv = out.remove(0);

    let mut rows: Vec<Vec<i32>> = prompts.to_vec();
    let mut done = vec![false; b];
    let mut gen_lens = vec![0usize; b];

    // One seed draw per call — the same single `next_u64` the fused path
    // feeds the graph — then the counter-based Gumbel stream, keyed by
    // (position, row), replays exactly the fused sampler's draws.
    let mut base = crate::util::rng::sampler_base(rng.next_u64() as u32);

    for pos in p..s {
        // sample next token per row from `logits` [B, V]
        let ld = logits.as_f32()?;
        let mut step_tokens = Vec::with_capacity(b);
        for row in 0..b {
            let tok = if done[row] {
                PAD
            } else {
                let slice = &ld[row * v..(row + 1) * v];
                let t = crate::util::rng::counter_sample_logits(
                    slice,
                    cfg.temperature,
                    cfg.top_k,
                    base,
                    row,
                ) as i32;
                gen_lens[row] += 1;
                if cfg.stop_at_eos && t == EOS {
                    done[row] = true;
                }
                t
            };
            rows[row].push(tok);
            step_tokens.push(tok);
        }
        // the fused graph advances the counter for every row each step,
        // finished or not
        base = base.wrapping_add((b * v) as u32);
        if done.iter().all(|&d| d) || pos == s - 1 {
            // pad the remaining columns
            for row in rows.iter_mut() {
                row.resize(s, PAD);
            }
            break;
        }
        // decode next position — borrowed params + caches, so per-token
        // cost is O(step inputs), not O(params) (the old loop cloned the
        // full ParamSet every token)
        let step_t = Tensor::i32(vec![b], step_tokens);
        let pos_t = Tensor::scalar_i32(pos as i32);
        let mut inputs: Vec<&Tensor> = params.tensors.iter().collect();
        inputs.push(&ck);
        inputs.push(&cv);
        inputs.push(&step_t);
        inputs.push(&pos_t);
        let mut out = engine.run_refs("decode_step", &inputs)?;
        drop(inputs);
        logits = out.remove(0);
        ck = out.remove(0);
        cv = out.remove(0);
    }

    // loss masks over generated spans
    let masks = rows
        .iter()
        .zip(&gen_lens)
        .map(|(_, &glen)| {
            let mut m = vec![0.0f32; s];
            for x in m.iter_mut().skip(p).take(glen) {
                *x = 1.0;
            }
            m
        })
        .collect();

    Ok(GenOutput { rows, gen_lens, masks })
}

/// Tokens matrix [B,S] as a Tensor (training input layout).
pub fn rows_tensor(rows: &[Vec<i32>]) -> Tensor {
    let b = rows.len();
    let s = rows[0].len();
    Tensor::i32(vec![b, s], rows.iter().flatten().copied().collect())
}

pub fn masks_tensor(masks: &[Vec<f32>]) -> Tensor {
    let b = masks.len();
    let s = masks[0].len();
    Tensor::f32(vec![b, s], masks.iter().flatten().copied().collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_tensor_layout() {
        let t = rows_tensor(&[vec![1, 2], vec![3, 4]]);
        assert_eq!(t.shape, vec![2, 2]);
        assert_eq!(t.as_i32().unwrap(), &[1, 2, 3, 4]);
    }

    #[test]
    fn masks_tensor_layout() {
        let t = masks_tensor(&[vec![0.0, 1.0], vec![1.0, 0.0]]);
        assert_eq!(t.shape, vec![2, 2]);
        assert_eq!(t.as_f32().unwrap(), &[0.0, 1.0, 1.0, 0.0]);
    }

    // engine-backed generation tests live in rust/tests/coordinator_integration.rs
}
