//! PJRT execution engine: loads AOT HLO-text artifacts, compiles them once,
//! and executes them with host `Tensor` inputs.
//!
//! This is the only place Python-built compute enters the Rust system.  The
//! pattern follows /opt/xla-example/load_hlo: `HloModuleProto::from_text_file`
//! → `XlaComputation::from_proto` → `PjRtClient::compile` → `execute`.
//! HLO *text* is the interchange format (serialized protos from jax ≥ 0.5 are
//! rejected by xla_extension 0.5.1 — see aot.py).
//!
//! Thread-safety: `xla` wrapper types hold raw pointers and are not `Send`;
//! the engine serializes all PJRT access behind one mutex.  XLA-CPU
//! parallelizes *inside* an execution via its intra-op thread pool, so
//! coordinator-level threads lose no meaningful compute parallelism.

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::runtime::manifest::{artifacts_dir, ArtifactSpec, Manifest};
use crate::runtime::tensor::Tensor;

struct Inner {
    client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

/// Per-artifact execution statistics (feeds the utilization monitor and the
/// §Perf tables in EXPERIMENTS.md).
#[derive(Debug, Clone, Default)]
pub struct ExecStats {
    pub calls: u64,
    pub total: Duration,
    pub compile_time: Duration,
}

pub struct Engine {
    manifest: Manifest,
    inner: Mutex<Inner>,
    stats: Mutex<HashMap<String, ExecStats>>,
}

// SAFETY: all access to the raw-pointer-holding xla types is serialized
// behind `inner`; the PJRT CPU plugin itself is thread-safe.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

impl Engine {
    /// Load the artifact set for a named config (e.g. "tiny", "quickstart").
    pub fn load(config: &str) -> Result<Engine> {
        Self::from_dir(artifacts_dir(config))
    }

    pub fn from_dir(dir: impl AsRef<std::path::Path>) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            manifest,
            inner: Mutex::new(Inner { client, executables: HashMap::new() }),
            stats: Mutex::new(HashMap::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Pre-compile a set of artifacts (elides first-call latency).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.ensure_compiled(n)?;
        }
        Ok(())
    }

    fn ensure_compiled(&self, name: &str) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        if inner.executables.contains_key(name) {
            return Ok(());
        }
        let path = self.manifest.hlo_path(name)?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = inner
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact '{name}'"))?;
        inner.executables.insert(name.to_string(), exe);
        self.stats
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .compile_time = t0.elapsed();
        Ok(())
    }

    fn validate_inputs(spec: &ArtifactSpec, inputs: &[&Tensor]) -> Result<()> {
        if inputs.len() != spec.inputs.len() {
            bail!(
                "artifact '{}' expects {} inputs, got {}",
                spec.name,
                spec.inputs.len(),
                inputs.len()
            );
        }
        for (i, (t, s)) in inputs.iter().zip(&spec.inputs).enumerate() {
            if t.shape != s.shape || t.dtype() != s.dtype {
                bail!(
                    "artifact '{}' input #{i} ('{}'): expected {:?} {}, \
                     got {:?} {}",
                    spec.name,
                    s.name,
                    s.shape,
                    s.dtype.name(),
                    t.shape,
                    t.dtype().name()
                );
            }
        }
        Ok(())
    }

    /// Execute an artifact.  Inputs/outputs are host tensors in manifest
    /// order; the tuple root is decomposed into one tensor per output.
    pub fn run(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let refs: Vec<&Tensor> = inputs.iter().collect();
        self.run_refs(name, &refs)
    }

    /// Borrowing variant of `run` — hot paths avoid cloning multi-MB
    /// parameter tensors just to build the input list (§Perf).
    pub fn run_refs(&self, name: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        let n_outputs = {
            let spec = self.manifest.artifact(name)?;
            Self::validate_inputs(spec, inputs)?;
            spec.outputs.len()
        };
        self.ensure_compiled(name)?;

        let t0 = Instant::now();
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;

        let outputs = {
            let inner = self.inner.lock().unwrap();
            let exe = inner.executables.get(name).unwrap();
            let result = exe
                .execute::<xla::Literal>(&lits)
                .with_context(|| format!("executing '{name}'"))?;
            let root = result[0][0]
                .to_literal_sync()
                .context("fetching result literal")?;
            let parts = root.to_tuple().context("decomposing result tuple")?;
            parts
                .iter()
                .map(Tensor::from_literal)
                .collect::<Result<Vec<_>>>()?
        };

        if outputs.len() != n_outputs {
            bail!(
                "artifact '{}' returned {} outputs, manifest says {}",
                name,
                outputs.len(),
                n_outputs
            );
        }

        let mut stats = self.stats.lock().unwrap();
        let e = stats.entry(name.to_string()).or_default();
        e.calls += 1;
        e.total += t0.elapsed();
        Ok(outputs)
    }

    /// Snapshot of per-artifact stats.
    pub fn stats(&self) -> HashMap<String, ExecStats> {
        self.stats.lock().unwrap().clone()
    }

    /// Mean wallclock of one call of `name`, if it has been run.
    pub fn mean_call_time(&self, name: &str) -> Option<Duration> {
        let stats = self.stats.lock().unwrap();
        let e = stats.get(name)?;
        if e.calls == 0 {
            return None;
        }
        Some(e.total / e.calls as u32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Engine tests that need built artifacts live in rust/tests/; here we
    // only check the failure paths that need no artifacts.

    #[test]
    fn missing_dir_fails_cleanly() {
        let msg = match Engine::from_dir("/nonexistent/path") {
            Ok(_) => panic!("expected error"),
            Err(e) => format!("{e:#}"),
        };
        assert!(msg.contains("make artifacts"), "{msg}");
    }
}
