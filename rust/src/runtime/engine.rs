//! PJRT execution engine: loads AOT HLO-text artifacts, compiles them once,
//! and executes them with host `Tensor` inputs.
//!
//! This is the only place Python-built compute enters the Rust system.  The
//! pattern follows /opt/xla-example/load_hlo: `HloModuleProto::from_text_file`
//! → `XlaComputation::from_proto` → `PjRtClient::compile` → `execute`.
//! HLO *text* is the interchange format (serialized protos from jax ≥ 0.5 are
//! rejected by xla_extension 0.5.1 — see aot.py).
//!
//! The XLA bridge is feature-gated (`pjrt`): without the vendored `xla`
//! crate the engine still loads manifests and validates artifact I/O
//! contracts, but execution returns an error and engine-backed tests skip
//! via [`Engine::try_load`].
//!
//! Thread-safety: `xla` wrapper types hold raw pointers and are not `Send`;
//! the engine serializes all PJRT access behind one mutex.  XLA-CPU
//! parallelizes *inside* an execution via its intra-op thread pool, so
//! coordinator-level threads lose no meaningful compute parallelism.

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Duration;
#[cfg(feature = "pjrt")]
use std::time::Instant;

#[cfg(feature = "pjrt")]
use anyhow::Context;
use anyhow::{bail, Result};

use crate::runtime::manifest::{artifacts_dir, ArtifactSpec, Manifest};
use crate::runtime::tensor::Tensor;

#[cfg(feature = "pjrt")]
struct Inner {
    client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

#[cfg(not(feature = "pjrt"))]
struct Inner {}

/// Per-artifact execution statistics (feeds the utilization monitor and the
/// §Perf tables in EXPERIMENTS.md).
#[derive(Debug, Clone, Default)]
pub struct ExecStats {
    pub calls: u64,
    pub total: Duration,
    pub compile_time: Duration,
}

pub struct Engine {
    manifest: Manifest,
    #[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
    inner: Mutex<Inner>,
    stats: Mutex<HashMap<String, ExecStats>>,
}

// SAFETY: all access to the raw-pointer-holding xla types is serialized
// behind `inner`; the PJRT CPU plugin itself is thread-safe.
#[cfg(feature = "pjrt")]
unsafe impl Send for Engine {}
#[cfg(feature = "pjrt")]
unsafe impl Sync for Engine {}

impl Engine {
    /// True when this build can actually execute artifacts.
    pub const fn backend_available() -> bool {
        cfg!(feature = "pjrt")
    }

    /// Load an artifact set if (and only if) it exists AND this build has an
    /// execution backend.  Engine-backed tests use this to self-skip — so it
    /// returns `None` only for the two legitimate skip reasons (no backend,
    /// artifacts never built) and PANICS on artifacts that exist but fail to
    /// load: a corrupt manifest must fail the suite loudly, not skip it.
    pub fn try_load(config: &str) -> Option<Engine> {
        if !Self::backend_available() {
            return None;
        }
        let dir = artifacts_dir(config);
        if !dir.join("manifest.json").exists() {
            return None;
        }
        match Self::from_dir(&dir) {
            Ok(e) => Some(e),
            Err(e) => panic!(
                "artifact set '{config}' exists at {dir:?} but failed to \
                 load — fix or rebuild it (`make artifacts`): {e:#}"
            ),
        }
    }

    /// Load the artifact set for a named config (e.g. "tiny", "quickstart").
    pub fn load(config: &str) -> Result<Engine> {
        Self::from_dir(artifacts_dir(config))
    }

    pub fn from_dir(dir: impl AsRef<std::path::Path>) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        Ok(Engine {
            manifest,
            inner: Mutex::new(Self::new_inner()?),
            stats: Mutex::new(HashMap::new()),
        })
    }

    #[cfg(feature = "pjrt")]
    fn new_inner() -> Result<Inner> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Inner { client, executables: HashMap::new() })
    }

    #[cfg(not(feature = "pjrt"))]
    fn new_inner() -> Result<Inner> {
        Ok(Inner {})
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Pre-compile a set of artifacts (elides first-call latency).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.ensure_compiled(n)?;
        }
        Ok(())
    }

    #[cfg(feature = "pjrt")]
    fn ensure_compiled(&self, name: &str) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        if inner.executables.contains_key(name) {
            return Ok(());
        }
        let path = self.manifest.hlo_path(name)?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = inner
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact '{name}'"))?;
        inner.executables.insert(name.to_string(), exe);
        self.stats
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .compile_time = t0.elapsed();
        Ok(())
    }

    #[cfg(not(feature = "pjrt"))]
    fn ensure_compiled(&self, name: &str) -> Result<()> {
        bail!(
            "artifact '{name}' cannot compile: gcore was built without the \
             `pjrt` feature (no XLA backend)"
        )
    }

    fn validate_inputs(spec: &ArtifactSpec, inputs: &[&Tensor]) -> Result<()> {
        if inputs.len() != spec.inputs.len() {
            bail!(
                "artifact '{}' expects {} inputs, got {}",
                spec.name,
                spec.inputs.len(),
                inputs.len()
            );
        }
        for (i, (t, s)) in inputs.iter().zip(&spec.inputs).enumerate() {
            if t.shape != s.shape || t.dtype() != s.dtype {
                bail!(
                    "artifact '{}' input #{i} ('{}'): expected {:?} {}, \
                     got {:?} {}",
                    spec.name,
                    s.name,
                    s.shape,
                    s.dtype.name(),
                    t.shape,
                    t.dtype().name()
                );
            }
        }
        Ok(())
    }

    /// Execute an artifact.  Inputs/outputs are host tensors in manifest
    /// order; the tuple root is decomposed into one tensor per output.
    pub fn run(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let refs: Vec<&Tensor> = inputs.iter().collect();
        self.run_refs(name, &refs)
    }

    /// Borrowing variant of `run` — hot paths avoid cloning multi-MB
    /// parameter tensors just to build the input list (§Perf).
    pub fn run_refs(&self, name: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        let n_outputs = {
            let spec = self.manifest.artifact(name)?;
            Self::validate_inputs(spec, inputs)?;
            spec.outputs.len()
        };
        self.execute(name, inputs, n_outputs)
    }

    #[cfg(feature = "pjrt")]
    fn execute(&self, name: &str, inputs: &[&Tensor], n_outputs: usize) -> Result<Vec<Tensor>> {
        self.ensure_compiled(name)?;

        let t0 = Instant::now();
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;

        let outputs = {
            let inner = self.inner.lock().unwrap();
            let exe = inner.executables.get(name).unwrap();
            let result = exe
                .execute::<xla::Literal>(&lits)
                .with_context(|| format!("executing '{name}'"))?;
            let root = result[0][0]
                .to_literal_sync()
                .context("fetching result literal")?;
            let parts = root.to_tuple().context("decomposing result tuple")?;
            parts
                .iter()
                .map(Tensor::from_literal)
                .collect::<Result<Vec<_>>>()?
        };

        if outputs.len() != n_outputs {
            bail!(
                "artifact '{}' returned {} outputs, manifest says {}",
                name,
                outputs.len(),
                n_outputs
            );
        }

        let mut stats = self.stats.lock().unwrap();
        let e = stats.entry(name.to_string()).or_default();
        e.calls += 1;
        e.total += t0.elapsed();
        Ok(outputs)
    }

    #[cfg(not(feature = "pjrt"))]
    fn execute(&self, name: &str, _inputs: &[&Tensor], _n_outputs: usize) -> Result<Vec<Tensor>> {
        bail!(
            "artifact '{name}' cannot execute: gcore was built without the \
             `pjrt` feature (no XLA backend) — enable it with the vendored \
             xla crate to run artifacts"
        )
    }

    /// Snapshot of per-artifact stats.
    pub fn stats(&self) -> HashMap<String, ExecStats> {
        self.stats.lock().unwrap().clone()
    }

    /// Mean wallclock of one call of `name`, if it has been run.
    pub fn mean_call_time(&self, name: &str) -> Option<Duration> {
        let stats = self.stats.lock().unwrap();
        let e = stats.get(name)?;
        if e.calls == 0 {
            return None;
        }
        Some(e.total / e.calls as u32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Engine tests that need built artifacts live in rust/tests/; here we
    // exercise the manifest contract and the failure paths that need none.

    fn tmpdir(name: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir()
            .join("gcore_engine_tests")
            .join(format!("{name}_{}", std::process::id()));
        std::fs::remove_dir_all(&d).ok();
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    /// A minimal-but-complete manifest with one artifact.
    const MINIMAL_MANIFEST: &str = r#"{
        "config": {"name": "synthetic", "vocab": 16, "d_model": 8,
                   "n_layers": 1, "n_heads": 2, "d_ff": 16, "max_seq": 8,
                   "prompt_len": 4, "batch": 2, "use_pallas": false},
        "param_count": 6,
        "scalar_param_count": 2,
        "policy_tree": [{"path": "w", "shape": [2, 3], "dtype": "f32"}],
        "scalar_tree": [{"path": "b", "shape": [2], "dtype": "f32"}],
        "artifacts": {
            "echo": {
                "file": "echo.hlo.txt",
                "inputs": [{"name": "x", "shape": [2], "dtype": "f32"}],
                "outputs": [{"name": "y", "shape": [2], "dtype": "f32"}],
                "hlo_bytes": 128
            }
        }
    }"#;

    fn synthetic_engine(name: &str) -> Engine {
        let dir = tmpdir(name);
        std::fs::write(dir.join("manifest.json"), MINIMAL_MANIFEST).unwrap();
        Engine::from_dir(&dir).unwrap()
    }

    #[test]
    fn missing_dir_fails_cleanly() {
        let msg = match Engine::from_dir("/nonexistent/path") {
            Ok(_) => panic!("expected error"),
            Err(e) => format!("{e:#}"),
        };
        assert!(msg.contains("make artifacts"), "{msg}");
    }

    #[test]
    fn manifest_roundtrip_through_engine() {
        let e = synthetic_engine("roundtrip");
        let d = &e.manifest().dims;
        assert_eq!(d.name, "synthetic");
        assert_eq!(d.vocab, 16);
        assert_eq!(d.gen_len(), 4);
        assert_eq!(d.d_head(), 4);
        assert_eq!(e.manifest().param_count, 6);
        assert_eq!(e.manifest().policy_bytes(), 24);
        assert_eq!(e.manifest().policy_tree[0].num_elements(), 6);
        let a = e.manifest().artifact("echo").unwrap();
        assert_eq!(a.inputs.len(), 1);
        assert_eq!(a.outputs[0].shape, vec![2]);
        assert!(e
            .manifest()
            .hlo_path("echo")
            .unwrap()
            .ends_with("echo.hlo.txt"));
    }

    #[test]
    fn malformed_manifests_rejected() {
        let cases: Vec<(&str, String)> = vec![
            ("not json", "{".to_string()),
            ("not an object", "[1, 2]".to_string()),
            ("missing config", r#"{"param_count": 1}"#.to_string()),
            ("bad dtype", MINIMAL_MANIFEST.replace("\"f32\"", "\"f64\"")),
            (
                "shape not array",
                MINIMAL_MANIFEST.replace("\"shape\": [2, 3]", "\"shape\": 6"),
            ),
            (
                "missing artifact file",
                MINIMAL_MANIFEST.replace("\"file\": \"echo.hlo.txt\",", ""),
            ),
        ];
        for (label, text) in cases {
            let dir = tmpdir(&format!("bad_{}", label.replace(' ', "_")));
            std::fs::write(dir.join("manifest.json"), text).unwrap();
            assert!(
                Engine::from_dir(&dir).is_err(),
                "manifest with {label} must be rejected"
            );
        }
    }

    #[test]
    fn unknown_artifact_is_actionable() {
        let e = synthetic_engine("unknown");
        let msg = format!("{:#}", e.run("nope", &[]).unwrap_err());
        assert!(msg.contains("'nope'"), "{msg}");
    }

    #[test]
    fn input_arity_validated_before_execution() {
        let e = synthetic_engine("arity");
        let msg = format!("{:#}", e.run("echo", &[]).unwrap_err());
        assert!(msg.contains("expects 1 inputs"), "{msg}");
    }

    #[test]
    fn input_shape_and_dtype_validated_before_execution() {
        let e = synthetic_engine("shape");
        // wrong shape
        let msg = format!(
            "{:#}",
            e.run("echo", &[Tensor::zeros_f32(vec![3])]).unwrap_err()
        );
        assert!(msg.contains("expected [2]"), "{msg}");
        // wrong dtype
        let msg = format!(
            "{:#}",
            e.run("echo", &[Tensor::i32(vec![2], vec![0, 0])]).unwrap_err()
        );
        assert!(msg.contains("f32"), "{msg}");
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_backend_error_is_actionable() {
        let e = synthetic_engine("stub");
        assert!(!Engine::backend_available());
        assert!(Engine::try_load("tiny").is_none());
        let msg = format!(
            "{:#}",
            e.run("echo", &[Tensor::zeros_f32(vec![2])]).unwrap_err()
        );
        assert!(msg.contains("pjrt"), "{msg}");
        assert!(e.warmup(&["echo"]).is_err());
    }

    #[test]
    fn stats_start_empty() {
        let e = synthetic_engine("stats");
        assert!(e.stats().is_empty());
        assert!(e.mean_call_time("echo").is_none());
    }
}
