//! Execution engine: loads AOT HLO-text artifacts and executes them with
//! host `Tensor` inputs through one of two backends:
//!
//! * **`Pjrt`** (feature `pjrt`) — the vendored `xla` crate, following
//!   /opt/xla-example/load_hlo: `HloModuleProto::from_text_file` →
//!   `XlaComputation::from_proto` → `PjRtClient::compile` → `execute`.
//!   HLO *text* is the interchange format (serialized protos from jax ≥
//!   0.5 are rejected by xla_extension 0.5.1 — see aot.py).
//! * **`Interp`** — the pure-Rust HLO interpreter (`runtime::hlo`), always
//!   compiled in.  It executes the checked-in fixture artifact sets under
//!   `rust/tests/fixtures/artifacts/` (emitted and jax-validated by
//!   `python -m compile.fixturegen`), so the engine-backed test tier runs
//!   on stock CI runners with no XLA closure and no Python.
//!
//! Selection: `pjrt` builds default to PJRT, everything else to the
//! interpreter; `GCORE_ENGINE=interp|pjrt|auto` overrides.  With both
//! backends in one build the differential test in tests/hlo_golden.rs
//! asserts they agree on the fixture artifacts.
//!
//! Thread-safety: `xla` wrapper types hold raw pointers and are not
//! `Send`; the engine serializes all PJRT access behind one mutex (XLA-CPU
//! parallelizes *inside* an execution).  The interpreter is pure, so
//! compiled programs are shared as `Arc` snapshots and coordinator threads
//! execute concurrently.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

#[cfg(feature = "pjrt")]
use anyhow::Context;
use anyhow::{bail, Result};

use crate::runtime::hlo::{verify, Program};
use crate::runtime::manifest::{artifacts_dir, ArtifactSpec, Manifest};
use crate::runtime::tensor::Tensor;

/// Which execution backend to build an engine on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// PJRT when the `pjrt` feature is compiled in, interpreter otherwise.
    Auto,
    Pjrt,
    Interp,
}

impl BackendKind {
    /// Parse a `GCORE_ENGINE` value.
    pub fn parse(s: &str) -> Result<BackendKind> {
        Ok(match s {
            "auto" | "" => BackendKind::Auto,
            "pjrt" => BackendKind::Pjrt,
            "interp" => BackendKind::Interp,
            other => bail!(
                "unknown GCORE_ENGINE value '{other}' (auto|pjrt|interp)"
            ),
        })
    }

    /// The backend selected by the environment (`GCORE_ENGINE`), default
    /// [`BackendKind::Auto`].
    pub fn from_env() -> Result<BackendKind> {
        match std::env::var("GCORE_ENGINE") {
            Ok(v) => Self::parse(&v),
            Err(_) => Ok(BackendKind::Auto),
        }
    }
}

enum ExecBackend {
    #[cfg(feature = "pjrt")]
    Pjrt {
        client: xla::PjRtClient,
        executables: HashMap<String, xla::PjRtLoadedExecutable>,
    },
    /// Pure-Rust HLO interpreter: parsed programs, keyed by artifact name.
    Interp {
        programs: HashMap<String, Arc<Program>>,
    },
}

/// Per-artifact execution statistics (feeds the utilization monitor and the
/// §Perf tables in EXPERIMENTS.md).
#[derive(Debug, Clone, Default)]
pub struct ExecStats {
    pub calls: u64,
    pub total: Duration,
    pub compile_time: Duration,
}

/// Dense KV-cache geometry of the `decode_step` artifact (`[L,B,H,S,D]`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvCacheSpec {
    pub layers: usize,
    pub batch: usize,
    pub heads: usize,
    pub max_seq: usize,
    pub d_head: usize,
}

pub struct Engine {
    manifest: Manifest,
    inner: Mutex<ExecBackend>,
    stats: Mutex<HashMap<String, ExecStats>>,
}

// SAFETY: all access to the raw-pointer-holding xla types is serialized
// behind `inner`; the PJRT CPU plugin itself is thread-safe.  The
// interpreter variant holds only owned data and is naturally Send + Sync.
#[cfg(feature = "pjrt")]
unsafe impl Send for Engine {}
#[cfg(feature = "pjrt")]
unsafe impl Sync for Engine {}

impl Engine {
    /// True when this build can execute artifacts.  Always true since the
    /// interpreter backend landed — kept for the historical call sites
    /// that gated on the `pjrt` feature.
    pub const fn backend_available() -> bool {
        true
    }

    /// Load an artifact set if (and only if) it exists.  Engine-backed
    /// tests use this to self-skip — since the interpreter backend landed
    /// the ONLY legitimate skip reason is a missing artifact set (and the
    /// checked-in fixture sets make even that unusual); artifacts that
    /// exist but fail to load PANIC so a corrupt set fails the suite
    /// loudly instead of skipping it.
    pub fn try_load(config: &str) -> Option<Engine> {
        let dir = artifacts_dir(config);
        if !dir.join("manifest.json").exists() {
            return None;
        }
        match Self::from_dir(&dir) {
            Ok(e) => Some(e),
            Err(e) => panic!(
                "artifact set '{config}' exists at {dir:?} but failed to \
                 load — fix or rebuild it (`make artifacts`, or \
                 `python -m compile.fixturegen` for the fixture sets): {e:#}"
            ),
        }
    }

    /// Load the artifact set for a named config (e.g. "tiny", "quickstart").
    pub fn load(config: &str) -> Result<Engine> {
        Self::from_dir(artifacts_dir(config))
    }

    /// Load with the backend chosen by `GCORE_ENGINE` (default: PJRT when
    /// compiled in, interpreter otherwise).
    pub fn from_dir(dir: impl AsRef<std::path::Path>) -> Result<Engine> {
        Self::from_dir_with_backend(dir, BackendKind::from_env()?)
    }

    /// Load with an explicit backend choice (the differential tests build
    /// one engine per backend this way).
    pub fn from_dir_with_backend(
        dir: impl AsRef<std::path::Path>,
        kind: BackendKind,
    ) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let engine = Engine {
            manifest,
            inner: Mutex::new(Self::new_backend(kind)?),
            stats: Mutex::new(HashMap::new()),
        };
        engine.preverify_interp()?;
        Ok(engine)
    }

    /// Interpreter backend: eagerly parse + statically verify every
    /// artifact whose HLO file is present, so a corrupt set fails at load
    /// (`try_load` then panics at startup) instead of mid-rollout on a
    /// coordinator thread.  Artifacts whose HLO file is *missing* are
    /// skipped on purpose: gated sets may omit files by design (the
    /// micro-set tests in rollout_integration.rs do) and the lazy
    /// `ensure_compiled` error for them is the actionable one.
    fn preverify_interp(&self) -> Result<()> {
        if self.backend_name() != "interp" {
            return Ok(());
        }
        let names: Vec<String> = self.manifest.artifacts.keys().cloned().collect();
        for name in names {
            if self.manifest.hlo_path(&name)?.exists() {
                self.ensure_compiled(&name)?;
            }
        }
        Ok(())
    }

    #[cfg(feature = "pjrt")]
    fn new_backend(kind: BackendKind) -> Result<ExecBackend> {
        match kind {
            BackendKind::Interp => Ok(ExecBackend::Interp { programs: HashMap::new() }),
            BackendKind::Auto | BackendKind::Pjrt => {
                let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
                Ok(ExecBackend::Pjrt { client, executables: HashMap::new() })
            }
        }
    }

    #[cfg(not(feature = "pjrt"))]
    fn new_backend(kind: BackendKind) -> Result<ExecBackend> {
        match kind {
            BackendKind::Auto | BackendKind::Interp => {
                Ok(ExecBackend::Interp { programs: HashMap::new() })
            }
            BackendKind::Pjrt => bail!(
                "GCORE_ENGINE=pjrt but gcore was built without the `pjrt` \
                 feature (no XLA backend); unset GCORE_ENGINE (or set it to \
                 'interp'/'auto') to use the built-in HLO interpreter, or \
                 rebuild with the vendored xla crate"
            ),
        }
    }

    /// Name of the active backend ("pjrt" or "interp").
    pub fn backend_name(&self) -> &'static str {
        match &*self.inner.lock().unwrap() {
            #[cfg(feature = "pjrt")]
            ExecBackend::Pjrt { .. } => "pjrt",
            ExecBackend::Interp { .. } => "interp",
        }
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Geometry of the `decode_step` KV-cache operands `[L,B,H,S,D]` — the
    /// contract the paged rollout data plane gathers/scatters against.
    /// Read from the artifact's declared input shapes (not re-derived from
    /// `dims`) so a manifest/HLO drift fails here, loudly.
    pub fn kv_cache_spec(&self) -> Result<KvCacheSpec> {
        let spec = self.manifest.artifact("decode_step")?;
        let np = self.manifest.policy_tree.len();
        let cache = spec.inputs.get(np).ok_or_else(|| {
            anyhow::anyhow!("decode_step has no cache operand after {np} params")
        })?;
        let d = &self.manifest.dims;
        let sh = &cache.shape;
        if sh.len() != 5 || sh[1] != d.batch || sh[3] != d.max_seq {
            bail!(
                "decode_step cache operand '{}' has shape {:?}; expected \
                 [layers, batch={}, heads, max_seq={}, d_head]",
                cache.name,
                sh,
                d.batch,
                d.max_seq
            );
        }
        Ok(KvCacheSpec {
            layers: sh[0],
            batch: sh[1],
            heads: sh[2],
            max_seq: sh[3],
            d_head: sh[4],
        })
    }

    /// Pre-compile a set of artifacts (elides first-call latency).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.ensure_compiled(n)?;
        }
        Ok(())
    }

    /// Compile (PJRT) or parse (interpreter) an artifact once.
    fn ensure_compiled(&self, name: &str) -> Result<()> {
        {
            let inner = self.inner.lock().unwrap();
            let present = match &*inner {
                #[cfg(feature = "pjrt")]
                ExecBackend::Pjrt { executables, .. } => executables.contains_key(name),
                ExecBackend::Interp { programs } => programs.contains_key(name),
            };
            if present {
                return Ok(());
            }
        }
        let path = self.manifest.hlo_path(name)?;
        let t0 = Instant::now();
        let mut inner = self.inner.lock().unwrap();
        // re-check after re-locking: a racing thread may have compiled the
        // artifact while we resolved the path (cold engine, world >= 2)
        let present = match &*inner {
            #[cfg(feature = "pjrt")]
            ExecBackend::Pjrt { executables, .. } => executables.contains_key(name),
            ExecBackend::Interp { programs } => programs.contains_key(name),
        };
        if present {
            return Ok(());
        }
        match &mut *inner {
            #[cfg(feature = "pjrt")]
            ExecBackend::Pjrt { client, executables } => {
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().context("non-utf8 path")?,
                )
                .with_context(|| format!("parsing HLO text {path:?}"))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client
                    .compile(&comp)
                    .with_context(|| format!("compiling artifact '{name}'"))?;
                executables.insert(name.to_string(), exe);
            }
            ExecBackend::Interp { programs } => {
                let text = std::fs::read_to_string(&path).map_err(|e| {
                    anyhow::anyhow!(
                        "reading HLO text {path:?}: {e} — regenerate the \
                         artifact set (`make artifacts`, or \
                         `python -m compile.fixturegen` for fixtures)"
                    )
                })?;
                let program = Program::parse(&text)
                    .map_err(|e| e.context(format!("compiling HLO text {path:?}")))?;
                let io = verify::verify_artifact_io(
                    program.module(),
                    self.manifest.artifact(name)?,
                );
                if !io.is_empty() {
                    let list = io
                        .iter()
                        .map(|d| format!("  {d}"))
                        .collect::<Vec<_>>()
                        .join("\n");
                    bail!(
                        "artifact '{name}' ({path:?}) disagrees with its \
                         manifest I/O contract:\n{list}"
                    );
                }
                programs.insert(name.to_string(), Arc::new(program));
            }
        }
        drop(inner);
        self.stats
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .compile_time = t0.elapsed();
        Ok(())
    }

    fn validate_inputs(spec: &ArtifactSpec, inputs: &[&Tensor]) -> Result<()> {
        if inputs.len() != spec.inputs.len() {
            bail!(
                "artifact '{}' expects {} inputs, got {}",
                spec.name,
                spec.inputs.len(),
                inputs.len()
            );
        }
        for (i, (t, s)) in inputs.iter().zip(&spec.inputs).enumerate() {
            if t.shape != s.shape || t.dtype() != s.dtype {
                bail!(
                    "artifact '{}' input #{i} ('{}'): expected {:?} {}, \
                     got {:?} {}",
                    spec.name,
                    s.name,
                    s.shape,
                    s.dtype.name(),
                    t.shape,
                    t.dtype().name()
                );
            }
        }
        Ok(())
    }

    /// Execute an artifact.  Inputs/outputs are host tensors in manifest
    /// order; the tuple root is decomposed into one tensor per output.
    pub fn run(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let refs: Vec<&Tensor> = inputs.iter().collect();
        self.run_refs(name, &refs)
    }

    /// Borrowing variant of `run` — hot paths avoid cloning multi-MB
    /// parameter tensors just to build the input list (§Perf).
    pub fn run_refs(&self, name: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        let n_outputs = {
            let spec = self.manifest.artifact(name)?;
            Self::validate_inputs(spec, inputs)?;
            spec.outputs.len()
        };
        self.execute(name, inputs, n_outputs)
    }

    fn execute(&self, name: &str, inputs: &[&Tensor], n_outputs: usize) -> Result<Vec<Tensor>> {
        self.ensure_compiled(name)?;
        let t0 = Instant::now();
        let outputs = self.execute_inner(name, inputs)?;
        if outputs.len() != n_outputs {
            bail!(
                "artifact '{}' returned {} outputs, manifest says {}",
                name,
                outputs.len(),
                n_outputs
            );
        }
        let mut stats = self.stats.lock().unwrap();
        let e = stats.entry(name.to_string()).or_default();
        e.calls += 1;
        e.total += t0.elapsed();
        Ok(outputs)
    }

    #[cfg(feature = "pjrt")]
    fn execute_inner(&self, name: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        // Interp: run outside the backend lock (pure, thread-safe).
        let program = {
            let inner = self.inner.lock().unwrap();
            match &*inner {
                ExecBackend::Interp { programs } => Some(programs[name].clone()),
                ExecBackend::Pjrt { .. } => None,
            }
        };
        if let Some(p) = program {
            return Self::run_interp(&p, name, inputs);
        }
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let inner = self.inner.lock().unwrap();
        let ExecBackend::Pjrt { executables, .. } = &*inner else {
            unreachable!("backend changed under us");
        };
        let exe = executables.get(name).unwrap();
        let result = exe
            .execute::<xla::Literal>(&lits)
            .with_context(|| format!("executing '{name}'"))?;
        let root = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let parts = root.to_tuple().context("decomposing result tuple")?;
        parts.iter().map(Tensor::from_literal).collect::<Result<Vec<_>>>()
    }

    #[cfg(not(feature = "pjrt"))]
    fn execute_inner(&self, name: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        let program = {
            let inner = self.inner.lock().unwrap();
            let ExecBackend::Interp { programs } = &*inner;
            programs[name].clone()
        };
        Self::run_interp(&program, name, inputs)
    }

    fn run_interp(program: &Program, name: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        program
            .evaluate_refs(inputs)
            .map_err(|e| e.context(format!("interpreting '{name}'")))
    }

    /// Fused elementwise-chain count of a compiled artifact (interp
    /// backend only; `None` before `ensure_compiled` or on PJRT, where
    /// XLA does its own fusion).
    pub fn fused_chains(&self, name: &str) -> Option<usize> {
        let inner = self.inner.lock().unwrap();
        match &*inner {
            ExecBackend::Interp { programs } => {
                programs.get(name).map(|p| p.fused_chain_count())
            }
            #[cfg(feature = "pjrt")]
            ExecBackend::Pjrt { .. } => None,
        }
    }

    /// Snapshot of per-artifact stats.
    pub fn stats(&self) -> HashMap<String, ExecStats> {
        self.stats.lock().unwrap().clone()
    }

    /// Mean wallclock of one call of `name`, if it has been run.
    pub fn mean_call_time(&self, name: &str) -> Option<Duration> {
        let stats = self.stats.lock().unwrap();
        let e = stats.get(name)?;
        if e.calls == 0 {
            return None;
        }
        Some(e.total / e.calls as u32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Engine tests that need the fixture artifact sets live in
    // rust/tests/; here we exercise the manifest contract, backend
    // selection and the failure paths that need no artifacts.

    fn tmpdir(name: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir()
            .join("gcore_engine_tests")
            .join(format!("{name}_{}", std::process::id()));
        std::fs::remove_dir_all(&d).ok();
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    /// A minimal-but-complete manifest with one artifact.
    const MINIMAL_MANIFEST: &str = r#"{
        "config": {"name": "synthetic", "vocab": 16, "d_model": 8,
                   "n_layers": 1, "n_heads": 2, "d_ff": 16, "max_seq": 8,
                   "prompt_len": 4, "batch": 2, "use_pallas": false},
        "param_count": 6,
        "scalar_param_count": 2,
        "policy_tree": [{"path": "w", "shape": [2, 3], "dtype": "f32"}],
        "scalar_tree": [{"path": "b", "shape": [2], "dtype": "f32"}],
        "artifacts": {
            "echo": {
                "file": "echo.hlo.txt",
                "inputs": [{"name": "x", "shape": [2], "dtype": "f32"}],
                "outputs": [{"name": "y", "shape": [2], "dtype": "f32"}],
                "hlo_bytes": 128
            }
        }
    }"#;

    const ECHO_HLO: &str = "HloModule echo\n\nENTRY %entry (p0: f32[2]) -> (f32[2]) {\n  \
        %v0 = f32[2] parameter(0)\n  %v1 = f32[2] negate(f32[2] %v0)\n  \
        %v2 = f32[2] negate(f32[2] %v1)\n  \
        ROOT %result = (f32[2]) tuple(f32[2] %v2)\n}\n";

    fn synthetic_engine(name: &str) -> Engine {
        let dir = tmpdir(name);
        std::fs::write(dir.join("manifest.json"), MINIMAL_MANIFEST).unwrap();
        Engine::from_dir(&dir).unwrap()
    }

    #[test]
    fn missing_dir_fails_cleanly() {
        let msg = match Engine::from_dir("/nonexistent/path") {
            Ok(_) => panic!("expected error"),
            Err(e) => format!("{e:#}"),
        };
        assert!(msg.contains("make artifacts"), "{msg}");
    }

    #[test]
    fn manifest_roundtrip_through_engine() {
        let e = synthetic_engine("roundtrip");
        let d = &e.manifest().dims;
        assert_eq!(d.name, "synthetic");
        assert_eq!(d.vocab, 16);
        assert_eq!(d.gen_len(), 4);
        assert_eq!(d.d_head(), 4);
        assert_eq!(e.manifest().param_count, 6);
        assert_eq!(e.manifest().policy_bytes(), 24);
        assert_eq!(e.manifest().policy_tree[0].num_elements(), 6);
        let a = e.manifest().artifact("echo").unwrap();
        assert_eq!(a.inputs.len(), 1);
        assert_eq!(a.outputs[0].shape, vec![2]);
        assert!(e
            .manifest()
            .hlo_path("echo")
            .unwrap()
            .ends_with("echo.hlo.txt"));
    }

    #[test]
    fn malformed_manifests_rejected() {
        let cases: Vec<(&str, String)> = vec![
            ("not json", "{".to_string()),
            ("not an object", "[1, 2]".to_string()),
            ("missing config", r#"{"param_count": 1}"#.to_string()),
            ("bad dtype", MINIMAL_MANIFEST.replace("\"f32\"", "\"f64\"")),
            (
                "shape not array",
                MINIMAL_MANIFEST.replace("\"shape\": [2, 3]", "\"shape\": 6"),
            ),
            (
                "missing artifact file",
                MINIMAL_MANIFEST.replace("\"file\": \"echo.hlo.txt\",", ""),
            ),
        ];
        for (label, text) in cases {
            let dir = tmpdir(&format!("bad_{}", label.replace(' ', "_")));
            std::fs::write(dir.join("manifest.json"), text).unwrap();
            assert!(
                Engine::from_dir(&dir).is_err(),
                "manifest with {label} must be rejected"
            );
        }
    }

    #[test]
    fn unknown_artifact_is_actionable() {
        let e = synthetic_engine("unknown");
        let msg = format!("{:#}", e.run("nope", &[]).unwrap_err());
        assert!(msg.contains("'nope'"), "{msg}");
    }

    #[test]
    fn input_arity_validated_before_execution() {
        let e = synthetic_engine("arity");
        let msg = format!("{:#}", e.run("echo", &[]).unwrap_err());
        assert!(msg.contains("expects 1 inputs"), "{msg}");
    }

    #[test]
    fn input_shape_and_dtype_validated_before_execution() {
        let e = synthetic_engine("shape");
        // wrong shape
        let msg = format!(
            "{:#}",
            e.run("echo", &[Tensor::zeros_f32(vec![3])]).unwrap_err()
        );
        assert!(msg.contains("expected [2]"), "{msg}");
        // wrong dtype
        let msg = format!(
            "{:#}",
            e.run("echo", &[Tensor::i32(vec![2], vec![0, 0])]).unwrap_err()
        );
        assert!(msg.contains("f32"), "{msg}");
    }

    #[test]
    fn backend_kind_parses() {
        assert_eq!(BackendKind::parse("auto").unwrap(), BackendKind::Auto);
        assert_eq!(BackendKind::parse("").unwrap(), BackendKind::Auto);
        assert_eq!(BackendKind::parse("pjrt").unwrap(), BackendKind::Pjrt);
        assert_eq!(BackendKind::parse("interp").unwrap(), BackendKind::Interp);
        let msg = BackendKind::parse("tpu").unwrap_err().to_string();
        assert!(msg.contains("GCORE_ENGINE") && msg.contains("tpu"), "{msg}");
    }

    /// The engine is always executable now: default builds select the
    /// interpreter, and asking for PJRT without the feature fails with an
    /// error that names both GCORE_ENGINE and the interpreter fallback.
    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn backend_selection_without_pjrt_feature() {
        assert!(Engine::backend_available());
        let dir = tmpdir("selection");
        std::fs::write(dir.join("manifest.json"), MINIMAL_MANIFEST).unwrap();
        let e = Engine::from_dir_with_backend(&dir, BackendKind::Auto).unwrap();
        assert_eq!(e.backend_name(), "interp");
        let e = Engine::from_dir_with_backend(&dir, BackendKind::Interp).unwrap();
        assert_eq!(e.backend_name(), "interp");
        let msg = format!(
            "{:#}",
            Engine::from_dir_with_backend(&dir, BackendKind::Pjrt).unwrap_err()
        );
        assert!(msg.contains("GCORE_ENGINE"), "{msg}");
        assert!(msg.contains("interp"), "{msg}");
        assert!(msg.contains("pjrt"), "{msg}");
    }

    #[test]
    fn interp_backend_executes_hlo_text() {
        let dir = tmpdir("interp_exec");
        std::fs::write(dir.join("manifest.json"), MINIMAL_MANIFEST).unwrap();
        std::fs::write(dir.join("echo.hlo.txt"), ECHO_HLO).unwrap();
        let e = Engine::from_dir_with_backend(&dir, BackendKind::Interp).unwrap();
        let x = Tensor::f32(vec![2], vec![1.5, -2.0]);
        let out = e.run("echo", &[x.clone()]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0], x);
        // stats recorded a compile and a call
        let st = e.stats();
        assert_eq!(st["echo"].calls, 1);
        assert!(e.mean_call_time("echo").is_some());
        assert!(e.warmup(&["echo"]).is_ok());
    }

    #[test]
    fn interp_missing_hlo_file_is_actionable() {
        let e = synthetic_engine("missing_hlo");
        if e.backend_name() != "interp" {
            return; // pjrt build without GCORE_ENGINE override
        }
        let msg = format!(
            "{:#}",
            e.run("echo", &[Tensor::zeros_f32(vec![2])]).unwrap_err()
        );
        assert!(msg.contains("echo.hlo.txt"), "{msg}");
        assert!(msg.contains("fixturegen"), "{msg}");
    }

    #[test]
    fn stats_start_empty() {
        let e = synthetic_engine("stats");
        assert!(e.stats().is_empty());
        assert!(e.mean_call_time("echo").is_none());
    }

    #[test]
    fn interp_load_verifies_present_hlo() {
        // a shape-corrupt artifact must fail at LOAD time (try_load panics
        // at startup), not at first execution mid-rollout
        let dir = tmpdir("load_verify");
        std::fs::write(dir.join("manifest.json"), MINIMAL_MANIFEST).unwrap();
        std::fs::write(
            dir.join("echo.hlo.txt"),
            ECHO_HLO.replace("%v1 = f32[2]", "%v1 = f32[3]"),
        )
        .unwrap();
        let msg = format!(
            "{:#}",
            Engine::from_dir_with_backend(&dir, BackendKind::Interp).unwrap_err()
        );
        assert!(msg.contains("failed static verification"), "{msg}");
        assert!(msg.contains("%v1"), "{msg}");
    }

    #[test]
    fn interp_load_rejects_manifest_io_drift() {
        // HLO verifies internally but disagrees with the manifest's declared
        // output shape — the by-position tensor feed would silently corrupt
        let dir = tmpdir("io_drift");
        std::fs::write(
            dir.join("manifest.json"),
            MINIMAL_MANIFEST.replace(
                r#""name": "y", "shape": [2]"#,
                r#""name": "y", "shape": [3]"#,
            ),
        )
        .unwrap();
        std::fs::write(dir.join("echo.hlo.txt"), ECHO_HLO).unwrap();
        let msg = format!(
            "{:#}",
            Engine::from_dir_with_backend(&dir, BackendKind::Interp).unwrap_err()
        );
        assert!(msg.contains("I/O contract"), "{msg}");
        assert!(msg.contains("output #0"), "{msg}");
    }
}
