//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime.
//!
//! The manifest pins, for every artifact, the exact flat order / shapes /
//! dtypes of HLO parameters and tuple outputs (jax flattens pytrees in
//! sorted-dict-key order), plus the policy / scalar-model parameter trees so
//! the coordinator can checkpoint, shard and all-reduce flat tensor lists
//! without reconstructing a pytree.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::runtime::tensor::Dtype;
use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl TensorSpec {
    fn from_json(j: &Json, name_key: &str) -> Result<TensorSpec> {
        Ok(TensorSpec {
            name: j.req(name_key)?.as_str().unwrap_or_default().to_string(),
            shape: j
                .req("shape")?
                .as_arr()
                .context("shape not array")?
                .iter()
                .map(|d| d.as_usize().context("bad dim"))
                .collect::<Result<_>>()?,
            dtype: Dtype::parse(j.req("dtype")?.as_str().context("dtype not str")?)?,
        })
    }

    pub fn num_elements(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub hlo_bytes: usize,
}

/// Model dimensions baked into the artifact set (mirror of ModelConfig).
#[derive(Debug, Clone)]
pub struct ModelDims {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub prompt_len: usize,
    pub batch: usize,
    pub use_pallas: bool,
}

impl ModelDims {
    pub fn gen_len(&self) -> usize {
        self.max_seq - self.prompt_len
    }

    pub fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }
}

/// Sampler parameters compiled into the fused `generate_rollout` artifact
/// (aot.py records them so the runtime can refuse a mismatched
/// `SamplerConfig` instead of silently decoding a different distribution).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BakedSampler {
    pub top_k: usize,
    pub stop_at_eos: bool,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub dims: ModelDims,
    pub param_count: usize,
    pub scalar_param_count: usize,
    /// Flat policy parameter tree (manifest order == HLO parameter order).
    pub policy_tree: Vec<TensorSpec>,
    /// Flat scalar-head (critic / BT reward) parameter tree.
    pub scalar_tree: Vec<TensorSpec>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    /// Sampler block for `generate_rollout`; absent in sets predating it
    /// (or sets without the fused artifact).
    pub sampler: Option<BakedSampler>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts`"))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;

        let cfg = j.req("config")?;
        let dims = ModelDims {
            name: cfg.req("name")?.as_str().unwrap_or_default().to_string(),
            vocab: cfg.req("vocab")?.as_usize().context("vocab")?,
            d_model: cfg.req("d_model")?.as_usize().context("d_model")?,
            n_layers: cfg.req("n_layers")?.as_usize().context("n_layers")?,
            n_heads: cfg.req("n_heads")?.as_usize().context("n_heads")?,
            d_ff: cfg.req("d_ff")?.as_usize().context("d_ff")?,
            max_seq: cfg.req("max_seq")?.as_usize().context("max_seq")?,
            prompt_len: cfg.req("prompt_len")?.as_usize().context("prompt_len")?,
            batch: cfg.req("batch")?.as_usize().context("batch")?,
            use_pallas: cfg.req("use_pallas")?.as_bool().unwrap_or(false),
        };

        let tree = |key: &str| -> Result<Vec<TensorSpec>> {
            j.req(key)?
                .as_arr()
                .context("tree not array")?
                .iter()
                .map(|t| TensorSpec::from_json(t, "path"))
                .collect()
        };

        let mut artifacts = BTreeMap::new();
        for (name, a) in j.req("artifacts")?.as_obj().context("artifacts")? {
            let specs = |key: &str| -> Result<Vec<TensorSpec>> {
                a.req(key)?
                    .as_arr()
                    .context("io not array")?
                    .iter()
                    .map(|t| TensorSpec::from_json(t, "name"))
                    .collect()
            };
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    file: a.req("file")?.as_str().context("file")?.to_string(),
                    inputs: specs("inputs")?,
                    outputs: specs("outputs")?,
                    hlo_bytes: a
                        .get("hlo_bytes")
                        .and_then(|v| v.as_usize())
                        .unwrap_or(0),
                },
            );
        }

        let sampler = j
            .get("sampler")
            .map(|s| -> Result<BakedSampler> {
                Ok(BakedSampler {
                    top_k: s.req("top_k")?.as_usize().context("sampler.top_k")?,
                    stop_at_eos: s.req("stop_at_eos")?.as_bool().unwrap_or(true),
                })
            })
            .transpose()?;

        Ok(Manifest {
            dir,
            dims,
            param_count: j.req("param_count")?.as_usize().context("param_count")?,
            scalar_param_count: j
                .req("scalar_param_count")?
                .as_usize()
                .context("scalar_param_count")?,
            policy_tree: tree("policy_tree")?,
            scalar_tree: tree("scalar_tree")?,
            artifacts,
            sampler,
        })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .with_context(|| format!("artifact '{name}' not in manifest"))
    }

    pub fn hlo_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.artifact(name)?.file))
    }

    /// Total bytes of one policy parameter set (f32).
    pub fn policy_bytes(&self) -> usize {
        self.param_count * 4
    }
}

/// Locate the artifacts directory for a config: `$GCORE_ARTIFACTS/<cfg>`,
/// or — walking up from the cwd — `artifacts/<cfg>` (sets built by
/// `make artifacts` / aot.py), falling back to the checked-in fixture sets
/// under `rust/tests/fixtures/artifacts/<cfg>` (emitted and jax-validated
/// by `python -m compile.fixturegen`; what CI and fresh checkouts run the
/// engine tier against).
pub fn artifacts_dir(config: &str) -> PathBuf {
    if let Ok(base) = std::env::var("GCORE_ARTIFACTS") {
        return PathBuf::from(base).join(config);
    }
    // walk up from cwd looking for <ancestor>/artifacts/<config> first
    // (locally-built sets win), then the committed fixture set
    let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        for rel in ["artifacts", "rust/tests/fixtures/artifacts"] {
            let cand = dir.join(rel).join(config);
            if cand.join("manifest.json").exists() {
                return cand;
            }
        }
        if !dir.pop() {
            break;
        }
    }
    PathBuf::from("artifacts").join(config)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The committed fixture set makes "artifacts not built" a repo
    /// defect, not a skip reason: resolution must always succeed.
    fn tiny() -> Manifest {
        let dir = artifacts_dir("tiny");
        Manifest::load(&dir).unwrap_or_else(|e| {
            panic!(
                "tiny artifact set missing at {dir:?} — the fixture set \
                 should be checked in under rust/tests/fixtures/artifacts \
                 (regenerate with `python -m compile.fixturegen`): {e:#}"
            )
        })
    }

    #[test]
    fn loads_tiny_manifest() {
        let m = tiny();
        assert_eq!(m.dims.name, "tiny");
        assert_eq!(m.dims.vocab, 256);
        assert_eq!(m.policy_tree.len(), 17);
        assert_eq!(m.scalar_tree.len(), 17);
        assert!(m.artifacts.contains_key("train_step"));
        assert!(m.artifacts.contains_key("decode_step"));
    }

    #[test]
    fn param_tree_elements_match_count() {
        let m = tiny();
        let total: usize = m.policy_tree.iter().map(|t| t.num_elements()).sum();
        assert_eq!(total, m.param_count);
        let stotal: usize = m.scalar_tree.iter().map(|t| t.num_elements()).sum();
        assert_eq!(stotal, m.scalar_param_count);
    }

    #[test]
    fn artifact_io_arity_contract() {
        let m = tiny();
        let np = m.policy_tree.len();
        // policy_grad: params + 8 data args in; grads + 4 scalars out
        let pg = m.artifact("policy_grad").unwrap();
        assert_eq!(pg.inputs.len(), np + 8);
        assert_eq!(pg.outputs.len(), np + 4);
        // train_step: 3 trees + 10 data in; 3 trees + 4 scalars out
        let ts = m.artifact("train_step").unwrap();
        assert_eq!(ts.inputs.len(), 3 * np + 10);
        assert_eq!(ts.outputs.len(), 3 * np + 4);
        // decode_step roundtrip shapes
        let ds = m.artifact("decode_step").unwrap();
        assert_eq!(ds.inputs[np].shape, ds.outputs[1].shape);
    }

    #[test]
    fn missing_artifact_errors() {
        assert!(tiny().artifact("nonexistent").is_err());
    }
}
