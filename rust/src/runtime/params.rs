//! Parameter sets and optimizer state: flat tensor lists in manifest order.
//!
//! A `ParamSet` is the Rust-side representation of one model's weights —
//! policy, reference, critic, or reward model.  The flat ordering is pinned
//! by the manifest (`policy_tree` / `scalar_tree`), so gradient all-reduce,
//! checkpointing and weight broadcast are order-stable across processes.

use anyhow::{bail, Result};

use crate::runtime::engine::Engine;
use crate::runtime::manifest::TensorSpec;
use crate::runtime::tensor::Tensor;

#[derive(Debug, Clone, PartialEq)]
pub struct ParamSet {
    pub tensors: Vec<Tensor>,
}

impl ParamSet {
    pub fn new(tensors: Vec<Tensor>) -> ParamSet {
        ParamSet { tensors }
    }

    /// Zero tensors shaped after a manifest tree (Adam m/v init).
    pub fn zeros(tree: &[TensorSpec]) -> ParamSet {
        ParamSet {
            tensors: tree
                .iter()
                .map(|s| Tensor::zeros_f32(s.shape.clone()))
                .collect(),
        }
    }

    pub fn num_elements(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }

    pub fn size_bytes(&self) -> usize {
        self.tensors.iter().map(|t| t.size_bytes()).sum()
    }

    /// Elementwise average of several same-shaped sets (gradient reduce).
    pub fn average(sets: &[&ParamSet]) -> Result<ParamSet> {
        if sets.is_empty() {
            bail!("average of zero param sets");
        }
        let mut acc = sets[0].clone();
        for s in &sets[1..] {
            if s.tensors.len() != acc.tensors.len() {
                bail!("param set arity mismatch");
            }
            for (a, b) in acc.tensors.iter_mut().zip(&s.tensors) {
                a.add_assign(b)?;
            }
        }
        let scale = 1.0 / sets.len() as f32;
        for t in &mut acc.tensors {
            t.scale(scale)?;
        }
        Ok(acc)
    }

    /// Global L2 norm across all tensors (telemetry).
    pub fn l2_norm(&self) -> Result<f64> {
        let mut sq = 0.0;
        for t in &self.tensors {
            let n = t.l2_norm()?;
            sq += n * n;
        }
        Ok(sq.sqrt())
    }
}

/// Initialise a policy-model parameter set via the `init_policy` artifact.
pub fn init_policy(engine: &Engine, seed: u32) -> Result<ParamSet> {
    Ok(ParamSet::new(
        engine.run("init_policy", &[Tensor::scalar_u32(seed)])?,
    ))
}

/// Initialise a scalar-head (critic / BT reward) parameter set.
pub fn init_scalar(engine: &Engine, seed: u32) -> Result<ParamSet> {
    Ok(ParamSet::new(
        engine.run("init_scalar", &[Tensor::scalar_u32(seed)])?,
    ))
}

/// Optimiser-carrying training state for one model replica.
#[derive(Debug, Clone)]
pub struct TrainState {
    pub params: ParamSet,
    pub m: ParamSet,
    pub v: ParamSet,
    pub step: u64,
}

impl TrainState {
    pub fn new(params: ParamSet, tree: &[TensorSpec]) -> TrainState {
        TrainState {
            params,
            m: ParamSet::zeros(tree),
            v: ParamSet::zeros(tree),
            step: 0,
        }
    }

    /// Apply pre-reduced gradients via the `adam_*` artifact.
    /// `artifact` is "adam_policy" or "adam_scalar".
    pub fn apply_grads(
        &mut self,
        engine: &Engine,
        artifact: &str,
        grads: &ParamSet,
        lr: f32,
    ) -> Result<()> {
        self.step += 1;
        let n = self.params.tensors.len();
        let step_t = Tensor::scalar_f32(self.step as f32);
        let lr_t = Tensor::scalar_f32(lr);
        let mut inputs: Vec<&Tensor> = Vec::with_capacity(4 * n + 2);
        inputs.extend(self.params.tensors.iter());
        inputs.extend(self.m.tensors.iter());
        inputs.extend(self.v.tensors.iter());
        inputs.extend(grads.tensors.iter());
        inputs.push(&step_t);
        inputs.push(&lr_t);
        let mut out = engine.run_refs(artifact, &inputs)?;
        if out.len() != 3 * n {
            bail!("{artifact} returned {} tensors, expected {}", out.len(), 3 * n);
        }
        let v = out.split_off(2 * n);
        let m = out.split_off(n);
        self.params = ParamSet::new(out);
        self.m = ParamSet::new(m);
        self.v = ParamSet::new(v);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(shape: Vec<usize>) -> TensorSpec {
        TensorSpec { name: "t".into(), shape, dtype: crate::runtime::tensor::Dtype::F32 }
    }

    #[test]
    fn zeros_matches_tree() {
        let tree = vec![spec(vec![2, 3]), spec(vec![4])];
        let p = ParamSet::zeros(&tree);
        assert_eq!(p.num_elements(), 10);
        assert_eq!(p.size_bytes(), 40);
    }

    #[test]
    fn average_of_sets() {
        let a = ParamSet::new(vec![Tensor::f32(vec![2], vec![1.0, 3.0])]);
        let b = ParamSet::new(vec![Tensor::f32(vec![2], vec![3.0, 5.0])]);
        let avg = ParamSet::average(&[&a, &b]).unwrap();
        assert_eq!(avg.tensors[0].as_f32().unwrap(), &[2.0, 4.0]);
    }

    #[test]
    fn average_empty_fails() {
        assert!(ParamSet::average(&[]).is_err());
    }

    #[test]
    fn l2_norm() {
        let p = ParamSet::new(vec![
            Tensor::f32(vec![2], vec![3.0, 0.0]),
            Tensor::f32(vec![1], vec![4.0]),
        ]);
        assert!((p.l2_norm().unwrap() - 5.0).abs() < 1e-9);
    }
}
