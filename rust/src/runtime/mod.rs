//! Runtime layer: PJRT client wrapper executing the AOT artifacts built by
//! `python/compile/aot.py`.  See DESIGN.md §3 (Layer 3 → runtime).

pub mod engine;
pub mod hlo;
pub mod manifest;
pub mod params;
pub mod tensor;

pub use engine::{BackendKind, Engine};
pub use manifest::{artifacts_dir, Manifest, ModelDims, TensorSpec};
pub use params::{init_policy, init_scalar, ParamSet, TrainState};
pub use tensor::{Dtype, Tensor, TensorData};
