//! Host tensors: the owned, `Send` value type the coordinator passes around.
//!
//! `xla::Literal` wraps raw C pointers (not `Send`), so the L3 data plane —
//! RPC payloads, checkpoints, gradient all-reduce — moves `Tensor`s and only
//! converts to/from `Literal` at the PJRT boundary inside `Engine`.

#[cfg(feature = "pjrt")]
use anyhow::Context;
use anyhow::{bail, Result};

use crate::util::pod;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
    U32,
}

impl Dtype {
    pub fn parse(s: &str) -> Result<Dtype> {
        Ok(match s {
            "f32" => Dtype::F32,
            "i32" => Dtype::I32,
            "u32" => Dtype::U32,
            other => bail!("unsupported dtype '{other}' (artifacts are f32/i32/u32)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Dtype::F32 => "f32",
            Dtype::I32 => "i32",
            Dtype::U32 => "u32",
        }
    }

    pub fn size_bytes(&self) -> usize {
        4
    }

    #[cfg(feature = "pjrt")]
    fn element_type(&self) -> xla::ElementType {
        match self {
            Dtype::F32 => xla::ElementType::F32,
            Dtype::I32 => xla::ElementType::S32,
            Dtype::U32 => xla::ElementType::U32,
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U32(Vec<u32>),
}

/// A host-resident n-d array (row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

impl Tensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data: TensorData::F32(data) }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data: TensorData::I32(data) }
    }

    pub fn u32(shape: Vec<usize>, data: Vec<u32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data: TensorData::U32(data) }
    }

    pub fn scalar_f32(v: f32) -> Tensor {
        Tensor::f32(vec![], vec![v])
    }

    pub fn scalar_i32(v: i32) -> Tensor {
        Tensor::i32(vec![], vec![v])
    }

    pub fn scalar_u32(v: u32) -> Tensor {
        Tensor::u32(vec![], vec![v])
    }

    pub fn zeros_f32(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor::f32(shape, vec![0.0; n])
    }

    pub fn dtype(&self) -> Dtype {
        match &self.data {
            TensorData::F32(_) => Dtype::F32,
            TensorData::I32(_) => Dtype::I32,
            TensorData::U32(_) => Dtype::U32,
        }
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn size_bytes(&self) -> usize {
        self.len() * 4
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            _ => bail!("tensor is {:?}, expected f32", self.dtype()),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match &mut self.data {
            TensorData::F32(v) => Ok(v),
            other => bail!("tensor is not f32: {:?}", matches!(other, TensorData::F32(_))),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            _ => bail!("tensor is {:?}, expected i32", self.dtype()),
        }
    }

    pub fn scalar_value_f32(&self) -> Result<f32> {
        let v = self.as_f32()?;
        if v.len() != 1 {
            bail!("expected scalar, got shape {:?}", self.shape);
        }
        Ok(v[0])
    }

    /// The element storage viewed as raw bytes (native order — equal to the
    /// little-endian wire order on every supported target).  Zero-copy: the
    /// codec and the gradient collective serialize straight from this view.
    pub fn raw_bytes(&self) -> &[u8] {
        match &self.data {
            TensorData::F32(v) => pod::f32_as_bytes(v),
            TensorData::I32(v) => pod::i32_as_bytes(v),
            TensorData::U32(v) => pod::u32_as_bytes(v),
        }
    }

    /// Convert to an XLA literal (PJRT boundary; engine-internal).
    #[cfg(feature = "pjrt")]
    pub fn to_literal(&self) -> Result<xla::Literal> {
        xla::Literal::create_from_shape_and_untyped_data(
            self.dtype().element_type(),
            &self.shape,
            self.raw_bytes(),
        )
        .context("literal creation failed")
    }

    /// Convert back from an XLA literal.
    #[cfg(feature = "pjrt")]
    pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape().context("literal has no array shape")?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = match shape.ty() {
            xla::ElementType::F32 => TensorData::F32(lit.to_vec::<f32>()?),
            xla::ElementType::S32 => TensorData::I32(lit.to_vec::<i32>()?),
            xla::ElementType::U32 => TensorData::U32(lit.to_vec::<u32>()?),
            other => bail!("unsupported literal element type {other:?}"),
        };
        Ok(Tensor { shape: dims, data })
    }

    // ---- element-wise ops used by the gradient collective -----------------

    /// self += other (f32, shapes must match).  Iterates both storages
    /// directly — no copy of the right-hand side.
    pub fn add_assign(&mut self, other: &Tensor) -> Result<()> {
        if self.shape != other.shape {
            bail!("shape mismatch {:?} vs {:?}", self.shape, other.shape);
        }
        let b = other.as_f32()?;
        let a = self.as_f32_mut()?;
        for (x, &y) in a.iter_mut().zip(b) {
            *x += y;
        }
        Ok(())
    }

    /// Overwrite this f32 tensor's elements from little-endian wire bytes
    /// without allocating (one memcpy on aligned LE buffers) — the
    /// zero-copy half of `decode_param_flat_into`.
    pub fn copy_from_le_f32_bytes(&mut self, bytes: &[u8]) -> Result<()> {
        let dst = self.as_f32_mut()?;
        if bytes.len() != dst.len() * 4 {
            bail!(
                "flat payload is {} bytes, tensor needs {}",
                bytes.len(),
                dst.len() * 4
            );
        }
        pod::copy_le_f32(bytes, dst);
        Ok(())
    }

    /// self *= s (f32).
    pub fn scale(&mut self, s: f32) -> Result<()> {
        for x in self.as_f32_mut()? {
            *x *= s;
        }
        Ok(())
    }

    /// L2 norm (f32) — used by grad-norm telemetry.
    pub fn l2_norm(&self) -> Result<f64> {
        Ok(self
            .as_f32()?
            .iter()
            .map(|&x| (x as f64) * (x as f64))
            .sum::<f64>()
            .sqrt())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[cfg(feature = "pjrt")]
    #[test]
    fn literal_roundtrip_f32() {
        let t = Tensor::f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn literal_roundtrip_i32_scalar() {
        let t = Tensor::scalar_i32(-7);
        let back = Tensor::from_literal(&t.to_literal().unwrap()).unwrap();
        assert_eq!(back.as_i32().unwrap(), &[-7]);
        assert!(back.shape.is_empty());
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn literal_roundtrip_u32() {
        let t = Tensor::u32(vec![4], vec![0, 1, u32::MAX, 42]);
        let back = Tensor::from_literal(&t.to_literal().unwrap()).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn add_and_scale() {
        let mut a = Tensor::f32(vec![3], vec![1., 2., 3.]);
        let b = Tensor::f32(vec![3], vec![10., 20., 30.]);
        a.add_assign(&b).unwrap();
        a.scale(0.5).unwrap();
        assert_eq!(a.as_f32().unwrap(), &[5.5, 11.0, 16.5]);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let mut a = Tensor::zeros_f32(vec![2]);
        let b = Tensor::zeros_f32(vec![3]);
        assert!(a.add_assign(&b).is_err());
    }

    #[test]
    fn raw_bytes_match_le_wire_order() {
        let t = Tensor::f32(vec![2], vec![1.5, -2.0]);
        let expect: Vec<u8> = [1.5f32, -2.0].iter().flat_map(|x| x.to_le_bytes()).collect();
        assert_eq!(t.raw_bytes(), &expect[..]);
        let ti = Tensor::i32(vec![1], vec![-1]);
        assert_eq!(ti.raw_bytes(), &[0xFF, 0xFF, 0xFF, 0xFF]);
    }

    #[test]
    fn copy_from_le_bytes_fills_in_place() {
        let mut t = Tensor::zeros_f32(vec![3]);
        let src: Vec<u8> = [7.0f32, -0.5, 1e-30]
            .iter()
            .flat_map(|x| x.to_le_bytes())
            .collect();
        t.copy_from_le_f32_bytes(&src).unwrap();
        assert_eq!(t.as_f32().unwrap(), &[7.0, -0.5, 1e-30]);
        // wrong length rejected
        assert!(t.copy_from_le_f32_bytes(&src[..8]).is_err());
        // non-f32 rejected
        let mut ti = Tensor::i32(vec![1], vec![0]);
        assert!(ti.copy_from_le_f32_bytes(&[0; 4]).is_err());
    }

    #[test]
    fn dtype_parse() {
        assert_eq!(Dtype::parse("f32").unwrap(), Dtype::F32);
        assert!(Dtype::parse("f64").is_err());
        assert_eq!(Dtype::parse("i32").unwrap().name(), "i32");
    }
}
