//! Static HLO verifier: full shape/dtype inference and def-use validation
//! over parsed [`HloModule`]s, run *before* anything is evaluated.
//!
//! The interpreter used to discover malformed programs at eval time — a
//! shape mismatch deep inside `train_step` surfaced as whatever `bail!`
//! fired first, mid-decode, with no instruction context.  This pass
//! re-derives every instruction's output shape from its operands and
//! attributes and checks it against the declared shape, so a corrupt or
//! drifted artifact fails at *load* with the instruction name, opcode and
//! both shapes.  The remaining op-set gaps (`conditional`, `custom-call`)
//! become structured [`Diagnostic`]s instead of runtime errors.
//!
//! Entry points:
//!
//! * [`verify_module`] — all diagnostics for a parsed module.
//! * [`verify_text`] — parse + verify; parse failures become diagnostics.
//! * [`verify_artifact_io`] — cross-check a module's entry signature
//!   against the manifest's declared input/output specs.
//! * [`infer_shape`] — per-instruction inference, public so the property
//!   tests can assert inferred == declared over every fixture instruction.
//! * [`lint_set`] — verify + [`plan`](super::plan) every artifact in a
//!   manifest directory (the `gcore hlo-lint` backend).

use std::fmt;
use std::path::Path;

use anyhow::{anyhow, bail, Result};

use crate::runtime::hlo::parser::{Computation, HDtype, HShape, HloModule, Instr, Literal};
use crate::runtime::hlo::plan::StaticPlan;
use crate::runtime::manifest::{ArtifactSpec, Manifest};
use crate::runtime::tensor::Dtype;

/// Opcodes the interpreter is known not to support yet (tracked in
/// ROADMAP.md).  The verifier reports these as [`DiagKind::UnsupportedOp`]
/// with a `documented op-set gap` note, which is what the machine-readable
/// gap report in `gcore hlo-lint` is built from.
pub const DOCUMENTED_GAPS: &[&str] = &["conditional", "custom-call"];

/// Diagnostic category (stable, machine-readable).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DiagKind {
    /// HLO text did not parse at all.
    ParseError,
    /// Declared output shape disagrees with the inferred shape.
    ShapeMismatch,
    /// Operand/output dtypes are inconsistent or illegal for the op.
    DtypeMismatch,
    /// Attribute missing, malformed, or out of range.
    BadAttribute,
    /// Reduce body computation fails the arity/dtype/fold contract.
    BadReduce,
    /// Opcode outside the interpreter's op set.
    UnsupportedOp,
    /// Def-use defect: dead value, misplaced tuple, bad parameter
    /// numbering, unreferenced computation.
    DefUse,
    /// Module entry signature disagrees with the manifest spec.
    IoContract,
}

impl DiagKind {
    pub fn name(&self) -> &'static str {
        match self {
            DiagKind::ParseError => "parse-error",
            DiagKind::ShapeMismatch => "shape-mismatch",
            DiagKind::DtypeMismatch => "dtype-mismatch",
            DiagKind::BadAttribute => "bad-attribute",
            DiagKind::BadReduce => "bad-reduce",
            DiagKind::UnsupportedOp => "unsupported-op",
            DiagKind::DefUse => "def-use",
            DiagKind::IoContract => "io-contract",
        }
    }
}

/// One verifier finding, anchored to an instruction when there is one.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    pub kind: DiagKind,
    /// Computation name ("" for module-level findings).
    pub computation: String,
    /// Instruction name without the leading `%` ("" for computation-level).
    pub instr: String,
    /// Opcode of the offending instruction ("" when not applicable).
    pub opcode: String,
    pub message: String,
}

impl Diagnostic {
    fn module(kind: DiagKind, message: String) -> Diagnostic {
        Diagnostic {
            kind,
            computation: String::new(),
            instr: String::new(),
            opcode: String::new(),
            message,
        }
    }

    fn instr(kind: DiagKind, comp: &str, ins: &Instr, message: String) -> Diagnostic {
        Diagnostic {
            kind,
            computation: comp.to_string(),
            instr: ins.name.clone(),
            opcode: ins.opcode.clone(),
            message,
        }
    }
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[{}]", self.kind.name())?;
        if !self.computation.is_empty() {
            write!(f, " %{}", self.computation)?;
        }
        if !self.instr.is_empty() {
            write!(f, " %{} ({})", self.instr, self.opcode)?;
        }
        write!(f, ": {}", self.message)
    }
}

fn scalar(dtype: HDtype) -> HShape {
    HShape { dtype, dims: Vec::new() }
}

fn shaped(dtype: HDtype, dims: Vec<usize>) -> HShape {
    HShape { dtype, dims }
}

/// Element size in bytes of the evaluator's host representation
/// (`Vec<f32>`/`Vec<i32>`/`Vec<u32>`/`Vec<bool>`).
pub fn dtype_bytes(d: HDtype) -> usize {
    match d {
        HDtype::Pred => 1,
        _ => 4,
    }
}

// ---------------------------------------------------------------------------
// Per-instruction shape/dtype inference
// ---------------------------------------------------------------------------

/// Binary opcodes and the dtypes the evaluator implements them for
/// (mirrors `eval::binary` exactly — the verifier must not admit programs
/// the evaluator rejects).
fn binary_dtype_ok(opcode: &str, d: HDtype) -> bool {
    match opcode {
        "add" | "subtract" | "multiply" | "maximum" | "minimum" => {
            matches!(d, HDtype::F32 | HDtype::S32 | HDtype::U32)
        }
        "divide" | "power" => d == HDtype::F32,
        "and" | "or" | "xor" => matches!(d, HDtype::U32 | HDtype::Pred),
        "shift-left" | "shift-right-logical" => d == HDtype::U32,
        _ => false,
    }
}

fn unary_dtype_ok(opcode: &str, d: HDtype) -> bool {
    match opcode {
        "not" => matches!(d, HDtype::Pred | HDtype::U32),
        "negate" | "abs" => matches!(d, HDtype::F32 | HDtype::S32),
        "exponential" | "log" | "tanh" | "rsqrt" | "sqrt" | "sine" | "cosine" => d == HDtype::F32,
        _ => false,
    }
}

fn convert_ok(from: HDtype, to: HDtype) -> bool {
    use HDtype::*;
    matches!(
        (from, to),
        (F32, F32)
            | (S32, S32)
            | (U32, U32)
            | (Pred, Pred)
            | (Pred, F32)
            | (Pred, S32)
            | (Pred, U32)
            | (S32, F32)
            | (U32, F32)
            | (S32, U32)
            | (U32, S32)
            | (F32, S32)
            | (F32, U32)
    )
}

const BINARY_OPS: &[&str] = &[
    "add",
    "subtract",
    "multiply",
    "divide",
    "maximum",
    "minimum",
    "power",
    "and",
    "or",
    "xor",
    "shift-left",
    "shift-right-logical",
];

const UNARY_OPS: &[&str] = &[
    "negate",
    "abs",
    "exponential",
    "log",
    "tanh",
    "rsqrt",
    "sqrt",
    "sine",
    "cosine",
    "not",
];

/// Infer the output shape of instruction `idx` of computation `c` from its
/// operands' *declared* shapes and its attributes.  `Ok(None)` means a
/// tuple-shaped value (only the root tuple).  Errors carry the full
/// mismatch context (operand shapes, attribute values) but not the
/// instruction identity — [`verify_module`] adds that.
pub fn infer_shape(m: &HloModule, c: &Computation, idx: usize) -> Result<Option<HShape>> {
    let ins = &c.instrs[idx];
    // operand's declared shape (tuple-shaped operands are rejected — only
    // the root is a tuple and nothing may consume it)
    let osh = |k: usize| -> Result<&HShape> {
        let op = *ins
            .operands
            .get(k)
            .ok_or_else(|| anyhow!("missing operand #{k}"))?;
        c.instrs[op]
            .shape
            .as_ref()
            .ok_or_else(|| anyhow!("operand #{k} (%{}) is tuple-shaped", c.instrs[op].name))
    };
    let arity = |n: usize| -> Result<()> {
        if ins.operands.len() != n {
            bail!("expected {n} operands, got {}", ins.operands.len());
        }
        Ok(())
    };
    let declared = ins.shape.as_ref();

    let opcode = ins.opcode.as_str();
    if opcode == "tuple" {
        return Ok(None);
    }
    if opcode == "while" {
        // flattened loop-carried state: N operands; condition/body each
        // take N matching parameters (no tuple-shaped parameters), the
        // body root is a tuple of N values with the same shapes, and the
        // condition root is a scalar pred.  The result is tuple-shaped;
        // element k has loop-state shape k (consumed via get-tuple-element).
        if ins.operands.is_empty() {
            bail!("while with no loop-carried state");
        }
        let cond_name = ins
            .condition
            .as_deref()
            .ok_or_else(|| anyhow!("while without condition="))?;
        let body_name = ins.body.as_deref().ok_or_else(|| anyhow!("while without body="))?;
        let cond = m.computation(cond_name)?;
        let body = m.computation(body_name)?;
        let n = ins.operands.len();
        for (what, comp) in [("condition", cond), ("body", body)] {
            if comp.params.len() != n {
                bail!(
                    "while {what} '%{}' has {} parameters but the loop carries {n} values",
                    comp.name,
                    comp.params.len()
                );
            }
            // also rules out condition/body reference cycles, so the
            // planner and evaluator can recurse into sub-computations
            if comp.instrs.iter().any(|i| i.opcode == "while") {
                bail!("nested while (inside {what} '%{}') is unsupported", comp.name);
            }
        }
        for k in 0..n {
            let s = osh(k)?;
            for (what, comp) in [("condition", cond), ("body", body)] {
                let p = comp.params[k];
                let psh = comp.instrs[p].shape.as_ref().ok_or_else(|| {
                    anyhow!("while {what} parameter #{k} is tuple-shaped")
                })?;
                if psh != s {
                    bail!(
                        "loop state #{k} is {} but {what} '%{}' parameter %{} is {}",
                        s.to_text(),
                        comp.name,
                        comp.instrs[p].name,
                        psh.to_text()
                    );
                }
            }
        }
        match cond.instrs[cond.root].shape.as_ref() {
            Some(sh) if sh.dims.is_empty() && sh.dtype == HDtype::Pred => {}
            Some(sh) => bail!(
                "while condition '%{cond_name}' root must be pred[], got {}",
                sh.to_text()
            ),
            None => bail!("while condition '%{cond_name}' root is tuple-shaped"),
        }
        let broot = &body.instrs[body.root];
        if broot.opcode != "tuple" {
            bail!(
                "while body '%{body_name}' root must be a tuple, got '{}'",
                broot.opcode
            );
        }
        if broot.operands.len() != n {
            bail!(
                "while body '%{body_name}' root tuple has {} elements but the loop carries {n} values",
                broot.operands.len()
            );
        }
        for (k, &op) in broot.operands.iter().enumerate() {
            let s = osh(k)?;
            match body.instrs[op].shape.as_ref() {
                Some(sh) if sh == s => {}
                Some(sh) => bail!(
                    "while body '%{body_name}' root element #{k} is {} but loop state #{k} is {}",
                    sh.to_text(),
                    s.to_text()
                ),
                None => bail!("while body '%{body_name}' root element #{k} is tuple-shaped"),
            }
        }
        return Ok(None);
    }
    if BINARY_OPS.contains(&opcode) {
        arity(2)?;
        let (a, b) = (osh(0)?, osh(1)?);
        if a.dims != b.dims {
            bail!("operand shapes differ: {} vs {}", a.to_text(), b.to_text());
        }
        if a.dtype != b.dtype {
            bail!(
                "operand dtypes differ: {} vs {}",
                a.dtype.name(),
                b.dtype.name()
            );
        }
        if !binary_dtype_ok(opcode, a.dtype) {
            bail!("'{opcode}' not supported on {}", a.dtype.name());
        }
        return Ok(Some(a.clone()));
    }
    if UNARY_OPS.contains(&opcode) {
        arity(1)?;
        let a = osh(0)?;
        if !unary_dtype_ok(opcode, a.dtype) {
            bail!("'{opcode}' not supported on {}", a.dtype.name());
        }
        return Ok(Some(a.clone()));
    }
    Ok(Some(match opcode {
        "parameter" => {
            if ins.param_idx.is_none() {
                bail!("parameter without a parameter number");
            }
            declared
                .ok_or_else(|| anyhow!("tuple-shaped parameters unsupported"))?
                .clone()
        }
        "constant" => {
            let sh = declared.ok_or_else(|| anyhow!("tuple-shaped constants unsupported"))?;
            let lit_len = match ins.literal.as_ref() {
                Some(Literal::F32(v)) => v.len(),
                Some(Literal::S32(v)) => v.len(),
                Some(Literal::U32(v)) => v.len(),
                Some(Literal::Pred(v)) => v.len(),
                None => bail!("constant without a literal"),
            };
            if lit_len != sh.num_elements() {
                bail!(
                    "literal has {lit_len} elements, declared shape {} needs {}",
                    sh.to_text(),
                    sh.num_elements()
                );
            }
            sh.clone()
        }
        "compare" => {
            arity(2)?;
            let (a, b) = (osh(0)?, osh(1)?);
            if a.dims != b.dims {
                bail!("operand shapes differ: {} vs {}", a.to_text(), b.to_text());
            }
            if a.dtype != b.dtype || a.dtype == HDtype::Pred {
                bail!(
                    "compare needs matching f32/s32/u32 operands, got {} vs {}",
                    a.dtype.name(),
                    b.dtype.name()
                );
            }
            if ins.direction.is_none() {
                bail!("compare without direction=");
            }
            shaped(HDtype::Pred, a.dims.clone())
        }
        "select" => {
            arity(3)?;
            let (p, a, b) = (osh(0)?, osh(1)?, osh(2)?);
            if p.dtype != HDtype::Pred {
                bail!("select predicate must be pred, got {}", p.dtype.name());
            }
            if p.dims != a.dims || a.dims != b.dims {
                bail!(
                    "select shapes differ: pred {}, on-true {}, on-false {}",
                    p.to_text(),
                    a.to_text(),
                    b.to_text()
                );
            }
            if a.dtype != b.dtype {
                bail!(
                    "select branch dtypes differ: {} vs {}",
                    a.dtype.name(),
                    b.dtype.name()
                );
            }
            a.clone()
        }
        "convert" => {
            arity(1)?;
            let a = osh(0)?;
            let out = declared.ok_or_else(|| anyhow!("convert without declared shape"))?;
            if !convert_ok(a.dtype, out.dtype) {
                bail!(
                    "unsupported convert {} -> {}",
                    a.dtype.name(),
                    out.dtype.name()
                );
            }
            shaped(out.dtype, a.dims.clone())
        }
        "broadcast" => {
            arity(1)?;
            let a = osh(0)?;
            let out = declared.ok_or_else(|| anyhow!("broadcast without declared shape"))?;
            if ins.dims.len() != a.dims.len() {
                bail!(
                    "dimensions={:?} maps {} axes but operand {} has rank {}",
                    ins.dims,
                    ins.dims.len(),
                    a.to_text(),
                    a.dims.len()
                );
            }
            for (i, &d) in ins.dims.iter().enumerate() {
                if d >= out.dims.len() {
                    bail!("dimensions={:?} maps axis {i} out of range", ins.dims);
                }
                if out.dims[d] != a.dims[i] {
                    bail!(
                        "operand axis {i} (size {}) maps to output axis {d} (size {})",
                        a.dims[i],
                        out.dims[d]
                    );
                }
            }
            shaped(a.dtype, out.dims.clone())
        }
        "reshape" => {
            arity(1)?;
            let a = osh(0)?;
            let out = declared.ok_or_else(|| anyhow!("reshape without declared shape"))?;
            if out.num_elements() != a.num_elements() {
                bail!(
                    "element count mismatch: operand {} has {}, declared {} has {}",
                    a.to_text(),
                    a.num_elements(),
                    out.to_text(),
                    out.num_elements()
                );
            }
            shaped(a.dtype, out.dims.clone())
        }
        "transpose" => {
            arity(1)?;
            let a = osh(0)?;
            let perm = &ins.dims;
            let mut seen = vec![false; a.dims.len()];
            if perm.len() != a.dims.len() {
                bail!("permutation {:?} rank-mismatches operand {}", perm, a.to_text());
            }
            for &p in perm {
                if p >= a.dims.len() || seen[p] {
                    bail!("dimensions={perm:?} is not a permutation of 0..{}", a.dims.len());
                }
                seen[p] = true;
            }
            shaped(a.dtype, perm.iter().map(|&p| a.dims[p]).collect())
        }
        "slice" => {
            arity(1)?;
            let a = osh(0)?;
            if ins.slice.len() != a.dims.len() {
                bail!("slice spec rank {} != operand rank {}", ins.slice.len(), a.dims.len());
            }
            let mut dims = Vec::with_capacity(a.dims.len());
            for (k, (&(s, l, st), &d)) in ins.slice.iter().zip(&a.dims).enumerate() {
                if st == 0 {
                    bail!("slice stride 0 on axis {k}");
                }
                if s > l || l > d {
                    bail!("slice [{s}:{l}] out of range for axis {k} (size {d})");
                }
                dims.push((l - s + st - 1) / st);
            }
            shaped(a.dtype, dims)
        }
        "concatenate" => {
            if ins.operands.is_empty() {
                bail!("concatenate with no operands");
            }
            // a missing dimensions= attribute used to silently default to
            // axis 0 (eval.rs pre-verifier); it is a hard error now
            let axis = match ins.dims.as_slice() {
                [d] => *d,
                [] => bail!("concatenate without dimensions= (no silent axis-0 default)"),
                other => bail!("concatenate with multi-axis dimensions={other:?}"),
            };
            let first = osh(0)?;
            if axis >= first.dims.len() {
                bail!("concatenate axis {axis} out of range for rank {}", first.dims.len());
            }
            let mut dims = first.dims.clone();
            dims[axis] = 0;
            for k in 0..ins.operands.len() {
                let a = osh(k)?;
                if a.dtype != first.dtype {
                    bail!(
                        "operand #{k} dtype {} != {}",
                        a.dtype.name(),
                        first.dtype.name()
                    );
                }
                if a.dims.len() != first.dims.len() {
                    bail!("operand #{k} rank-mismatches {}", first.to_text());
                }
                for (ax, (&x, &y)) in a.dims.iter().zip(&first.dims).enumerate() {
                    if ax != axis && x != y {
                        bail!(
                            "operand #{k} size {x} on axis {ax} != {y} (off-axis sizes must match)"
                        );
                    }
                }
                dims[axis] += a.dims[axis];
            }
            shaped(first.dtype, dims)
        }
        "pad" => {
            arity(2)?;
            let (a, pv) = (osh(0)?, osh(1)?);
            if !pv.dims.is_empty() {
                bail!("pad value must be scalar, got {}", pv.to_text());
            }
            if pv.dtype != a.dtype {
                bail!("pad value dtype {} != operand {}", pv.dtype.name(), a.dtype.name());
            }
            if ins.pad_cfg.len() != a.dims.len() {
                bail!("padding spec rank {} != operand rank {}", ins.pad_cfg.len(), a.dims.len());
            }
            let mut dims = Vec::with_capacity(a.dims.len());
            for (k, (&(lo, hi, interior), &d)) in ins.pad_cfg.iter().zip(&a.dims).enumerate() {
                if lo < 0 || hi < 0 || interior != 0 {
                    bail!(
                        "negative/interior padding unsupported (axis {k}: {lo}_{hi}_{interior})"
                    );
                }
                dims.push(d + lo as usize + hi as usize);
            }
            shaped(a.dtype, dims)
        }
        "reduce" => {
            arity(2)?;
            let (a, init) = (osh(0)?, osh(1)?);
            if !init.dims.is_empty() {
                bail!("reduce init must be scalar, got {}", init.to_text());
            }
            if init.dtype != a.dtype {
                bail!("reduce init dtype {} != operand {}", init.dtype.name(), a.dtype.name());
            }
            let body = ins
                .to_apply
                .as_deref()
                .ok_or_else(|| anyhow!("reduce without to_apply="))?;
            check_reduce_body(m, body, a.dtype)?;
            let mut seen = vec![false; a.dims.len()];
            for &d in &ins.dims {
                if d >= a.dims.len() || seen[d] {
                    bail!(
                        "dimensions={:?} not a set of distinct axes of {}",
                        ins.dims,
                        a.to_text()
                    );
                }
                seen[d] = true;
            }
            let dims: Vec<usize> = a
                .dims
                .iter()
                .enumerate()
                .filter(|(i, _)| !seen[*i])
                .map(|(_, &d)| d)
                .collect();
            shaped(a.dtype, dims)
        }
        "dot" => {
            arity(2)?;
            let (a, b) = (osh(0)?, osh(1)?);
            if a.dtype != HDtype::F32 || b.dtype != HDtype::F32 {
                bail!(
                    "dot requires f32 operands, got {} and {}",
                    a.dtype.name(),
                    b.dtype.name()
                );
            }
            // a missing dimension-numbers block used to silently default to
            // "no batch, no contraction" (an outer product); hard error now
            let dd = ins
                .dot
                .as_ref()
                .ok_or_else(|| anyhow!("dot without dimension numbers (no silent default)"))?;
            if dd.lhs_batch.len() != dd.rhs_batch.len() {
                bail!(
                    "batch dim arity mismatch: lhs {:?} vs rhs {:?}",
                    dd.lhs_batch,
                    dd.rhs_batch
                );
            }
            if dd.lhs_contract.len() != dd.rhs_contract.len() {
                bail!(
                    "contracting dim arity mismatch: lhs {:?} vs rhs {:?}",
                    dd.lhs_contract,
                    dd.rhs_contract
                );
            }
            let check_side = |dims: &[usize], rank: usize, what: &str| -> Result<()> {
                let mut seen = vec![false; rank];
                for &d in dims {
                    if d >= rank || seen[d] {
                        bail!("{what} dims {dims:?} invalid for rank {rank}");
                    }
                    seen[d] = true;
                }
                Ok(())
            };
            check_side(&dd.lhs_batch, a.dims.len(), "lhs_batch")?;
            check_side(&dd.lhs_contract, a.dims.len(), "lhs_contracting")?;
            check_side(&dd.rhs_batch, b.dims.len(), "rhs_batch")?;
            check_side(&dd.rhs_contract, b.dims.len(), "rhs_contracting")?;
            for (&lb, &rb) in dd.lhs_batch.iter().zip(&dd.rhs_batch) {
                if a.dims[lb] != b.dims[rb] {
                    bail!(
                        "batch dim size mismatch: lhs axis {lb} (size {}) vs rhs axis {rb} (size {})",
                        a.dims[lb],
                        b.dims[rb]
                    );
                }
            }
            for (&lc, &rc) in dd.lhs_contract.iter().zip(&dd.rhs_contract) {
                if a.dims[lc] != b.dims[rc] {
                    bail!(
                        "contracting dim size mismatch: lhs axis {lc} (size {}) vs rhs axis {rc} (size {})",
                        a.dims[lc],
                        b.dims[rc]
                    );
                }
            }
            let lhs_free = (0..a.dims.len())
                .filter(|i| !dd.lhs_batch.contains(i) && !dd.lhs_contract.contains(i));
            let rhs_free = (0..b.dims.len())
                .filter(|i| !dd.rhs_batch.contains(i) && !dd.rhs_contract.contains(i));
            let mut dims: Vec<usize> = dd.lhs_batch.iter().map(|&i| a.dims[i]).collect();
            dims.extend(lhs_free.map(|i| a.dims[i]));
            dims.extend(rhs_free.map(|i| b.dims[i]));
            shaped(HDtype::F32, dims)
        }
        "iota" => {
            let out = declared.ok_or_else(|| anyhow!("iota without declared shape"))?;
            let d = *ins
                .dims
                .first()
                .ok_or_else(|| anyhow!("iota without iota_dimension="))?;
            if d >= out.dims.len() {
                bail!("iota_dimension={d} out of range for {}", out.to_text());
            }
            if out.dtype == HDtype::Pred {
                bail!("pred iota unsupported");
            }
            out.clone()
        }
        "dynamic-slice" => {
            let a = osh(0)?;
            if ins.operands.len() != 1 + a.dims.len() {
                bail!(
                    "expected operand + {} scalar start indices, got {} operands",
                    a.dims.len(),
                    ins.operands.len()
                );
            }
            check_start_indices(c, ins, 1, a.dims.len())?;
            if ins.dyn_sizes.len() != a.dims.len() {
                bail!(
                    "dynamic_slice_sizes={:?} rank-mismatches operand {}",
                    ins.dyn_sizes,
                    a.to_text()
                );
            }
            for (k, (&sz, &d)) in ins.dyn_sizes.iter().zip(&a.dims).enumerate() {
                if sz > d {
                    bail!("slice size {sz} exceeds operand axis {k} (size {d})");
                }
            }
            shaped(a.dtype, ins.dyn_sizes.clone())
        }
        "dynamic-update-slice" => {
            let base = osh(0)?;
            let upd = osh(1)?;
            if ins.operands.len() != 2 + base.dims.len() {
                bail!(
                    "expected base + update + {} scalar start indices, got {} operands",
                    base.dims.len(),
                    ins.operands.len()
                );
            }
            if upd.dtype != base.dtype {
                bail!("update dtype {} != base {}", upd.dtype.name(), base.dtype.name());
            }
            if upd.dims.len() != base.dims.len() {
                bail!("update {} rank-mismatches base {}", upd.to_text(), base.to_text());
            }
            for (k, (&u, &d)) in upd.dims.iter().zip(&base.dims).enumerate() {
                if u > d {
                    bail!("update size {u} exceeds base axis {k} (size {d})");
                }
            }
            check_start_indices(c, ins, 2, base.dims.len())?;
            base.clone()
        }
        "gather" => {
            arity(2)?;
            let (a, idxs) = (osh(0)?, osh(1)?);
            if a.dtype != HDtype::F32 {
                bail!("gather operand must be f32, got {}", a.dtype.name());
            }
            if idxs.dtype != HDtype::S32 {
                bail!("gather indices must be s32, got {}", idxs.dtype.name());
            }
            let g = ins
                .gather
                .as_ref()
                .ok_or_else(|| anyhow!("gather without dimension numbers"))?;
            let orank = a.dims.len();
            if g.slice_sizes.len() != orank {
                bail!("slice_sizes={:?} rank-mismatches operand {}", g.slice_sizes, a.to_text());
            }
            for (k, (&sz, &d)) in g.slice_sizes.iter().zip(&a.dims).enumerate() {
                if sz > d {
                    bail!("slice size {sz} exceeds operand axis {k} (size {d})");
                }
            }
            if g.index_vector_dim > idxs.dims.len() {
                bail!(
                    "index_vector_dim={} out of range for indices {}",
                    g.index_vector_dim,
                    idxs.to_text()
                );
            }
            let mut batch_dims = idxs.dims.clone();
            let ncomp = if g.index_vector_dim < idxs.dims.len() {
                batch_dims.remove(g.index_vector_dim)
            } else {
                1
            };
            if ncomp != g.start_index_map.len() {
                bail!(
                    "{ncomp} index components != start_index_map={:?}",
                    g.start_index_map
                );
            }
            for &d in &g.start_index_map {
                if d >= orank {
                    bail!("start_index_map={:?} out of range for rank {orank}", g.start_index_map);
                }
            }
            let offset_operand_dims: Vec<usize> =
                (0..orank).filter(|i| !g.collapsed_slice_dims.contains(i)).collect();
            if g.offset_dims.len() != offset_operand_dims.len() {
                bail!(
                    "offset_dims={:?} must name one output axis per non-collapsed operand dim ({})",
                    g.offset_dims,
                    offset_operand_dims.len()
                );
            }
            let out_rank = g.offset_dims.len() + batch_dims.len();
            let mut dims = vec![0usize; out_rank];
            let mut is_offset = vec![false; out_rank];
            for (k, &ax) in g.offset_dims.iter().enumerate() {
                if ax >= out_rank || is_offset[ax] {
                    bail!("offset_dims={:?} invalid for output rank {out_rank}", g.offset_dims);
                }
                is_offset[ax] = true;
                dims[ax] = g.slice_sizes[offset_operand_dims[k]];
            }
            let mut b = 0;
            for (ax, d) in dims.iter_mut().enumerate() {
                if !is_offset[ax] {
                    *d = batch_dims[b];
                    b += 1;
                }
            }
            shaped(HDtype::F32, dims)
        }
        "get-tuple-element" => {
            arity(1)?;
            let src = &c.instrs[ins.operands[0]];
            if src.shape.is_some() {
                bail!(
                    "get-tuple-element operand %{} is not tuple-shaped",
                    src.name
                );
            }
            let k = ins
                .tuple_index
                .ok_or_else(|| anyhow!("get-tuple-element without index="))?;
            // tuple-shaped values (while results, root tuples) carry their
            // element shapes on their own operands
            if k >= src.operands.len() {
                bail!(
                    "index={k} out of range for tuple %{} with {} elements",
                    src.name,
                    src.operands.len()
                );
            }
            c.instrs[src.operands[k]]
                .shape
                .as_ref()
                .ok_or_else(|| anyhow!("tuple element #{k} is itself tuple-shaped"))?
                .clone()
        }
        "sort" => {
            arity(1)?;
            let a = osh(0)?;
            if a.dtype != HDtype::F32 {
                bail!("sort operand must be f32, got {}", a.dtype.name());
            }
            let axis = match ins.dims.as_slice() {
                [d] => *d,
                other => bail!("sort needs a single dimensions= axis, got {other:?}"),
            };
            if axis >= a.dims.len() {
                bail!("sort axis {axis} out of range for {}", a.to_text());
            }
            let cmp = ins
                .to_apply
                .as_deref()
                .ok_or_else(|| anyhow!("sort without to_apply= comparator"))?;
            check_sort_comparator(m, cmp, a.dtype)?;
            a.clone()
        }
        "scatter" => {
            arity(3)?;
            let (a, idxs, upd) = (osh(0)?, osh(1)?, osh(2)?);
            if a.dtype != HDtype::F32 || upd.dtype != HDtype::F32 {
                bail!(
                    "scatter operand/updates must be f32, got {} and {}",
                    a.dtype.name(),
                    upd.dtype.name()
                );
            }
            if idxs.dtype != HDtype::S32 {
                bail!("scatter indices must be s32, got {}", idxs.dtype.name());
            }
            let sd = ins
                .scatter
                .as_ref()
                .ok_or_else(|| anyhow!("scatter without dimension numbers"))?;
            let comb = ins
                .to_apply
                .as_deref()
                .ok_or_else(|| anyhow!("scatter without to_apply= combiner"))?;
            check_reduce_body(m, comb, a.dtype)
                .map_err(|e| anyhow!("scatter combiner: {e:#}"))?;
            let orank = a.dims.len();
            if sd.index_vector_dim > idxs.dims.len() {
                bail!(
                    "index_vector_dim={} out of range for indices {}",
                    sd.index_vector_dim,
                    idxs.to_text()
                );
            }
            let mut batch_dims = idxs.dims.clone();
            let ncomp = if sd.index_vector_dim < idxs.dims.len() {
                batch_dims.remove(sd.index_vector_dim)
            } else {
                1
            };
            if ncomp != sd.scatter_dims_to_operand_dims.len() {
                bail!(
                    "{ncomp} index components != scatter_dims_to_operand_dims={:?}",
                    sd.scatter_dims_to_operand_dims
                );
            }
            for &d in &sd.scatter_dims_to_operand_dims {
                if d >= orank {
                    bail!(
                        "scatter_dims_to_operand_dims={:?} out of range for rank {orank}",
                        sd.scatter_dims_to_operand_dims
                    );
                }
            }
            for &d in &sd.inserted_window_dims {
                if d >= orank {
                    bail!(
                        "inserted_window_dims={:?} out of range for rank {orank}",
                        sd.inserted_window_dims
                    );
                }
            }
            let window_operand_dims: Vec<usize> =
                (0..orank).filter(|i| !sd.inserted_window_dims.contains(i)).collect();
            if sd.update_window_dims.len() != window_operand_dims.len() {
                bail!(
                    "update_window_dims={:?} must name one updates axis per non-inserted operand dim ({})",
                    sd.update_window_dims,
                    window_operand_dims.len()
                );
            }
            let urank = upd.dims.len();
            let mut is_window = vec![false; urank];
            for &ax in &sd.update_window_dims {
                if ax >= urank || is_window[ax] {
                    bail!(
                        "update_window_dims={:?} invalid for updates rank {urank}",
                        sd.update_window_dims
                    );
                }
                is_window[ax] = true;
            }
            for (k, &ax) in sd.update_window_dims.iter().enumerate() {
                let od = window_operand_dims[k];
                if upd.dims[ax] > a.dims[od] {
                    bail!(
                        "update window size {} exceeds operand axis {od} (size {})",
                        upd.dims[ax],
                        a.dims[od]
                    );
                }
            }
            let update_batch: Vec<usize> = (0..urank).filter(|i| !is_window[*i]).collect();
            if update_batch.len() != batch_dims.len() {
                bail!(
                    "updates have {} batch axes but indices imply {}",
                    update_batch.len(),
                    batch_dims.len()
                );
            }
            for (k, &ax) in update_batch.iter().enumerate() {
                if upd.dims[ax] != batch_dims[k] {
                    bail!(
                        "updates batch axis {ax} (size {}) != indices batch dim #{k} (size {})",
                        upd.dims[ax],
                        batch_dims[k]
                    );
                }
            }
            a.clone()
        }
        "rng-bit-generator" => {
            arity(1)?;
            let a = osh(0)?;
            if !a.dims.is_empty() || a.dtype != HDtype::U32 {
                bail!("rng-bit-generator state must be scalar u32, got {}", a.to_text());
            }
            let out = declared
                .ok_or_else(|| anyhow!("rng-bit-generator without declared shape"))?;
            if out.dtype != HDtype::U32 {
                bail!("rng-bit-generator output must be u32, got {}", out.dtype.name());
            }
            out.clone()
        }
        "rng" => {
            arity(2)?;
            for (what, k) in [("low", 0), ("high", 1)] {
                let s = osh(k)?;
                if !s.dims.is_empty() || s.dtype != HDtype::F32 {
                    bail!("rng {what} bound must be f32[], got {}", s.to_text());
                }
            }
            match ins.distribution.as_deref() {
                Some("rng_uniform") => {}
                other => bail!("rng distribution {other:?} unsupported (only rng_uniform)"),
            }
            let out = declared.ok_or_else(|| anyhow!("rng without declared shape"))?;
            if out.dtype != HDtype::F32 {
                bail!("rng output must be f32, got {}", out.dtype.name());
            }
            out.clone()
        }
        other => {
            let gap = if DOCUMENTED_GAPS.contains(&other) {
                " (documented op-set gap — see ROADMAP.md)"
            } else {
                ""
            };
            bail!("unsupported opcode '{other}'{gap}");
        }
    }))
}

/// Scalar-integer check for the trailing start-index operands of
/// dynamic-slice / dynamic-update-slice.
fn check_start_indices(c: &Computation, ins: &Instr, from: usize, rank: usize) -> Result<()> {
    for k in 0..rank {
        let op = ins.operands[from + k];
        let sh = c.instrs[op]
            .shape
            .as_ref()
            .ok_or_else(|| anyhow!("start index #{k} is tuple-shaped"))?;
        if !sh.dims.is_empty() || !matches!(sh.dtype, HDtype::S32 | HDtype::U32) {
            bail!("start index #{k} must be scalar s32/u32, got {}", sh.to_text());
        }
    }
    Ok(())
}

/// Validate a reduce body: two scalar parameters of the operand dtype and
/// a root that is one of the supported folds over both parameters.
fn check_reduce_body(m: &HloModule, name: &str, dtype: HDtype) -> Result<()> {
    let body = m.computation(name)?;
    if body.params.len() != 2 {
        bail!(
            "reduce body '%{name}' has {} parameters, expected 2",
            body.params.len()
        );
    }
    for &p in &body.params {
        let sh = body.instrs[p]
            .shape
            .as_ref()
            .ok_or_else(|| anyhow!("reduce body '%{name}' parameter is tuple-shaped"))?;
        if !sh.dims.is_empty() || sh.dtype != dtype {
            bail!(
                "reduce body '%{name}' parameter %{} is {}, expected {}[]",
                body.instrs[p].name,
                sh.to_text(),
                dtype.name()
            );
        }
    }
    let root = &body.instrs[body.root];
    if !matches!(root.opcode.as_str(), "add" | "maximum" | "minimum") {
        bail!(
            "reduce body '%{name}' root op '{}' is not a supported fold (add/maximum/minimum)",
            root.opcode
        );
    }
    if root.operands.len() != 2
        || !root.operands.iter().all(|&o| body.params.contains(&o))
    {
        bail!("reduce body '%{name}' root must combine exactly the two parameters");
    }
    Ok(())
}

/// Validate a sort comparator: two scalar parameters of the key dtype and
/// a root `compare` over exactly those parameters *in order*, with an
/// ordering direction (GT/GE = descending, LT/LE = ascending — the
/// evaluator keys its sort off the direction, so EQ/NE are rejected).
fn check_sort_comparator(m: &HloModule, name: &str, dtype: HDtype) -> Result<()> {
    use crate::runtime::hlo::parser::CmpDir;
    let cmp = m.computation(name)?;
    if cmp.params.len() != 2 {
        bail!(
            "sort comparator '%{name}' has {} parameters, expected 2",
            cmp.params.len()
        );
    }
    for &p in &cmp.params {
        let sh = cmp.instrs[p]
            .shape
            .as_ref()
            .ok_or_else(|| anyhow!("sort comparator '%{name}' parameter is tuple-shaped"))?;
        if !sh.dims.is_empty() || sh.dtype != dtype {
            bail!(
                "sort comparator '%{name}' parameter %{} is {}, expected {}[]",
                cmp.instrs[p].name,
                sh.to_text(),
                dtype.name()
            );
        }
    }
    let root = &cmp.instrs[cmp.root];
    if root.opcode != "compare" {
        bail!(
            "sort comparator '%{name}' root op '{}' is not a compare",
            root.opcode
        );
    }
    if root.operands != cmp.params {
        bail!("sort comparator '%{name}' root must compare the two parameters in order");
    }
    match root.direction {
        Some(CmpDir::Gt | CmpDir::Ge | CmpDir::Lt | CmpDir::Le) => Ok(()),
        other => bail!("sort comparator '%{name}' direction {other:?} is not an ordering"),
    }
}

// ---------------------------------------------------------------------------
// Module-level verification
// ---------------------------------------------------------------------------

/// Run every static check over a parsed module; returns all diagnostics
/// (empty == verified).
pub fn verify_module(m: &HloModule) -> Vec<Diagnostic> {
    let mut diags = Vec::new();

    // unreferenced non-entry computations (dead reduce bodies usually mean
    // an emitter bug or a mangled to_apply=/condition=/body= reference)
    let mut referenced = vec![false; m.computations.len()];
    referenced[m.entry] = true;
    for c in &m.computations {
        for ins in &c.instrs {
            for name in [
                ins.to_apply.as_deref(),
                ins.condition.as_deref(),
                ins.body.as_deref(),
            ]
            .into_iter()
            .flatten()
            {
                if let Some(k) = m.computations.iter().position(|cc| cc.name == name) {
                    referenced[k] = true;
                }
            }
        }
    }
    for (k, c) in m.computations.iter().enumerate() {
        if !referenced[k] {
            diags.push(Diagnostic::module(
                DiagKind::DefUse,
                format!("computation '%{}' is never referenced", c.name),
            ));
        }
    }

    for (ci, c) in m.computations.iter().enumerate() {
        verify_computation(m, c, ci == m.entry, &mut diags);
    }
    diags
}

fn verify_computation(m: &HloModule, c: &Computation, is_entry: bool, diags: &mut Vec<Diagnostic>) {
    // parameter numbering must be dense and unique
    let param_idxs: Vec<usize> = c
        .instrs
        .iter()
        .filter_map(|i| if i.opcode == "parameter" { i.param_idx } else { None })
        .collect();
    {
        let mut sorted = param_idxs.clone();
        sorted.sort_unstable();
        if sorted != (0..param_idxs.len()).collect::<Vec<_>>() {
            diags.push(Diagnostic {
                kind: DiagKind::DefUse,
                computation: c.name.clone(),
                instr: String::new(),
                opcode: String::new(),
                message: format!("parameter numbers {param_idxs:?} are not dense 0..{}", param_idxs.len()),
            });
        }
    }

    // def-use: operands resolve before their consumers (the parser builds
    // indices def-before-use; a violation here means a parser bug) and
    // every non-parameter value is consumed or is the root
    let mut used = vec![false; c.instrs.len()];
    for (i, ins) in c.instrs.iter().enumerate() {
        for &op in &ins.operands {
            if op >= i {
                diags.push(Diagnostic::instr(
                    DiagKind::DefUse,
                    &c.name,
                    ins,
                    format!("operand %{} is not defined before use", c.instrs[op].name),
                ));
            } else {
                used[op] = true;
            }
        }
    }
    for (i, ins) in c.instrs.iter().enumerate() {
        if i != c.root && !used[i] && ins.opcode != "parameter" {
            diags.push(Diagnostic::instr(
                DiagKind::DefUse,
                &c.name,
                ins,
                "value is never used (dead instruction)".to_string(),
            ));
        }
    }

    // tuples: the entry root must be a tuple, and nothing else may be one
    let root = &c.instrs[c.root];
    if is_entry && root.opcode != "tuple" {
        diags.push(Diagnostic::instr(
            DiagKind::DefUse,
            &c.name,
            root,
            format!("entry root must be a tuple, got '{}'", root.opcode),
        ));
    }
    for (i, ins) in c.instrs.iter().enumerate() {
        if ins.opcode == "tuple" && i != c.root {
            diags.push(Diagnostic::instr(
                DiagKind::DefUse,
                &c.name,
                ins,
                "tuples are only supported as the root".to_string(),
            ));
        }
    }

    // per-instruction shape/dtype inference vs declared shape
    for (i, ins) in c.instrs.iter().enumerate() {
        match infer_shape(m, c, i) {
            Ok(None) => {} // tuple root: element shapes are the operands'
            Ok(Some(inferred)) => match ins.shape.as_ref() {
                Some(declared) if *declared == inferred => {}
                Some(declared) => diags.push(Diagnostic::instr(
                    DiagKind::ShapeMismatch,
                    &c.name,
                    ins,
                    format!(
                        "declared shape {} but operands/attributes infer {}",
                        declared.to_text(),
                        inferred.to_text()
                    ),
                )),
                None => diags.push(Diagnostic::instr(
                    DiagKind::ShapeMismatch,
                    &c.name,
                    ins,
                    format!("tuple-shaped result declared but '{}' infers {}", ins.opcode, inferred.to_text()),
                )),
            },
            Err(e) => {
                let msg = format!("{e:#}");
                let kind = classify_error(&ins.opcode, &msg);
                diags.push(Diagnostic::instr(kind, &c.name, ins, msg));
            }
        }
    }
}

/// Map an inference error to a diagnostic category from its opcode/text
/// (inference reports one error per instruction; the text carries detail).
fn classify_error(opcode: &str, msg: &str) -> DiagKind {
    if msg.contains("unsupported opcode") {
        DiagKind::UnsupportedOp
    } else if msg.contains("reduce body")
        || msg.contains("comparator")
        || msg.contains("combiner")
        || (opcode == "reduce" && msg.contains("computation"))
    {
        DiagKind::BadReduce
    } else if msg.contains("dtype") || msg.contains("not supported on") || msg.contains("must be pred")
    {
        DiagKind::DtypeMismatch
    } else if msg.contains("operand") && msg.contains("shape") {
        DiagKind::ShapeMismatch
    } else if opcode == "tuple" {
        DiagKind::DefUse
    } else {
        DiagKind::BadAttribute
    }
}

/// Parse + verify HLO text; a parse failure becomes a single diagnostic.
/// Returns the module too so callers can go on to plan when clean.
pub fn verify_text(text: &str) -> (Option<HloModule>, Vec<Diagnostic>) {
    match HloModule::parse(text) {
        Ok(m) => {
            let diags = verify_module(&m);
            (Some(m), diags)
        }
        Err(e) => (
            None,
            vec![Diagnostic::module(DiagKind::ParseError, format!("{e:#}"))],
        ),
    }
}

// ---------------------------------------------------------------------------
// Manifest I/O cross-check
// ---------------------------------------------------------------------------

fn dtype_to_h(d: Dtype) -> HDtype {
    match d {
        Dtype::F32 => HDtype::F32,
        Dtype::I32 => HDtype::S32,
        Dtype::U32 => HDtype::U32,
    }
}

/// Cross-check a module's entry signature against the manifest's declared
/// artifact spec: parameter count/shapes/dtypes and root tuple element
/// shapes must agree exactly (a drifted manifest corrupts training
/// numerics silently — the engine feeds tensors by position).
pub fn verify_artifact_io(m: &HloModule, spec: &ArtifactSpec) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    let entry = m.entry_computation();
    let mut io_diag = |message: String| {
        diags.push(Diagnostic {
            kind: DiagKind::IoContract,
            computation: entry.name.clone(),
            instr: String::new(),
            opcode: String::new(),
            message,
        });
    };

    if entry.params.len() != spec.inputs.len() {
        io_diag(format!(
            "manifest declares {} inputs but entry has {} parameters",
            spec.inputs.len(),
            entry.params.len()
        ));
    }
    for (k, (&p, s)) in entry.params.iter().zip(&spec.inputs).enumerate() {
        match entry.instrs[p].shape.as_ref() {
            Some(sh) if sh.dims == s.shape && sh.dtype == dtype_to_h(s.dtype) => {}
            Some(sh) => io_diag(format!(
                "input #{k} ('{}'): manifest says {:?} {}, HLO parameter %{} is {}",
                s.name,
                s.shape,
                s.dtype.name(),
                entry.instrs[p].name,
                sh.to_text()
            )),
            None => io_diag(format!("input #{k} ('{}') is tuple-shaped in the HLO", s.name)),
        }
    }

    let root = &entry.instrs[entry.root];
    if root.opcode == "tuple" {
        if root.operands.len() != spec.outputs.len() {
            io_diag(format!(
                "manifest declares {} outputs but root tuple has {} elements",
                spec.outputs.len(),
                root.operands.len()
            ));
        }
        for (k, (&op, s)) in root.operands.iter().zip(&spec.outputs).enumerate() {
            match entry.instrs[op].shape.as_ref() {
                Some(sh) if sh.dims == s.shape && sh.dtype == dtype_to_h(s.dtype) => {}
                Some(sh) => io_diag(format!(
                    "output #{k} ('{}'): manifest says {:?} {}, HLO root element %{} is {}",
                    s.name,
                    s.shape,
                    s.dtype.name(),
                    entry.instrs[op].name,
                    sh.to_text()
                )),
                None => io_diag(format!("output #{k} ('{}') is tuple-shaped", s.name)),
            }
        }
    }
    diags
}

// ---------------------------------------------------------------------------
// Directory lint (the `gcore hlo-lint` backend)
// ---------------------------------------------------------------------------

/// Per-artifact lint result.
#[derive(Debug)]
pub struct ArtifactLint {
    pub name: String,
    /// Entry-computation instruction count (0 when the module never parsed).
    pub instrs: usize,
    pub diagnostics: Vec<Diagnostic>,
    /// Analysis plan when the artifact verified cleanly.
    pub plan: Option<StaticPlan>,
}

/// Lint report over one artifact set (manifest + HLO files).
#[derive(Debug)]
pub struct LintReport {
    pub set_name: String,
    pub artifacts: Vec<ArtifactLint>,
}

impl LintReport {
    pub fn total_diagnostics(&self) -> usize {
        self.artifacts.iter().map(|a| a.diagnostics.len()).sum()
    }
}

/// Verify + plan every artifact in a manifest directory.  Missing HLO
/// files are diagnostics (the set is corrupt), as are parse failures,
/// verification findings, and manifest-I/O drift.
pub fn lint_set(dir: &Path) -> Result<LintReport> {
    let manifest = Manifest::load(dir)?;
    let mut artifacts = Vec::new();
    for (name, spec) in &manifest.artifacts {
        let path = manifest.hlo_path(name)?;
        let mut lint = ArtifactLint {
            name: name.clone(),
            instrs: 0,
            diagnostics: Vec::new(),
            plan: None,
        };
        match std::fs::read_to_string(&path) {
            Err(e) => lint.diagnostics.push(Diagnostic::module(
                DiagKind::ParseError,
                format!("cannot read {path:?}: {e}"),
            )),
            Ok(text) => {
                let (module, mut diags) = verify_text(&text);
                if let Some(m) = &module {
                    lint.instrs = m.entry_computation().instrs.len();
                    diags.extend(verify_artifact_io(m, spec));
                }
                let clean = diags.is_empty();
                lint.diagnostics = diags;
                if clean {
                    if let Some(m) = &module {
                        lint.plan = Some(StaticPlan::build(m));
                    }
                }
            }
        }
        artifacts.push(lint);
    }
    Ok(LintReport {
        set_name: manifest.dims.name.clone(),
        artifacts,
    })
}

#[cfg(test)]
mod tests {
    #![allow(clippy::disallowed_methods)]

    use super::*;

    fn verify_src(text: &str) -> Vec<Diagnostic> {
        let (_, d) = verify_text(text);
        d
    }

    #[test]
    fn clean_module_verifies() {
        let text = r#"%radd (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(f32[] %a, f32[] %b)
}

ENTRY %m (x: f32[2,3]) -> (f32[2]) {
  %x = f32[2,3] parameter(0)
  %z = f32[] constant(0)
  %s = f32[2] reduce(f32[2,3] %x, f32[] %z), dimensions={1}, to_apply=%radd
  ROOT %t = (f32[2]) tuple(f32[2] %s)
}
"#;
        let diags = verify_src(text);
        assert!(diags.is_empty(), "{diags:?}");
    }

    #[test]
    fn shape_mismatch_names_instruction_and_both_shapes() {
        let text = r#"ENTRY %m (x: f32[2,3]) -> (f32[3,2]) {
  %x = f32[2,3] parameter(0)
  %tr = f32[2,3] transpose(f32[2,3] %x), dimensions={1,0}
  ROOT %t = (f32[3,2]) tuple(f32[2,3] %tr)
}
"#;
        let diags = verify_src(text);
        assert_eq!(diags.len(), 1, "{diags:?}");
        let d = &diags[0];
        assert_eq!(d.kind, DiagKind::ShapeMismatch);
        assert_eq!(d.instr, "tr");
        assert_eq!(d.opcode, "transpose");
        assert!(d.message.contains("f32[2,3]") && d.message.contains("f32[3,2]"), "{}", d.message);
    }

    #[test]
    fn documented_gaps_are_structured_diagnostics() {
        for op in ["conditional", "custom-call"] {
            let text = format!(
                "ENTRY %m (x: f32[2]) -> (f32[2]) {{\n  %x = f32[2] parameter(0)\n  \
                 %w = f32[2] {op}(f32[2] %x)\n  ROOT %t = (f32[2]) tuple(f32[2] %w)\n}}\n"
            );
            let diags = verify_src(&text);
            assert!(
                diags.iter().any(|d| d.kind == DiagKind::UnsupportedOp
                    && d.opcode == op
                    && d.message.contains("documented op-set gap")),
                "{op}: {diags:?}"
            );
        }
    }

    const LOOP: &str = r#"%loop_cond (ci: s32[], cx: f32[4]) -> pred[] {
  %ci = s32[] parameter(0)
  %cx = f32[4] parameter(1)
  %cl = s32[] constant(3)
  ROOT %cp = pred[] compare(s32[] %ci, s32[] %cl), direction=LT
}

%loop_body (bi: s32[], bx: f32[4]) -> (s32[], f32[4]) {
  %bi = s32[] parameter(0)
  %bx = f32[4] parameter(1)
  %b1 = s32[] constant(1)
  %bn = s32[] add(s32[] %bi, s32[] %b1)
  %bneg = f32[4] negate(f32[4] %bx)
  ROOT %bt = (s32[], f32[4]) tuple(s32[] %bn, f32[4] %bneg)
}

ENTRY %m (i: s32[], x: f32[4]) -> (f32[4]) {
  %i = s32[] parameter(0)
  %x = f32[4] parameter(1)
  %w = (s32[], f32[4]) while(s32[] %i, f32[4] %x), condition=%loop_cond, body=%loop_body
  %out = f32[4] get-tuple-element((s32[], f32[4]) %w), index=1
  ROOT %t = (f32[4]) tuple(f32[4] %out)
}
"#;

    #[test]
    fn while_loop_verifies_cleanly() {
        let diags = verify_src(LOOP);
        assert!(diags.is_empty(), "{diags:?}");
    }

    #[test]
    fn while_state_shape_mismatch_flagged() {
        // body returns f32[5] for a f32[4] loop slot
        let text = LOOP
            .replace("%bneg = f32[4] negate(f32[4] %bx)", "%bneg = f32[4] negate(f32[4] %bx)\n  %bz = f32[] constant(0)\n  %bpad = f32[5] pad(f32[4] %bneg, f32[] %bz), padding=0_1")
            .replace(
                "ROOT %bt = (s32[], f32[4]) tuple(s32[] %bn, f32[4] %bneg)",
                "ROOT %bt = (s32[], f32[5]) tuple(s32[] %bn, f32[5] %bpad)",
            );
        let diags = verify_src(&text);
        assert!(
            diags
                .iter()
                .any(|d| d.opcode == "while" && d.message.contains("root element #1")),
            "{diags:?}"
        );
    }

    #[test]
    fn gte_index_out_of_range_flagged() {
        let text = LOOP.replace("index=1", "index=2");
        let diags = verify_src(&text);
        assert!(
            diags.iter().any(|d| d.opcode == "get-tuple-element"
                && d.message.contains("out of range")),
            "{diags:?}"
        );
    }

    #[test]
    fn sort_scatter_rng_verify_cleanly() {
        let text = r#"%sort_gt_f32 (sg_lhs: f32[], sg_rhs: f32[]) -> pred[] {
  %sg_lhs = f32[] parameter(0)
  %sg_rhs = f32[] parameter(1)
  ROOT %sg_out = pred[] compare(f32[] %sg_lhs, f32[] %sg_rhs), direction=GT
}

%scatter_add_f32 (sa_lhs: f32[], sa_rhs: f32[]) -> f32[] {
  %sa_lhs = f32[] parameter(0)
  %sa_rhs = f32[] parameter(1)
  ROOT %sa_out = f32[] add(f32[] %sa_lhs, f32[] %sa_rhs)
}

ENTRY %m (x: f32[2,4], tbl: f32[8,4], idx: s32[2], upd: f32[2,4], seed: u32[], lo: f32[], hi: f32[]) -> (f32[2,4], f32[8,4], u32[2,4], f32[3]) {
  %x = f32[2,4] parameter(0)
  %tbl = f32[8,4] parameter(1)
  %idx = s32[2] parameter(2)
  %upd = f32[2,4] parameter(3)
  %seed = u32[] parameter(4)
  %lo = f32[] parameter(5)
  %hi = f32[] parameter(6)
  %srt = f32[2,4] sort(f32[2,4] %x), dimensions={1}, to_apply=%sort_gt_f32
  %sc = f32[8,4] scatter(f32[8,4] %tbl, s32[2] %idx, f32[2,4] %upd), update_window_dims={1}, inserted_window_dims={0}, scatter_dims_to_operand_dims={0}, index_vector_dim=1, to_apply=%scatter_add_f32
  %bits = u32[2,4] rng-bit-generator(u32[] %seed), algorithm=rng_default
  %u = f32[3] rng(f32[] %lo, f32[] %hi), distribution=rng_uniform
  ROOT %t = (f32[2,4], f32[8,4], u32[2,4], f32[3]) tuple(f32[2,4] %srt, f32[8,4] %sc, u32[2,4] %bits, f32[3] %u)
}
"#;
        let diags = verify_src(text);
        assert!(diags.is_empty(), "{diags:?}");
    }

    #[test]
    fn sort_comparator_must_be_an_ordering() {
        let text = r#"%sort_eq (a: f32[], b: f32[]) -> pred[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = pred[] compare(f32[] %a, f32[] %b), direction=EQ
}

ENTRY %m (x: f32[4]) -> (f32[4]) {
  %x = f32[4] parameter(0)
  %s = f32[4] sort(f32[4] %x), dimensions={0}, to_apply=%sort_eq
  ROOT %t = (f32[4]) tuple(f32[4] %s)
}
"#;
        let diags = verify_src(text);
        assert!(
            diags.iter().any(|d| d.kind == DiagKind::BadReduce
                && d.opcode == "sort"
                && d.message.contains("not an ordering")),
            "{diags:?}"
        );
    }

    #[test]
    fn scatter_batch_mismatch_flagged() {
        let text = r#"%scatter_add_f32 (sa_lhs: f32[], sa_rhs: f32[]) -> f32[] {
  %sa_lhs = f32[] parameter(0)
  %sa_rhs = f32[] parameter(1)
  ROOT %sa_out = f32[] add(f32[] %sa_lhs, f32[] %sa_rhs)
}

ENTRY %m (tbl: f32[8,4], idx: s32[3], upd: f32[2,4]) -> (f32[8,4]) {
  %tbl = f32[8,4] parameter(0)
  %idx = s32[3] parameter(1)
  %upd = f32[2,4] parameter(2)
  %sc = f32[8,4] scatter(f32[8,4] %tbl, s32[3] %idx, f32[2,4] %upd), update_window_dims={1}, inserted_window_dims={0}, scatter_dims_to_operand_dims={0}, index_vector_dim=1, to_apply=%scatter_add_f32
  ROOT %t = (f32[8,4]) tuple(f32[8,4] %sc)
}
"#;
        let diags = verify_src(text);
        assert!(
            diags
                .iter()
                .any(|d| d.opcode == "scatter" && d.message.contains("batch")),
            "{diags:?}"
        );
    }

    #[test]
    fn dead_values_and_unreferenced_computations_flagged() {
        let text = r#"%orphan (a: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  ROOT %n = f32[] negate(f32[] %a)
}

ENTRY %m (x: f32[2]) -> (f32[2]) {
  %x = f32[2] parameter(0)
  %dead = f32[2] negate(f32[2] %x)
  ROOT %t = (f32[2]) tuple(f32[2] %x)
}
"#;
        let diags = verify_src(text);
        assert!(diags.iter().any(|d| d.message.contains("never referenced")), "{diags:?}");
        assert!(diags.iter().any(|d| d.instr == "dead" && d.message.contains("never used")), "{diags:?}");
    }

    #[test]
    fn io_contract_cross_checks_manifest() {
        let text = "ENTRY %m (x: f32[2]) -> (f32[2]) {\n  %x = f32[2] parameter(0)\n  \
                    ROOT %t = (f32[2]) tuple(f32[2] %x)\n}\n";
        let (m, diags) = verify_text(text);
        assert!(diags.is_empty());
        let m = m.unwrap();
        let spec = ArtifactSpec {
            name: "echo".into(),
            file: "echo.hlo.txt".into(),
            inputs: vec![crate::runtime::manifest::TensorSpec {
                name: "x".into(),
                shape: vec![3],
                dtype: Dtype::F32,
            }],
            outputs: vec![crate::runtime::manifest::TensorSpec {
                name: "y".into(),
                shape: vec![2],
                dtype: Dtype::F32,
            }],
            hlo_bytes: 0,
        };
        let diags = verify_artifact_io(&m, &spec);
        assert_eq!(diags.len(), 1, "{diags:?}");
        assert_eq!(diags[0].kind, DiagKind::IoContract);
        assert!(diags[0].message.contains("[3]"), "{}", diags[0].message);
    }
}
