//! Deterministic fork–join helper for the evaluator's data-parallel
//! kernels (`dot`, `reduce`).
//!
//! `GCORE_EVAL_THREADS` (default 1) sets the worker count.  Work is
//! partitioned into contiguous spans of *output* rows, and each row is
//! computed exactly as the sequential kernel would compute it — the
//! partition never changes any per-element accumulation order, so results
//! are bit-identical for every thread count.  That invariant is what lets
//! the nightly TSan job hammer the pool while the golden tests keep
//! asserting exact equality.
//!
//! Threads are scoped (`std::thread::scope`), so the pool holds no global
//! state, needs no shutdown, and borrows the caller's buffers directly.
//! With one thread (the default, and the right choice on single-core CI
//! runners) no thread is ever spawned.

use std::sync::OnceLock;

/// Worker count from `GCORE_EVAL_THREADS`, clamped to `[1, 64]`.
/// Unset/unparseable means 1: fully sequential, no spawns.
pub fn threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        std::env::var("GCORE_EVAL_THREADS")
            .ok()
            .and_then(|s| s.trim().parse::<usize>().ok())
            .map(|n| n.clamp(1, 64))
            .unwrap_or(1)
    })
}

/// Split `data` into at most `threads` contiguous parts aligned to `unit`
/// elements and run `f(first_row, part)` over each part — in parallel
/// when `threads > 1`.
///
/// `f` must compute every `unit`-sized row of its part independently of
/// rows outside the part; since the parts tile the rows exactly, the
/// result is identical to `f(0, data)` for any thread count.  `data.len()`
/// must be a multiple of `unit`.
pub fn run_parts<T, F>(threads: usize, data: &mut [T], unit: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    if data.is_empty() || unit == 0 {
        return;
    }
    debug_assert_eq!(data.len() % unit, 0, "partial trailing row");
    let rows = data.len() / unit;
    let nthreads = threads.clamp(1, rows);
    if nthreads <= 1 {
        f(0, data);
        return;
    }
    let per = rows.div_ceil(nthreads);
    std::thread::scope(|s| {
        let fr = &f;
        let mut rest = data;
        let mut row0 = 0usize;
        while !rest.is_empty() {
            let take = (per * unit).min(rest.len());
            let (head, tail) = rest.split_at_mut(take);
            rest = tail;
            let r0 = row0;
            row0 += take / unit;
            s.spawn(move || fr(r0, head));
        }
    });
}

#[cfg(test)]
mod tests {
    #![allow(clippy::disallowed_methods)]

    use super::*;

    fn square_rows(threads: usize, n_rows: usize, unit: usize) -> Vec<f32> {
        let mut v: Vec<f32> = (0..n_rows * unit).map(|i| i as f32).collect();
        run_parts(threads, &mut v, unit, |row0, part| {
            for (k, chunk) in part.chunks_mut(unit).enumerate() {
                let row = row0 + k;
                for x in chunk.iter_mut() {
                    *x = *x * *x + row as f32;
                }
            }
        });
        v
    }

    #[test]
    fn any_thread_count_is_bit_identical() {
        let want = square_rows(1, 13, 7);
        for threads in [2, 3, 4, 8, 64] {
            assert_eq!(square_rows(threads, 13, 7), want, "threads={threads}");
        }
    }

    #[test]
    fn more_threads_than_rows_is_fine() {
        assert_eq!(square_rows(16, 2, 3), square_rows(1, 2, 3));
    }

    #[test]
    fn empty_and_zero_unit_are_no_ops() {
        let mut v: Vec<f32> = vec![];
        run_parts(4, &mut v, 4, |_, _| panic!("must not run"));
        let mut v2 = vec![1.0f32];
        run_parts(4, &mut v2, 0, |_, _| panic!("must not run"));
        assert_eq!(v2, vec![1.0]);
    }

    #[test]
    fn default_thread_count_is_at_least_one() {
        assert!(threads() >= 1);
    }
}
