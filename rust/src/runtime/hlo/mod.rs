//! Pure-Rust HLO-text interpreter: the execution backend that makes the
//! engine-gated test tier run on stock CI runners (no vendored XLA, no
//! Python toolchain).
//!
//! Two layers:
//!
//! * [`parser`] — HLO *text* (the interchange format `python/compile/aot.py`
//!   emits) → [`parser::HloModule`].  Covers the op set the checked-in
//!   fixture artifact sets use — parameter/constant/tuple, elementwise
//!   arithmetic, `dot` (general), reshape/broadcast/transpose/slice/
//!   concatenate/pad, reduce, select/compare, exp/log/tanh/rsqrt/sqrt/
//!   sin/cos/power, iota, convert, integer bit ops, dynamic-slice/
//!   dynamic-update-slice and gather — and fails loudly on anything else.
//! * [`eval`] — a reference evaluator over host tensors.  Values are
//!   `Arc`-backed so shape-only ops (reshape, same-type convert) are
//!   zero-copy and buffers are taken at their last use — elementwise ops
//!   and `dynamic-update-slice` then mutate in place, keeping the stepwise
//!   decode loop's allocations bounded (asserted in tests/alloc_counts.rs).
//!
//! The fixture artifacts themselves (a real 2-layer byte-level transformer:
//! forward, KV-cached prefill/decode, PPO/SFT/BT/critic gradients, fused
//! Adam train step) are emitted by `python/compile/fixturegen/` — an HLO
//! graph builder with reverse-mode autodiff whose output is differentially
//! validated against `python/compile/model.py` (jax) at generation time,
//! then committed under `rust/tests/fixtures/artifacts/` together with
//! jax-generated golden outputs.  CI never runs Python: it evaluates the
//! committed text with this interpreter and compares against the committed
//! goldens.
//!
//! Known op-set gaps (tracked in ROADMAP.md): no `while`/`sort`/`rng-*` /
//! `scatter`, so the fused `generate_rollout` artifact is not part of the
//! fixture sets — the coordinator's stepwise `prefill`/`decode_step` path
//! covers generation.

pub mod eval;
pub mod parser;

pub use eval::Program;
pub use parser::HloModule;
