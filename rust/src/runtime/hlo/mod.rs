//! Pure-Rust HLO-text interpreter: the execution backend that makes the
//! engine-gated test tier run on stock CI runners (no vendored XLA, no
//! Python toolchain).
//!
//! The pipeline is **parse → verify → plan → eval**, with everything
//! before eval running once per artifact at engine load:
//!
//! * [`parser`] — HLO *text* (the interchange format `python/compile/aot.py`
//!   emits) → [`parser::HloModule`].  Covers the op set the checked-in
//!   fixture artifact sets use — parameter/constant/tuple, elementwise
//!   arithmetic, `dot` (general), reshape/broadcast/transpose/slice/
//!   concatenate/pad, reduce, select/compare, exp/log/tanh/rsqrt/sqrt/
//!   sin/cos/power, iota, convert, integer bit ops, dynamic-slice/
//!   dynamic-update-slice, gather, scatter, sort, `while` over flattened
//!   tuple state (+ get-tuple-element), and the counter-based
//!   `rng`/`rng-bit-generator` lowerings — and fails loudly on anything
//!   else.
//!   Opcodes in the documented gap set parse structurally (their
//!   attributes are ignored) so the verifier can report them as
//!   diagnostics instead of a parse failure.
//! * [`verify`] — static analysis over the parsed module: full
//!   shape/dtype inference per instruction (declared shape must equal the
//!   shape re-derived from operands + attributes), def-use validation
//!   (dead values, parameter numbering, reduce-body contracts,
//!   unreferenced computations), and the manifest I/O cross-check.  All
//!   findings are structured [`verify::Diagnostic`]s; `gcore hlo-lint`
//!   renders them as a table over an artifact directory.
//! * [`plan`] — liveness + alias analysis emitting a [`plan::StaticPlan`]:
//!   per-value last-use indices, provable buffer uniqueness (what makes
//!   in-place mutation a checked promise instead of an `Arc::try_unwrap`
//!   guess), a static peak-live-bytes bound, and the fusible
//!   elementwise-chain report the evaluator compiles into fused kernels.
//! * [`eval`] — a reference evaluator over host tensors.  Values are
//!   `Arc`-backed so shape-only ops (reshape, same-type convert) are
//!   zero-copy and buffers are taken at their plan-computed last use —
//!   elementwise ops and `dynamic-update-slice` then mutate in place,
//!   keeping the stepwise decode loop's allocations bounded (asserted in
//!   tests/alloc_counts.rs and cross-checked by the lint's
//!   peak-live-bytes column).  The planner's fusible chains run as
//!   parse-time-compiled blocked kernels (no chain intermediates), and
//!   `dot`/f32 `reduce` partition output rows over [`pool`]
//!   (`GCORE_EVAL_THREADS`) with bit-identical results at any thread
//!   count.
//!
//! The fixture artifacts themselves (a real 2-layer byte-level transformer:
//! forward, KV-cached prefill/decode, PPO/SFT/BT/critic gradients, fused
//! Adam train step) are emitted by `python/compile/fixturegen/` — an HLO
//! graph builder with reverse-mode autodiff whose output is differentially
//! validated against `python/compile/model.py` (jax) at generation time,
//! then committed under `rust/tests/fixtures/artifacts/` together with
//! jax-generated golden outputs.  CI never runs Python: it evaluates the
//! committed text with this interpreter and compares against the committed
//! goldens.
//!
//! Known op-set gaps (tracked in ROADMAP.md, reported as structured
//! `unsupported-op` diagnostics by the verifier): `conditional` and
//! `custom-call` only.  With `while`/`sort`/`scatter`/`rng-*` closed, the
//! fused `generate_rollout` artifact ships in both fixture sets and
//! `tests/rollout_integration.rs` holds it bit-identical to the
//! coordinator's stepwise `prefill`/`decode_step` path.

// This module tree interprets untrusted-ish artifact text on the training
// hot path: a panic here takes down a coordinator thread mid-rollout.
// `clippy.toml` disallows unwrap/expect and the deny is scoped to
// runtime/hlo (the workspace-level lint table allows it elsewhere); test
// submodules opt back in locally.
#![deny(clippy::disallowed_methods)]

pub mod eval;
pub mod parser;
pub mod plan;
pub mod pool;
pub mod verify;

pub use eval::Program;
pub use parser::HloModule;
