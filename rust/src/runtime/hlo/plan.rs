//! Static execution plan for a *verified* entry computation.
//!
//! [`StaticPlan::build`] runs once per artifact (at engine load) and
//! precomputes what the evaluator used to guess dynamically:
//!
//! * **`last_use`** — the instruction index at which each value's slot is
//!   taken (moved, not cloned).  Root operands are pinned live
//!   (`usize::MAX`).
//! * **`unique`** — whether a value's buffer is *provably* uniquely owned
//!   when its slot is taken.  `reshape` and same-dtype `convert` are
//!   zero-copy aliases in the evaluator: an alias created *without*
//!   consuming its operand leaves two live handles on one buffer, so the
//!   whole alias group is conservatively marked shared forever.  The
//!   evaluator mutates in place exactly when `taken && unique` — and
//!   *errors* if an `Arc::try_unwrap` the plan promised would succeed
//!   fails, instead of silently falling back to a copy (the old
//!   `unwrap_or_else(clone)` heuristic, which hid sharing bugs as
//!   allocations).
//! * **`peak_live_bytes`** — an upper bound on simultaneously-live buffer
//!   bytes under the slot/alias model, including `dot`'s transient operand
//!   regroup copies (statically decidable from the dimension numbers).
//!   The model excludes transient `Vec` growth inside kernels and the
//!   output tensors' hand-off copies; `gcore hlo-lint` cross-checks it
//!   against the 3 MB/token decode budget `tests/alloc_counts.rs` pins.
//! * **`fusible_chains`** — maximal straight-line runs of same-shape
//!   elementwise instructions where each link is the sole consumer of its
//!   predecessor: exactly the sequences the evaluator collapses into one
//!   fused loop at parse time without changing buffer lifetimes.
//! * **`comps`** — a [`CompPlan`] per computation, so `while`
//!   condition/body computations get the same liveness/alias treatment as
//!   the entry.  A `while` result owns its loop state (tuple element `k`
//!   has loop-operand `k`'s shape); `get-tuple-element` is an alias onto
//!   that state, and the while's transient charge is the larger of its
//!   sub-computation peaks.
//!
//! The plan is derived from *declared* shapes, which is sound only after
//! [`super::verify`] has proven declared == inferred for every
//! instruction; [`super::eval::Program::parse`] enforces that ordering.

use crate::runtime::hlo::parser::{Computation, HDtype, HloModule};
use crate::runtime::hlo::verify::dtype_bytes;

/// Elementwise opcodes that preserve shape and can fuse / mutate in place.
const ELEMENTWISE: &[&str] = &[
    "add",
    "subtract",
    "multiply",
    "divide",
    "maximum",
    "minimum",
    "power",
    "and",
    "or",
    "xor",
    "shift-left",
    "shift-right-logical",
    "negate",
    "abs",
    "exponential",
    "log",
    "tanh",
    "rsqrt",
    "sqrt",
    "sine",
    "cosine",
    "not",
    "select",
];

/// Plan for a single computation (see [`StaticPlan`] for field semantics).
/// `while` bodies and conditions get their own plans so the evaluator can
/// move/mutate loop-local buffers exactly as it does at the entry level.
#[derive(Debug, Clone)]
pub struct CompPlan {
    pub last_use: Vec<usize>,
    pub unique: Vec<bool>,
    pub peak_live_bytes: usize,
    pub fusible_chains: Vec<Vec<usize>>,
}

impl CompPlan {
    /// `shared_params` marks every parameter buffer as shared: `while`
    /// condition computations observe the live loop state through cheap
    /// clones (the body still needs it afterwards), so nothing reachable
    /// from a condition parameter may be mutated in place.
    fn build(
        module: &HloModule,
        c: &Computation,
        allow_while: bool,
        shared_params: bool,
    ) -> CompPlan {
        let last_use = compute_last_use(c);
        let (unique, peak_live_bytes) =
            alias_and_liveness(module, c, &last_use, allow_while, shared_params);
        let fusible_chains = fusible_chains(c, &last_use);
        CompPlan { last_use, unique, peak_live_bytes, fusible_chains }
    }
}

#[derive(Debug, Clone)]
pub struct StaticPlan {
    /// `last_use[i]` = index of the last *entry* instruction consuming
    /// value `i` (`usize::MAX` for the root, root operands, and unused
    /// values).
    pub last_use: Vec<usize>,
    /// `unique[i]` = taking value `i`'s slot yields the only handle on its
    /// buffer, so in-place mutation is safe.
    pub unique: Vec<bool>,
    /// Static bound on simultaneously-live value bytes (see module doc for
    /// the model).  For `while`, the bound charges the loop state once plus
    /// the larger of the condition/body sub-computation peaks.
    pub peak_live_bytes: usize,
    /// Maximal fusible elementwise runs (instruction indices, in order);
    /// only chains of length ≥ 2 are reported.
    pub fusible_chains: Vec<Vec<usize>>,
    /// One plan per computation, indexed like `module.computations`
    /// (the entry's is duplicated into the flat fields above).
    pub comps: Vec<CompPlan>,
}

impl StaticPlan {
    /// Build the plan for every computation of a verified module.
    pub fn build(module: &HloModule) -> StaticPlan {
        // computations referenced as a `while` condition= get shared
        // parameter groups (see `CompPlan::build`)
        let cond_names: Vec<&str> = module
            .computations
            .iter()
            .flat_map(|c| c.instrs.iter())
            .filter(|ins| ins.opcode == "while")
            .filter_map(|ins| ins.condition.as_deref())
            .collect();
        let comps: Vec<CompPlan> = module
            .computations
            .iter()
            .map(|c| {
                CompPlan::build(module, c, true, cond_names.contains(&c.name.as_str()))
            })
            .collect();
        let e = &comps[module.entry];
        StaticPlan {
            last_use: e.last_use.clone(),
            unique: e.unique.clone(),
            peak_live_bytes: e.peak_live_bytes,
            fusible_chains: e.fusible_chains.clone(),
            comps,
        }
    }
}

/// `true` when instruction `i` *takes* operand `op`'s slot: `i` is the
/// last use and `op` appears exactly once in the operand list (mirrors the
/// evaluator's take condition exactly).
fn takes(entry: &Computation, last_use: &[usize], i: usize, op: usize) -> bool {
    last_use[op] == i
        && entry.instrs[i].operands.iter().filter(|&&o| o == op).count() == 1
}

fn compute_last_use(entry: &Computation) -> Vec<usize> {
    let mut last_use = vec![usize::MAX; entry.instrs.len()];
    for (i, ins) in entry.instrs.iter().enumerate() {
        for &op in &ins.operands {
            last_use[op] = i;
        }
    }
    // the root and its operands become the caller's outputs — never drop
    // them early
    last_use[entry.root] = usize::MAX;
    for &op in &entry.instrs[entry.root].operands {
        last_use[op] = usize::MAX;
    }
    last_use
}

/// Is instruction `i` a zero-copy alias of its operand in the evaluator?
fn is_alias(entry: &Computation, i: usize) -> bool {
    let ins = &entry.instrs[i];
    match ins.opcode.as_str() {
        "reshape" => true,
        // extracting a tuple element hands out another handle on the loop
        // state's buffers (or moves one out, when the tuple is taken)
        "get-tuple-element" => true,
        "convert" => {
            // same-dtype convert returns the value unchanged
            let out = ins.shape.as_ref();
            let inp = ins.operands.first().and_then(|&o| entry.instrs[o].shape.as_ref());
            matches!((out, inp), (Some(a), Some(b)) if a.dtype == b.dtype)
        }
        _ => false,
    }
}

fn value_bytes(entry: &Computation, i: usize) -> usize {
    let ins = &entry.instrs[i];
    match ins.shape.as_ref() {
        Some(sh) => sh.num_elements() * dtype_bytes(sh.dtype),
        // a while result owns its loop state (element k has operand k's
        // shape); other tuple-shaped values (the root) own nothing
        None if ins.opcode == "while" => ins
            .operands
            .iter()
            .filter_map(|&o| entry.instrs[o].shape.as_ref())
            .map(|sh| sh.num_elements() * dtype_bytes(sh.dtype))
            .sum(),
        None => 0,
    }
}

/// Which operand the evaluator mutates in place when it owns the buffer
/// (f32 elementwise ops mutate the lhs / on-true branch;
/// `dynamic-update-slice` and `scatter` mutate the base/operand).
fn inplace_operand(entry: &Computation, i: usize) -> Option<usize> {
    let ins = &entry.instrs[i];
    let f32_out = matches!(
        ins.shape.as_ref().map(|s| s.dtype),
        Some(HDtype::F32)
    );
    let slot = match ins.opcode.as_str() {
        "dynamic-update-slice" => 0,
        "scatter" => 0,
        "select" if f32_out => 1,
        op if f32_out && ELEMENTWISE.contains(&op) && op != "select" => 0,
        _ => return None,
    };
    ins.operands.get(slot).copied()
}

/// Per-iteration transient bound for a `while`: the larger of the
/// condition/body sub-computation peaks (the loop state itself is charged
/// as the while's own bytes).  `allow_while` is false when already inside
/// a sub-computation — the verifier rejects nested `while`, so this only
/// guards unverified input against unbounded recursion.
fn while_transient_bytes(
    module: &HloModule,
    entry: &Computation,
    i: usize,
    allow_while: bool,
) -> usize {
    let ins = &entry.instrs[i];
    if ins.opcode != "while" || !allow_while {
        return 0;
    }
    [(ins.condition.as_deref(), true), (ins.body.as_deref(), false)]
        .into_iter()
        .filter_map(|(name, shared)| {
            let sub = module.computation(name?).ok()?;
            Some(CompPlan::build(module, sub, false, shared).peak_live_bytes)
        })
        .max()
        .unwrap_or(0)
}

/// `dot` regroups each operand into canonical [batch, free, contract] /
/// [batch, contract, free] order before the kernel; a non-identity order
/// materializes a transient copy of that operand.  Statically decidable
/// from the dimension numbers.
fn dot_transient_bytes(entry: &Computation, i: usize) -> usize {
    let ins = &entry.instrs[i];
    if ins.opcode != "dot" {
        return 0;
    }
    let Some(dd) = ins.dot.as_ref() else { return 0 };
    let mut transient = 0usize;
    let sides = [
        (ins.operands.first(), &dd.lhs_batch, &dd.lhs_contract, false),
        (ins.operands.get(1), &dd.rhs_batch, &dd.rhs_contract, true),
    ];
    for (op, batch, contract, contract_before_free) in sides {
        let Some(&op) = op else { continue };
        let Some(sh) = entry.instrs[op].shape.as_ref() else { continue };
        let rank = sh.dims.len();
        let free: Vec<usize> =
            (0..rank).filter(|d| !batch.contains(d) && !contract.contains(d)).collect();
        let order: Vec<usize> = if contract_before_free {
            batch.iter().chain(contract.iter()).chain(&free).copied().collect()
        } else {
            batch.iter().chain(&free).chain(contract.iter()).copied().collect()
        };
        if order.iter().enumerate().any(|(k, &d)| k != d) {
            transient += sh.num_elements() * dtype_bytes(sh.dtype);
        }
    }
    transient
}

/// One pass over a computation computing (a) per-value buffer uniqueness
/// via alias groups and (b) the peak-live-bytes bound via a
/// refcount-per-group simulation in instruction order.
fn alias_and_liveness(
    module: &HloModule,
    entry: &Computation,
    last_use: &[usize],
    allow_while: bool,
    shared_params: bool,
) -> (Vec<bool>, usize) {
    let n = entry.instrs.len();
    // --- alias groups: gid[i] identifies the underlying buffer; an alias
    // created without taking its operand leaves the group shared forever
    let mut gid = vec![usize::MAX; n];
    let mut shared: Vec<bool> = Vec::new();
    let mut next_gid = 0usize;
    let mut fresh = |shared: &mut Vec<bool>| {
        shared.push(false);
        next_gid += 1;
        next_gid - 1
    };
    for i in 0..n {
        let ins = &entry.instrs[i];
        if ins.opcode == "tuple" {
            continue;
        }
        if is_alias(entry, i) {
            let op = ins.operands[0];
            gid[i] = gid[op];
            if !takes(entry, last_use, i, op) {
                shared[gid[op]] = true;
            }
        } else {
            gid[i] = fresh(&mut shared);
            if shared_params && ins.opcode == "parameter" {
                shared[gid[i]] = true;
            }
        }
    }
    let unique: Vec<bool> =
        (0..n).map(|i| gid[i] != usize::MAX && !shared[gid[i]]).collect();

    // --- liveness simulation: refcount per group, bytes per group
    let mut refcnt = vec![0usize; next_gid];
    let mut group_bytes = vec![0usize; next_gid];
    let mut live = 0usize;
    let mut peak = 0usize;
    for i in 0..n {
        let ins = &entry.instrs[i];
        if i == entry.root {
            break; // outputs stay live; the tuple itself owns no buffer
        }
        let alias = is_alias(entry, i);
        let inplace = match inplace_operand(entry, i) {
            Some(op) => takes(entry, last_use, i, op) && unique[op],
            None => false,
        };
        let alloc = if alias || inplace { 0 } else { value_bytes(entry, i) };
        peak = peak.max(
            live + alloc
                + dot_transient_bytes(entry, i)
                + while_transient_bytes(module, entry, i, allow_while),
        );
        // release every operand handle this instruction consumes (an alias
        // that takes its operand *moves* the handle instead)
        let mut seen_ops: Vec<usize> = Vec::new();
        for &op in &ins.operands {
            if seen_ops.contains(&op) {
                continue;
            }
            seen_ops.push(op);
            if takes(entry, last_use, i, op) && !(alias && op == ins.operands[0]) {
                let g = gid[op];
                refcnt[g] -= 1;
                if refcnt[g] == 0 {
                    live -= group_bytes[g];
                }
            }
        }
        // materialize this instruction's handle
        let g = gid[i];
        if alias {
            if !takes(entry, last_use, i, ins.operands[0]) {
                refcnt[g] += 1; // second handle on the same buffer
            }
        } else {
            refcnt[g] = 1;
            group_bytes[g] = value_bytes(entry, i);
            live += group_bytes[g];
        }
        peak = peak.max(live);
    }
    (unique, peak)
}

/// Maximal same-shape elementwise runs where each link is the sole
/// consumer of its predecessor (length ≥ 2).
fn fusible_chains(entry: &Computation, last_use: &[usize]) -> Vec<Vec<usize>> {
    let n = entry.instrs.len();
    // pred[i] = the chain predecessor of i, if any
    let mut pred = vec![usize::MAX; n];
    let mut has_succ = vec![false; n];
    for i in 0..n {
        let ins = &entry.instrs[i];
        if !ELEMENTWISE.contains(&ins.opcode.as_str()) {
            continue;
        }
        let dims = match ins.shape.as_ref() {
            Some(sh) => &sh.dims,
            None => continue,
        };
        for &op in &ins.operands {
            let prev = &entry.instrs[op];
            if ELEMENTWISE.contains(&prev.opcode.as_str())
                && takes(entry, last_use, i, op)
                && prev.shape.as_ref().map(|s| &s.dims) == Some(dims)
                && !has_succ[op]
            {
                pred[i] = op;
                has_succ[op] = true;
                break;
            }
        }
    }
    let mut chains = Vec::new();
    for end in 0..n {
        if has_succ[end] || pred[end] == usize::MAX {
            continue; // not a chain tail, or a singleton
        }
        let mut chain = vec![end];
        let mut cur = end;
        while pred[cur] != usize::MAX {
            cur = pred[cur];
            chain.push(cur);
        }
        chain.reverse();
        chains.push(chain);
    }
    chains
}

#[cfg(test)]
mod tests {
    #![allow(clippy::disallowed_methods)]

    use super::*;
    use crate::runtime::hlo::parser::HloModule;

    fn plan(text: &str) -> StaticPlan {
        StaticPlan::build(&HloModule::parse(text).unwrap())
    }

    #[test]
    fn last_use_pins_root_operands() {
        let p = plan(
            "ENTRY %m (x: f32[2]) -> (f32[2]) {\n  %x = f32[2] parameter(0)\n  \
             %n = f32[2] negate(f32[2] %x)\n  ROOT %t = (f32[2]) tuple(f32[2] %n)\n}\n",
        );
        assert_eq!(p.last_use[0], 1); // x consumed by negate
        assert_eq!(p.last_use[1], usize::MAX); // root operand
        assert!(p.unique[1]);
    }

    #[test]
    fn alias_without_take_marks_group_shared() {
        // %x is used by both the reshape and the add, so the reshape clones
        // the handle: neither value may be mutated in place.
        let p = plan(
            "ENTRY %m (x: f32[4]) -> (f32[4]) {\n  %x = f32[4] parameter(0)\n  \
             %r = f32[2,2] reshape(f32[4] %x)\n  \
             %r2 = f32[4] reshape(f32[2,2] %r)\n  \
             %s = f32[4] add(f32[4] %x, f32[4] %r2)\n  \
             ROOT %t = (f32[4]) tuple(f32[4] %s)\n}\n",
        );
        assert!(!p.unique[0] && !p.unique[1] && !p.unique[2], "{:?}", p.unique);
        assert!(p.unique[3]); // add output is a fresh buffer
    }

    #[test]
    fn alias_with_take_stays_unique() {
        let p = plan(
            "ENTRY %m (x: f32[4]) -> (f32[2,2]) {\n  %x = f32[4] parameter(0)\n  \
             %r = f32[2,2] reshape(f32[4] %x)\n  \
             %n = f32[2,2] negate(f32[2,2] %r)\n  \
             ROOT %t = (f32[2,2]) tuple(f32[2,2] %n)\n}\n",
        );
        assert!(p.unique[0] && p.unique[1] && p.unique[2], "{:?}", p.unique);
    }

    #[test]
    fn peak_live_counts_in_place_once() {
        // x (16B) negated in place then halved in place: peak = x + the
        // broadcast 0.5 (16B) + the scalar (4B), never 2 copies of x.
        let p = plan(
            "ENTRY %m (x: f32[4]) -> (f32[4]) {\n  %x = f32[4] parameter(0)\n  \
             %h = f32[] constant(0.5)\n  \
             %hb = f32[4] broadcast(f32[] %h), dimensions={}\n  \
             %n = f32[4] negate(f32[4] %x)\n  \
             %m2 = f32[4] multiply(f32[4] %n, f32[4] %hb)\n  \
             ROOT %t = (f32[4]) tuple(f32[4] %m2)\n}\n",
        );
        assert_eq!(p.peak_live_bytes, 16 + 4 + 16);
    }

    #[test]
    fn while_gets_sub_plans_and_charges_state_plus_body_peak() {
        let text = r#"%wc (ci: s32[], cx: f32[4]) -> pred[] {
  %ci = s32[] parameter(0)
  %cx = f32[4] parameter(1)
  %cl = s32[] constant(3)
  ROOT %cp = pred[] compare(s32[] %ci, s32[] %cl), direction=LT
}

%wb (bi: s32[], bx: f32[4]) -> (s32[], f32[4]) {
  %bi = s32[] parameter(0)
  %bx = f32[4] parameter(1)
  %b1 = s32[] constant(1)
  %bn = s32[] add(s32[] %bi, s32[] %b1)
  %bneg = f32[4] negate(f32[4] %bx)
  ROOT %bt = (s32[], f32[4]) tuple(s32[] %bn, f32[4] %bneg)
}

ENTRY %m (i: s32[], x: f32[4]) -> (f32[4]) {
  %i = s32[] parameter(0)
  %x = f32[4] parameter(1)
  %w = (s32[], f32[4]) while(s32[] %i, f32[4] %x), condition=%wc, body=%wb
  %out = f32[4] get-tuple-element((s32[], f32[4]) %w), index=1
  ROOT %t = (f32[4]) tuple(f32[4] %out)
}
"#;
        let m = HloModule::parse(text).unwrap();
        let p = StaticPlan::build(&m);
        assert_eq!(p.comps.len(), 3);
        // the entry plan is the flat one
        assert_eq!(p.comps[2].last_use, p.last_use);
        // gte takes the while's only handle — state stays uniquely owned
        let entry = m.entry_computation();
        assert_eq!(p.last_use[2], 3); // while consumed by the gte
        assert!(p.unique[3], "{:?}", p.unique);
        assert_eq!(p.last_use[entry.root], usize::MAX);
        // the peak charges the 20-byte loop state (4B counter + 16B vec)
        // at the while, on top of the live operands
        assert!(p.peak_live_bytes >= 20, "{}", p.peak_live_bytes);
        // body plan sees its own elementwise structure
        let body = &p.comps[1];
        assert_eq!(body.last_use.len(), 6);
        // condition parameters are statically shared (the loop state must
        // survive the condition for the body), body parameters are not
        assert!(p.comps[0].unique.iter().take(2).all(|u| !u), "{:?}", p.comps[0].unique);
        assert!(p.comps[1].unique[1], "{:?}", p.comps[1].unique);
    }

    #[test]
    fn fusible_chain_found() {
        let p = plan(
            "ENTRY %m (x: f32[4], y: f32[4]) -> (f32[4]) {\n  %x = f32[4] parameter(0)\n  \
             %y = f32[4] parameter(1)\n  \
             %a = f32[4] add(f32[4] %x, f32[4] %y)\n  \
             %n = f32[4] negate(f32[4] %a)\n  \
             %e = f32[4] exponential(f32[4] %n)\n  \
             ROOT %t = (f32[4]) tuple(f32[4] %e)\n}\n",
        );
        assert_eq!(p.fusible_chains, vec![vec![2, 3, 4]]);
    }
}
