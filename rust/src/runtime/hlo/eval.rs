//! Reference evaluator for *verified* HLO modules.
//!
//! Correctness first, but with the two properties the engine tier needs:
//!
//! * values are `Arc`-backed, so `reshape` (and same-type `convert`) are
//!   zero-copy and operand buffers are *taken* at their last use — unary /
//!   binary elementwise ops and `dynamic-update-slice` then mutate in
//!   place instead of allocating.  The stepwise decode loop's per-token
//!   allocations stay bounded by the step outputs (tests/alloc_counts.rs).
//! * evaluation is pure and `&self`, so coordinator threads execute
//!   concurrently (unlike PJRT, which the engine serializes).
//!
//! [`Program::parse`] runs [`super::verify`] and precomputes a
//! [`StaticPlan`] before anything executes: liveness (`last_use`) and
//! buffer uniqueness come from the plan, so in-place mutation is a
//! *checked promise* — an `Arc::try_unwrap` the plan said would succeed
//! erroring out is a planner bug surfaced loudly, not a silent copy.
//!
//! Control flow and speed, layered on the same machinery:
//!
//! * `while` runs its condition over cheap clones of the flattened loop
//!   state and threads the state through the body *by move*, so the
//!   body's in-place paths (KV-cache `dynamic-update-slice`, fused Adam
//!   chains) work across iterations exactly as at the entry level.
//! * the planner's fusible elementwise chains are compiled into
//!   [`CompFused`] kernels at parse time: one blocked pass per chain,
//!   no intermediate materialization.
//! * `dot` and f32 `reduce` fan out over [`super::pool`]
//!   (`GCORE_EVAL_THREADS`), partitioned by output rows so any thread
//!   count is bit-identical to sequential execution.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::runtime::hlo::parser::{
    CmpDir, Computation, DotDims, HDtype, HShape, HloModule, Instr, Literal, ReduceKind,
};
use crate::runtime::hlo::plan::{CompPlan, StaticPlan};
use crate::runtime::hlo::pool;
use crate::runtime::hlo::verify;
use crate::runtime::tensor::{Tensor, TensorData};
use crate::util::rng::hash_u32;

/// A compiled-for-evaluation module: parse + verify + plan once, evaluate
/// many times.
#[derive(Debug, Clone)]
pub struct Program {
    module: HloModule,
    plan: StaticPlan,
    /// Per-computation fused elementwise kernels (indexed like
    /// `module.computations`), compiled from the plan's fusible chains.
    fused: Vec<CompFused>,
}

impl Program {
    pub fn parse(text: &str) -> Result<Program> {
        Program::compile(HloModule::parse(text)?)
    }

    /// Verify a parsed module and build its execution plan.  Any verifier
    /// diagnostic — shape/dtype mismatch, def-use defect, unsupported op,
    /// missing attribute — rejects the module here, before evaluation.
    pub fn compile(module: HloModule) -> Result<Program> {
        let diags = verify::verify_module(&module);
        if !diags.is_empty() {
            let list: Vec<String> = diags.iter().map(|d| format!("  {d}")).collect();
            bail!(
                "module '{}' failed static verification with {} diagnostic(s):\n{}",
                module.name,
                diags.len(),
                list.join("\n")
            );
        }
        let plan = StaticPlan::build(&module);
        let fused = module
            .computations
            .iter()
            .zip(&plan.comps)
            .map(|(c, p)| CompFused::build(c, p))
            .collect();
        Ok(Program { module, plan, fused })
    }

    pub fn module(&self) -> &HloModule {
        &self.module
    }

    /// The static execution plan (liveness, uniqueness, peak-live bound).
    pub fn plan(&self) -> &StaticPlan {
        &self.plan
    }

    /// Instruction count of the entry computation (interp "compile" stat).
    pub fn num_instructions(&self) -> usize {
        self.module.entry_computation().instrs.len()
    }

    /// Fused elementwise chains compiled across all computations (the
    /// Einterp table's fusion column).
    pub fn fused_chain_count(&self) -> usize {
        self.fused.iter().map(|f| f.tails.len()).sum()
    }

    /// Evaluate the entry computation.  The root must be a tuple; its
    /// elements come back as one host tensor each (the engine contract).
    pub fn evaluate(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let refs: Vec<&Tensor> = inputs.iter().collect();
        self.evaluate_refs(&refs)
    }

    /// Borrowing variant of [`Program::evaluate`] — parameters are copied
    /// into the value arena exactly once (the engine's hot path).
    pub fn evaluate_refs(&self, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        let entry = self.module.entry_computation();
        if inputs.len() != entry.params.len() {
            bail!(
                "module '{}' expects {} parameters, got {}",
                self.module.name,
                entry.params.len(),
                inputs.len()
            );
        }
        let root = &entry.instrs[entry.root];
        if root.opcode != "tuple" {
            bail!("entry root must be a tuple, got '{}'", root.opcode);
        }
        let params: Vec<Option<Val>> =
            inputs.iter().map(|t| Some(Val::from_tensor(t))).collect();
        let outs = self.eval_comp(self.module.entry, params)?;
        outs.into_iter().map(|(v, owned)| v.into_tensor(owned)).collect()
    }

    /// Run one computation with positional parameter values.  Returns the
    /// root values: every tuple element for a tuple root (the entry /
    /// `while`-body contract), or the single root value otherwise
    /// (`while` conditions).  The `bool` per value is the plan's
    /// ownership promise — `true` means the returned handle is provably
    /// the only one on its buffer.
    fn eval_comp(&self, ci: usize, mut params: Vec<Option<Val>>) -> Result<Vec<(Val, bool)>> {
        let comp = &self.module.computations[ci];
        let plan = &self.plan.comps[ci];
        let fused = &self.fused[ci];
        let mut slots: Vec<Option<SlotVal>> = vec![None; comp.instrs.len()];
        for (i, ins) in comp.instrs.iter().enumerate() {
            if i == comp.root {
                break;
            }
            if fused.interior[i] {
                continue; // computed by the fused kernel at its chain tail
            }
            let val = if let Some(chain) = fused.tails.get(&i) {
                let v = self.exec_fused(comp, plan, chain, &mut slots).with_context(|| {
                    format!("evaluating fused chain ending at %{} ({})", ins.name, ins.opcode)
                })?;
                Some(SlotVal::One(v))
            } else {
                self.exec(plan, i, ins, &mut params, &mut slots)
                    .with_context(|| format!("evaluating %{} ({})", ins.name, ins.opcode))?
            };
            if let Some(v) = val {
                if let (SlotVal::One(one), Some(shape)) = (&v, &ins.shape) {
                    debug_assert_eq!(
                        one.dims,
                        shape.dims,
                        "%{}: result shape mismatch",
                        ins.name
                    );
                }
                slots[i] = Some(v);
            }
        }
        let root = &comp.instrs[comp.root];
        if root.opcode != "tuple" {
            // non-tuple root (a `while` condition): execute it like any
            // other instruction and hand back the single value
            let v = self
                .exec(plan, comp.root, root, &mut params, &mut slots)
                .with_context(|| format!("evaluating root %{} ({})", root.name, root.opcode))?
                .context("root produced no value")?
                .into_val()?;
            return Ok(vec![(v, plan.unique[comp.root])]);
        }
        // take (not clone) each root operand at its LAST occurrence so
        // uniquely-owned buffers move straight into the outputs without a
        // copy; earlier duplicate occurrences clone (legal HLO may repeat
        // a tuple element)
        root.operands
            .iter()
            .enumerate()
            .map(|(k, &op)| {
                let dup_later = root.operands[k + 1..].contains(&op);
                let v = if dup_later {
                    slots[op].clone()
                } else {
                    slots[op].take()
                };
                let owned = !dup_later && plan.unique[op];
                Ok((v.context("root operand missing")?.into_val()?, owned))
            })
            .collect()
    }

    /// Execute one instruction.  Returns `None` only for non-root tuples
    /// (which own nothing) — every other opcode yields a value.
    fn exec(
        &self,
        plan: &CompPlan,
        idx: usize,
        ins: &Instr,
        params: &mut [Option<Val>],
        slots: &mut [Option<SlotVal>],
    ) -> Result<Option<SlotVal>> {
        // tuple-shaped slots (`while` results) are only consumed by
        // `get-tuple-element`, which moves an element out of a taken tuple
        if ins.opcode == "get-tuple-element" {
            return Ok(Some(SlotVal::One(gte(plan, idx, ins, slots)?)));
        }
        // Take operands out of their slots at their plan-computed last use
        // so uniquely-owned buffers can be mutated in place downstream.
        // `owned[k]` = the take yields the only handle on the buffer (per
        // the static alias analysis), so in-place mutation is safe.
        let mut args: Vec<Val> = Vec::with_capacity(ins.operands.len());
        let mut owned: Vec<bool> = Vec::with_capacity(ins.operands.len());
        for &op in &ins.operands {
            let (v, own) = grab(plan, ins, idx, op, slots)?;
            args.push(v);
            owned.push(own);
        }
        if ins.opcode == "while" {
            return Ok(Some(SlotVal::Tuple(self.exec_while(ins, args)?)));
        }
        let out_shape = ins.shape.as_ref();
        let v = match ins.opcode.as_str() {
            "parameter" => {
                let p = ins.param_idx.context("parameter without number")?;
                params
                    .get_mut(p)
                    .and_then(|s| s.take())
                    .with_context(|| format!("parameter {p} missing or consumed twice"))?
            }
            "constant" => Val::from_literal(
                ins.literal.as_ref().context("constant without literal")?,
                &out_shape.context("constant without shape")?.dims,
            )?,
            "tuple" => return Ok(None),
            "add" => binary(args, &owned, BinOp::Add)?,
            "subtract" => binary(args, &owned, BinOp::Sub)?,
            "multiply" => binary(args, &owned, BinOp::Mul)?,
            "divide" => binary(args, &owned, BinOp::Div)?,
            "maximum" => binary(args, &owned, BinOp::Max)?,
            "minimum" => binary(args, &owned, BinOp::Min)?,
            "power" => binary(args, &owned, BinOp::Pow)?,
            "and" => binary(args, &owned, BinOp::And)?,
            "or" => binary(args, &owned, BinOp::Or)?,
            "xor" => binary(args, &owned, BinOp::Xor)?,
            "shift-left" => binary(args, &owned, BinOp::Shl)?,
            "shift-right-logical" => binary(args, &owned, BinOp::Shr)?,
            "negate" => unary(args, &owned, UnOp::Neg)?,
            "abs" => unary(args, &owned, UnOp::Abs)?,
            "exponential" => unary(args, &owned, UnOp::Exp)?,
            "log" => unary(args, &owned, UnOp::Log)?,
            "tanh" => unary(args, &owned, UnOp::Tanh)?,
            "rsqrt" => unary(args, &owned, UnOp::Rsqrt)?,
            "sqrt" => unary(args, &owned, UnOp::Sqrt)?,
            "sine" => unary(args, &owned, UnOp::Sin)?,
            "cosine" => unary(args, &owned, UnOp::Cos)?,
            "not" => unary(args, &owned, UnOp::Not)?,
            "compare" => compare(args, ins.direction.context("compare without direction")?)?,
            "select" => select(args, &owned)?,
            "convert" => convert(args, out_shape.context("convert without shape")?.dtype)?,
            "broadcast" => broadcast(
                args,
                &ins.dims,
                &out_shape.context("broadcast without shape")?.dims,
            )?,
            "reshape" => {
                let mut v = args.remove_first()?;
                let out = out_shape.context("reshape without shape")?;
                if out.num_elements() != v.len() {
                    bail!("reshape element count mismatch");
                }
                v.dims = out.dims.clone();
                v
            }
            "transpose" => transpose(args, &ins.dims)?,
            "slice" => slice_op(args, &ins.slice)?,
            // a missing dimensions= used to silently mean axis 0 here; the
            // verifier rejects it at compile time and this is the backstop
            "concatenate" => concat(
                args,
                ins.dims
                    .first()
                    .copied()
                    .context("concatenate without dimensions= (no silent axis-0 default)")?,
            )?,
            "pad" => pad(args, &ins.pad_cfg)?,
            "reduce" => {
                let name = ins.to_apply.as_deref().context("reduce without to_apply")?;
                let kind = self.module.reduce_kind(name)?;
                reduce(args, &ins.dims, kind)?
            }
            // absent dimension numbers used to default to an outer product;
            // also rejected by the verifier, error kept as the backstop
            "dot" => dot(
                args,
                ins.dot
                    .clone()
                    .context("dot without dimension numbers (no silent default)")?,
            )?,
            "iota" => iota(
                out_shape.context("iota without shape")?,
                ins.dims.first().copied().context("iota without dimension")?,
            )?,
            "dynamic-slice" => dynamic_slice(args, &ins.dyn_sizes)?,
            "dynamic-update-slice" => dynamic_update_slice(args, &owned)?,
            "gather" => gather(args, ins, out_shape.context("gather without shape")?)?,
            "sort" => self.sort(args, &owned, ins)?,
            "scatter" => self.scatter(args, &owned, ins)?,
            "rng-bit-generator" => {
                rng_bit_generator(args, out_shape.context("rng-bit-generator without shape")?)?
            }
            "rng" => rng_uniform(args, out_shape.context("rng without shape")?, ins)?,
            other => bail!("unsupported opcode '{other}'"),
        };
        Ok(Some(SlotVal::One(v)))
    }

    /// `while` over flattened loop state.  The condition sees the state
    /// through cheap `Arc` clones (the body still needs it); the body
    /// consumes the state by move, with each element made uniquely owned
    /// first so the body plan's in-place promises hold across iterations
    /// (weights pass through as moves, the KV caches mutate in place).
    fn exec_while(&self, ins: &Instr, args: Vec<Val>) -> Result<Vec<Val>> {
        let cond =
            self.comp_index(ins.condition.as_deref().context("while without condition=")?)?;
        let body = self.comp_index(ins.body.as_deref().context("while without body=")?)?;
        let mut state: Vec<Val> = args.into_iter().map(ensure_owned).collect();
        loop {
            let cond_params: Vec<Option<Val>> =
                state.iter().map(|v| Some(v.clone())).collect();
            let out = self.eval_comp(cond, cond_params)?;
            let go = match out.first() {
                Some((v, _)) => *v.as_pred()?.first().context("empty while condition")?,
                None => bail!("while condition produced no value"),
            };
            if !go {
                return Ok(state);
            }
            let body_params: Vec<Option<Val>> = state.into_iter().map(Some).collect();
            let outs = self.eval_comp(body, body_params)?;
            state = outs.into_iter().map(|(v, _)| ensure_owned(v)).collect();
        }
    }

    fn comp_index(&self, name: &str) -> Result<usize> {
        self.module
            .computations
            .iter()
            .position(|c| c.name == name)
            .with_context(|| {
                format!("no computation '{name}' in module '{}'", self.module.name)
            })
    }

    /// `sort` along one axis; the comparator's compare direction keys the
    /// order (GT/GE descending, LT/LE ascending — the verifier admits
    /// only ordered comparators over the two parameters).  Matches
    /// `np.sort` / flipped `np.sort` on the fixture value domain.
    fn sort(&self, mut args: Vec<Val>, owned: &[bool], ins: &Instr) -> Result<Val> {
        let name = ins.to_apply.as_deref().context("sort without to_apply")?;
        let cmpc = self.module.computation(name)?;
        let dir = cmpc.instrs[cmpc.root]
            .direction
            .context("sort comparator without direction")?;
        let descending = matches!(dir, CmpDir::Gt | CmpDir::Ge);
        let axis = ins.dims.first().copied().context("sort without dimensions=")?;
        let a = args.remove_first()?;
        let (dims, mut v) = a.into_f32_owned(owned.first().copied().unwrap_or(false))?;
        if axis >= dims.len() {
            bail!("sort dimension out of range");
        }
        let st = strides(&dims);
        let axis_len = dims[axis];
        let stride = st[axis];
        if stride == 1 {
            for lane in v.chunks_mut(axis_len.max(1)) {
                lane.sort_unstable_by(f32::total_cmp);
                if descending {
                    lane.reverse();
                }
            }
        } else {
            let mut lane = vec![0f32; axis_len];
            let mut lane_dims = dims.clone();
            lane_dims[axis] = 1;
            let mut it = Stepper::new(&lane_dims, &st);
            while let Some(base) = it.next() {
                for (t, l) in lane.iter_mut().enumerate() {
                    *l = v[base + t * stride];
                }
                lane.sort_unstable_by(f32::total_cmp);
                if descending {
                    lane.reverse();
                }
                for (t, &l) in lane.iter().enumerate() {
                    v[base + t * stride] = l;
                }
            }
        }
        Ok(Val::f32(dims, v))
    }

    /// XLA `scatter` (the jax embedding-gradient lowering plus add/max/min
    /// combiners).  Start coordinates are clamped to the operand domain
    /// per element, mirroring `fixturegen/hlo_eval.py::_scatter` exactly.
    /// The operand is the in-place candidate — the embedding-grad call
    /// accumulates straight into the consumed zeros buffer.
    fn scatter(&self, mut args: Vec<Val>, owned: &[bool], ins: &Instr) -> Result<Val> {
        let sd = ins.scatter.as_ref().context("scatter without dimension numbers")?;
        let kind = self
            .module
            .reduce_kind(ins.to_apply.as_deref().context("scatter without to_apply")?)?;
        if args.len() != 3 {
            bail!("scatter expects operand, indices, updates");
        }
        let updates = args.pop().context("scatter missing updates")?;
        let indices = args.pop().context("scatter missing indices")?;
        let operand = args.pop().context("scatter missing operand")?;
        let orank = operand.dims.len();
        let urank = updates.dims.len();
        let window_operand_dims: Vec<usize> =
            (0..orank).filter(|d| !sd.inserted_window_dims.contains(d)).collect();
        let update_batch_axes: Vec<usize> =
            (0..urank).filter(|a| !sd.update_window_dims.contains(a)).collect();
        let idx = indices.as_s32()?;
        let istrides = strides(&indices.dims);
        let irank = indices.dims.len();
        let upd_dims = updates.dims.clone();
        let ustrides = strides(&upd_dims);
        let upd = updates.as_f32()?;
        let (odims, mut out) =
            operand.into_f32_owned(owned.first().copied().unwrap_or(false))?;
        let ostrides = strides(&odims);
        let ivd = sd.index_vector_dim;
        let mut ucoord = vec![0usize; urank];
        let mut start = vec![0usize; orank];
        for (lin, &uval) in upd.iter().enumerate() {
            for (a2, c) in ucoord.iter_mut().enumerate() {
                *c = (lin / ustrides[a2]) % upd_dims[a2];
            }
            start.fill(0);
            for (c, &od) in sd.scatter_dims_to_operand_dims.iter().enumerate() {
                // flat offset of this element's index row: batch coords
                // with the component axis spliced in at index_vector_dim
                let mut flat = 0usize;
                let mut b = 0usize;
                for (ax, &istr) in istrides.iter().enumerate().take(irank) {
                    let coord = if ax == ivd {
                        c
                    } else {
                        let v = ucoord[update_batch_axes[b]];
                        b += 1;
                        v
                    };
                    flat += coord * istr;
                }
                let raw = idx[flat];
                let hi = odims[od].saturating_sub(1);
                start[od] = raw.max(0).min(hi as i32) as usize;
            }
            for (&w_axis, &op_dim) in
                sd.update_window_dims.iter().zip(&window_operand_dims)
            {
                start[op_dim] += ucoord[w_axis];
            }
            let mut dst = 0usize;
            for (d2, &s) in start.iter().enumerate() {
                if s >= odims[d2] {
                    bail!("scatter write out of bounds (dim {d2})");
                }
                dst += s * ostrides[d2];
            }
            out[dst] = match kind {
                ReduceKind::Add => out[dst] + uval,
                ReduceKind::Max => out[dst].max(uval),
                ReduceKind::Min => out[dst].min(uval),
            };
        }
        Ok(Val::f32(odims, out))
    }

    /// Execute a fused elementwise chain in one blocked pass.  The carried
    /// buffer is acquired once (in place when the plan owns it) and every
    /// chain op is applied block by block, so chain intermediates never
    /// materialize and the working set stays cache-resident.  Per element
    /// the applied functions are *exactly* the ones [`binary`]/[`unary`]/
    /// [`select`] use, so fused results are bit-identical to stepwise.
    fn exec_fused(
        &self,
        comp: &Computation,
        plan: &CompPlan,
        chain: &[usize],
        slots: &mut [Option<SlotVal>],
    ) -> Result<Val> {
        let mut exts: Vec<Val> = Vec::new();
        let mut steps: Vec<FusedStep> = Vec::with_capacity(chain.len());
        let mut carried: Option<(Val, bool)> = None;
        for (k, &i) in chain.iter().enumerate() {
            let ins = &comp.instrs[i];
            let kind = fused_fn(ins).context("non-fusible op in fused chain (compiler bug)")?;
            let prev = if k == 0 { usize::MAX } else { chain[k - 1] };
            match kind {
                FusedKind::Un(f) => {
                    let op = *ins.operands.first().context("unary without operand")?;
                    if k == 0 {
                        carried = Some(grab(plan, ins, i, op, slots)?);
                    } else if op != prev {
                        bail!("fused unary link mismatch");
                    }
                    steps.push(FusedStep::Un(f));
                }
                FusedKind::Bin(f) => {
                    let (a, b) = match (ins.operands.first(), ins.operands.get(1)) {
                        (Some(&a), Some(&b)) => (a, b),
                        _ => bail!("binary op missing operands"),
                    };
                    if k == 0 {
                        carried = Some(grab(plan, ins, i, a, slots)?);
                        exts.push(grab(plan, ins, i, b, slots)?.0);
                        steps.push(FusedStep::BinL(f, exts.len() - 1));
                    } else if a == prev {
                        exts.push(grab(plan, ins, i, b, slots)?.0);
                        steps.push(FusedStep::BinL(f, exts.len() - 1));
                    } else if b == prev {
                        exts.push(grab(plan, ins, i, a, slots)?.0);
                        steps.push(FusedStep::BinR(f, exts.len() - 1));
                    } else {
                        bail!("fused binary link mismatch");
                    }
                }
                FusedKind::Select => {
                    let (p, t, fo) = match (
                        ins.operands.first(),
                        ins.operands.get(1),
                        ins.operands.get(2),
                    ) {
                        (Some(&p), Some(&t), Some(&fo)) => (p, t, fo),
                        _ => bail!("select missing operands"),
                    };
                    if k == 0 || t == prev {
                        if k == 0 {
                            carried = Some(grab(plan, ins, i, t, slots)?);
                        }
                        exts.push(grab(plan, ins, i, p, slots)?.0);
                        let pe = exts.len() - 1;
                        exts.push(grab(plan, ins, i, fo, slots)?.0);
                        steps.push(FusedStep::SelT(pe, exts.len() - 1));
                    } else if fo == prev {
                        exts.push(grab(plan, ins, i, p, slots)?.0);
                        let pe = exts.len() - 1;
                        exts.push(grab(plan, ins, i, t, slots)?.0);
                        steps.push(FusedStep::SelF(pe, exts.len() - 1));
                    } else {
                        bail!("fused select link mismatch");
                    }
                }
            }
        }
        let (head, head_owned) = carried.context("fused chain has no head value")?;
        let (dims, mut buf) = head.into_f32_owned(head_owned)?;
        let n = buf.len();
        if exts.iter().any(|e| e.len() != n) {
            bail!("fused chain operand length mismatch");
        }
        const BLOCK: usize = 1024;
        let mut at = 0usize;
        while at < n {
            let end = (at + BLOCK).min(n);
            for step in &steps {
                match step {
                    FusedStep::Un(f) => {
                        for x in &mut buf[at..end] {
                            *x = f(*x);
                        }
                    }
                    FusedStep::BinL(f, e) => {
                        let ext = exts[*e].as_f32()?;
                        for (x, &y) in buf[at..end].iter_mut().zip(&ext[at..end]) {
                            *x = f(*x, y);
                        }
                    }
                    FusedStep::BinR(f, e) => {
                        let ext = exts[*e].as_f32()?;
                        for (x, &y) in buf[at..end].iter_mut().zip(&ext[at..end]) {
                            *x = f(y, *x);
                        }
                    }
                    FusedStep::SelT(pe, fe) => {
                        let pv = exts[*pe].as_pred()?;
                        let fv = exts[*fe].as_f32()?;
                        for ((x, &pi), &fi) in
                            buf[at..end].iter_mut().zip(&pv[at..end]).zip(&fv[at..end])
                        {
                            if !pi {
                                *x = fi;
                            }
                        }
                    }
                    FusedStep::SelF(pe, te) => {
                        let pv = exts[*pe].as_pred()?;
                        let tv = exts[*te].as_f32()?;
                        for ((x, &pi), &ti) in
                            buf[at..end].iter_mut().zip(&pv[at..end]).zip(&tv[at..end])
                        {
                            if pi {
                                *x = ti;
                            }
                        }
                    }
                }
            }
            at = end;
        }
        Ok(Val::f32(dims, buf))
    }
}

// ---------------------------------------------------------------------------
// Parse-time fusion of elementwise chains
// ---------------------------------------------------------------------------

/// How a fusible opcode combines the carried value with its externals.
enum FusedKind {
    Un(fn(f32) -> f32),
    Bin(fn(f32, f32) -> f32),
    Select,
}

/// One compiled chain link: the op plus indices into the chain's gathered
/// external-operand list (`BinR` = carried value is the *rhs*).
#[derive(Debug, Clone, Copy)]
enum FusedStep {
    Un(fn(f32) -> f32),
    BinL(fn(f32, f32) -> f32, usize),
    BinR(fn(f32, f32) -> f32, usize),
    /// carried value is the on-true branch: (pred ext, on-false ext)
    SelT(usize, usize),
    /// carried value is the on-false branch: (pred ext, on-true ext)
    SelF(usize, usize),
}

/// The per-element functions MUST match the [`binary`]/[`unary`] tables
/// exactly — fused and stepwise execution are asserted bit-identical.
fn fused_fn(ins: &Instr) -> Option<FusedKind> {
    Some(match ins.opcode.as_str() {
        "add" => FusedKind::Bin(|x, y| x + y),
        "subtract" => FusedKind::Bin(|x, y| x - y),
        "multiply" => FusedKind::Bin(|x, y| x * y),
        "divide" => FusedKind::Bin(|x, y| x / y),
        "maximum" => FusedKind::Bin(f32::max),
        "minimum" => FusedKind::Bin(f32::min),
        "power" => FusedKind::Bin(f32::powf),
        "negate" => FusedKind::Un(|x| -x),
        "abs" => FusedKind::Un(f32::abs),
        "exponential" => FusedKind::Un(f32::exp),
        "log" => FusedKind::Un(f32::ln),
        "tanh" => FusedKind::Un(f32::tanh),
        "rsqrt" => FusedKind::Un(|x| 1.0 / x.sqrt()),
        "sqrt" => FusedKind::Un(f32::sqrt),
        "sine" => FusedKind::Un(f32::sin),
        "cosine" => FusedKind::Un(f32::cos),
        "select" => FusedKind::Select,
        _ => return None,
    })
}

/// Fused-kernel schedule for one computation, compiled once at
/// [`Program::compile`] from the plan's fusible chains.
#[derive(Debug, Clone, Default)]
struct CompFused {
    /// Chain-interior instructions: skipped by the interpreter loop, their
    /// values exist only inside the fused kernel's blocked pass.
    interior: Vec<bool>,
    /// Chain tail instruction index → the full chain (indices in order).
    tails: HashMap<usize, Vec<usize>>,
}

impl CompFused {
    /// Admit a planner chain only when every link is an f32 op with a
    /// fused implementation and every *interior* link has exactly one
    /// consumer in the whole computation — the planner's `takes`
    /// condition proves the successor is the *last* use, but an earlier
    /// instruction may also read the link, and that read needs the
    /// intermediate materialized.
    fn build(c: &Computation, plan: &CompPlan) -> CompFused {
        let n = c.instrs.len();
        let mut use_count = vec![0usize; n];
        for ins in &c.instrs {
            for &op in &ins.operands {
                use_count[op] += 1;
            }
        }
        let mut interior = vec![false; n];
        let mut tails = HashMap::new();
        'chains: for chain in &plan.fusible_chains {
            // The evaluator executes the root through its dedicated path
            // (tuple unpack / single-value return), which never consults
            // the fused schedule — a chain ending at the root must stay
            // stepwise so its interior values actually materialize.
            if chain.len() < 2 || chain.last() == Some(&c.root) {
                continue;
            }
            for (k, &i) in chain.iter().enumerate() {
                let ins = &c.instrs[i];
                if !matches!(ins.shape.as_ref().map(|s| s.dtype), Some(HDtype::F32)) {
                    continue 'chains;
                }
                if fused_fn(ins).is_none() {
                    continue 'chains;
                }
                if k + 1 < chain.len() && use_count[i] != 1 {
                    continue 'chains;
                }
            }
            for &i in &chain[..chain.len() - 1] {
                interior[i] = true;
            }
            if let Some(&tail) = chain.last() {
                tails.insert(tail, chain.clone());
            }
        }
        CompFused { interior, tails }
    }
}

// ---------------------------------------------------------------------------
// Slots and operand acquisition
// ---------------------------------------------------------------------------

/// What an instruction slot holds: one tensor value, or — for `while`
/// results — the flattened loop-state tuple.
#[derive(Debug, Clone)]
enum SlotVal {
    One(Val),
    Tuple(Vec<Val>),
}

impl SlotVal {
    fn into_val(self) -> Result<Val> {
        match self {
            SlotVal::One(v) => Ok(v),
            SlotVal::Tuple(_) => {
                bail!("tuple-shaped value used where a tensor is required")
            }
        }
    }
}

/// Acquire instruction `i`'s operand `op` from its slot: take at the
/// plan-computed last use (when `op` appears exactly once in `i`'s
/// operand list), clone otherwise.  The returned `bool` is the in-place
/// promise: taken *and* statically unique.
fn grab(
    plan: &CompPlan,
    ins: &Instr,
    i: usize,
    op: usize,
    slots: &mut [Option<SlotVal>],
) -> Result<(Val, bool)> {
    let take = plan.last_use[op] == i
        && ins.operands.iter().filter(|&&o| o == op).count() == 1;
    let v = if take { slots[op].take() } else { slots[op].clone() };
    let v = v.with_context(|| format!("operand #{op} missing"))?.into_val()?;
    Ok((v, take && plan.unique[op]))
}

/// `get-tuple-element`: move element `k` out of a taken tuple (the
/// common case — the plan pins the `while` slot to its last `gte`), or
/// clone the element's `Arc` handle from a shared one.
fn gte(
    plan: &CompPlan,
    idx: usize,
    ins: &Instr,
    slots: &mut [Option<SlotVal>],
) -> Result<Val> {
    let op = *ins.operands.first().context("get-tuple-element without operand")?;
    let k = ins.tuple_index.context("get-tuple-element without index=")?;
    let take = plan.last_use[op] == idx
        && ins.operands.iter().filter(|&&o| o == op).count() == 1;
    let v = if take { slots[op].take() } else { slots[op].clone() };
    match v.with_context(|| format!("operand #{op} missing"))? {
        SlotVal::Tuple(mut els) => {
            if k >= els.len() {
                bail!("tuple index {k} out of range ({} elements)", els.len());
            }
            Ok(els.swap_remove(k))
        }
        SlotVal::One(_) => bail!("get-tuple-element of a non-tuple value"),
    }
}

/// Make a value's buffer uniquely owned, deep-copying only when the
/// handle is shared.  Loop state crossing a `while` iteration boundary
/// goes through this so the body plan's uniqueness promises always hold
/// at runtime (weights that pass through untouched stay zero-copy).
fn ensure_owned(v: Val) -> Val {
    let Val { dims, data } = v;
    let data = match data {
        Data::F32(a) if Arc::strong_count(&a) > 1 => Data::F32(Arc::new(a.as_ref().clone())),
        Data::S32(a) if Arc::strong_count(&a) > 1 => Data::S32(Arc::new(a.as_ref().clone())),
        Data::U32(a) if Arc::strong_count(&a) > 1 => Data::U32(Arc::new(a.as_ref().clone())),
        Data::Pred(a) if Arc::strong_count(&a) > 1 => {
            Data::Pred(Arc::new(a.as_ref().clone()))
        }
        other => other,
    };
    Val { dims, data }
}

// ---------------------------------------------------------------------------
// Counter-based RNG ops (the fixture PRNG scheme)
// ---------------------------------------------------------------------------

/// `rng-bit-generator` over the fixture scheme: a scalar u32 counter
/// base, element `j` (row-major) drawing `hash_u32(base + j)`.  The state
/// advance is an explicit u32 add in the graph, not part of this op.
fn rng_bit_generator(mut args: Vec<Val>, shape: &HShape) -> Result<Val> {
    let base = args.pop().context("rng-bit-generator missing state operand")?;
    let b = match &base.data {
        Data::U32(v) => *v.first().context("rng-bit-generator empty state")?,
        _ => bail!("rng-bit-generator state must be u32"),
    };
    let n = shape.num_elements();
    let out: Vec<u32> = (0..n).map(|j| hash_u32(b.wrapping_add(j as u32))).collect();
    Ok(Val::u32(shape.dims.clone(), out))
}

/// Legacy `rng(distribution=rng_uniform)`: deterministic counter-based
/// uniform over `[lo, hi)` — element `j` hashes its own flat index (this
/// form carries no seed operand; the fixture goldens pin the stream).
fn rng_uniform(mut args: Vec<Val>, shape: &HShape, ins: &Instr) -> Result<Val> {
    if ins.distribution.as_deref() != Some("rng_uniform") {
        bail!("rng distribution {:?} unsupported", ins.distribution);
    }
    let hi = args.pop().context("rng missing upper bound")?;
    let lo = args.pop().context("rng missing lower bound")?;
    let lo = *lo.as_f32()?.first().context("rng lower bound empty")?;
    let hi = *hi.as_f32()?.first().context("rng upper bound empty")?;
    let n = shape.num_elements();
    let out: Vec<f32> = (0..n)
        .map(|j| {
            let u = ((hash_u32(j as u32) >> 8) as f32 + 0.5) * (1.0 / 16777216.0);
            lo + u * (hi - lo)
        })
        .collect();
    Ok(Val::f32(shape.dims.clone(), out))
}

// ---------------------------------------------------------------------------
// Values
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub enum Data {
    F32(Arc<Vec<f32>>),
    S32(Arc<Vec<i32>>),
    U32(Arc<Vec<u32>>),
    Pred(Arc<Vec<bool>>),
}

#[derive(Debug, Clone)]
pub struct Val {
    pub dims: Vec<usize>,
    pub data: Data,
}

trait ValVec {
    fn remove_first(&mut self) -> Result<Val>;
}

impl ValVec for Vec<Val> {
    fn remove_first(&mut self) -> Result<Val> {
        if self.is_empty() {
            bail!("missing operand");
        }
        Ok(self.remove(0))
    }
}

impl Val {
    pub fn f32(dims: Vec<usize>, v: Vec<f32>) -> Val {
        Val { dims, data: Data::F32(Arc::new(v)) }
    }

    pub fn s32(dims: Vec<usize>, v: Vec<i32>) -> Val {
        Val { dims, data: Data::S32(Arc::new(v)) }
    }

    pub fn u32(dims: Vec<usize>, v: Vec<u32>) -> Val {
        Val { dims, data: Data::U32(Arc::new(v)) }
    }

    pub fn pred(dims: Vec<usize>, v: Vec<bool>) -> Val {
        Val { dims, data: Data::Pred(Arc::new(v)) }
    }

    pub fn len(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> HDtype {
        match &self.data {
            Data::F32(_) => HDtype::F32,
            Data::S32(_) => HDtype::S32,
            Data::U32(_) => HDtype::U32,
            Data::Pred(_) => HDtype::Pred,
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            Data::F32(v) => Ok(v),
            other => bail!("expected f32 value, got {:?}", dtype_of(other)),
        }
    }

    pub fn as_s32(&self) -> Result<&[i32]> {
        match &self.data {
            Data::S32(v) => Ok(v),
            other => bail!("expected s32 value, got {:?}", dtype_of(other)),
        }
    }

    pub fn as_pred(&self) -> Result<&[bool]> {
        match &self.data {
            Data::Pred(v) => Ok(v),
            other => bail!("expected pred value, got {:?}", dtype_of(other)),
        }
    }

    /// f32 buffer for in-place mutation.  `owned` is the static plan's
    /// promise that this handle is the only one — then the unwrap must
    /// succeed, and failure is a planner bug reported loudly.  Without the
    /// promise the buffer is copied (never a guessed `try_unwrap`).
    fn into_f32_owned(self, owned: bool) -> Result<(Vec<usize>, Vec<f32>)> {
        match self.data {
            Data::F32(a) => {
                let v = if owned {
                    Arc::try_unwrap(a).map_err(|_| {
                        anyhow::anyhow!(
                            "static plan marked this buffer unique but it is shared \
                             (planner bug)"
                        )
                    })?
                } else {
                    a.as_ref().clone()
                };
                Ok((self.dims, v))
            }
            other => bail!("expected f32 value, got {:?}", dtype_of(&other)),
        }
    }

    fn from_tensor(t: &Tensor) -> Val {
        match &t.data {
            TensorData::F32(v) => Val::f32(t.shape.clone(), v.clone()),
            TensorData::I32(v) => Val::s32(t.shape.clone(), v.clone()),
            TensorData::U32(v) => Val::u32(t.shape.clone(), v.clone()),
        }
    }

    /// Hand the buffer to a host tensor.  `owned` (from the static plan)
    /// moves the buffer without a copy and treats a shared `Arc` as a
    /// planner bug; `!owned` copies.
    fn into_tensor(self, owned: bool) -> Result<Tensor> {
        let dims = self.dims;
        macro_rules! unwrap_buf {
            ($a:expr) => {
                if owned {
                    Arc::try_unwrap($a).map_err(|_| {
                        anyhow::anyhow!(
                            "static plan marked this output buffer unique but it \
                             is shared (planner bug)"
                        )
                    })?
                } else {
                    $a.as_ref().clone()
                }
            };
        }
        Ok(match self.data {
            Data::F32(a) => Tensor::f32(dims, unwrap_buf!(a)),
            Data::S32(a) => Tensor::i32(dims, unwrap_buf!(a)),
            Data::U32(a) => Tensor::u32(dims, unwrap_buf!(a)),
            Data::Pred(_) => bail!("pred values cannot cross the engine boundary"),
        })
    }

    fn from_literal(lit: &Literal, dims: &[usize]) -> Result<Val> {
        let n: usize = dims.iter().product();
        let check = |len: usize| -> Result<()> {
            if len != n {
                bail!("literal has {len} elements, shape needs {n}");
            }
            Ok(())
        };
        Ok(match lit {
            Literal::F32(v) => {
                check(v.len())?;
                Val::f32(dims.to_vec(), v.clone())
            }
            Literal::S32(v) => {
                check(v.len())?;
                Val::s32(dims.to_vec(), v.clone())
            }
            Literal::U32(v) => {
                check(v.len())?;
                Val::u32(dims.to_vec(), v.clone())
            }
            Literal::Pred(v) => {
                check(v.len())?;
                Val::pred(dims.to_vec(), v.clone())
            }
        })
    }
}

fn dtype_of(d: &Data) -> HDtype {
    match d {
        Data::F32(_) => HDtype::F32,
        Data::S32(_) => HDtype::S32,
        Data::U32(_) => HDtype::U32,
        Data::Pred(_) => HDtype::Pred,
    }
}

// ---------------------------------------------------------------------------
// Index helpers
// ---------------------------------------------------------------------------

/// Row-major strides.
pub fn strides(dims: &[usize]) -> Vec<usize> {
    let mut s = vec![1usize; dims.len()];
    for i in (0..dims.len().saturating_sub(1)).rev() {
        s[i] = s[i + 1] * dims[i + 1];
    }
    s
}

/// Iterate `dims` in row-major order, tracking a source offset through
/// arbitrary per-axis strides (0 for broadcast axes).  O(1) amortized per
/// element.
struct Stepper<'a> {
    dims: &'a [usize],
    strides: &'a [usize],
    counters: Vec<usize>,
    offset: usize,
    done: bool,
}

impl<'a> Stepper<'a> {
    fn new(dims: &'a [usize], strides: &'a [usize]) -> Stepper<'a> {
        Stepper {
            dims,
            strides,
            counters: vec![0; dims.len()],
            offset: 0,
            done: dims.iter().any(|&d| d == 0),
        }
    }

    #[inline]
    fn next(&mut self) -> Option<usize> {
        if self.done {
            return None;
        }
        let cur = self.offset;
        // increment (row-major: last axis fastest)
        let mut axis = self.dims.len();
        loop {
            if axis == 0 {
                self.done = true;
                break;
            }
            axis -= 1;
            self.counters[axis] += 1;
            self.offset += self.strides[axis];
            if self.counters[axis] < self.dims[axis] {
                break;
            }
            self.counters[axis] = 0;
            self.offset -= self.strides[axis] * self.dims[axis];
        }
        Some(cur)
    }
}

// ---------------------------------------------------------------------------
// Elementwise ops
// ---------------------------------------------------------------------------

#[derive(Clone, Copy)]
enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
    Max,
    Min,
    Pow,
    And,
    Or,
    Xor,
    Shl,
    Shr,
}

fn binary(mut args: Vec<Val>, owned: &[bool], op: BinOp) -> Result<Val> {
    let b = args.pop().context("binary op missing rhs")?;
    let a = args.pop().context("binary op missing lhs")?;
    if a.dims != b.dims {
        bail!("elementwise shape mismatch {:?} vs {:?}", a.dims, b.dims);
    }
    match (&a.data, &b.data) {
        (Data::F32(_), Data::F32(_)) => {
            let f: fn(f32, f32) -> f32 = match op {
                BinOp::Add => |x, y| x + y,
                BinOp::Sub => |x, y| x - y,
                BinOp::Mul => |x, y| x * y,
                BinOp::Div => |x, y| x / y,
                BinOp::Max => f32::max,
                BinOp::Min => f32::min,
                BinOp::Pow => f32::powf,
                _ => bail!("bitwise op on f32"),
            };
            // mutate the lhs buffer in place when the plan owns it (hot path)
            let (dims, mut x) = a.into_f32_owned(owned.first().copied().unwrap_or(false))?;
            let rhs = b.as_f32()?;
            for (xi, &yi) in x.iter_mut().zip(rhs.iter()) {
                *xi = f(*xi, yi);
            }
            Ok(Val::f32(dims, x))
        }
        (Data::S32(xa), Data::S32(xb)) => {
            let out: Vec<i32> = xa
                .iter()
                .zip(xb.iter())
                .map(|(&x, &y)| match op {
                    BinOp::Add => x.wrapping_add(y),
                    BinOp::Sub => x.wrapping_sub(y),
                    BinOp::Mul => x.wrapping_mul(y),
                    BinOp::Max => x.max(y),
                    BinOp::Min => x.min(y),
                    _ => 0,
                })
                .collect();
            match op {
                BinOp::Add | BinOp::Sub | BinOp::Mul | BinOp::Max | BinOp::Min => {
                    Ok(Val::s32(a.dims.clone(), out))
                }
                _ => bail!("unsupported s32 binary op"),
            }
        }
        (Data::U32(xa), Data::U32(xb)) => {
            let out: Result<Vec<u32>> = xa
                .iter()
                .zip(xb.iter())
                .map(|(&x, &y)| {
                    Ok(match op {
                        BinOp::Add => x.wrapping_add(y),
                        BinOp::Sub => x.wrapping_sub(y),
                        BinOp::Mul => x.wrapping_mul(y),
                        BinOp::Max => x.max(y),
                        BinOp::Min => x.min(y),
                        BinOp::And => x & y,
                        BinOp::Or => x | y,
                        BinOp::Xor => x ^ y,
                        BinOp::Shl => x.wrapping_shl(y),
                        BinOp::Shr => x.wrapping_shr(y),
                        _ => bail!("unsupported u32 binary op"),
                    })
                })
                .collect();
            Ok(Val::u32(a.dims.clone(), out?))
        }
        (Data::Pred(xa), Data::Pred(xb)) => {
            let out: Result<Vec<bool>> = xa
                .iter()
                .zip(xb.iter())
                .map(|(&x, &y)| {
                    Ok(match op {
                        BinOp::And => x && y,
                        BinOp::Or => x || y,
                        BinOp::Xor => x ^ y,
                        _ => bail!("unsupported pred binary op"),
                    })
                })
                .collect();
            Ok(Val::pred(a.dims.clone(), out?))
        }
        _ => bail!("binary op dtype mismatch {:?} vs {:?}", a.dtype(), b.dtype()),
    }
}

#[derive(Clone, Copy)]
enum UnOp {
    Neg,
    Abs,
    Exp,
    Log,
    Tanh,
    Rsqrt,
    Sqrt,
    Sin,
    Cos,
    Not,
}

fn unary(mut args: Vec<Val>, owned: &[bool], op: UnOp) -> Result<Val> {
    let a = args.remove_first()?;
    match (&a.data, op) {
        (Data::Pred(p), UnOp::Not) => {
            let out: Vec<bool> = p.iter().map(|&x| !x).collect();
            Ok(Val::pred(a.dims.clone(), out))
        }
        (Data::U32(p), UnOp::Not) => {
            let out: Vec<u32> = p.iter().map(|&x| !x).collect();
            Ok(Val::u32(a.dims.clone(), out))
        }
        (Data::S32(p), UnOp::Neg) => {
            let out: Vec<i32> = p.iter().map(|&x| x.wrapping_neg()).collect();
            Ok(Val::s32(a.dims.clone(), out))
        }
        (Data::S32(p), UnOp::Abs) => {
            let out: Vec<i32> = p.iter().map(|&x| x.wrapping_abs()).collect();
            Ok(Val::s32(a.dims.clone(), out))
        }
        (Data::F32(_), _) => {
            let f: fn(f32) -> f32 = match op {
                UnOp::Neg => |x| -x,
                UnOp::Abs => f32::abs,
                UnOp::Exp => f32::exp,
                UnOp::Log => f32::ln,
                UnOp::Tanh => f32::tanh,
                UnOp::Rsqrt => |x| 1.0 / x.sqrt(),
                UnOp::Sqrt => f32::sqrt,
                UnOp::Sin => f32::sin,
                UnOp::Cos => f32::cos,
                UnOp::Not => return Err(anyhow::anyhow!("'not' on f32")),
            };
            let (dims, mut x) = a.into_f32_owned(owned.first().copied().unwrap_or(false))?;
            for xi in x.iter_mut() {
                *xi = f(*xi);
            }
            Ok(Val::f32(dims, x))
        }
        _ => bail!("unsupported unary op on {:?}", a.dtype()),
    }
}

fn compare(mut args: Vec<Val>, dir: CmpDir) -> Result<Val> {
    let b = args.pop().context("compare missing rhs")?;
    let a = args.pop().context("compare missing lhs")?;
    if a.dims != b.dims {
        bail!("compare shape mismatch {:?} vs {:?}", a.dims, b.dims);
    }
    macro_rules! cmp {
        ($xa:expr, $xb:expr) => {
            $xa.iter()
                .zip($xb.iter())
                .map(|(x, y)| match dir {
                    CmpDir::Eq => x == y,
                    CmpDir::Ne => x != y,
                    CmpDir::Lt => x < y,
                    CmpDir::Le => x <= y,
                    CmpDir::Gt => x > y,
                    CmpDir::Ge => x >= y,
                })
                .collect::<Vec<bool>>()
        };
    }
    let out = match (&a.data, &b.data) {
        (Data::F32(xa), Data::F32(xb)) => cmp!(xa, xb),
        (Data::S32(xa), Data::S32(xb)) => cmp!(xa, xb),
        (Data::U32(xa), Data::U32(xb)) => cmp!(xa, xb),
        _ => bail!("compare dtype mismatch"),
    };
    Ok(Val::pred(a.dims.clone(), out))
}

fn select(mut args: Vec<Val>, owned: &[bool]) -> Result<Val> {
    let b = args.pop().context("select missing on-false")?;
    let a = args.pop().context("select missing on-true")?;
    let p = args.pop().context("select missing predicate")?;
    if p.dims != a.dims || a.dims != b.dims {
        bail!("select shape mismatch");
    }
    let pv = p.as_pred()?;
    match (&a.data, &b.data) {
        (Data::F32(_), Data::F32(_)) => {
            // the on-true branch (operand #1) is the in-place candidate
            let (dims, mut x) = a.into_f32_owned(owned.get(1).copied().unwrap_or(false))?;
            let on_false = b.as_f32()?;
            for ((xi, &fi), &pi) in x.iter_mut().zip(on_false.iter()).zip(pv.iter()) {
                if !pi {
                    *xi = fi;
                }
            }
            Ok(Val::f32(dims, x))
        }
        (Data::S32(xa), Data::S32(xb)) => {
            let out: Vec<i32> = pv
                .iter()
                .zip(xa.iter().zip(xb.iter()))
                .map(|(&p, (&x, &y))| if p { x } else { y })
                .collect();
            Ok(Val::s32(a.dims.clone(), out))
        }
        (Data::U32(xa), Data::U32(xb)) => {
            let out: Vec<u32> = pv
                .iter()
                .zip(xa.iter().zip(xb.iter()))
                .map(|(&p, (&x, &y))| if p { x } else { y })
                .collect();
            Ok(Val::u32(a.dims.clone(), out))
        }
        _ => bail!("select dtype mismatch"),
    }
}

fn convert(mut args: Vec<Val>, to: HDtype) -> Result<Val> {
    let a = args.remove_first()?;
    if a.dtype() == to {
        return Ok(a); // zero-copy
    }
    let dims = a.dims.clone();
    macro_rules! conv {
        ($src:expr, $f:expr) => {
            $src.iter().map($f).collect()
        };
    }
    Ok(match (&a.data, to) {
        (Data::Pred(v), HDtype::F32) => Val::f32(dims, conv!(v, |&x| if x { 1.0 } else { 0.0 })),
        (Data::Pred(v), HDtype::S32) => Val::s32(dims, conv!(v, |&x| x as i32)),
        (Data::Pred(v), HDtype::U32) => Val::u32(dims, conv!(v, |&x| x as u32)),
        (Data::S32(v), HDtype::F32) => Val::f32(dims, conv!(v, |&x| x as f32)),
        (Data::U32(v), HDtype::F32) => Val::f32(dims, conv!(v, |&x| x as f32)),
        (Data::S32(v), HDtype::U32) => Val::u32(dims, conv!(v, |&x| x as u32)),
        (Data::U32(v), HDtype::S32) => Val::s32(dims, conv!(v, |&x| x as i32)),
        (Data::F32(v), HDtype::S32) => Val::s32(dims, conv!(v, |&x| x as i32)),
        (Data::F32(v), HDtype::U32) => Val::u32(dims, conv!(v, |&x| x as u32)),
        (src, to) => bail!("unsupported convert {:?} -> {:?}", dtype_of(src), to),
    })
}

// ---------------------------------------------------------------------------
// Shape ops
// ---------------------------------------------------------------------------

fn broadcast(mut args: Vec<Val>, dims_map: &[usize], out_dims: &[usize]) -> Result<Val> {
    let a = args.remove_first()?;
    if dims_map.len() != a.dims.len() {
        bail!(
            "broadcast dims {:?} rank-mismatch input {:?}",
            dims_map,
            a.dims
        );
    }
    for (i, &d) in dims_map.iter().enumerate() {
        if out_dims[d] != a.dims[i] {
            bail!("broadcast dim {i} size mismatch");
        }
    }
    // per-output-axis source strides (0 on new axes)
    let in_strides = strides(&a.dims);
    let mut map_strides = vec![0usize; out_dims.len()];
    for (i, &d) in dims_map.iter().enumerate() {
        map_strides[d] = in_strides[i];
    }
    let n: usize = out_dims.iter().product();
    macro_rules! bc {
        ($src:expr, $mk:path) => {{
            let mut out = Vec::with_capacity(n);
            let mut st = Stepper::new(out_dims, &map_strides);
            while let Some(off) = st.next() {
                out.push($src[off]);
            }
            $mk(out_dims.to_vec(), out)
        }};
    }
    Ok(match &a.data {
        Data::F32(v) => bc!(v, Val::f32),
        Data::S32(v) => bc!(v, Val::s32),
        Data::U32(v) => bc!(v, Val::u32),
        Data::Pred(v) => bc!(v, Val::pred),
    })
}

fn transpose(mut args: Vec<Val>, perm: &[usize]) -> Result<Val> {
    let a = args.remove_first()?;
    if perm.len() != a.dims.len() {
        bail!("transpose perm rank mismatch");
    }
    let out_dims: Vec<usize> = perm.iter().map(|&p| a.dims[p]).collect();
    let in_strides = strides(&a.dims);
    let map_strides: Vec<usize> = perm.iter().map(|&p| in_strides[p]).collect();
    let n = a.len();
    macro_rules! tr {
        ($src:expr, $mk:path) => {{
            let mut out = Vec::with_capacity(n);
            let mut st = Stepper::new(&out_dims, &map_strides);
            while let Some(off) = st.next() {
                out.push($src[off]);
            }
            $mk(out_dims.clone(), out)
        }};
    }
    Ok(match &a.data {
        Data::F32(v) => tr!(v, Val::f32),
        Data::S32(v) => tr!(v, Val::s32),
        Data::U32(v) => tr!(v, Val::u32),
        Data::Pred(v) => tr!(v, Val::pred),
    })
}

fn slice_op(mut args: Vec<Val>, spec: &[(usize, usize, usize)]) -> Result<Val> {
    let a = args.remove_first()?;
    if spec.len() != a.dims.len() {
        bail!("slice spec rank mismatch");
    }
    let out_dims: Vec<usize> = spec
        .iter()
        .map(|&(s, l, st)| {
            if st == 0 {
                bail!("slice stride 0");
            }
            Ok((l.saturating_sub(s) + st - 1) / st)
        })
        .collect::<Result<_>>()?;
    let in_strides = strides(&a.dims);
    let base: usize = spec
        .iter()
        .zip(&in_strides)
        .map(|(&(s, _, _), &str_)| s * str_)
        .sum();
    let map_strides: Vec<usize> = spec
        .iter()
        .zip(&in_strides)
        .map(|(&(_, _, st), &str_)| st * str_)
        .collect();
    let n: usize = out_dims.iter().product();
    macro_rules! sl {
        ($src:expr, $mk:path) => {{
            let mut out = Vec::with_capacity(n);
            let mut st = Stepper::new(&out_dims, &map_strides);
            while let Some(off) = st.next() {
                out.push($src[base + off]);
            }
            $mk(out_dims.clone(), out)
        }};
    }
    Ok(match &a.data {
        Data::F32(v) => sl!(v, Val::f32),
        Data::S32(v) => sl!(v, Val::s32),
        Data::U32(v) => sl!(v, Val::u32),
        Data::Pred(v) => sl!(v, Val::pred),
    })
}

fn concat(args: Vec<Val>, dim: usize) -> Result<Val> {
    if args.is_empty() {
        bail!("concatenate with no operands");
    }
    let rank = args[0].dims.len();
    if dim >= rank {
        bail!("concatenate dim out of range");
    }
    let mut out_dims = args[0].dims.clone();
    out_dims[dim] = args.iter().map(|a| a.dims[dim]).sum();
    for a in &args {
        for (i, (&x, &y)) in a.dims.iter().zip(&out_dims).enumerate() {
            if i != dim && x != y {
                bail!("concatenate shape mismatch off-axis");
            }
        }
    }
    let outer: usize = out_dims[..dim].iter().product();
    macro_rules! cc {
        ($variant:path, $mk:path, $t:ty) => {{
            let mut out: Vec<$t> = Vec::with_capacity(out_dims.iter().product());
            for o in 0..outer {
                for a in &args {
                    let chunk: usize = a.dims[dim..].iter().product();
                    let src = match &a.data {
                        $variant(v) => v,
                        _ => bail!("concatenate dtype mismatch"),
                    };
                    out.extend_from_slice(&src[o * chunk..(o + 1) * chunk]);
                }
            }
            $mk(out_dims.clone(), out)
        }};
    }
    Ok(match &args[0].data {
        Data::F32(_) => cc!(Data::F32, Val::f32, f32),
        Data::S32(_) => cc!(Data::S32, Val::s32, i32),
        Data::U32(_) => cc!(Data::U32, Val::u32, u32),
        Data::Pred(_) => cc!(Data::Pred, Val::pred, bool),
    })
}

fn pad(mut args: Vec<Val>, cfg: &[(i64, i64, i64)]) -> Result<Val> {
    let pad_val = args.pop().context("pad missing value")?;
    let a = args.pop().context("pad missing operand")?;
    if cfg.len() != a.dims.len() {
        bail!("pad spec rank mismatch");
    }
    if cfg.iter().any(|&(l, h, i)| l < 0 || h < 0 || i != 0) {
        bail!("negative/interior padding unsupported");
    }
    let out_dims: Vec<usize> = a
        .dims
        .iter()
        .zip(cfg)
        .map(|(&d, &(l, h, _))| d + l as usize + h as usize)
        .collect();
    let out_strides = strides(&out_dims);
    let base: usize = cfg
        .iter()
        .zip(&out_strides)
        .map(|(&(l, _, _), &s)| l as usize * s)
        .sum();
    let n: usize = out_dims.iter().product();
    macro_rules! pd {
        ($src:expr, $pv:expr, $mk:path) => {{
            let fill = $pv[0];
            let mut out = vec![fill; n];
            let mut st = Stepper::new(&a.dims, &out_strides);
            let mut i = 0usize;
            while let Some(off) = st.next() {
                out[base + off] = $src[i];
                i += 1;
            }
            $mk(out_dims.clone(), out)
        }};
    }
    Ok(match (&a.data, &pad_val.data) {
        (Data::F32(v), Data::F32(p)) => pd!(v, p, Val::f32),
        (Data::S32(v), Data::S32(p)) => pd!(v, p, Val::s32),
        (Data::U32(v), Data::U32(p)) => pd!(v, p, Val::u32),
        _ => bail!("pad dtype mismatch"),
    })
}

fn reduce(mut args: Vec<Val>, dims: &[usize], kind: ReduceKind) -> Result<Val> {
    let init = args.pop().context("reduce missing init")?;
    let a = args.pop().context("reduce missing operand")?;
    let reduce_set: Vec<bool> = (0..a.dims.len()).map(|i| dims.contains(&i)).collect();
    let out_dims: Vec<usize> = a
        .dims
        .iter()
        .enumerate()
        .filter(|(i, _)| !reduce_set[*i])
        .map(|(_, &d)| d)
        .collect();
    let out_strides_full = strides(&out_dims);
    // per-input-axis contribution to the output offset (0 on reduced axes)
    let mut map = vec![0usize; a.dims.len()];
    let mut k = 0;
    for i in 0..a.dims.len() {
        if !reduce_set[i] {
            map[i] = out_strides_full[k];
            k += 1;
        }
    }
    let n_out: usize = out_dims.iter().product();
    // Threaded f32 path: output-major, one out element per unit, with the
    // reduced coordinates visited in row-major axis order — for each out
    // element that is exactly the order the sequential input-major sweep
    // combines them in, so both paths are bit-identical for every thread
    // count.  Integer reduce stays sequential (wrapping adds are
    // order-insensitive anyway, and the hot reductions are f32).
    if pool::threads() > 1 && n_out > 0 {
        if let (Data::F32(v), Data::F32(iv)) = (&a.data, &init.data) {
            let ist = strides(&a.dims);
            let keep_strides: Vec<usize> = (0..a.dims.len())
                .filter(|&i| !reduce_set[i])
                .map(|i| ist[i])
                .collect();
            let red_dims: Vec<usize> = (0..a.dims.len())
                .filter(|&i| reduce_set[i])
                .map(|i| a.dims[i])
                .collect();
            let red_strides: Vec<usize> = (0..a.dims.len())
                .filter(|&i| reduce_set[i])
                .map(|i| ist[i])
                .collect();
            let comb: fn(f32, f32) -> f32 = match kind {
                ReduceKind::Add => |x, y| x + y,
                ReduceKind::Max => f32::max,
                ReduceKind::Min => f32::min,
            };
            let init0 = *iv.first().context("reduce init empty")?;
            let mut out = vec![init0; n_out];
            pool::run_parts(pool::threads(), &mut out, 1, |row0, part| {
                for (t, o) in part.iter_mut().enumerate() {
                    let oi = row0 + t;
                    let mut base = 0usize;
                    for (kk, &kd) in out_dims.iter().enumerate() {
                        base += ((oi / out_strides_full[kk]) % kd) * keep_strides[kk];
                    }
                    let mut acc = *o;
                    let mut st = Stepper::new(&red_dims, &red_strides);
                    while let Some(off) = st.next() {
                        acc = comb(acc, v[base + off]);
                    }
                    *o = acc;
                }
            });
            return Ok(Val::f32(out_dims, out));
        }
    }
    macro_rules! red {
        ($src:expr, $iv:expr, $mk:path, $t:ty, $comb:expr) => {{
            let comb: fn($t, $t) -> $t = $comb;
            let mut out = vec![$iv[0]; n_out];
            let mut st = Stepper::new(&a.dims, &map);
            let mut i = 0usize;
            while let Some(off) = st.next() {
                out[off] = comb(out[off], $src[i]);
                i += 1;
            }
            $mk(out_dims.clone(), out)
        }};
    }
    Ok(match (&a.data, &init.data) {
        (Data::F32(v), Data::F32(iv)) => match kind {
            ReduceKind::Add => red!(v, iv, Val::f32, f32, |x, y| x + y),
            ReduceKind::Max => red!(v, iv, Val::f32, f32, f32::max),
            ReduceKind::Min => red!(v, iv, Val::f32, f32, f32::min),
        },
        (Data::S32(v), Data::S32(iv)) => match kind {
            ReduceKind::Add => red!(v, iv, Val::s32, i32, |x, y| x.wrapping_add(y)),
            ReduceKind::Max => red!(v, iv, Val::s32, i32, i32::max),
            ReduceKind::Min => red!(v, iv, Val::s32, i32, i32::min),
        },
        (Data::U32(v), Data::U32(iv)) => match kind {
            ReduceKind::Add => red!(v, iv, Val::u32, u32, |x, y| x.wrapping_add(y)),
            ReduceKind::Max => red!(v, iv, Val::u32, u32, u32::max),
            ReduceKind::Min => red!(v, iv, Val::u32, u32, u32::min),
        },
        _ => bail!("reduce dtype mismatch"),
    })
}

fn iota(shape: &HShape, dim: usize) -> Result<Val> {
    if dim >= shape.dims.len() {
        bail!("iota dimension out of range");
    }
    let dims = shape.dims.clone();
    let n = shape.num_elements();
    let st = strides(&dims);
    let size = dims[dim];
    let stride = st[dim];
    macro_rules! io {
        ($t:ty, $mk:path) => {{
            let mut out = vec![0 as $t; n];
            for (i, o) in out.iter_mut().enumerate() {
                *o = ((i / stride) % size) as $t;
            }
            $mk(dims.clone(), out)
        }};
    }
    Ok(match shape.dtype {
        HDtype::S32 => io!(i32, Val::s32),
        HDtype::U32 => io!(u32, Val::u32),
        HDtype::F32 => io!(f32, Val::f32),
        HDtype::Pred => bail!("pred iota unsupported"),
    })
}

// ---------------------------------------------------------------------------
// Dot
// ---------------------------------------------------------------------------

/// Materialize `a` with its axes permuted into `order` (row-major).
/// Zero-copy when `order` is already the identity — the canonical layouts
/// the emitter produces hit that path on the hot matmuls.
fn regroup_f32(a: &Val, order: &[usize]) -> Result<Arc<Vec<f32>>> {
    let identity = order.iter().enumerate().all(|(i, &o)| i == o);
    match &a.data {
        Data::F32(v) => {
            if identity {
                Ok(v.clone())
            } else {
                let dims_out: Vec<usize> = order.iter().map(|&i| a.dims[i]).collect();
                let in_strides = strides(&a.dims);
                let map: Vec<usize> = order.iter().map(|&i| in_strides[i]).collect();
                let mut out = Vec::with_capacity(a.len());
                let mut st = Stepper::new(&dims_out, &map);
                while let Some(off) = st.next() {
                    out.push(v[off]);
                }
                Ok(Arc::new(out))
            }
        }
        _ => bail!("dot requires f32 operands"),
    }
}

fn dot(mut args: Vec<Val>, dd: DotDims) -> Result<Val> {
    let rhs = args.pop().context("dot missing rhs")?;
    let lhs = args.pop().context("dot missing lhs")?;
    let lhs_free: Vec<usize> = (0..lhs.dims.len())
        .filter(|i| !dd.lhs_batch.contains(i) && !dd.lhs_contract.contains(i))
        .collect();
    let rhs_free: Vec<usize> = (0..rhs.dims.len())
        .filter(|i| !dd.rhs_batch.contains(i) && !dd.rhs_contract.contains(i))
        .collect();
    for (&lb, &rb) in dd.lhs_batch.iter().zip(&dd.rhs_batch) {
        if lhs.dims[lb] != rhs.dims[rb] {
            bail!("dot batch dim mismatch");
        }
    }
    for (&lc, &rc) in dd.lhs_contract.iter().zip(&dd.rhs_contract) {
        if lhs.dims[lc] != rhs.dims[rc] {
            bail!("dot contracting dim mismatch");
        }
    }

    // regroup to lhs [batch..., free..., contract...] and
    // rhs [batch..., contract..., free...]
    let lorder: Vec<usize> = dd
        .lhs_batch
        .iter()
        .chain(&lhs_free)
        .chain(&dd.lhs_contract)
        .copied()
        .collect();
    let rorder: Vec<usize> = dd
        .rhs_batch
        .iter()
        .chain(&dd.rhs_contract)
        .chain(&rhs_free)
        .copied()
        .collect();
    let ldata = regroup_f32(&lhs, &lorder)?;
    let rdata = regroup_f32(&rhs, &rorder)?;

    let nb: usize = dd.lhs_batch.iter().map(|&i| lhs.dims[i]).product();
    let m: usize = lhs_free.iter().map(|&i| lhs.dims[i]).product();
    let k: usize = dd.lhs_contract.iter().map(|&i| lhs.dims[i]).product();
    let n: usize = rhs_free.iter().map(|&i| rhs.dims[i]).product();

    // Output rows are independent, so the pool partitions them across
    // workers; within a part, up to four rows sharing one batch's rhs
    // panel advance together so each `rrow` load is amortized 4x (the
    // train-step matmuls are rhs-bandwidth bound).  Per output element
    // the ki-ascending accumulation order — and the zero-skip below — are
    // exactly the single-row kernel's, so any thread count and any block
    // shape produce bit-identical results.
    let mut out = vec![0f32; nb * m * n];
    let ld: &[f32] = &ldata;
    let rd: &[f32] = &rdata;
    pool::run_parts(pool::threads(), &mut out, n, |row0, part| {
        let total = part.len() / n.max(1);
        let mut g = row0; // global output row: b * m + mi
        let mut done = 0usize;
        let mut rest = part;
        while done < total {
            let b = g / m.max(1);
            let mi = g % m.max(1);
            let bs = (m - mi).min(4).min(total - done);
            let (block, tail) = rest.split_at_mut(bs * n);
            rest = tail;
            let lbase = b * m * k;
            let rbase = b * k * n;
            let mut rows: Vec<&mut [f32]> = block.chunks_mut(n.max(1)).collect();
            for ki in 0..k {
                let rrow = &rd[rbase + ki * n..rbase + (ki + 1) * n];
                for (t, orow) in rows.iter_mut().enumerate() {
                    // Deliberate deviation from strict IEEE dot semantics:
                    // an exactly-zero lhs element contributes nothing, even
                    // against a non-finite rhs row (XLA would produce NaN
                    // from 0·inf).  This makes one-hot embedding matmuls
                    // O(rows) instead of O(rows·V), and every fixture
                    // artifact is finite-valued, so the two semantics agree
                    // there (asserted by the jax goldens + interp==pjrt
                    // tests).
                    let a = ld[lbase + (mi + t) * k + ki];
                    if a == 0.0 {
                        continue;
                    }
                    for (o, &r) in orow.iter_mut().zip(rrow.iter()) {
                        *o += a * r;
                    }
                }
            }
            g += bs;
            done += bs;
        }
    });
    let mut out_dims: Vec<usize> = dd.lhs_batch.iter().map(|&i| lhs.dims[i]).collect();
    out_dims.extend(lhs_free.iter().map(|&i| lhs.dims[i]));
    out_dims.extend(rhs_free.iter().map(|&i| rhs.dims[i]));
    Ok(Val::f32(out_dims, out))
}

// ---------------------------------------------------------------------------
// Dynamic slice / update
// ---------------------------------------------------------------------------

fn start_indices(args: &[Val], rank: usize) -> Result<Vec<usize>> {
    if args.len() != rank {
        bail!("expected {rank} start indices, got {}", args.len());
    }
    args.iter()
        .map(|v| {
            if !v.dims.is_empty() {
                bail!("start index must be scalar");
            }
            Ok(match &v.data {
                Data::S32(x) => x[0].max(0) as usize,
                Data::U32(x) => x[0] as usize,
                _ => bail!("start index must be integer"),
            })
        })
        .collect()
}

fn dynamic_slice(mut args: Vec<Val>, sizes: &[usize]) -> Result<Val> {
    if args.is_empty() {
        bail!("dynamic-slice missing operand");
    }
    let a = args.remove(0);
    let starts = start_indices(&args, a.dims.len())?;
    let spec: Vec<(usize, usize, usize)> = starts
        .iter()
        .zip(sizes)
        .zip(&a.dims)
        .map(|((&s, &sz), &d)| {
            let s = s.min(d.saturating_sub(sz));
            (s, s + sz, 1)
        })
        .collect();
    slice_op(vec![a], &spec)
}

fn dynamic_update_slice(mut args: Vec<Val>, owned: &[bool]) -> Result<Val> {
    if args.len() < 2 {
        bail!("dynamic-update-slice missing operands");
    }
    let base_owned = owned.first().copied().unwrap_or(false);
    let base = args.remove(0);
    let update = args.remove(0);
    if base.dtype() != update.dtype() {
        bail!("dynamic-update-slice dtype mismatch");
    }
    let starts = start_indices(&args, base.dims.len())?;
    let starts: Vec<usize> = starts
        .iter()
        .zip(&update.dims)
        .zip(&base.dims)
        .map(|((&s, &u), &d)| s.min(d.saturating_sub(u)))
        .collect();
    let base_dims = base.dims.clone();
    let base_strides = strides(&base_dims);
    let offset: usize = starts.iter().zip(&base_strides).map(|(&s, &st)| s * st).sum();
    // Merge trailing axes into one contiguous run: axis i joins while its
    // base stride equals the run built inside it (innermost always does).
    // The KV decode hot path ([L,B,H,1,D] into [L,B,H,S,D]) then moves
    // d_head-sized blocks per step instead of scalars.
    let mut run = 1usize;
    let mut outer = update.dims.len();
    while outer > 0 && base_strides[outer - 1] == run {
        run *= update.dims[outer - 1];
        outer -= 1;
    }
    macro_rules! dus {
        ($variant:path, $mk:path, $t:ty) => {{
            let upd: &[$t] = match &update.data {
                $variant(v) => v,
                _ => bail!("dynamic-update-slice dtype mismatch"),
            };
            let arc = match base.data {
                $variant(a) => a,
                _ => unreachable!(),
            };
            // in place when the plan owns the base (the decode-loop hot
            // path); a broken ownership promise errors instead of copying
            let mut buf = if base_owned {
                match Arc::try_unwrap(arc) {
                    Ok(v) => v,
                    Err(_) => bail!(
                        "static plan marked the update base unique but it is \
                         shared (planner bug)"
                    ),
                }
            } else {
                arc.as_ref().clone()
            };
            let mut st = Stepper::new(&update.dims[..outer], &base_strides[..outer]);
            let mut i = 0usize;
            while let Some(off) = st.next() {
                buf[offset + off..offset + off + run].copy_from_slice(&upd[i..i + run]);
                i += run;
            }
            $mk(base_dims.clone(), buf)
        }};
    }
    Ok(match &update.data {
        Data::F32(_) => dus!(Data::F32, Val::f32, f32),
        Data::S32(_) => dus!(Data::S32, Val::s32, i32),
        Data::U32(_) => dus!(Data::U32, Val::u32, u32),
        Data::Pred(_) => dus!(Data::Pred, Val::pred, bool),
    })
}

// ---------------------------------------------------------------------------
// Gather (the embedding-lookup / take-along-axis subset)
// ---------------------------------------------------------------------------

fn gather(mut args: Vec<Val>, ins: &Instr, out_shape: &HShape) -> Result<Val> {
    let g = ins.gather.as_ref().context("gather without dimension numbers")?;
    let indices = args.pop().context("gather missing indices")?;
    let operand = args.pop().context("gather missing operand")?;
    let orank = operand.dims.len();
    if g.slice_sizes.len() != orank {
        bail!("gather slice_sizes rank mismatch");
    }
    for (&sz, &d) in g.slice_sizes.iter().zip(&operand.dims) {
        if sz > d {
            bail!("gather slice size exceeds operand dim");
        }
    }
    // indices batch shape: indices dims with index_vector_dim removed
    // (index_vector_dim == rank means implicit trailing 1)
    let mut batch_dims: Vec<usize> = indices.dims.clone();
    let ncomp = if g.index_vector_dim < indices.dims.len() {
        batch_dims.remove(g.index_vector_dim)
    } else {
        1
    };
    if ncomp != g.start_index_map.len() {
        bail!("gather index components {} != start_index_map", ncomp);
    }
    let idx_i32 = indices.as_s32()?;
    let idx_strides = strides(&indices.dims);
    let comp_stride = if g.index_vector_dim < indices.dims.len() {
        idx_strides[g.index_vector_dim]
    } else {
        0
    };
    // strides of the batch portion within the indices buffer
    let batch_strides: Vec<usize> = (0..indices.dims.len())
        .filter(|&i| i != g.index_vector_dim)
        .map(|i| idx_strides[i])
        .collect();

    // offset dims of the output map to non-collapsed operand dims, in order
    let offset_operand_dims: Vec<usize> =
        (0..orank).filter(|i| !g.collapsed_slice_dims.contains(i)).collect();
    if g.offset_dims.len() != offset_operand_dims.len() {
        bail!("gather offset_dims/collapsed mismatch");
    }
    let out_dims = out_shape.dims.clone();
    let out_batch_axes: Vec<usize> =
        (0..out_dims.len()).filter(|a| !g.offset_dims.contains(a)).collect();
    if out_batch_axes.len() != batch_dims.len() {
        bail!("gather output batch rank mismatch");
    }
    let op_strides = strides(&operand.dims);
    let src = operand.as_f32()?;

    let n: usize = out_dims.iter().product();
    let mut out = Vec::with_capacity(n);
    let out_strides_ = strides(&out_dims);
    for lin in 0..n {
        // decompose output index
        let mut start_off = 0usize; // offset from gathered start indices
        let mut in_slice_off = 0usize; // offset within the slice
        let mut batch_lin = 0usize;
        for (axis, &od) in out_dims.iter().enumerate() {
            let coord = (lin / out_strides_[axis]) % od;
            if let Some(k) = g.offset_dims.iter().position(|&a| a == axis) {
                in_slice_off += coord * op_strides[offset_operand_dims[k]];
            } else {
                // every non-offset output axis is a batch axis (verified
                // statically: offset_dims ∪ batch axes cover the output)
                let b = out_batch_axes
                    .iter()
                    .position(|&a| a == axis)
                    .with_context(|| {
                        format!("gather output axis {axis} is neither offset nor batch")
                    })?;
                batch_lin += coord * batch_strides[b];
            }
        }
        for (c, &od) in g.start_index_map.iter().enumerate() {
            let raw = idx_i32[batch_lin + c * comp_stride].max(0) as usize;
            let clamped = raw.min(operand.dims[od] - g.slice_sizes[od]);
            start_off += clamped * op_strides[od];
        }
        out.push(src[start_off + in_slice_off]);
    }
    Ok(Val::f32(out_dims, out))
}

#[cfg(test)]
mod tests {
    #![allow(clippy::disallowed_methods)]

    use super::*;

    fn run(text: &str, inputs: &[Tensor]) -> Vec<Tensor> {
        Program::parse(text).unwrap().evaluate(inputs).unwrap()
    }

    #[test]
    fn elementwise_and_broadcast() {
        let text = r#"ENTRY %m (a: f32[2,3], s: f32[]) -> (f32[2,3]) {
  %a = f32[2,3] parameter(0)
  %s = f32[] parameter(1)
  %sb = f32[2,3] broadcast(f32[] %s), dimensions={}
  %x = f32[2,3] multiply(f32[2,3] %a, f32[2,3] %sb)
  %e = f32[2,3] exponential(f32[2,3] %x)
  ROOT %t = (f32[2,3]) tuple(f32[2,3] %e)
}
"#;
        let a = Tensor::f32(vec![2, 3], vec![0.0, 1.0, -1.0, 2.0, 0.5, -0.5]);
        let out = run(text, &[a.clone(), Tensor::scalar_f32(2.0)]);
        let got = out[0].as_f32().unwrap();
        for (g, x) in got.iter().zip(a.as_f32().unwrap()) {
            assert_eq!(*g, (2.0 * x).exp());
        }
    }

    #[test]
    fn row_broadcast_matches_dims_mapping() {
        let text = r#"ENTRY %m (v: f32[3]) -> (f32[2,3], f32[3,2]) {
  %v = f32[3] parameter(0)
  %r = f32[2,3] broadcast(f32[3] %v), dimensions={1}
  %c = f32[3,2] broadcast(f32[3] %v), dimensions={0}
  ROOT %t = (f32[2,3], f32[3,2]) tuple(f32[2,3] %r, f32[3,2] %c)
}
"#;
        let out = run(text, &[Tensor::f32(vec![3], vec![1.0, 2.0, 3.0])]);
        assert_eq!(out[0].as_f32().unwrap(), &[1., 2., 3., 1., 2., 3.]);
        assert_eq!(out[1].as_f32().unwrap(), &[1., 1., 2., 2., 3., 3.]);
    }

    #[test]
    fn reduce_sum_and_max() {
        let text = r#"%radd (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(f32[] %a, f32[] %b)
}

%rmax (c: f32[], d: f32[]) -> f32[] {
  %c = f32[] parameter(0)
  %d = f32[] parameter(1)
  ROOT %r2 = f32[] maximum(f32[] %c, f32[] %d)
}

ENTRY %m (x: f32[2,3]) -> (f32[2], f32[3], f32[]) {
  %x = f32[2,3] parameter(0)
  %zero = f32[] constant(0)
  %ninf = f32[] constant(-inf)
  %rows = f32[2] reduce(f32[2,3] %x, f32[] %zero), dimensions={1}, to_apply=%radd
  %cols = f32[3] reduce(f32[2,3] %x, f32[] %ninf), dimensions={0}, to_apply=%rmax
  %all = f32[] reduce(f32[2,3] %x, f32[] %zero), dimensions={0,1}, to_apply=%radd
  ROOT %t = (f32[2], f32[3], f32[]) tuple(f32[2] %rows, f32[3] %cols, f32[] %all)
}
"#;
        let x = Tensor::f32(vec![2, 3], vec![1., -2., 3., 4., 5., -6.]);
        let out = run(text, &[x]);
        assert_eq!(out[0].as_f32().unwrap(), &[2.0, 3.0]);
        assert_eq!(out[1].as_f32().unwrap(), &[4.0, 5.0, 3.0]);
        assert_eq!(out[2].as_f32().unwrap(), &[5.0]);
    }

    #[test]
    fn dot_plain_and_batched() {
        let text = r#"ENTRY %m (a: f32[2,3], b: f32[3,4], q: f32[2,2,3], k: f32[2,4,3]) -> (f32[2,4], f32[2,2,4]) {
  %a = f32[2,3] parameter(0)
  %b = f32[3,4] parameter(1)
  %q = f32[2,2,3] parameter(2)
  %k = f32[2,4,3] parameter(3)
  %mm = f32[2,4] dot(f32[2,3] %a, f32[3,4] %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %bmm = f32[2,2,4] dot(f32[2,2,3] %q, f32[2,4,3] %k), lhs_batch_dims={0}, rhs_batch_dims={0}, lhs_contracting_dims={2}, rhs_contracting_dims={2}
  ROOT %t = (f32[2,4], f32[2,2,4]) tuple(f32[2,4] %mm, f32[2,2,4] %bmm)
}
"#;
        let a = Tensor::f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let b = Tensor::f32(vec![3, 4], (0..12).map(|i| i as f32).collect());
        let q = Tensor::f32(vec![2, 2, 3], (0..12).map(|i| (i % 5) as f32).collect());
        let k = Tensor::f32(vec![2, 4, 3], (0..24).map(|i| (i % 7) as f32 - 3.0).collect());
        let out = run(text, &[a.clone(), b.clone(), q.clone(), k.clone()]);
        // reference mm
        let (av, bv) = (a.as_f32().unwrap(), b.as_f32().unwrap());
        for i in 0..2 {
            for j in 0..4 {
                let want: f32 = (0..3).map(|l| av[i * 3 + l] * bv[l * 4 + j]).sum();
                assert_eq!(out[0].as_f32().unwrap()[i * 4 + j], want);
            }
        }
        // reference bmm: q[b,i,:] · k[b,j,:]
        let (qv, kv) = (q.as_f32().unwrap(), k.as_f32().unwrap());
        for bb in 0..2 {
            for i in 0..2 {
                for j in 0..4 {
                    let want: f32 = (0..3)
                        .map(|l| qv[bb * 6 + i * 3 + l] * kv[bb * 12 + j * 3 + l])
                        .sum();
                    assert_eq!(out[1].as_f32().unwrap()[bb * 8 + i * 4 + j], want);
                }
            }
        }
    }

    #[test]
    fn transpose_slice_concat_pad() {
        let text = r#"ENTRY %m (x: f32[2,3]) -> (f32[3,2], f32[2,2], f32[2,5], f32[4,3]) {
  %x = f32[2,3] parameter(0)
  %zero = f32[] constant(9)
  %tr = f32[3,2] transpose(f32[2,3] %x), dimensions={1,0}
  %sl = f32[2,2] slice(f32[2,3] %x), slice={[0:2], [1:3]}
  %cc = f32[2,5] concatenate(f32[2,3] %x, f32[2,2] %sl), dimensions={1}
  %pd = f32[4,3] pad(f32[2,3] %x, f32[] %zero), padding=1_1x0_0
  ROOT %t = (f32[3,2], f32[2,2], f32[2,5], f32[4,3]) tuple(f32[3,2] %tr, f32[2,2] %sl, f32[2,5] %cc, f32[4,3] %pd)
}
"#;
        let x = Tensor::f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let out = run(text, &[x]);
        assert_eq!(out[0].as_f32().unwrap(), &[1., 4., 2., 5., 3., 6.]);
        assert_eq!(out[1].as_f32().unwrap(), &[2., 3., 5., 6.]);
        assert_eq!(out[2].as_f32().unwrap(), &[1., 2., 3., 2., 3., 4., 5., 6., 5., 6.]);
        assert_eq!(
            out[3].as_f32().unwrap(),
            &[9., 9., 9., 1., 2., 3., 4., 5., 6., 9., 9., 9.]
        );
    }

    #[test]
    fn iota_compare_select_convert() {
        let text = r#"ENTRY %m (x: s32[4]) -> (f32[4]) {
  %x = s32[4] parameter(0)
  %i = s32[4] iota(), iota_dimension=0
  %p = pred[4] compare(s32[4] %i, s32[4] %x), direction=LE
  %pf = f32[4] convert(pred[4] %p)
  %xf = f32[4] convert(s32[4] %x)
  %sel = f32[4] select(pred[4] %p, f32[4] %xf, f32[4] %pf)
  ROOT %t = (f32[4]) tuple(f32[4] %sel)
}
"#;
        let x = Tensor::i32(vec![4], vec![2, 0, 1, 5]);
        let out = run(text, &[x]);
        // i = [0,1,2,3]; p = i<=x = [T,F,F,T]; sel = [2, 0, 0, 5]
        assert_eq!(out[0].as_f32().unwrap(), &[2.0, 0.0, 0.0, 5.0]);
    }

    #[test]
    fn dynamic_slice_and_update() {
        let text = r#"ENTRY %m (x: f32[2,4], u: f32[2,1], p: s32[]) -> (f32[2,2], f32[2,4]) {
  %x = f32[2,4] parameter(0)
  %u = f32[2,1] parameter(1)
  %p = s32[] parameter(2)
  %z = s32[] constant(0)
  %ds = f32[2,2] dynamic-slice(f32[2,4] %x, s32[] %z, s32[] %p), dynamic_slice_sizes={2,2}
  %du = f32[2,4] dynamic-update-slice(f32[2,4] %x, f32[2,1] %u, s32[] %z, s32[] %p)
  ROOT %t = (f32[2,2], f32[2,4]) tuple(f32[2,2] %ds, f32[2,4] %du)
}
"#;
        let x = Tensor::f32(vec![2, 4], (0..8).map(|i| i as f32).collect());
        let u = Tensor::f32(vec![2, 1], vec![100.0, 200.0]);
        let out = run(text, &[x, u, Tensor::scalar_i32(1)]);
        assert_eq!(out[0].as_f32().unwrap(), &[1., 2., 5., 6.]);
        assert_eq!(out[1].as_f32().unwrap(), &[0., 100., 2., 3., 4., 200., 6., 7.]);
    }

    #[test]
    fn gather_embedding_lookup() {
        // tok_emb[V=4, D=2] gathered at indices [3] → [3, 2]
        let text = r#"ENTRY %m (e: f32[4,2], ix: s32[3]) -> (f32[3,2]) {
  %e = f32[4,2] parameter(0)
  %ix = s32[3] parameter(1)
  %g = f32[3,2] gather(f32[4,2] %e, s32[3] %ix), offset_dims={1}, collapsed_slice_dims={0}, start_index_map={0}, index_vector_dim=1, slice_sizes={1,2}
  ROOT %t = (f32[3,2]) tuple(f32[3,2] %g)
}
"#;
        let e = Tensor::f32(vec![4, 2], vec![0., 1., 10., 11., 20., 21., 30., 31.]);
        let ix = Tensor::i32(vec![3], vec![2, 0, 3]);
        let out = run(text, &[e, ix]);
        assert_eq!(out[0].as_f32().unwrap(), &[20., 21., 0., 1., 30., 31.]);
    }

    #[test]
    fn u32_hash_ops() {
        let text = r#"ENTRY %m (s: u32[]) -> (u32[4]) {
  %s = u32[] parameter(0)
  %i = u32[4] iota(), iota_dimension=0
  %sb = u32[4] broadcast(u32[] %s), dimensions={}
  %x0 = u32[4] add(u32[4] %i, u32[4] %sb)
  %c = u32[] constant(2654435761)
  %cb = u32[4] broadcast(u32[] %c), dimensions={}
  %x1 = u32[4] multiply(u32[4] %x0, u32[4] %cb)
  %sh = u32[] constant(16)
  %shb = u32[4] broadcast(u32[] %sh), dimensions={}
  %x2 = u32[4] shift-right-logical(u32[4] %x1, u32[4] %shb)
  %x3 = u32[4] xor(u32[4] %x1, u32[4] %x2)
  ROOT %t = (u32[4]) tuple(u32[4] %x3)
}
"#;
        let out = run(text, &[Tensor::scalar_u32(7)]);
        let got = match &out[0].data {
            TensorData::U32(v) => v.clone(),
            _ => panic!("expected u32"),
        };
        let want: Vec<u32> = (0..4u32)
            .map(|i| {
                let x = i.wrapping_add(7).wrapping_mul(2654435761);
                x ^ (x >> 16)
            })
            .collect();
        assert_eq!(got, want);
    }

    #[test]
    fn softmax_composed_from_primitives() {
        let text = r#"%radd (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(f32[] %a, f32[] %b)
}

%rmax (c: f32[], d: f32[]) -> f32[] {
  %c = f32[] parameter(0)
  %d = f32[] parameter(1)
  ROOT %r2 = f32[] maximum(f32[] %c, f32[] %d)
}

ENTRY %m (x: f32[2,4]) -> (f32[2,4]) {
  %x = f32[2,4] parameter(0)
  %ninf = f32[] constant(-inf)
  %zero = f32[] constant(0)
  %mx = f32[2] reduce(f32[2,4] %x, f32[] %ninf), dimensions={1}, to_apply=%rmax
  %mxb = f32[2,4] broadcast(f32[2] %mx), dimensions={0}
  %sub = f32[2,4] subtract(f32[2,4] %x, f32[2,4] %mxb)
  %ex = f32[2,4] exponential(f32[2,4] %sub)
  %sm = f32[2] reduce(f32[2,4] %ex, f32[] %zero), dimensions={1}, to_apply=%radd
  %smb = f32[2,4] broadcast(f32[2] %sm), dimensions={0}
  %p = f32[2,4] divide(f32[2,4] %ex, f32[2,4] %smb)
  ROOT %t = (f32[2,4]) tuple(f32[2,4] %p)
}
"#;
        let x = Tensor::f32(vec![2, 4], vec![1., 2., 3., 4., -1., 0., 1., 2.]);
        let out = Program::parse(text).unwrap().evaluate(&[x.clone()]).unwrap();
        let xd = x.as_f32().unwrap();
        for r in 0..2 {
            let row = &xd[r * 4..(r + 1) * 4];
            let mx = row.iter().fold(f32::MIN, |a, &b| a.max(b));
            let ex: Vec<f32> = row.iter().map(|&v| (v - mx).exp()).collect();
            let s: f32 = ex.iter().sum();
            for c in 0..4 {
                let got = out[0].as_f32().unwrap()[r * 4 + c];
                assert!((got - ex[c] / s).abs() < 1e-7, "{got} vs {}", ex[c] / s);
            }
        }
    }

    #[test]
    fn arity_mismatch_is_error() {
        let p = Program::parse(
            "ENTRY %m (a: f32[1]) -> (f32[1]) {\n  %a = f32[1] parameter(0)\n  ROOT %t = (f32[1]) tuple(f32[1] %a)\n}\n",
        )
        .unwrap();
        assert!(p.evaluate(&[]).is_err());
    }

    #[test]
    fn while_doubles_until_counter_stops() {
        // 3 iterations: i 0→3, x doubles each time, both tuple elements
        // extracted (the first gte clones the loop state, the second
        // takes it)
        let text = r#"HloModule loopy

%cond (ci: s32[], cx: f32[4]) -> pred[] {
  %ci = s32[] parameter(0)
  %cx = f32[4] parameter(1)
  %cl = s32[] constant(3)
  ROOT %cp = pred[] compare(s32[] %ci, s32[] %cl), direction=LT
}

%body (bi: s32[], bx: f32[4]) -> (s32[], f32[4]) {
  %bi = s32[] parameter(0)
  %bx = f32[4] parameter(1)
  %b1 = s32[] constant(1)
  %bn = s32[] add(s32[] %bi, s32[] %b1)
  %bx2 = f32[4] add(f32[4] %bx, f32[4] %bx)
  ROOT %bt = (s32[], f32[4]) tuple(s32[] %bn, f32[4] %bx2)
}

ENTRY %m (i: s32[], x: f32[4]) -> (s32[], f32[4]) {
  %i = s32[] parameter(0)
  %x = f32[4] parameter(1)
  %w = (s32[], f32[4]) while(s32[] %i, f32[4] %x), condition=%cond, body=%body
  %g0 = s32[] get-tuple-element((s32[], f32[4]) %w), index=0
  %g1 = f32[4] get-tuple-element((s32[], f32[4]) %w), index=1
  ROOT %t = (s32[], f32[4]) tuple(s32[] %g0, f32[4] %g1)
}
"#;
        let out = run(
            text,
            &[Tensor::scalar_i32(0), Tensor::f32(vec![4], vec![1., -2., 0.5, 3.])],
        );
        assert_eq!(out[0].as_i32().unwrap(), &[3]);
        assert_eq!(out[1].as_f32().unwrap(), &[8., -16., 4., 24.]);
    }

    #[test]
    fn while_zero_iterations_passes_state_through() {
        let text = r#"HloModule noloop

%cond (ci: s32[], cx: f32[2]) -> pred[] {
  %ci = s32[] parameter(0)
  %cx = f32[2] parameter(1)
  %cl = s32[] constant(0)
  ROOT %cp = pred[] compare(s32[] %ci, s32[] %cl), direction=LT
}

%body (bi: s32[], bx: f32[2]) -> (s32[], f32[2]) {
  %bi = s32[] parameter(0)
  %bx = f32[2] parameter(1)
  ROOT %bt = (s32[], f32[2]) tuple(s32[] %bi, f32[2] %bx)
}

ENTRY %m (i: s32[], x: f32[2]) -> (f32[2]) {
  %i = s32[] parameter(0)
  %x = f32[2] parameter(1)
  %w = (s32[], f32[2]) while(s32[] %i, f32[2] %x), condition=%cond, body=%body
  %g1 = f32[2] get-tuple-element((s32[], f32[2]) %w), index=1
  ROOT %t = (f32[2]) tuple(f32[2] %g1)
}
"#;
        let out = run(text, &[Tensor::scalar_i32(5), Tensor::f32(vec![2], vec![7., 9.])]);
        assert_eq!(out[0].as_f32().unwrap(), &[7., 9.]);
    }

    #[test]
    fn sort_ascending_descending_and_inner_axis() {
        let text = r#"HloModule sorty

%cmp_lt (la: f32[], lb: f32[]) -> pred[] {
  %la = f32[] parameter(0)
  %lb = f32[] parameter(1)
  ROOT %l = pred[] compare(f32[] %la, f32[] %lb), direction=LT
}

%cmp_gt (ga: f32[], gb: f32[]) -> pred[] {
  %ga = f32[] parameter(0)
  %gb = f32[] parameter(1)
  ROOT %g = pred[] compare(f32[] %ga, f32[] %gb), direction=GT
}

ENTRY %m (x: f32[5], y: f32[2,3]) -> (f32[5], f32[5], f32[2,3]) {
  %x = f32[5] parameter(0)
  %y = f32[2,3] parameter(1)
  %asc = f32[5] sort(f32[5] %x), dimensions={0}, to_apply=%cmp_lt
  %dsc = f32[5] sort(f32[5] %x), dimensions={0}, to_apply=%cmp_gt
  %cols = f32[2,3] sort(f32[2,3] %y), dimensions={0}, to_apply=%cmp_lt
  ROOT %t = (f32[5], f32[5], f32[2,3]) tuple(f32[5] %asc, f32[5] %dsc, f32[2,3] %cols)
}
"#;
        let x = Tensor::f32(vec![5], vec![3., -1., 2., -1.5, 0.]);
        let y = Tensor::f32(vec![2, 3], vec![4., -2., 1., -3., 5., 0.]);
        let out = run(text, &[x, y]);
        assert_eq!(out[0].as_f32().unwrap(), &[-1.5, -1., 0., 2., 3.]);
        assert_eq!(out[1].as_f32().unwrap(), &[3., 2., 0., -1., -1.5]);
        // axis-0 sort: each column sorted independently (strided lanes)
        assert_eq!(out[2].as_f32().unwrap(), &[-3., -2., 0., 4., 5., 1.]);
    }

    #[test]
    fn scatter_accumulates_embedding_grad_rows() {
        // The jax embedding-grad lowering shape: duplicate index rows
        // accumulate, and an out-of-range row clamps to the last row
        // (mirroring fixturegen/hlo_eval.py::_scatter).
        let text = r#"HloModule scat

%scatter_add_f32 (sa: f32[], sb: f32[]) -> f32[] {
  %sa = f32[] parameter(0)
  %sb = f32[] parameter(1)
  ROOT %s = f32[] add(f32[] %sa, f32[] %sb)
}

ENTRY %m (tbl: f32[4,2], idx: s32[3], upd: f32[3,2]) -> (f32[4,2]) {
  %tbl = f32[4,2] parameter(0)
  %idx = s32[3] parameter(1)
  %upd = f32[3,2] parameter(2)
  %sc = f32[4,2] scatter(f32[4,2] %tbl, s32[3] %idx, f32[3,2] %upd), update_window_dims={1}, inserted_window_dims={0}, scatter_dims_to_operand_dims={0}, index_vector_dim=1, to_apply=%scatter_add_f32
  ROOT %t = (f32[4,2]) tuple(f32[4,2] %sc)
}
"#;
        let tbl = Tensor::zeros_f32(vec![4, 2]);
        let idx = Tensor::i32(vec![3], vec![1, 9, 1]);
        let upd = Tensor::f32(vec![3, 2], vec![1., 2., 10., 20., 100., 200.]);
        let out = run(text, &[tbl, idx, upd]);
        assert_eq!(
            out[0].as_f32().unwrap(),
            &[0., 0., 101., 202., 0., 0., 10., 20.]
        );
    }

    #[test]
    fn rng_bit_generator_matches_counter_hash_stream() {
        let text = r#"ENTRY %m (seed: u32[]) -> (s32[4]) {
  %seed = u32[] parameter(0)
  %bits = u32[4] rng-bit-generator(u32[] %seed), algorithm=rng_default
  %s = s32[4] convert(u32[4] %bits)
  ROOT %t = (s32[4]) tuple(s32[4] %s)
}
"#;
        let out = run(text, &[Tensor::scalar_u32(7)]);
        let want: Vec<i32> = (0u32..4).map(|j| hash_u32(7 + j) as i32).collect();
        assert_eq!(out[0].as_i32().unwrap(), &want[..]);
    }

    #[test]
    fn rng_uniform_is_the_fixture_counter_stream() {
        let text = r#"ENTRY %m (lo: f32[], hi: f32[]) -> (f32[6]) {
  %lo = f32[] parameter(0)
  %hi = f32[] parameter(1)
  %r = f32[6] rng(f32[] %lo, f32[] %hi), distribution=rng_uniform
  ROOT %t = (f32[6]) tuple(f32[6] %r)
}
"#;
        let out = run(text, &[Tensor::scalar_f32(2.0), Tensor::scalar_f32(4.0)]);
        for (j, &got) in out[0].as_f32().unwrap().iter().enumerate() {
            let u = ((hash_u32(j as u32) >> 8) as f32 + 0.5) * (1.0 / 16777216.0);
            assert_eq!(got, 2.0 + u * 2.0);
            assert!((2.0..4.0).contains(&got));
        }
    }

    #[test]
    fn fused_chain_matches_stepwise_semantics() {
        // multiply → add → tanh is a planner chain; the fused kernel must
        // produce exactly what the stepwise ops would.
        let text = r#"ENTRY %m (a: f32[8], b: f32[8], c: f32[8]) -> (f32[8]) {
  %a = f32[8] parameter(0)
  %b = f32[8] parameter(1)
  %c = f32[8] parameter(2)
  %y = f32[8] multiply(f32[8] %a, f32[8] %b)
  %z = f32[8] add(f32[8] %y, f32[8] %c)
  %w = f32[8] tanh(f32[8] %z)
  ROOT %t = (f32[8]) tuple(f32[8] %w)
}
"#;
        let p = Program::parse(text).unwrap();
        // the chain must actually be compiled (not silently rejected)
        let ef = &p.fused[p.module.entry];
        assert_eq!(ef.tails.len(), 1, "expected one fused chain");
        let chain = ef.tails.values().next().unwrap();
        assert_eq!(chain.len(), 3);
        assert_eq!(ef.interior.iter().filter(|&&x| x).count(), 2);

        let a: Vec<f32> = (0..8).map(|i| (i as f32) * 0.25 - 1.0).collect();
        let b: Vec<f32> = (0..8).map(|i| 1.5 - (i as f32) * 0.5).collect();
        let c: Vec<f32> = (0..8).map(|i| (i as f32) * 0.1).collect();
        let out = p
            .evaluate(&[
                Tensor::f32(vec![8], a.clone()),
                Tensor::f32(vec![8], b.clone()),
                Tensor::f32(vec![8], c.clone()),
            ])
            .unwrap();
        for i in 0..8 {
            assert_eq!(out[0].as_f32().unwrap()[i], (a[i] * b[i] + c[i]).tanh());
        }
    }

    #[test]
    fn fused_select_and_rhs_carry_links() {
        // chain where the carried value enters a subtract as the *rhs*
        // and then a select as the on-true branch (pred driven by an
        // in-graph compare, as in the real artifacts)
        let text = r#"ENTRY %m (a: f32[4], b: f32[4], g: f32[4], f: f32[4]) -> (f32[4]) {
  %a = f32[4] parameter(0)
  %b = f32[4] parameter(1)
  %g = f32[4] parameter(2)
  %f = f32[4] parameter(3)
  %zero = f32[] constant(0)
  %zb = f32[4] broadcast(f32[] %zero), dimensions={}
  %p = pred[4] compare(f32[4] %g, f32[4] %zb), direction=GT
  %n = f32[4] negate(f32[4] %a)
  %d = f32[4] subtract(f32[4] %b, f32[4] %n)
  %s = f32[4] select(pred[4] %p, f32[4] %d, f32[4] %f)
  ROOT %t = (f32[4]) tuple(f32[4] %s)
}
"#;
        let p = Program::parse(text).unwrap();
        let a = vec![1., -2., 3., -4.];
        let b = vec![0.5, 0.5, 0.5, 0.5];
        let g = vec![1., -1., 1., -1.];
        let f = vec![9., 9., 9., 9.];
        let out = p
            .evaluate(&[
                Tensor::f32(vec![4], a.clone()),
                Tensor::f32(vec![4], b.clone()),
                Tensor::f32(vec![4], g.clone()),
                Tensor::f32(vec![4], f.clone()),
            ])
            .unwrap();
        for i in 0..4 {
            let want = if g[i] > 0.0 { b[i] - (-a[i]) } else { f[i] };
            assert_eq!(out[0].as_f32().unwrap()[i], want);
        }
    }

    #[test]
    fn fused_chain_with_extra_interior_consumer_stays_stepwise() {
        // %ex feeds both the reduce and the divide: the planner still
        // chains sub→ex→p, but the fused compiler must reject it so the
        // reduce can read the materialized %ex (the softmax shape)
        let text = r#"%radd (ra: f32[], rb: f32[]) -> f32[] {
  %ra = f32[] parameter(0)
  %rb = f32[] parameter(1)
  ROOT %r = f32[] add(f32[] %ra, f32[] %rb)
}

ENTRY %m (x: f32[2,4], m0: f32[2,4]) -> (f32[2,4]) {
  %x = f32[2,4] parameter(0)
  %m0 = f32[2,4] parameter(1)
  %zero = f32[] constant(0)
  %sub = f32[2,4] subtract(f32[2,4] %x, f32[2,4] %m0)
  %ex = f32[2,4] exponential(f32[2,4] %sub)
  %sm = f32[2] reduce(f32[2,4] %ex, f32[] %zero), dimensions={1}, to_apply=%radd
  %smb = f32[2,4] broadcast(f32[2] %sm), dimensions={0}
  %p = f32[2,4] divide(f32[2,4] %ex, f32[2,4] %smb)
  ROOT %t = (f32[2,4]) tuple(f32[2,4] %p)
}
"#;
        let p = Program::parse(text).unwrap();
        assert!(
            p.fused[p.module.entry].tails.is_empty(),
            "chain with a second interior consumer must not fuse"
        );
        let x: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let m0 = vec![3., 3., 3., 3., 7., 7., 7., 7.];
        let out = p
            .evaluate(&[Tensor::f32(vec![2, 4], x.clone()), Tensor::f32(vec![2, 4], m0.clone())])
            .unwrap();
        for r in 0..2 {
            let ex: Vec<f32> = (0..4).map(|c| (x[r * 4 + c] - m0[r * 4 + c]).exp()).collect();
            let s: f32 = ex.iter().sum();
            for c in 0..4 {
                assert_eq!(out[0].as_f32().unwrap()[r * 4 + c], ex[c] / s);
            }
        }
    }

    #[test]
    fn blocked_dot_handles_odd_rows_and_batch_boundaries() {
        // m=5 forces a 4-row block + a 1-row remainder per batch; nb=2
        // checks blocks never straddle a batch boundary
        let text = r#"ENTRY %m (q: f32[2,5,3], k: f32[2,3,2]) -> (f32[2,5,2]) {
  %q = f32[2,5,3] parameter(0)
  %k = f32[2,3,2] parameter(1)
  %o = f32[2,5,2] dot(f32[2,5,3] %q, f32[2,3,2] %k), lhs_batch_dims={0}, rhs_batch_dims={0}, lhs_contracting_dims={2}, rhs_contracting_dims={1}
  ROOT %t = (f32[2,5,2]) tuple(f32[2,5,2] %o)
}
"#;
        let qv: Vec<f32> = (0..30).map(|i| ((i % 11) as f32) - 4.0).collect();
        let kv: Vec<f32> = (0..12).map(|i| ((i % 5) as f32) * 0.5 - 1.0).collect();
        let out = run(
            text,
            &[Tensor::f32(vec![2, 5, 3], qv.clone()), Tensor::f32(vec![2, 3, 2], kv.clone())],
        );
        for b in 0..2 {
            for i in 0..5 {
                for j in 0..2 {
                    let mut want = 0f32;
                    for l in 0..3 {
                        let a = qv[b * 15 + i * 3 + l];
                        if a == 0.0 {
                            continue;
                        }
                        want += a * kv[b * 6 + l * 2 + j];
                    }
                    assert_eq!(out[0].as_f32().unwrap()[b * 10 + i * 2 + j], want);
                }
            }
        }
    }
}
