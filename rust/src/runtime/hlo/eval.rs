//! Reference evaluator for *verified* HLO modules.
//!
//! Correctness first, but with the two properties the engine tier needs:
//!
//! * values are `Arc`-backed, so `reshape` (and same-type `convert`) are
//!   zero-copy and operand buffers are *taken* at their last use — unary /
//!   binary elementwise ops and `dynamic-update-slice` then mutate in
//!   place instead of allocating.  The stepwise decode loop's per-token
//!   allocations stay bounded by the step outputs (tests/alloc_counts.rs).
//! * evaluation is pure and `&self`, so coordinator threads execute
//!   concurrently (unlike PJRT, which the engine serializes).
//!
//! [`Program::parse`] runs [`super::verify`] and precomputes a
//! [`StaticPlan`] before anything executes: liveness (`last_use`) and
//! buffer uniqueness come from the plan, so in-place mutation is a
//! *checked promise* — an `Arc::try_unwrap` the plan said would succeed
//! erroring out is a planner bug surfaced loudly, not a silent copy.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::runtime::hlo::parser::{
    CmpDir, DotDims, HDtype, HShape, HloModule, Instr, Literal, ReduceKind,
};
use crate::runtime::hlo::plan::StaticPlan;
use crate::runtime::hlo::verify;
use crate::runtime::tensor::{Tensor, TensorData};

/// A compiled-for-evaluation module: parse + verify + plan once, evaluate
/// many times.
#[derive(Debug, Clone)]
pub struct Program {
    module: HloModule,
    plan: StaticPlan,
}

impl Program {
    pub fn parse(text: &str) -> Result<Program> {
        Program::compile(HloModule::parse(text)?)
    }

    /// Verify a parsed module and build its execution plan.  Any verifier
    /// diagnostic — shape/dtype mismatch, def-use defect, unsupported op,
    /// missing attribute — rejects the module here, before evaluation.
    pub fn compile(module: HloModule) -> Result<Program> {
        let diags = verify::verify_module(&module);
        if !diags.is_empty() {
            let list: Vec<String> = diags.iter().map(|d| format!("  {d}")).collect();
            bail!(
                "module '{}' failed static verification with {} diagnostic(s):\n{}",
                module.name,
                diags.len(),
                list.join("\n")
            );
        }
        let plan = StaticPlan::build(&module);
        Ok(Program { module, plan })
    }

    pub fn module(&self) -> &HloModule {
        &self.module
    }

    /// The static execution plan (liveness, uniqueness, peak-live bound).
    pub fn plan(&self) -> &StaticPlan {
        &self.plan
    }

    /// Instruction count of the entry computation (interp "compile" stat).
    pub fn num_instructions(&self) -> usize {
        self.module.entry_computation().instrs.len()
    }

    /// Evaluate the entry computation.  The root must be a tuple; its
    /// elements come back as one host tensor each (the engine contract).
    pub fn evaluate(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let refs: Vec<&Tensor> = inputs.iter().collect();
        self.evaluate_refs(&refs)
    }

    /// Borrowing variant of [`Program::evaluate`] — parameters are copied
    /// into the value arena exactly once (the engine's hot path).
    pub fn evaluate_refs(&self, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        let entry = self.module.entry_computation();
        if inputs.len() != entry.params.len() {
            bail!(
                "module '{}' expects {} parameters, got {}",
                self.module.name,
                entry.params.len(),
                inputs.len()
            );
        }
        let mut slots: Vec<Option<Val>> = vec![None; entry.instrs.len()];
        for (i, ins) in entry.instrs.iter().enumerate() {
            if i == entry.root {
                break;
            }
            let val = self
                .exec(i, ins, inputs, &mut slots)
                .with_context(|| format!("evaluating %{} ({})", ins.name, ins.opcode))?;
            if let Some(v) = val {
                if let Some(shape) = &ins.shape {
                    debug_assert_eq!(
                        v.dims,
                        shape.dims,
                        "%{}: result shape mismatch",
                        ins.name
                    );
                }
                slots[i] = Some(v);
            }
        }
        let root = &entry.instrs[entry.root];
        if root.opcode != "tuple" {
            bail!("entry root must be a tuple, got '{}'", root.opcode);
        }
        // take (not clone) each root operand at its LAST occurrence so
        // uniquely-owned buffers move straight into the output tensors
        // without a copy; earlier duplicate occurrences clone (legal HLO
        // may repeat a tuple element)
        root.operands
            .iter()
            .enumerate()
            .map(|(k, &op)| {
                let dup_later = root.operands[k + 1..].contains(&op);
                let v = if dup_later {
                    slots[op].clone()
                } else {
                    slots[op].take()
                };
                let owned = !dup_later && self.plan.unique[op];
                v.context("root operand missing")?.into_tensor(owned)
            })
            .collect()
    }

    /// Execute one instruction.  Returns `None` only for the root tuple.
    fn exec(
        &self,
        idx: usize,
        ins: &Instr,
        inputs: &[&Tensor],
        slots: &mut [Option<Val>],
    ) -> Result<Option<Val>> {
        // Take operands out of their slots at their plan-computed last use
        // so uniquely-owned buffers can be mutated in place downstream.
        // `owned[k]` = the take yields the only handle on the buffer (per
        // the static alias analysis), so in-place mutation is safe.
        let mut args: Vec<Val> = Vec::with_capacity(ins.operands.len());
        let mut owned: Vec<bool> = Vec::with_capacity(ins.operands.len());
        for &op in &ins.operands {
            let take = self.plan.last_use[op] == idx
                && ins.operands.iter().filter(|&&o| o == op).count() == 1;
            let v = if take {
                slots[op].take()
            } else {
                slots[op].clone()
            };
            args.push(v.with_context(|| format!("operand #{op} missing"))?);
            owned.push(take && self.plan.unique[op]);
        }
        let out_shape = ins.shape.as_ref();
        let v = match ins.opcode.as_str() {
            "parameter" => {
                let p = ins.param_idx.context("parameter without number")?;
                Val::from_tensor(inputs[p])
            }
            "constant" => Val::from_literal(
                ins.literal.as_ref().context("constant without literal")?,
                &out_shape.context("constant without shape")?.dims,
            )?,
            "tuple" => return Ok(None),
            "add" => binary(args, &owned, BinOp::Add)?,
            "subtract" => binary(args, &owned, BinOp::Sub)?,
            "multiply" => binary(args, &owned, BinOp::Mul)?,
            "divide" => binary(args, &owned, BinOp::Div)?,
            "maximum" => binary(args, &owned, BinOp::Max)?,
            "minimum" => binary(args, &owned, BinOp::Min)?,
            "power" => binary(args, &owned, BinOp::Pow)?,
            "and" => binary(args, &owned, BinOp::And)?,
            "or" => binary(args, &owned, BinOp::Or)?,
            "xor" => binary(args, &owned, BinOp::Xor)?,
            "shift-left" => binary(args, &owned, BinOp::Shl)?,
            "shift-right-logical" => binary(args, &owned, BinOp::Shr)?,
            "negate" => unary(args, &owned, UnOp::Neg)?,
            "abs" => unary(args, &owned, UnOp::Abs)?,
            "exponential" => unary(args, &owned, UnOp::Exp)?,
            "log" => unary(args, &owned, UnOp::Log)?,
            "tanh" => unary(args, &owned, UnOp::Tanh)?,
            "rsqrt" => unary(args, &owned, UnOp::Rsqrt)?,
            "sqrt" => unary(args, &owned, UnOp::Sqrt)?,
            "sine" => unary(args, &owned, UnOp::Sin)?,
            "cosine" => unary(args, &owned, UnOp::Cos)?,
            "not" => unary(args, &owned, UnOp::Not)?,
            "compare" => compare(args, ins.direction.context("compare without direction")?)?,
            "select" => select(args, &owned)?,
            "convert" => convert(args, out_shape.context("convert without shape")?.dtype)?,
            "broadcast" => broadcast(
                args,
                &ins.dims,
                &out_shape.context("broadcast without shape")?.dims,
            )?,
            "reshape" => {
                let mut v = args.remove_first()?;
                let out = out_shape.context("reshape without shape")?;
                if out.num_elements() != v.len() {
                    bail!("reshape element count mismatch");
                }
                v.dims = out.dims.clone();
                v
            }
            "transpose" => transpose(args, &ins.dims)?,
            "slice" => slice_op(args, &ins.slice)?,
            // a missing dimensions= used to silently mean axis 0 here; the
            // verifier rejects it at compile time and this is the backstop
            "concatenate" => concat(
                args,
                ins.dims
                    .first()
                    .copied()
                    .context("concatenate without dimensions= (no silent axis-0 default)")?,
            )?,
            "pad" => pad(args, &ins.pad_cfg)?,
            "reduce" => {
                let name = ins.to_apply.as_deref().context("reduce without to_apply")?;
                let kind = self.module.reduce_kind(name)?;
                reduce(args, &ins.dims, kind)?
            }
            // absent dimension numbers used to default to an outer product;
            // also rejected by the verifier, error kept as the backstop
            "dot" => dot(
                args,
                ins.dot
                    .clone()
                    .context("dot without dimension numbers (no silent default)")?,
            )?,
            "iota" => iota(
                out_shape.context("iota without shape")?,
                ins.dims.first().copied().context("iota without dimension")?,
            )?,
            "dynamic-slice" => dynamic_slice(args, &ins.dyn_sizes)?,
            "dynamic-update-slice" => dynamic_update_slice(args, &owned)?,
            "gather" => gather(args, ins, out_shape.context("gather without shape")?)?,
            "get-tuple-element" => bail!("tuples only supported at the root"),
            other => bail!("unsupported opcode '{other}'"),
        };
        Ok(Some(v))
    }
}

// ---------------------------------------------------------------------------
// Values
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub enum Data {
    F32(Arc<Vec<f32>>),
    S32(Arc<Vec<i32>>),
    U32(Arc<Vec<u32>>),
    Pred(Arc<Vec<bool>>),
}

#[derive(Debug, Clone)]
pub struct Val {
    pub dims: Vec<usize>,
    pub data: Data,
}

trait ValVec {
    fn remove_first(&mut self) -> Result<Val>;
}

impl ValVec for Vec<Val> {
    fn remove_first(&mut self) -> Result<Val> {
        if self.is_empty() {
            bail!("missing operand");
        }
        Ok(self.remove(0))
    }
}

impl Val {
    pub fn f32(dims: Vec<usize>, v: Vec<f32>) -> Val {
        Val { dims, data: Data::F32(Arc::new(v)) }
    }

    pub fn s32(dims: Vec<usize>, v: Vec<i32>) -> Val {
        Val { dims, data: Data::S32(Arc::new(v)) }
    }

    pub fn u32(dims: Vec<usize>, v: Vec<u32>) -> Val {
        Val { dims, data: Data::U32(Arc::new(v)) }
    }

    pub fn pred(dims: Vec<usize>, v: Vec<bool>) -> Val {
        Val { dims, data: Data::Pred(Arc::new(v)) }
    }

    pub fn len(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> HDtype {
        match &self.data {
            Data::F32(_) => HDtype::F32,
            Data::S32(_) => HDtype::S32,
            Data::U32(_) => HDtype::U32,
            Data::Pred(_) => HDtype::Pred,
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            Data::F32(v) => Ok(v),
            other => bail!("expected f32 value, got {:?}", dtype_of(other)),
        }
    }

    pub fn as_s32(&self) -> Result<&[i32]> {
        match &self.data {
            Data::S32(v) => Ok(v),
            other => bail!("expected s32 value, got {:?}", dtype_of(other)),
        }
    }

    pub fn as_pred(&self) -> Result<&[bool]> {
        match &self.data {
            Data::Pred(v) => Ok(v),
            other => bail!("expected pred value, got {:?}", dtype_of(other)),
        }
    }

    /// f32 buffer for in-place mutation.  `owned` is the static plan's
    /// promise that this handle is the only one — then the unwrap must
    /// succeed, and failure is a planner bug reported loudly.  Without the
    /// promise the buffer is copied (never a guessed `try_unwrap`).
    fn into_f32_owned(self, owned: bool) -> Result<(Vec<usize>, Vec<f32>)> {
        match self.data {
            Data::F32(a) => {
                let v = if owned {
                    Arc::try_unwrap(a).map_err(|_| {
                        anyhow::anyhow!(
                            "static plan marked this buffer unique but it is shared \
                             (planner bug)"
                        )
                    })?
                } else {
                    a.as_ref().clone()
                };
                Ok((self.dims, v))
            }
            other => bail!("expected f32 value, got {:?}", dtype_of(&other)),
        }
    }

    fn from_tensor(t: &Tensor) -> Val {
        match &t.data {
            TensorData::F32(v) => Val::f32(t.shape.clone(), v.clone()),
            TensorData::I32(v) => Val::s32(t.shape.clone(), v.clone()),
            TensorData::U32(v) => Val::u32(t.shape.clone(), v.clone()),
        }
    }

    /// Hand the buffer to a host tensor.  `owned` (from the static plan)
    /// moves the buffer without a copy and treats a shared `Arc` as a
    /// planner bug; `!owned` copies.
    fn into_tensor(self, owned: bool) -> Result<Tensor> {
        let dims = self.dims;
        macro_rules! unwrap_buf {
            ($a:expr) => {
                if owned {
                    Arc::try_unwrap($a).map_err(|_| {
                        anyhow::anyhow!(
                            "static plan marked this output buffer unique but it \
                             is shared (planner bug)"
                        )
                    })?
                } else {
                    $a.as_ref().clone()
                }
            };
        }
        Ok(match self.data {
            Data::F32(a) => Tensor::f32(dims, unwrap_buf!(a)),
            Data::S32(a) => Tensor::i32(dims, unwrap_buf!(a)),
            Data::U32(a) => Tensor::u32(dims, unwrap_buf!(a)),
            Data::Pred(_) => bail!("pred values cannot cross the engine boundary"),
        })
    }

    fn from_literal(lit: &Literal, dims: &[usize]) -> Result<Val> {
        let n: usize = dims.iter().product();
        let check = |len: usize| -> Result<()> {
            if len != n {
                bail!("literal has {len} elements, shape needs {n}");
            }
            Ok(())
        };
        Ok(match lit {
            Literal::F32(v) => {
                check(v.len())?;
                Val::f32(dims.to_vec(), v.clone())
            }
            Literal::S32(v) => {
                check(v.len())?;
                Val::s32(dims.to_vec(), v.clone())
            }
            Literal::U32(v) => {
                check(v.len())?;
                Val::u32(dims.to_vec(), v.clone())
            }
            Literal::Pred(v) => {
                check(v.len())?;
                Val::pred(dims.to_vec(), v.clone())
            }
        })
    }
}

fn dtype_of(d: &Data) -> HDtype {
    match d {
        Data::F32(_) => HDtype::F32,
        Data::S32(_) => HDtype::S32,
        Data::U32(_) => HDtype::U32,
        Data::Pred(_) => HDtype::Pred,
    }
}

// ---------------------------------------------------------------------------
// Index helpers
// ---------------------------------------------------------------------------

/// Row-major strides.
pub fn strides(dims: &[usize]) -> Vec<usize> {
    let mut s = vec![1usize; dims.len()];
    for i in (0..dims.len().saturating_sub(1)).rev() {
        s[i] = s[i + 1] * dims[i + 1];
    }
    s
}

/// Iterate `dims` in row-major order, tracking a source offset through
/// arbitrary per-axis strides (0 for broadcast axes).  O(1) amortized per
/// element.
struct Stepper<'a> {
    dims: &'a [usize],
    strides: &'a [usize],
    counters: Vec<usize>,
    offset: usize,
    done: bool,
}

impl<'a> Stepper<'a> {
    fn new(dims: &'a [usize], strides: &'a [usize]) -> Stepper<'a> {
        Stepper {
            dims,
            strides,
            counters: vec![0; dims.len()],
            offset: 0,
            done: dims.iter().any(|&d| d == 0),
        }
    }

    #[inline]
    fn next(&mut self) -> Option<usize> {
        if self.done {
            return None;
        }
        let cur = self.offset;
        // increment (row-major: last axis fastest)
        let mut axis = self.dims.len();
        loop {
            if axis == 0 {
                self.done = true;
                break;
            }
            axis -= 1;
            self.counters[axis] += 1;
            self.offset += self.strides[axis];
            if self.counters[axis] < self.dims[axis] {
                break;
            }
            self.counters[axis] = 0;
            self.offset -= self.strides[axis] * self.dims[axis];
        }
        Some(cur)
    }
}

// ---------------------------------------------------------------------------
// Elementwise ops
// ---------------------------------------------------------------------------

#[derive(Clone, Copy)]
enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
    Max,
    Min,
    Pow,
    And,
    Or,
    Xor,
    Shl,
    Shr,
}

fn binary(mut args: Vec<Val>, owned: &[bool], op: BinOp) -> Result<Val> {
    let b = args.pop().context("binary op missing rhs")?;
    let a = args.pop().context("binary op missing lhs")?;
    if a.dims != b.dims {
        bail!("elementwise shape mismatch {:?} vs {:?}", a.dims, b.dims);
    }
    match (&a.data, &b.data) {
        (Data::F32(_), Data::F32(_)) => {
            let f: fn(f32, f32) -> f32 = match op {
                BinOp::Add => |x, y| x + y,
                BinOp::Sub => |x, y| x - y,
                BinOp::Mul => |x, y| x * y,
                BinOp::Div => |x, y| x / y,
                BinOp::Max => f32::max,
                BinOp::Min => f32::min,
                BinOp::Pow => f32::powf,
                _ => bail!("bitwise op on f32"),
            };
            // mutate the lhs buffer in place when the plan owns it (hot path)
            let (dims, mut x) = a.into_f32_owned(owned.first().copied().unwrap_or(false))?;
            let rhs = b.as_f32()?;
            for (xi, &yi) in x.iter_mut().zip(rhs.iter()) {
                *xi = f(*xi, yi);
            }
            Ok(Val::f32(dims, x))
        }
        (Data::S32(xa), Data::S32(xb)) => {
            let out: Vec<i32> = xa
                .iter()
                .zip(xb.iter())
                .map(|(&x, &y)| match op {
                    BinOp::Add => x.wrapping_add(y),
                    BinOp::Sub => x.wrapping_sub(y),
                    BinOp::Mul => x.wrapping_mul(y),
                    BinOp::Max => x.max(y),
                    BinOp::Min => x.min(y),
                    _ => 0,
                })
                .collect();
            match op {
                BinOp::Add | BinOp::Sub | BinOp::Mul | BinOp::Max | BinOp::Min => {
                    Ok(Val::s32(a.dims.clone(), out))
                }
                _ => bail!("unsupported s32 binary op"),
            }
        }
        (Data::U32(xa), Data::U32(xb)) => {
            let out: Result<Vec<u32>> = xa
                .iter()
                .zip(xb.iter())
                .map(|(&x, &y)| {
                    Ok(match op {
                        BinOp::Add => x.wrapping_add(y),
                        BinOp::Sub => x.wrapping_sub(y),
                        BinOp::Mul => x.wrapping_mul(y),
                        BinOp::Max => x.max(y),
                        BinOp::Min => x.min(y),
                        BinOp::And => x & y,
                        BinOp::Or => x | y,
                        BinOp::Xor => x ^ y,
                        BinOp::Shl => x.wrapping_shl(y),
                        BinOp::Shr => x.wrapping_shr(y),
                        _ => bail!("unsupported u32 binary op"),
                    })
                })
                .collect();
            Ok(Val::u32(a.dims.clone(), out?))
        }
        (Data::Pred(xa), Data::Pred(xb)) => {
            let out: Result<Vec<bool>> = xa
                .iter()
                .zip(xb.iter())
                .map(|(&x, &y)| {
                    Ok(match op {
                        BinOp::And => x && y,
                        BinOp::Or => x || y,
                        BinOp::Xor => x ^ y,
                        _ => bail!("unsupported pred binary op"),
                    })
                })
                .collect();
            Ok(Val::pred(a.dims.clone(), out?))
        }
        _ => bail!("binary op dtype mismatch {:?} vs {:?}", a.dtype(), b.dtype()),
    }
}

#[derive(Clone, Copy)]
enum UnOp {
    Neg,
    Abs,
    Exp,
    Log,
    Tanh,
    Rsqrt,
    Sqrt,
    Sin,
    Cos,
    Not,
}

fn unary(mut args: Vec<Val>, owned: &[bool], op: UnOp) -> Result<Val> {
    let a = args.remove_first()?;
    match (&a.data, op) {
        (Data::Pred(p), UnOp::Not) => {
            let out: Vec<bool> = p.iter().map(|&x| !x).collect();
            Ok(Val::pred(a.dims.clone(), out))
        }
        (Data::U32(p), UnOp::Not) => {
            let out: Vec<u32> = p.iter().map(|&x| !x).collect();
            Ok(Val::u32(a.dims.clone(), out))
        }
        (Data::S32(p), UnOp::Neg) => {
            let out: Vec<i32> = p.iter().map(|&x| x.wrapping_neg()).collect();
            Ok(Val::s32(a.dims.clone(), out))
        }
        (Data::S32(p), UnOp::Abs) => {
            let out: Vec<i32> = p.iter().map(|&x| x.wrapping_abs()).collect();
            Ok(Val::s32(a.dims.clone(), out))
        }
        (Data::F32(_), _) => {
            let f: fn(f32) -> f32 = match op {
                UnOp::Neg => |x| -x,
                UnOp::Abs => f32::abs,
                UnOp::Exp => f32::exp,
                UnOp::Log => f32::ln,
                UnOp::Tanh => f32::tanh,
                UnOp::Rsqrt => |x| 1.0 / x.sqrt(),
                UnOp::Sqrt => f32::sqrt,
                UnOp::Sin => f32::sin,
                UnOp::Cos => f32::cos,
                UnOp::Not => return Err(anyhow::anyhow!("'not' on f32")),
            };
            let (dims, mut x) = a.into_f32_owned(owned.first().copied().unwrap_or(false))?;
            for xi in x.iter_mut() {
                *xi = f(*xi);
            }
            Ok(Val::f32(dims, x))
        }
        _ => bail!("unsupported unary op on {:?}", a.dtype()),
    }
}

fn compare(mut args: Vec<Val>, dir: CmpDir) -> Result<Val> {
    let b = args.pop().context("compare missing rhs")?;
    let a = args.pop().context("compare missing lhs")?;
    if a.dims != b.dims {
        bail!("compare shape mismatch {:?} vs {:?}", a.dims, b.dims);
    }
    macro_rules! cmp {
        ($xa:expr, $xb:expr) => {
            $xa.iter()
                .zip($xb.iter())
                .map(|(x, y)| match dir {
                    CmpDir::Eq => x == y,
                    CmpDir::Ne => x != y,
                    CmpDir::Lt => x < y,
                    CmpDir::Le => x <= y,
                    CmpDir::Gt => x > y,
                    CmpDir::Ge => x >= y,
                })
                .collect::<Vec<bool>>()
        };
    }
    let out = match (&a.data, &b.data) {
        (Data::F32(xa), Data::F32(xb)) => cmp!(xa, xb),
        (Data::S32(xa), Data::S32(xb)) => cmp!(xa, xb),
        (Data::U32(xa), Data::U32(xb)) => cmp!(xa, xb),
        _ => bail!("compare dtype mismatch"),
    };
    Ok(Val::pred(a.dims.clone(), out))
}

fn select(mut args: Vec<Val>, owned: &[bool]) -> Result<Val> {
    let b = args.pop().context("select missing on-false")?;
    let a = args.pop().context("select missing on-true")?;
    let p = args.pop().context("select missing predicate")?;
    if p.dims != a.dims || a.dims != b.dims {
        bail!("select shape mismatch");
    }
    let pv = p.as_pred()?;
    match (&a.data, &b.data) {
        (Data::F32(_), Data::F32(_)) => {
            // the on-true branch (operand #1) is the in-place candidate
            let (dims, mut x) = a.into_f32_owned(owned.get(1).copied().unwrap_or(false))?;
            let on_false = b.as_f32()?;
            for ((xi, &fi), &pi) in x.iter_mut().zip(on_false.iter()).zip(pv.iter()) {
                if !pi {
                    *xi = fi;
                }
            }
            Ok(Val::f32(dims, x))
        }
        (Data::S32(xa), Data::S32(xb)) => {
            let out: Vec<i32> = pv
                .iter()
                .zip(xa.iter().zip(xb.iter()))
                .map(|(&p, (&x, &y))| if p { x } else { y })
                .collect();
            Ok(Val::s32(a.dims.clone(), out))
        }
        (Data::U32(xa), Data::U32(xb)) => {
            let out: Vec<u32> = pv
                .iter()
                .zip(xa.iter().zip(xb.iter()))
                .map(|(&p, (&x, &y))| if p { x } else { y })
                .collect();
            Ok(Val::u32(a.dims.clone(), out))
        }
        _ => bail!("select dtype mismatch"),
    }
}

fn convert(mut args: Vec<Val>, to: HDtype) -> Result<Val> {
    let a = args.remove_first()?;
    if a.dtype() == to {
        return Ok(a); // zero-copy
    }
    let dims = a.dims.clone();
    macro_rules! conv {
        ($src:expr, $f:expr) => {
            $src.iter().map($f).collect()
        };
    }
    Ok(match (&a.data, to) {
        (Data::Pred(v), HDtype::F32) => Val::f32(dims, conv!(v, |&x| if x { 1.0 } else { 0.0 })),
        (Data::Pred(v), HDtype::S32) => Val::s32(dims, conv!(v, |&x| x as i32)),
        (Data::Pred(v), HDtype::U32) => Val::u32(dims, conv!(v, |&x| x as u32)),
        (Data::S32(v), HDtype::F32) => Val::f32(dims, conv!(v, |&x| x as f32)),
        (Data::U32(v), HDtype::F32) => Val::f32(dims, conv!(v, |&x| x as f32)),
        (Data::S32(v), HDtype::U32) => Val::u32(dims, conv!(v, |&x| x as u32)),
        (Data::U32(v), HDtype::S32) => Val::s32(dims, conv!(v, |&x| x as i32)),
        (Data::F32(v), HDtype::S32) => Val::s32(dims, conv!(v, |&x| x as i32)),
        (Data::F32(v), HDtype::U32) => Val::u32(dims, conv!(v, |&x| x as u32)),
        (src, to) => bail!("unsupported convert {:?} -> {:?}", dtype_of(src), to),
    })
}

// ---------------------------------------------------------------------------
// Shape ops
// ---------------------------------------------------------------------------

fn broadcast(mut args: Vec<Val>, dims_map: &[usize], out_dims: &[usize]) -> Result<Val> {
    let a = args.remove_first()?;
    if dims_map.len() != a.dims.len() {
        bail!(
            "broadcast dims {:?} rank-mismatch input {:?}",
            dims_map,
            a.dims
        );
    }
    for (i, &d) in dims_map.iter().enumerate() {
        if out_dims[d] != a.dims[i] {
            bail!("broadcast dim {i} size mismatch");
        }
    }
    // per-output-axis source strides (0 on new axes)
    let in_strides = strides(&a.dims);
    let mut map_strides = vec![0usize; out_dims.len()];
    for (i, &d) in dims_map.iter().enumerate() {
        map_strides[d] = in_strides[i];
    }
    let n: usize = out_dims.iter().product();
    macro_rules! bc {
        ($src:expr, $mk:path) => {{
            let mut out = Vec::with_capacity(n);
            let mut st = Stepper::new(out_dims, &map_strides);
            while let Some(off) = st.next() {
                out.push($src[off]);
            }
            $mk(out_dims.to_vec(), out)
        }};
    }
    Ok(match &a.data {
        Data::F32(v) => bc!(v, Val::f32),
        Data::S32(v) => bc!(v, Val::s32),
        Data::U32(v) => bc!(v, Val::u32),
        Data::Pred(v) => bc!(v, Val::pred),
    })
}

fn transpose(mut args: Vec<Val>, perm: &[usize]) -> Result<Val> {
    let a = args.remove_first()?;
    if perm.len() != a.dims.len() {
        bail!("transpose perm rank mismatch");
    }
    let out_dims: Vec<usize> = perm.iter().map(|&p| a.dims[p]).collect();
    let in_strides = strides(&a.dims);
    let map_strides: Vec<usize> = perm.iter().map(|&p| in_strides[p]).collect();
    let n = a.len();
    macro_rules! tr {
        ($src:expr, $mk:path) => {{
            let mut out = Vec::with_capacity(n);
            let mut st = Stepper::new(&out_dims, &map_strides);
            while let Some(off) = st.next() {
                out.push($src[off]);
            }
            $mk(out_dims.clone(), out)
        }};
    }
    Ok(match &a.data {
        Data::F32(v) => tr!(v, Val::f32),
        Data::S32(v) => tr!(v, Val::s32),
        Data::U32(v) => tr!(v, Val::u32),
        Data::Pred(v) => tr!(v, Val::pred),
    })
}

fn slice_op(mut args: Vec<Val>, spec: &[(usize, usize, usize)]) -> Result<Val> {
    let a = args.remove_first()?;
    if spec.len() != a.dims.len() {
        bail!("slice spec rank mismatch");
    }
    let out_dims: Vec<usize> = spec
        .iter()
        .map(|&(s, l, st)| {
            if st == 0 {
                bail!("slice stride 0");
            }
            Ok((l.saturating_sub(s) + st - 1) / st)
        })
        .collect::<Result<_>>()?;
    let in_strides = strides(&a.dims);
    let base: usize = spec
        .iter()
        .zip(&in_strides)
        .map(|(&(s, _, _), &str_)| s * str_)
        .sum();
    let map_strides: Vec<usize> = spec
        .iter()
        .zip(&in_strides)
        .map(|(&(_, _, st), &str_)| st * str_)
        .collect();
    let n: usize = out_dims.iter().product();
    macro_rules! sl {
        ($src:expr, $mk:path) => {{
            let mut out = Vec::with_capacity(n);
            let mut st = Stepper::new(&out_dims, &map_strides);
            while let Some(off) = st.next() {
                out.push($src[base + off]);
            }
            $mk(out_dims.clone(), out)
        }};
    }
    Ok(match &a.data {
        Data::F32(v) => sl!(v, Val::f32),
        Data::S32(v) => sl!(v, Val::s32),
        Data::U32(v) => sl!(v, Val::u32),
        Data::Pred(v) => sl!(v, Val::pred),
    })
}

fn concat(args: Vec<Val>, dim: usize) -> Result<Val> {
    if args.is_empty() {
        bail!("concatenate with no operands");
    }
    let rank = args[0].dims.len();
    if dim >= rank {
        bail!("concatenate dim out of range");
    }
    let mut out_dims = args[0].dims.clone();
    out_dims[dim] = args.iter().map(|a| a.dims[dim]).sum();
    for a in &args {
        for (i, (&x, &y)) in a.dims.iter().zip(&out_dims).enumerate() {
            if i != dim && x != y {
                bail!("concatenate shape mismatch off-axis");
            }
        }
    }
    let outer: usize = out_dims[..dim].iter().product();
    macro_rules! cc {
        ($variant:path, $mk:path, $t:ty) => {{
            let mut out: Vec<$t> = Vec::with_capacity(out_dims.iter().product());
            for o in 0..outer {
                for a in &args {
                    let chunk: usize = a.dims[dim..].iter().product();
                    let src = match &a.data {
                        $variant(v) => v,
                        _ => bail!("concatenate dtype mismatch"),
                    };
                    out.extend_from_slice(&src[o * chunk..(o + 1) * chunk]);
                }
            }
            $mk(out_dims.clone(), out)
        }};
    }
    Ok(match &args[0].data {
        Data::F32(_) => cc!(Data::F32, Val::f32, f32),
        Data::S32(_) => cc!(Data::S32, Val::s32, i32),
        Data::U32(_) => cc!(Data::U32, Val::u32, u32),
        Data::Pred(_) => cc!(Data::Pred, Val::pred, bool),
    })
}

fn pad(mut args: Vec<Val>, cfg: &[(i64, i64, i64)]) -> Result<Val> {
    let pad_val = args.pop().context("pad missing value")?;
    let a = args.pop().context("pad missing operand")?;
    if cfg.len() != a.dims.len() {
        bail!("pad spec rank mismatch");
    }
    if cfg.iter().any(|&(l, h, i)| l < 0 || h < 0 || i != 0) {
        bail!("negative/interior padding unsupported");
    }
    let out_dims: Vec<usize> = a
        .dims
        .iter()
        .zip(cfg)
        .map(|(&d, &(l, h, _))| d + l as usize + h as usize)
        .collect();
    let out_strides = strides(&out_dims);
    let base: usize = cfg
        .iter()
        .zip(&out_strides)
        .map(|(&(l, _, _), &s)| l as usize * s)
        .sum();
    let n: usize = out_dims.iter().product();
    macro_rules! pd {
        ($src:expr, $pv:expr, $mk:path) => {{
            let fill = $pv[0];
            let mut out = vec![fill; n];
            let mut st = Stepper::new(&a.dims, &out_strides);
            let mut i = 0usize;
            while let Some(off) = st.next() {
                out[base + off] = $src[i];
                i += 1;
            }
            $mk(out_dims.clone(), out)
        }};
    }
    Ok(match (&a.data, &pad_val.data) {
        (Data::F32(v), Data::F32(p)) => pd!(v, p, Val::f32),
        (Data::S32(v), Data::S32(p)) => pd!(v, p, Val::s32),
        (Data::U32(v), Data::U32(p)) => pd!(v, p, Val::u32),
        _ => bail!("pad dtype mismatch"),
    })
}

fn reduce(mut args: Vec<Val>, dims: &[usize], kind: ReduceKind) -> Result<Val> {
    let init = args.pop().context("reduce missing init")?;
    let a = args.pop().context("reduce missing operand")?;
    let reduce_set: Vec<bool> = (0..a.dims.len()).map(|i| dims.contains(&i)).collect();
    let out_dims: Vec<usize> = a
        .dims
        .iter()
        .enumerate()
        .filter(|(i, _)| !reduce_set[*i])
        .map(|(_, &d)| d)
        .collect();
    let out_strides_full = strides(&out_dims);
    // per-input-axis contribution to the output offset (0 on reduced axes)
    let mut map = vec![0usize; a.dims.len()];
    let mut k = 0;
    for i in 0..a.dims.len() {
        if !reduce_set[i] {
            map[i] = out_strides_full[k];
            k += 1;
        }
    }
    let n_out: usize = out_dims.iter().product();
    macro_rules! red {
        ($src:expr, $iv:expr, $mk:path, $t:ty, $comb:expr) => {{
            let comb: fn($t, $t) -> $t = $comb;
            let mut out = vec![$iv[0]; n_out];
            let mut st = Stepper::new(&a.dims, &map);
            let mut i = 0usize;
            while let Some(off) = st.next() {
                out[off] = comb(out[off], $src[i]);
                i += 1;
            }
            $mk(out_dims.clone(), out)
        }};
    }
    Ok(match (&a.data, &init.data) {
        (Data::F32(v), Data::F32(iv)) => match kind {
            ReduceKind::Add => red!(v, iv, Val::f32, f32, |x, y| x + y),
            ReduceKind::Max => red!(v, iv, Val::f32, f32, f32::max),
            ReduceKind::Min => red!(v, iv, Val::f32, f32, f32::min),
        },
        (Data::S32(v), Data::S32(iv)) => match kind {
            ReduceKind::Add => red!(v, iv, Val::s32, i32, |x, y| x.wrapping_add(y)),
            ReduceKind::Max => red!(v, iv, Val::s32, i32, i32::max),
            ReduceKind::Min => red!(v, iv, Val::s32, i32, i32::min),
        },
        (Data::U32(v), Data::U32(iv)) => match kind {
            ReduceKind::Add => red!(v, iv, Val::u32, u32, |x, y| x.wrapping_add(y)),
            ReduceKind::Max => red!(v, iv, Val::u32, u32, u32::max),
            ReduceKind::Min => red!(v, iv, Val::u32, u32, u32::min),
        },
        _ => bail!("reduce dtype mismatch"),
    })
}

fn iota(shape: &HShape, dim: usize) -> Result<Val> {
    if dim >= shape.dims.len() {
        bail!("iota dimension out of range");
    }
    let dims = shape.dims.clone();
    let n = shape.num_elements();
    let st = strides(&dims);
    let size = dims[dim];
    let stride = st[dim];
    macro_rules! io {
        ($t:ty, $mk:path) => {{
            let mut out = vec![0 as $t; n];
            for (i, o) in out.iter_mut().enumerate() {
                *o = ((i / stride) % size) as $t;
            }
            $mk(dims.clone(), out)
        }};
    }
    Ok(match shape.dtype {
        HDtype::S32 => io!(i32, Val::s32),
        HDtype::U32 => io!(u32, Val::u32),
        HDtype::F32 => io!(f32, Val::f32),
        HDtype::Pred => bail!("pred iota unsupported"),
    })
}

// ---------------------------------------------------------------------------
// Dot
// ---------------------------------------------------------------------------

/// Materialize `a` with its axes permuted into `order` (row-major).
/// Zero-copy when `order` is already the identity — the canonical layouts
/// the emitter produces hit that path on the hot matmuls.
fn regroup_f32(a: &Val, order: &[usize]) -> Result<Arc<Vec<f32>>> {
    let identity = order.iter().enumerate().all(|(i, &o)| i == o);
    match &a.data {
        Data::F32(v) => {
            if identity {
                Ok(v.clone())
            } else {
                let dims_out: Vec<usize> = order.iter().map(|&i| a.dims[i]).collect();
                let in_strides = strides(&a.dims);
                let map: Vec<usize> = order.iter().map(|&i| in_strides[i]).collect();
                let mut out = Vec::with_capacity(a.len());
                let mut st = Stepper::new(&dims_out, &map);
                while let Some(off) = st.next() {
                    out.push(v[off]);
                }
                Ok(Arc::new(out))
            }
        }
        _ => bail!("dot requires f32 operands"),
    }
}

fn dot(mut args: Vec<Val>, dd: DotDims) -> Result<Val> {
    let rhs = args.pop().context("dot missing rhs")?;
    let lhs = args.pop().context("dot missing lhs")?;
    let lhs_free: Vec<usize> = (0..lhs.dims.len())
        .filter(|i| !dd.lhs_batch.contains(i) && !dd.lhs_contract.contains(i))
        .collect();
    let rhs_free: Vec<usize> = (0..rhs.dims.len())
        .filter(|i| !dd.rhs_batch.contains(i) && !dd.rhs_contract.contains(i))
        .collect();
    for (&lb, &rb) in dd.lhs_batch.iter().zip(&dd.rhs_batch) {
        if lhs.dims[lb] != rhs.dims[rb] {
            bail!("dot batch dim mismatch");
        }
    }
    for (&lc, &rc) in dd.lhs_contract.iter().zip(&dd.rhs_contract) {
        if lhs.dims[lc] != rhs.dims[rc] {
            bail!("dot contracting dim mismatch");
        }
    }

    // regroup to lhs [batch..., free..., contract...] and
    // rhs [batch..., contract..., free...]
    let lorder: Vec<usize> = dd
        .lhs_batch
        .iter()
        .chain(&lhs_free)
        .chain(&dd.lhs_contract)
        .copied()
        .collect();
    let rorder: Vec<usize> = dd
        .rhs_batch
        .iter()
        .chain(&dd.rhs_contract)
        .chain(&rhs_free)
        .copied()
        .collect();
    let ldata = regroup_f32(&lhs, &lorder)?;
    let rdata = regroup_f32(&rhs, &rorder)?;

    let nb: usize = dd.lhs_batch.iter().map(|&i| lhs.dims[i]).product();
    let m: usize = lhs_free.iter().map(|&i| lhs.dims[i]).product();
    let k: usize = dd.lhs_contract.iter().map(|&i| lhs.dims[i]).product();
    let n: usize = rhs_free.iter().map(|&i| rhs.dims[i]).product();

    let mut out = vec![0f32; nb * m * n];
    for b in 0..nb {
        let lbase = b * m * k;
        let rbase = b * k * n;
        let obase = b * m * n;
        for mi in 0..m {
            let lrow = &ldata[lbase + mi * k..lbase + (mi + 1) * k];
            let orow = &mut out[obase + mi * n..obase + (mi + 1) * n];
            for (ki, &a) in lrow.iter().enumerate() {
                // Deliberate deviation from strict IEEE dot semantics: an
                // exactly-zero lhs element contributes nothing, even
                // against a non-finite rhs row (XLA would produce NaN from
                // 0·inf).  This makes one-hot embedding matmuls O(rows)
                // instead of O(rows·V), and every fixture artifact is
                // finite-valued, so the two semantics agree there
                // (asserted by the jax goldens + interp==pjrt tests).
                if a == 0.0 {
                    continue;
                }
                let rrow = &rdata[rbase + ki * n..rbase + (ki + 1) * n];
                for (o, &r) in orow.iter_mut().zip(rrow.iter()) {
                    *o += a * r;
                }
            }
        }
    }
    let mut out_dims: Vec<usize> = dd.lhs_batch.iter().map(|&i| lhs.dims[i]).collect();
    out_dims.extend(lhs_free.iter().map(|&i| lhs.dims[i]));
    out_dims.extend(rhs_free.iter().map(|&i| rhs.dims[i]));
    Ok(Val::f32(out_dims, out))
}

// ---------------------------------------------------------------------------
// Dynamic slice / update
// ---------------------------------------------------------------------------

fn start_indices(args: &[Val], rank: usize) -> Result<Vec<usize>> {
    if args.len() != rank {
        bail!("expected {rank} start indices, got {}", args.len());
    }
    args.iter()
        .map(|v| {
            if !v.dims.is_empty() {
                bail!("start index must be scalar");
            }
            Ok(match &v.data {
                Data::S32(x) => x[0].max(0) as usize,
                Data::U32(x) => x[0] as usize,
                _ => bail!("start index must be integer"),
            })
        })
        .collect()
}

fn dynamic_slice(mut args: Vec<Val>, sizes: &[usize]) -> Result<Val> {
    if args.is_empty() {
        bail!("dynamic-slice missing operand");
    }
    let a = args.remove(0);
    let starts = start_indices(&args, a.dims.len())?;
    let spec: Vec<(usize, usize, usize)> = starts
        .iter()
        .zip(sizes)
        .zip(&a.dims)
        .map(|((&s, &sz), &d)| {
            let s = s.min(d.saturating_sub(sz));
            (s, s + sz, 1)
        })
        .collect();
    slice_op(vec![a], &spec)
}

fn dynamic_update_slice(mut args: Vec<Val>, owned: &[bool]) -> Result<Val> {
    if args.len() < 2 {
        bail!("dynamic-update-slice missing operands");
    }
    let base_owned = owned.first().copied().unwrap_or(false);
    let base = args.remove(0);
    let update = args.remove(0);
    if base.dtype() != update.dtype() {
        bail!("dynamic-update-slice dtype mismatch");
    }
    let starts = start_indices(&args, base.dims.len())?;
    let starts: Vec<usize> = starts
        .iter()
        .zip(&update.dims)
        .zip(&base.dims)
        .map(|((&s, &u), &d)| s.min(d.saturating_sub(u)))
        .collect();
    let base_dims = base.dims.clone();
    let base_strides = strides(&base_dims);
    let offset: usize = starts.iter().zip(&base_strides).map(|(&s, &st)| s * st).sum();
    // Merge trailing axes into one contiguous run: axis i joins while its
    // base stride equals the run built inside it (innermost always does).
    // The KV decode hot path ([L,B,H,1,D] into [L,B,H,S,D]) then moves
    // d_head-sized blocks per step instead of scalars.
    let mut run = 1usize;
    let mut outer = update.dims.len();
    while outer > 0 && base_strides[outer - 1] == run {
        run *= update.dims[outer - 1];
        outer -= 1;
    }
    macro_rules! dus {
        ($variant:path, $mk:path, $t:ty) => {{
            let upd: &[$t] = match &update.data {
                $variant(v) => v,
                _ => bail!("dynamic-update-slice dtype mismatch"),
            };
            let arc = match base.data {
                $variant(a) => a,
                _ => unreachable!(),
            };
            // in place when the plan owns the base (the decode-loop hot
            // path); a broken ownership promise errors instead of copying
            let mut buf = if base_owned {
                match Arc::try_unwrap(arc) {
                    Ok(v) => v,
                    Err(_) => bail!(
                        "static plan marked the update base unique but it is \
                         shared (planner bug)"
                    ),
                }
            } else {
                arc.as_ref().clone()
            };
            let mut st = Stepper::new(&update.dims[..outer], &base_strides[..outer]);
            let mut i = 0usize;
            while let Some(off) = st.next() {
                buf[offset + off..offset + off + run].copy_from_slice(&upd[i..i + run]);
                i += run;
            }
            $mk(base_dims.clone(), buf)
        }};
    }
    Ok(match &update.data {
        Data::F32(_) => dus!(Data::F32, Val::f32, f32),
        Data::S32(_) => dus!(Data::S32, Val::s32, i32),
        Data::U32(_) => dus!(Data::U32, Val::u32, u32),
        Data::Pred(_) => dus!(Data::Pred, Val::pred, bool),
    })
}

// ---------------------------------------------------------------------------
// Gather (the embedding-lookup / take-along-axis subset)
// ---------------------------------------------------------------------------

fn gather(mut args: Vec<Val>, ins: &Instr, out_shape: &HShape) -> Result<Val> {
    let g = ins.gather.as_ref().context("gather without dimension numbers")?;
    let indices = args.pop().context("gather missing indices")?;
    let operand = args.pop().context("gather missing operand")?;
    let orank = operand.dims.len();
    if g.slice_sizes.len() != orank {
        bail!("gather slice_sizes rank mismatch");
    }
    for (&sz, &d) in g.slice_sizes.iter().zip(&operand.dims) {
        if sz > d {
            bail!("gather slice size exceeds operand dim");
        }
    }
    // indices batch shape: indices dims with index_vector_dim removed
    // (index_vector_dim == rank means implicit trailing 1)
    let mut batch_dims: Vec<usize> = indices.dims.clone();
    let ncomp = if g.index_vector_dim < indices.dims.len() {
        batch_dims.remove(g.index_vector_dim)
    } else {
        1
    };
    if ncomp != g.start_index_map.len() {
        bail!("gather index components {} != start_index_map", ncomp);
    }
    let idx_i32 = indices.as_s32()?;
    let idx_strides = strides(&indices.dims);
    let comp_stride = if g.index_vector_dim < indices.dims.len() {
        idx_strides[g.index_vector_dim]
    } else {
        0
    };
    // strides of the batch portion within the indices buffer
    let batch_strides: Vec<usize> = (0..indices.dims.len())
        .filter(|&i| i != g.index_vector_dim)
        .map(|i| idx_strides[i])
        .collect();

    // offset dims of the output map to non-collapsed operand dims, in order
    let offset_operand_dims: Vec<usize> =
        (0..orank).filter(|i| !g.collapsed_slice_dims.contains(i)).collect();
    if g.offset_dims.len() != offset_operand_dims.len() {
        bail!("gather offset_dims/collapsed mismatch");
    }
    let out_dims = out_shape.dims.clone();
    let out_batch_axes: Vec<usize> =
        (0..out_dims.len()).filter(|a| !g.offset_dims.contains(a)).collect();
    if out_batch_axes.len() != batch_dims.len() {
        bail!("gather output batch rank mismatch");
    }
    let op_strides = strides(&operand.dims);
    let src = operand.as_f32()?;

    let n: usize = out_dims.iter().product();
    let mut out = Vec::with_capacity(n);
    let out_strides_ = strides(&out_dims);
    for lin in 0..n {
        // decompose output index
        let mut start_off = 0usize; // offset from gathered start indices
        let mut in_slice_off = 0usize; // offset within the slice
        let mut batch_lin = 0usize;
        for (axis, &od) in out_dims.iter().enumerate() {
            let coord = (lin / out_strides_[axis]) % od;
            if let Some(k) = g.offset_dims.iter().position(|&a| a == axis) {
                in_slice_off += coord * op_strides[offset_operand_dims[k]];
            } else {
                // every non-offset output axis is a batch axis (verified
                // statically: offset_dims ∪ batch axes cover the output)
                let b = out_batch_axes
                    .iter()
                    .position(|&a| a == axis)
                    .with_context(|| {
                        format!("gather output axis {axis} is neither offset nor batch")
                    })?;
                batch_lin += coord * batch_strides[b];
            }
        }
        for (c, &od) in g.start_index_map.iter().enumerate() {
            let raw = idx_i32[batch_lin + c * comp_stride].max(0) as usize;
            let clamped = raw.min(operand.dims[od] - g.slice_sizes[od]);
            start_off += clamped * op_strides[od];
        }
        out.push(src[start_off + in_slice_off]);
    }
    Ok(Val::f32(out_dims, out))
}

#[cfg(test)]
mod tests {
    #![allow(clippy::disallowed_methods)]

    use super::*;

    fn run(text: &str, inputs: &[Tensor]) -> Vec<Tensor> {
        Program::parse(text).unwrap().evaluate(inputs).unwrap()
    }

    #[test]
    fn elementwise_and_broadcast() {
        let text = r#"ENTRY %m (a: f32[2,3], s: f32[]) -> (f32[2,3]) {
  %a = f32[2,3] parameter(0)
  %s = f32[] parameter(1)
  %sb = f32[2,3] broadcast(f32[] %s), dimensions={}
  %x = f32[2,3] multiply(f32[2,3] %a, f32[2,3] %sb)
  %e = f32[2,3] exponential(f32[2,3] %x)
  ROOT %t = (f32[2,3]) tuple(f32[2,3] %e)
}
"#;
        let a = Tensor::f32(vec![2, 3], vec![0.0, 1.0, -1.0, 2.0, 0.5, -0.5]);
        let out = run(text, &[a.clone(), Tensor::scalar_f32(2.0)]);
        let got = out[0].as_f32().unwrap();
        for (g, x) in got.iter().zip(a.as_f32().unwrap()) {
            assert_eq!(*g, (2.0 * x).exp());
        }
    }

    #[test]
    fn row_broadcast_matches_dims_mapping() {
        let text = r#"ENTRY %m (v: f32[3]) -> (f32[2,3], f32[3,2]) {
  %v = f32[3] parameter(0)
  %r = f32[2,3] broadcast(f32[3] %v), dimensions={1}
  %c = f32[3,2] broadcast(f32[3] %v), dimensions={0}
  ROOT %t = (f32[2,3], f32[3,2]) tuple(f32[2,3] %r, f32[3,2] %c)
}
"#;
        let out = run(text, &[Tensor::f32(vec![3], vec![1.0, 2.0, 3.0])]);
        assert_eq!(out[0].as_f32().unwrap(), &[1., 2., 3., 1., 2., 3.]);
        assert_eq!(out[1].as_f32().unwrap(), &[1., 1., 2., 2., 3., 3.]);
    }

    #[test]
    fn reduce_sum_and_max() {
        let text = r#"%radd (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(f32[] %a, f32[] %b)
}

%rmax (c: f32[], d: f32[]) -> f32[] {
  %c = f32[] parameter(0)
  %d = f32[] parameter(1)
  ROOT %r2 = f32[] maximum(f32[] %c, f32[] %d)
}

ENTRY %m (x: f32[2,3]) -> (f32[2], f32[3], f32[]) {
  %x = f32[2,3] parameter(0)
  %zero = f32[] constant(0)
  %ninf = f32[] constant(-inf)
  %rows = f32[2] reduce(f32[2,3] %x, f32[] %zero), dimensions={1}, to_apply=%radd
  %cols = f32[3] reduce(f32[2,3] %x, f32[] %ninf), dimensions={0}, to_apply=%rmax
  %all = f32[] reduce(f32[2,3] %x, f32[] %zero), dimensions={0,1}, to_apply=%radd
  ROOT %t = (f32[2], f32[3], f32[]) tuple(f32[2] %rows, f32[3] %cols, f32[] %all)
}
"#;
        let x = Tensor::f32(vec![2, 3], vec![1., -2., 3., 4., 5., -6.]);
        let out = run(text, &[x]);
        assert_eq!(out[0].as_f32().unwrap(), &[2.0, 3.0]);
        assert_eq!(out[1].as_f32().unwrap(), &[4.0, 5.0, 3.0]);
        assert_eq!(out[2].as_f32().unwrap(), &[5.0]);
    }

    #[test]
    fn dot_plain_and_batched() {
        let text = r#"ENTRY %m (a: f32[2,3], b: f32[3,4], q: f32[2,2,3], k: f32[2,4,3]) -> (f32[2,4], f32[2,2,4]) {
  %a = f32[2,3] parameter(0)
  %b = f32[3,4] parameter(1)
  %q = f32[2,2,3] parameter(2)
  %k = f32[2,4,3] parameter(3)
  %mm = f32[2,4] dot(f32[2,3] %a, f32[3,4] %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %bmm = f32[2,2,4] dot(f32[2,2,3] %q, f32[2,4,3] %k), lhs_batch_dims={0}, rhs_batch_dims={0}, lhs_contracting_dims={2}, rhs_contracting_dims={2}
  ROOT %t = (f32[2,4], f32[2,2,4]) tuple(f32[2,4] %mm, f32[2,2,4] %bmm)
}
"#;
        let a = Tensor::f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let b = Tensor::f32(vec![3, 4], (0..12).map(|i| i as f32).collect());
        let q = Tensor::f32(vec![2, 2, 3], (0..12).map(|i| (i % 5) as f32).collect());
        let k = Tensor::f32(vec![2, 4, 3], (0..24).map(|i| (i % 7) as f32 - 3.0).collect());
        let out = run(text, &[a.clone(), b.clone(), q.clone(), k.clone()]);
        // reference mm
        let (av, bv) = (a.as_f32().unwrap(), b.as_f32().unwrap());
        for i in 0..2 {
            for j in 0..4 {
                let want: f32 = (0..3).map(|l| av[i * 3 + l] * bv[l * 4 + j]).sum();
                assert_eq!(out[0].as_f32().unwrap()[i * 4 + j], want);
            }
        }
        // reference bmm: q[b,i,:] · k[b,j,:]
        let (qv, kv) = (q.as_f32().unwrap(), k.as_f32().unwrap());
        for bb in 0..2 {
            for i in 0..2 {
                for j in 0..4 {
                    let want: f32 = (0..3)
                        .map(|l| qv[bb * 6 + i * 3 + l] * kv[bb * 12 + j * 3 + l])
                        .sum();
                    assert_eq!(out[1].as_f32().unwrap()[bb * 8 + i * 4 + j], want);
                }
            }
        }
    }

    #[test]
    fn transpose_slice_concat_pad() {
        let text = r#"ENTRY %m (x: f32[2,3]) -> (f32[3,2], f32[2,2], f32[2,5], f32[4,3]) {
  %x = f32[2,3] parameter(0)
  %zero = f32[] constant(9)
  %tr = f32[3,2] transpose(f32[2,3] %x), dimensions={1,0}
  %sl = f32[2,2] slice(f32[2,3] %x), slice={[0:2], [1:3]}
  %cc = f32[2,5] concatenate(f32[2,3] %x, f32[2,2] %sl), dimensions={1}
  %pd = f32[4,3] pad(f32[2,3] %x, f32[] %zero), padding=1_1x0_0
  ROOT %t = (f32[3,2], f32[2,2], f32[2,5], f32[4,3]) tuple(f32[3,2] %tr, f32[2,2] %sl, f32[2,5] %cc, f32[4,3] %pd)
}
"#;
        let x = Tensor::f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let out = run(text, &[x]);
        assert_eq!(out[0].as_f32().unwrap(), &[1., 4., 2., 5., 3., 6.]);
        assert_eq!(out[1].as_f32().unwrap(), &[2., 3., 5., 6.]);
        assert_eq!(out[2].as_f32().unwrap(), &[1., 2., 3., 2., 3., 4., 5., 6., 5., 6.]);
        assert_eq!(
            out[3].as_f32().unwrap(),
            &[9., 9., 9., 1., 2., 3., 4., 5., 6., 9., 9., 9.]
        );
    }

    #[test]
    fn iota_compare_select_convert() {
        let text = r#"ENTRY %m (x: s32[4]) -> (f32[4]) {
  %x = s32[4] parameter(0)
  %i = s32[4] iota(), iota_dimension=0
  %p = pred[4] compare(s32[4] %i, s32[4] %x), direction=LE
  %pf = f32[4] convert(pred[4] %p)
  %xf = f32[4] convert(s32[4] %x)
  %sel = f32[4] select(pred[4] %p, f32[4] %xf, f32[4] %pf)
  ROOT %t = (f32[4]) tuple(f32[4] %sel)
}
"#;
        let x = Tensor::i32(vec![4], vec![2, 0, 1, 5]);
        let out = run(text, &[x]);
        // i = [0,1,2,3]; p = i<=x = [T,F,F,T]; sel = [2, 0, 0, 5]
        assert_eq!(out[0].as_f32().unwrap(), &[2.0, 0.0, 0.0, 5.0]);
    }

    #[test]
    fn dynamic_slice_and_update() {
        let text = r#"ENTRY %m (x: f32[2,4], u: f32[2,1], p: s32[]) -> (f32[2,2], f32[2,4]) {
  %x = f32[2,4] parameter(0)
  %u = f32[2,1] parameter(1)
  %p = s32[] parameter(2)
  %z = s32[] constant(0)
  %ds = f32[2,2] dynamic-slice(f32[2,4] %x, s32[] %z, s32[] %p), dynamic_slice_sizes={2,2}
  %du = f32[2,4] dynamic-update-slice(f32[2,4] %x, f32[2,1] %u, s32[] %z, s32[] %p)
  ROOT %t = (f32[2,2], f32[2,4]) tuple(f32[2,2] %ds, f32[2,4] %du)
}
"#;
        let x = Tensor::f32(vec![2, 4], (0..8).map(|i| i as f32).collect());
        let u = Tensor::f32(vec![2, 1], vec![100.0, 200.0]);
        let out = run(text, &[x, u, Tensor::scalar_i32(1)]);
        assert_eq!(out[0].as_f32().unwrap(), &[1., 2., 5., 6.]);
        assert_eq!(out[1].as_f32().unwrap(), &[0., 100., 2., 3., 4., 200., 6., 7.]);
    }

    #[test]
    fn gather_embedding_lookup() {
        // tok_emb[V=4, D=2] gathered at indices [3] → [3, 2]
        let text = r#"ENTRY %m (e: f32[4,2], ix: s32[3]) -> (f32[3,2]) {
  %e = f32[4,2] parameter(0)
  %ix = s32[3] parameter(1)
  %g = f32[3,2] gather(f32[4,2] %e, s32[3] %ix), offset_dims={1}, collapsed_slice_dims={0}, start_index_map={0}, index_vector_dim=1, slice_sizes={1,2}
  ROOT %t = (f32[3,2]) tuple(f32[3,2] %g)
}
"#;
        let e = Tensor::f32(vec![4, 2], vec![0., 1., 10., 11., 20., 21., 30., 31.]);
        let ix = Tensor::i32(vec![3], vec![2, 0, 3]);
        let out = run(text, &[e, ix]);
        assert_eq!(out[0].as_f32().unwrap(), &[20., 21., 0., 1., 30., 31.]);
    }

    #[test]
    fn u32_hash_ops() {
        let text = r#"ENTRY %m (s: u32[]) -> (u32[4]) {
  %s = u32[] parameter(0)
  %i = u32[4] iota(), iota_dimension=0
  %sb = u32[4] broadcast(u32[] %s), dimensions={}
  %x0 = u32[4] add(u32[4] %i, u32[4] %sb)
  %c = u32[] constant(2654435761)
  %cb = u32[4] broadcast(u32[] %c), dimensions={}
  %x1 = u32[4] multiply(u32[4] %x0, u32[4] %cb)
  %sh = u32[] constant(16)
  %shb = u32[4] broadcast(u32[] %sh), dimensions={}
  %x2 = u32[4] shift-right-logical(u32[4] %x1, u32[4] %shb)
  %x3 = u32[4] xor(u32[4] %x1, u32[4] %x2)
  ROOT %t = (u32[4]) tuple(u32[4] %x3)
}
"#;
        let out = run(text, &[Tensor::scalar_u32(7)]);
        let got = match &out[0].data {
            TensorData::U32(v) => v.clone(),
            _ => panic!("expected u32"),
        };
        let want: Vec<u32> = (0..4u32)
            .map(|i| {
                let x = i.wrapping_add(7).wrapping_mul(2654435761);
                x ^ (x >> 16)
            })
            .collect();
        assert_eq!(got, want);
    }

    #[test]
    fn softmax_composed_from_primitives() {
        let text = r#"%radd (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(f32[] %a, f32[] %b)
}

%rmax (c: f32[], d: f32[]) -> f32[] {
  %c = f32[] parameter(0)
  %d = f32[] parameter(1)
  ROOT %r2 = f32[] maximum(f32[] %c, f32[] %d)
}

ENTRY %m (x: f32[2,4]) -> (f32[2,4]) {
  %x = f32[2,4] parameter(0)
  %ninf = f32[] constant(-inf)
  %zero = f32[] constant(0)
  %mx = f32[2] reduce(f32[2,4] %x, f32[] %ninf), dimensions={1}, to_apply=%rmax
  %mxb = f32[2,4] broadcast(f32[2] %mx), dimensions={0}
  %sub = f32[2,4] subtract(f32[2,4] %x, f32[2,4] %mxb)
  %ex = f32[2,4] exponential(f32[2,4] %sub)
  %sm = f32[2] reduce(f32[2,4] %ex, f32[] %zero), dimensions={1}, to_apply=%radd
  %smb = f32[2,4] broadcast(f32[2] %sm), dimensions={0}
  %p = f32[2,4] divide(f32[2,4] %ex, f32[2,4] %smb)
  ROOT %t = (f32[2,4]) tuple(f32[2,4] %p)
}
"#;
        let x = Tensor::f32(vec![2, 4], vec![1., 2., 3., 4., -1., 0., 1., 2.]);
        let out = Program::parse(text).unwrap().evaluate(&[x.clone()]).unwrap();
        let xd = x.as_f32().unwrap();
        for r in 0..2 {
            let row = &xd[r * 4..(r + 1) * 4];
            let mx = row.iter().fold(f32::MIN, |a, &b| a.max(b));
            let ex: Vec<f32> = row.iter().map(|&v| (v - mx).exp()).collect();
            let s: f32 = ex.iter().sum();
            for c in 0..4 {
                let got = out[0].as_f32().unwrap()[r * 4 + c];
                assert!((got - ex[c] / s).abs() < 1e-7, "{got} vs {}", ex[c] / s);
            }
        }
    }

    #[test]
    fn arity_mismatch_is_error() {
        let p = Program::parse(
            "ENTRY %m (a: f32[1]) -> (f32[1]) {\n  %a = f32[1] parameter(0)\n  ROOT %t = (f32[1]) tuple(f32[1] %a)\n}\n",
        )
        .unwrap();
        assert!(p.evaluate(&[]).is_err());
    }
}
