//! HLO-text parser: the interchange format emitted by `python/compile/aot.py`
//! (and by [`super::builder`]) → an executable [`HloModule`].
//!
//! This is deliberately a *practical* parser, not a full grammar: it covers
//! the instruction syntax XLA's `HloModule::ToString` emits for the op set
//! the artifact sets use, and fails loudly (with the offending line) on
//! anything else — a silent mis-parse would corrupt training numerics.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

/// Element types the artifact contract uses (`pred` appears only as an
/// intermediate inside modules; manifest I/O is f32/s32/u32).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HDtype {
    F32,
    S32,
    U32,
    Pred,
}

impl HDtype {
    pub fn parse(s: &str) -> Result<HDtype> {
        Ok(match s {
            "f32" => HDtype::F32,
            "s32" => HDtype::S32,
            "u32" => HDtype::U32,
            "pred" => HDtype::Pred,
            other => bail!("unsupported element type '{other}'"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            HDtype::F32 => "f32",
            HDtype::S32 => "s32",
            HDtype::U32 => "u32",
            HDtype::Pred => "pred",
        }
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HShape {
    pub dtype: HDtype,
    pub dims: Vec<usize>,
}

impl HShape {
    pub fn num_elements(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn to_text(&self) -> String {
        let dims: Vec<String> = self.dims.iter().map(|d| d.to_string()).collect();
        format!("{}[{}]", self.dtype.name(), dims.join(","))
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CmpDir {
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
}

impl CmpDir {
    pub fn parse(s: &str) -> Result<CmpDir> {
        Ok(match s {
            "EQ" => CmpDir::Eq,
            "NE" => CmpDir::Ne,
            "LT" => CmpDir::Lt,
            "LE" => CmpDir::Le,
            "GT" => CmpDir::Gt,
            "GE" => CmpDir::Ge,
            other => bail!("unknown compare direction '{other}'"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            CmpDir::Eq => "EQ",
            CmpDir::Ne => "NE",
            CmpDir::Lt => "LT",
            CmpDir::Le => "LE",
            CmpDir::Gt => "GT",
            CmpDir::Ge => "GE",
        }
    }
}

/// `dot` dimension numbers.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct DotDims {
    pub lhs_batch: Vec<usize>,
    pub rhs_batch: Vec<usize>,
    pub lhs_contract: Vec<usize>,
    pub rhs_contract: Vec<usize>,
}

/// `gather` dimension numbers (the embedding-lookup subset).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct GatherDims {
    pub offset_dims: Vec<usize>,
    pub collapsed_slice_dims: Vec<usize>,
    pub start_index_map: Vec<usize>,
    pub index_vector_dim: usize,
    pub slice_sizes: Vec<usize>,
}

/// `scatter` dimension numbers (the jax embedding-grad lowering subset).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ScatterDims {
    pub update_window_dims: Vec<usize>,
    pub inserted_window_dims: Vec<usize>,
    pub scatter_dims_to_operand_dims: Vec<usize>,
    pub index_vector_dim: usize,
}

#[derive(Debug, Clone, PartialEq)]
pub enum Literal {
    F32(Vec<f32>),
    S32(Vec<i32>),
    U32(Vec<u32>),
    Pred(Vec<bool>),
}

/// One parsed instruction.  Operands are indices into the owning
/// computation's instruction list (HLO text is in def-before-use order).
#[derive(Debug, Clone)]
pub struct Instr {
    pub name: String,
    /// `None` for tuple-shaped instructions (the ROOT tuple).
    pub shape: Option<HShape>,
    pub opcode: String,
    pub operands: Vec<usize>,
    /// `dimensions={...}` / `iota_dimension=` payload.
    pub dims: Vec<usize>,
    /// `slice={[start:limit:stride], ...}`.
    pub slice: Vec<(usize, usize, usize)>,
    /// `padding=low_high[_interior]x...` per dimension.
    pub pad_cfg: Vec<(i64, i64, i64)>,
    pub dot: Option<DotDims>,
    pub gather: Option<GatherDims>,
    pub scatter: Option<ScatterDims>,
    /// `dynamic_slice_sizes={...}`.
    pub dyn_sizes: Vec<usize>,
    pub direction: Option<CmpDir>,
    pub to_apply: Option<String>,
    /// `while` loop computations: `condition=%name`, `body=%name`.
    pub condition: Option<String>,
    pub body: Option<String>,
    /// Old-style `rng` op: `distribution=rng_uniform`.
    pub distribution: Option<String>,
    pub literal: Option<Literal>,
    pub param_idx: Option<usize>,
    pub tuple_index: Option<usize>,
}

#[derive(Debug, Clone)]
pub struct Computation {
    pub name: String,
    pub instrs: Vec<Instr>,
    /// Instruction index per parameter number.
    pub params: Vec<usize>,
    pub root: usize,
    pub is_entry: bool,
}

/// What kind of fold a reduce body computes (the evaluator fast-paths
/// these; arbitrary reduce bodies are rejected at parse time).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReduceKind {
    Add,
    Max,
    Min,
}

#[derive(Debug, Clone)]
pub struct HloModule {
    pub name: String,
    pub computations: Vec<Computation>,
    pub entry: usize,
}

impl HloModule {
    pub fn entry_computation(&self) -> &Computation {
        &self.computations[self.entry]
    }

    pub fn computation(&self, name: &str) -> Result<&Computation> {
        self.computations
            .iter()
            .find(|c| c.name == name)
            .with_context(|| format!("no computation '{name}' in module '{}'", self.name))
    }

    /// Classify a reduce body computation as one of the supported folds.
    pub fn reduce_kind(&self, name: &str) -> Result<ReduceKind> {
        let c = self.computation(name)?;
        let root = &c.instrs[c.root];
        Ok(match root.opcode.as_str() {
            "add" => ReduceKind::Add,
            "maximum" => ReduceKind::Max,
            "minimum" => ReduceKind::Min,
            other => bail!("unsupported reduce body op '{other}' in '{name}'"),
        })
    }

    /// Parse HLO text into a module.
    pub fn parse(text: &str) -> Result<HloModule> {
        let mut name = String::from("module");
        let mut computations: Vec<Computation> = Vec::new();
        let mut entry = None;

        let mut lines = text.lines().peekable();
        while let Some(line) = lines.next() {
            let t = line.trim();
            if t.is_empty() || t.starts_with("//") {
                continue;
            }
            if let Some(rest) = t.strip_prefix("HloModule") {
                name = rest
                    .trim()
                    .trim_end_matches(',')
                    .split([',', ' '])
                    .next()
                    .unwrap_or("module")
                    .to_string();
                continue;
            }
            // computation header: `[ENTRY ]%name (p: shape, ...) -> shape {`
            if t.contains("->") && t.ends_with('{') {
                let is_entry = t.starts_with("ENTRY");
                let mut comp = parse_computation(t, &mut lines)
                    .with_context(|| format!("parsing computation at '{t}'"))?;
                comp.is_entry = is_entry;
                if is_entry {
                    entry = Some(computations.len());
                }
                computations.push(comp);
                continue;
            }
            bail!("unrecognised top-level HLO line: '{t}'");
        }
        // single-computation modules may omit ENTRY
        let entry = match entry {
            Some(e) => e,
            None if computations.len() == 1 => 0,
            None => bail!("module '{name}' has no ENTRY computation"),
        };
        Ok(HloModule { name, computations, entry })
    }
}

fn parse_computation<'a>(
    header: &str,
    lines: &mut impl Iterator<Item = &'a str>,
) -> Result<Computation> {
    let h = header.trim_start_matches("ENTRY").trim();
    let name = h
        .split('(')
        .next()
        .context("computation header missing '('")?
        .trim()
        .trim_start_matches('%')
        .to_string();

    let mut instrs: Vec<Instr> = Vec::new();
    let mut by_name: HashMap<String, usize> = HashMap::new();
    let mut params: Vec<(usize, usize)> = Vec::new(); // (param number, instr idx)
    let mut root = None;

    for line in lines {
        let t = line.trim();
        if t.is_empty() {
            continue;
        }
        if t == "}" {
            break;
        }
        let (is_root, instr) =
            parse_instr(t, &by_name).with_context(|| format!("parsing instruction '{t}'"))?;
        let idx = instrs.len();
        if let Some(p) = instr.param_idx {
            params.push((p, idx));
        }
        if is_root {
            root = Some(idx);
        }
        by_name.insert(instr.name.clone(), idx);
        instrs.push(instr);
    }

    params.sort();
    let params: Vec<usize> = params.into_iter().map(|(_, i)| i).collect();
    let root = match root {
        Some(r) => r,
        None => instrs.len().checked_sub(1).context("empty computation")?,
    };
    Ok(Computation { name, instrs, params, root, is_entry: false })
}

/// Split `s` on commas at brace/paren/bracket depth zero.
fn split_top(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut depth = 0i32;
    let mut start = 0;
    for (i, c) in s.char_indices() {
        match c {
            '{' | '(' | '[' => depth += 1,
            '}' | ')' | ']' => depth -= 1,
            ',' if depth == 0 => {
                out.push(s[start..i].trim());
                start = i + 1;
            }
            _ => {}
        }
    }
    let tail = s[start..].trim();
    if !tail.is_empty() {
        out.push(tail);
    }
    out
}

/// Find the byte index of the `)`/`}` matching the opener at byte `open`.
fn matching_paren(s: &str, open: usize) -> Result<usize> {
    let mut depth = 0i32;
    for (i, c) in s.bytes().enumerate().skip(open) {
        match c {
            b'(' | b'{' | b'[' => depth += 1,
            b')' | b'}' | b']' => {
                depth -= 1;
                if depth == 0 {
                    return Ok(i);
                }
            }
            _ => {}
        }
    }
    bail!("unbalanced parentheses in '{s}'")
}

/// Parse a shape prefix like `f32[4,64]{1,0}` at the start of `s`.
/// Returns (shape, bytes consumed).  Tuple shapes return (None, consumed).
fn parse_shape_prefix(s: &str) -> Result<(Option<HShape>, usize)> {
    let s_trim = s.trim_start();
    let lead = s.len() - s_trim.len();
    if s_trim.starts_with('(') {
        let close = matching_paren(s_trim, 0)?;
        return Ok((None, lead + close + 1));
    }
    let lb = s_trim.find('[').context("shape missing '['")?;
    let dtype = HDtype::parse(&s_trim[..lb])?;
    let rb = s_trim[lb..].find(']').context("shape missing ']'")? + lb;
    let dims_str = &s_trim[lb + 1..rb];
    let dims: Vec<usize> = if dims_str.trim().is_empty() {
        Vec::new()
    } else {
        dims_str
            .split(',')
            .map(|d| d.trim().parse::<usize>().context("bad dim"))
            .collect::<Result<_>>()?
    };
    let mut consumed = rb + 1;
    // optional layout suffix `{1,0}`
    if s_trim[consumed..].starts_with('{') {
        let close = matching_paren(s_trim, consumed)?;
        consumed = close + 1;
    }
    Ok((Some(HShape { dtype, dims }), lead + consumed))
}

fn parse_usize_list(s: &str) -> Result<Vec<usize>> {
    let inner = s.trim().trim_start_matches('{').trim_end_matches('}');
    if inner.trim().is_empty() {
        return Ok(Vec::new());
    }
    inner
        .split(',')
        .map(|d| d.trim().parse::<usize>().with_context(|| format!("bad index in '{s}'")))
        .collect()
}

fn parse_literal(dtype: HDtype, payload: &str) -> Result<Literal> {
    // strip all braces, split on commas: covers scalars, 1-D and nested
    let flat: String = payload.chars().filter(|c| !matches!(c, '{' | '}')).collect();
    let toks: Vec<&str> = flat.split(',').map(|t| t.trim()).filter(|t| !t.is_empty()).collect();
    let parse_f32 = |t: &str| -> Result<f32> {
        Ok(match t {
            "inf" => f32::INFINITY,
            "-inf" => f32::NEG_INFINITY,
            "nan" => f32::NAN,
            _ => t.parse::<f32>().with_context(|| format!("bad f32 literal '{t}'"))?,
        })
    };
    Ok(match dtype {
        HDtype::F32 => Literal::F32(toks.iter().map(|t| parse_f32(t)).collect::<Result<_>>()?),
        HDtype::S32 => Literal::S32(
            toks.iter()
                .map(|t| t.parse::<i32>().with_context(|| format!("bad s32 '{t}'")))
                .collect::<Result<_>>()?,
        ),
        HDtype::U32 => Literal::U32(
            toks.iter()
                .map(|t| t.parse::<u32>().with_context(|| format!("bad u32 '{t}'")))
                .collect::<Result<_>>()?,
        ),
        HDtype::Pred => Literal::Pred(
            toks.iter()
                .map(|t| match *t {
                    "true" | "1" => Ok(true),
                    "false" | "0" => Ok(false),
                    other => bail!("bad pred literal '{other}'"),
                })
                .collect::<Result<_>>()?,
        ),
    })
}

fn parse_padding(s: &str) -> Result<Vec<(i64, i64, i64)>> {
    s.split('x')
        .map(|dim| {
            let parts: Vec<&str> = dim.split('_').collect();
            let get = |i: usize| -> Result<i64> {
                parts
                    .get(i)
                    .copied()
                    .unwrap_or("0")
                    .parse::<i64>()
                    .with_context(|| format!("bad padding '{dim}'"))
            };
            if parts.len() < 2 || parts.len() > 3 {
                bail!("bad padding spec '{dim}'");
            }
            Ok((get(0)?, get(1)?, if parts.len() == 3 { get(2)? } else { 0 }))
        })
        .collect()
}

fn parse_slice_spec(s: &str) -> Result<Vec<(usize, usize, usize)>> {
    let inner = s.trim().trim_start_matches('{').trim_end_matches('}');
    split_top(inner)
        .into_iter()
        .map(|part| {
            let p = part.trim().trim_start_matches('[').trim_end_matches(']');
            let nums: Vec<usize> = p
                .split(':')
                .map(|n| n.trim().parse::<usize>().with_context(|| format!("bad slice '{part}'")))
                .collect::<Result<_>>()?;
            Ok(match nums.len() {
                2 => (nums[0], nums[1], 1),
                3 => (nums[0], nums[1], nums[2]),
                _ => bail!("bad slice spec '{part}'"),
            })
        })
        .collect()
}

fn parse_instr(line: &str, by_name: &HashMap<String, usize>) -> Result<(bool, Instr)> {
    let (is_root, rest) = match line.strip_prefix("ROOT ") {
        Some(r) => (true, r),
        None => (false, line),
    };
    let eq = rest.find('=').context("instruction missing '='")?;
    let name = rest[..eq].trim().trim_start_matches('%').to_string();
    let rhs = rest[eq + 1..].trim();

    let (shape, consumed) = parse_shape_prefix(rhs)?;
    let rhs = rhs[consumed..].trim_start();
    let open = rhs.find('(').context("instruction missing opcode '('")?;
    let opcode = rhs[..open].trim().to_string();
    let close = matching_paren(rhs, open)?;
    let operand_str = &rhs[open + 1..close];
    let attr_str = rhs[close + 1..].trim_start_matches(',').trim();

    let mut instr = Instr {
        name,
        shape,
        opcode: opcode.clone(),
        operands: Vec::new(),
        dims: Vec::new(),
        slice: Vec::new(),
        pad_cfg: Vec::new(),
        dot: None,
        gather: None,
        scatter: None,
        dyn_sizes: Vec::new(),
        direction: None,
        to_apply: None,
        condition: None,
        body: None,
        distribution: None,
        literal: None,
        param_idx: None,
        tuple_index: None,
    };

    match opcode.as_str() {
        "parameter" => {
            instr.param_idx =
                Some(operand_str.trim().parse::<usize>().context("bad parameter number")?);
        }
        "constant" => {
            let dtype = instr
                .shape
                .as_ref()
                .context("tuple-shaped constants unsupported")?
                .dtype;
            instr.literal = Some(parse_literal(dtype, operand_str)?);
        }
        _ => {
            for frag in split_top(operand_str) {
                // fragment is `[shape ]%name`; take the %-token
                let opname = frag
                    .split_whitespace()
                    .rev()
                    .find(|t| t.starts_with('%'))
                    .with_context(|| format!("operand '{frag}' has no %name"))?
                    .trim_start_matches('%');
                let idx = *by_name
                    .get(opname)
                    .with_context(|| format!("operand '%{opname}' not yet defined"))?;
                instr.operands.push(idx);
            }
        }
    }

    let mut dot = DotDims::default();
    let mut has_dot = false;
    let mut gather = GatherDims::default();
    let mut has_gather = false;
    let mut scatter = ScatterDims::default();
    let mut has_scatter = false;
    for attr in split_top(attr_str) {
        if attr.is_empty() {
            continue;
        }
        let (key, val) = match attr.split_once('=') {
            Some(kv) => kv,
            // flags like `sharding` we don't model
            None => continue,
        };
        let (key, val) = (key.trim(), val.trim());
        match key {
            "dimensions" => instr.dims = parse_usize_list(val)?,
            "iota_dimension" => instr.dims = vec![val.parse::<usize>().context("iota dim")?],
            "index" => instr.tuple_index = Some(val.parse::<usize>().context("gte index")?),
            "slice" => instr.slice = parse_slice_spec(val)?,
            "padding" => instr.pad_cfg = parse_padding(val)?,
            "dynamic_slice_sizes" => instr.dyn_sizes = parse_usize_list(val)?,
            "direction" => instr.direction = Some(CmpDir::parse(val)?),
            "to_apply" => instr.to_apply = Some(val.trim_start_matches('%').to_string()),
            "condition" => instr.condition = Some(val.trim_start_matches('%').to_string()),
            "body" => instr.body = Some(val.trim_start_matches('%').to_string()),
            "distribution" => instr.distribution = Some(val.to_string()),
            "lhs_batch_dims" => {
                dot.lhs_batch = parse_usize_list(val)?;
                has_dot = true;
            }
            "rhs_batch_dims" => {
                dot.rhs_batch = parse_usize_list(val)?;
                has_dot = true;
            }
            "lhs_contracting_dims" => {
                dot.lhs_contract = parse_usize_list(val)?;
                has_dot = true;
            }
            "rhs_contracting_dims" => {
                dot.rhs_contract = parse_usize_list(val)?;
                has_dot = true;
            }
            "offset_dims" => {
                gather.offset_dims = parse_usize_list(val)?;
                has_gather = true;
            }
            "collapsed_slice_dims" => {
                gather.collapsed_slice_dims = parse_usize_list(val)?;
                has_gather = true;
            }
            "start_index_map" => {
                gather.start_index_map = parse_usize_list(val)?;
                has_gather = true;
            }
            "index_vector_dim" => {
                let v = val.parse().context("index_vector_dim")?;
                if opcode == "scatter" {
                    scatter.index_vector_dim = v;
                    has_scatter = true;
                } else {
                    gather.index_vector_dim = v;
                    has_gather = true;
                }
            }
            "update_window_dims" => {
                scatter.update_window_dims = parse_usize_list(val)?;
                has_scatter = true;
            }
            "inserted_window_dims" => {
                scatter.inserted_window_dims = parse_usize_list(val)?;
                has_scatter = true;
            }
            "scatter_dims_to_operand_dims" => {
                scatter.scatter_dims_to_operand_dims = parse_usize_list(val)?;
                has_scatter = true;
            }
            "slice_sizes" => {
                gather.slice_sizes = parse_usize_list(val)?;
                has_gather = true;
            }
            // metadata we can safely ignore (`algorithm`: rng-bit-generator
            // is pinned to the counter-based scheme; `is_stable`: our sort
            // comparators are strict total orders over distinct keys)
            "metadata" | "sharding" | "frontend_attributes" | "backend_config"
            | "operand_precision" | "indices_are_sorted" | "entry_computation_layout"
            | "algorithm" | "is_stable" => {}
            other => {
                // documented-gap opcodes (`conditional`, `custom-call`)
                // carry attributes we don't model; parse them structurally
                // so the verifier can report a structured unsupported-op
                // diagnostic instead of this being a parse failure
                if !super::verify::DOCUMENTED_GAPS.contains(&opcode.as_str()) {
                    bail!("unsupported attribute '{other}' on op '{opcode}'");
                }
            }
        }
    }
    if has_dot {
        instr.dot = Some(dot);
    }
    if has_gather {
        instr.gather = Some(gather);
    }
    if has_scatter {
        instr.scatter = Some(scatter);
    }
    Ok((is_root, instr))
}

#[cfg(test)]
mod tests {
    #![allow(clippy::disallowed_methods)]

    use super::*;

    const SMALL: &str = r#"HloModule small

%reduce_add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %add.1 = f32[] add(f32[] %a, f32[] %b)
}

ENTRY %main (p0: f32[2,3]) -> (f32[2]) {
  %p0 = f32[2,3]{1,0} parameter(0)
  %c0 = f32[] constant(0)
  %half = f32[] constant(0.5)
  %hb = f32[2,3] broadcast(f32[] %half), dimensions={}
  %scaled = f32[2,3] multiply(f32[2,3] %p0, f32[2,3] %hb)
  %red = f32[2] reduce(f32[2,3] %scaled, f32[] %c0), dimensions={1}, to_apply=%reduce_add
  ROOT %t = (f32[2]) tuple(f32[2] %red)
}
"#;

    #[test]
    fn parses_module_structure() {
        let m = HloModule::parse(SMALL).unwrap();
        assert_eq!(m.name, "small");
        assert_eq!(m.computations.len(), 2);
        let e = m.entry_computation();
        assert_eq!(e.name, "main");
        assert_eq!(e.params.len(), 1);
        assert_eq!(e.instrs.len(), 7);
        let red = &e.instrs[5];
        assert_eq!(red.opcode, "reduce");
        assert_eq!(red.dims, vec![1]);
        assert_eq!(red.to_apply.as_deref(), Some("reduce_add"));
        assert_eq!(m.reduce_kind("reduce_add").unwrap(), ReduceKind::Add);
        let root = &e.instrs[e.root];
        assert_eq!(root.opcode, "tuple");
        assert!(root.shape.is_none());
    }

    #[test]
    fn parses_shapes_and_literals() {
        let (s, used) = parse_shape_prefix("f32[4,64]{1,0} rest").unwrap();
        let s = s.unwrap();
        assert_eq!(s.dims, vec![4, 64]);
        assert_eq!(&"f32[4,64]{1,0} rest"[used..], " rest");
        assert_eq!(
            parse_literal(HDtype::F32, "{ { 1, 2 }, { 3, 4.5 } }").unwrap(),
            Literal::F32(vec![1.0, 2.0, 3.0, 4.5])
        );
        assert_eq!(parse_literal(HDtype::S32, "-7").unwrap(), Literal::S32(vec![-7]));
        assert_eq!(
            parse_literal(HDtype::F32, "-1e+30").unwrap(),
            Literal::F32(vec![-1e30])
        );
    }

    #[test]
    fn parses_dot_and_slice_attrs() {
        let text = r#"ENTRY %m (a: f32[2,3], b: f32[3,4]) -> f32[2,4] {
  %a = f32[2,3] parameter(0)
  %b = f32[3,4] parameter(1)
  %s = f32[2,2] slice(f32[2,3] %a), slice={[0:2], [1:3]}
  ROOT %d = f32[2,4] dot(f32[2,3] %a, f32[3,4] %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"#;
        let m = HloModule::parse(text).unwrap();
        let e = m.entry_computation();
        assert_eq!(e.instrs[2].slice, vec![(0, 2, 1), (1, 3, 1)]);
        let d = e.instrs[3].dot.clone().unwrap();
        assert_eq!(d.lhs_contract, vec![1]);
        assert_eq!(d.rhs_contract, vec![0]);
        assert!(d.lhs_batch.is_empty());
    }

    #[test]
    fn rejects_garbage() {
        assert!(HloModule::parse("HloModule x\nwat").is_err());
        assert!(HloModule::parse(
            "ENTRY %m (a: f32[1]) -> f32[1] {\n  %a = f32[1] frobnicate(%z)\n}\n"
        )
        .is_err());
    }

    #[test]
    fn parses_while_sort_scatter_rng_attrs() {
        let text = r#"HloModule loopy

%sort_gt_f32 (ga: f32[], gb: f32[]) -> pred[] {
  %ga = f32[] parameter(0)
  %gb = f32[] parameter(1)
  ROOT %g = pred[] compare(f32[] %ga, f32[] %gb), direction=GT
}

%scatter_add_f32 (sa: f32[], sb: f32[]) -> f32[] {
  %sa = f32[] parameter(0)
  %sb = f32[] parameter(1)
  ROOT %s = f32[] add(f32[] %sa, f32[] %sb)
}

%loop_cond (ci: s32[], cx: f32[4]) -> pred[] {
  %ci = s32[] parameter(0)
  %cx = f32[4] parameter(1)
  %cl = s32[] constant(3)
  ROOT %cp = pred[] compare(s32[] %ci, s32[] %cl), direction=LT
}

%loop_body (bi: s32[], bx: f32[4]) -> (s32[], f32[4]) {
  %bi = s32[] parameter(0)
  %bx = f32[4] parameter(1)
  %b1 = s32[] constant(1)
  %bn = s32[] add(s32[] %bi, s32[] %b1)
  %bneg = f32[4] negate(f32[4] %bx)
  ROOT %bt = (s32[], f32[4]) tuple(s32[] %bn, f32[4] %bneg)
}

ENTRY %m (i: s32[], x: f32[4], tbl: f32[8,4], idx: s32[2], upd: f32[2,4], seed: u32[]) -> (f32[4]) {
  %i = s32[] parameter(0)
  %x = f32[4] parameter(1)
  %tbl = f32[8,4] parameter(2)
  %idx = s32[2] parameter(3)
  %upd = f32[2,4] parameter(4)
  %seed = u32[] parameter(5)
  %srt = f32[4] sort(f32[4] %x), dimensions={0}, to_apply=%sort_gt_f32
  %sc = f32[8,4] scatter(f32[8,4] %tbl, s32[2] %idx, f32[2,4] %upd), update_window_dims={1}, inserted_window_dims={0}, scatter_dims_to_operand_dims={0}, index_vector_dim=1, to_apply=%scatter_add_f32
  %bits = u32[4] rng-bit-generator(u32[] %seed), algorithm=rng_default
  %bf = f32[4] convert(u32[4] %bits)
  %z0 = f32[] constant(0)
  %scf = f32[4] reduce(f32[8,4] %sc, f32[] %z0), dimensions={0}, to_apply=%scatter_add_f32
  %w = (s32[], f32[4]) while(s32[] %i, f32[4] %srt), condition=%loop_cond, body=%loop_body
  %out = f32[4] get-tuple-element((s32[], f32[4]) %w), index=1
  ROOT %t = (f32[4]) tuple(f32[4] %out)
}
"#;
        let m = HloModule::parse(text).unwrap();
        let e = m.entry_computation();
        let by = |n: &str| e.instrs.iter().find(|i| i.name == n).unwrap();

        let srt = by("srt");
        assert_eq!(srt.opcode, "sort");
        assert_eq!(srt.dims, vec![0]);
        assert_eq!(srt.to_apply.as_deref(), Some("sort_gt_f32"));

        let sc = by("sc");
        let sd = sc.scatter.clone().unwrap();
        assert_eq!(sd.update_window_dims, vec![1]);
        assert_eq!(sd.inserted_window_dims, vec![0]);
        assert_eq!(sd.scatter_dims_to_operand_dims, vec![0]);
        assert_eq!(sd.index_vector_dim, 1);
        assert!(sc.gather.is_none(), "scatter attrs must not populate gather dims");
        assert_eq!(sc.to_apply.as_deref(), Some("scatter_add_f32"));

        let bits = by("bits");
        assert_eq!(bits.opcode, "rng-bit-generator");
        assert_eq!(bits.operands.len(), 1);

        let w = by("w");
        assert_eq!(w.opcode, "while");
        assert!(w.shape.is_none(), "while result is tuple-shaped");
        assert_eq!(w.operands.len(), 2);
        assert_eq!(w.condition.as_deref(), Some("loop_cond"));
        assert_eq!(w.body.as_deref(), Some("loop_body"));

        let out = by("out");
        assert_eq!(out.opcode, "get-tuple-element");
        assert_eq!(out.tuple_index, Some(1));
        assert_eq!(out.operands, vec![e.instrs.iter().position(|i| i.name == "w").unwrap()]);
    }

    #[test]
    fn parses_rng_distribution_attr() {
        let text = r#"ENTRY %m (a: f32[], b: f32[]) -> (f32[3]) {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  %r = f32[3] rng(f32[] %a, f32[] %b), distribution=rng_uniform
  ROOT %t = (f32[3]) tuple(f32[3] %r)
}
"#;
        let m = HloModule::parse(text).unwrap();
        let r = &m.entry_computation().instrs[2];
        assert_eq!(r.opcode, "rng");
        assert_eq!(r.distribution.as_deref(), Some("rng_uniform"));
    }

    #[test]
    fn padding_spec_parses() {
        assert_eq!(
            parse_padding("0_0x1_2x0_0_3").unwrap(),
            vec![(0, 0, 0), (1, 2, 0), (0, 0, 3)]
        );
        assert!(parse_padding("nope").is_err());
    }
}
