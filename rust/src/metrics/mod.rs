//! Metrics: stage timers, counters, and the utilization monitor that feeds
//! dynamic placement (paper §3.2: "we continuously monitor hardware
//! utilization and gradually reduce the resource allocation for roles with
//! low utilization") and the progress watchdog (§4.2).

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Cumulative per-stage wallclock + call counts.
#[derive(Debug, Default)]
pub struct StageTimers {
    inner: Mutex<BTreeMap<String, (Duration, u64)>>,
}

impl StageTimers {
    pub fn new() -> StageTimers {
        StageTimers::default()
    }

    pub fn record(&self, stage: &str, dur: Duration) {
        let mut m = self.inner.lock().unwrap();
        let e = m.entry(stage.to_string()).or_insert((Duration::ZERO, 0));
        e.0 += dur;
        e.1 += 1;
    }

    /// Time a closure under a stage label.
    pub fn time<T>(&self, stage: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.record(stage, t0.elapsed());
        out
    }

    pub fn total(&self, stage: &str) -> Duration {
        self.inner
            .lock()
            .unwrap()
            .get(stage)
            .map(|(d, _)| *d)
            .unwrap_or(Duration::ZERO)
    }

    pub fn snapshot(&self) -> BTreeMap<String, (Duration, u64)> {
        self.inner.lock().unwrap().clone()
    }

    /// Markdown summary (examples print this at the end of a run).
    pub fn report(&self) -> String {
        let snap = self.snapshot();
        let total: f64 = snap.values().map(|(d, _)| d.as_secs_f64()).sum();
        let mut s = String::from("| stage | calls | total | share |\n|---|---|---|---|\n");
        for (stage, (dur, calls)) in &snap {
            s.push_str(&format!(
                "| {stage} | {calls} | {:.2}s | {:.1}% |\n",
                dur.as_secs_f64(),
                100.0 * dur.as_secs_f64() / total.max(1e-12),
            ));
        }
        s
    }
}

/// Sliding-window per-role utilization: the dynamic-placement signal.
#[derive(Debug, Clone)]
pub struct UtilizationMonitor {
    window: usize,
    /// per role: ring buffer of (busy_s, wall_s) samples
    samples: BTreeMap<String, Vec<(f64, f64)>>,
}

impl UtilizationMonitor {
    pub fn new(window: usize) -> UtilizationMonitor {
        UtilizationMonitor { window: window.max(1), samples: BTreeMap::new() }
    }

    /// Record one round: `busy` seconds of useful work observed over
    /// `wall` seconds of wallclock for `role`'s device group.
    pub fn record(&mut self, role: &str, busy: f64, wall: f64) {
        let buf = self.samples.entry(role.to_string()).or_default();
        buf.push((busy, wall));
        if buf.len() > self.window {
            buf.remove(0);
        }
    }

    /// Windowed utilization of a role (None until it has samples).
    pub fn utilization(&self, role: &str) -> Option<f64> {
        let buf = self.samples.get(role)?;
        if buf.is_empty() {
            return None;
        }
        let busy: f64 = buf.iter().map(|(b, _)| b).sum();
        let wall: f64 = buf.iter().map(|(_, w)| w).sum();
        if wall <= 0.0 {
            return None;
        }
        Some((busy / wall).clamp(0.0, 1.0))
    }

    pub fn roles(&self) -> Vec<String> {
        self.samples.keys().cloned().collect()
    }

    /// The (lowest, highest)-utilization roles — the rebalancing pair.
    pub fn extremes(&self) -> Option<(String, String)> {
        let mut pairs: Vec<(String, f64)> = self
            .samples
            .keys()
            .filter_map(|r| self.utilization(r).map(|u| (r.clone(), u)))
            .collect();
        if pairs.len() < 2 {
            return None;
        }
        pairs.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        Some((pairs[0].0.clone(), pairs[pairs.len() - 1].0.clone()))
    }
}

/// Training-progress watchdog (paper §4.2): terminate/restart when the
/// observed step rate falls below a floor.
#[derive(Debug)]
pub struct ProgressWatchdog {
    started: Instant,
    last_step_at: Instant,
    steps: u64,
    /// minimum acceptable steps/second (long-run)
    pub min_rate: f64,
    /// maximum silence between steps
    pub max_stall: Duration,
}

impl ProgressWatchdog {
    pub fn new(min_rate: f64, max_stall: Duration) -> ProgressWatchdog {
        let now = Instant::now();
        ProgressWatchdog { started: now, last_step_at: now, steps: 0, min_rate, max_stall }
    }

    pub fn step_done(&mut self) {
        self.steps += 1;
        self.last_step_at = Instant::now();
    }

    /// Err ⇒ the job must be terminated, resources reallocated, restarted.
    pub fn check(&self) -> Result<(), String> {
        if self.last_step_at.elapsed() > self.max_stall {
            return Err(format!(
                "stalled: no step for {:.1}s (max {:.1}s)",
                self.last_step_at.elapsed().as_secs_f64(),
                self.max_stall.as_secs_f64()
            ));
        }
        let elapsed = self.started.elapsed().as_secs_f64();
        if elapsed > 1.0 && self.steps > 0 {
            let rate = self.steps as f64 / elapsed;
            if rate < self.min_rate {
                return Err(format!(
                    "below expected progress: {rate:.3} steps/s < {:.3}",
                    self.min_rate
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_timers_accumulate() {
        let t = StageTimers::new();
        t.record("generate", Duration::from_millis(100));
        t.record("generate", Duration::from_millis(50));
        t.record("train", Duration::from_millis(25));
        assert_eq!(t.total("generate"), Duration::from_millis(150));
        let snap = t.snapshot();
        assert_eq!(snap["generate"].1, 2);
        assert!(t.report().contains("| generate | 2 |"));
    }

    #[test]
    fn time_closure() {
        let t = StageTimers::new();
        let v = t.time("work", || {
            std::thread::sleep(Duration::from_millis(5));
            42
        });
        assert_eq!(v, 42);
        assert!(t.total("work") >= Duration::from_millis(4));
    }

    #[test]
    fn utilization_window() {
        let mut m = UtilizationMonitor::new(3);
        m.record("gen", 5.0, 10.0);
        assert!((m.utilization("gen").unwrap() - 0.5).abs() < 1e-9);
        // window evicts old samples
        for _ in 0..3 {
            m.record("gen", 10.0, 10.0);
        }
        assert!((m.utilization("gen").unwrap() - 1.0).abs() < 1e-9);
        assert_eq!(m.utilization("unknown"), None);
    }

    #[test]
    fn extremes_find_rebalance_pair() {
        let mut m = UtilizationMonitor::new(4);
        m.record("gen", 9.0, 10.0);
        m.record("reward", 3.0, 10.0);
        m.record("train", 6.0, 10.0);
        let (lo, hi) = m.extremes().unwrap();
        assert_eq!(lo, "reward");
        assert_eq!(hi, "gen");
    }

    #[test]
    fn watchdog_detects_stall() {
        let mut w = ProgressWatchdog::new(0.0, Duration::from_millis(10));
        w.step_done();
        assert!(w.check().is_ok());
        std::thread::sleep(Duration::from_millis(25));
        assert!(w.check().is_err());
    }

    #[test]
    fn watchdog_detects_slow_rate() {
        let w = ProgressWatchdog {
            started: Instant::now() - Duration::from_secs(100),
            last_step_at: Instant::now(),
            steps: 5,
            min_rate: 1.0,
            max_stall: Duration::from_secs(3600),
        };
        let err = w.check().unwrap_err();
        assert!(err.contains("below expected progress"), "{err}");
    }
}
