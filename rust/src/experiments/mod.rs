//! Experiment table generators (DESIGN.md §4): every quantified claim in
//! the paper regenerated as a markdown table.  Shared by `gcore bench eN`
//! and the `rust/benches/e*_*.rs` harnesses; EXPERIMENTS.md records the
//! outputs.
//!
//! E6 (BT vs generative reward) and E10 (end-to-end training) are
//! engine-backed and live in `examples/genrm_vs_bt.rs` and
//! `examples/rlhf_e2e.rs`.

use crate::attention::{
    allgather_attention_cost, allgather_naive_cost, ring_attention_cost, AttnConfig,
};
use crate::balance::evaluate_epoch;
use crate::checkpoint::{CheckpointManager, CheckpointMeta, ShardState};
use crate::cluster::topology::Topology;
use crate::cluster::workload::{GenLenModel, TrainTimeModel};
use crate::coordinator::collective::{Collective, CollectiveBackend};
use crate::coordinator::rpc_collective::{
    CollectiveStatus, Heartbeat, RendezvousHost, RpcCollective,
};
use crate::coordinator::single::{route_parallel, route_single};
use crate::data::payload::PayloadSpec;
use crate::placement::{run_coexist_static, run_colocate, run_dynamic, PlacementSpec};
use crate::rpc::client::{RetryPolicy, RpcClient};
use crate::rpc::server::RpcServer;
use crate::rpc::transport::{FlakyTransport, InProcTransport};
use crate::runtime::params::ParamSet;
use crate::runtime::tensor::Tensor;
use crate::storage::dataloader::{Dataloader, LoaderState};
use crate::util::rng::Rng;

// The table type moved to `bench::table` when rows became typed `Metric`
// cells (ISSUE 8); re-exported here so `experiments::Table` stays the
// spelling every builder and bench binary uses.
pub use crate::bench::{Metric, Table};

/// How many leading columns of an experiment's table identify the row
/// (world size, payload, backend, …) rather than measure it.  The bench
/// store keys each sample by "<id>/<key cells joined by '/'>", so these
/// widths define series identity across commits.
pub fn key_columns(id: &str) -> usize {
    match id {
        "e1" | "e2" => 2,
        "e5" | "e8c" | "einterp" | "echaos" => 3,
        "e9a" => 5,
        _ => 1,
    }
}

fn f(x: f64, prec: usize) -> Metric {
    Metric::f64(x, prec)
}

/// E1 — single vs parallel controllers under multimodal payload load
/// (paper §3.1: the 768 GB single-controller arithmetic + Fig. 1).
pub fn e1_controller_scaling(quick: bool) -> Table {
    // scaled-down images so the bench runs in-process; the BYTES column
    // extrapolates to the paper's 2k-resolution scenario
    let spec = PayloadSpec::paper_2k().scaled(if quick { 32 } else { 16 });
    let samples = if quick { 16 } else { 64 };
    let paper = PayloadSpec::paper_2k();
    let mut rows = Vec::new();
    // single controller with a memory ceiling sized to HALF the rollout:
    let limit = spec.bytes_per_sample() * samples / 2;
    let single_capped = route_single(&spec, samples, limit, 7);
    for n in [1usize, 2, 4, 8] {
        // min-of-3 to damp scheduler noise on shared CPUs
        let r = (0..3)
            .map(|rep| {
                if n == 1 {
                    route_single(&spec, samples, usize::MAX, 7 + rep).unwrap()
                } else {
                    route_parallel(&spec, samples, n, 7 + rep).unwrap()
                }
            })
            .min_by(|a, b| a.wall_secs.partial_cmp(&b.wall_secs).unwrap())
            .unwrap();
        rows.push(vec![
            n.into(),
            r.samples.into(),
            f(r.peak_bytes_per_controller as f64 / 1e9, 3),
            f(paper.bytes_per_sample() as f64 * (samples / n) as f64 / 1e9, 0),
            f(r.wall_secs, 3),
            f(r.throughput_gbps, 2),
        ]);
    }
    rows.push(vec![
        "1 (capped)".into(),
        samples.into(),
        "OOM".into(),
        f(paper.bytes_per_sample() as f64 * samples as f64 / 1e9, 0),
        single_capped
            .err()
            .map(|e| Metric::Bool(e.to_string().contains("OOM")))
            .unwrap_or_else(|| "?".into()),
        "-".into(),
    ]);
    Table {
        title: "E1 — controller data-plane scaling (multimodal rollout, §3.1)".into(),
        header: vec![
            "controllers".into(),
            "samples".into(),
            "peak GB/ctrl (scaled)".into(),
            "peak GB/ctrl @paper-2k".into(),
            "wall s".into(),
            "GB/s".into(),
        ],
        rows,
        ..Table::default()
    }
}

/// E2 — placement strategies under plain GRPO vs dynamic sampling (§2.3, §3.2).
pub fn e2_placement(quick: bool) -> Table {
    let base = PlacementSpec {
        steps: if quick { 6 } else { 20 },
        n_devices: if quick { 16 } else { 64 },
        batch: if quick { 128 } else { 512 },
        ..PlacementSpec::paper_like()
    };
    let mut rows = Vec::new();
    for (label, dapo, accept_p0) in [
        ("plain GRPO", false, 0.9),
        ("dynamic sampling", true, 0.5),
    ] {
        let mut spec = base.clone();
        spec.dynamic_sampling = dapo;
        spec.accept.p0 = accept_p0;
        spec.accept.floor = 0.25;
        let colo = run_colocate(&spec);
        let stat = run_coexist_static(&spec, 0.5);
        let dynp = run_dynamic(&spec).report;
        for (strategy, r) in [("co-locate", &colo), ("co-exist 50/50", &stat), ("dynamic", &dynp)] {
            rows.push(vec![
                label.into(),
                strategy.into(),
                f(r.makespan_s, 0),
                f(r.utilization * 100.0, 1),
                f(r.swap_s, 0),
                f(r.bubble_s, 0),
                f(r.samples_per_hour(), 0),
            ]);
        }
    }
    Table {
        title: "E2 — placement under plain GRPO vs dynamic sampling (§2.3/§3.2)".into(),
        header: vec![
            "workload".into(),
            "placement".into(),
            "makespan s".into(),
            "util %".into(),
            "swap dev-s".into(),
            "bubble dev-s".into(),
            "samples/h".into(),
        ],
        rows,
        ..Table::default()
    }
}

/// E3 — long-tail amplification (§3.2 item 2): tail heaviness sweep.
pub fn e3_longtail(quick: bool) -> Table {
    let mut rows = Vec::new();
    for (label, sigma) in [("uniform-ish σ=0.1", 0.1), ("moderate σ=0.7", 0.7), ("heavy σ=1.2", 1.2)] {
        let mut spec = PlacementSpec {
            steps: if quick { 8 } else { 40 },
            n_devices: if quick { 16 } else { 64 },
            batch: if quick { 128 } else { 512 },
            dynamic_sampling: true,
            ..PlacementSpec::paper_like()
        };
        spec.accept.p0 = 0.5;
        spec.gen_len.sigma = sigma;
        let colo = run_colocate(&spec);
        let dynp = run_dynamic(&spec).report;
        rows.push(vec![
            label.into(),
            f(colo.utilization * 100.0, 1),
            f(dynp.utilization * 100.0, 1),
            f(colo.bubble_s, 0),
            f(dynp.bubble_s, 0),
            f(colo.makespan_s / dynp.makespan_s, 2),
        ]);
    }
    Table {
        title: "E3 — long-tail amplification: co-locate vs dynamic (§3.2)".into(),
        header: vec![
            "tail".into(),
            "colo util %".into(),
            "dyn util %".into(),
            "colo bubble dev-s".into(),
            "dyn bubble dev-s".into(),
            "speedup ×".into(),
        ],
        rows,
        ..Table::default()
    }
}

/// E4 — workload balancing: naive vs sorted-bucket (<10% waste claim, §4.4).
pub fn e4_balance(quick: bool) -> Table {
    let model = TrainTimeModel::default_7b();
    let mut rows = Vec::new();
    for (label, sigma) in [("σ=0.7", 0.7), ("σ=1.0", 1.0), ("σ=1.3", 1.3)] {
        let glm = GenLenModel { sigma, ..GenLenModel::reasoning_default() };
        // paper regime: global batches are large relative to the dp degree
        // (32 seqs/rank); plus one starved row (8/rank) showing the limit
        for (ranks, per_rank) in [(8usize, 32usize), (32, 32), (32, 8)] {
            let gb = ranks * per_rank;
            let n = gb * if quick { 8 } else { 24 };
            let mut rng = Rng::new(4);
            let lens = glm.sample_batch(&mut rng, 0, n);
            let naive =
                evaluate_epoch("naive", &lens, &model, gb, ranks, 5).expect("known strategy");
            let bal =
                evaluate_epoch("balanced", &lens, &model, gb, ranks, 5).expect("known strategy");
            rows.push(vec![
                format!("{label}, {ranks} ranks × {per_rank}/rank"),
                f(naive.mean_waste * 100.0, 1),
                f(bal.mean_waste * 100.0, 1),
                f(naive.p95_waste * 100.0, 1),
                f(bal.p95_waste * 100.0, 1),
                (bal.mean_waste < 0.10).into(),
            ]);
        }
    }
    Table {
        title: "E4 — workload balancing waste: naive vs sorted-bucket (§4.4)".into(),
        header: vec![
            "distribution".into(),
            "naive mean waste %".into(),
            "balanced mean waste %".into(),
            "naive p95 %".into(),
            "balanced p95 %".into(),
            "<10% (paper)".into(),
        ],
        rows,
        ..Table::default()
    }
}

/// E5 — distributed attention: ring vs all-gather-KV feasibility (§4.5).
pub fn e5_attention(_quick: bool) -> Table {
    let topo = Topology::paper_testbed();
    let mut rows = Vec::new();
    for (seq, cp) in [
        (1usize << 15, 8usize),
        (1 << 17, 16),
        (1 << 18, 32),
        (1 << 20, 64),
    ] {
        let cfg = AttnConfig::h20_default(seq, cp);
        for cost in [
            ring_attention_cost(&cfg, &topo),
            allgather_attention_cost(&cfg, &topo),
            allgather_naive_cost(&cfg, &topo),
        ] {
            rows.push(vec![
                format!("{}k", seq / 1024).into(),
                cp.into(),
                cost.scheme.into(),
                f(cost.peak_mem_bytes as f64 / 1e9, 2),
                f(cost.comm_time, 3),
                f(cost.step_time, 3),
                cost.feasible.into(),
                cost.arbitrary_masks.into(),
            ]);
        }
    }
    Table {
        title: "E5 — context-parallel attention: ring vs all-gather-KV (§4.5)".into(),
        header: vec![
            "seq".into(),
            "cp".into(),
            "scheme".into(),
            "peak GB/rank".into(),
            "comm s".into(),
            "step s".into(),
            "feasible".into(),
            "any-mask".into(),
        ],
        rows,
        ..Table::default()
    }
}

/// E7 — dynamic ratio adaptation as response length grows (§3.2).
pub fn e7_dynamic_ratio(quick: bool) -> Table {
    let mut spec = PlacementSpec {
        steps: if quick { 16 } else { 48 },
        n_devices: if quick { 16 } else { 64 },
        batch: if quick { 128 } else { 512 },
        ..PlacementSpec::paper_like()
    };
    spec.gen_len.growth_per_step = if quick { 0.08 } else { 0.03 };
    let d = run_dynamic(&spec);
    let stat = run_coexist_static(&spec, crate::placement::heuristic_gen_fraction(spec.policy_gb, spec.reward_gb));
    let mut rows = Vec::new();
    let stride = (d.trace.len() / 8).max(1);
    for (step, frac, ug, ur) in d.trace.iter().step_by(stride) {
        rows.push(vec![
            (*step).into(),
            f(spec.gen_len.median_at(*step), 0),
            f(*frac * 100.0, 1),
            f(*ug * 100.0, 1),
            f(*ur * 100.0, 1),
        ]);
    }
    rows.push(vec![
        "— summary —".into(),
        "".into(),
        format!("dyn makespan {}s", d.report.makespan_s.round()).into(),
        format!("static makespan {}s", stat.makespan_s.round()).into(),
        format!("speedup {:.2}×", stat.makespan_s / d.report.makespan_s).into(),
    ]);
    Table {
        title: "E7 — dynamic placement tracks response-length growth (§3.2)".into(),
        header: vec![
            "step".into(),
            "median gen len".into(),
            "gen pool %".into(),
            "gen util %".into(),
            "reward util %".into(),
        ],
        rows,
        ..Table::default()
    }
}

/// E8 — exactly-once RPC under injected faults (§4.2).
pub fn e8_rpc(quick: bool) -> Table {
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::Arc;
    let calls = if quick { 200 } else { 2000 };
    let mut rows = Vec::new();
    for (label, dreq, dresp, dup) in [
        ("clean", 0.0, 0.0, 0.0),
        ("10% req loss", 0.1, 0.0, 0.0),
        ("20% resp loss", 0.0, 0.2, 0.0),
        ("hostile 20/20/20", 0.2, 0.2, 0.2),
    ] {
        let count = Arc::new(AtomicU64::new(0));
        let c2 = count.clone();
        let server = Arc::new(RpcServer::new(move |_: &str, p: &[u8]| {
            c2.fetch_add(1, Ordering::SeqCst);
            Ok(p.to_vec())
        }));
        let flaky = FlakyTransport::new(InProcTransport::new(server.clone()), 99)
            .with_probs(dreq, dresp, dup);
        let client = RpcClient::new(flaky)
            .with_retry(RetryPolicy::exponential(64, std::time::Duration::from_micros(5)));
        let t0 = std::time::Instant::now();
        let mut ok = 0usize;
        for i in 0..calls {
            if client.call("work", vec![(i % 256) as u8]).is_ok() {
                ok += 1;
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let executed = count.load(Ordering::SeqCst);
        rows.push(vec![
            label.into(),
            format!("{ok}/{calls}").into(),
            executed.into(),
            (executed == calls as u64).into(),
            client.stats().retries.into(),
            f(calls as f64 / wall, 0),
        ]);
    }
    Table {
        title: "E8 — exactly-once RPC under fault injection (§4.2)".into(),
        header: vec![
            "fault profile".into(),
            "calls ok".into(),
            "handler executions".into(),
            "exactly-once".into(),
            "retries".into(),
            "calls/s".into(),
        ],
        rows,
        ..Table::default()
    }
}

/// Rank-varying but deterministic all-reduce operand (E8c).
fn e8c_param_set(rank: usize, n: usize) -> ParamSet {
    ParamSet::new(vec![Tensor::f32(
        vec![n],
        (0..n)
            .map(|i| ((i * 7 + rank * 31 + 13) % 97) as f32 / 97.0 - 0.5)
            .collect(),
    )])
}

/// Drive `rounds` all-reduce rounds of an `n`-element gradient across a
/// collective group (one thread per rank); returns (wall seconds, rank-0
/// result of the final round).
fn e8c_time_all_reduce(
    collectives: Vec<std::sync::Arc<Collective>>,
    n: usize,
    rounds: usize,
) -> (f64, ParamSet) {
    let t0 = std::time::Instant::now();
    let handles: Vec<_> = collectives
        .into_iter()
        .enumerate()
        .map(|(rank, col)| {
            std::thread::spawn(move || {
                let set = e8c_param_set(rank, n);
                let mut last = None;
                for _ in 0..rounds {
                    last = Some(col.all_reduce_mean(rank, &set).expect("all-reduce"));
                }
                last.unwrap()
            })
        })
        .collect();
    let results: Vec<ParamSet> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let wall = t0.elapsed().as_secs_f64();
    for r in &results[1..] {
        assert_eq!(r, &results[0], "ranks must agree on the reduced set");
    }
    (wall, results.into_iter().next().unwrap())
}

/// Rendezvous-backed TCP group with metered per-rank client transports.
fn e8c_rendezvous_tcp_group(
    world: usize,
) -> (
    crate::rpc::transport::TcpRpcHost,
    Vec<std::sync::Arc<Collective>>,
    Vec<std::sync::Arc<crate::rpc::transport::TransferStats>>,
) {
    use crate::rpc::transport::{MeteredTransport, TcpRpcHost, TcpTransport};
    use std::sync::Arc;
    let host = TcpRpcHost::spawn(RendezvousHost::serve(world)).expect("spawn rendezvous host");
    let mut stats = Vec::with_capacity(world);
    let cols = (0..world)
        .map(|_| {
            let metered = MeteredTransport::new(TcpTransport::connect(host.addr));
            stats.push(metered.stats());
            Collective::with_backend(Arc::new(RpcCollective::new(metered, world)))
        })
        .collect();
    (host, cols, stats)
}

/// Ring-backed TCP group with metered per-rank successor transports —
/// the exact launcher wiring (`launch::ring_tcp_group_with`) plus a byte
/// meter on each rank's client.
fn e8c_ring_tcp_group(
    world: usize,
    chunk_bytes: usize,
) -> (
    Vec<crate::rpc::transport::TcpRpcHost>,
    Vec<std::sync::Arc<Collective>>,
    Vec<std::sync::Arc<crate::rpc::transport::TransferStats>>,
) {
    use crate::rpc::transport::{MeteredTransport, TcpTransport};
    let stats_cell = std::cell::RefCell::new(Vec::with_capacity(world));
    let (hosts, cols) = crate::launch::ring_tcp_group_with(
        world,
        chunk_bytes,
        crate::rpc::server::DEFAULT_TOMBSTONE_CAPACITY,
        0,
        |_, addr| {
            let metered = MeteredTransport::new(TcpTransport::connect(addr));
            stats_cell.borrow_mut().push(metered.stats());
            metered
        },
    )
    .expect("spawn ring peers");
    (hosts, cols, stats_cell.into_inner())
}

fn e8c_max_rank_mb(stats: &[std::sync::Arc<crate::rpc::transport::TransferStats>]) -> f64 {
    stats.iter().map(|s| s.total()).max().unwrap_or(0) as f64 / 1e6
}

/// Measured cross-OS-process collective traffic: spawn a real
/// `gcore train-dist` job (2 worker processes) and parse each worker's
/// `collective-bytes` line off its stdout (`launch::run_worker` prints the
/// totals its metered transports counted).  Whole-job numbers, so the
/// ms/MB columns read as job totals, not per-round.  Only possible when
/// the current executable IS `gcore` — under `cargo test` (or without the
/// fixture engine) this returns no rows, keeping the in-proc sweep's row
/// count stable.
fn e8c_train_dist_rows(quick: bool) -> Vec<Vec<Metric>> {
    let Ok(exe) = std::env::current_exe() else { return Vec::new() };
    if exe.file_stem().and_then(|s| s.to_str()) != Some("gcore") {
        return Vec::new();
    }
    if crate::runtime::Engine::try_load("tiny").is_none() {
        return Vec::new();
    }
    let modes: &[&str] = if quick { &["ring"] } else { &["tcp", "ring"] };
    let mut rows = Vec::new();
    for mode in modes {
        let t0 = std::time::Instant::now();
        let out = std::process::Command::new(&exe)
            .args([
                "train-dist",
                "--artifacts",
                "tiny",
                "--world",
                "2",
                "--steps",
                "1",
                "--sft-steps",
                "1",
                "--collective",
                mode,
            ])
            .output();
        let wall = t0.elapsed().as_secs_f64();
        let Ok(out) = out else { continue };
        if !out.status.success() {
            continue;
        }
        let text = String::from_utf8_lossy(&out.stdout);
        let mut max_total = 0u64;
        let mut workers = 0usize;
        for line in text.lines() {
            let Some(rest) = line.strip_prefix("[gcore] worker ") else { continue };
            let Some(ix) = rest.find(" collective-bytes sent=") else { continue };
            let nums = &rest[ix + " collective-bytes sent=".len()..];
            let mut it = nums.split(" recv=");
            let sent: u64 = it.next().and_then(|s| s.trim().parse().ok()).unwrap_or(0);
            let recv: u64 = it.next().and_then(|s| s.trim().parse().ok()).unwrap_or(0);
            max_total = max_total.max(sent + recv);
            workers += 1;
        }
        if workers == 0 {
            continue;
        }
        rows.push(vec![
            "2".into(),
            "1 train step (tiny)".into(),
            format!("train-dist {mode} (os-proc, whole job)").into(),
            f(wall * 1e3, 0),
            f(max_total as f64 / 1e6, 2),
            "-".into(),
            "-".into(),
        ]);
    }
    rows
}

/// E8c — collective scalability sweep: payload size × world size across the
/// in-proc reference, the rank-0 rendezvous RPC backend and the streaming
/// ring backend, all over real loopback TCP (§3.1 + §4.2).
///
/// "client MB/round" is MEASURED on each rank's metered CLIENT transport
/// (max across ranks, per round) — request + response frames on the
/// connection the rank initiates.  Ring ranks additionally RECEIVE ~the
/// same volume through their own peer server (unmetered here), so absolute
/// totals are ~2× the column; the scaling shape is what the column is for:
/// rendezvous grows linearly with world size (every Ready reply carries
/// all world payloads — the O(world²) host funnel seen from one rank)
/// while the ring stays flat, independent of world.  The "identical"
/// column asserts both RPC backends reproduce the in-proc all-reduce
/// bit-for-bit.
pub fn e8_collective(quick: bool) -> Table {
    use std::sync::Arc;
    let worlds: &[usize] = if quick { &[2, 4] } else { &[2, 4, 8] };
    let sizes: &[usize] = if quick { &[4_096, 65_536] } else { &[65_536, 1_048_576] };
    let rounds = if quick { 2 } else { 8 };
    let chunk_bytes = 64 * 1024;
    let mut rows = Vec::new();
    for &world in worlds {
        for &n in sizes {
            // reference: the in-proc condvar rendezvous
            let inproc = Collective::new(world);
            let (ref_wall, ref_set) =
                e8c_time_all_reduce((0..world).map(|_| inproc.clone()).collect(), n, rounds);

            // rank-0 rendezvous RPC over real TCP
            let (host, cols, rdv_stats) = e8c_rendezvous_tcp_group(world);
            let (rdv_wall, rdv_set) = e8c_time_all_reduce(cols, n, rounds);
            drop(host);

            // streaming ring over real TCP
            let (hosts, cols, ring_stats) = e8c_ring_tcp_group(world, chunk_bytes);
            let (ring_wall, ring_set) = e8c_time_all_reduce(cols, n, rounds);
            drop(hosts);

            let mb = (n * 4) as f64 / 1e6;
            let per_round = |stats: &[Arc<crate::rpc::transport::TransferStats>]| {
                e8c_max_rank_mb(stats) / rounds as f64
            };
            for (backend, wall, set, rank_mb) in [
                ("in-proc rendezvous", ref_wall, &ref_set, None),
                ("rendezvous rpc (tcp)", rdv_wall, &rdv_set, Some(per_round(&rdv_stats))),
                ("ring (tcp)", ring_wall, &ring_set, Some(per_round(&ring_stats))),
            ] {
                rows.push(vec![
                    world.into(),
                    Metric::f64_unit(mb, 2, "MB"),
                    backend.into(),
                    f(wall / rounds as f64 * 1e3, 2),
                    rank_mb.map(|m| f(m, 2)).unwrap_or_else(|| "-".into()),
                    f(mb * world as f64 * rounds as f64 / wall, 1),
                    (set == &ref_set).into(),
                ]);
            }
        }
    }
    // true cross-process TCP overhead, measured on a real train-dist job
    // (no rows under `cargo test`, so the in-proc sweep's shape is stable)
    rows.extend(e8c_train_dist_rows(quick));
    Table {
        title: "E8c — collective sweep: rendezvous O(world) vs ring O(1) per-rank bytes (§3.1/§4.2)"
            .into(),
        header: vec![
            "world".into(),
            "payload".into(),
            "backend".into(),
            "ms/round".into(),
            "client MB/round".into(),
            "agg MB/s".into(),
            "identical".into(),
        ],
        rows,
        ..Table::default()
    }
}

// ---------------------------------------------------------------------------
// E9a — bucketed, overlapped gradient all-reduce (stage-4 hot path)
// ---------------------------------------------------------------------------

/// Uneven tensor sizes (4 large + 4 small) so bucket plans actually split
/// on tensor boundaries; totals 16 × (n/16) elements.
fn e9a_shapes(n: usize) -> Vec<usize> {
    let b = (n / 16).max(1);
    let mut s = vec![3 * b; 4];
    s.extend(std::iter::repeat(b).take(4));
    s
}

/// SPMD-identical initial parameters (all ranks start bit-identical).
fn e9a_init_params(shapes: &[usize]) -> ParamSet {
    ParamSet::new(
        shapes
            .iter()
            .enumerate()
            .map(|(ti, &n)| {
                Tensor::f32(
                    vec![n],
                    (0..n)
                        .map(|i| ((ti * 131 + i * 7 + 13) % 97) as f32 / 97.0 - 0.5)
                        .collect(),
                )
            })
            .collect(),
    )
}

/// Simulated per-bucket backward pass: `passes` fused mul-adds per element
/// derived from the params — the knob that sets the compute:comm ratio of
/// the modeled stage 4 (calibrated so compute ≈ one reduce round, the
/// regime real RLHF training sits in).
fn e9a_grad(params: &[f32], grads: &mut [f32], rank: usize, step: usize, passes: usize) {
    let r = (rank as f32 + 1.0) * 0.01;
    let s = (step as f32 + 1.0) * 0.001;
    for (g, &p) in grads.iter_mut().zip(params) {
        let mut acc = p + r + s;
        for _ in 0..passes {
            acc = acc * 0.999_999 + 0.000_001 * p;
        }
        *g = acc;
    }
}

/// Host-side Adam apply — the post-reduce work that overlaps with later
/// buckets' reduces in the overlapped mode.
fn e9a_adam(params: &mut [f32], m: &mut [f32], v: &mut [f32], grads: &[f32], step: i32) {
    let lr = 1e-3f32;
    let bc1 = 1.0 - 0.9f32.powi(step);
    let bc2 = 1.0 - 0.999f32.powi(step);
    for i in 0..params.len() {
        let g = grads[i];
        m[i] = 0.9 * m[i] + 0.1 * g;
        v[i] = 0.999 * v[i] + 0.001 * g * g;
        let mh = m[i] / bc1;
        let vh = v[i] / bc2;
        params[i] -= lr * mh / (vh.sqrt() + 1e-8);
    }
}

#[derive(Clone, Copy)]
enum E9aMode {
    /// compute all grads → one monolithic reduce → apply all (the old path)
    Monolithic,
    /// per-bucket: compute → submit async; finished buckets decode + apply
    /// while later buckets are still on the wire
    Bucketed(usize),
}

/// Run `steps` simulated stage-4 iterations on one rank; returns
/// (wall seconds, final params).  Both modes are elementwise-identical
/// arithmetic, so final params must match bit-for-bit.
fn e9a_stage4(
    col: std::sync::Arc<Collective>,
    rank: usize,
    shapes: &[usize],
    steps: usize,
    passes: usize,
    mode: E9aMode,
) -> (f64, ParamSet) {
    use crate::coordinator::collective::{plan_reduce_buckets, ReduceOp};
    use crate::util::pod;
    let world = col.world_size();
    let mut params = e9a_init_params(shapes);
    let mut grads = params.clone();
    let mut m: Vec<Vec<f32>> = shapes.iter().map(|&n| vec![0.0; n]).collect();
    let mut v: Vec<Vec<f32>> = shapes.iter().map(|&n| vec![0.0; n]).collect();
    col.barrier(rank).expect("e9a barrier");
    let t0 = std::time::Instant::now();
    for step in 0..steps {
        let adam_step = step as i32 + 1;
        match mode {
            E9aMode::Monolithic => {
                for ti in 0..shapes.len() {
                    e9a_grad(
                        params.tensors[ti].as_f32().unwrap(),
                        grads.tensors[ti].as_f32_mut().unwrap(),
                        rank,
                        step,
                        passes,
                    );
                }
                let reduced = col.all_reduce_mean(rank, &grads).expect("e9a reduce");
                for ti in 0..shapes.len() {
                    e9a_adam(
                        params.tensors[ti].as_f32_mut().unwrap(),
                        &mut m[ti],
                        &mut v[ti],
                        reduced.tensors[ti].as_f32().unwrap(),
                        adam_step,
                    );
                }
            }
            E9aMode::Bucketed(bucket_bytes) => {
                let plan = plan_reduce_buckets(&grads, bucket_bytes);
                let mut handles = Vec::with_capacity(plan.len());
                for (k, bucket) in plan.iter().enumerate() {
                    let mut payload = Vec::with_capacity(bucket.bytes.len());
                    for ti in bucket.tensors.clone() {
                        e9a_grad(
                            params.tensors[ti].as_f32().unwrap(),
                            grads.tensors[ti].as_f32_mut().unwrap(),
                            rank,
                            step,
                            passes,
                        );
                        pod::extend_le_f32(&mut payload, grads.tensors[ti].as_f32().unwrap());
                    }
                    handles.push(col.all_reduce_async(
                        rank,
                        &format!("params/b{k}"),
                        payload,
                        ReduceOp::SumF32,
                    ));
                }
                let scale = 1.0 / world as f32;
                for (bucket, handle) in plan.iter().zip(handles) {
                    let summed = handle.wait().expect("e9a bucket reduce");
                    let mut pos = 0usize;
                    for ti in bucket.tensors.clone() {
                        let nb = grads.tensors[ti].len() * 4;
                        grads.tensors[ti]
                            .copy_from_le_f32_bytes(&summed[pos..pos + nb])
                            .unwrap();
                        pos += nb;
                        grads.tensors[ti].scale(scale).unwrap();
                        e9a_adam(
                            params.tensors[ti].as_f32_mut().unwrap(),
                            &mut m[ti],
                            &mut v[ti],
                            grads.tensors[ti].as_f32().unwrap(),
                            adam_step,
                        );
                    }
                }
            }
        }
    }
    (t0.elapsed().as_secs_f64(), params)
}

/// Drive one mode across all ranks of a group; returns (max rank wall,
/// rank-0 final params, max per-rank client bytes moved).
fn e9a_run_mode(
    cols: &[std::sync::Arc<Collective>],
    stats: &[std::sync::Arc<crate::rpc::transport::TransferStats>],
    shapes: &[usize],
    steps: usize,
    passes: usize,
    mode: E9aMode,
) -> (f64, ParamSet, f64) {
    let before: Vec<u64> = stats.iter().map(|s| s.total()).collect();
    let shapes_v = shapes.to_vec();
    let handles: Vec<_> = cols
        .iter()
        .cloned()
        .enumerate()
        .map(|(rank, col)| {
            let shapes = shapes_v.clone();
            std::thread::spawn(move || e9a_stage4(col, rank, &shapes, steps, passes, mode))
        })
        .collect();
    let results: Vec<(f64, ParamSet)> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    for r in &results[1..] {
        assert_eq!(r.1, results[0].1, "ranks must agree on final params");
    }
    let wall = results.iter().map(|(w, _)| *w).fold(0.0, f64::max);
    let moved = stats
        .iter()
        .zip(&before)
        .map(|(s, b)| s.total().saturating_sub(*b))
        .max()
        .unwrap_or(0) as f64
        / 1e6;
    (wall, results.into_iter().next().unwrap().1, moved)
}

fn e9a_bits(set: &ParamSet) -> Vec<u32> {
    set.tensors
        .iter()
        .flat_map(|t| t.as_f32().unwrap().iter().map(|f| f.to_bits()))
        .collect()
}

/// E9a — bucketed, overlapped gradient all-reduce over the ring backend
/// (payload × world × bucket-size sweep of the modeled stage-4 hot path;
/// `bench e9a --json BENCH_allreduce.json` is the CI artifact).
///
/// The modeled stage 4 per step: backward (`passes` mul-adds/element,
/// calibrated so compute ≈ one reduce round) → gradient mean-reduce →
/// host-side Adam apply.  Monolithic runs the three phases serially;
/// overlapped submits each bucket to the communicator thread as soon as
/// its grads exist and applies finished buckets while later ones are still
/// on the wire.  Final params must stay bit-identical between modes.
pub fn e9a_allreduce(quick: bool) -> Table {
    let worlds: &[usize] = if quick { &[2, 4] } else { &[2, 4, 8] };
    let n: usize = if quick { 49_152 } else { 1_048_576 };
    let steps = if quick { 2 } else { 3 };
    let chunk_bytes = 16 * 1024;
    let shapes = e9a_shapes(n);
    // bucket bounds: smaller than one (large) tensor, mid, >= whole set
    let tensor_bytes = shapes[0] * 4;
    let total_bytes = n * 4;
    let bucket_sizes = [tensor_bytes / 2, total_bytes / 4, 8 * total_bytes];
    let mut rows = Vec::new();
    for &world in worlds {
        let (hosts, cols, stats) = e8c_ring_tcp_group(world, chunk_bytes);

        // calibrate: one pure-comm step (passes = 0), then per-pass compute
        // cost, so compute ≈ comm — the balanced regime overlap targets
        let (comm_wall, _, _) = e9a_run_mode(&cols, &stats, &shapes, 1, 0, E9aMode::Monolithic);
        let probe_passes = 8usize;
        let probe_params = e9a_init_params(&shapes);
        let flat: Vec<f32> = probe_params
            .tensors
            .iter()
            .flat_map(|t| t.as_f32().unwrap().iter().copied())
            .collect();
        let mut probe_grads = vec![0.0f32; flat.len()];
        let t0 = std::time::Instant::now();
        e9a_grad(&flat, &mut probe_grads, 0, 0, probe_passes);
        let per_pass = t0.elapsed().as_secs_f64() / probe_passes as f64;
        let passes = ((comm_wall / per_pass.max(1e-9)) as usize).clamp(4, 4096);

        let (mono_wall, mono_params, mono_mb) =
            e9a_run_mode(&cols, &stats, &shapes, steps, passes, E9aMode::Monolithic);
        rows.push(vec![
            world.into(),
            Metric::f64_unit(total_bytes as f64 / 1e6, 2, "MB"),
            "monolithic".into(),
            "-".into(),
            Metric::int(1),
            f(mono_wall / steps as f64 * 1e3, 2),
            f(1.0, 2),
            f(mono_mb / steps as f64, 2),
            true.into(),
        ]);
        for &bb in &bucket_sizes {
            let buckets =
                crate::coordinator::collective::plan_reduce_buckets(&probe_params, bb).len();
            let (wall, params, mb) =
                e9a_run_mode(&cols, &stats, &shapes, steps, passes, E9aMode::Bucketed(bb));
            rows.push(vec![
                world.into(),
                Metric::f64_unit(total_bytes as f64 / 1e6, 2, "MB"),
                "bucketed+overlap".into(),
                (bb / 1024).into(),
                buckets.into(),
                f(wall / steps as f64 * 1e3, 2),
                f(mono_wall / wall, 2),
                f(mb / steps as f64, 2),
                (e9a_bits(&params) == e9a_bits(&mono_params)).into(),
            ]);
        }
        drop(hosts);
    }
    Table {
        title: "E9a — bucketed, overlapped gradient all-reduce on the ring (stage-4 hot path)"
            .into(),
        header: vec![
            "world".into(),
            "payload".into(),
            "mode".into(),
            "bucket KB".into(),
            "buckets".into(),
            "stage-4 ms/step".into(),
            "speedup ×".into(),
            "client MB/step".into(),
            "identical".into(),
        ],
        rows,
        ..Table::default()
    }
}

/// E9 — async/on-demand checkpointing + elastic resume (§4.3).
pub fn e9_checkpoint(quick: bool) -> Table {
    let dir = std::env::temp_dir().join(format!("gcore_e9_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let mgr = CheckpointManager::new(&dir);
    let n_elems = if quick { 1_000_000 } else { 8_000_000 };
    let shard = ShardState {
        rank: 0,
        params: vec![(
            "policy".into(),
            ParamSet::new(vec![Tensor::f32(vec![n_elems], vec![0.5; n_elems])]),
        )],
        rng_seed: 1,
        opt_step: 0,
        controller_rng: None,
        taskgen_rng: None,
    };
    let meta = CheckpointMeta {
        step: 1,
        world_size: 4,
        loader: LoaderState { seed: 9, epoch: 0, cursor: 128 },
    };
    let mut rows = Vec::new();

    // sync save
    let t0 = std::time::Instant::now();
    mgr.save_shard(1, &meta, &shard).unwrap();
    let sync_s = t0.elapsed().as_secs_f64();
    rows.push(vec!["sync save".into(), f(sync_s * 1e3, 1), "-".into(), "ok".into()]);

    // async save: measure the *blocking* time seen by training
    let t0 = std::time::Instant::now();
    let h = mgr.save_async(2, meta.clone(), shard.clone());
    let block_s = t0.elapsed().as_secs_f64();
    let t1 = std::time::Instant::now();
    h.wait().unwrap();
    let bg_s = t1.elapsed().as_secs_f64();
    rows.push(vec![
        "async save".into(),
        f(block_s * 1e3, 1),
        f(bg_s * 1e3, 1),
        format!("training blocked {:.0}× less", (sync_s / block_s.max(1e-6)).min(9999.0)).into(),
    ]);

    // deadline abandon
    let r = mgr.save_with_deadline(3, &meta, &shard, std::time::Duration::from_nanos(1));
    rows.push(vec![
        "on-demand, 0 deadline".into(),
        "-".into(),
        "-".into(),
        if r.is_err() { "abandoned cleanly (paper §4.3)".into() } else { "UNEXPECTED".into() },
    ]);

    // elastic resume: consume at world=4, resume at world=2 and 8
    let mut dl = Dataloader::new(1024, 64, 42);
    for _ in 0..5 {
        dl.next_global_batch();
    }
    let state = dl.state();
    let stream = |world: usize| -> Vec<usize> {
        let mut dl = Dataloader::resume(1024, 64, state.clone());
        let mut out = Vec::new();
        for _ in 0..4 {
            let gb = dl.next_global_batch();
            for r in 0..world {
                out.extend(Dataloader::rank_slice(&gb, r, world).unwrap());
            }
        }
        out
    };
    let same = stream(2) == stream(4) && stream(4) == stream(8);
    rows.push(vec![
        "elastic resume 4→{2,8}".into(),
        "-".into(),
        "-".into(),
        if same { "identical sample stream".into() } else { "MISMATCH".into() },
    ]);

    std::fs::remove_dir_all(&dir).ok();
    Table {
        title: "E9 — async / on-demand / elastic checkpointing (§4.3)".into(),
        header: vec![
            "operation".into(),
            "blocking ms".into(),
            "background ms".into(),
            "outcome".into(),
        ],
        rows,
        ..Table::default()
    }
}

/// One chaos round-trip at a given lease TTL: a world of 3 rendezvouses
/// through a lease-armed host, the last rank "crashes" (stops
/// heartbeating and never offers its round), and the survivors' blocked
/// polls must fail with a typed `PeerDead` in roughly one TTL.  Returns
/// (detection ms — slowest survivor, recovery ms — wall time for an
/// epoch-bumped fresh host to re-rendezvous the full world).
fn echaos_once(world: usize, ttl_ms: u64, kill_round: usize) -> (f64, f64) {
    use crate::rpc::transport::InProcTransport;
    use std::sync::Arc;
    use std::time::{Duration, Instant};

    let server = Arc::new(RpcServer::new(
        RendezvousHost::new(world).with_lease_ttl(Duration::from_millis(ttl_ms)),
    ));
    let beat = Duration::from_millis((ttl_ms / 5).max(5));
    let rounds = kill_round + 2;
    let handles: Vec<_> = (0..world)
        .map(|rank| {
            let server = server.clone();
            std::thread::spawn(move || -> Option<f64> {
                let col =
                    RpcCollective::for_rank(InProcTransport::new(server.clone()), world, rank);
                let hb = Heartbeat::start(
                    RpcClient::new(InProcTransport::new(server.clone())),
                    rank as u32,
                    0,
                    beat,
                );
                for round in 0..rounds {
                    if rank == world - 1 && round == kill_round {
                        // the "crash": stop beating, never offer this round
                        drop(hb);
                        return None;
                    }
                    let t0 = Instant::now();
                    if let Err(err) = col.exchange(rank, "chaos.round", vec![rank as u8]) {
                        let dead = matches!(
                            CollectiveStatus::classify_error(&err),
                            Some(CollectiveStatus::PeerDead { .. })
                        );
                        assert!(dead, "survivor failed without PeerDead: {err:#}");
                        return Some(t0.elapsed().as_secs_f64() * 1e3);
                    }
                }
                None
            })
        })
        .collect();
    let detect_ms = handles
        .into_iter()
        .filter_map(|h| h.join().unwrap())
        .fold(0.0_f64, f64::max);
    assert!(detect_ms > 0.0, "no survivor reported a typed PeerDead");

    // recovery: a fresh host one epoch up, the full world re-rendezvouses
    let t0 = Instant::now();
    let server = Arc::new(RpcServer::new(
        RendezvousHost::new(world)
            .with_epoch(1)
            .with_lease_ttl(Duration::from_millis(ttl_ms)),
    ));
    let handles: Vec<_> = (0..world)
        .map(|rank| {
            let server = server.clone();
            std::thread::spawn(move || {
                let col =
                    RpcCollective::for_rank(InProcTransport::new(server.clone()), world, rank)
                        .with_epoch(1);
                let _hb = Heartbeat::start(
                    RpcClient::new(InProcTransport::new(server)),
                    rank as u32,
                    1,
                    beat,
                );
                col.exchange(rank, "chaos.recover", vec![rank as u8])
                    .expect("recovered round");
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    (detect_ms, t0.elapsed().as_secs_f64() * 1e3)
}

/// Echaos — rank-death detection latency and epoch-bumped recovery time
/// for the elastic `train-dist` path (EXPERIMENTS.md §Echaos): detection
/// must track the heartbeat lease TTL, three orders of magnitude under
/// the 300 s collective round timeout that used to be the only backstop.
pub fn echaos_recovery(quick: bool) -> Table {
    let world = 3;
    let kill_round = 2;
    let reps = if quick { 3 } else { 5 };
    let ttls: &[u64] = if quick { &[100, 250] } else { &[100, 250, 500] };
    let mut rows = Vec::new();
    for &ttl in ttls {
        // min-of-reps damps scheduler noise: detection's floor is the TTL
        // itself, recovery's is one rendezvous round
        let (mut detect, mut recover) = (f64::MAX, f64::MAX);
        for _ in 0..reps {
            let (d, r) = echaos_once(world, ttl, kill_round);
            detect = detect.min(d);
            recover = recover.min(r);
        }
        rows.push(vec![
            "restart".into(),
            ttl.into(),
            kill_round.into(),
            f(detect, 1),
            Metric::Bool(detect < 30_000.0),
            f(recover, 1),
        ]);
    }
    Table {
        title: "Echaos — rank-death detection + epoch-bumped recovery (elastic train-dist)"
            .into(),
        header: vec![
            "policy".into(),
            "lease ttl".into(),
            "kill round".into(),
            "detect ms".into(),
            "detect \u{226a} 300s timeout".into(),
            "recover ms".into(),
        ],
        rows,
        ..Table::default()
    }
}

/// E-interp: per-artifact wallclock of the pure-Rust HLO interpreter on
/// the checked-in fixture sets (parse/"compile" once, then warm calls).
/// The CI engine-tests job uploads this as `BENCH_engine_interp.json`, so
/// interpreter perf trajectory is visible on every PR; with the `pjrt`
/// feature the same harness times XLA for the comparison column in
/// EXPERIMENTS.md §Einterp.
pub fn einterp_engine(quick: bool) -> Table {
    use crate::runtime::Engine;
    let reps = if quick { 3usize } else { 10 };
    let mut rows = Vec::new();
    let mut timing = Vec::new();
    for config in ["synthetic", "tiny"] {
        let Some(engine) = Engine::try_load(config) else {
            rows.push(vec![
                config.into(),
                "-".into(),
                "missing".into(),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
            ]);
            continue;
        };
        let names: Vec<String> = engine.manifest().artifacts.keys().cloned().collect();
        for name in names {
            let spec = engine.manifest().artifact(&name).unwrap().clone();
            // benign placeholder inputs: zeros for tensors (token 0 is in
            // range), 1.0 for f32 scalars (Adam's `step` must be >= 1)
            let inputs: Vec<Tensor> = spec
                .inputs
                .iter()
                .map(|s| match s.dtype {
                    crate::runtime::Dtype::F32 => {
                        if s.shape.is_empty() {
                            Tensor::scalar_f32(1.0)
                        } else {
                            Tensor::zeros_f32(s.shape.clone())
                        }
                    }
                    crate::runtime::Dtype::I32 => {
                        Tensor::i32(s.shape.clone(), vec![0; s.num_elements()])
                    }
                    crate::runtime::Dtype::U32 => {
                        Tensor::u32(s.shape.clone(), vec![0; s.num_elements()])
                    }
                })
                .collect();
            engine.run(&name, &inputs).unwrap(); // warm (parse + first call)
            // per-rep timings so the bench DB gets the full wall-clock
            // distribution (p50/p90/p99), not just the mean the cell shows
            let r = crate::util::bench::bench_n(&format!("einterp/{config}/{name}"), reps, || {
                engine.run(&name, &inputs).unwrap();
            });
            let ms = r.mean_ns() / 1e6;
            let compile_ms = engine
                .stats()
                .get(&name)
                .map(|s| s.compile_time.as_secs_f64() * 1e3)
                .unwrap_or(0.0);
            let fused = engine
                .fused_chains(&name)
                .map(|n| Metric::int(n as i64))
                .unwrap_or_else(|| "-".into());
            rows.push(vec![
                config.into(),
                name.clone().into(),
                engine.backend_name().into(),
                fused,
                Metric::int(crate::runtime::hlo::pool::threads() as i64),
                f(compile_ms, 1),
                f(ms, 2),
            ]);
            timing.push((r.name.clone(), r));
        }
    }
    Table {
        title: "Einterp: engine backend per-artifact wallclock".into(),
        header: vec![
            "config".into(),
            "artifact".into(),
            "backend".into(),
            "fused chains".into(),
            "threads".into(),
            "parse/compile ms".into(),
            "ms/call".into(),
        ],
        rows,
        timing,
    }
}

/// Egen — continuous-batching rollout scheduler throughput vs queue depth
/// (the tentpole claim for the generation data plane: with token-granular
/// retirement and a paged KV cache, tokens/s stays near-flat as the
/// request queue deepens past the engine's fixed `[batch]`, because
/// retired rows stop paying decode cost and their pages recycle into the
/// next wave).  `bench egen --json BENCH_generation.json` is the CI
/// artifact.  Grouped prompts (each distinct task repeated `g` times, the
/// GRPO shape) exercise prefix-page sharing; the final row arms the
/// long-tail `CancelPolicy`.
pub fn egen_generation(quick: bool) -> Table {
    use crate::coordinator::generation::SamplerConfig;
    use crate::coordinator::rollout::{self, CancelPolicy, RolloutOptions};
    use crate::data::tasks::TaskGen;
    use crate::runtime::params::init_policy;
    use crate::runtime::Engine;

    let header: Vec<String> = [
        "queue depth",
        "waves",
        "decode calls",
        "tokens",
        "tokens/s",
        "live-slot util %",
        "peak pages",
        "shared hits",
        "cancelled",
    ]
    .map(String::from)
    .to_vec();
    let title = "Egen — continuous-batching rollout throughput vs queue depth (§2.2)".to_string();

    let engine = match Engine::try_load("tiny") {
        Some(e) => Some(e),
        None => Engine::try_load("synthetic"),
    };
    let Some(engine) = engine else {
        let n = header.len();
        return Table {
            title,
            header,
            rows: vec![{
                let mut r = vec![Metric::text("no fixture engine (set GCORE_ENGINE=interp)")];
                r.resize(n, "-".into());
                r
            }],
            ..Table::default()
        };
    };

    let dims = engine.manifest().dims.clone();
    let (b, p) = (dims.batch, dims.prompt_len);
    let kinds = crate::config::RunConfig::default()
        .task_kinds()
        .expect("default task kinds");
    let scfg = SamplerConfig { temperature: 1.0, top_k: 8, stop_at_eos: true };
    let params = init_policy(&engine, 7).expect("init policy");
    let reps = if quick { 1 } else { 3 };
    let g = b.clamp(1, 4); // GRPO-style repeats → shared prompt pages

    let mut rows = Vec::new();
    let mut bench_case = |label: String, depth: usize, opts: &RolloutOptions| {
        let mut tg = TaskGen::new(kinds.clone(), 11);
        let mut requests = Vec::with_capacity(depth);
        while requests.len() < depth {
            let t = tg.sample();
            for _ in 0..g {
                if requests.len() == depth {
                    break;
                }
                requests.push(rollout::RolloutRequest {
                    id: requests.len(),
                    prompt: t.prompt_tokens(p).expect("prompt tokens"),
                });
            }
        }
        // min-of-reps wall clock; stats are identical across reps (fixed seed)
        let mut best: Option<(f64, rollout::SchedulerStats)> = None;
        for _ in 0..reps {
            let mut rng = Rng::new(7);
            let t0 = std::time::Instant::now();
            let run = rollout::run(&engine, &params, &requests, &scfg, &mut rng, opts)
                .expect("rollout scheduler");
            let wall = t0.elapsed().as_secs_f64();
            if best.as_ref().is_none_or(|(w, _)| wall < *w) {
                best = Some((wall, run.stats));
            }
        }
        let (wall, st) = best.unwrap();
        rows.push(vec![
            label.into(),
            st.waves.into(),
            st.decode_calls.into(),
            st.generated_tokens.into(),
            f(crate::util::bench::per_sec(st.generated_tokens, wall), 0),
            f(st.live_slot_steps as f64 / st.slot_steps.max(1) as f64 * 100.0, 1),
            st.peak_pages.into(),
            st.shared_page_hits.into(),
            st.cancelled.into(),
        ]);
    };

    for depth in [b, 2 * b, 4 * b] {
        bench_case(format!("{depth}"), depth, &RolloutOptions::default());
    }
    bench_case(
        format!("{} + cancel", 2 * b),
        2 * b,
        &RolloutOptions {
            cancel: Some(CancelPolicy { needed: b, grace_steps: 4 }),
            ..RolloutOptions::default()
        },
    );

    Table { title, header, rows, ..Table::default() }
}

/// Run one experiment by id ("e1".."e9a", "egen", "einterp", "echaos"),
/// print its table, and return it.
pub fn run(id: &str, quick: bool) -> Option<Table> {
    let t = match id {
        "e1" => e1_controller_scaling(quick),
        "e2" => e2_placement(quick),
        "e3" => e3_longtail(quick),
        "e4" => e4_balance(quick),
        "e5" => e5_attention(quick),
        "e7" => e7_dynamic_ratio(quick),
        "e8" => e8_rpc(quick),
        "e8c" => e8_collective(quick),
        "e9" => e9_checkpoint(quick),
        "e9a" => e9a_allreduce(quick),
        "egen" => egen_generation(quick),
        "einterp" => einterp_engine(quick),
        "echaos" => echaos_recovery(quick),
        _ => return None,
    };
    t.print();
    Some(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Every rendered cell must survive `Metric::parse` → `render` — the
    /// lossless-ingest guarantee the bench store depends on when reading
    /// archived string cells back.
    fn assert_cells_roundtrip(id: &str, t: &Table) {
        for row in t.rendered_rows() {
            for cell in row {
                assert_eq!(
                    Metric::parse(&cell).render(),
                    cell,
                    "{id}: parse/render broke on {cell:?}"
                );
            }
        }
    }

    #[test]
    fn all_tables_generate_quick() {
        for id in ["e2", "e3", "e4", "e5", "e7", "e9"] {
            let t = run(id, true).unwrap();
            assert!(!t.rows.is_empty(), "{id}");
            assert!(t.rows.iter().all(|r| r.len() == t.header.len()), "{id}");
            assert_cells_roundtrip(id, &t);
        }
    }

    #[test]
    fn e8_exactly_once_holds() {
        let t = e8_rpc(true);
        for row in t.rendered_rows() {
            assert_eq!(row[3], "true", "exactly-once violated in {row:?}");
        }
        assert_cells_roundtrip("e8", &t);
    }

    #[test]
    fn e8c_backends_bit_identical_across_sweep() {
        let t = e8_collective(true);
        assert_eq!(t.rows.len(), 12); // 2 worlds × 2 sizes × 3 backends
        let identical = t.header.len() - 1;
        for row in t.rendered_rows() {
            assert_eq!(row[identical], "true", "backend diverged from in-proc: {row:?}");
        }
        assert_cells_roundtrip("e8c", &t);
    }

    #[test]
    fn echaos_detection_tracks_lease_ttl() {
        let (detect_ms, recover_ms) = echaos_once(3, 150, 1);
        // detection's floor is one lease TTL (a lease can only lapse after
        // the victim has been silent that long); its ceiling must be
        // nowhere near the 300 s round timeout, the pre-lease backstop
        assert!(detect_ms >= 50.0, "died before any lease could lapse: {detect_ms} ms");
        assert!(detect_ms < 10_000.0, "lease gating broken: detection took {detect_ms} ms");
        assert!(recover_ms < 10_000.0, "epoch-bumped recovery took {recover_ms} ms");
    }

    #[test]
    fn e8c_ring_per_rank_bytes_flat_rendezvous_grows() {
        // the measured (not asserted-by-construction) scalability claim:
        // per-rank bytes grow ~linearly in world size through the rank-0
        // rendezvous, but stay ~flat around the ring
        let t = e8_collective(true);
        let rendered = t.rendered_rows();
        let mb_of = |world: &str, backend: &str| -> f64 {
            rendered
                .iter()
                .filter(|r| r[0] == world && r[2] == backend)
                .map(|r| r[4].parse::<f64>().expect("per-rank MB"))
                .fold(0.0, f64::max) // largest payload row dominates
        };
        let rdv2 = mb_of("2", "rendezvous rpc (tcp)");
        let rdv4 = mb_of("4", "rendezvous rpc (tcp)");
        let ring2 = mb_of("2", "ring (tcp)");
        let ring4 = mb_of("4", "ring (tcp)");
        assert!(
            rdv4 > rdv2 * 1.3,
            "rendezvous per-rank bytes must grow with world: {rdv2} -> {rdv4}"
        );
        assert!(
            ring4 <= ring2 * 2.5,
            "ring per-rank bytes must stay ~flat in world: {ring2} -> {ring4}"
        );
        assert!(
            ring4 < rdv4,
            "at world 4 the ring must move fewer per-rank bytes ({ring4} vs {rdv4})"
        );
    }

    #[test]
    fn e9a_overlap_stays_bit_identical_to_monolithic() {
        // the correctness half of the E9a claim: whatever the wall-clock
        // numbers on this machine, bucketed+overlapped stage 4 must end on
        // exactly the monolithic params (the speedup itself is reported by
        // `bench e9a` / the CI artifact, not asserted — CI machines vary)
        let t = e9a_allreduce(true);
        assert_eq!(t.rows.len(), 8); // 2 worlds × (1 monolithic + 3 bucket sizes)
        let identical = t.header.len() - 1;
        let rendered = t.rendered_rows();
        for row in &rendered {
            assert_eq!(row[identical], "true", "overlap diverged: {row:?}");
        }
        assert_cells_roundtrip("e9a", &t);
        // the sweep must include a sub-tensor, a mid, and a whole-set bucket
        // bound (buckets strictly decreasing as the bound grows)
        let buckets: Vec<usize> = rendered
            .iter()
            .filter(|r| r[2] == "bucketed+overlap" && r[0] == "2")
            .map(|r| r[4].parse().unwrap())
            .collect();
        assert_eq!(buckets.len(), 3);
        assert!(buckets[0] > buckets[1] && buckets[1] > buckets[2], "{buckets:?}");
        assert_eq!(buckets[2], 1, "largest bound must cover the whole set");
    }

    #[test]
    fn egen_reports_three_plus_concurrency_levels() {
        // engine-gated (needs the fixture artifact sets + a backend)
        if crate::runtime::Engine::try_load("tiny").is_none()
            && crate::runtime::Engine::try_load("synthetic").is_none()
        {
            return;
        }
        let t = egen_generation(true);
        assert!(t.rows.len() >= 4, "3 depths + 1 cancel row, got {:?}", t.rows);
        assert!(t.rows.iter().all(|r| r.len() == t.header.len()));
        let rendered = t.rendered_rows();
        for row in &rendered {
            let toks: f64 = row[4].parse().expect("tokens/s cell");
            assert!(toks > 0.0, "throughput must be positive: {row:?}");
        }
        assert_cells_roundtrip("egen", &t);
        // the cancel row must actually preempt someone
        let cancel_row = rendered.last().unwrap();
        assert!(
            cancel_row[8].parse::<usize>().unwrap() > 0,
            "cancel policy preempted nothing: {cancel_row:?}"
        );
    }

    #[test]
    fn e4_balanced_meets_paper_bound() {
        let t = e4_balance(true);
        for row in t.rendered_rows() {
            if row[0].contains("× 32/rank") {
                assert_eq!(row[5], "true", "balanced waste must be <10%: {row:?}");
            }
        }
    }

    #[test]
    fn markdown_roundtrip() {
        let t = e5_attention(true);
        let md = t.to_markdown();
        assert!(md.contains("### E5"));
        assert!(md.lines().count() > 5);
    }

    #[test]
    fn json_keeps_legacy_shape_with_schema_version() {
        use crate::util::json::Json;
        let t = e5_attention(true);
        let j = t.to_json();
        assert_eq!(
            j.get("schema_version").and_then(Json::as_i64),
            Some(crate::bench::TABLE_SCHEMA_VERSION)
        );
        assert_eq!(j.get("title").and_then(Json::as_str), Some(t.title.as_str()));
        // rows are still arrays of strings, cell-for-cell what the
        // stringly-typed schema v1 emitted
        let rows = j.get("rows").and_then(Json::as_arr).unwrap();
        let rendered = t.rendered_rows();
        assert_eq!(rows.len(), rendered.len());
        for (jr, rr) in rows.iter().zip(&rendered) {
            let cells: Vec<&str> =
                jr.as_arr().unwrap().iter().map(|c| c.as_str().unwrap()).collect();
            assert_eq!(&cells, &rr.iter().map(String::as_str).collect::<Vec<_>>());
        }
    }

    #[test]
    fn key_columns_stay_within_table_width() {
        // key widths must leave at least one non-key column in every table
        for (id, width) in
            [("e2", 7), ("e3", 6), ("e4", 6), ("e5", 8), ("e7", 5), ("e9", 4)]
        {
            assert!(key_columns(id) < width, "{id}");
        }
        assert_eq!(key_columns("unknown"), 1);
    }

    #[test]
    fn typed_cells_ingest_losslessly() {
        // the redesign's point: the store sees the same numbers the cells
        // carry, with no string re-parsing in between
        let t = e4_balance(true);
        let path = std::env::temp_dir()
            .join(format!("gcore_exp_ingest_{}.jsonl", std::process::id()));
        std::fs::remove_file(&path).ok();
        let mut db = crate::bench::BenchDb::open(&path).unwrap();
        let n = crate::bench::ingest_table(&mut db, "e4", &t, key_columns("e4"), "c1", 1).unwrap();
        // 4 numeric columns per row (the Bool gate column carries no value)
        assert_eq!(n, t.rows.len() * 4);
        for (row, rendered) in t.rows.iter().zip(t.rendered_rows()) {
            let label = format!("e4/{}", rendered[0]);
            let series = db.series(&label, "naive mean waste %");
            assert_eq!(series.len(), 1, "{label}");
            assert_eq!(Some(series[0].value), row[1].value());
        }
        std::fs::remove_file(&path).ok();
    }
}
