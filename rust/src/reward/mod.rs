//! Stage-2 rewarding (paper §2.2, §3.2): Bradley-Terry scoring and
//! **generative rewarding**.
//!
//! Generative rewarding follows the paper's description exactly: "We use a
//! causal text generation inference engine to replace the traditional
//! regression-based rewarding model ... and then use this model to
//! generate reward scores through generation and regex matching" — the
//! verifier LM reads "<prompt><answer> V:" and its next-token prediction
//! ("yes"/"no") *is* the verification decision (the GenRM insight [48]).
//!
//! Two extraction paths:
//! * `VerdictMode::Logit` — compare the 'y' vs 'n' next-token logits
//!   (the single-token decision; cheapest, used inside the training loop);
//! * `VerdictMode::Regex` — greedy-decode a few tokens and regex-match
//!   `yes|no` (the paper's literal mechanism; used by the examples/tests
//!   and required when verdicts are longer than one token).

use anyhow::{bail, Result};
use regex::Regex;

use crate::coordinator::generation::GenOutput;
use crate::data::tasks::Task;
use crate::data::tokenizer::{self, PAD};
use crate::runtime::engine::Engine;
use crate::runtime::params::ParamSet;
use crate::runtime::tensor::Tensor;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RewardKind {
    /// programmatic ground truth (the synthetic tasks' oracle)
    GroundTruth,
    /// Bradley-Terry scalar head
    BradleyTerry,
    /// generative verifier LM
    Generative,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VerdictMode {
    Logit,
    Regex,
}

pub struct Rewarder {
    pub kind: RewardKind,
    pub bt_params: Option<ParamSet>,
    pub verifier_params: Option<ParamSet>,
    pub verdict_mode: VerdictMode,
}

impl Rewarder {
    pub fn ground_truth() -> Rewarder {
        Rewarder {
            kind: RewardKind::GroundTruth,
            bt_params: None,
            verifier_params: None,
            verdict_mode: VerdictMode::Logit,
        }
    }

    pub fn bradley_terry(params: ParamSet) -> Rewarder {
        Rewarder {
            kind: RewardKind::BradleyTerry,
            bt_params: Some(params),
            verifier_params: None,
            verdict_mode: VerdictMode::Logit,
        }
    }

    pub fn generative(params: ParamSet, mode: VerdictMode) -> Rewarder {
        Rewarder {
            kind: RewardKind::Generative,
            bt_params: None,
            verifier_params: Some(params),
            verdict_mode: mode,
        }
    }

    /// Score one generation batch.  `tasks` pairs 1:1 with `gen.rows`.
    pub fn score(&self, engine: &Engine, tasks: &[Task], gen: &GenOutput) -> Result<Vec<f32>> {
        let dims = engine.manifest().dims.clone();
        if tasks.len() != gen.rows.len() {
            bail!("tasks {} vs rows {}", tasks.len(), gen.rows.len());
        }
        match self.kind {
            RewardKind::GroundTruth => Ok(tasks
                .iter()
                .zip(&gen.rows)
                .map(|(t, row)| {
                    let resp = tokenizer::extract_response(row, dims.prompt_len);
                    if t.check(&resp) {
                        1.0
                    } else {
                        0.0
                    }
                })
                .collect()),
            RewardKind::BradleyTerry => {
                let params = self.bt_params.as_ref().expect("bt params");
                score_bt(engine, params, &gen.rows, dims.prompt_len)
            }
            RewardKind::Generative => {
                let params = self.verifier_params.as_ref().expect("verifier params");
                let responses: Vec<String> = gen
                    .rows
                    .iter()
                    .map(|r| tokenizer::extract_response(r, dims.prompt_len))
                    .collect();
                score_generative(engine, params, tasks, &responses, self.verdict_mode)
            }
        }
    }
}

/// Bradley-Terry scores: reward head value at each row's last real token.
pub fn score_bt(
    engine: &Engine,
    params: &ParamSet,
    rows: &[Vec<i32>],
    prompt_len: usize,
) -> Result<Vec<f32>> {
    let b = rows.len();
    let s = rows[0].len();
    let idx: Vec<i32> = rows
        .iter()
        .map(|r| tokenizer::last_token_index(r, prompt_len) as i32)
        .collect();
    let mut inputs = params.tensors.clone();
    inputs.push(Tensor::i32(vec![b, s], rows.iter().flatten().copied().collect()));
    inputs.push(Tensor::i32(vec![b], idx));
    let scores = engine.run("reward_score", &inputs)?.remove(0);
    Ok(scores.as_f32()?.to_vec())
}

/// Build one verifier query row: "<padded prompt><answer> V:" padded to S.
/// Returns (row, query_end_index) where `query_end_index` is the ':'
/// position — the verdict token is predicted from there.
pub fn verifier_row(
    task: &Task,
    response: &str,
    prompt_len: usize,
    seq: usize,
) -> Result<(Vec<i32>, usize)> {
    let mut row = task.prompt_tokens(prompt_len)?;
    // cap the response in BYTES so the query always fits (generated text
    // can contain multi-byte replacement chars after lossy decode)
    let budget = seq.saturating_sub(prompt_len + 3 + 4);
    let mut resp = response.to_string();
    while resp.len() > budget {
        resp.pop();
    }
    row.extend(tokenizer::encode(&format!("{resp} V:")));
    let qend = row.len() - 1;
    row.resize(seq, PAD);
    Ok((row, qend))
}

/// Generative verification of a batch of (task, response) pairs.
pub fn score_generative(
    engine: &Engine,
    params: &ParamSet,
    tasks: &[Task],
    responses: &[String],
    mode: VerdictMode,
) -> Result<Vec<f32>> {
    let dims = engine.manifest().dims.clone();
    let (b, s, v) = (dims.batch, dims.max_seq, dims.vocab);
    if tasks.len() != b {
        bail!("verifier batch must be exactly {b}, got {}", tasks.len());
    }
    let mut rows = Vec::with_capacity(b);
    let mut qends = Vec::with_capacity(b);
    for (t, r) in tasks.iter().zip(responses) {
        let (row, qend) = verifier_row(t, r, dims.prompt_len, s)?;
        rows.push(row);
        qends.push(qend);
    }

    match mode {
        VerdictMode::Logit => {
            let mut inputs = params.tensors.clone();
            inputs.push(Tensor::i32(vec![b, s], rows.iter().flatten().copied().collect()));
            let logits = engine.run("fwd_logits", &inputs)?.remove(0);
            let ld = logits.as_f32()?;
            Ok((0..b)
                .map(|i| {
                    let base = i * s * v + qends[i] * v;
                    let y = ld[base + b'y' as usize];
                    let n = ld[base + b'n' as usize];
                    if y > n {
                        1.0
                    } else {
                        0.0
                    }
                })
                .collect())
        }
        VerdictMode::Regex => {
            let re = Regex::new(r"^(yes|no)").unwrap();
            // greedy-decode up to 4 verdict tokens via repeated full forwards
            let mut cur = rows.clone();
            let mut ends = qends.clone();
            for _ in 0..4 {
                let mut inputs = params.tensors.clone();
                inputs.push(Tensor::i32(
                    vec![b, s],
                    cur.iter().flatten().copied().collect(),
                ));
                let logits = engine.run("fwd_logits", &inputs)?.remove(0);
                let ld = logits.as_f32()?;
                for i in 0..b {
                    if ends[i] + 1 >= s {
                        continue;
                    }
                    let base = i * s * v + ends[i] * v;
                    let tok = ld[base..base + v]
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .unwrap()
                        .0 as i32;
                    ends[i] += 1;
                    cur[i][ends[i]] = tok;
                }
            }
            Ok((0..b)
                .map(|i| {
                    let verdict: String =
                        tokenizer::decode(&cur[i][qends[i] + 1..=ends[i].min(s - 1)]);
                    match re.captures(verdict.trim()) {
                        Some(c) if &c[1] == "yes" => 1.0,
                        _ => 0.0,
                    }
                })
                .collect())
        }
    }
}

/// Accuracy of scores against ground truth (eval telemetry for E6).
pub fn reward_accuracy(tasks: &[Task], responses: &[String], scores: &[f32]) -> f64 {
    let mut correct = 0usize;
    for ((t, r), &s) in tasks.iter().zip(responses).zip(scores) {
        let truth = t.check(r);
        let predicted = s > 0.5;
        if truth == predicted {
            correct += 1;
        }
    }
    correct as f64 / tasks.len().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tasks::{TaskGen, TaskKind};

    #[test]
    fn verifier_row_shape_and_qend() {
        let mut g = TaskGen::new(vec![TaskKind::Add], 1);
        let t = g.sample();
        let (row, qend) = verifier_row(&t, "7", 16, 64).unwrap();
        assert_eq!(row.len(), 64);
        assert_eq!(row[qend], b':' as i32);
        let text = tokenizer::decode(&row);
        assert!(text.ends_with("V:"), "{text}");
    }

    #[test]
    fn verifier_row_truncates_long_response() {
        let mut g = TaskGen::new(vec![TaskKind::Add], 2);
        let t = g.sample();
        let long = "9".repeat(200);
        let (row, qend) = verifier_row(&t, &long, 16, 64).unwrap();
        assert_eq!(row.len(), 64);
        assert!(qend < 64);
    }

    #[test]
    fn reward_accuracy_metric() {
        let mut g = TaskGen::new(vec![TaskKind::Add], 3);
        let tasks: Vec<Task> = g.sample_n(4);
        let responses: Vec<String> = vec![
            tasks[0].answer.clone(),   // correct
            "wrong".into(),            // wrong
            tasks[2].answer.clone(),   // correct
            "wrong".into(),            // wrong
        ];
        // scores agree with truth on 3 of 4
        let scores = [1.0, 0.0, 0.0, 0.0];
        assert!((reward_accuracy(&tasks, &responses, &scores) - 0.75).abs() < 1e-9);
    }
}
