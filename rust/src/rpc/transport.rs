//! RPC transports: in-process, TCP (length-prefixed frames), and a
//! fault-injecting wrapper for the exactly-once tests (E8).

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::rpc::server::{RpcServer, Service};
use crate::rpc::wire::{Request, Response};
use crate::util::rng::Rng;

/// A request/response transport.  `deliver` carries one encoded Request and
/// returns the encoded Response (or a transport error — the retry trigger).
pub trait Transport: Send + Sync {
    fn deliver(&self, request: &Request) -> Result<Response>;
}

impl<T: Transport + ?Sized> Transport for Box<T> {
    fn deliver(&self, request: &Request) -> Result<Response> {
        (**self).deliver(request)
    }
}

impl<T: Transport + ?Sized> Transport for Arc<T> {
    fn deliver(&self, request: &Request) -> Result<Response> {
        (**self).deliver(request)
    }
}

// ---------------------------------------------------------------------------
// In-process
// ---------------------------------------------------------------------------

pub struct InProcTransport<S: Service> {
    server: Arc<RpcServer<S>>,
}

impl<S: Service> InProcTransport<S> {
    pub fn new(server: Arc<RpcServer<S>>) -> Self {
        InProcTransport { server }
    }
}

impl<S: Service> Transport for InProcTransport<S> {
    fn deliver(&self, request: &Request) -> Result<Response> {
        Ok(self.server.dispatch(request))
    }
}

// ---------------------------------------------------------------------------
// TCP
// ---------------------------------------------------------------------------

fn write_frame(stream: &mut TcpStream, bytes: &[u8]) -> Result<()> {
    stream.write_all(&(bytes.len() as u32).to_le_bytes())?;
    stream.write_all(bytes)?;
    Ok(())
}

fn read_frame(stream: &mut TcpStream) -> Result<Vec<u8>> {
    let mut len_buf = [0u8; 4];
    stream.read_exact(&mut len_buf).context("reading frame length")?;
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > 1 << 30 {
        bail!("frame too large: {len}");
    }
    let mut buf = vec![0u8; len];
    stream.read_exact(&mut buf).context("reading frame body")?;
    Ok(buf)
}

/// TCP server: accepts connections, one handler thread each, dispatching
/// into a shared `RpcServer`.
pub struct TcpRpcHost {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl TcpRpcHost {
    /// Bind on 127.0.0.1:0 (ephemeral port) and serve until dropped.
    pub fn spawn<S: Service + 'static>(server: Arc<RpcServer<S>>) -> Result<TcpRpcHost> {
        Self::spawn_on("127.0.0.1:0", server)
    }

    /// Bind on an explicit address (fixed ports for multi-process launches)
    /// and serve until dropped.
    pub fn spawn_on<S: Service + 'static>(
        addr: &str,
        server: Arc<RpcServer<S>>,
    ) -> Result<TcpRpcHost> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::spawn(move || {
            let mut workers = Vec::new();
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((mut stream, _)) => {
                        stream.set_nonblocking(false).ok();
                        let server = server.clone();
                        workers.push(std::thread::spawn(move || {
                            loop {
                                let frame = match read_frame(&mut stream) {
                                    Ok(f) => f,
                                    Err(_) => break, // connection closed
                                };
                                let resp = match Request::decode(&frame) {
                                    Ok(req) => server.dispatch(&req),
                                    Err(e) => Response {
                                        id: 0,
                                        status: crate::rpc::wire::Status::Err,
                                        payload: format!("{e:#}").into_bytes(),
                                    },
                                };
                                if write_frame(&mut stream, &resp.encode()).is_err() {
                                    break;
                                }
                            }
                        }));
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(2));
                    }
                    Err(_) => break,
                }
            }
            for w in workers {
                w.join().ok();
            }
        });
        Ok(TcpRpcHost { addr, stop, handle: Some(handle) })
    }
}

impl Drop for TcpRpcHost {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            h.join().ok();
        }
    }
}

/// TCP client transport: one persistent connection, re-established on error.
///
/// Every socket operation is bounded: `connect_timeout` caps the handshake
/// and `io_timeout` caps each read/write.  A hung peer therefore surfaces as
/// a deliver error (which the retry layer turns into a reconnect) instead of
/// wedging the caller forever.  Defaults are generous — they exist to bound
/// pathologies, not to race healthy servers.
pub struct TcpTransport {
    addr: std::net::SocketAddr,
    conn: Mutex<Option<TcpStream>>,
    connect_timeout: std::time::Duration,
    io_timeout: std::time::Duration,
}

impl TcpTransport {
    pub const DEFAULT_CONNECT_TIMEOUT: std::time::Duration =
        std::time::Duration::from_millis(10_000);
    pub const DEFAULT_IO_TIMEOUT: std::time::Duration =
        std::time::Duration::from_millis(30_000);

    pub fn connect(addr: std::net::SocketAddr) -> TcpTransport {
        TcpTransport {
            addr,
            conn: Mutex::new(None),
            connect_timeout: Self::DEFAULT_CONNECT_TIMEOUT,
            io_timeout: Self::DEFAULT_IO_TIMEOUT,
        }
    }

    /// Override both timeouts (config-plumbed from `tcp_connect_timeout_ms`
    /// / `tcp_io_timeout_ms`).  Zero means "no bound" for that class.
    pub fn with_timeouts(
        mut self,
        connect: std::time::Duration,
        io: std::time::Duration,
    ) -> TcpTransport {
        self.connect_timeout = connect;
        self.io_timeout = io;
        self
    }
}

impl Transport for TcpTransport {
    fn deliver(&self, request: &Request) -> Result<Response> {
        let mut guard = self.conn.lock().unwrap();
        if guard.is_none() {
            let stream = if self.connect_timeout.is_zero() {
                TcpStream::connect(self.addr).context("connecting")?
            } else {
                TcpStream::connect_timeout(&self.addr, self.connect_timeout)
                    .context("connecting")?
            };
            if !self.io_timeout.is_zero() {
                stream.set_read_timeout(Some(self.io_timeout)).ok();
                stream.set_write_timeout(Some(self.io_timeout)).ok();
            }
            *guard = Some(stream);
        }
        let stream = guard.as_mut().unwrap();
        let result = (|| -> Result<Response> {
            write_frame(stream, &request.encode())?;
            let frame = read_frame(stream)?;
            Response::decode(&frame)
        })();
        if result.is_err() {
            *guard = None; // force reconnect on next call
        }
        result
    }
}

// ---------------------------------------------------------------------------
// Byte metering
// ---------------------------------------------------------------------------

/// Bytes a metered transport moved (encoded request/response frames).
#[derive(Debug, Default)]
pub struct TransferStats {
    pub sent: AtomicU64,
    pub received: AtomicU64,
}

impl TransferStats {
    pub fn total(&self) -> u64 {
        self.sent.load(Ordering::Relaxed) + self.received.load(Ordering::Relaxed)
    }
}

/// Wraps a transport and counts encoded request/response bytes — how E8c
/// measures per-rank traffic instead of asserting it.
pub struct MeteredTransport<T: Transport> {
    inner: T,
    stats: Arc<TransferStats>,
}

impl<T: Transport> MeteredTransport<T> {
    pub fn new(inner: T) -> MeteredTransport<T> {
        MeteredTransport { inner, stats: Arc::new(TransferStats::default()) }
    }

    /// Meter into an existing counter — lets every connection a rank opens
    /// (bootstrap + ring successor) accumulate into one per-rank total.
    pub fn with_stats(inner: T, stats: Arc<TransferStats>) -> MeteredTransport<T> {
        MeteredTransport { inner, stats }
    }

    /// Shared handle to the counters (read after the run completes).
    pub fn stats(&self) -> Arc<TransferStats> {
        self.stats.clone()
    }
}

/// Encoded size of a request frame, without re-encoding it:
/// u64 id + length-prefixed method + length-prefixed payload (wire.rs).
fn request_frame_len(req: &Request) -> u64 {
    (8 + 4 + req.method.len() + 4 + req.payload.len()) as u64
}

/// Encoded size of a response frame: u64 id + status byte + payload.
fn response_frame_len(resp: &Response) -> u64 {
    (8 + 1 + 4 + resp.payload.len()) as u64
}

impl<T: Transport> Transport for MeteredTransport<T> {
    fn deliver(&self, request: &Request) -> Result<Response> {
        self.stats
            .sent
            .fetch_add(request_frame_len(request), Ordering::Relaxed);
        let resp = self.inner.deliver(request)?;
        self.stats
            .received
            .fetch_add(response_frame_len(&resp), Ordering::Relaxed);
        Ok(resp)
    }
}

// ---------------------------------------------------------------------------
// Fault injection
// ---------------------------------------------------------------------------

/// Wraps a transport and injects failures:
/// * `drop_request_prob` — request lost before reaching the server;
/// * `drop_response_prob` — server executed, but the response is lost
///   (the dangerous case exactly-once semantics exist for);
/// * `duplicate_prob` — the request is delivered twice.
pub struct FlakyTransport<T: Transport> {
    inner: T,
    pub drop_request_prob: f64,
    pub drop_response_prob: f64,
    pub duplicate_prob: f64,
    rng: Mutex<Rng>,
    pub injected_failures: AtomicU64,
}

impl<T: Transport> FlakyTransport<T> {
    pub fn new(inner: T, seed: u64) -> FlakyTransport<T> {
        FlakyTransport {
            inner,
            drop_request_prob: 0.0,
            drop_response_prob: 0.0,
            duplicate_prob: 0.0,
            rng: Mutex::new(Rng::new(seed)),
            injected_failures: AtomicU64::new(0),
        }
    }

    pub fn with_probs(mut self, req: f64, resp: f64, dup: f64) -> Self {
        self.drop_request_prob = req;
        self.drop_response_prob = resp;
        self.duplicate_prob = dup;
        self
    }
}

impl<T: Transport> Transport for FlakyTransport<T> {
    fn deliver(&self, request: &Request) -> Result<Response> {
        let (drop_req, drop_resp, dup) = {
            let mut rng = self.rng.lock().unwrap();
            (
                rng.bool(self.drop_request_prob),
                rng.bool(self.drop_response_prob),
                rng.bool(self.duplicate_prob),
            )
        };
        if drop_req {
            self.injected_failures.fetch_add(1, Ordering::Relaxed);
            bail!("injected: request dropped");
        }
        if dup {
            // deliver twice; first response discarded
            let _ = self.inner.deliver(request)?;
        }
        let resp = self.inner.deliver(request)?;
        if drop_resp {
            self.injected_failures.fetch_add(1, Ordering::Relaxed);
            bail!("injected: response dropped");
        }
        Ok(resp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rpc::wire::Status;

    fn echo() -> Arc<RpcServer<impl Service>> {
        Arc::new(RpcServer::new(|_m: &str, p: &[u8]| Ok(p.to_vec())))
    }

    #[test]
    fn inproc_roundtrip() {
        let t = InProcTransport::new(echo());
        let r = t
            .deliver(&Request { id: 1, method: "e".into(), payload: vec![5] })
            .unwrap();
        assert_eq!(r.status, Status::Ok);
        assert_eq!(r.payload, vec![5]);
    }

    #[test]
    fn tcp_roundtrip() {
        let server = echo();
        let host = TcpRpcHost::spawn(server.clone()).unwrap();
        let t = TcpTransport::connect(host.addr);
        for i in 0..10u64 {
            let r = t
                .deliver(&Request { id: i, method: "e".into(), payload: vec![i as u8] })
                .unwrap();
            assert_eq!(r.payload, vec![i as u8]);
        }
        assert_eq!(server.stats().executed, 10);
    }

    #[test]
    fn tcp_concurrent_clients() {
        let server = echo();
        let host = TcpRpcHost::spawn(server.clone()).unwrap();
        let addr = host.addr;
        let threads: Vec<_> = (0..4)
            .map(|t| {
                std::thread::spawn(move || {
                    let tr = TcpTransport::connect(addr);
                    for i in 0..25u64 {
                        let id = t * 1000 + i;
                        let r = tr
                            .deliver(&Request {
                                id,
                                method: "e".into(),
                                payload: id.to_le_bytes().to_vec(),
                            })
                            .unwrap();
                        assert_eq!(r.payload, id.to_le_bytes().to_vec());
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(server.stats().executed, 100);
    }

    #[test]
    fn metered_transport_counts_frame_bytes() {
        let t = MeteredTransport::new(InProcTransport::new(echo()));
        let stats = t.stats();
        let req = Request { id: 1, method: "e".into(), payload: vec![7; 100] };
        let resp = t.deliver(&req).unwrap();
        assert_eq!(stats.sent.load(Ordering::Relaxed), req.encode().len() as u64);
        assert_eq!(
            stats.received.load(Ordering::Relaxed),
            resp.encode().len() as u64
        );
        assert_eq!(stats.total(), (req.encode().len() + resp.encode().len()) as u64);
    }

    #[test]
    fn io_timeout_bounds_a_silent_server() {
        // A listener that accepts but never replies: the read must time out
        // instead of blocking forever, and the error forces a reconnect.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let hold = std::thread::spawn(move || {
            let (_stream, _) = listener.accept().unwrap();
            std::thread::sleep(std::time::Duration::from_millis(500));
        });
        let t = TcpTransport::connect(addr).with_timeouts(
            std::time::Duration::from_millis(1000),
            std::time::Duration::from_millis(50),
        );
        let t0 = std::time::Instant::now();
        let r = t.deliver(&Request { id: 1, method: "e".into(), payload: vec![] });
        assert!(r.is_err(), "silent server must surface as a deliver error");
        assert!(
            t0.elapsed() < std::time::Duration::from_millis(450),
            "read should be cut by the io timeout, took {:?}",
            t0.elapsed()
        );
        hold.join().unwrap();
    }

    #[test]
    fn flaky_drops_surface_as_errors() {
        let t = FlakyTransport::new(InProcTransport::new(echo()), 1)
            .with_probs(1.0, 0.0, 0.0);
        assert!(t
            .deliver(&Request { id: 1, method: "e".into(), payload: vec![] })
            .is_err());
    }
}
