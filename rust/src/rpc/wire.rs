//! RPC wire format: binary envelopes over the util::codec primitives.

use anyhow::{bail, Result};

use crate::util::codec::{Reader, Writer};

#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub id: u64,
    pub method: String,
    pub payload: Vec<u8>,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Status {
    Ok = 0,
    Err = 1,
    /// cleanup acknowledgement
    Cleaned = 2,
}

#[derive(Debug, Clone, PartialEq)]
pub struct Response {
    pub id: u64,
    pub status: Status,
    pub payload: Vec<u8>,
}

pub const METHOD_CLEANUP: &str = "__cleanup";

impl Request {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        w.u64(self.id);
        w.str(&self.method);
        w.bytes(&self.payload);
        w.into_bytes()
    }

    pub fn decode(bytes: &[u8]) -> Result<Request> {
        let mut r = Reader::new(bytes);
        let req = Request {
            id: r.u64()?,
            method: r.str()?,
            payload: r.bytes()?.to_vec(),
        };
        r.expect_end()?;
        Ok(req)
    }

    pub fn cleanup(id_to_clean: u64, my_id: u64) -> Request {
        let mut w = Writer::new();
        w.u64(id_to_clean);
        Request { id: my_id, method: METHOD_CLEANUP.into(), payload: w.into_bytes() }
    }
}

impl Response {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        w.u64(self.id);
        w.u8(self.status as u8);
        w.bytes(&self.payload);
        w.into_bytes()
    }

    pub fn decode(bytes: &[u8]) -> Result<Response> {
        let mut r = Reader::new(bytes);
        let id = r.u64()?;
        let status = match r.u8()? {
            0 => Status::Ok,
            1 => Status::Err,
            2 => Status::Cleaned,
            s => bail!("bad status byte {s}"),
        };
        let payload = r.bytes()?.to_vec();
        r.expect_end()?;
        Ok(Response { id, status, payload })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        let req = Request { id: 42, method: "generate".into(), payload: vec![1, 2, 3] };
        assert_eq!(Request::decode(&req.encode()).unwrap(), req);
    }

    #[test]
    fn response_roundtrip() {
        for status in [Status::Ok, Status::Err, Status::Cleaned] {
            let resp = Response { id: 7, status, payload: b"xyz".to_vec() };
            assert_eq!(Response::decode(&resp.encode()).unwrap(), resp);
        }
    }

    #[test]
    fn truncated_frames_rejected() {
        let req = Request { id: 1, method: "m".into(), payload: vec![0; 16] };
        let enc = req.encode();
        assert!(Request::decode(&enc[..enc.len() - 1]).is_err());
        assert!(Response::decode(&[1, 2, 3]).is_err());
    }
}
