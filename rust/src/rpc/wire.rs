//! RPC wire format: binary envelopes over the util::codec primitives.

use anyhow::{bail, Result};

use crate::util::codec::{Reader, Writer};

#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub id: u64,
    pub method: String,
    pub payload: Vec<u8>,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Status {
    Ok = 0,
    Err = 1,
    /// cleanup acknowledgement
    Cleaned = 2,
}

#[derive(Debug, Clone, PartialEq)]
pub struct Response {
    pub id: u64,
    pub status: Status,
    pub payload: Vec<u8>,
}

pub const METHOD_CLEANUP: &str = "__cleanup";

impl Request {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        w.u64(self.id);
        w.str(&self.method);
        w.bytes(&self.payload);
        w.into_bytes()
    }

    pub fn decode(bytes: &[u8]) -> Result<Request> {
        let mut r = Reader::new(bytes);
        let req = Request {
            id: r.u64()?,
            method: r.str()?,
            payload: r.bytes()?.to_vec(),
        };
        r.expect_end()?;
        Ok(req)
    }

    pub fn cleanup(id_to_clean: u64, my_id: u64) -> Request {
        let mut w = Writer::new();
        w.u64(id_to_clean);
        Request { id: my_id, method: METHOD_CLEANUP.into(), payload: w.into_bytes() }
    }
}

impl Response {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        w.u64(self.id);
        w.u8(self.status as u8);
        w.bytes(&self.payload);
        w.into_bytes()
    }

    pub fn decode(bytes: &[u8]) -> Result<Response> {
        let mut r = Reader::new(bytes);
        let id = r.u64()?;
        let status = match r.u8()? {
            0 => Status::Ok,
            1 => Status::Err,
            2 => Status::Cleaned,
            s => bail!("bad status byte {s}"),
        };
        let payload = r.bytes()?.to_vec();
        r.expect_end()?;
        Ok(Response { id, status, payload })
    }
}

// ---------------------------------------------------------------------------
// Collective rendezvous frames (coordinator::rpc_collective)
// ---------------------------------------------------------------------------

/// One rank's contribution to a collective all-gather round, batched as a
/// single length-prefixed frame (seq/rank/world header + opaque payload —
/// e.g. a codec-encoded `ParamSet` for gradient all-reduce).
#[derive(Debug, Clone, PartialEq)]
pub struct GatherFrame {
    /// Round sequence number — SPMD lockstep guarantees all ranks agree.
    pub seq: u64,
    pub rank: u32,
    pub world: u32,
    /// Rendezvous generation: bumped by the supervisor on every recovery
    /// respawn so frames from a pre-crash epoch are rejected instead of
    /// contaminating the restarted job's rounds.
    pub epoch: u64,
    /// Logical channel ("params", "scalars", …) — checked by the host to
    /// catch collective-order mismatches early.
    pub tag: String,
    pub payload: Vec<u8>,
}

impl GatherFrame {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        w.u64(self.seq);
        w.u32(self.rank);
        w.u32(self.world);
        w.u64(self.epoch);
        w.str(&self.tag);
        w.bytes(&self.payload);
        w.into_bytes()
    }

    pub fn decode(bytes: &[u8]) -> Result<GatherFrame> {
        let mut r = Reader::new(bytes);
        let f = GatherFrame {
            seq: r.u64()?,
            rank: r.u32()?,
            world: r.u32()?,
            epoch: r.u64()?,
            tag: r.str()?,
            payload: r.bytes()?.to_vec(),
        };
        r.expect_end()?;
        Ok(f)
    }
}

/// A poll for a round's result (no payload re-upload on retry loops).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PollFrame {
    pub seq: u64,
    pub rank: u32,
    pub epoch: u64,
}

impl PollFrame {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        w.u64(self.seq);
        w.u32(self.rank);
        w.u64(self.epoch);
        w.into_bytes()
    }

    pub fn decode(bytes: &[u8]) -> Result<PollFrame> {
        let mut r = Reader::new(bytes);
        let f = PollFrame { seq: r.u64()?, rank: r.u32()?, epoch: r.u64()? };
        r.expect_end()?;
        Ok(f)
    }
}

/// A worker's heartbeat (or liveness probe) to the rendezvous host:
/// "rank R of generation E is alive".  The same frame doubles as the
/// payload of `collective.alive` probes, which read the lease table
/// without renewing any lease.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HeartbeatFrame {
    pub rank: u32,
    pub epoch: u64,
}

impl HeartbeatFrame {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        w.u32(self.rank);
        w.u64(self.epoch);
        w.into_bytes()
    }

    pub fn decode(bytes: &[u8]) -> Result<HeartbeatFrame> {
        let mut r = Reader::new(bytes);
        let f = HeartbeatFrame { rank: r.u32()?, epoch: r.u64()? };
        r.expect_end()?;
        Ok(f)
    }
}

/// The rendezvous host's view of group liveness, returned to heartbeats
/// and `collective.alive` probes: the first rank whose lease expired, if
/// any.  Latched — once a rank is declared dead the verdict never reverts,
/// so every prober observes the same casualty.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LivenessReply {
    pub dead: Option<u32>,
}

impl LivenessReply {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        match self.dead {
            None => w.u8(0),
            Some(rank) => {
                w.u8(1);
                w.u32(rank);
            }
        }
        w.into_bytes()
    }

    pub fn decode(bytes: &[u8]) -> Result<LivenessReply> {
        let mut r = Reader::new(bytes);
        let reply = match r.u8()? {
            0 => LivenessReply { dead: None },
            1 => LivenessReply { dead: Some(r.u32()?) },
            t => bail!("bad liveness-reply tag {t}"),
        };
        r.expect_end()?;
        Ok(reply)
    }
}

/// The rendezvous host's answer: still waiting, or every rank's payload in
/// rank order.
#[derive(Debug, Clone, PartialEq)]
pub enum GatherReply {
    Pending,
    Ready(Vec<Vec<u8>>),
}

impl GatherReply {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        match self {
            GatherReply::Pending => w.u8(0),
            GatherReply::Ready(parts) => {
                w.u8(1);
                w.u32(parts.len() as u32);
                for p in parts {
                    w.bytes(p);
                }
            }
        }
        w.into_bytes()
    }

    pub fn decode(bytes: &[u8]) -> Result<GatherReply> {
        let mut r = Reader::new(bytes);
        let reply = match r.u8()? {
            0 => GatherReply::Pending,
            1 => {
                let n = r.u32()? as usize;
                let mut parts = Vec::with_capacity(n);
                for _ in 0..n {
                    parts.push(r.bytes()?.to_vec());
                }
                GatherReply::Ready(parts)
            }
            t => bail!("bad gather-reply tag {t}"),
        };
        r.expect_end()?;
        Ok(reply)
    }
}

// ---------------------------------------------------------------------------
// Ring collective streaming frames (coordinator::ring_collective)
// ---------------------------------------------------------------------------

/// Ring traffic phases.  `GATHER` carries origin payloads hopping around the
/// ring (all-gather); `REDUCE` carries rank-order partial sums flowing
/// 0 → 1 → … → N-1; `BCAST` distributes the fully reduced result from the
/// last rank back around the ring.
pub const PHASE_GATHER: u8 = 0;
pub const PHASE_REDUCE: u8 = 1;
pub const PHASE_BCAST: u8 = 2;

/// One bounded chunk of a streamed collective payload.  Large ParamSets are
/// split into `total` chunks so no host ever buffers a whole multi-GB
/// payload; `round` is the SPMD round epoch, `origin` the rank whose payload
/// the chunk belongs to (all-gather routing; 0 for reduce/bcast streams).
#[derive(Debug, Clone, PartialEq)]
pub struct ChunkFrame {
    pub round: u64,
    pub phase: u8,
    pub origin: u32,
    /// chunk index within the payload
    pub chunk: u32,
    /// total chunks this payload streams as (>= 1 even when empty)
    pub total: u32,
    /// logical channel ("params", "scalars", …) — checked by the receiver to
    /// catch collective-order mismatches early.
    pub tag: String,
    pub payload: Vec<u8>,
}

impl ChunkFrame {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        w.u64(self.round);
        w.u8(self.phase);
        w.u32(self.origin);
        w.u32(self.chunk);
        w.u32(self.total);
        w.str(&self.tag);
        w.bytes(&self.payload);
        w.into_bytes()
    }

    pub fn decode(bytes: &[u8]) -> Result<ChunkFrame> {
        let mut r = Reader::new(bytes);
        let f = ChunkFrame {
            round: r.u64()?,
            phase: r.u8()?,
            origin: r.u32()?,
            chunk: r.u32()?,
            total: r.u32()?,
            tag: r.str()?,
            payload: r.bytes()?.to_vec(),
        };
        r.expect_end()?;
        if f.phase > PHASE_BCAST {
            bail!("bad chunk phase {}", f.phase);
        }
        if f.total == 0 {
            bail!("chunk total must be >= 1");
        }
        if f.chunk >= f.total {
            bail!("chunk index {} out of range for total {}", f.chunk, f.total);
        }
        Ok(f)
    }
}

/// The ring peer's answer to a delivered chunk: how many chunks its inbox is
/// currently buffering.  Senders throttle when this exceeds their window, so
/// a slow rank bounds its predecessor's stream instead of buffering it whole.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChunkAck {
    pub backlog: u32,
}

impl ChunkAck {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        w.u32(self.backlog);
        w.into_bytes()
    }

    pub fn decode(bytes: &[u8]) -> Result<ChunkAck> {
        let mut r = Reader::new(bytes);
        let a = ChunkAck { backlog: r.u32()? };
        r.expect_end()?;
        Ok(a)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        let req = Request { id: 42, method: "generate".into(), payload: vec![1, 2, 3] };
        assert_eq!(Request::decode(&req.encode()).unwrap(), req);
    }

    #[test]
    fn response_roundtrip() {
        for status in [Status::Ok, Status::Err, Status::Cleaned] {
            let resp = Response { id: 7, status, payload: b"xyz".to_vec() };
            assert_eq!(Response::decode(&resp.encode()).unwrap(), resp);
        }
    }

    #[test]
    fn gather_frames_roundtrip() {
        let f = GatherFrame {
            seq: 9,
            rank: 2,
            world: 4,
            epoch: 3,
            tag: "params".into(),
            payload: vec![1, 2, 3, 4, 5],
        };
        assert_eq!(GatherFrame::decode(&f.encode()).unwrap(), f);
        let p = PollFrame { seq: 9, rank: 2, epoch: 3 };
        assert_eq!(PollFrame::decode(&p.encode()).unwrap(), p);
        for reply in [
            GatherReply::Pending,
            GatherReply::Ready(vec![vec![], vec![7, 7], vec![0; 100]]),
        ] {
            assert_eq!(GatherReply::decode(&reply.encode()).unwrap(), reply);
        }
        assert!(GatherReply::decode(&[9]).is_err());
    }

    #[test]
    fn heartbeat_frames_roundtrip() {
        let h = HeartbeatFrame { rank: 3, epoch: 2 };
        assert_eq!(HeartbeatFrame::decode(&h.encode()).unwrap(), h);
        for reply in [LivenessReply { dead: None }, LivenessReply { dead: Some(1) }] {
            assert_eq!(LivenessReply::decode(&reply.encode()).unwrap(), reply);
        }
        assert!(LivenessReply::decode(&[7]).is_err());
        let enc = h.encode();
        assert!(HeartbeatFrame::decode(&enc[..enc.len() - 1]).is_err());
    }

    #[test]
    fn chunk_frames_roundtrip() {
        let f = ChunkFrame {
            round: 12,
            phase: PHASE_REDUCE,
            origin: 0,
            chunk: 3,
            total: 7,
            tag: "params".into(),
            payload: vec![1, 2, 3],
        };
        assert_eq!(ChunkFrame::decode(&f.encode()).unwrap(), f);
        let a = ChunkAck { backlog: 9 };
        assert_eq!(ChunkAck::decode(&a.encode()).unwrap(), a);
        // empty payloads stream as one empty chunk
        let empty = ChunkFrame {
            round: 0,
            phase: PHASE_GATHER,
            origin: 2,
            chunk: 0,
            total: 1,
            tag: "barrier".into(),
            payload: vec![],
        };
        assert_eq!(ChunkFrame::decode(&empty.encode()).unwrap(), empty);
    }

    #[test]
    fn malformed_chunk_frames_rejected() {
        let mut bad_phase = ChunkFrame {
            round: 1,
            phase: PHASE_BCAST,
            origin: 0,
            chunk: 0,
            total: 1,
            tag: "t".into(),
            payload: vec![],
        };
        bad_phase.phase = 9;
        assert!(ChunkFrame::decode(&bad_phase.encode()).is_err(), "bad phase");
        let out_of_range = ChunkFrame { phase: PHASE_GATHER, chunk: 5, total: 5, ..bad_phase };
        assert!(
            ChunkFrame::decode(&out_of_range.encode()).is_err(),
            "chunk index must be < total"
        );
        let enc = ChunkAck { backlog: 1 }.encode();
        assert!(ChunkAck::decode(&enc[..enc.len() - 1]).is_err());
    }

    #[test]
    fn truncated_frames_rejected() {
        let req = Request { id: 1, method: "m".into(), payload: vec![0; 16] };
        let enc = req.encode();
        assert!(Request::decode(&enc[..enc.len() - 1]).is_err());
        assert!(Response::decode(&[1, 2, 3]).is_err());
    }
}
