//! RPC client: unique ids, bounded retries, result retrieval + cleanup.
//!
//! The paper's protocol (§4.2): the client retries until it retrieves the
//! cached result, then sends a cleanup message.  A server-side `Err`
//! response is NOT retried — it is the fail-fast signal the coordinator
//! escalates into full job termination.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::rpc::transport::Transport;
use crate::rpc::wire::{Request, Response, Status};
use crate::util::rng::Rng;

/// Exponential backoff with decorrelated jitter and an overall per-call
/// deadline.  Jitter is seeded (per call: `seed ^ request id`), so retry
/// schedules are deterministic in tests while still decorrelating real
/// clients hammering one recovering server.  `fixed` recovers the old
/// constant-interval behaviour (base == cap ⇒ no growth, no jitter).
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    pub max_attempts: usize,
    /// first-retry sleep, and the floor of every jittered draw
    pub base: Duration,
    /// ceiling on any single backoff sleep
    pub cap: Duration,
    /// overall wall-clock bound across all attempts of one call (delivery
    /// stops retrying once exceeded, even with attempts left)
    pub deadline: Option<Duration>,
    /// jitter stream seed
    pub seed: u64,
}

impl RetryPolicy {
    /// Constant-interval retries (the pre-backoff behaviour).
    pub fn fixed(max_attempts: usize, interval: Duration) -> RetryPolicy {
        RetryPolicy {
            max_attempts,
            base: interval,
            cap: interval,
            deadline: None,
            seed: 0x5EED,
        }
    }

    /// Decorrelated-jitter exponential backoff: each sleep draws uniformly
    /// from [base, 3 × previous], clamped to a cap of 64 × base.
    pub fn exponential(max_attempts: usize, base: Duration) -> RetryPolicy {
        RetryPolicy {
            max_attempts,
            base,
            cap: base.saturating_mul(64),
            deadline: None,
            seed: 0x5EED,
        }
    }

    pub fn with_deadline(mut self, deadline: Duration) -> Self {
        self.deadline = Some(deadline);
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// The next backoff sleep given the previous one (decorrelated jitter:
    /// `min(cap, uniform(base, prev * 3))`).
    fn next_backoff(&self, prev: Duration, rng: &mut Rng) -> Duration {
        if self.cap <= self.base {
            return self.base; // fixed-interval degenerate case
        }
        let lo = self.base.as_nanos() as f64;
        let hi = (prev.as_nanos() as f64 * 3.0).max(lo);
        let draw = rng.range(lo, hi);
        Duration::from_nanos(draw as u64).min(self.cap)
    }
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy::exponential(8, Duration::from_millis(1))
    }
}

#[derive(Debug, Clone, Default)]
pub struct ClientStats {
    pub calls: u64,
    pub retries: u64,
    pub failures: u64,
}

pub struct RpcClient<T: Transport> {
    transport: T,
    next_id: AtomicU64,
    pub retry: RetryPolicy,
    stats: std::sync::Mutex<ClientStats>,
}

impl<T: Transport> RpcClient<T> {
    pub fn new(transport: T) -> RpcClient<T> {
        // Unique id space per client instance: high bits from a per-process
        // counter so two clients sharing a server never collide.
        static CLIENT_SEQ: AtomicU64 = AtomicU64::new(1);
        let base = CLIENT_SEQ.fetch_add(1, Ordering::Relaxed) << 40;
        RpcClient {
            transport,
            next_id: AtomicU64::new(base),
            retry: RetryPolicy::default(),
            stats: std::sync::Mutex::new(ClientStats::default()),
        }
    }

    pub fn with_retry(mut self, retry: RetryPolicy) -> Self {
        self.retry = retry;
        self
    }

    /// Override the request-id namespace.  The default `CLIENT_SEQ` base is
    /// only unique *within* one process — clients in different OS processes
    /// sharing one server (the multi-process collective) must carve up the
    /// id space explicitly or they would collide in the server's result
    /// cache.
    pub fn with_id_base(self, base: u64) -> Self {
        self.next_id.store(base, Ordering::Relaxed);
        self
    }

    pub fn stats(&self) -> ClientStats {
        self.stats.lock().unwrap().clone()
    }

    /// Borrow the underlying transport (fault-injection stats in tests).
    pub fn transport(&self) -> &T {
        &self.transport
    }

    fn fresh_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Issue one exactly-once call: retry delivery until the result is
    /// retrieved, then clean up the server-side cache entry.
    pub fn call(&self, method: &str, payload: Vec<u8>) -> Result<Vec<u8>> {
        let id = self.fresh_id();
        let req = Request { id, method: method.to_string(), payload };
        self.stats.lock().unwrap().calls += 1;

        let resp = self.deliver_with_retry(&req)?;
        let result = match resp.status {
            Status::Ok => Ok(resp.payload),
            // server-side error: fail fast, no retry (paper §4.2)
            Status::Err => {
                self.stats.lock().unwrap().failures += 1;
                bail!(
                    "rpc '{}' failed on server: {}",
                    method,
                    String::from_utf8_lossy(&resp.payload)
                )
            }
            Status::Cleaned => bail!("unexpected Cleaned status for call"),
        };

        // best-effort cleanup with retry; result already safe in hand
        let cleanup = Request::cleanup(id, self.fresh_id());
        let _ = self.deliver_with_retry(&cleanup);
        result
    }

    fn deliver_with_retry(&self, req: &Request) -> Result<Response> {
        let t0 = Instant::now();
        // per-call jitter stream: deterministic given (policy seed, id)
        let mut rng = Rng::new(self.retry.seed ^ req.id);
        let mut backoff = self.retry.base;
        let mut last_err = None;
        let mut attempts = 0usize;
        while attempts < self.retry.max_attempts {
            attempts += 1;
            match self.transport.deliver(req) {
                Ok(resp) => return Ok(resp),
                Err(e) => last_err = Some(e),
            }
            if attempts == self.retry.max_attempts {
                break;
            }
            if let Some(deadline) = self.retry.deadline {
                if t0.elapsed() + backoff >= deadline {
                    self.stats.lock().unwrap().failures += 1;
                    bail!(
                        "rpc '{}' (id {}) undeliverable after {} attempts \
                         (per-call deadline {:?} exhausted): {:#}",
                        req.method,
                        req.id,
                        attempts,
                        deadline,
                        last_err.unwrap()
                    );
                }
            }
            self.stats.lock().unwrap().retries += 1;
            std::thread::sleep(backoff);
            backoff = self.retry.next_backoff(backoff, &mut rng);
        }
        self.stats.lock().unwrap().failures += 1;
        bail!(
            "rpc '{}' (id {}) undeliverable after {} attempts: {:#}",
            req.method,
            req.id,
            self.retry.max_attempts,
            last_err.unwrap()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rpc::server::{RpcServer, Service};
    use crate::rpc::transport::{FlakyTransport, InProcTransport};
    use std::sync::atomic::AtomicU64 as Counter;
    use std::sync::Arc;

    fn counting_server() -> (Arc<RpcServer<impl Service>>, Arc<Counter>) {
        let count = Arc::new(Counter::new(0));
        let c2 = count.clone();
        let server = Arc::new(RpcServer::new(move |_: &str, p: &[u8]| {
            c2.fetch_add(1, Ordering::SeqCst);
            Ok(p.to_vec())
        }));
        (server, count)
    }

    #[test]
    fn call_cleans_up_after_itself() {
        let (server, _) = counting_server();
        let client = RpcClient::new(InProcTransport::new(server.clone()));
        client.call("m", vec![1]).unwrap();
        assert_eq!(server.stats().cached_now, 0, "cache must be cleaned");
        assert_eq!(server.stats().cleaned, 1);
    }

    #[test]
    fn exactly_once_under_heavy_response_loss() {
        // Responses are lost 40% of the time: the client retries the SAME
        // id, the server serves from cache, the handler runs exactly once
        // per logical call.
        let (server, count) = counting_server();
        let flaky = FlakyTransport::new(InProcTransport::new(server.clone()), 99)
            .with_probs(0.2, 0.4, 0.2);
        let client = RpcClient::new(flaky)
            .with_retry(RetryPolicy::exponential(64, Duration::from_micros(10)));
        let calls = 50;
        for i in 0..calls {
            let out = client.call("work", vec![i as u8]).unwrap();
            assert_eq!(out, vec![i as u8]);
        }
        assert_eq!(
            count.load(Ordering::SeqCst),
            calls,
            "handler must run exactly once per logical call"
        );
        assert!(client.stats().retries > 0, "test should actually inject loss");
    }

    #[test]
    fn server_error_fails_fast_without_retry() {
        let server = Arc::new(RpcServer::new(|_: &str, _: &[u8]| -> anyhow::Result<Vec<u8>> {
            anyhow::bail!("worker exploded")
        }));
        let client = RpcClient::new(InProcTransport::new(server.clone()));
        let err = client.call("m", vec![]).unwrap_err().to_string();
        assert!(err.contains("worker exploded"), "{err}");
        assert_eq!(server.stats().executed, 1, "no retry on server error");
    }

    #[test]
    fn undeliverable_reports_attempts() {
        let (server, _) = counting_server();
        let flaky = FlakyTransport::new(InProcTransport::new(server), 7)
            .with_probs(1.0, 0.0, 0.0);
        let client = RpcClient::new(flaky)
            .with_retry(RetryPolicy::fixed(3, Duration::from_micros(1)));
        let err = client.call("m", vec![]).unwrap_err().to_string();
        assert!(err.contains("3 attempts"), "{err}");
    }

    #[test]
    fn backoff_grows_within_bounds_and_is_deterministic() {
        let policy = RetryPolicy::exponential(16, Duration::from_micros(100)).with_seed(42);
        let walk = |policy: &RetryPolicy, seed: u64| -> Vec<Duration> {
            let mut rng = Rng::new(seed);
            let mut prev = policy.base;
            (0..12)
                .map(|_| {
                    prev = policy.next_backoff(prev, &mut rng);
                    prev
                })
                .collect()
        };
        let a = walk(&policy, 7);
        let b = walk(&policy, 7);
        assert_eq!(a, b, "same seed must give the same schedule");
        let c = walk(&policy, 8);
        assert_ne!(a, c, "different seeds must decorrelate");
        for d in &a {
            assert!(*d >= policy.base && *d <= policy.cap, "{d:?} out of bounds");
        }
        // the schedule must actually grow away from the base at some point
        assert!(a.iter().any(|d| *d > policy.base * 2), "{a:?}");
        // fixed policies never jitter
        let fixed = RetryPolicy::fixed(8, Duration::from_micros(50));
        assert!(walk(&fixed, 9).iter().all(|d| *d == fixed.base));
    }

    #[test]
    fn per_call_deadline_cuts_retries_short() {
        let (server, _) = counting_server();
        let flaky = FlakyTransport::new(InProcTransport::new(server), 13)
            .with_probs(1.0, 0.0, 0.0); // nothing ever delivers
        let client = RpcClient::new(flaky).with_retry(
            RetryPolicy::fixed(1_000_000, Duration::from_millis(5))
                .with_deadline(Duration::from_millis(30)),
        );
        let t0 = std::time::Instant::now();
        let err = client.call("m", vec![]).unwrap_err().to_string();
        assert!(t0.elapsed() < Duration::from_secs(5), "deadline must bound the call");
        assert!(err.contains("deadline"), "{err}");
        let stats = client.stats();
        assert_eq!(stats.calls, 1);
        assert!(stats.failures >= 1, "deadline exhaustion must count as failure");
        assert!(stats.retries >= 1 && stats.retries < 100, "{}", stats.retries);
    }

    #[test]
    fn ids_unique_across_clients() {
        let (server, count) = counting_server();
        let c1 = RpcClient::new(InProcTransport::new(server.clone()));
        let c2 = RpcClient::new(InProcTransport::new(server.clone()));
        c1.call("m", vec![]).unwrap();
        c2.call("m", vec![]).unwrap();
        assert_eq!(count.load(Ordering::SeqCst), 2);
    }
}
