//! RPC client: unique ids, bounded retries, result retrieval + cleanup.
//!
//! The paper's protocol (§4.2): the client retries until it retrieves the
//! cached result, then sends a cleanup message.  A server-side `Err`
//! response is NOT retried — it is the fail-fast signal the coordinator
//! escalates into full job termination.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use anyhow::{bail, Result};

use crate::rpc::transport::Transport;
use crate::rpc::wire::{Request, Response, Status};

#[derive(Debug, Clone)]
pub struct RetryPolicy {
    pub max_attempts: usize,
    pub backoff: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { max_attempts: 8, backoff: Duration::from_millis(1) }
    }
}

#[derive(Debug, Clone, Default)]
pub struct ClientStats {
    pub calls: u64,
    pub retries: u64,
    pub failures: u64,
}

pub struct RpcClient<T: Transport> {
    transport: T,
    next_id: AtomicU64,
    pub retry: RetryPolicy,
    stats: std::sync::Mutex<ClientStats>,
}

impl<T: Transport> RpcClient<T> {
    pub fn new(transport: T) -> RpcClient<T> {
        // Unique id space per client instance: high bits from a per-process
        // counter so two clients sharing a server never collide.
        static CLIENT_SEQ: AtomicU64 = AtomicU64::new(1);
        let base = CLIENT_SEQ.fetch_add(1, Ordering::Relaxed) << 40;
        RpcClient {
            transport,
            next_id: AtomicU64::new(base),
            retry: RetryPolicy::default(),
            stats: std::sync::Mutex::new(ClientStats::default()),
        }
    }

    pub fn with_retry(mut self, retry: RetryPolicy) -> Self {
        self.retry = retry;
        self
    }

    /// Override the request-id namespace.  The default `CLIENT_SEQ` base is
    /// only unique *within* one process — clients in different OS processes
    /// sharing one server (the multi-process collective) must carve up the
    /// id space explicitly or they would collide in the server's result
    /// cache.
    pub fn with_id_base(self, base: u64) -> Self {
        self.next_id.store(base, Ordering::Relaxed);
        self
    }

    pub fn stats(&self) -> ClientStats {
        self.stats.lock().unwrap().clone()
    }

    /// Borrow the underlying transport (fault-injection stats in tests).
    pub fn transport(&self) -> &T {
        &self.transport
    }

    fn fresh_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Issue one exactly-once call: retry delivery until the result is
    /// retrieved, then clean up the server-side cache entry.
    pub fn call(&self, method: &str, payload: Vec<u8>) -> Result<Vec<u8>> {
        let id = self.fresh_id();
        let req = Request { id, method: method.to_string(), payload };
        self.stats.lock().unwrap().calls += 1;

        let resp = self.deliver_with_retry(&req)?;
        let result = match resp.status {
            Status::Ok => Ok(resp.payload),
            // server-side error: fail fast, no retry (paper §4.2)
            Status::Err => {
                self.stats.lock().unwrap().failures += 1;
                bail!(
                    "rpc '{}' failed on server: {}",
                    method,
                    String::from_utf8_lossy(&resp.payload)
                )
            }
            Status::Cleaned => bail!("unexpected Cleaned status for call"),
        };

        // best-effort cleanup with retry; result already safe in hand
        let cleanup = Request::cleanup(id, self.fresh_id());
        let _ = self.deliver_with_retry(&cleanup);
        result
    }

    fn deliver_with_retry(&self, req: &Request) -> Result<Response> {
        let mut last_err = None;
        for attempt in 0..self.retry.max_attempts {
            match self.transport.deliver(req) {
                Ok(resp) => return Ok(resp),
                Err(e) => {
                    last_err = Some(e);
                    if attempt + 1 < self.retry.max_attempts {
                        self.stats.lock().unwrap().retries += 1;
                        std::thread::sleep(self.retry.backoff);
                    }
                }
            }
        }
        self.stats.lock().unwrap().failures += 1;
        bail!(
            "rpc '{}' (id {}) undeliverable after {} attempts: {:#}",
            req.method,
            req.id,
            self.retry.max_attempts,
            last_err.unwrap()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rpc::server::{RpcServer, Service};
    use crate::rpc::transport::{FlakyTransport, InProcTransport};
    use std::sync::atomic::AtomicU64 as Counter;
    use std::sync::Arc;

    fn counting_server() -> (Arc<RpcServer<impl Service>>, Arc<Counter>) {
        let count = Arc::new(Counter::new(0));
        let c2 = count.clone();
        let server = Arc::new(RpcServer::new(move |_: &str, p: &[u8]| {
            c2.fetch_add(1, Ordering::SeqCst);
            Ok(p.to_vec())
        }));
        (server, count)
    }

    #[test]
    fn call_cleans_up_after_itself() {
        let (server, _) = counting_server();
        let client = RpcClient::new(InProcTransport::new(server.clone()));
        client.call("m", vec![1]).unwrap();
        assert_eq!(server.stats().cached_now, 0, "cache must be cleaned");
        assert_eq!(server.stats().cleaned, 1);
    }

    #[test]
    fn exactly_once_under_heavy_response_loss() {
        // Responses are lost 40% of the time: the client retries the SAME
        // id, the server serves from cache, the handler runs exactly once
        // per logical call.
        let (server, count) = counting_server();
        let flaky = FlakyTransport::new(InProcTransport::new(server.clone()), 99)
            .with_probs(0.2, 0.4, 0.2);
        let client = RpcClient::new(flaky).with_retry(RetryPolicy {
            max_attempts: 64,
            backoff: Duration::from_micros(10),
        });
        let calls = 50;
        for i in 0..calls {
            let out = client.call("work", vec![i as u8]).unwrap();
            assert_eq!(out, vec![i as u8]);
        }
        assert_eq!(
            count.load(Ordering::SeqCst),
            calls,
            "handler must run exactly once per logical call"
        );
        assert!(client.stats().retries > 0, "test should actually inject loss");
    }

    #[test]
    fn server_error_fails_fast_without_retry() {
        let server = Arc::new(RpcServer::new(|_: &str, _: &[u8]| -> anyhow::Result<Vec<u8>> {
            anyhow::bail!("worker exploded")
        }));
        let client = RpcClient::new(InProcTransport::new(server.clone()));
        let err = client.call("m", vec![]).unwrap_err().to_string();
        assert!(err.contains("worker exploded"), "{err}");
        assert_eq!(server.stats().executed, 1, "no retry on server error");
    }

    #[test]
    fn undeliverable_reports_attempts() {
        let (server, _) = counting_server();
        let flaky = FlakyTransport::new(InProcTransport::new(server), 7)
            .with_probs(1.0, 0.0, 0.0);
        let client = RpcClient::new(flaky).with_retry(RetryPolicy {
            max_attempts: 3,
            backoff: Duration::from_micros(1),
        });
        let err = client.call("m", vec![]).unwrap_err().to_string();
        assert!(err.contains("3 attempts"), "{err}");
    }

    #[test]
    fn ids_unique_across_clients() {
        let (server, count) = counting_server();
        let c1 = RpcClient::new(InProcTransport::new(server.clone()));
        let c2 = RpcClient::new(InProcTransport::new(server.clone()));
        c1.call("m", vec![]).unwrap();
        c2.call("m", vec![]).unwrap();
        assert_eq!(count.load(Ordering::SeqCst), 2);
    }
}
