//! Exactly-once RPC — implemented verbatim from the paper (§4.2):
//!
//! > "each RPC request is assigned a unique ID, and the result is cached on
//! >  the server side until the client successfully retrieves it.  The
//! >  client then sends a request to clean up the cached RPC result."
//!
//! > "If the RPC returns an unexpected or undesired result, the controller
//! >  simply terminates all processes."  — surfaced here as hard errors the
//! >  coordinator escalates (fail-fast; deep-learning jobs are all-or-
//! >  nothing).
//!
//! Two transports: in-process (controller ↔ worker threads) and TCP
//! (length-prefixed frames; multi-process launches).  `FlakyTransport`
//! injects drops/duplicates for the E8 exactly-once tests.

pub mod client;
pub mod server;
pub mod transport;
pub mod wire;

pub use client::RpcClient;
pub use server::{RpcServer, Service};
pub use transport::{FlakyTransport, InProcTransport, TcpRpcHost, TcpTransport, Transport};
pub use wire::{GatherFrame, GatherReply, PollFrame, Request, Response, Status};
