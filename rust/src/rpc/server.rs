//! RPC server: executes service methods with exactly-once semantics.
//!
//! Duplicate deliveries of a request id return the cached result without
//! re-executing (the paper's server-side result cache, §4.2); the cache
//! entry lives until the client's cleanup message.  Re-delivery *after*
//! cleanup is a protocol violation (the client only cleans up once it has
//! the result) and is answered with a hard error — the coordinator's
//! fail-fast rule then tears the job down.
//!
//! Tombstones are BOUNDED, by count and (optionally) by age: a long job
//! cleans up millions of ids, so the violation-detection set evicts its
//! oldest entries past [`DEFAULT_TOMBSTONE_CAPACITY`] (configurable via
//! [`RpcServer::with_tombstone_capacity`] / the `rpc_tombstone_capacity`
//! config knob) and expires entries older than the TTL set by
//! [`RpcServer::with_tombstone_ttl`] (the `rpc_tombstone_ttl_ms` knob;
//! 0 = count-based only).  Eviction/expiry trades early violation
//! detection for bounded memory: a request re-delivered after its
//! tombstone aged out re-executes as a fresh call instead of erroring.
//! Services must therefore stay duplicate-tolerant beyond the tombstone
//! horizon — the in-tree ones are (the rendezvous host is idempotent per
//! (seq, rank); the ring inbox drops chunks for rounds it already
//! retired).

use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::rpc::wire::{Request, Response, Status, METHOD_CLEANUP};
use crate::util::codec::Reader;

/// Default bound on the cleanup-tombstone set (ids, not bytes).
pub const DEFAULT_TOMBSTONE_CAPACITY: usize = 1 << 16;

/// FIFO-bounded tombstone set: O(1) insert/lookup, oldest ids evicted once
/// `cap` is exceeded, and — when a TTL is set — expired once older than it
/// (entries are in insertion order, so expiry only ever pops the front).
struct TombstoneSet {
    cap: usize,
    ttl: Option<Duration>,
    order: VecDeque<(u64, Instant)>,
    ids: HashSet<u64>,
    evicted: u64,
    expired: u64,
}

impl TombstoneSet {
    fn new(cap: usize) -> TombstoneSet {
        assert!(cap >= 1, "tombstone capacity must be >= 1");
        TombstoneSet {
            cap,
            ttl: None,
            order: VecDeque::new(),
            ids: HashSet::new(),
            evicted: 0,
            expired: 0,
        }
    }

    /// Drop every entry older than the TTL (front of the queue first).
    fn purge_expired(&mut self) {
        let Some(ttl) = self.ttl else { return };
        let now = Instant::now();
        while let Some(&(id, at)) = self.order.front() {
            if now.duration_since(at) <= ttl {
                break;
            }
            self.order.pop_front();
            self.ids.remove(&id);
            self.expired += 1;
        }
    }

    fn insert(&mut self, id: u64) {
        self.purge_expired();
        if !self.ids.insert(id) {
            return; // already tombstoned (duplicate cleanup)
        }
        self.order.push_back((id, Instant::now()));
        while self.order.len() > self.cap {
            if let Some((old, _)) = self.order.pop_front() {
                self.ids.remove(&old);
                self.evicted += 1;
            }
        }
    }

    fn contains(&mut self, id: u64) -> bool {
        self.purge_expired();
        self.ids.contains(&id)
    }
}

/// A dispatchable service: the worker-side handler the controller calls.
pub trait Service: Send + Sync {
    fn handle(&self, method: &str, payload: &[u8]) -> Result<Vec<u8>>;
}

impl<F> Service for F
where
    F: Fn(&str, &[u8]) -> Result<Vec<u8>> + Send + Sync,
{
    fn handle(&self, method: &str, payload: &[u8]) -> Result<Vec<u8>> {
        self(method, payload)
    }
}

#[derive(Debug, Clone, Default)]
pub struct ServerStats {
    pub executed: u64,
    pub duplicates_served: u64,
    pub cleaned: u64,
    pub errors: u64,
    pub cached_now: usize,
    pub tombstones_now: usize,
    pub tombstones_evicted: u64,
    pub tombstones_expired: u64,
}

pub struct RpcServer<S: Service> {
    service: S,
    /// request id → cached result (until cleanup)
    cache: Mutex<HashMap<u64, Response>>,
    /// ids whose cache has been cleaned — bounded tombstones for violation
    /// detection (oldest evicted past capacity; see module docs)
    tombstones: Mutex<TombstoneSet>,
    stats: Mutex<ServerStats>,
}

impl<S: Service> RpcServer<S> {
    pub fn new(service: S) -> RpcServer<S> {
        Self::with_capacity(service, DEFAULT_TOMBSTONE_CAPACITY)
    }

    fn with_capacity(service: S, tombstone_capacity: usize) -> RpcServer<S> {
        RpcServer {
            service,
            cache: Mutex::new(HashMap::new()),
            tombstones: Mutex::new(TombstoneSet::new(tombstone_capacity)),
            stats: Mutex::new(ServerStats::default()),
        }
    }

    /// Bound the cleanup-tombstone set to `cap` ids (the
    /// `rpc_tombstone_capacity` config knob).
    pub fn with_tombstone_capacity(mut self, cap: usize) -> Self {
        assert!(cap >= 1, "tombstone capacity must be >= 1");
        let t = self.tombstones.get_mut().unwrap();
        t.cap = cap;
        while t.order.len() > t.cap {
            if let Some((old, _)) = t.order.pop_front() {
                t.ids.remove(&old);
                t.evicted += 1;
            }
        }
        self
    }

    /// Expire tombstones older than `ttl` (the `rpc_tombstone_ttl_ms`
    /// config knob; zero disables age-based expiry).  An expired entry's
    /// request id re-executes as a fresh call — safe for the in-tree
    /// duplicate-tolerant services, see module docs.
    pub fn with_tombstone_ttl(mut self, ttl: Duration) -> Self {
        self.tombstones.get_mut().unwrap().ttl =
            if ttl.is_zero() { None } else { Some(ttl) };
        self
    }

    pub fn service(&self) -> &S {
        &self.service
    }

    pub fn stats(&self) -> ServerStats {
        let mut s = self.stats.lock().unwrap().clone();
        s.cached_now = self.cache.lock().unwrap().len();
        let mut t = self.tombstones.lock().unwrap();
        t.purge_expired();
        s.tombstones_now = t.ids.len();
        s.tombstones_evicted = t.evicted;
        s.tombstones_expired = t.expired;
        s
    }

    /// Handle one delivered request (possibly a duplicate).
    pub fn dispatch(&self, req: &Request) -> Response {
        if req.method == METHOD_CLEANUP {
            return self.handle_cleanup(req);
        }
        // duplicate delivery? serve from cache, do NOT re-execute
        if let Some(cached) = self.cache.lock().unwrap().get(&req.id) {
            self.stats.lock().unwrap().duplicates_served += 1;
            return cached.clone();
        }
        if self.tombstones.lock().unwrap().contains(req.id) {
            // re-delivery after cleanup: protocol violation → fail fast
            self.stats.lock().unwrap().errors += 1;
            return Response {
                id: req.id,
                status: Status::Err,
                payload: b"request id re-delivered after cleanup".to_vec(),
            };
        }
        let resp = match self.service.handle(&req.method, &req.payload) {
            Ok(payload) => Response { id: req.id, status: Status::Ok, payload },
            Err(e) => {
                self.stats.lock().unwrap().errors += 1;
                Response {
                    id: req.id,
                    status: Status::Err,
                    payload: format!("{e:#}").into_bytes(),
                }
            }
        };
        self.stats.lock().unwrap().executed += 1;
        self.cache.lock().unwrap().insert(req.id, resp.clone());
        resp
    }

    fn handle_cleanup(&self, req: &Request) -> Response {
        let target = match Reader::new(&req.payload).u64() {
            Ok(t) => t,
            Err(_) => {
                return Response {
                    id: req.id,
                    status: Status::Err,
                    payload: b"bad cleanup payload".to_vec(),
                }
            }
        };
        if self.cache.lock().unwrap().remove(&target).is_some() {
            self.tombstones.lock().unwrap().insert(target);
            self.stats.lock().unwrap().cleaned += 1;
        }
        // cleanup is idempotent — duplicate cleanups succeed silently
        Response { id: req.id, status: Status::Cleaned, payload: vec![] }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    fn echo_server() -> RpcServer<impl Service> {
        RpcServer::new(|method: &str, payload: &[u8]| {
            if method == "fail" {
                anyhow::bail!("boom");
            }
            Ok(payload.to_vec())
        })
    }

    #[test]
    fn executes_and_caches() {
        let s = echo_server();
        let req = Request { id: 1, method: "echo".into(), payload: vec![9] };
        let r1 = s.dispatch(&req);
        assert_eq!(r1.status, Status::Ok);
        assert_eq!(r1.payload, vec![9]);
        assert_eq!(s.stats().cached_now, 1);
    }

    #[test]
    fn duplicate_not_reexecuted() {
        let count = AtomicU64::new(0);
        let s = RpcServer::new(move |_: &str, _: &[u8]| {
            count.fetch_add(1, Ordering::SeqCst);
            Ok(count.load(Ordering::SeqCst).to_le_bytes().to_vec())
        });
        let req = Request { id: 5, method: "inc".into(), payload: vec![] };
        let r1 = s.dispatch(&req);
        let r2 = s.dispatch(&req);
        assert_eq!(r1, r2, "duplicate must return the cached result");
        assert_eq!(s.stats().executed, 1);
        assert_eq!(s.stats().duplicates_served, 1);
    }

    #[test]
    fn cleanup_releases_cache_and_is_idempotent() {
        let s = echo_server();
        s.dispatch(&Request { id: 1, method: "echo".into(), payload: vec![1] });
        assert_eq!(s.stats().cached_now, 1);
        let c = s.dispatch(&Request::cleanup(1, 2));
        assert_eq!(c.status, Status::Cleaned);
        assert_eq!(s.stats().cached_now, 0);
        // idempotent
        let c2 = s.dispatch(&Request::cleanup(1, 3));
        assert_eq!(c2.status, Status::Cleaned);
    }

    #[test]
    fn redelivery_after_cleanup_is_violation() {
        let s = echo_server();
        let req = Request { id: 1, method: "echo".into(), payload: vec![1] };
        s.dispatch(&req);
        s.dispatch(&Request::cleanup(1, 2));
        let r = s.dispatch(&req);
        assert_eq!(r.status, Status::Err);
    }

    #[test]
    fn tombstones_are_bounded_and_eviction_is_safe() {
        let count = AtomicU64::new(0);
        let s = RpcServer::new(move |_: &str, _: &[u8]| {
            count.fetch_add(1, Ordering::SeqCst);
            Ok(count.load(Ordering::SeqCst).to_le_bytes().to_vec())
        })
        .with_tombstone_capacity(4);

        // execute + clean up ids 1..=6: capacity 4 evicts the oldest two
        for id in 1..=6u64 {
            s.dispatch(&Request { id, method: "inc".into(), payload: vec![] });
            s.dispatch(&Request::cleanup(id, 100 + id));
        }
        let st = s.stats();
        assert_eq!(st.tombstones_now, 4, "set must stay at capacity");
        assert_eq!(st.tombstones_evicted, 2);

        // LIVE tombstone (id 6) still detects the protocol violation
        let r = s.dispatch(&Request { id: 6, method: "inc".into(), payload: vec![] });
        assert_eq!(r.status, Status::Err, "live tombstone must still dedupe");

        // EVICTED tombstone (id 1): re-delivery re-executes as a fresh call
        // — safe, just no longer flagged
        let r = s.dispatch(&Request { id: 1, method: "inc".into(), payload: vec![] });
        assert_eq!(r.status, Status::Ok, "evicted entry must re-execute safely");
        assert_eq!(s.stats().executed, 7, "6 originals + 1 re-execution");
    }

    #[test]
    fn tombstones_expire_past_the_age_horizon() {
        let count = AtomicU64::new(0);
        let s = RpcServer::new(move |_: &str, _: &[u8]| {
            count.fetch_add(1, Ordering::SeqCst);
            Ok(count.load(Ordering::SeqCst).to_le_bytes().to_vec())
        })
        .with_tombstone_capacity(64)
        .with_tombstone_ttl(std::time::Duration::from_millis(40));

        s.dispatch(&Request { id: 1, method: "inc".into(), payload: vec![] });
        s.dispatch(&Request::cleanup(1, 100));
        // inside the horizon: re-delivery is still a protocol violation
        let r = s.dispatch(&Request { id: 1, method: "inc".into(), payload: vec![] });
        assert_eq!(r.status, Status::Err, "live tombstone must flag re-delivery");

        std::thread::sleep(std::time::Duration::from_millis(80));
        // past the horizon: the tombstone aged out, re-execution is safe
        let r = s.dispatch(&Request { id: 1, method: "inc".into(), payload: vec![] });
        assert_eq!(r.status, Status::Ok, "expired tombstone must re-execute");
        let st = s.stats();
        assert!(st.tombstones_expired >= 1, "{st:?}");
        assert_eq!(st.executed, 2, "original + eviction-safe re-execution");
    }

    #[test]
    fn zero_ttl_disables_age_expiry() {
        let s = echo_server().with_tombstone_ttl(std::time::Duration::ZERO);
        s.dispatch(&Request { id: 1, method: "echo".into(), payload: vec![1] });
        s.dispatch(&Request::cleanup(1, 2));
        std::thread::sleep(std::time::Duration::from_millis(20));
        let r = s.dispatch(&Request { id: 1, method: "echo".into(), payload: vec![1] });
        assert_eq!(r.status, Status::Err, "TTL 0 must keep count-based behaviour");
        assert_eq!(s.stats().tombstones_expired, 0);
    }

    #[test]
    fn duplicate_cleanup_does_not_double_count_tombstones() {
        let s = echo_server().with_tombstone_capacity(8);
        s.dispatch(&Request { id: 1, method: "echo".into(), payload: vec![1] });
        s.dispatch(&Request::cleanup(1, 2));
        s.dispatch(&Request::cleanup(1, 3));
        let st = s.stats();
        assert_eq!(st.tombstones_now, 1);
        assert_eq!(st.tombstones_evicted, 0);
    }

    #[test]
    fn service_errors_are_cached_too() {
        let s = echo_server();
        let req = Request { id: 9, method: "fail".into(), payload: vec![] };
        let r1 = s.dispatch(&req);
        assert_eq!(r1.status, Status::Err);
        let r2 = s.dispatch(&req);
        assert_eq!(r1, r2);
        assert_eq!(s.stats().executed, 1);
    }
}
