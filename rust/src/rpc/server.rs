//! RPC server: executes service methods with exactly-once semantics.
//!
//! Duplicate deliveries of a request id return the cached result without
//! re-executing (the paper's server-side result cache, §4.2); the cache
//! entry lives until the client's cleanup message.  Re-delivery *after*
//! cleanup is a protocol violation (the client only cleans up once it has
//! the result) and is answered with a hard error — the coordinator's
//! fail-fast rule then tears the job down.

use std::collections::{HashMap, HashSet};
use std::sync::Mutex;

use anyhow::Result;

use crate::rpc::wire::{Request, Response, Status, METHOD_CLEANUP};
use crate::util::codec::Reader;

/// A dispatchable service: the worker-side handler the controller calls.
pub trait Service: Send + Sync {
    fn handle(&self, method: &str, payload: &[u8]) -> Result<Vec<u8>>;
}

impl<F> Service for F
where
    F: Fn(&str, &[u8]) -> Result<Vec<u8>> + Send + Sync,
{
    fn handle(&self, method: &str, payload: &[u8]) -> Result<Vec<u8>> {
        self(method, payload)
    }
}

#[derive(Debug, Clone, Default)]
pub struct ServerStats {
    pub executed: u64,
    pub duplicates_served: u64,
    pub cleaned: u64,
    pub errors: u64,
    pub cached_now: usize,
}

pub struct RpcServer<S: Service> {
    service: S,
    /// request id → cached result (until cleanup)
    cache: Mutex<HashMap<u64, Response>>,
    /// ids whose cache has been cleaned — tombstones for violation detection
    tombstones: Mutex<HashSet<u64>>,
    stats: Mutex<ServerStats>,
}

impl<S: Service> RpcServer<S> {
    pub fn new(service: S) -> RpcServer<S> {
        RpcServer {
            service,
            cache: Mutex::new(HashMap::new()),
            tombstones: Mutex::new(HashSet::new()),
            stats: Mutex::new(ServerStats::default()),
        }
    }

    pub fn service(&self) -> &S {
        &self.service
    }

    pub fn stats(&self) -> ServerStats {
        let mut s = self.stats.lock().unwrap().clone();
        s.cached_now = self.cache.lock().unwrap().len();
        s
    }

    /// Handle one delivered request (possibly a duplicate).
    pub fn dispatch(&self, req: &Request) -> Response {
        if req.method == METHOD_CLEANUP {
            return self.handle_cleanup(req);
        }
        // duplicate delivery? serve from cache, do NOT re-execute
        if let Some(cached) = self.cache.lock().unwrap().get(&req.id) {
            self.stats.lock().unwrap().duplicates_served += 1;
            return cached.clone();
        }
        if self.tombstones.lock().unwrap().contains(&req.id) {
            // re-delivery after cleanup: protocol violation → fail fast
            self.stats.lock().unwrap().errors += 1;
            return Response {
                id: req.id,
                status: Status::Err,
                payload: b"request id re-delivered after cleanup".to_vec(),
            };
        }
        let resp = match self.service.handle(&req.method, &req.payload) {
            Ok(payload) => Response { id: req.id, status: Status::Ok, payload },
            Err(e) => {
                self.stats.lock().unwrap().errors += 1;
                Response {
                    id: req.id,
                    status: Status::Err,
                    payload: format!("{e:#}").into_bytes(),
                }
            }
        };
        self.stats.lock().unwrap().executed += 1;
        self.cache.lock().unwrap().insert(req.id, resp.clone());
        resp
    }

    fn handle_cleanup(&self, req: &Request) -> Response {
        let target = match Reader::new(&req.payload).u64() {
            Ok(t) => t,
            Err(_) => {
                return Response {
                    id: req.id,
                    status: Status::Err,
                    payload: b"bad cleanup payload".to_vec(),
                }
            }
        };
        if self.cache.lock().unwrap().remove(&target).is_some() {
            self.tombstones.lock().unwrap().insert(target);
            self.stats.lock().unwrap().cleaned += 1;
        }
        // cleanup is idempotent — duplicate cleanups succeed silently
        Response { id: req.id, status: Status::Cleaned, payload: vec![] }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    fn echo_server() -> RpcServer<impl Service> {
        RpcServer::new(|method: &str, payload: &[u8]| {
            if method == "fail" {
                anyhow::bail!("boom");
            }
            Ok(payload.to_vec())
        })
    }

    #[test]
    fn executes_and_caches() {
        let s = echo_server();
        let req = Request { id: 1, method: "echo".into(), payload: vec![9] };
        let r1 = s.dispatch(&req);
        assert_eq!(r1.status, Status::Ok);
        assert_eq!(r1.payload, vec![9]);
        assert_eq!(s.stats().cached_now, 1);
    }

    #[test]
    fn duplicate_not_reexecuted() {
        let count = AtomicU64::new(0);
        let s = RpcServer::new(move |_: &str, _: &[u8]| {
            count.fetch_add(1, Ordering::SeqCst);
            Ok(count.load(Ordering::SeqCst).to_le_bytes().to_vec())
        });
        let req = Request { id: 5, method: "inc".into(), payload: vec![] };
        let r1 = s.dispatch(&req);
        let r2 = s.dispatch(&req);
        assert_eq!(r1, r2, "duplicate must return the cached result");
        assert_eq!(s.stats().executed, 1);
        assert_eq!(s.stats().duplicates_served, 1);
    }

    #[test]
    fn cleanup_releases_cache_and_is_idempotent() {
        let s = echo_server();
        s.dispatch(&Request { id: 1, method: "echo".into(), payload: vec![1] });
        assert_eq!(s.stats().cached_now, 1);
        let c = s.dispatch(&Request::cleanup(1, 2));
        assert_eq!(c.status, Status::Cleaned);
        assert_eq!(s.stats().cached_now, 0);
        // idempotent
        let c2 = s.dispatch(&Request::cleanup(1, 3));
        assert_eq!(c2.status, Status::Cleaned);
    }

    #[test]
    fn redelivery_after_cleanup_is_violation() {
        let s = echo_server();
        let req = Request { id: 1, method: "echo".into(), payload: vec![1] };
        s.dispatch(&req);
        s.dispatch(&Request::cleanup(1, 2));
        let r = s.dispatch(&req);
        assert_eq!(r.status, Status::Err);
    }

    #[test]
    fn service_errors_are_cached_too() {
        let s = echo_server();
        let req = Request { id: 9, method: "fail".into(), payload: vec![] };
        let r1 = s.dispatch(&req);
        assert_eq!(r1.status, Status::Err);
        let r2 = s.dispatch(&req);
        assert_eq!(r1, r2);
        assert_eq!(s.stats().executed, 1);
    }
}
