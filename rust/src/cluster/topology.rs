//! Cluster topology: nodes × GPUs, NVLink intra-node / RDMA inter-node.
//!
//! Mirrors the paper's testbed (§5): 8 machines × 8 H20-96GB, NVLink
//! intra-node, 200 Gbps RDMA inter-node.  Used for collective-time and
//! weight-broadcast estimates, and for the paper's "form communication
//! groups according to the GPU switch topology" placement rule (§4.2).

use crate::cluster::device::DeviceId;

#[derive(Debug, Clone)]
pub struct Topology {
    pub nodes: usize,
    pub gpus_per_node: usize,
    /// intra-node (NVLink) bandwidth per GPU, GB/s
    pub nvlink_gbps: f64,
    /// inter-node (RDMA) bandwidth per node, GB/s (200 Gbps ≈ 25 GB/s)
    pub rdma_gbps: f64,
}

impl Topology {
    /// The paper's evaluation cluster: 8×8 H20, NVLink ~400 GB/s, 200 Gbps RDMA.
    pub fn paper_testbed() -> Topology {
        Topology { nodes: 8, gpus_per_node: 8, nvlink_gbps: 400.0, rdma_gbps: 25.0 }
    }

    pub fn new(nodes: usize, gpus_per_node: usize) -> Topology {
        Topology { nodes, gpus_per_node, ..Topology::paper_testbed() }
    }

    pub fn total_gpus(&self) -> usize {
        self.nodes * self.gpus_per_node
    }

    pub fn node_of(&self, d: DeviceId) -> usize {
        d.0 / self.gpus_per_node
    }

    pub fn same_node(&self, a: DeviceId, b: DeviceId) -> bool {
        self.node_of(a) == self.node_of(b)
    }

    /// Devices of one node — the topology-aligned communication group the
    /// paper prefers (§4.2).
    pub fn node_devices(&self, node: usize) -> Vec<DeviceId> {
        let base = node * self.gpus_per_node;
        (base..base + self.gpus_per_node).map(DeviceId).collect()
    }

    /// Ring all-reduce time for `bytes` over `n` ranks: 2(n-1)/n × bytes /
    /// bottleneck-bandwidth.  If the group spans nodes, RDMA is the
    /// bottleneck; otherwise NVLink.
    pub fn allreduce_time(&self, group: &[DeviceId], bytes: f64) -> f64 {
        let n = group.len().max(1) as f64;
        if n == 1.0 {
            return 0.0;
        }
        let spans_nodes = group
            .windows(2)
            .any(|w| !self.same_node(w[0], w[1]));
        let bw = if spans_nodes { self.rdma_gbps } else { self.nvlink_gbps } * 1e9;
        2.0 * (n - 1.0) / n * bytes / bw
    }

    /// All-gather time for `bytes` per rank over the group.
    pub fn allgather_time(&self, group: &[DeviceId], bytes_per_rank: f64) -> f64 {
        let n = group.len().max(1) as f64;
        if n == 1.0 {
            return 0.0;
        }
        let spans_nodes = group.windows(2).any(|w| !self.same_node(w[0], w[1]));
        let bw = if spans_nodes { self.rdma_gbps } else { self.nvlink_gbps } * 1e9;
        (n - 1.0) * bytes_per_rank / bw
    }

    /// Point-to-point transfer time (weight broadcast hop).
    pub fn p2p_time(&self, a: DeviceId, b: DeviceId, bytes: f64) -> f64 {
        let bw = if self.same_node(a, b) { self.nvlink_gbps } else { self.rdma_gbps };
        bytes / (bw * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_testbed_shape() {
        let t = Topology::paper_testbed();
        assert_eq!(t.total_gpus(), 64);
        assert_eq!(t.node_of(DeviceId(0)), 0);
        assert_eq!(t.node_of(DeviceId(63)), 7);
        assert!(t.same_node(DeviceId(8), DeviceId(15)));
        assert!(!t.same_node(DeviceId(7), DeviceId(8)));
    }

    #[test]
    fn node_groups_are_topology_aligned() {
        let t = Topology::paper_testbed();
        let g = t.node_devices(2);
        assert_eq!(g.len(), 8);
        assert!(g.windows(2).all(|w| t.same_node(w[0], w[1])));
    }

    #[test]
    fn intra_node_allreduce_faster_than_inter() {
        let t = Topology::paper_testbed();
        let intra = t.node_devices(0);
        let inter: Vec<DeviceId> = (0..8).map(|i| DeviceId(i * 8)).collect();
        let bytes = 1e9;
        assert!(t.allreduce_time(&intra, bytes) < t.allreduce_time(&inter, bytes));
    }

    #[test]
    fn single_rank_collectives_free() {
        let t = Topology::paper_testbed();
        assert_eq!(t.allreduce_time(&[DeviceId(0)], 1e9), 0.0);
        assert_eq!(t.allgather_time(&[DeviceId(0)], 1e9), 0.0);
    }

    #[test]
    fn allreduce_scales_with_bytes() {
        let t = Topology::paper_testbed();
        let g = t.node_devices(0);
        assert!(t.allreduce_time(&g, 2e9) > 1.9 * t.allreduce_time(&g, 1e9));
    }
}
