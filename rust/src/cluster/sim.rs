//! Discrete-event cluster timeline: device busy/idle/swap accounting.
//!
//! The placement engines (placement::*) schedule stage work onto device
//! groups through this simulator; it tracks, per device, busy time by work
//! kind — the raw signal behind every utilization/bubble number in
//! EXPERIMENTS.md (E2/E3/E7).

use std::collections::BTreeMap;

use crate::cluster::device::DeviceId;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum WorkKind {
    Generate,
    Reward,
    Prepare,
    Train,
    Swap,
    WeightSync,
    Comm,
}

impl WorkKind {
    pub fn name(&self) -> &'static str {
        match self {
            WorkKind::Generate => "generate",
            WorkKind::Reward => "reward",
            WorkKind::Prepare => "prepare",
            WorkKind::Train => "train",
            WorkKind::Swap => "swap",
            WorkKind::WeightSync => "weight_sync",
            WorkKind::Comm => "comm",
        }
    }
}

#[derive(Debug, Clone, Default)]
struct DeviceTimeline {
    busy_until: f64,
    busy_by_kind: BTreeMap<WorkKind, f64>,
}

/// The simulated cluster timeline.
#[derive(Debug, Clone)]
pub struct Sim {
    devices: Vec<DeviceTimeline>,
}

impl Sim {
    pub fn new(n_devices: usize) -> Sim {
        Sim { devices: vec![DeviceTimeline::default(); n_devices] }
    }

    pub fn n_devices(&self) -> usize {
        self.devices.len()
    }

    /// Earliest time every device in `group` is free.
    pub fn group_ready(&self, group: &[DeviceId]) -> f64 {
        group
            .iter()
            .map(|d| self.devices[d.0].busy_until)
            .fold(0.0, f64::max)
    }

    /// Schedule `duration` seconds of `kind` work on every device of the
    /// group, starting when the whole group is free (synchronous stage,
    /// the co-location pattern).  Returns (start, end).
    pub fn run_group(
        &mut self,
        group: &[DeviceId],
        kind: WorkKind,
        duration: f64,
    ) -> (f64, f64) {
        let start = self.group_ready(group);
        let end = start + duration;
        for d in group {
            let t = &mut self.devices[d.0];
            t.busy_until = end;
            *t.busy_by_kind.entry(kind).or_insert(0.0) += duration;
        }
        (start, end)
    }

    /// Schedule work on a single device starting as soon as it is free
    /// (asynchronous / co-exist pattern).  Returns (start, end).
    pub fn run_one(&mut self, d: DeviceId, kind: WorkKind, duration: f64) -> (f64, f64) {
        let t = &mut self.devices[d.0];
        let start = t.busy_until;
        let end = start + duration;
        t.busy_until = end;
        *t.busy_by_kind.entry(kind).or_insert(0.0) += duration;
        (start, end)
    }

    /// Schedule work on a device starting no earlier than `not_before`
    /// (models a data dependency on another role's output).
    pub fn run_one_after(
        &mut self,
        d: DeviceId,
        not_before: f64,
        kind: WorkKind,
        duration: f64,
    ) -> (f64, f64) {
        let t = &mut self.devices[d.0];
        let start = t.busy_until.max(not_before);
        let end = start + duration;
        t.busy_until = end;
        *t.busy_by_kind.entry(kind).or_insert(0.0) += duration;
        (start, end)
    }

    /// Force all devices idle-forward to `time` (barrier).
    pub fn barrier(&mut self, time: f64) {
        for d in &mut self.devices {
            d.busy_until = d.busy_until.max(time);
        }
    }

    pub fn makespan(&self) -> f64 {
        self.devices.iter().map(|d| d.busy_until).fold(0.0, f64::max)
    }

    pub fn device_busy(&self, d: DeviceId) -> f64 {
        self.devices[d.0].busy_by_kind.values().sum()
    }

    /// Busy seconds by kind, summed over all devices.
    pub fn busy_by_kind(&self) -> BTreeMap<WorkKind, f64> {
        let mut out = BTreeMap::new();
        for d in &self.devices {
            for (k, v) in &d.busy_by_kind {
                *out.entry(*k).or_insert(0.0) += v;
            }
        }
        out
    }

    /// Cluster utilization: busy device-seconds (excluding swap, which is
    /// overhead, not useful work) / (makespan × n_devices).
    pub fn utilization(&self) -> f64 {
        let makespan = self.makespan();
        if makespan == 0.0 {
            return 0.0;
        }
        let useful: f64 = self
            .busy_by_kind()
            .iter()
            .filter(|(k, _)| !matches!(k, WorkKind::Swap | WorkKind::WeightSync))
            .map(|(_, v)| v)
            .sum();
        useful / (makespan * self.devices.len() as f64)
    }

    /// Total idle (bubble) device-seconds up to the makespan.
    pub fn bubble_seconds(&self) -> f64 {
        let makespan = self.makespan();
        let busy: f64 = self.busy_by_kind().values().sum();
        makespan * self.devices.len() as f64 - busy
    }

    /// Swap-overhead device-seconds.
    pub fn swap_seconds(&self) -> f64 {
        self.busy_by_kind().get(&WorkKind::Swap).copied().unwrap_or(0.0)
            + self
                .busy_by_kind()
                .get(&WorkKind::WeightSync)
                .copied()
                .unwrap_or(0.0)
    }
}

/// Summary for a placement run (one row of the E2/E3/E7 tables).
#[derive(Debug, Clone)]
pub struct SimReport {
    pub makespan_s: f64,
    pub utilization: f64,
    pub bubble_s: f64,
    pub swap_s: f64,
    pub samples: usize,
}

impl SimReport {
    pub fn from_sim(sim: &Sim, samples: usize) -> SimReport {
        SimReport {
            makespan_s: sim.makespan(),
            utilization: sim.utilization(),
            bubble_s: sim.bubble_seconds(),
            swap_s: sim.swap_seconds(),
            samples,
        }
    }

    pub fn samples_per_hour(&self) -> f64 {
        if self.makespan_s == 0.0 {
            return 0.0;
        }
        self.samples as f64 * 3600.0 / self.makespan_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ids(v: std::ops::Range<usize>) -> Vec<DeviceId> {
        v.map(DeviceId).collect()
    }

    #[test]
    fn group_runs_synchronously() {
        let mut sim = Sim::new(4);
        sim.run_one(DeviceId(0), WorkKind::Generate, 10.0);
        // group waits for slowest member
        let (start, end) = sim.run_group(&ids(0..4), WorkKind::Train, 5.0);
        assert_eq!(start, 10.0);
        assert_eq!(end, 15.0);
        assert_eq!(sim.makespan(), 15.0);
    }

    #[test]
    fn utilization_excludes_swap() {
        let mut sim = Sim::new(2);
        sim.run_group(&ids(0..2), WorkKind::Generate, 10.0);
        sim.run_group(&ids(0..2), WorkKind::Swap, 10.0);
        // 20s makespan, 10s useful per device
        assert!((sim.utilization() - 0.5).abs() < 1e-9);
        assert_eq!(sim.swap_seconds(), 20.0);
    }

    #[test]
    fn bubbles_counted() {
        let mut sim = Sim::new(2);
        sim.run_one(DeviceId(0), WorkKind::Generate, 10.0);
        // device 1 idle for the whole 10s
        assert!((sim.bubble_seconds() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn run_one_after_respects_dependency() {
        let mut sim = Sim::new(2);
        let (_, gen_end) = sim.run_one(DeviceId(0), WorkKind::Generate, 7.0);
        let (start, _) = sim.run_one_after(DeviceId(1), gen_end, WorkKind::Reward, 3.0);
        assert_eq!(start, 7.0);
    }

    #[test]
    fn independent_devices_overlap() {
        let mut sim = Sim::new(2);
        sim.run_one(DeviceId(0), WorkKind::Generate, 10.0);
        sim.run_one(DeviceId(1), WorkKind::Reward, 10.0);
        assert_eq!(sim.makespan(), 10.0); // parallel, not 20
        assert!((sim.utilization() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn report_samples_per_hour() {
        let mut sim = Sim::new(1);
        sim.run_one(DeviceId(0), WorkKind::Generate, 3600.0);
        let r = SimReport::from_sim(&sim, 100);
        assert!((r.samples_per_hour() - 100.0).abs() < 1e-9);
    }
}
