//! Workload models: long-tail generation lengths, response-length growth
//! over RL training, dynamic-sampling acceptance decay, and stage time
//! models.  These drive the placement experiments (E2/E3/E7).
//!
//! The paper observes (§3.2): generation produces long-tail outputs that
//! amplify co-location bubbles; response length *grows* during RL training
//! (R1-style "thinking time"), so static placement ratios go stale; and the
//! DAPO acceptance rate *decays* as the policy improves, multiplying swap
//! rounds.

use crate::util::rng::Rng;

/// Long-tail generation-length distribution with training-time drift.
#[derive(Debug, Clone)]
pub struct GenLenModel {
    /// lognormal location at step 0 (ln tokens)
    pub mu0: f64,
    /// lognormal scale (tail heaviness)
    pub sigma: f64,
    /// per-step drift of mu — the R1-style length growth
    pub growth_per_step: f64,
    /// hard cap (max_new_tokens)
    pub max_len: usize,
}

impl GenLenModel {
    /// Defaults shaped like reasoning-RL traces: median ~350 tokens at
    /// step 0, heavy tail, doubling time of a few hundred steps.
    pub fn reasoning_default() -> GenLenModel {
        GenLenModel { mu0: 5.86, sigma: 0.7, growth_per_step: 0.002, max_len: 8192 }
    }

    pub fn mu_at(&self, step: usize) -> f64 {
        self.mu0 + self.growth_per_step * step as f64
    }

    /// Median length at a training step (closed form for tests/benches).
    pub fn median_at(&self, step: usize) -> f64 {
        self.mu_at(step).exp().min(self.max_len as f64)
    }

    pub fn sample(&self, rng: &mut Rng, step: usize) -> usize {
        let len = rng.lognormal(self.mu_at(step), self.sigma);
        (len.round() as usize).clamp(1, self.max_len)
    }

    /// A batch of per-sequence lengths.
    pub fn sample_batch(&self, rng: &mut Rng, step: usize, n: usize) -> Vec<usize> {
        (0..n).map(|_| self.sample(rng, step)).collect()
    }
}

/// DAPO dynamic-sampling acceptance model: the probability that a prompt
/// group survives the "not all-correct / not all-wrong" filter decays as
/// training sharpens the policy (paper §3.2 item 1).
#[derive(Debug, Clone)]
pub struct AcceptanceModel {
    pub p0: f64,
    /// exponential decay rate per step
    pub decay: f64,
    /// floor (some prompts always stay informative)
    pub floor: f64,
}

impl AcceptanceModel {
    pub fn default_decay() -> AcceptanceModel {
        AcceptanceModel { p0: 0.9, decay: 0.004, floor: 0.25 }
    }

    pub fn accept_prob(&self, step: usize) -> f64 {
        self.floor + (self.p0 - self.floor) * (-self.decay * step as f64).exp()
    }

    /// Expected number of generation rounds to fill a batch at `step`
    /// (geometric: each round keeps `p` of its groups).
    pub fn expected_rounds(&self, step: usize) -> f64 {
        1.0 / self.accept_prob(step)
    }

    /// Sample whether one prompt group is accepted.
    pub fn sample(&self, rng: &mut Rng, step: usize) -> bool {
        rng.bool(self.accept_prob(step))
    }
}

/// Time model for auto-regressive generation on one device group.
#[derive(Debug, Clone)]
pub struct GenTimeModel {
    /// seconds per generated token per sequence at batch=1
    pub s_per_token: f64,
    /// batching efficiency: tokens of concurrent sequences overlap; a batch
    /// of B sequences runs at B^(1-batch_eff) × single-stream speed
    /// (batch_eff = 1 → perfect batching)
    pub batch_eff: f64,
}

impl GenTimeModel {
    pub fn vllm_like() -> GenTimeModel {
        GenTimeModel { s_per_token: 0.05, batch_eff: 0.9 }
    }

    /// Continuous-batching completion time of a batch: each sequence i
    /// finishes after (len_i / throughput_share) — approximated as the
    /// longest sequence bounding the batch, with shorter ones freeing
    /// capacity (the long-tail bubble source).
    ///
    /// Returns (makespan_s, useful_s): makespan = wallclock to drain the
    /// batch, useful = device-seconds of actual work.  The difference is
    /// the long-tail bubble.
    pub fn batch_times(&self, lens: &[usize]) -> (f64, f64) {
        if lens.is_empty() {
            return (0.0, 0.0);
        }
        let b = lens.len() as f64;
        let per_tok = self.s_per_token / b.powf(self.batch_eff);
        let max_len = *lens.iter().max().unwrap() as f64;
        let sum_len: f64 = lens.iter().map(|&l| l as f64).sum();
        let makespan = max_len * per_tok * b; // drained at batch rate until the longest finishes
        let useful = sum_len * per_tok * b;
        (makespan, useful.min(makespan * b))
    }

    /// Bubble fraction of a batch: idle device-time / total device-time.
    pub fn bubble_fraction(&self, lens: &[usize]) -> f64 {
        if lens.is_empty() {
            return 0.0;
        }
        let max_len = *lens.iter().max().unwrap() as f64;
        let sum_len: f64 = lens.iter().map(|&l| l as f64).sum();
        1.0 - sum_len / (max_len * lens.len() as f64)
    }
}

/// Time model for training forward+backward over packed sequences.
/// Attention is quadratic in sequence length; MLP linear (paper §4.4).
#[derive(Debug, Clone)]
pub struct TrainTimeModel {
    /// seconds per token (linear part: MLP + projections)
    pub s_per_token: f64,
    /// seconds per token² (attention part)
    pub s_per_token2: f64,
}

impl TrainTimeModel {
    pub fn default_7b() -> TrainTimeModel {
        TrainTimeModel { s_per_token: 2e-5, s_per_token2: 4e-9 }
    }

    /// Cost of one sequence of length `s`: linear + quadratic terms.
    pub fn seq_cost(&self, s: usize) -> f64 {
        self.s_per_token * s as f64 + self.s_per_token2 * (s as f64) * (s as f64)
    }

    /// Cost of one microbatch on one rank = sum of its sequence costs.
    pub fn micro_cost(&self, lens: &[usize]) -> f64 {
        lens.iter().map(|&l| self.seq_cost(l)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn genlen_grows_with_steps() {
        let m = GenLenModel::reasoning_default();
        assert!(m.median_at(500) > 1.5 * m.median_at(0));
        let mut rng = Rng::new(1);
        let early: usize = m.sample_batch(&mut rng, 0, 512).iter().sum();
        let late: usize = m.sample_batch(&mut rng, 500, 512).iter().sum();
        assert!(late > early);
    }

    #[test]
    fn genlen_respects_cap() {
        let m = GenLenModel { max_len: 100, ..GenLenModel::reasoning_default() };
        let mut rng = Rng::new(2);
        assert!(m.sample_batch(&mut rng, 1000, 1000).iter().all(|&l| l <= 100 && l >= 1));
    }

    #[test]
    fn genlen_has_long_tail() {
        let m = GenLenModel::reasoning_default();
        let mut rng = Rng::new(3);
        let mut lens = m.sample_batch(&mut rng, 0, 4000);
        lens.sort_unstable();
        let p50 = lens[2000] as f64;
        let p99 = lens[3960] as f64;
        assert!(p99 > 3.0 * p50, "p50={p50} p99={p99}");
    }

    #[test]
    fn acceptance_decays_to_floor() {
        let a = AcceptanceModel::default_decay();
        assert!(a.accept_prob(0) > 0.85);
        assert!(a.accept_prob(2000) < 0.3);
        assert!(a.accept_prob(100_000) >= a.floor - 1e-9);
        assert!(a.expected_rounds(2000) > a.expected_rounds(0));
    }

    #[test]
    fn bubble_fraction_zero_for_uniform() {
        let g = GenTimeModel::vllm_like();
        assert!(g.bubble_fraction(&[100, 100, 100]) < 1e-12);
        let frac = g.bubble_fraction(&[100, 100, 1000]);
        assert!(frac > 0.5, "{frac}");
    }

    #[test]
    fn batch_times_useful_le_makespan_times_b() {
        let g = GenTimeModel::vllm_like();
        let (mk, useful) = g.batch_times(&[50, 500, 200]);
        assert!(mk > 0.0 && useful > 0.0);
        assert!(useful <= mk * 3.0 + 1e-9);
    }

    #[test]
    fn train_cost_quadratic_dominates_long_seqs() {
        let t = TrainTimeModel::default_7b();
        // one 2s-long sequence costs more than two s-long ones (paper §4.4)
        let one = t.seq_cost(8192);
        let two = 2.0 * t.seq_cost(4096);
        assert!(one > 1.3 * two, "one={one} two={two}");
    }
}
