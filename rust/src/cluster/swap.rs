//! Swap cost model: the time-sharing overhead of co-location (paper §2.3).
//!
//! Calibrated to the paper's quoted figures: "swapping a 32B model could
//! take nearly a minute, and updating weights may take tens of seconds"
//! (§2.3) / "swapping a 32B model typically takes only 30-60 seconds"
//! (§3.2).  A 32B model in bf16 is ~64 GB of weights; with a host-link
//! bandwidth of ~3 GB/s plus a fixed engine re-initialisation / graph
//! re-capture cost of ~10 s, a 32B swap-in lands at ≈31 s — inside the
//! paper's band — and swap-out (no capture) at ≈21 s.

/// Model size presets (weights only, bf16).
pub fn model_weights_gb(params_b: f64) -> f64 {
    params_b * 2.0 // bf16: 2 bytes/param; params_b in billions → GB
}

#[derive(Debug, Clone)]
pub struct SwapCostModel {
    /// effective HBM↔host bandwidth during swap, GB/s
    pub host_bw_gbps: f64,
    /// fixed cost of inference-engine re-init + CUDA-graph re-capture, s
    pub capture_s: f64,
    /// fixed cost of releasing memory / tearing down, s
    pub teardown_s: f64,
}

impl Default for SwapCostModel {
    fn default() -> Self {
        // calibrated to the paper's 30-60 s band for a 32B model
        SwapCostModel { host_bw_gbps: 3.0, capture_s: 10.0, teardown_s: 2.0 }
    }
}

impl SwapCostModel {
    /// Time to bring a model of `gb` weights (per-device shard) into HBM
    /// and make it servable.
    pub fn swap_in(&self, gb: f64) -> f64 {
        self.capture_s + gb / self.host_bw_gbps
    }

    /// Time to evict a model (offload to host memory).
    pub fn swap_out(&self, gb: f64) -> f64 {
        self.teardown_s + gb / self.host_bw_gbps
    }

    /// Full exchange: evict `out_gb`, load `in_gb` (sequential — same link).
    pub fn exchange(&self, out_gb: f64, in_gb: f64) -> f64 {
        self.swap_out(out_gb) + self.swap_in(in_gb)
    }

    /// Weight update cost: copy fresh training weights into the inference
    /// engine ("updating weights may take tens of seconds", §2.3).  Same
    /// link, no capture (engine stays alive).
    pub fn weight_update(&self, gb: f64) -> f64 {
        gb / self.host_bw_gbps
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_calibration_32b() {
        let m = SwapCostModel::default();
        let gb = model_weights_gb(32.0); // 64 GB
        let t_in = m.swap_in(gb / 8.0 * 8.0); // whole model across 8 cards: per-link share
        // single-link view: 30-60 s band
        assert!((30.0..=60.0).contains(&t_in), "swap_in = {t_in}");
        let upd = m.weight_update(gb);
        assert!((10.0..=40.0).contains(&upd), "weight_update = {upd}");
    }

    #[test]
    fn exchange_is_sum() {
        let m = SwapCostModel::default();
        assert!(
            (m.exchange(10.0, 20.0) - (m.swap_out(10.0) + m.swap_in(20.0))).abs()
                < 1e-12
        );
    }

    #[test]
    fn monotone_in_size() {
        let m = SwapCostModel::default();
        assert!(m.swap_in(64.0) > m.swap_in(8.0));
        assert!(m.swap_out(64.0) > m.swap_out(8.0));
    }

    #[test]
    fn small_models_dominated_by_capture() {
        let m = SwapCostModel::default();
        // a 1B model swap is mostly fixed cost — why swaps only hurt when
        // they become *frequent* (dynamic sampling, §3.2)
        let t = m.swap_in(model_weights_gb(1.0));
        assert!(t < m.capture_s * 1.2);
    }
}
