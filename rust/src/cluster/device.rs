//! Simulated GPU devices: HBM memory ledger + model residency.
//!
//! The paper's placement claims (§2.3, §3.2) are about *memory and time
//! accounting* — which models fit where, what swapping costs, when OOM
//! hits.  `Device` tracks exactly that; the actual numerics run elsewhere
//! (runtime::Engine on PJRT-CPU).

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct DeviceId(pub usize);

/// The RLHF roles a device can host (paper §2.2's model zoo).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum ModelRole {
    /// Actor weights in the training framework layout.
    PolicyTrain,
    /// Actor weights in the inference-engine layout (vLLM/SGLang analogue).
    PolicyGen,
    /// Generative reward model (verifier LM) in inference layout.
    RewardGen,
    /// Bradley-Terry reward model.
    RewardModel,
    Reference,
    Critic,
}

impl ModelRole {
    pub fn name(&self) -> &'static str {
        match self {
            ModelRole::PolicyTrain => "policy_train",
            ModelRole::PolicyGen => "policy_gen",
            ModelRole::RewardGen => "reward_gen",
            ModelRole::RewardModel => "reward_model",
            ModelRole::Reference => "reference",
            ModelRole::Critic => "critic",
        }
    }
}

/// One simulated GPU: capacity + resident allocations (GB granularity).
#[derive(Debug, Clone)]
pub struct Device {
    pub id: DeviceId,
    pub hbm_gb: f64,
    resident: BTreeMap<ModelRole, f64>,
    /// transient allocations (activations, KV cache) by tag
    transient: BTreeMap<String, f64>,
}

impl Device {
    pub fn new(id: DeviceId, hbm_gb: f64) -> Device {
        Device { id, hbm_gb, resident: BTreeMap::new(), transient: BTreeMap::new() }
    }

    pub fn used_gb(&self) -> f64 {
        self.resident.values().sum::<f64>() + self.transient.values().sum::<f64>()
    }

    pub fn free_gb(&self) -> f64 {
        self.hbm_gb - self.used_gb()
    }

    pub fn hosts(&self, role: ModelRole) -> bool {
        self.resident.contains_key(&role)
    }

    pub fn resident_roles(&self) -> Vec<ModelRole> {
        self.resident.keys().copied().collect()
    }

    /// Load a model's shard onto this device; OOM if it does not fit.
    pub fn load(&mut self, role: ModelRole, gb: f64) -> Result<()> {
        if self.hosts(role) {
            bail!("device {:?} already hosts {}", self.id, role.name());
        }
        if gb > self.free_gb() + 1e-9 {
            bail!(
                "OOM on device {:?}: loading {} needs {:.1} GB, {:.1} GB free \
                 (resident: {:?})",
                self.id,
                role.name(),
                gb,
                self.free_gb(),
                self.resident
            );
        }
        self.resident.insert(role, gb);
        Ok(())
    }

    /// Unload (swap out) a model shard.
    pub fn unload(&mut self, role: ModelRole) -> Result<f64> {
        match self.resident.remove(&role) {
            Some(gb) => Ok(gb),
            None => bail!("device {:?} does not host {}", self.id, role.name()),
        }
    }

    /// Reserve transient memory (KV cache, activations, comm buffers).
    pub fn reserve(&mut self, tag: &str, gb: f64) -> Result<()> {
        if gb > self.free_gb() + 1e-9 {
            bail!(
                "OOM on device {:?}: transient '{}' needs {:.1} GB, {:.1} free",
                self.id,
                tag,
                gb,
                self.free_gb()
            );
        }
        *self.transient.entry(tag.to_string()).or_insert(0.0) += gb;
        Ok(())
    }

    pub fn release(&mut self, tag: &str) -> f64 {
        self.transient.remove(tag).unwrap_or(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_unload_ledger() {
        let mut d = Device::new(DeviceId(0), 96.0);
        d.load(ModelRole::PolicyGen, 64.0).unwrap();
        assert!(d.hosts(ModelRole::PolicyGen));
        assert!((d.free_gb() - 32.0).abs() < 1e-9);
        assert_eq!(d.unload(ModelRole::PolicyGen).unwrap(), 64.0);
        assert_eq!(d.free_gb(), 96.0);
    }

    #[test]
    fn oom_rejected_with_context() {
        let mut d = Device::new(DeviceId(1), 96.0);
        d.load(ModelRole::PolicyGen, 64.0).unwrap();
        let err = d.load(ModelRole::RewardGen, 64.0).unwrap_err().to_string();
        assert!(err.contains("OOM"), "{err}");
        // co-locating both 64GB models on one 96GB card is exactly the
        // paper's motivation for time-sharing (§2.3)
    }

    #[test]
    fn double_load_rejected() {
        let mut d = Device::new(DeviceId(2), 96.0);
        d.load(ModelRole::Critic, 10.0).unwrap();
        assert!(d.load(ModelRole::Critic, 10.0).is_err());
    }

    #[test]
    fn transient_reservations() {
        let mut d = Device::new(DeviceId(3), 96.0);
        d.load(ModelRole::PolicyGen, 64.0).unwrap();
        d.reserve("kv_cache", 20.0).unwrap();
        assert!(d.reserve("activations", 20.0).is_err()); // 84 + 20 > 96
        assert_eq!(d.release("kv_cache"), 20.0);
        d.reserve("activations", 20.0).unwrap();
    }

    #[test]
    fn unload_missing_errors() {
        let mut d = Device::new(DeviceId(4), 96.0);
        assert!(d.unload(ModelRole::Reference).is_err());
    }
}
