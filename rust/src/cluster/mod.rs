//! Simulated GPU-cluster substrate (paper §5 testbed analogue).
//!
//! The paper evaluates on 8×8 H20-96GB with NVLink/RDMA; this module
//! provides the memory/time/topology accounting those experiments need —
//! the numerics themselves run through `runtime::Engine` (PJRT-CPU).
//! See DESIGN.md §1 for the substitution argument.

pub mod device;
pub mod sim;
pub mod swap;
pub mod topology;
pub mod workload;

pub use device::{Device, DeviceId, ModelRole};
pub use sim::{Sim, SimReport, WorkKind};
pub use swap::{model_weights_gb, SwapCostModel};
pub use topology::Topology;
pub use workload::{AcceptanceModel, GenLenModel, GenTimeModel, TrainTimeModel};
