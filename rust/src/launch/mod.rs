//! Launcher: bootstraps a parallel-controller training job (paper §4.2's
//! "launch tasks via [the] job scheduling system" analogue — here, one
//! thread per controller sharing a PJRT engine and in-proc collectives;
//! the same controller code runs over the TCP RPC transport for
//! multi-process launches).

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::checkpoint::{CheckpointManager, CheckpointMeta, ShardState};
use crate::config::RunConfig;
use crate::coordinator::collective::Collective;
use crate::coordinator::controller::{Controller, StepStats};
use crate::coordinator::pretrain;
use crate::reward::{RewardKind, Rewarder};
use crate::runtime::engine::Engine;
use crate::runtime::params::init_policy;
use crate::storage::dataloader::LoaderState;

#[derive(Debug, Clone, Default)]
pub struct TrainReport {
    pub sft_losses: Vec<f32>,
    pub steps: Vec<StepStats>,
    pub eval_before: f64,
    pub eval_after: f64,
    pub reward_model_metric: f32,
    pub timers_markdown: String,
}

/// Build the configured rewarder, pre-training reward models as needed.
pub fn build_rewarder(engine: &Engine, cfg: &RunConfig) -> Result<(Rewarder, f32)> {
    match cfg.reward {
        RewardKind::GroundTruth => Ok((Rewarder::ground_truth(), 1.0)),
        RewardKind::BradleyTerry => {
            let (params, rep) = pretrain::train_bt(
                engine,
                cfg.task_kinds()?,
                cfg.bt_train_steps,
                3e-3,
                cfg.seed + 101,
            )?;
            Ok((Rewarder::bradley_terry(params), rep.final_metric))
        }
        RewardKind::Generative => {
            let (params, rep) = pretrain::train_verifier(
                engine,
                cfg.task_kinds()?,
                cfg.verifier_sft_steps,
                2e-3,
                cfg.seed + 202,
            )?;
            Ok((
                Rewarder::generative(params, cfg.verdict_mode),
                rep.final_metric,
            ))
        }
    }
}

fn clone_rewarder(r: &Rewarder) -> Rewarder {
    Rewarder {
        kind: r.kind,
        bt_params: r.bt_params.clone(),
        verifier_params: r.verifier_params.clone(),
        verdict_mode: r.verdict_mode,
    }
}

/// Run a full RLHF training job: SFT warm-start → (optional) reward-model
/// pre-training → `cfg.steps` RLHF steps across `cfg.world` controllers.
pub fn run_training(cfg: &RunConfig) -> Result<TrainReport> {
    let engine = Arc::new(Engine::load(&cfg.artifacts)?);
    let (rewarder, rm_metric) = build_rewarder(&engine, cfg)?;

    // identical initial policy on every controller (SPMD)
    let policy = init_policy(&engine, cfg.seed as u32)?;
    let collective = Collective::new(cfg.world);

    let ckpt = cfg
        .checkpoint_dir
        .as_ref()
        .map(|d| Arc::new(CheckpointManager::new(d)));

    let handles: Vec<_> = (0..cfg.world)
        .map(|rank| {
            let engine = engine.clone();
            let collective = collective.clone();
            let cfg = cfg.clone();
            let policy = policy.clone();
            let rewarder = clone_rewarder(&rewarder);
            let ckpt = ckpt.clone();
            std::thread::spawn(move || -> Result<TrainReport> {
                let mut c = Controller::new(
                    rank,
                    engine,
                    collective,
                    cfg.clone(),
                    policy,
                    rewarder,
                )?;
                let mut report = TrainReport::default();

                // SFT warm-start
                for _ in 0..cfg.sft_steps {
                    let loss = c.sft_step()?;
                    report.sft_losses.push(loss);
                }
                c.freeze_reference();
                if rank == 0 {
                    report.eval_before = c.evaluate(4)?;
                }

                // RLHF steps
                for step in 0..cfg.steps {
                    let stats = c.rlhf_step(step)?;
                    if rank == 0 {
                        report.steps.push(stats);
                        if let Some(ckpt) = &ckpt {
                            if cfg.checkpoint_every > 0
                                && (step + 1) % cfg.checkpoint_every == 0
                            {
                                let meta = CheckpointMeta {
                                    step: step as u64 + 1,
                                    world_size: cfg.world,
                                    loader: LoaderState {
                                        seed: cfg.seed,
                                        epoch: 0,
                                        cursor: (step + 1)
                                            * c.engine.manifest().dims.batch,
                                    },
                                };
                                let shard = ShardState {
                                    rank,
                                    params: vec![
                                        ("policy".into(), c.state.params.clone()),
                                        ("adam_m".into(), c.state.m.clone()),
                                        ("adam_v".into(), c.state.v.clone()),
                                    ],
                                    rng_seed: cfg.seed,
                                };
                                // async: training continues while it writes
                                let h = ckpt.save_async(step as u64 + 1, meta, shard);
                                drop(h); // completion checked at job end
                            }
                        }
                    }
                }

                if rank == 0 {
                    report.eval_after = c.evaluate(4)?;
                    report.timers_markdown = c.timers.report();
                }
                Ok(report)
            })
        })
        .collect();

    let mut rank0: Option<TrainReport> = None;
    for (rank, h) in handles.into_iter().enumerate() {
        let r = h
            .join()
            .map_err(|_| anyhow::anyhow!("controller {rank} panicked"))?
            .with_context(|| format!("controller {rank} failed"))?;
        if rank == 0 {
            rank0 = Some(r);
        }
    }
    let mut report = rank0.context("no rank-0 report")?;
    report.reward_model_metric = rm_metric;
    Ok(report)
}
