//! Launcher: bootstraps a parallel-controller training job (paper §4.2's
//! "launch tasks via [the] job scheduling system" analogue).
//!
//! Launch modes share one per-rank body ([`run_rank`]) and the same
//! `Controller` code — only the `CollectiveBackend` differs:
//!
//! * [`run_training`] — one thread per controller, in-proc condvar
//!   rendezvous (`CollectiveMode::InProc`), TCP-loopback rendezvous
//!   collectives (`CollectiveMode::Tcp`), or streaming ring collectives
//!   (`CollectiveMode::Ring`);
//! * [`run_training_tcp`] — threads again, but every gradient all-reduce /
//!   metric reduction / barrier travels as exactly-once RPC rounds against
//!   a rank-0 rendezvous service over real TCP.  Bit-identical to the
//!   in-proc launch (asserted in tests/system_integration.rs);
//! * [`run_training_ring`] — threads whose collectives stream chunked
//!   frames around a TCP ring of peer-hosted inbox services — O(payload)
//!   bytes per rank, no rank-0 bottleneck, and still bit-identical to the
//!   in-proc launch;
//! * [`run_worker`] + [`serve_coordinator`] — the multi-process path used
//!   by `gcore train-dist`: the parent hosts the rendezvous service and
//!   spawns one `gcore train-worker --rank R --coord HOST:PORT` OS process
//!   per controller.  Workers never share an address space; they meet only
//!   through the RPC collective (and each deterministically re-derives the
//!   initial policy / reward model from the shared seed instead of
//!   broadcasting multi-MB weights).  With `--collective ring` the
//!   rendezvous is only the bootstrap: each worker hosts its own ring peer
//!   on an ephemeral port, all-gathers the addresses through the
//!   coordinator once, then streams everything rank-to-rank.
//!
//! Worker failures carry typed collective statuses
//! ([`CollectiveStatus`]): [`worker_exit_code`] maps them to stable exit
//! codes, which `train-dist` decodes back into a reason instead of
//! grepping stderr.
//!
//! Fault tolerance (multi-process path): each worker runs a [`Heartbeat`]
//! thread against the rendezvous host for its whole life.  When a lease
//! lapses the host latches the dead rank, and every later collective
//! `offer`/`poll` from the survivors fails in milliseconds with a typed
//! `PeerDead` status — nobody waits out the 300 s round timeout.  The ring
//! backend never revisits the coordinator after bootstrap, so it carries a
//! throttled [`LivenessProbe`] instead, checked between streaming waits.
//! The `train-dist` supervisor can then `--recover restart` from the
//! latest COMPLETE checkpoint: every rank persists its own shard (policy,
//! Adam moments, frozen reference, and both RNG stream positions), so a
//! respawned worker resumes mid-run bit-identically, while a bumped
//! rendezvous epoch rejects frames from stale processes.
//! `GCORE_CHAOS=kill:rank=R,step=S` injects the crash the chaos tier
//! recovers from.

use std::net::SocketAddr;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::checkpoint::{CheckpointManager, CheckpointMeta, ShardState};
use crate::config::{CollectiveMode, RunConfig};
use crate::coordinator::collective::{
    decode_param_set, encode_param_set, Collective, CollectiveBackend,
};
use crate::coordinator::controller::{Controller, StepStats};
use crate::coordinator::pretrain;
use crate::coordinator::ring_collective::{RingCollective, RingInbox, RingPeer};
use crate::coordinator::rpc_collective::{
    CollectiveStatus, Heartbeat, LivenessProbe, RendezvousHost, RpcCollective,
};
use crate::reward::{RewardKind, Rewarder};
use crate::rpc::client::RpcClient;
use crate::rpc::server::RpcServer;
use crate::rpc::transport::{MeteredTransport, TcpRpcHost, TcpTransport, TransferStats};
use crate::runtime::engine::Engine;
use crate::runtime::params::{init_policy, ParamSet};
use crate::storage::dataloader::LoaderState;
use crate::util::codec::{Reader, Writer};
use crate::util::rng::Rng;

#[derive(Debug, Clone, Default)]
pub struct TrainReport {
    pub sft_losses: Vec<f32>,
    pub steps: Vec<StepStats>,
    pub eval_before: f64,
    pub eval_after: f64,
    pub reward_model_metric: f32,
    pub timers_markdown: String,
}

/// Build the configured rewarder, pre-training reward models as needed.
pub fn build_rewarder(engine: &Engine, cfg: &RunConfig) -> Result<(Rewarder, f32)> {
    match cfg.reward {
        RewardKind::GroundTruth => Ok((Rewarder::ground_truth(), 1.0)),
        RewardKind::BradleyTerry => {
            let (params, rep) = pretrain::train_bt(
                engine,
                cfg.task_kinds()?,
                cfg.bt_train_steps,
                3e-3,
                cfg.seed + 101,
            )?;
            Ok((Rewarder::bradley_terry(params), rep.final_metric))
        }
        RewardKind::Generative => {
            let (params, rep) = pretrain::train_verifier(
                engine,
                cfg.task_kinds()?,
                cfg.verifier_sft_steps,
                2e-3,
                cfg.seed + 202,
            )?;
            Ok((
                Rewarder::generative(params, cfg.verdict_mode),
                rep.final_metric,
            ))
        }
    }
}

fn clone_rewarder(r: &Rewarder) -> Rewarder {
    Rewarder {
        kind: r.kind,
        bt_params: r.bt_params.clone(),
        verifier_params: r.verifier_params.clone(),
        verdict_mode: r.verdict_mode,
    }
}

/// Wire form of a pre-trained rewarder: final metric + the reward-model
/// parameter set (the kind/verdict mode come from the shared config, so
/// only the weights travel).  Used by [`broadcast_rewarder`].
pub fn encode_rewarder(r: &Rewarder, metric: f32) -> Vec<u8> {
    let mut w = Writer::new();
    w.f32(metric);
    let params = r.bt_params.as_ref().or_else(|| r.verifier_params.as_ref());
    match params {
        Some(set) => {
            w.u8(1);
            w.bytes(&encode_param_set(set));
        }
        None => w.u8(0),
    }
    w.into_bytes()
}

/// Inverse of [`encode_rewarder`]: rebuild the rewarder for `cfg.reward`
/// from broadcast bytes.
pub fn decode_rewarder(cfg: &RunConfig, bytes: &[u8]) -> Result<(Rewarder, f32)> {
    let mut r = Reader::new(bytes);
    let metric = r.f32()?;
    let has_params = r.u8()? == 1;
    let params = if has_params { Some(decode_param_set(r.bytes()?)?) } else { None };
    r.expect_end()?;
    let rewarder = match cfg.reward {
        RewardKind::GroundTruth => Rewarder::ground_truth(),
        RewardKind::BradleyTerry => Rewarder::bradley_terry(
            params.context("broadcast Bradley-Terry rewarder carries no params")?,
        ),
        RewardKind::Generative => Rewarder::generative(
            params.context("broadcast generative rewarder carries no params")?,
            cfg.verdict_mode,
        ),
    };
    Ok((rewarder, metric))
}

/// Pre-train the reward model on rank 0 only and broadcast the weights to
/// every rank over the collective's bytes channel (ROADMAP: `train-dist`
/// workers used to re-derive reward models per process — deterministic but
/// wasteful).  Every rank, rank 0 included, constructs its rewarder from
/// the broadcast bytes, so the resulting state is bit-identical across
/// ranks by construction.  Ground-truth rewarding has no model, and a
/// world of one has no peers — both skip the broadcast.
///
/// Caveat: non-root ranks sit inside the broadcast exchange while rank 0
/// pre-trains, so the pre-train must finish within the backend's
/// collective `round_timeout` (300s default — generous for the in-tree
/// artifact sets; raise it via the backend builder for reward models that
/// train longer, or the waiting ranks fail fast with a typed timeout).
pub fn broadcast_rewarder(
    engine: &Engine,
    cfg: &RunConfig,
    collective: &Collective,
    rank: usize,
) -> Result<(Rewarder, f32)> {
    if collective.world_size() == 1 || cfg.reward == RewardKind::GroundTruth {
        return build_rewarder(engine, cfg);
    }
    let payload = if rank == 0 {
        let (rewarder, metric) = build_rewarder(engine, cfg)?;
        encode_rewarder(&rewarder, metric)
    } else {
        Vec::new()
    };
    let bytes = collective.broadcast_bytes(rank, 0, payload)?;
    if bytes.is_empty() {
        bail!("rewarder broadcast delivered an empty payload");
    }
    decode_rewarder(cfg, &bytes)
}

/// Exit code a chaos-killed worker dies with — distinct from the typed
/// collective codes (65..=70) so supervisors and tests can tell "injected
/// crash" from "collective failure".
pub const CHAOS_EXIT_CODE: i32 = 86;

/// A `TcpTransport` to `addr` carrying the config's connect/IO timeouts
/// (0 = unbounded): the one choke point through which every transport the
/// multi-process path opens — rendezvous, ring successor, heartbeat,
/// liveness probe — picks up its bounds.
pub fn tcp_transport(cfg: &RunConfig, addr: SocketAddr) -> TcpTransport {
    TcpTransport::connect(addr).with_timeouts(
        Duration::from_millis(cfg.tcp_connect_timeout_ms),
        Duration::from_millis(cfg.tcp_io_timeout_ms),
    )
}

/// Parse a `GCORE_CHAOS` spec: `kill:rank=R,step=S` crashes rank R with
/// [`CHAOS_EXIT_CODE`] right before RLHF step S runs (steps are 0-based,
/// so `step=0` dies before any optimiser update).
pub fn parse_chaos(spec: &str) -> Result<(usize, usize)> {
    let rest = spec
        .strip_prefix("kill:")
        .with_context(|| format!("unsupported GCORE_CHAOS {spec:?} (want kill:rank=R,step=S)"))?;
    let (mut rank, mut step) = (None, None);
    for part in rest.split(',') {
        let (key, val) = part
            .split_once('=')
            .with_context(|| format!("malformed GCORE_CHAOS field {part:?} (want key=value)"))?;
        let n: usize = val
            .parse()
            .with_context(|| format!("GCORE_CHAOS {key}={val:?} is not a number"))?;
        match key {
            "rank" => rank = Some(n),
            "step" => step = Some(n),
            other => bail!("unknown GCORE_CHAOS field {other:?} (want rank= or step=)"),
        }
    }
    Ok((
        rank.context("GCORE_CHAOS is missing rank=")?,
        step.context("GCORE_CHAOS is missing step=")?,
    ))
}

fn chaos_from_env() -> Result<Option<(usize, usize)>> {
    match std::env::var("GCORE_CHAOS") {
        Ok(spec) if !spec.is_empty() => Ok(Some(parse_chaos(&spec)?)),
        _ => Ok(None),
    }
}

/// Snapshot everything a rank needs to resume bit-identically: policy +
/// Adam moments, the frozen reference policy, the optimiser step count,
/// and both RNG stream positions (controller sampling + task generation).
fn snapshot_shard(rank: usize, cfg: &RunConfig, c: &Controller) -> ShardState {
    ShardState {
        rank,
        params: vec![
            ("policy".into(), c.state.params.clone()),
            ("adam_m".into(), c.state.m.clone()),
            ("adam_v".into(), c.state.v.clone()),
            ("ref".into(), c.ref_params.clone()),
        ],
        rng_seed: cfg.seed,
        opt_step: c.state.step,
        controller_rng: Some(c.rng.state()),
        taskgen_rng: Some(c.taskgen.rng_state()),
    }
}

/// Inverse of [`snapshot_shard`]: load a shard back into a fresh
/// controller.  Shards from before the RNG-carrying format bail —
/// resuming without the stream positions would silently fork the
/// trajectory instead of replaying it.
fn restore_controller(c: &mut Controller, shard: &ShardState) -> Result<()> {
    let set = |name: &str| {
        shard
            .param_set(name)
            .cloned()
            .with_context(|| format!("checkpoint shard carries no {name:?} param set"))
    };
    c.state.params = set("policy")?;
    c.state.m = set("adam_m")?;
    c.state.v = set("adam_v")?;
    c.ref_params = set("ref")?;
    c.state.step = shard.opt_step;
    c.rng = Rng::from_state(
        shard
            .controller_rng
            .context("checkpoint shard predates RNG snapshots (no controller stream)")?,
    );
    c.taskgen.restore_rng(
        shard
            .taskgen_rng
            .context("checkpoint shard predates RNG snapshots (no taskgen stream)")?,
    );
    Ok(())
}

/// The full per-rank training body: SFT warm-start (or checkpoint resume)
/// → RLHF steps → (rank 0) evaluation + checkpointing.  Identical across
/// launch modes — the collective is the only thing that knows where the
/// peers live.
pub fn run_rank(
    rank: usize,
    engine: Arc<Engine>,
    collective: Arc<Collective>,
    cfg: RunConfig,
    policy: ParamSet,
    rewarder: Rewarder,
    ckpt: Option<Arc<CheckpointManager>>,
) -> Result<TrainReport> {
    let mut c = Controller::new(rank, engine, collective, cfg.clone(), policy, rewarder)?;
    let mut report = TrainReport::default();
    let mut pending_ckpt: Option<crate::checkpoint::AsyncSaveHandle> = None;
    let chaos = chaos_from_env()?;

    let start_step = match cfg.resume_step {
        // Crash-restart resume: restore exactly what this rank's shard
        // captured at the checkpoint boundary and skip the warm-start
        // phases the first life already ran.  Evaluation draws nothing
        // from the controller RNG (greedy decode, fresh eval taskgen), so
        // skipping eval_before leaves the replayed trajectory untouched.
        Some(step) => {
            let mgr = ckpt
                .as_ref()
                .context("resume_step is set but no checkpoint_dir is configured")?;
            let shard = mgr
                .load_shard(step, rank)
                .with_context(|| format!("rank {rank}: loading resume shard at step {step}"))?;
            restore_controller(&mut c, &shard)
                .with_context(|| format!("rank {rank}: restoring checkpoint step {step}"))?;
            step as usize
        }
        None => {
            // SFT warm-start
            for _ in 0..cfg.sft_steps {
                let loss = c.sft_step()?;
                report.sft_losses.push(loss);
            }
            c.freeze_reference();
            if rank == 0 {
                report.eval_before = c.evaluate(4)?;
            }
            0
        }
    };

    // RLHF steps
    for step in start_step..cfg.steps {
        if let Some((kill_rank, kill_step)) = chaos {
            if rank == kill_rank && step == kill_step {
                eprintln!("[gcore] chaos: killing rank {rank} before rlhf step {step}");
                std::process::exit(CHAOS_EXIT_CODE);
            }
        }
        let stats = c.rlhf_step(step)?;
        if rank == 0 {
            report.steps.push(stats);
        }
        if let Some(mgr) = &ckpt {
            if cfg.checkpoint_every > 0 && (step + 1) % cfg.checkpoint_every == 0 {
                // EVERY rank saves its shard — recovery only trusts a step
                // once all `world` shards landed (`latest_complete_step`),
                // and each rank's RNG streams are rank-specific.  Rank 0's
                // save also writes the meta.
                let meta = CheckpointMeta {
                    step: step as u64 + 1,
                    world_size: cfg.world,
                    loader: LoaderState {
                        seed: cfg.seed,
                        epoch: 0,
                        cursor: (step + 1) * c.engine.manifest().dims.batch,
                    },
                };
                let shard = snapshot_shard(rank, &cfg, &c);
                // async: training continues while it writes; awaiting
                // the PREVIOUS save here caps us at one write in flight
                if let Some(h) = pending_ckpt.take() {
                    h.wait()?;
                }
                pending_ckpt = Some(mgr.save_async(step as u64 + 1, meta, shard));
            }
        }
    }

    // the last async save must land before the process can exit, or the
    // final checkpoint is silently truncated (train-worker exits right away)
    if let Some(h) = pending_ckpt.take() {
        h.wait()?;
    }
    if rank == 0 {
        report.eval_after = c.evaluate(4)?;
        report.timers_markdown = c.timers.report();
    }
    Ok(report)
}

/// Spawn one thread per rank, each coordinating through its `Collective`
/// (`collectives[rank]`), and return rank 0's report.
fn run_threads(cfg: &RunConfig, collectives: Vec<Arc<Collective>>) -> Result<TrainReport> {
    assert_eq!(collectives.len(), cfg.world);
    let engine = Arc::new(Engine::load(&cfg.artifacts)?);
    let (rewarder, rm_metric) = build_rewarder(&engine, cfg)?;

    // identical initial policy on every controller (SPMD)
    let policy = init_policy(&engine, cfg.seed as u32)?;

    let ckpt = cfg
        .checkpoint_dir
        .as_ref()
        .map(|d| Arc::new(CheckpointManager::new(d)));

    let handles: Vec<_> = collectives
        .into_iter()
        .enumerate()
        .map(|(rank, collective)| {
            let engine = engine.clone();
            let cfg = cfg.clone();
            let policy = policy.clone();
            let rewarder = clone_rewarder(&rewarder);
            let ckpt = ckpt.clone();
            std::thread::spawn(move || {
                run_rank(rank, engine, collective, cfg, policy, rewarder, ckpt)
            })
        })
        .collect();

    let mut rank0: Option<TrainReport> = None;
    for (rank, h) in handles.into_iter().enumerate() {
        let r = h
            .join()
            .map_err(|_| anyhow::anyhow!("controller {rank} panicked"))?
            .with_context(|| format!("controller {rank} failed"))?;
        if rank == 0 {
            rank0 = Some(r);
        }
    }
    let mut report = rank0.context("no rank-0 report")?;
    report.reward_model_metric = rm_metric;
    Ok(report)
}

/// Run a full RLHF training job: SFT warm-start → (optional) reward-model
/// pre-training → `cfg.steps` RLHF steps across `cfg.world` controllers.
/// The collective transport is `cfg.collective` (in-proc threads by
/// default).
pub fn run_training(cfg: &RunConfig) -> Result<TrainReport> {
    match cfg.collective {
        CollectiveMode::InProc => {
            let collective = Collective::new(cfg.world);
            run_threads(cfg, (0..cfg.world).map(|_| collective.clone()).collect())
        }
        CollectiveMode::Tcp => run_training_tcp(cfg),
        CollectiveMode::Ring => run_training_ring(cfg),
    }
}

/// Thread-per-controller launch whose collectives run as exactly-once RPC
/// rounds over real TCP (loopback) — the single-machine rehearsal of the
/// multi-process path, bit-identical to `run_training`.
pub fn run_training_tcp(cfg: &RunConfig) -> Result<TrainReport> {
    let server = Arc::new(
        RpcServer::new(RendezvousHost::new(cfg.world))
            .with_tombstone_capacity(cfg.rpc_tombstone_capacity)
            .with_tombstone_ttl(Duration::from_millis(cfg.rpc_tombstone_ttl_ms)),
    );
    let host = TcpRpcHost::spawn(server)?;
    let addr = host.addr;
    let collectives = (0..cfg.world)
        .map(|_| {
            Collective::with_backend(Arc::new(RpcCollective::new(
                TcpTransport::connect(addr),
                cfg.world,
            )))
        })
        .collect();
    let report = run_threads(cfg, collectives);
    drop(host); // all clients joined; release the listener
    report
}

/// Build a full loopback-TCP ring: one inbox host per rank (tombstones
/// bounded to `tombstone_capacity`), each rank's client connected to its
/// successor's host through `connect` — the launcher passes a plain
/// `TcpTransport`, E8c wraps it in a byte meter.  One wiring path for both,
/// so the benchmark always measures the topology the launcher runs.
/// Returns the hosts (keep them alive for the duration of the job) and the
/// per-rank collectives.
pub fn ring_tcp_group_with<T, F>(
    world: usize,
    chunk_bytes: usize,
    tombstone_capacity: usize,
    tombstone_ttl_ms: u64,
    connect: F,
) -> Result<(Vec<TcpRpcHost>, Vec<Arc<Collective>>)>
where
    T: crate::rpc::transport::Transport + 'static,
    F: Fn(usize, SocketAddr) -> T,
{
    let inboxes: Vec<Arc<RingInbox>> = (0..world).map(|_| RingInbox::new()).collect();
    let hosts = inboxes
        .iter()
        .map(|ib| {
            let server = Arc::new(
                RpcServer::new(RingPeer::new(ib.clone()))
                    .with_tombstone_capacity(tombstone_capacity)
                    .with_tombstone_ttl(Duration::from_millis(tombstone_ttl_ms)),
            );
            TcpRpcHost::spawn(server)
        })
        .collect::<Result<Vec<_>>>()?;
    let collectives = (0..world)
        .map(|rank| {
            let succ = connect(rank, hosts[(rank + 1) % world].addr);
            Collective::with_backend(Arc::new(
                RingCollective::new(rank, world, inboxes[rank].clone(), succ)
                    .with_chunk_bytes(chunk_bytes),
            ))
        })
        .collect();
    Ok((hosts, collectives))
}

/// `ring_tcp_group_with` over plain TCP transports and the default
/// tombstone bound.
pub fn ring_tcp_group(
    world: usize,
    chunk_bytes: usize,
) -> Result<(Vec<TcpRpcHost>, Vec<Arc<Collective>>)> {
    ring_tcp_group_with(
        world,
        chunk_bytes,
        crate::rpc::server::DEFAULT_TOMBSTONE_CAPACITY,
        0,
        |_, addr| TcpTransport::connect(addr),
    )
}

/// Thread-per-controller launch over streaming ring collectives
/// (loopback TCP) — O(payload) bytes per rank, bit-identical to
/// `run_training` (asserted in tests/system_integration.rs).
pub fn run_training_ring(cfg: &RunConfig) -> Result<TrainReport> {
    let (hosts, collectives) = ring_tcp_group_with(
        cfg.world,
        cfg.ring_chunk_bytes,
        cfg.rpc_tombstone_capacity,
        cfg.rpc_tombstone_ttl_ms,
        |_, addr| TcpTransport::connect(addr),
    )?;
    let report = run_threads(cfg, collectives);
    drop(hosts); // all clients joined; release the listeners
    report
}

/// Host the rendezvous service for a multi-process launch (`train-dist`):
/// binds 127.0.0.1:`port` (0 = ephemeral; read the actual address off the
/// returned host) and serves until dropped.  `tombstone_capacity` bounds
/// the server's cleanup-tombstone set (`rpc_tombstone_capacity` knob).
/// `epoch` is the recovery generation the host accepts (supervisor
/// respawns bump it, so frames from pre-crash processes are rejected as
/// stale); a non-zero `lease_ttl_ms` arms heartbeat leases — a rank that
/// stops beating for that long is latched dead and every survivor's next
/// collective call fails fast with a typed `PeerDead` status.
pub fn serve_coordinator(
    world: usize,
    port: u16,
    tombstone_capacity: usize,
    tombstone_ttl_ms: u64,
    epoch: u64,
    lease_ttl_ms: u64,
) -> Result<TcpRpcHost> {
    let mut rendezvous = RendezvousHost::new(world).with_epoch(epoch);
    if lease_ttl_ms > 0 {
        rendezvous = rendezvous.with_lease_ttl(Duration::from_millis(lease_ttl_ms));
    }
    let server = Arc::new(
        RpcServer::new(rendezvous)
            .with_tombstone_capacity(tombstone_capacity)
            .with_tombstone_ttl(Duration::from_millis(tombstone_ttl_ms)),
    );
    TcpRpcHost::spawn_on(&format!("127.0.0.1:{port}"), server)
}

/// Build the collective one `train-worker` coordinates through.  For the
/// rendezvous modes this is a single RPC client at `coord`.  For the ring,
/// the worker hosts its own inbox service on an ephemeral port, all-gathers
/// every rank's address through the coordinator ONCE (the only rendezvous
/// round), then streams all collective traffic to its ring successor; the
/// returned host must stay alive for the duration of the job.
///
/// Every outbound connection is wrapped in a [`MeteredTransport`] feeding
/// one per-rank [`TransferStats`], so `train-dist` reports the bytes each
/// worker actually moved over real sockets (E8c measures, not models).
fn build_worker_collective(
    cfg: &RunConfig,
    rank: usize,
    coord: SocketAddr,
) -> Result<(Arc<Collective>, Option<TcpRpcHost>, Arc<TransferStats>)> {
    let stats = Arc::new(TransferStats::default());
    match cfg.collective {
        CollectiveMode::Ring => {
            let boot = RpcCollective::for_rank(
                MeteredTransport::with_stats(tcp_transport(cfg, coord), stats.clone()),
                cfg.world,
                rank,
            )
            .with_epoch(cfg.coord_epoch);
            let inbox = RingInbox::new();
            let server = Arc::new(
                RpcServer::new(RingPeer::new(inbox.clone()))
                    .with_tombstone_capacity(cfg.rpc_tombstone_capacity)
                    .with_tombstone_ttl(Duration::from_millis(cfg.rpc_tombstone_ttl_ms)),
            );
            let host = TcpRpcHost::spawn(server)?;
            let addrs = boot
                .exchange(rank, "ring.bootstrap", host.addr.to_string().into_bytes())
                .context("ring bootstrap address exchange")?;
            let succ_raw = &addrs[(rank + 1) % cfg.world];
            let succ: SocketAddr = std::str::from_utf8(succ_raw)
                .context("ring bootstrap address is not utf8")?
                .parse()
                .context("ring bootstrap address did not parse")?;
            let mut backend = RingCollective::new(
                rank,
                cfg.world,
                inbox,
                MeteredTransport::with_stats(tcp_transport(cfg, succ), stats.clone()),
            )
            .with_chunk_bytes(cfg.ring_chunk_bytes);
            if cfg.heartbeat_interval_ms > 0 && cfg.world > 1 {
                // after bootstrap the ring never revisits the coordinator,
                // so a dead peer would otherwise only surface as a 300 s
                // inbox timeout — poll the host's latched liveness verdict
                // (throttled, unmetered control plane) between chunk waits
                let probe_client = RpcClient::new(tcp_transport(cfg, coord))
                    .with_id_base((3u64 << 62) | ((rank as u64) << 40));
                backend = backend.with_probe(Arc::new(LivenessProbe::new(
                    probe_client,
                    rank as u32,
                    cfg.coord_epoch,
                    Duration::from_millis(cfg.heartbeat_interval_ms),
                )));
            }
            Ok((Collective::with_backend(Arc::new(backend)), Some(host), stats))
        }
        _ => {
            let backend = RpcCollective::for_rank(
                MeteredTransport::with_stats(tcp_transport(cfg, coord), stats.clone()),
                cfg.world,
                rank,
            )
            .with_epoch(cfg.coord_epoch);
            Ok((Collective::with_backend(Arc::new(backend)), None, stats))
        }
    }
}

/// One `train-worker` OS process: rank `rank` of `cfg.world`, coordinating
/// only through the collective rooted at `coord`.  Every worker re-derives
/// the initial policy from the shared seed (one cheap engine call); the
/// reward model is pre-trained on rank 0 only and broadcast over the
/// collective's bytes channel ([`broadcast_rewarder`] — the ring's chunked
/// streaming makes the multi-MB weight frame O(payload) per rank), so all
/// ranks still start bit-identical.
pub fn run_worker(cfg: &RunConfig, rank: usize, coord: SocketAddr) -> Result<TrainReport> {
    // Heartbeat lease: this rank's liveness thread beats the rendezvous
    // host for the worker's whole life — engine load, reward pre-training,
    // every training phase — so a crash ANYWHERE lapses the lease.  The
    // lease only starts at the FIRST beat (no false positives while other
    // ranks are still spawning), and dropping the guard joins the thread
    // on clean exit.  A killed process simply stops beating.
    let _heartbeat = if cfg.world > 1 && cfg.heartbeat_interval_ms > 0 {
        let client = RpcClient::new(tcp_transport(cfg, coord))
            .with_id_base((1u64 << 62) | ((rank as u64) << 40));
        Some(Heartbeat::start(
            client,
            rank as u32,
            cfg.coord_epoch,
            Duration::from_millis(cfg.heartbeat_interval_ms),
        ))
    } else {
        None
    };
    let engine = Arc::new(Engine::load(&cfg.artifacts)?);
    let policy = init_policy(&engine, cfg.seed as u32)?;
    // `_ring_host` keeps this rank's inbox service alive until training ends
    let (collective, _ring_host, net) = build_worker_collective(cfg, rank, coord)?;
    let (rewarder, rm_metric) = broadcast_rewarder(&engine, cfg, &collective, rank)?;
    let ckpt = cfg
        .checkpoint_dir
        .as_ref()
        .map(|d| Arc::new(CheckpointManager::new(d)));
    let mut report = run_rank(rank, engine, collective, cfg.clone(), policy, rewarder, ckpt)
        .with_context(|| format!("worker rank {rank} failed"))?;
    report.reward_model_metric = rm_metric;
    // machine-readable per-rank byte totals: the train-dist parent (and
    // E8c) parses this line off the worker's stdout
    println!(
        "[gcore] worker {rank} collective-bytes sent={} recv={}",
        net.sent.load(std::sync::atomic::Ordering::Relaxed),
        net.received.load(std::sync::atomic::Ordering::Relaxed)
    );
    Ok(report)
}

/// The process exit code a `train-worker` reports for `err`: typed
/// collective statuses map to stable codes (`CollectiveStatus::exit_code`,
/// 65..=70) the parent matches on; anything else is 1.
pub fn worker_exit_code(err: &anyhow::Error) -> i32 {
    match CollectiveStatus::classify_error(err) {
        Some(status) => status.exit_code(),
        None => 1,
    }
}

/// Decode a worker's exit status into the typed collective reason, if any
/// (the `train-dist` parent's half of the exit-code contract).
pub fn describe_worker_exit(code: Option<i32>) -> Option<&'static str> {
    code.and_then(CollectiveStatus::from_exit_code)
        .map(|s| s.describe())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reward::VerdictMode;
    use crate::runtime::tensor::Tensor;

    fn bt_rewarder() -> Rewarder {
        Rewarder::bradley_terry(ParamSet::new(vec![
            Tensor::f32(vec![2, 2], vec![0.5, -1.25, f32::MIN_POSITIVE, 3.0]),
            Tensor::f32(vec![3], vec![-0.0, 9.0, 1e-30]),
        ]))
    }

    #[test]
    fn chaos_spec_parses_and_rejects_garbage() {
        assert_eq!(parse_chaos("kill:rank=1,step=3").unwrap(), (1, 3));
        assert_eq!(parse_chaos("kill:step=0,rank=2").unwrap(), (2, 0));
        for bad in [
            "rank=1,step=3",        // missing action
            "pause:rank=1,step=3",  // unknown action
            "kill:rank=1",          // missing step
            "kill:step=3",          // missing rank
            "kill:rank=x,step=3",   // non-numeric
            "kill:rank=1,step=3,victim=2", // unknown field
            "kill:rank1,step=3",    // malformed field
        ] {
            assert!(parse_chaos(bad).is_err(), "{bad:?} should not parse");
        }
    }

    #[test]
    fn rewarder_wire_roundtrip_is_bit_exact() {
        let cfg = RunConfig {
            reward: RewardKind::BradleyTerry,
            ..RunConfig::default()
        };
        let r = bt_rewarder();
        let bytes = encode_rewarder(&r, 0.875);
        let (back, metric) = decode_rewarder(&cfg, &bytes).unwrap();
        assert_eq!(metric, 0.875);
        assert_eq!(back.kind, RewardKind::BradleyTerry);
        assert_eq!(back.bt_params, r.bt_params);

        // generative path carries the verifier weights + config's verdict mode
        let gcfg = RunConfig {
            reward: RewardKind::Generative,
            verdict_mode: VerdictMode::Regex,
            ..RunConfig::default()
        };
        let v = Rewarder::generative(
            ParamSet::new(vec![Tensor::f32(vec![2], vec![1.0, 2.0])]),
            VerdictMode::Logit, // overwritten by the config on decode
        );
        let (back, _) = decode_rewarder(&gcfg, &encode_rewarder(&v, 0.5)).unwrap();
        assert_eq!(back.kind, RewardKind::Generative);
        assert_eq!(back.verdict_mode, VerdictMode::Regex);
        assert_eq!(back.verifier_params, v.verifier_params);

        // a BT config can't decode a payload without params
        let no_params = encode_rewarder(&Rewarder::ground_truth(), 1.0);
        assert!(decode_rewarder(&cfg, &no_params).is_err());
    }

    #[test]
    fn rewarder_broadcast_is_bit_identical_across_ranks() {
        // no engine needed: drive broadcast_bytes + the rewarder codec the
        // way broadcast_rewarder does, across an in-proc world of 3
        let world = 3;
        let col = Collective::new(world);
        let cfg = RunConfig {
            reward: RewardKind::BradleyTerry,
            world,
            ..RunConfig::default()
        };
        let reference = bt_rewarder();
        let payload = encode_rewarder(&reference, 0.75);
        let handles: Vec<_> = (0..world)
            .map(|rank| {
                let col = col.clone();
                let cfg = cfg.clone();
                let payload = if rank == 0 { payload.clone() } else { Vec::new() };
                std::thread::spawn(move || {
                    let bytes = col.broadcast_bytes(rank, 0, payload).unwrap();
                    decode_rewarder(&cfg, &bytes).unwrap()
                })
            })
            .collect();
        for h in handles {
            let (r, metric) = h.join().unwrap();
            assert_eq!(metric, 0.75);
            assert_eq!(r.bt_params, reference.bt_params, "weights must be bit-identical");
        }
    }
}
