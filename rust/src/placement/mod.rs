//! Placement engines (paper §2.3, §3.2): **co-locate**, **co-exist**, and
//! G-Core's **dynamic placement**, evaluated on the simulated cluster.
//!
//! * Co-locate: every role time-shares all GPUs; stage transitions swap
//!   models in/out (30–60 s for 32B-class models).  Cheap for plain GRPO,
//!   but dynamic sampling multiplies the swap rounds and the long tail
//!   amplifies the bubbles (§3.2).
//! * Co-exist (static split): generation and rewarding pools pipeline
//!   without swaps; the split is fixed up front and goes stale as the
//!   workload drifts.
//! * Dynamic placement: stages 1–2 co-exist on a split that is re-balanced
//!   from measured utilization; stages 3–4 co-locate on ALL devices.  The
//!   initial split comes from the paper's heuristic (activated parameter
//!   counts); re-balancing "gradually reduce[s] the resource allocation
//!   for roles with low utilization".

use crate::cluster::device::DeviceId;
use crate::cluster::sim::{Sim, SimReport, WorkKind};
use crate::cluster::swap::SwapCostModel;
use crate::cluster::workload::{AcceptanceModel, GenLenModel, GenTimeModel, TrainTimeModel};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct PlacementSpec {
    pub n_devices: usize,
    pub steps: usize,
    /// sequences per training step (global batch)
    pub batch: usize,
    pub group_size: usize,
    /// per-device weight shard sizes (GB)
    pub policy_gb: f64,
    pub reward_gb: f64,
    pub gen_len: GenLenModel,
    /// verifier generation lengths (generative rewarding)
    pub reward_len: GenLenModel,
    pub accept: AcceptanceModel,
    pub dynamic_sampling: bool,
    pub gen_time: GenTimeModel,
    pub reward_time: GenTimeModel,
    pub train_time: TrainTimeModel,
    pub swap: SwapCostModel,
    pub seed: u64,
}

impl PlacementSpec {
    /// A paper-§5-like default: 64 devices, 7B-class policy + verifier.
    pub fn paper_like() -> PlacementSpec {
        PlacementSpec {
            n_devices: 64,
            steps: 20,
            batch: 512,
            group_size: 8,
            policy_gb: 14.0,
            reward_gb: 14.0,
            gen_len: GenLenModel::reasoning_default(),
            reward_len: GenLenModel {
                mu0: 4.6, // verifier verdicts ~100 tokens
                sigma: 0.5,
                growth_per_step: 0.0,
                max_len: 1024,
            },
            accept: AcceptanceModel::default_decay(),
            dynamic_sampling: true,
            gen_time: GenTimeModel::vllm_like(),
            reward_time: GenTimeModel::vllm_like(),
            train_time: TrainTimeModel::default_7b(),
            swap: SwapCostModel::default(),
            seed: 11,
        }
    }

    fn ids(&self, range: std::ops::Range<usize>) -> Vec<DeviceId> {
        range.map(DeviceId).collect()
    }

    /// rounds of generation needed at `step` under dynamic sampling
    fn rounds_at(&self, step: usize, rng: &mut Rng) -> usize {
        if !self.dynamic_sampling {
            return 1;
        }
        let p = self.accept.accept_prob(step);
        // accepted fraction per round ≈ p; need full batch
        let mut need = 1.0f64;
        let mut rounds = 0;
        while need > 1e-3 && rounds < 8 {
            rounds += 1;
            need -= p * need.max(0.3); // diminishing fills
            let _ = rng; // jitterless expectation model
        }
        rounds.max((1.0 / p).round() as usize).min(8)
    }

    /// makespan + per-device busy of a generation round on `pool` devices.
    fn gen_round(
        &self,
        sim: &mut Sim,
        pool: &[DeviceId],
        lens: &[usize],
        time: &GenTimeModel,
        kind: WorkKind,
        not_before: f64,
    ) -> f64 {
        // shard sequences round-robin across the pool; each device's busy
        // time is its own batch makespan — the long tail shows up as
        // inter-device spread
        let per: Vec<Vec<usize>> = {
            let mut v = vec![Vec::new(); pool.len()];
            for (i, &l) in lens.iter().enumerate() {
                v[i % pool.len()].push(l);
            }
            v
        };
        let mut end = not_before;
        for (d, dev_lens) in pool.iter().zip(&per) {
            let (mk, _) = time.batch_times(dev_lens);
            let (_, e) = sim.run_one_after(*d, not_before, kind, mk);
            end = end.max(e);
        }
        end
    }
}

/// Heuristic initial split (paper §3.2): proportional to activated params.
pub fn heuristic_gen_fraction(policy_gb: f64, reward_gb: f64) -> f64 {
    (policy_gb / (policy_gb + reward_gb)).clamp(0.1, 0.9)
}

// ---------------------------------------------------------------------------
// Co-locate
// ---------------------------------------------------------------------------

pub fn run_colocate(spec: &PlacementSpec) -> SimReport {
    let mut sim = Sim::new(spec.n_devices);
    let mut rng = Rng::new(spec.seed);
    let all = spec.ids(0..spec.n_devices);
    let mut samples = 0usize;

    for step in 0..spec.steps {
        let rounds = spec.rounds_at(step, &mut rng);
        for round in 0..rounds {
            // swap policy-gen in (first round: from train layout)
            let swap_in = if round == 0 {
                spec.swap.exchange(spec.policy_gb, spec.policy_gb)
            } else {
                spec.swap.exchange(spec.reward_gb, spec.policy_gb)
            };
            sim.run_group(&all, WorkKind::Swap, swap_in);
            let lens = spec.gen_len.sample_batch(&mut rng, step, spec.batch);
            let end = spec.gen_round(&mut sim, &all, &lens, &spec.gen_time, WorkKind::Generate, 0.0);
            sim.barrier(end); // synchronous stage transition
            // swap reward model in
            sim.run_group(&all, WorkKind::Swap, spec.swap.exchange(spec.policy_gb, spec.reward_gb));
            let rlens = spec.reward_len.sample_batch(&mut rng, step, spec.batch);
            let end = spec.gen_round(&mut sim, &all, &rlens, &spec.reward_time, WorkKind::Reward, 0.0);
            sim.barrier(end);
        }
        // swap training layout in
        sim.run_group(&all, WorkKind::Swap, spec.swap.exchange(spec.reward_gb, spec.policy_gb));
        train_stages(spec, &mut sim, &all, step, &mut rng);
        samples += spec.batch;
    }
    SimReport::from_sim(&sim, samples)
}

// ---------------------------------------------------------------------------
// Co-exist (static split) and dynamic placement
// ---------------------------------------------------------------------------

fn train_stages(
    spec: &PlacementSpec,
    sim: &mut Sim,
    all: &[DeviceId],
    step: usize,
    rng: &mut Rng,
) {
    // Stage 3 prep: old/ref logprob forwards — linear cost over tokens
    let lens = spec.gen_len.sample_batch(rng, step, spec.batch);
    let total_tokens: usize = lens.iter().sum();
    let prep = 2.0 * spec.train_time.s_per_token * total_tokens as f64 / all.len() as f64;
    sim.run_group(all, WorkKind::Prepare, prep);
    // Stage 4 train: fwd+bwd ≈ 3× forward, workload-balanced (per §4.4 the
    // balancer keeps waste <10%; charge the balanced cost + 5%)
    let cost: f64 = lens.iter().map(|&l| spec.train_time.seq_cost(l)).sum();
    let train = 3.0 * 1.05 * cost / all.len() as f64;
    sim.run_group(all, WorkKind::Train, train);
}

/// Shared body for co-exist variants. `gen_frac_of_step(step, utils)`
/// chooses the split each step; returns the trace of splits used.
fn run_coexist_inner(
    spec: &PlacementSpec,
    mut gen_frac_of_step: impl FnMut(usize, Option<(f64, f64)>) -> f64,
) -> (SimReport, Vec<(usize, f64, f64, f64)>) {
    let mut sim = Sim::new(spec.n_devices);
    let mut rng = Rng::new(spec.seed);
    let all: Vec<DeviceId> = spec.ids(0..spec.n_devices);
    let mut samples = 0usize;
    let mut trace = Vec::new();
    let mut last_utils: Option<(f64, f64)> = None;

    for step in 0..spec.steps {
        let frac = gen_frac_of_step(step, last_utils).clamp(0.1, 0.9);
        let n_gen = ((spec.n_devices as f64 * frac).round() as usize)
            .clamp(1, spec.n_devices - 1);
        let gen_pool = spec.ids(0..n_gen);
        let reward_pool = spec.ids(n_gen..spec.n_devices);

        let rounds = spec.rounds_at(step, &mut rng);
        let step_start = sim.makespan();
        let mut gen_busy = 0.0;
        let mut reward_busy = 0.0;
        // pipelined rounds: reward round r starts when gen round r ends;
        // gen round r+1 starts immediately after gen round r (no swaps!)
        let mut gen_end = step_start;
        let mut reward_end = step_start;
        for _round in 0..rounds {
            let lens = spec.gen_len.sample_batch(&mut rng, step, spec.batch);
            let t0 = gen_end;
            gen_end = spec.gen_round(&mut sim, &gen_pool, &lens, &spec.gen_time, WorkKind::Generate, t0);
            gen_busy += gen_end - t0;
            let rlens = spec.reward_len.sample_batch(&mut rng, step, spec.batch);
            let r0 = gen_end.max(reward_end);
            reward_end = spec.gen_round(&mut sim, &reward_pool, &rlens, &spec.reward_time, WorkKind::Reward, r0);
            reward_busy += reward_end - r0;
        }
        let stage12_end = gen_end.max(reward_end);
        sim.barrier(stage12_end);
        // measured pool utilizations over stages 1-2 (the dynamic signal)
        let wall = (stage12_end - step_start).max(1e-9);
        let util_gen = gen_busy / wall;
        let util_reward = reward_busy / wall;
        last_utils = Some((util_gen, util_reward));
        trace.push((step, frac, util_gen, util_reward));

        // stages 3-4 co-locate on ALL devices: one swap to training layout
        sim.run_group(&all, WorkKind::Swap, spec.swap.exchange(spec.policy_gb, spec.policy_gb));
        train_stages(spec, &mut sim, &all, step, &mut rng);
        // weight sync back to the generation pool
        sim.run_group(&gen_pool, WorkKind::WeightSync, spec.swap.weight_update(spec.policy_gb));
        samples += spec.batch;
    }
    (SimReport::from_sim(&sim, samples), trace)
}

pub fn run_coexist_static(spec: &PlacementSpec, gen_frac: f64) -> SimReport {
    run_coexist_inner(spec, |_, _| gen_frac).0
}

#[derive(Debug, Clone)]
pub struct DynamicReport {
    pub report: SimReport,
    /// (step, gen_fraction, util_gen, util_reward)
    pub trace: Vec<(usize, f64, f64, f64)>,
}

/// G-Core dynamic placement: heuristic initial ratio, then per-step
/// gradient moves toward the higher-utilization role.
pub fn run_dynamic(spec: &PlacementSpec) -> DynamicReport {
    let step_frac = 1.0 / spec.n_devices as f64;
    let mut frac = heuristic_gen_fraction(spec.policy_gb, spec.reward_gb);
    let (report, trace) = run_coexist_inner(spec, |_, utils| {
        if let Some((ug, ur)) = utils {
            // move one device's worth toward the busier pool
            if ug > ur + 0.05 {
                frac += step_frac;
            } else if ur > ug + 0.05 {
                frac -= step_frac;
            }
        }
        frac
    });
    DynamicReport { report, trace }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast_spec() -> PlacementSpec {
        PlacementSpec { steps: 8, n_devices: 16, batch: 128, ..PlacementSpec::paper_like() }
    }

    #[test]
    fn colocate_without_dapo_swaps_negligible() {
        // paper §2.3: for plain GRPO, swap overhead is minor vs stage time —
        // in the paper's regime rollouts take "tens of minutes" (long
        // reasoning generations), so use the long-generation workload
        let mut spec = PlacementSpec { dynamic_sampling: false, ..fast_spec() };
        spec.gen_len.mu0 = 7.6; // median ~2000 tokens
        let r = run_colocate(&spec);
        assert!(
            r.swap_s < 0.15 * r.makespan_s * spec.n_devices as f64,
            "swap {} vs device-time {}",
            r.swap_s,
            r.makespan_s * spec.n_devices as f64
        );
    }

    #[test]
    fn dapo_amplifies_colocate_swaps() {
        // §3.2 item 1: resampling multiplies swap rounds
        let without = run_colocate(&PlacementSpec { dynamic_sampling: false, ..fast_spec() });
        let mut with_spec = fast_spec();
        with_spec.accept.p0 = 0.4;
        with_spec.accept.floor = 0.2;
        let with = run_colocate(&with_spec);
        assert!(
            with.swap_s > 2.0 * without.swap_s,
            "with {} vs without {}",
            with.swap_s,
            without.swap_s
        );
    }

    #[test]
    fn dynamic_beats_colocate_under_dapo() {
        // the headline E2 shape: same work, dynamic placement finishes
        // sooner and wastes less on swaps
        let mut spec = fast_spec();
        spec.accept.p0 = 0.5;
        spec.accept.floor = 0.2;
        let colo = run_colocate(&spec);
        let dynp = run_dynamic(&spec);
        assert!(
            dynp.report.makespan_s < colo.makespan_s,
            "dynamic {} vs colocate {}",
            dynp.report.makespan_s,
            colo.makespan_s
        );
        assert!(dynp.report.swap_s < colo.swap_s);
    }

    #[test]
    fn dynamic_tracks_workload_drift() {
        // E7: generation lengths grow over training → optimal split moves
        // toward generation; the dynamic trace must follow
        let mut spec = fast_spec();
        spec.steps = 24;
        spec.gen_len.growth_per_step = 0.08; // fast drift for the test
        let d = run_dynamic(&spec);
        let first = d.trace.first().unwrap().1;
        let last = d.trace.last().unwrap().1;
        assert!(
            last > first + 2.0 / spec.n_devices as f64,
            "gen fraction should grow: {first} -> {last}"
        );
    }

    #[test]
    fn dynamic_at_least_matches_best_static() {
        let spec = fast_spec();
        let dynp = run_dynamic(&spec).report;
        // sweep static splits; dynamic should be within 15% of the best
        let best = [0.3, 0.5, 0.7]
            .iter()
            .map(|&f| run_coexist_static(&spec, f).makespan_s)
            .fold(f64::INFINITY, f64::min);
        assert!(
            dynp.makespan_s < best * 1.15,
            "dynamic {} vs best static {best}",
            dynp.makespan_s
        );
    }

    #[test]
    fn heuristic_fraction_sane() {
        assert!((heuristic_gen_fraction(14.0, 14.0) - 0.5).abs() < 1e-9);
        assert!(heuristic_gen_fraction(64.0, 2.0) <= 0.9);
        assert!(heuristic_gen_fraction(2.0, 64.0) >= 0.1);
    }

    #[test]
    fn reports_have_positive_utilization() {
        let spec = fast_spec();
        for r in [
            run_colocate(&spec),
            run_coexist_static(&spec, 0.5),
            run_dynamic(&spec).report,
        ] {
            assert!(r.utilization > 0.05 && r.utilization <= 1.0, "{r:?}");
            assert!(r.makespan_s > 0.0);
        }
    }
}
