//! Run configuration: JSON config files + presets for the `gcore` launcher,
//! examples and benches.  (The offline vendor set has no TOML crate, so
//! configs are JSON — same composability, zero extra dependencies.)

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::reward::{RewardKind, VerdictMode};
use crate::util::json::Json;

/// How the controller group coordinates (see coordinator::collective):
/// in-proc condvar rendezvous between threads, RPC rounds against a rank-0
/// rendezvous service over TCP, or chunked streaming ring collectives
/// (peer-hosted RPC services, O(payload) per rank — no rank-0 bottleneck).
/// `train-dist` workers honour the same choice.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CollectiveMode {
    InProc,
    Tcp,
    Ring,
}

impl CollectiveMode {
    pub fn parse(s: &str) -> Result<CollectiveMode> {
        Ok(match s {
            "inproc" => CollectiveMode::InProc,
            "tcp" => CollectiveMode::Tcp,
            "ring" => CollectiveMode::Ring,
            other => bail!("unknown collective mode '{other}' (inproc|tcp|ring)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            CollectiveMode::InProc => "inproc",
            CollectiveMode::Tcp => "tcp",
            CollectiveMode::Ring => "ring",
        }
    }
}

/// What the `train-dist` supervisor does when a worker dies mid-run
/// (heartbeat lease expiry or process exit): nothing (fail-fast, the
/// pre-elastic behaviour), respawn the full world from the latest
/// complete checkpoint, or renegotiate the world size down to a divisor
/// and resume (the checkpoint module's elastic-resume rule).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecoverPolicy {
    None,
    Restart,
    Shrink,
}

impl RecoverPolicy {
    pub fn parse(s: &str) -> Result<RecoverPolicy> {
        Ok(match s {
            "none" => RecoverPolicy::None,
            "restart" => RecoverPolicy::Restart,
            "shrink" => RecoverPolicy::Shrink,
            other => bail!("unknown recover policy '{other}' (none|restart|shrink)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            RecoverPolicy::None => "none",
            RecoverPolicy::Restart => "restart",
            RecoverPolicy::Shrink => "shrink",
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct RunConfig {
    /// artifact set name (tiny / quickstart / e2e / path)
    pub artifacts: String,
    /// number of parallel controllers
    pub world: usize,
    pub steps: usize,
    /// GRPO group size (must divide the artifact batch)
    pub group_size: usize,
    // -- optimisation -------------------------------------------------------
    pub lr: f32,
    /// learning rate for the SFT warm-start (decoupled from the RL lr)
    pub sft_lr: f32,
    pub clip_eps: f32,
    pub kl_coef: f32,
    pub ent_coef: f32,
    // -- sampling -----------------------------------------------------------
    pub temperature: f32,
    pub top_k: usize,
    // -- rewarding ----------------------------------------------------------
    pub reward: RewardKind,
    pub verdict_mode: VerdictMode,
    // -- dynamic sampling (DAPO) --------------------------------------------
    pub dynamic_sampling: bool,
    pub max_resample_rounds: usize,
    // -- rollout scheduler (continuous batching / paged KV) ------------------
    /// token positions per KV-cache page
    pub kv_page_size: usize,
    /// page-pool capacity in pages (0 = auto-size: one full wave never
    /// blocks on admission)
    pub kv_cache_pages: usize,
    /// preempt straggler rollouts once the dynamic-sampling round has
    /// enough finished sequences (requires `dynamic_sampling`)
    pub rollout_cancel: bool,
    /// decode-step grace window before preemption (scaled down by batch
    /// utilization — balance::cancel_grace_steps)
    pub rollout_cancel_grace: usize,
    // -- warm starts ---------------------------------------------------------
    pub sft_steps: usize,
    pub verifier_sft_steps: usize,
    pub bt_train_steps: usize,
    // -- infra ---------------------------------------------------------------
    pub seed: u64,
    pub checkpoint_dir: Option<String>,
    pub checkpoint_every: usize,
    pub tasks: Vec<String>,
    // -- distributed launch ---------------------------------------------------
    /// collective transport for `gcore train` / `gcore train-dist`
    pub collective: CollectiveMode,
    /// rendezvous-host port for multi-process launches (0 = ephemeral)
    pub coordinator_port: u16,
    /// bytes per streamed chunk for the ring collective (`--collective ring`)
    pub ring_chunk_bytes: usize,
    /// bound on the RPC server's cleanup-tombstone set (ids; oldest evicted)
    pub rpc_tombstone_capacity: usize,
    /// age bound on cleanup tombstones in milliseconds (0 = count-based
    /// eviction only); entries older than this re-execute as fresh calls
    pub rpc_tombstone_ttl_ms: u64,
    /// size bound for gradient all-reduce buckets (tensor-boundary
    /// partition; bucket k reduces on the communicator thread while bucket
    /// k+1 serializes)
    pub allreduce_bucket_bytes: usize,
    // -- fault tolerance ------------------------------------------------------
    /// interval between worker heartbeats to the rendezvous host (0 =
    /// heartbeats off; multi-process `train-dist` workers only — thread
    /// launches share one failure domain already)
    pub heartbeat_interval_ms: u64,
    /// heartbeat lease TTL: a rank whose lease lapses this long is marked
    /// dead and every surviving rank's next collective poll fails with
    /// `PeerDead` (must comfortably exceed `heartbeat_interval_ms`)
    pub lease_ttl_ms: u64,
    /// TCP connect timeout for client transports (0 = OS default, blocking)
    pub tcp_connect_timeout_ms: u64,
    /// TCP per-frame read/write timeout for client transports (0 = none) —
    /// distinguishes a wedged-but-alive peer from a dead one so the retry
    /// loop actually runs
    pub tcp_io_timeout_ms: u64,
    /// `train-dist` supervisor action on worker death
    pub recover: RecoverPolicy,
    /// bound on recovery attempts before the supervisor gives up
    pub max_restarts: usize,
    /// resume training from this checkpoint step (workers skip warm-start
    /// and replay `resume_step..steps`); set by the supervisor on respawn
    pub resume_step: Option<u64>,
    /// rendezvous generation: the supervisor bumps this on every recovery
    /// respawn so frames from a pre-crash epoch are rejected as stale
    pub coord_epoch: u64,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            artifacts: "tiny".into(),
            world: 1,
            steps: 20,
            group_size: 4,
            lr: 1e-3,
            sft_lr: 1.5e-3,
            clip_eps: 0.2,
            kl_coef: 0.02,
            ent_coef: 0.0,
            temperature: 0.8,
            top_k: 16,
            reward: RewardKind::GroundTruth,
            verdict_mode: VerdictMode::Logit,
            dynamic_sampling: false,
            max_resample_rounds: 4,
            kv_page_size: 16,
            kv_cache_pages: 0,
            rollout_cancel: false,
            rollout_cancel_grace: 8,
            sft_steps: 30,
            verifier_sft_steps: 60,
            bt_train_steps: 40,
            seed: 17,
            checkpoint_dir: None,
            checkpoint_every: 0,
            tasks: vec!["add".into(), "max".into(), "copy".into()],
            collective: CollectiveMode::InProc,
            coordinator_port: 0,
            ring_chunk_bytes: 256 * 1024,
            rpc_tombstone_capacity: crate::rpc::server::DEFAULT_TOMBSTONE_CAPACITY,
            rpc_tombstone_ttl_ms: 0,
            allreduce_bucket_bytes: 4 * 1024 * 1024,
            heartbeat_interval_ms: 100,
            lease_ttl_ms: 1000,
            tcp_connect_timeout_ms: 10_000,
            tcp_io_timeout_ms: 30_000,
            recover: RecoverPolicy::None,
            max_restarts: 2,
            resume_step: None,
            coord_epoch: 0,
        }
    }
}

impl RunConfig {
    pub fn from_json(j: &Json) -> Result<RunConfig> {
        let mut cfg = RunConfig::default();
        let obj = j.as_obj().context("config must be a JSON object")?;
        for (key, val) in obj {
            match key.as_str() {
                "artifacts" => cfg.artifacts = req_str(val, key)?,
                "world" => cfg.world = req_usize(val, key)?,
                "steps" => cfg.steps = req_usize(val, key)?,
                "group_size" => cfg.group_size = req_usize(val, key)?,
                "lr" => cfg.lr = req_f32(val, key)?,
                "sft_lr" => cfg.sft_lr = req_f32(val, key)?,
                "clip_eps" => cfg.clip_eps = req_f32(val, key)?,
                "kl_coef" => cfg.kl_coef = req_f32(val, key)?,
                "ent_coef" => cfg.ent_coef = req_f32(val, key)?,
                "temperature" => cfg.temperature = req_f32(val, key)?,
                "top_k" => cfg.top_k = req_usize(val, key)?,
                "reward" => {
                    cfg.reward = match req_str(val, key)?.as_str() {
                        "ground_truth" => RewardKind::GroundTruth,
                        "bradley_terry" | "bt" => RewardKind::BradleyTerry,
                        "generative" | "genrm" => RewardKind::Generative,
                        other => bail!("unknown reward kind '{other}'"),
                    }
                }
                "verdict_mode" => {
                    cfg.verdict_mode = match req_str(val, key)?.as_str() {
                        "logit" => VerdictMode::Logit,
                        "regex" => VerdictMode::Regex,
                        other => bail!("unknown verdict mode '{other}'"),
                    }
                }
                "dynamic_sampling" => {
                    cfg.dynamic_sampling = val.as_bool().context("bool")?
                }
                "max_resample_rounds" => cfg.max_resample_rounds = req_usize(val, key)?,
                "kv_page_size" => cfg.kv_page_size = req_usize(val, key)?,
                "kv_cache_pages" => cfg.kv_cache_pages = req_usize(val, key)?,
                "rollout_cancel" => {
                    cfg.rollout_cancel = val.as_bool().context("bool")?
                }
                "rollout_cancel_grace" => {
                    cfg.rollout_cancel_grace = req_usize(val, key)?
                }
                "sft_steps" => cfg.sft_steps = req_usize(val, key)?,
                "verifier_sft_steps" => cfg.verifier_sft_steps = req_usize(val, key)?,
                "bt_train_steps" => cfg.bt_train_steps = req_usize(val, key)?,
                // number or string: JSON numbers are f64, so u64 seeds above
                // 2^53 only survive exactly as strings (to_json emits those)
                "seed" => {
                    cfg.seed = match val.as_str() {
                        Some(s) => s
                            .parse()
                            .with_context(|| format!("seed '{s}' is not a u64"))?,
                        None => req_usize(val, key)? as u64,
                    }
                }
                "checkpoint_dir" => cfg.checkpoint_dir = Some(req_str(val, key)?),
                "checkpoint_every" => cfg.checkpoint_every = req_usize(val, key)?,
                "tasks" => {
                    cfg.tasks = val
                        .as_arr()
                        .context("tasks must be an array")?
                        .iter()
                        .map(|t| t.as_str().map(String::from).context("task name"))
                        .collect::<Result<_>>()?
                }
                "collective" => {
                    cfg.collective = CollectiveMode::parse(&req_str(val, key)?)?
                }
                "coordinator_port" => {
                    let p = req_usize(val, key)?;
                    if p > u16::MAX as usize {
                        bail!("coordinator_port {p} out of range");
                    }
                    cfg.coordinator_port = p as u16
                }
                "ring_chunk_bytes" => cfg.ring_chunk_bytes = req_usize(val, key)?,
                "rpc_tombstone_capacity" => {
                    cfg.rpc_tombstone_capacity = req_usize(val, key)?
                }
                "rpc_tombstone_ttl_ms" => {
                    cfg.rpc_tombstone_ttl_ms = req_usize(val, key)? as u64
                }
                "allreduce_bucket_bytes" => {
                    cfg.allreduce_bucket_bytes = req_usize(val, key)?
                }
                "heartbeat_interval_ms" => {
                    cfg.heartbeat_interval_ms = req_usize(val, key)? as u64
                }
                "lease_ttl_ms" => cfg.lease_ttl_ms = req_usize(val, key)? as u64,
                "tcp_connect_timeout_ms" => {
                    cfg.tcp_connect_timeout_ms = req_usize(val, key)? as u64
                }
                "tcp_io_timeout_ms" => {
                    cfg.tcp_io_timeout_ms = req_usize(val, key)? as u64
                }
                "recover" => cfg.recover = RecoverPolicy::parse(&req_str(val, key)?)?,
                "max_restarts" => cfg.max_restarts = req_usize(val, key)?,
                "resume_step" => cfg.resume_step = Some(req_usize(val, key)? as u64),
                "coord_epoch" => cfg.coord_epoch = req_usize(val, key)? as u64,
                other => bail!("unknown config key '{other}'"),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn load(path: impl AsRef<Path>) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading config {:?}", path.as_ref()))?;
        Self::from_json(&Json::parse(&text)?)
    }

    /// Serialize to the same JSON schema `from_json` reads — the launcher
    /// uses this to hand a fully-resolved config to `train-worker`
    /// processes.  `from_json(&cfg.to_json()) == cfg` for every valid config.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        let mut put = |k: &str, v: Json| {
            m.insert(k.to_string(), v);
        };
        put("artifacts", Json::Str(self.artifacts.clone()));
        put("world", Json::Num(self.world as f64));
        put("steps", Json::Num(self.steps as f64));
        put("group_size", Json::Num(self.group_size as f64));
        put("lr", Json::Num(self.lr as f64));
        put("sft_lr", Json::Num(self.sft_lr as f64));
        put("clip_eps", Json::Num(self.clip_eps as f64));
        put("kl_coef", Json::Num(self.kl_coef as f64));
        put("ent_coef", Json::Num(self.ent_coef as f64));
        put("temperature", Json::Num(self.temperature as f64));
        put("top_k", Json::Num(self.top_k as f64));
        put(
            "reward",
            Json::Str(
                match self.reward {
                    RewardKind::GroundTruth => "ground_truth",
                    RewardKind::BradleyTerry => "bradley_terry",
                    RewardKind::Generative => "generative",
                }
                .into(),
            ),
        );
        put(
            "verdict_mode",
            Json::Str(
                match self.verdict_mode {
                    VerdictMode::Logit => "logit",
                    VerdictMode::Regex => "regex",
                }
                .into(),
            ),
        );
        put("dynamic_sampling", Json::Bool(self.dynamic_sampling));
        put("max_resample_rounds", Json::Num(self.max_resample_rounds as f64));
        put("kv_page_size", Json::Num(self.kv_page_size as f64));
        put("kv_cache_pages", Json::Num(self.kv_cache_pages as f64));
        put("rollout_cancel", Json::Bool(self.rollout_cancel));
        put("rollout_cancel_grace", Json::Num(self.rollout_cancel_grace as f64));
        put("sft_steps", Json::Num(self.sft_steps as f64));
        put("verifier_sft_steps", Json::Num(self.verifier_sft_steps as f64));
        put("bt_train_steps", Json::Num(self.bt_train_steps as f64));
        // string, not number: f64 can't carry u64 seeds above 2^53 exactly
        put("seed", Json::Str(self.seed.to_string()));
        if let Some(d) = &self.checkpoint_dir {
            put("checkpoint_dir", Json::Str(d.clone()));
        }
        put("checkpoint_every", Json::Num(self.checkpoint_every as f64));
        put(
            "tasks",
            Json::Arr(self.tasks.iter().map(|t| Json::Str(t.clone())).collect()),
        );
        put("collective", Json::Str(self.collective.name().into()));
        put("coordinator_port", Json::Num(self.coordinator_port as f64));
        put("ring_chunk_bytes", Json::Num(self.ring_chunk_bytes as f64));
        put(
            "rpc_tombstone_capacity",
            Json::Num(self.rpc_tombstone_capacity as f64),
        );
        put(
            "rpc_tombstone_ttl_ms",
            Json::Num(self.rpc_tombstone_ttl_ms as f64),
        );
        put(
            "allreduce_bucket_bytes",
            Json::Num(self.allreduce_bucket_bytes as f64),
        );
        put(
            "heartbeat_interval_ms",
            Json::Num(self.heartbeat_interval_ms as f64),
        );
        put("lease_ttl_ms", Json::Num(self.lease_ttl_ms as f64));
        put(
            "tcp_connect_timeout_ms",
            Json::Num(self.tcp_connect_timeout_ms as f64),
        );
        put("tcp_io_timeout_ms", Json::Num(self.tcp_io_timeout_ms as f64));
        put("recover", Json::Str(self.recover.name().into()));
        put("max_restarts", Json::Num(self.max_restarts as f64));
        put("coord_epoch", Json::Num(self.coord_epoch as f64));
        if let Some(s) = self.resume_step {
            put("resume_step", Json::Num(s as f64));
        }
        Json::Obj(m)
    }

    pub fn validate(&self) -> Result<()> {
        if self.world == 0 {
            bail!("world must be >= 1");
        }
        if self.group_size == 0 {
            bail!("group_size must be >= 1");
        }
        if self.tasks.is_empty() {
            bail!("at least one task kind required");
        }
        if self.ring_chunk_bytes < 16 {
            bail!("ring_chunk_bytes must be >= 16");
        }
        if self.rpc_tombstone_capacity == 0 {
            bail!("rpc_tombstone_capacity must be >= 1");
        }
        if self.allreduce_bucket_bytes < 4 {
            bail!("allreduce_bucket_bytes must be >= 4 (one f32 element)");
        }
        if self.kv_page_size == 0 {
            bail!("kv_page_size must be >= 1");
        }
        if self.rollout_cancel && !self.dynamic_sampling {
            bail!("rollout_cancel requires dynamic_sampling (cancelled groups are re-sampled)");
        }
        if self.heartbeat_interval_ms > 0 && self.lease_ttl_ms <= self.heartbeat_interval_ms {
            bail!(
                "lease_ttl_ms ({}) must exceed heartbeat_interval_ms ({}) or every \
                 scheduling hiccup reads as rank death",
                self.lease_ttl_ms,
                self.heartbeat_interval_ms
            );
        }
        Ok(())
    }

    pub fn task_kinds(&self) -> Result<Vec<crate::data::tasks::TaskKind>> {
        use crate::data::tasks::TaskKind;
        self.tasks
            .iter()
            .map(|t| {
                Ok(match t.as_str() {
                    "add" => TaskKind::Add,
                    "max" => TaskKind::Max,
                    "copy" => TaskKind::Copy,
                    "rev" => TaskKind::Rev,
                    other => bail!("unknown task '{other}'"),
                })
            })
            .collect()
    }
}

fn req_str(v: &Json, key: &str) -> Result<String> {
    v.as_str().map(String::from).with_context(|| format!("'{key}' must be string"))
}
fn req_usize(v: &Json, key: &str) -> Result<usize> {
    v.as_usize().with_context(|| format!("'{key}' must be integer"))
}
fn req_f32(v: &Json, key: &str) -> Result<f32> {
    v.as_f64().map(|f| f as f32).with_context(|| format!("'{key}' must be number"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_config() {
        let j = Json::parse(
            r#"{"artifacts":"quickstart","world":2,"steps":100,"group_size":8,
                "lr":0.0005,"reward":"generative","verdict_mode":"regex",
                "dynamic_sampling":true,"tasks":["add","rev"]}"#,
        )
        .unwrap();
        let cfg = RunConfig::from_json(&j).unwrap();
        assert_eq!(cfg.world, 2);
        assert_eq!(cfg.reward, RewardKind::Generative);
        assert_eq!(cfg.verdict_mode, VerdictMode::Regex);
        assert!(cfg.dynamic_sampling);
        assert_eq!(cfg.task_kinds().unwrap().len(), 2);
    }

    #[test]
    fn unknown_key_rejected() {
        let j = Json::parse(r#"{"wrld":2}"#).unwrap();
        let err = RunConfig::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("wrld"), "{err}");
    }

    #[test]
    fn invalid_values_rejected() {
        for bad in [
            r#"{"world":0}"#,
            r#"{"reward":"magic"}"#,
            r#"{"tasks":[]}"#,
            r#"{"tasks":["frobnicate"]}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            let cfg = RunConfig::from_json(&j);
            assert!(
                cfg.is_err() || cfg.unwrap().task_kinds().is_err(),
                "should reject {bad}"
            );
        }
    }

    #[test]
    fn default_is_valid() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn to_json_roundtrips_exactly() {
        let mut cfg = RunConfig {
            artifacts: "quickstart".into(),
            world: 4,
            steps: 7,
            lr: 5e-4,
            reward: RewardKind::Generative,
            verdict_mode: VerdictMode::Regex,
            dynamic_sampling: true,
            checkpoint_dir: Some("/tmp/ckpt".into()),
            checkpoint_every: 3,
            tasks: vec!["add".into(), "rev".into()],
            collective: CollectiveMode::Tcp,
            coordinator_port: 29400,
            // above 2^53: exact only because seeds serialize as strings
            seed: (1u64 << 60) + 3,
            ..RunConfig::default()
        };
        assert_eq!(RunConfig::from_json(&cfg.to_json()).unwrap(), cfg);
        cfg.checkpoint_dir = None;
        assert_eq!(RunConfig::from_json(&cfg.to_json()).unwrap(), cfg);
        // and the default too
        let d = RunConfig::default();
        assert_eq!(RunConfig::from_json(&d.to_json()).unwrap(), d);
    }

    #[test]
    fn rollout_scheduler_knobs_roundtrip_and_validate() {
        let cfg = RunConfig {
            dynamic_sampling: true,
            kv_page_size: 8,
            kv_cache_pages: 64,
            rollout_cancel: true,
            rollout_cancel_grace: 3,
            ..RunConfig::default()
        };
        assert_eq!(RunConfig::from_json(&cfg.to_json()).unwrap(), cfg);
        for bad in [
            r#"{"kv_page_size":0}"#,
            // cancellation without dynamic sampling has no re-sampling path
            r#"{"rollout_cancel":true}"#,
        ] {
            assert!(RunConfig::from_json(&Json::parse(bad).unwrap()).is_err(), "{bad}");
        }
        let j = Json::parse(r#"{"rollout_cancel":true,"dynamic_sampling":true}"#).unwrap();
        assert!(RunConfig::from_json(&j).unwrap().rollout_cancel);
    }

    #[test]
    fn collective_mode_parses() {
        let j = Json::parse(r#"{"collective":"tcp","coordinator_port":29500}"#).unwrap();
        let cfg = RunConfig::from_json(&j).unwrap();
        assert_eq!(cfg.collective, CollectiveMode::Tcp);
        assert_eq!(cfg.coordinator_port, 29500);
        let j = Json::parse(r#"{"collective":"ring","ring_chunk_bytes":4096}"#).unwrap();
        let cfg = RunConfig::from_json(&j).unwrap();
        assert_eq!(cfg.collective, CollectiveMode::Ring);
        assert_eq!(cfg.ring_chunk_bytes, 4096);
        for bad in [
            r#"{"collective":"carrier-pigeon"}"#,
            r#"{"coordinator_port":99999}"#,
            r#"{"ring_chunk_bytes":4}"#,
            r#"{"rpc_tombstone_capacity":0}"#,
        ] {
            assert!(RunConfig::from_json(&Json::parse(bad).unwrap()).is_err(), "{bad}");
        }
    }

    #[test]
    fn ring_and_tombstone_knobs_roundtrip() {
        let cfg = RunConfig {
            collective: CollectiveMode::Ring,
            ring_chunk_bytes: 64 * 1024,
            rpc_tombstone_capacity: 1024,
            rpc_tombstone_ttl_ms: 30_000,
            allreduce_bucket_bytes: 128 * 1024,
            ..RunConfig::default()
        };
        assert_eq!(RunConfig::from_json(&cfg.to_json()).unwrap(), cfg);
    }

    #[test]
    fn fault_tolerance_knobs_roundtrip_and_validate() {
        let cfg = RunConfig {
            heartbeat_interval_ms: 50,
            lease_ttl_ms: 400,
            tcp_connect_timeout_ms: 2_000,
            tcp_io_timeout_ms: 5_000,
            recover: RecoverPolicy::Restart,
            max_restarts: 5,
            resume_step: Some(7),
            coord_epoch: 2,
            ..RunConfig::default()
        };
        assert_eq!(RunConfig::from_json(&cfg.to_json()).unwrap(), cfg);
        // resume_step is omitted when unset, like checkpoint_dir
        let cfg = RunConfig { resume_step: None, ..cfg };
        assert_eq!(RunConfig::from_json(&cfg.to_json()).unwrap(), cfg);
        // a TTL at or below the heartbeat interval is a misconfiguration…
        let bad = r#"{"heartbeat_interval_ms":200,"lease_ttl_ms":200}"#;
        assert!(RunConfig::from_json(&Json::parse(bad).unwrap()).is_err());
        // …but heartbeats off ignores the TTL entirely
        let off = r#"{"heartbeat_interval_ms":0,"lease_ttl_ms":0}"#;
        assert!(RunConfig::from_json(&Json::parse(off).unwrap()).is_ok());
        assert!(RunConfig::from_json(&Json::parse(r#"{"recover":"maybe"}"#).unwrap()).is_err());
        for p in ["none", "restart", "shrink"] {
            assert_eq!(RecoverPolicy::parse(p).unwrap().name(), p);
        }
    }

    #[test]
    fn allreduce_bucket_knob_parses_and_validates() {
        let j = Json::parse(r#"{"allreduce_bucket_bytes":65536,"rpc_tombstone_ttl_ms":500}"#)
            .unwrap();
        let cfg = RunConfig::from_json(&j).unwrap();
        assert_eq!(cfg.allreduce_bucket_bytes, 65536);
        assert_eq!(cfg.rpc_tombstone_ttl_ms, 500);
        // 0 TTL (age expiry disabled) is legal; sub-element buckets are not
        assert!(RunConfig::from_json(&Json::parse(r#"{"rpc_tombstone_ttl_ms":0}"#).unwrap())
            .is_ok());
        assert!(
            RunConfig::from_json(&Json::parse(r#"{"allreduce_bucket_bytes":2}"#).unwrap())
                .is_err()
        );
    }
}
