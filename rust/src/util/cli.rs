//! Tiny argument parser for the `gcore` launcher and examples.
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments (subcommands).  From-scratch replacement for `clap` (not in the
//! offline vendor set).

use std::collections::BTreeMap;
use std::str::FromStr;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    bools: Vec<String>,
}

impl Args {
    pub fn parse_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Args {
        let mut args = Args::default();
        let mut it = items.into_iter().peekable();
        while let Some(item) = it.next() {
            if let Some(stripped) = item.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    args.flags.insert(stripped.to_string(), v);
                } else {
                    args.bools.push(stripped.to_string());
                }
            } else {
                args.positional.push(item);
            }
        }
        args
    }

    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn has(&self, key: &str) -> bool {
        self.bools.iter().any(|b| b == key) || self.flags.contains_key(key)
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn parse_or<T: FromStr>(&self, key: &str, default: T) -> T {
        match self.get(key) {
            Some(v) => v.parse().unwrap_or(default),
            None => default,
        }
    }

    pub fn require(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing required flag --{key}"))
    }

    /// Required flag that must parse (rank/port/address flags of the
    /// distributed launcher).
    pub fn require_parse<T: FromStr>(&self, key: &str) -> anyhow::Result<T>
    where
        T::Err: std::fmt::Display,
    {
        let v = self.require(key)?;
        v.parse()
            .map_err(|e| anyhow::anyhow!("invalid --{key} '{v}': {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn positional_and_flags() {
        let a = mk("train --config configs/e2e.json --steps 100 --verbose");
        assert_eq!(a.subcommand(), Some("train"));
        assert_eq!(a.get("config"), Some("configs/e2e.json"));
        assert_eq!(a.parse_or::<usize>("steps", 0), 100);
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn equals_form() {
        let a = mk("--x=1 --y=a=b");
        assert_eq!(a.get("x"), Some("1"));
        assert_eq!(a.get("y"), Some("a=b"));
    }

    #[test]
    fn trailing_bool_flag() {
        let a = mk("bench --fast");
        assert!(a.has("fast"));
        assert_eq!(a.subcommand(), Some("bench"));
    }

    #[test]
    fn negative_number_as_value() {
        // a value starting with '-' but not '--' is consumed as a value
        let a = mk("--offset -3");
        assert_eq!(a.parse_or::<i64>("offset", 0), -3);
    }

    #[test]
    fn parse_or_falls_back_on_garbage() {
        let a = mk("--n notanumber");
        assert_eq!(a.parse_or::<usize>("n", 42), 42);
    }

    #[test]
    fn require_errors() {
        let a = mk("run");
        assert!(a.require("config").is_err());
    }

    #[test]
    fn require_parse_typed() {
        let a = mk("train-worker --rank 3 --coord 127.0.0.1:29400 --port x");
        assert_eq!(a.require_parse::<usize>("rank").unwrap(), 3);
        let addr: std::net::SocketAddr = a.require_parse("coord").unwrap();
        assert_eq!(addr.port(), 29400);
        assert!(a.require_parse::<u16>("port").is_err(), "garbage must error");
        assert!(a.require_parse::<u16>("absent").is_err());
    }
}
