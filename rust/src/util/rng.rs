//! Deterministic PRNG + the distributions the cluster workload models need.
//!
//! From-scratch replacement for `rand`/`rand_distr` (not available in the
//! offline build): splitmix64-seeded xoshiro256++, Box-Muller normals, and
//! the heavy-tailed distributions (lognormal, Pareto, exponential) the
//! paper's long-tail generation-length traces are drawn from (§3.2).

/// xoshiro256++ with splitmix64 seeding — fast, high-quality, deterministic.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box-Muller output
    spare_normal: Option<f64>,
}

/// The complete serializable state of an [`Rng`] mid-stream: the
/// xoshiro256++ word state plus the cached Box-Muller spare.  Restoring a
/// snapshot resumes the stream at exactly the draw it was captured at —
/// the property checkpoint-resume relies on for bit-identical replay.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RngState {
    pub s: [u64; 4],
    /// the spare normal, bit-encoded (`f64::to_bits`) so the state is
    /// integer-only on the wire; `None` ⇒ no cached draw
    pub spare_normal_bits: Option<u64>,
}

/// lowbias32-style u32 mixer — the counter-based hash the fixture
/// artifacts' `rng-bit-generator` lowering draws from (mirrors
/// `python/compile/fixturegen/modelgen.py::M.hash_u32` exactly; see
/// `runtime/hlo/eval.rs` and the rollout sampler, which must stay
/// bit-identical to the fused graph).
pub fn hash_u32(mut z: u32) -> u32 {
    for (mul, shift) in [(0xED5AD4BBu32, 17), (0xAC4C1B51, 11), (0x31848BAB, 15)] {
        z = (z ^ (z >> shift)).wrapping_mul(mul);
    }
    z ^ (z >> 14)
}

/// Counter base for the rollout sampler's Gumbel stream: the same
/// `seed · 0x9E3779B1` the fused `generate_rollout` graph computes from
/// its scalar seed input.  Advance it by `batch · vocab` after every
/// decoded position (all rows, finished or not — the graph does).
pub fn sampler_base(seed32: u32) -> u32 {
    seed32.wrapping_mul(0x9E3779B1)
}

/// One counter-based Gumbel-max draw — op-for-op the fused
/// `generate_rollout` artifact's in-graph sampler (and
/// `fixturegen/validate.py::_counter_sample`), so the stepwise and
/// scheduler decode paths produce bit-identical tokens to the fused
/// graph under the same seed:
///
/// * element `i` of `row` draws `hash_u32(base + row·V + i)`, mapped to
///   `(0, 1)` via the fixture `(bits >> 8 + 0.5) / 2^24` ladder;
/// * `score = logits / temperature + gumbel(u)`, with the top-k gate
///   thresholded on the *raw* logits (k-th largest, ties kept);
/// * first index wins score ties (the graph reduces max then min-index).
///
/// `temperature <= 0` is an explicit greedy request the stochastic graph
/// cannot express; it keeps the legacy argmax (last index on ties, no
/// counter consumed) so greedy decodes are unchanged.
pub fn counter_sample_logits(
    logits: &[f32],
    temperature: f32,
    top_k: usize,
    base: u32,
    row: usize,
) -> usize {
    assert!(!logits.is_empty());
    let v = logits.len();
    if temperature <= 0.0 {
        let mut best = 0usize;
        for (i, &x) in logits.iter().enumerate() {
            if x >= logits[best] {
                best = i;
            }
        }
        return best;
    }
    let thresh = if top_k > 0 && top_k < v {
        let mut tmp = logits.to_vec();
        tmp.sort_unstable_by(f32::total_cmp);
        Some(tmp[v - top_k])
    } else {
        None
    };
    let row_base = base.wrapping_add((row * v) as u32);
    let mut best = 0usize;
    let mut best_score = f32::NEG_INFINITY;
    for (i, &logit) in logits.iter().enumerate() {
        if let Some(t) = thresh {
            if logit < t {
                continue;
            }
        }
        let bits = hash_u32(row_base.wrapping_add(i as u32));
        let u = ((bits >> 8) as f32 + 0.5) * (1.0 / 16777216.0);
        let gum = -(-u.ln()).ln();
        let score = logit / temperature + gum;
        if score > best_score {
            best = i;
            best_score = score;
        }
    }
    best
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            spare_normal: None,
        }
    }

    /// Derive an independent stream (for per-controller / per-worker RNGs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Snapshot the full mid-stream state (see [`RngState`]).
    pub fn state(&self) -> RngState {
        RngState {
            s: self.s,
            spare_normal_bits: self.spare_normal.map(f64::to_bits),
        }
    }

    /// Rebuild an `Rng` that continues exactly where `state` was captured.
    pub fn from_state(state: RngState) -> Rng {
        Rng {
            s: state.s,
            spare_normal: state.spare_normal_bits.map(f64::from_bits),
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) (Lemire-style rejection-free for our use).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.f64() * n as f64) as usize % n
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller (caches the paired draw).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        let (u1, u2) = (self.f64().max(1e-300), self.f64());
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    pub fn normal_with(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.normal()
    }

    /// Lognormal — the paper's long-tail response-length model.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal_with(mu, sigma).exp()
    }

    /// Pareto(scale, alpha) — the heavier straggler tail.
    pub fn pareto(&mut self, scale: f64, alpha: f64) -> f64 {
        scale / self.f64().max(1e-300).powf(1.0 / alpha)
    }

    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-300).ln() / lambda
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalised non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut t = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Categorical sample from a logits slice with temperature + top-k.
    /// This is the L3 token sampler's core (model::sampler wraps it).
    pub fn sample_logits(&mut self, logits: &[f32], temperature: f32, top_k: usize) -> usize {
        assert!(!logits.is_empty());
        if temperature <= 0.0 {
            // argmax
            return logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
        }
        let k = if top_k == 0 { logits.len() } else { top_k.min(logits.len()) };
        // partial top-k selection
        let mut idx: Vec<usize> = (0..logits.len()).collect();
        idx.select_nth_unstable_by(k - 1, |&a, &b| {
            logits[b].partial_cmp(&logits[a]).unwrap()
        });
        idx.truncate(k);
        let max = idx.iter().map(|&i| logits[i]).fold(f32::MIN, f32::max);
        let weights: Vec<f64> = idx
            .iter()
            .map(|&i| (((logits[i] - max) / temperature) as f64).exp())
            .collect();
        idx[self.weighted(&weights)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_sampler_greedy_keeps_last_max_tie() {
        // temperature <= 0 is a pure argmax with the same last-index
        // tie-break the old per-token sampler had; it must ignore the
        // counter entirely (any base/row give the same pick)
        let logits = [1.0, 3.0, 3.0, 0.5];
        assert_eq!(counter_sample_logits(&logits, 0.0, 2, 123, 0), 2);
        assert_eq!(counter_sample_logits(&logits, 0.0, 2, 999, 7), 2);
    }

    #[test]
    fn counter_sampler_is_a_pure_function_of_base_and_row() {
        let logits = [0.1, -0.4, 2.0, 0.3, 1.1];
        let a = counter_sample_logits(&logits, 0.8, 3, sampler_base(20), 1);
        let b = counter_sample_logits(&logits, 0.8, 3, sampler_base(20), 1);
        assert_eq!(a, b);
        // a different row of the same step reads a disjoint counter window
        let c = counter_sample_logits(&logits, 0.8, 3, sampler_base(20), 2);
        let d = counter_sample_logits(&logits, 0.8, 3, sampler_base(20), 2);
        assert_eq!(c, d);
    }

    #[test]
    fn counter_sampler_top_k_masks_below_threshold() {
        // with top_k=1 only the max logit survives the raw-logit
        // threshold, so the pick is the argmax no matter the gumbel draw
        let logits = [0.0, 5.0, 1.0, -2.0];
        for row in 0..8 {
            assert_eq!(counter_sample_logits(&logits, 1.0, 1, sampler_base(9), row), 1);
        }
        // top_k >= vocab disables the mask: every index must be reachable
        // across enough rows
        let flat = [0.0f32; 6];
        let mut seen = [false; 6];
        for row in 0..512 {
            seen[counter_sample_logits(&flat, 1.0, 6, sampler_base(77), row)] = true;
        }
        assert!(seen.iter().all(|&s| s), "seen={seen:?}");
    }

    #[test]
    fn counter_sampler_threshold_keeps_logit_ties() {
        // the top_k threshold is >= on raw logits, so values tied with
        // the k-th largest stay eligible (mirrors the in-graph compare GE)
        let logits = [2.0, 2.0, 2.0, -1.0];
        let mut seen = [false; 4];
        for row in 0..512 {
            seen[counter_sample_logits(&logits, 1.0, 2, sampler_base(5), row)] = true;
        }
        assert_eq!(seen, [true, true, true, false]);
    }

    #[test]
    fn sampler_base_is_the_fixture_seed_mix() {
        // fixturegen bakes base0 = seed * golden-ratio constant into the
        // fused rollout graph; the host sampler must mix identically
        assert_eq!(sampler_base(1), 0x9E3779B1);
        assert_eq!(sampler_base(2), 0x9E3779B1u32.wrapping_mul(2));
    }

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(8);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(2);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn lognormal_is_positive_and_skewed() {
        let mut r = Rng::new(4);
        let xs: Vec<f64> = (0..50_000).map(|_| r.lognormal(0.0, 1.0)).collect();
        assert!(xs.iter().all(|&x| x > 0.0));
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let mut sorted = xs.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = sorted[xs.len() / 2];
        assert!(mean > median, "lognormal mean should exceed median");
    }

    #[test]
    fn pareto_tail_heavier_than_exponential() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let p99 = |mut xs: Vec<f64>| {
            xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            xs[(n as f64 * 0.99) as usize]
        };
        let pareto: Vec<f64> = (0..n).map(|_| r.pareto(1.0, 1.2)).collect();
        let expo: Vec<f64> = (0..n).map(|_| 1.0 + r.exponential(1.0)).collect();
        // same scale / similar median, much heavier p99
        assert!(p99(pareto) > 2.0 * p99(expo));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn weighted_respects_weights() {
        let mut r = Rng::new(7);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.weighted(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0]);
        let frac2 = counts[2] as f64 / 30_000.0;
        assert!((frac2 - 0.7).abs() < 0.03, "{frac2}");
    }

    #[test]
    fn sample_logits_greedy_and_topk() {
        let mut r = Rng::new(8);
        let logits = vec![0.0f32, 5.0, 1.0, -2.0];
        assert_eq!(r.sample_logits(&logits, 0.0, 0), 1);
        // top_k=1 is greedy regardless of temperature
        for _ in 0..50 {
            assert_eq!(r.sample_logits(&logits, 1.0, 1), 1);
        }
        // top_k=2 only ever yields indices 1 or 2
        for _ in 0..200 {
            let s = r.sample_logits(&logits, 2.0, 2);
            assert!(s == 1 || s == 2, "{s}");
        }
    }

    #[test]
    fn state_snapshot_resumes_bit_identically() {
        let mut r = Rng::new(11);
        for _ in 0..37 {
            r.next_u64();
        }
        r.normal(); // leaves a cached spare so the snapshot carries it
        let snap = r.state();
        assert!(snap.spare_normal_bits.is_some());
        let mut resumed = Rng::from_state(snap);
        for _ in 0..64 {
            assert_eq!(r.normal().to_bits(), resumed.normal().to_bits());
            assert_eq!(r.next_u64(), resumed.next_u64());
        }
    }

    #[test]
    fn fork_streams_diverge() {
        let mut root = Rng::new(9);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }
}
