//! Compact binary codec for the RPC wire format, checkpoints and the KV
//! store.  Little-endian, length-prefixed; no external dependencies.

use anyhow::{bail, Context, Result};

use crate::runtime::tensor::{Tensor, TensorData};
use crate::util::pod;

#[derive(Default)]
pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    pub fn new() -> Writer {
        Writer::default()
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn bytes(&mut self, v: &[u8]) {
        self.u32(v.len() as u32);
        self.buf.extend_from_slice(v);
    }

    pub fn str(&mut self, v: &str) {
        self.bytes(v.as_bytes());
    }

    pub fn f32s(&mut self, v: &[f32]) {
        self.u32(v.len() as u32);
        pod::extend_le_f32(&mut self.buf, v);
    }

    /// Length-prefixed f64 vector — bit-exact (collective scalar reduction).
    pub fn f64s(&mut self, v: &[f64]) {
        self.u32(v.len() as u32);
        pod::extend_le_f64(&mut self.buf, v);
    }

    pub fn i32s(&mut self, v: &[i32]) {
        self.u32(v.len() as u32);
        pod::extend_le_i32(&mut self.buf, v);
    }

    /// Ragged token rows (collective sample exchange / RPC payloads).
    pub fn token_rows(&mut self, rows: &[Vec<i32>]) {
        self.u32(rows.len() as u32);
        for row in rows {
            self.i32s(row);
        }
    }

    pub fn tensor(&mut self, t: &Tensor) {
        let tag: u8 = match &t.data {
            TensorData::F32(_) => 0,
            TensorData::I32(_) => 1,
            TensorData::U32(_) => 2,
        };
        self.u8(tag);
        self.u32(t.shape.len() as u32);
        for &d in &t.shape {
            self.u32(d as u32);
        }
        self.bytes(t.raw_bytes());
    }

    pub fn tensors(&mut self, ts: &[Tensor]) {
        self.u32(ts.len() as u32);
        for t in ts {
            self.tensor(t);
        }
    }
}

// ---------------------------------------------------------------------------
// Chunked streaming frames (ring collective / bounded-buffer transfers)
// ---------------------------------------------------------------------------

/// Number of `chunk`-byte frames needed to stream `len` bytes.  Always >= 1:
/// an empty payload still travels as one empty frame so the receiver learns
/// the (zero) total without a side channel.
pub fn chunk_count(len: usize, chunk: usize) -> usize {
    assert!(chunk > 0, "chunk size must be > 0");
    if len == 0 {
        1
    } else {
        len.div_ceil(chunk)
    }
}

/// Byte range `[lo, hi)` of chunk `index` when streaming `len` bytes in
/// `chunk`-byte frames.  Indices past the end yield empty ranges.
pub fn chunk_range(len: usize, chunk: usize, index: usize) -> (usize, usize) {
    assert!(chunk > 0, "chunk size must be > 0");
    let lo = (index * chunk).min(len);
    let hi = (index * chunk + chunk).min(len);
    (lo, hi)
}

pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!("codec underrun: need {n} bytes at {}", self.pos);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn bytes(&mut self) -> Result<&'a [u8]> {
        let n = self.u32()? as usize;
        self.take(n)
    }

    pub fn str(&mut self) -> Result<String> {
        Ok(std::str::from_utf8(self.bytes()?)
            .context("invalid utf8 in codec string")?
            .to_string())
    }

    pub fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        let raw = self.take(n * 4)?;
        Ok(pod::to_f32_vec(raw))
    }

    pub fn f64s(&mut self) -> Result<Vec<f64>> {
        let n = self.u32()? as usize;
        let raw = self.take(n * 8)?;
        Ok(raw
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    pub fn i32s(&mut self) -> Result<Vec<i32>> {
        let n = self.u32()? as usize;
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    pub fn token_rows(&mut self) -> Result<Vec<Vec<i32>>> {
        let n = self.u32()? as usize;
        (0..n).map(|_| self.i32s()).collect()
    }

    pub fn tensor(&mut self) -> Result<Tensor> {
        let tag = self.u8()?;
        let rank = self.u32()? as usize;
        if rank > 16 {
            bail!("implausible tensor rank {rank}");
        }
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(self.u32()? as usize);
        }
        let raw = self.bytes()?;
        let n: usize = shape.iter().product();
        if raw.len() != n * 4 {
            bail!("tensor payload {} bytes, shape needs {}", raw.len(), n * 4);
        }
        let data = match tag {
            0 => TensorData::F32(pod::to_f32_vec(raw)),
            1 => TensorData::I32(
                raw.chunks_exact(4)
                    .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                    .collect(),
            ),
            2 => TensorData::U32(
                raw.chunks_exact(4)
                    .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
                    .collect(),
            ),
            _ => bail!("unknown tensor dtype tag {tag}"),
        };
        Ok(Tensor { shape, data })
    }

    pub fn tensors(&mut self) -> Result<Vec<Tensor>> {
        let n = self.u32()? as usize;
        (0..n).map(|_| self.tensor()).collect()
    }

    pub fn expect_end(&self) -> Result<()> {
        if self.remaining() != 0 {
            bail!("codec: {} trailing bytes", self.remaining());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_roundtrip() {
        let mut w = Writer::new();
        w.u8(7);
        w.u32(0xDEADBEEF);
        w.u64(u64::MAX);
        w.f32(-1.5);
        w.f64(std::f64::consts::PI);
        w.str("héllo");
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 0xDEADBEEF);
        assert_eq!(r.u64().unwrap(), u64::MAX);
        assert_eq!(r.f32().unwrap(), -1.5);
        assert_eq!(r.f64().unwrap(), std::f64::consts::PI);
        assert_eq!(r.str().unwrap(), "héllo");
        r.expect_end().unwrap();
    }

    #[test]
    fn tensor_roundtrip_all_dtypes() {
        let ts = vec![
            Tensor::f32(vec![2, 2], vec![1., 2., 3., 4.]),
            Tensor::i32(vec![3], vec![-1, 0, 1]),
            Tensor::u32(vec![], vec![9]),
        ];
        let mut w = Writer::new();
        w.tensors(&ts);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        assert_eq!(r.tensors().unwrap(), ts);
        r.expect_end().unwrap();
    }

    #[test]
    fn f64_and_token_rows_roundtrip_bit_exact() {
        let f64s = vec![0.0, -0.0, f64::NAN, f64::INFINITY, 1.5e-300, -7.25];
        let rows = vec![vec![], vec![1, -2, 3], vec![i32::MIN, i32::MAX]];
        let mut w = Writer::new();
        w.f64s(&f64s);
        w.token_rows(&rows);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        let back = r.f64s().unwrap();
        assert_eq!(back.len(), f64s.len());
        for (a, b) in back.iter().zip(&f64s) {
            assert_eq!(a.to_bits(), b.to_bits(), "f64 must roundtrip bit-exactly");
        }
        assert_eq!(r.token_rows().unwrap(), rows);
        r.expect_end().unwrap();
    }

    #[test]
    fn underrun_detected() {
        let mut w = Writer::new();
        w.u32(100); // claims 100 bytes follow
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        assert!(r.bytes().is_err());
    }

    #[test]
    fn chunk_math_covers_payload_exactly() {
        for (len, chunk) in [(0usize, 8usize), (1, 8), (8, 8), (9, 8), (100, 7), (64, 64)] {
            let n = chunk_count(len, chunk);
            assert!(n >= 1, "len {len} chunk {chunk}");
            let mut covered = 0;
            for i in 0..n {
                let (lo, hi) = chunk_range(len, chunk, i);
                assert_eq!(lo, covered, "len {len} chunk {chunk} idx {i}");
                assert!(hi - lo <= chunk);
                covered = hi;
            }
            assert_eq!(covered, len, "chunks must cover the payload exactly");
            // every chunk but the last is full-size
            for i in 0..n.saturating_sub(1) {
                let (lo, hi) = chunk_range(len, chunk, i);
                assert_eq!(hi - lo, chunk);
            }
            // past-the-end indices are empty
            let (lo, hi) = chunk_range(len, chunk, n + 3);
            assert_eq!(lo, hi);
        }
    }

    #[test]
    fn corrupted_tensor_rejected() {
        let mut w = Writer::new();
        w.u8(0);
        w.u32(1);
        w.u32(10); // shape says 10 elements
        w.bytes(&[0u8; 8]); // but only 2 elements of data
        let bytes = w.into_bytes();
        assert!(Reader::new(&bytes).tensor().is_err());
    }
}
