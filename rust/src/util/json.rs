//! Minimal JSON parser/serializer.
//!
//! The build environment vendors only the `xla` dependency closure, so the
//! manifest/config/checkpoint plumbing uses this from-scratch implementation
//! instead of serde_json (DESIGN.md §substrates).  Supports the full JSON
//! grammar (objects, arrays, strings with escapes incl. `\uXXXX`, numbers,
//! bools, null).  Numbers are stored as `f64`; every integer the G-Core
//! manifests emit fits in the 53-bit mantissa.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    // ---- accessors --------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that fails loudly with the missing key name.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key '{key}'"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // ---- builders ---------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn set(&mut self, key: &str, value: Json) {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), value);
        }
    }

    // ---- parse ------------------------------------------------------------

    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let bytes = input.as_bytes();
        let mut p = Parser { bytes, pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- serialize --------------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(1), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

// `to_string()` comes from the `ToString` blanket impl over this Display
// (an inherent `to_string` would shadow it — clippy's
// inherent_to_string_shadow_display).
impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        f.write_str(&s)
    }
}

impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected eof"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let b = self
                .bytes
                .get(self.pos)
                .copied()
                .ok_or_else(|| self.err("unterminated string"))?;
            self.pos += 1;
            match b {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self
                        .bytes
                        .get(self.pos)
                        .copied()
                        .ok_or_else(|| self.err("unterminated escape"))?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| self.err("bad \\u escape"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            // surrogate pairs
                            let c = if (0xD800..0xDC00).contains(&code) {
                                if self.bytes.get(self.pos) == Some(&b'\\')
                                    && self.bytes.get(self.pos + 1) == Some(&b'u')
                                {
                                    let hex2 = self
                                        .bytes
                                        .get(self.pos + 2..self.pos + 6)
                                        .ok_or_else(|| self.err("bad surrogate"))?;
                                    let lo = u32::from_str_radix(
                                        std::str::from_utf8(hex2)
                                            .map_err(|_| self.err("bad surrogate"))?,
                                        16,
                                    )
                                    .map_err(|_| self.err("bad surrogate"))?;
                                    self.pos += 6;
                                    let c = 0x10000
                                        + ((code - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                    char::from_u32(c)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(code)
                            };
                            s.push(c.ok_or_else(|| self.err("invalid codepoint"))?);
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                b if b < 0x80 => s.push(b as char),
                _ => {
                    // multi-byte utf-8: copy raw
                    self.pos -= 1;
                    let start = self.pos;
                    let len = utf8_len(b);
                    let chunk = self
                        .bytes
                        .get(start..start + len)
                        .ok_or_else(|| self.err("bad utf-8"))?;
                    s.push_str(
                        std::str::from_utf8(chunk).map_err(|_| self.err("bad utf-8"))?,
                    );
                    self.pos += len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-1", "3.5", "1e-3", "\"hi\""] {
            let v = Json::parse(s).unwrap();
            let v2 = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, v2, "{s}");
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x\ny"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn parse_unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é😀");
        // and raw multibyte
        let v = Json::parse("\"é😀\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "é😀");
    }

    #[test]
    fn roundtrip_escapes() {
        let s = "line1\nline2\t\"quoted\" \\slash\u{1}";
        let v = Json::Str(s.to_string());
        assert_eq!(Json::parse(&v.to_string()).unwrap().as_str().unwrap(), s);
    }

    #[test]
    fn integers_stay_exact() {
        let v = Json::parse("123456789012345").unwrap();
        assert_eq!(v.to_string(), "123456789012345");
        assert_eq!(v.as_i64().unwrap(), 123456789012345);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn pretty_parses_back() {
        let v = Json::obj(vec![
            ("x", Json::from(vec![1i64, 2, 3])),
            ("y", Json::obj(vec![("z", Json::Null)])),
        ]);
        assert_eq!(Json::parse(&v.to_string_pretty()).unwrap(), v);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap().to_string(), "{}");
        assert_eq!(Json::parse("[ ]").unwrap(), Json::Arr(vec![]));
    }
}
