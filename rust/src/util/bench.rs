//! Micro-benchmark harness (criterion is not in the offline vendor set).
//!
//! Provides warmup + calibrated measurement loops, robust statistics, and a
//! markdown table printer.  The `rust/benches/e*_*.rs` binaries (registered
//! with `harness = false`) use this to regenerate the paper-shaped tables
//! that EXPERIMENTS.md records.

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p90: Duration,
    pub p95: Duration,
    pub p99: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl BenchResult {
    pub fn mean_ns(&self) -> f64 {
        self.mean.as_nanos() as f64
    }

    pub fn p50_ns(&self) -> f64 {
        self.p50.as_nanos() as f64
    }

    pub fn p90_ns(&self) -> f64 {
        self.p90.as_nanos() as f64
    }

    pub fn p99_ns(&self) -> f64 {
        self.p99.as_nanos() as f64
    }

    pub fn throughput_per_sec(&self) -> f64 {
        1e9 / self.mean_ns().max(1.0)
    }
}

/// Human-readable wall-clock duration ("500 ns", "1.50 ms") — also the
/// renderer behind `bench::Metric::DurationNs`.
pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Run `f` repeatedly for ~`budget`, after `warmup` untimed iterations.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, budget: Duration, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<Duration> = Vec::new();
    let start = Instant::now();
    while start.elapsed() < budget || samples.len() < 5 {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
        if samples.len() >= 1_000_000 {
            break;
        }
    }
    summarize(name, samples)
}

/// Fixed-iteration variant for expensive bodies.
pub fn bench_n<F: FnMut()>(name: &str, iters: usize, mut f: F) -> BenchResult {
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    summarize(name, samples)
}

fn summarize(name: &str, mut samples: Vec<Duration>) -> BenchResult {
    assert!(!samples.is_empty());
    samples.sort();
    let n = samples.len();
    let total: Duration = samples.iter().sum();
    let pct = |q: f64| samples[(n as f64 * q) as usize % n];
    BenchResult {
        name: name.to_string(),
        iters: n,
        mean: total / n as u32,
        p50: samples[n / 2],
        p90: pct(0.90),
        p95: pct(0.95),
        p99: pct(0.99),
        min: samples[0],
        max: samples[n - 1],
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Items-per-second throughput, guarded against a zero wall clock (timer
/// granularity on very fast runs) — the Egen tokens/s column.
pub fn per_sec(n: usize, wall_secs: f64) -> f64 {
    n as f64 / wall_secs.max(1e-12)
}

/// Markdown table over results — the bench binaries' standard output format.
pub fn print_table(title: &str, results: &[BenchResult]) {
    println!("\n### {title}\n");
    println!("| case | iters | mean | p50 | p90 | p95 | p99 | min | max |");
    println!("|---|---|---|---|---|---|---|---|---|");
    for r in results {
        println!(
            "| {} | {} | {} | {} | {} | {} | {} | {} | {} |",
            r.name,
            r.iters,
            fmt_dur(r.mean),
            fmt_dur(r.p50),
            fmt_dur(r.p90),
            fmt_dur(r.p95),
            fmt_dur(r.p99),
            fmt_dur(r.min),
            fmt_dur(r.max),
        );
    }
}

/// Generic markdown table for paper-shaped (non-timing) tables, returned
/// as a string so callers can print it, log it, or assert on it
/// (`gcore hlo-lint` builds its diagnostics table through this).
pub fn format_rows(title: &str, header: &[&str], rows: &[Vec<String>]) -> String {
    let mut out = format!("\n### {title}\n\n");
    out.push_str(&format!("| {} |\n", header.join(" | ")));
    out.push_str(&format!(
        "|{}|\n",
        header.iter().map(|_| "---").collect::<Vec<_>>().join("|")
    ));
    for row in rows {
        out.push_str(&format!("| {} |\n", row.join(" | ")));
    }
    out
}

/// Generic markdown table printer for paper-shaped (non-timing) tables.
pub fn print_rows(title: &str, header: &[&str], rows: &[Vec<String>]) {
    print!("{}", format_rows(title, header, rows));
}

/// Human-readable byte count (the hlo-lint peak-live-bytes column).
pub fn fmt_bytes(b: usize) -> String {
    if b < 1024 {
        format!("{b} B")
    } else if b < 1024 * 1024 {
        format!("{:.1} KiB", b as f64 / 1024.0)
    } else {
        format!("{:.2} MiB", b as f64 / (1024.0 * 1024.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let r = bench("noop", 10, Duration::from_millis(20), || {
            black_box(1 + 1);
        });
        assert!(r.iters >= 5);
        assert!(r.min <= r.p50 && r.p50 <= r.p90);
        assert!(r.p90 <= r.p95 && r.p95 <= r.p99 && r.p99 <= r.max);
        assert!(r.mean.as_nanos() > 0);
    }

    #[test]
    fn percentiles_over_known_samples() {
        // 1..=100 ms, one of each: the index rule picks p50=51ms (upper
        // median), p90=91ms, p95=96ms, p99=100ms.
        let samples: Vec<Duration> = (1..=100u64).map(Duration::from_millis).collect();
        let r = summarize("known", samples);
        assert_eq!(r.iters, 100);
        assert_eq!(r.p50, Duration::from_millis(51));
        assert_eq!(r.p90, Duration::from_millis(91));
        assert_eq!(r.p95, Duration::from_millis(96));
        assert_eq!(r.p99, Duration::from_millis(100));
        assert_eq!(r.min, Duration::from_millis(1));
        assert_eq!(r.max, Duration::from_millis(100));
        assert_eq!(r.mean, Duration::from_micros(50_500));
    }

    #[test]
    fn bench_n_counts() {
        let mut count = 0;
        let r = bench_n("count", 37, || count += 1);
        assert_eq!(count, 37);
        assert_eq!(r.iters, 37);
    }

    #[test]
    fn per_sec_guards_zero_wall() {
        assert_eq!(per_sec(100, 2.0), 50.0);
        assert!(per_sec(1, 0.0).is_finite());
    }

    #[test]
    fn byte_and_row_formatting() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.0 KiB");
        assert_eq!(fmt_bytes(3 << 20), "3.00 MiB");
        let t = format_rows("T", &["a", "b"], &[vec!["1".into(), "2".into()]]);
        assert!(t.contains("### T"));
        assert!(t.contains("| a | b |"));
        assert!(t.contains("| 1 | 2 |"));
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_dur(Duration::from_nanos(500)), "500 ns");
        assert!(fmt_dur(Duration::from_micros(1500)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(2)).contains(" s"));
    }
}
