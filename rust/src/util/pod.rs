//! Safe POD slice reinterpretation for the collective data plane.
//!
//! The gradient hot path used to round-trip every f32 through 4-byte
//! `to_le_bytes`/`from_le_bytes` calls (encode, decode, and every
//! `ReduceOp::combine`).  These helpers expose the underlying storage as
//! byte slices (always safe for the POD element types used here) and, on
//! little-endian targets with aligned buffers, view wire bytes directly as
//! element slices — turning the per-element byte fiddling into
//! memcpy-/SIMD-friendly slice operations.  Misaligned or big-endian
//! buffers fall back to the per-element decode, so results are identical
//! everywhere; only the speed differs.
//!
//! This is the crate's sole module containing unsafe code (lib.rs pins
//! that inventory with `deny(unsafe_op_in_unsafe_fn)`): every unsafe
//! block here is a POD slice reinterpretation with a local SAFETY note,
//! wrapped in a safe API.

/// `&[f32]` viewed as raw bytes (native order — little-endian on every
/// supported target, which is also the wire order).
pub fn f32_as_bytes(v: &[f32]) -> &[u8] {
    // SAFETY: f32 is POD; any byte pattern is a valid u8.
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, std::mem::size_of_val(v)) }
}

/// `&[f64]` viewed as raw bytes.
pub fn f64_as_bytes(v: &[f64]) -> &[u8] {
    // SAFETY: as above.
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, std::mem::size_of_val(v)) }
}

/// `&[i32]` viewed as raw bytes.
pub fn i32_as_bytes(v: &[i32]) -> &[u8] {
    // SAFETY: as above.
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, std::mem::size_of_val(v)) }
}

/// `&[u32]` viewed as raw bytes.
pub fn u32_as_bytes(v: &[u32]) -> &[u8] {
    // SAFETY: as above.
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, std::mem::size_of_val(v)) }
}

/// View little-endian wire bytes as `&[f32]` when the buffer is aligned
/// and this target is little-endian; `None` sends the caller down the
/// per-element fallback.
pub fn bytes_as_f32(bytes: &[u8]) -> Option<&[f32]> {
    if !cfg!(target_endian = "little") || bytes.len() % 4 != 0 {
        return None;
    }
    // SAFETY: every bit pattern is a valid f32; alignment is checked below.
    let (pre, mid, post) = unsafe { bytes.align_to::<f32>() };
    if pre.is_empty() && post.is_empty() {
        Some(mid)
    } else {
        None
    }
}

/// Mutable variant of [`bytes_as_f32`].
pub fn bytes_as_f32_mut(bytes: &mut [u8]) -> Option<&mut [f32]> {
    if !cfg!(target_endian = "little") || bytes.len() % 4 != 0 {
        return None;
    }
    // SAFETY: as above.
    let (pre, mid, post) = unsafe { bytes.align_to_mut::<f32>() };
    if pre.is_empty() && post.is_empty() {
        Some(mid)
    } else {
        None
    }
}

/// View little-endian wire bytes as `&[f64]` (aligned, LE target only).
pub fn bytes_as_f64(bytes: &[u8]) -> Option<&[f64]> {
    if !cfg!(target_endian = "little") || bytes.len() % 8 != 0 {
        return None;
    }
    // SAFETY: every bit pattern is a valid f64; alignment is checked below.
    let (pre, mid, post) = unsafe { bytes.align_to::<f64>() };
    if pre.is_empty() && post.is_empty() {
        Some(mid)
    } else {
        None
    }
}

/// Mutable variant of [`bytes_as_f64`].
pub fn bytes_as_f64_mut(bytes: &mut [u8]) -> Option<&mut [f64]> {
    if !cfg!(target_endian = "little") || bytes.len() % 8 != 0 {
        return None;
    }
    // SAFETY: as above.
    let (pre, mid, post) = unsafe { bytes.align_to_mut::<f64>() };
    if pre.is_empty() && post.is_empty() {
        Some(mid)
    } else {
        None
    }
}

/// Append `v` to `buf` as little-endian bytes — one `memcpy` on LE targets.
pub fn extend_le_f32(buf: &mut Vec<u8>, v: &[f32]) {
    if cfg!(target_endian = "little") {
        buf.extend_from_slice(f32_as_bytes(v));
    } else {
        for x in v {
            buf.extend_from_slice(&x.to_le_bytes());
        }
    }
}

/// Append `v` to `buf` as little-endian bytes (f64).
pub fn extend_le_f64(buf: &mut Vec<u8>, v: &[f64]) {
    if cfg!(target_endian = "little") {
        buf.extend_from_slice(f64_as_bytes(v));
    } else {
        for x in v {
            buf.extend_from_slice(&x.to_le_bytes());
        }
    }
}

/// Append `v` to `buf` as little-endian bytes (i32).
pub fn extend_le_i32(buf: &mut Vec<u8>, v: &[i32]) {
    if cfg!(target_endian = "little") {
        buf.extend_from_slice(i32_as_bytes(v));
    } else {
        for x in v {
            buf.extend_from_slice(&x.to_le_bytes());
        }
    }
}

/// Copy little-endian f32 `bytes` into `dst` without allocating.
/// Panics if lengths disagree (callers validate first).
pub fn copy_le_f32(bytes: &[u8], dst: &mut [f32]) {
    assert_eq!(bytes.len(), dst.len() * 4, "byte/element length mismatch");
    match bytes_as_f32(bytes) {
        Some(src) => dst.copy_from_slice(src),
        None => {
            for (x, c) in dst.iter_mut().zip(bytes.chunks_exact(4)) {
                *x = f32::from_le_bytes(c.try_into().unwrap());
            }
        }
    }
}

/// Decode little-endian f32 bytes into a fresh vector (one allocation,
/// one memcpy on the aligned fast path).
pub fn to_f32_vec(bytes: &[u8]) -> Vec<f32> {
    assert_eq!(bytes.len() % 4, 0, "byte length not a multiple of 4");
    let mut out = vec![0.0f32; bytes.len() / 4];
    copy_le_f32(bytes, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_views_roundtrip() {
        let v = [1.0f32, -2.5, f32::MIN_POSITIVE, 0.0];
        let bytes = f32_as_bytes(&v);
        assert_eq!(bytes.len(), 16);
        let expect: Vec<u8> = v.iter().flat_map(|x| x.to_le_bytes()).collect();
        assert_eq!(bytes, &expect[..]);
        assert_eq!(i32_as_bytes(&[-1i32]), &[0xFF, 0xFF, 0xFF, 0xFF]);
        assert_eq!(u32_as_bytes(&[1u32]), &[1, 0, 0, 0]);
        assert_eq!(f64_as_bytes(&[0.5f64]), &0.5f64.to_le_bytes());
    }

    #[test]
    fn aligned_cast_and_misaligned_fallback_agree() {
        let v: Vec<f32> = (0..33).map(|i| i as f32 * 0.25 - 3.0).collect();
        let mut buf = Vec::new();
        extend_le_f32(&mut buf, &v);
        // aligned (Vec base pointers are at least 4-aligned in practice;
        // when not, the cast simply reports None and the copy still works)
        let mut back = vec![0.0f32; v.len()];
        copy_le_f32(&buf, &mut back);
        assert_eq!(back, v);
        // force a misaligned view: shift by one byte and decode a prefix
        let mut shifted = vec![0u8];
        shifted.extend_from_slice(&buf[..32]);
        assert!(bytes_as_f32(&shifted[1..]).is_none() || cfg!(not(target_endian = "little")));
        let mut back2 = vec![0.0f32; 8];
        copy_le_f32(&shifted[1..], &mut back2);
        assert_eq!(&back2[..], &v[..8]);
    }

    #[test]
    fn mutable_views_write_through() {
        let v = [1.0f32, 2.0, 3.0];
        let mut buf = Vec::new();
        extend_le_f32(&mut buf, &v);
        if let Some(s) = bytes_as_f32_mut(&mut buf) {
            for x in s.iter_mut() {
                *x *= 2.0;
            }
            assert_eq!(to_f32_vec(&buf), vec![2.0, 4.0, 6.0]);
        }
        let d = [0.25f64, -0.5];
        let mut buf64 = Vec::new();
        extend_le_f64(&mut buf64, &d);
        if let Some(s) = bytes_as_f64_mut(&mut buf64) {
            s[0] += 0.25;
        }
        if let Some(s) = bytes_as_f64(&buf64) {
            assert_eq!(s, &[0.5, -0.5]);
        }
    }

    #[test]
    fn length_mismatches_rejected() {
        assert!(bytes_as_f32(&[0u8; 5]).is_none());
        assert!(bytes_as_f64(&[0u8; 12]).is_none());
        let mut b = [0u8; 6];
        assert!(bytes_as_f32_mut(&mut b).is_none());
    }

    #[test]
    fn i32_extend_matches_per_element() {
        let v = [i32::MIN, -1, 0, 7, i32::MAX];
        let mut fast = Vec::new();
        extend_le_i32(&mut fast, &v);
        let slow: Vec<u8> = v.iter().flat_map(|x| x.to_le_bytes()).collect();
        assert_eq!(fast, slow);
    }
}
