//! From-scratch utility substrates (the offline vendor set contains only the
//! `xla` closure, so JSON / RNG / CLI / bench / property-testing are built
//! here — see DESIGN.md §substrates).

pub mod bench;
pub mod cli;
pub mod json;
pub mod codec;
pub mod pod;
pub mod prop;
pub mod rng;
