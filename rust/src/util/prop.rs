//! Seeded property-test runner (proptest is not in the offline vendor set).
//!
//! `check` runs a property over N generated cases; on failure it reports the
//! failing case seed so the run can be reproduced exactly with
//! `GCORE_PROP_SEED=<seed> cargo test <name>`.  No shrinking — cases are
//! kept small by construction instead (DESIGN.md §testing).

use super::rng::Rng;

/// Number of cases per property (override with GCORE_PROP_CASES).
pub fn default_cases() -> u64 {
    std::env::var("GCORE_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(256)
}

/// Run `property(rng)` over `default_cases()` seeds; panic with the
/// failing seed.
pub fn check<F>(name: &str, property: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    check_n(name, default_cases(), property)
}

/// `check` with an explicit case count — for properties whose cases are
/// expensive (thread groups, transports) and need a smaller default than
/// the global one.  `GCORE_PROP_SEED` replay and `GCORE_PROP_CASES`
/// override still apply (the env override wins when smaller).
pub fn check_n<F>(name: &str, cases: u64, property: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    if let Ok(seed) = std::env::var("GCORE_PROP_SEED") {
        let seed: u64 = seed.parse().expect("GCORE_PROP_SEED must be u64");
        let mut rng = Rng::new(seed);
        if let Err(msg) = property(&mut rng) {
            panic!("property '{name}' failed on replayed seed {seed}: {msg}");
        }
        return;
    }
    let cases = cases.min(default_cases());
    for case in 0..cases {
        // decorrelate case seeds; keep them printable/replayable
        let seed = 0x9E3779B97F4A7C15u64
            .wrapping_mul(case + 1)
            .wrapping_add(name.len() as u64);
        let mut rng = Rng::new(seed);
        if let Err(msg) = property(&mut rng) {
            panic!(
                "property '{name}' failed on case {case}/{cases} \
                 (replay with GCORE_PROP_SEED={seed}): {msg}"
            );
        }
    }
}

/// Assert helper producing property-style errors.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check("tautology", |rng| {
            let x = rng.below(100);
            prop_assert!(x < 100, "x={x}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "GCORE_PROP_SEED=")]
    fn failing_property_reports_seed() {
        check("always-fails", |rng| {
            let x = rng.below(10);
            prop_assert!(x > 100, "x={x} is not > 100");
            Ok(())
        });
    }
}
