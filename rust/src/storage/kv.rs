//! Log-structured key-value store — the FeatureKV/UnionDB analogue (§4.6).
//!
//! The paper stores massive multimodal training data in private KV stores
//! because "storing massive numbers of images directly in a distributed
//! file system can easily exceed file number quota".  This store keeps the
//! same property: **one append-only segment file** holds any number of
//! records; an in-memory index maps key → (offset, len).  Crash recovery
//! replays the log (corrupt/truncated tails are dropped); `compact`
//! rewrites live records and drops tombstones.
//!
//! Record layout: [u32 klen][key][u32 vlen | TOMBSTONE][value][u32 crc]
//! (crc over key+value, FNV-1a folded to 32 bits — self-contained).

use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

const TOMBSTONE: u32 = u32::MAX;

fn checksum(key: &[u8], value: &[u8]) -> u32 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in key.iter().chain(value.iter()) {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    (h ^ (h >> 32)) as u32
}

pub struct KvStore {
    path: PathBuf,
    writer: BufWriter<File>,
    /// key → (value offset, value len); offset points at the value bytes
    index: BTreeMap<String, (u64, u32)>,
    log_end: u64,
    pub stats: KvStats,
}

#[derive(Debug, Clone, Default)]
pub struct KvStats {
    pub puts: u64,
    pub gets: u64,
    pub deletes: u64,
    pub recovered_records: u64,
    pub dropped_tail_bytes: u64,
}

impl KvStore {
    /// Open (or create) a store backed by one segment file.
    pub fn open(path: impl AsRef<Path>) -> Result<KvStore> {
        let path = path.as_ref().to_path_buf();
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent).ok();
        }
        let mut stats = KvStats::default();
        let (index, log_end) = Self::recover(&path, &mut stats)?;
        let mut file = OpenOptions::new().create(true).append(true).open(&path)?;
        // if recovery dropped a corrupt tail, truncate it away
        let actual = file.metadata()?.len();
        if actual > log_end {
            stats.dropped_tail_bytes = actual - log_end;
            file.set_len(log_end)?;
            file.seek(SeekFrom::End(0))?;
        }
        Ok(KvStore { path, writer: BufWriter::new(file), index, log_end, stats })
    }

    fn recover(
        path: &Path,
        stats: &mut KvStats,
    ) -> Result<(BTreeMap<String, (u64, u32)>, u64)> {
        let mut index = BTreeMap::new();
        let Ok(mut file) = File::open(path) else {
            return Ok((index, 0));
        };
        let mut buf = Vec::new();
        file.read_to_end(&mut buf)?;
        let mut pos: usize = 0;
        let mut valid_end: usize = 0;
        loop {
            let rec_start = pos;
            let Some(klen) = read_u32(&buf, &mut pos) else { break };
            let Some(key) = read_bytes(&buf, &mut pos, klen as usize) else { break };
            let Some(vlen) = read_u32(&buf, &mut pos) else { break };
            if vlen == TOMBSTONE {
                let Some(crc) = read_u32(&buf, &mut pos) else { break };
                if crc != checksum(key, &[]) {
                    break;
                }
                let key = String::from_utf8_lossy(key).to_string();
                index.remove(&key);
            } else {
                let voff = pos as u64;
                let Some(value) = read_bytes(&buf, &mut pos, vlen as usize) else {
                    break;
                };
                let Some(crc) = read_u32(&buf, &mut pos) else { break };
                if crc != checksum(key, value) {
                    break;
                }
                let key = String::from_utf8_lossy(key).to_string();
                index.insert(key, (voff, vlen));
            }
            stats.recovered_records += 1;
            valid_end = pos;
            let _ = rec_start;
        }
        Ok((index, valid_end as u64))
    }

    pub fn len(&self) -> usize {
        self.index.len()
    }

    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }

    pub fn contains(&self, key: &str) -> bool {
        self.index.contains_key(key)
    }

    pub fn put(&mut self, key: &str, value: &[u8]) -> Result<()> {
        if key.len() >= TOMBSTONE as usize || value.len() >= TOMBSTONE as usize {
            bail!("key/value too large");
        }
        let kb = key.as_bytes();
        self.writer.write_all(&(kb.len() as u32).to_le_bytes())?;
        self.writer.write_all(kb)?;
        self.writer.write_all(&(value.len() as u32).to_le_bytes())?;
        let voff = self.log_end + 4 + kb.len() as u64 + 4;
        self.writer.write_all(value)?;
        self.writer.write_all(&checksum(kb, value).to_le_bytes())?;
        self.writer.flush()?;
        self.log_end = voff + value.len() as u64 + 4;
        self.index.insert(key.to_string(), (voff, value.len() as u32));
        self.stats.puts += 1;
        Ok(())
    }

    pub fn get(&mut self, key: &str) -> Result<Option<Vec<u8>>> {
        self.stats.gets += 1;
        let Some(&(off, len)) = self.index.get(key) else {
            return Ok(None);
        };
        let mut file = File::open(&self.path).context("reopening segment")?;
        file.seek(SeekFrom::Start(off))?;
        let mut out = vec![0u8; len as usize];
        file.read_exact(&mut out)?;
        Ok(Some(out))
    }

    pub fn delete(&mut self, key: &str) -> Result<bool> {
        self.stats.deletes += 1;
        if !self.index.contains_key(key) {
            return Ok(false);
        }
        let kb = key.as_bytes();
        self.writer.write_all(&(kb.len() as u32).to_le_bytes())?;
        self.writer.write_all(kb)?;
        self.writer.write_all(&TOMBSTONE.to_le_bytes())?;
        self.writer.write_all(&checksum(kb, &[]).to_le_bytes())?;
        self.writer.flush()?;
        self.log_end += 4 + kb.len() as u64 + 4 + 4;
        self.index.remove(key);
        Ok(true)
    }

    /// Keys with a prefix (e.g. all shards of one sample).
    pub fn scan_prefix(&self, prefix: &str) -> Vec<String> {
        self.index
            .range(prefix.to_string()..)
            .take_while(|(k, _)| k.starts_with(prefix))
            .map(|(k, _)| k.clone())
            .collect()
    }

    /// Rewrite live records into a fresh segment, dropping garbage.
    pub fn compact(&mut self) -> Result<()> {
        let tmp = self.path.with_extension("compact");
        {
            let file = OpenOptions::new()
                .create(true)
                .write(true)
                .truncate(true)
                .open(&tmp)?;
            let mut w = BufWriter::new(file);
            let keys: Vec<String> = self.index.keys().cloned().collect();
            let mut new_index = BTreeMap::new();
            let mut off: u64 = 0;
            for key in keys {
                let value = self.get(&key)?.expect("indexed key must exist");
                let kb = key.as_bytes();
                w.write_all(&(kb.len() as u32).to_le_bytes())?;
                w.write_all(kb)?;
                w.write_all(&(value.len() as u32).to_le_bytes())?;
                let voff = off + 4 + kb.len() as u64 + 4;
                w.write_all(&value)?;
                w.write_all(&checksum(kb, &value).to_le_bytes())?;
                off = voff + value.len() as u64 + 4;
                new_index.insert(key, (voff, value.len() as u32));
            }
            w.flush()?;
            self.index = new_index;
            self.log_end = off;
        }
        std::fs::rename(&tmp, &self.path)?;
        let mut file = OpenOptions::new().append(true).open(&self.path)?;
        file.seek(SeekFrom::End(0))?;
        self.writer = BufWriter::new(file);
        Ok(())
    }

    pub fn file_size(&self) -> u64 {
        self.log_end
    }
}

fn read_u32(buf: &[u8], pos: &mut usize) -> Option<u32> {
    let b = buf.get(*pos..*pos + 4)?;
    *pos += 4;
    Some(u32::from_le_bytes(b.try_into().unwrap()))
}

fn read_bytes<'a>(buf: &'a [u8], pos: &mut usize, n: usize) -> Option<&'a [u8]> {
    let b = buf.get(*pos..*pos + n)?;
    *pos += n;
    Some(b)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("gcore_kv_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(format!("{name}_{}.kv", std::process::id()));
        std::fs::remove_file(&p).ok();
        p
    }

    #[test]
    fn put_get_roundtrip() {
        let mut kv = KvStore::open(tmp("roundtrip")).unwrap();
        kv.put("a", b"alpha").unwrap();
        kv.put("b", &vec![7u8; 10_000]).unwrap();
        assert_eq!(kv.get("a").unwrap().unwrap(), b"alpha");
        assert_eq!(kv.get("b").unwrap().unwrap().len(), 10_000);
        assert_eq!(kv.get("missing").unwrap(), None);
    }

    #[test]
    fn overwrite_returns_latest() {
        let mut kv = KvStore::open(tmp("overwrite")).unwrap();
        kv.put("k", b"v1").unwrap();
        kv.put("k", b"v2").unwrap();
        assert_eq!(kv.get("k").unwrap().unwrap(), b"v2");
        assert_eq!(kv.len(), 1);
    }

    #[test]
    fn delete_then_recover() {
        let path = tmp("delete");
        {
            let mut kv = KvStore::open(&path).unwrap();
            kv.put("keep", b"1").unwrap();
            kv.put("drop", b"2").unwrap();
            kv.delete("drop").unwrap();
        }
        let mut kv = KvStore::open(&path).unwrap();
        assert_eq!(kv.get("keep").unwrap().unwrap(), b"1");
        assert_eq!(kv.get("drop").unwrap(), None);
    }

    #[test]
    fn recovery_drops_corrupt_tail() {
        let path = tmp("corrupt");
        {
            let mut kv = KvStore::open(&path).unwrap();
            kv.put("good", b"data").unwrap();
        }
        // append garbage (simulates a crash mid-write)
        {
            let mut f = OpenOptions::new().append(true).open(&path).unwrap();
            f.write_all(&[0xAB; 7]).unwrap();
        }
        let mut kv = KvStore::open(&path).unwrap();
        assert_eq!(kv.get("good").unwrap().unwrap(), b"data");
        assert!(kv.stats.dropped_tail_bytes > 0);
        // store still writable after recovery
        kv.put("new", b"x").unwrap();
        assert_eq!(kv.get("new").unwrap().unwrap(), b"x");
    }

    #[test]
    fn scan_prefix_ordered() {
        let mut kv = KvStore::open(tmp("scan")).unwrap();
        kv.put("img/1", b"a").unwrap();
        kv.put("img/2", b"b").unwrap();
        kv.put("txt/1", b"c").unwrap();
        assert_eq!(kv.scan_prefix("img/"), vec!["img/1", "img/2"]);
        assert_eq!(kv.scan_prefix("zzz").len(), 0);
    }

    #[test]
    fn compact_shrinks_file_and_preserves_data() {
        let path = tmp("compact");
        let mut kv = KvStore::open(&path).unwrap();
        for i in 0..50 {
            kv.put("churn", format!("version {i}").as_bytes()).unwrap();
        }
        kv.put("stable", b"here").unwrap();
        let before = kv.file_size();
        kv.compact().unwrap();
        assert!(kv.file_size() < before / 2, "{} -> {}", before, kv.file_size());
        assert_eq!(kv.get("churn").unwrap().unwrap(), b"version 49");
        assert_eq!(kv.get("stable").unwrap().unwrap(), b"here");
        // still writable after compaction
        kv.put("post", b"compact").unwrap();
        assert_eq!(kv.get("post").unwrap().unwrap(), b"compact");
    }

    #[test]
    fn many_records_one_file() {
        // the paper's point: thousands of records never create new files
        let path = tmp("many");
        let mut kv = KvStore::open(&path).unwrap();
        for i in 0..2000 {
            kv.put(&format!("rec/{i:05}"), &[i as u8; 64]).unwrap();
        }
        assert_eq!(kv.len(), 2000);
        assert_eq!(kv.scan_prefix("rec/").len(), 2000);
        // exactly one backing file
        assert!(path.exists());
    }
}
