//! Elastic, checkpointable dataloader (paper §4.3):
//!
//! > "we utilize distributed checkpointing and design the dataloader
//! >  consumption state such that checkpoints can be reused across GPU
//! >  clusters of varying sizes."
//!
//! The consumption state is **global** — (seed, epoch, cursor) over a
//! deterministic per-epoch permutation — and ranks carve their slice of
//! each global batch at read time.  Resuming the same state with a
//! different world size replays exactly the unconsumed suffix, in order,
//! with no sample lost or duplicated (property-tested).

use anyhow::{bail, Result};

use crate::util::codec::{Reader, Writer};
use crate::util::rng::Rng;

/// Serializable consumption state.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LoaderState {
    pub seed: u64,
    pub epoch: u64,
    /// samples consumed within the current epoch
    pub cursor: usize,
}

#[derive(Debug, Clone)]
pub struct Dataloader {
    n_samples: usize,
    global_batch: usize,
    state: LoaderState,
    /// permutation of the current epoch (derived, not stored)
    order: Vec<usize>,
}

impl Dataloader {
    pub fn new(n_samples: usize, global_batch: usize, seed: u64) -> Dataloader {
        assert!(n_samples > 0 && global_batch > 0 && global_batch <= n_samples);
        let state = LoaderState { seed, epoch: 0, cursor: 0 };
        let order = Self::epoch_order(n_samples, seed, 0);
        Dataloader { n_samples, global_batch, state, order }
    }

    pub fn resume(n_samples: usize, global_batch: usize, state: LoaderState) -> Dataloader {
        let order = Self::epoch_order(n_samples, state.seed, state.epoch);
        Dataloader { n_samples, global_batch, state, order }
    }

    fn epoch_order(n: usize, seed: u64, epoch: u64) -> Vec<usize> {
        let mut rng = Rng::new(seed ^ epoch.wrapping_mul(0x9E3779B97F4A7C15));
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        order
    }

    pub fn state(&self) -> LoaderState {
        self.state.clone()
    }

    pub fn epoch(&self) -> u64 {
        self.state.epoch
    }

    /// The next **global** batch of sample indices (advances the cursor;
    /// wraps to a new epoch/permutation when exhausted).
    pub fn next_global_batch(&mut self) -> Vec<usize> {
        if self.state.cursor + self.global_batch > self.n_samples {
            self.state.epoch += 1;
            self.state.cursor = 0;
            self.order = Self::epoch_order(self.n_samples, self.state.seed, self.state.epoch);
        }
        let start = self.state.cursor;
        self.state.cursor += self.global_batch;
        self.order[start..start + self.global_batch].to_vec()
    }

    /// A rank's slice of a global batch — the elastic carve: works for any
    /// world size that divides the global batch.
    pub fn rank_slice(global_batch: &[usize], rank: usize, world: usize) -> Result<Vec<usize>> {
        if world == 0 || rank >= world {
            bail!("bad rank {rank} / world {world}");
        }
        if global_batch.len() % world != 0 {
            bail!(
                "global batch {} not divisible by world size {world}",
                global_batch.len()
            );
        }
        let per = global_batch.len() / world;
        Ok(global_batch[rank * per..(rank + 1) * per].to_vec())
    }
}

impl LoaderState {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        w.u64(self.seed);
        w.u64(self.epoch);
        w.u64(self.cursor as u64);
        w.into_bytes()
    }

    pub fn decode(bytes: &[u8]) -> Result<LoaderState> {
        let mut r = Reader::new(bytes);
        let s = LoaderState {
            seed: r.u64()?,
            epoch: r.u64()?,
            cursor: r.u64()? as usize,
        };
        r.expect_end()?;
        Ok(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn batches_partition_epoch() {
        let mut dl = Dataloader::new(100, 10, 1);
        let mut seen = Vec::new();
        for _ in 0..10 {
            seen.extend(dl.next_global_batch());
        }
        seen.sort_unstable();
        assert_eq!(seen, (0..100).collect::<Vec<_>>());
        assert_eq!(dl.epoch(), 0);
        dl.next_global_batch();
        assert_eq!(dl.epoch(), 1);
    }

    #[test]
    fn epochs_reshuffle() {
        let mut dl = Dataloader::new(50, 50, 2);
        let e0 = dl.next_global_batch();
        let e1 = dl.next_global_batch();
        assert_ne!(e0, e1);
        let mut s0 = e0.clone();
        let mut s1 = e1.clone();
        s0.sort_unstable();
        s1.sort_unstable();
        assert_eq!(s0, s1);
    }

    #[test]
    fn state_roundtrip() {
        let mut dl = Dataloader::new(64, 8, 3);
        dl.next_global_batch();
        dl.next_global_batch();
        let enc = dl.state().encode();
        assert_eq!(LoaderState::decode(&enc).unwrap(), dl.state());
    }

    #[test]
    fn resume_replays_exact_suffix() {
        let mut dl = Dataloader::new(96, 12, 7);
        for _ in 0..3 {
            dl.next_global_batch();
        }
        let state = dl.state();
        let expected: Vec<Vec<usize>> = (0..6).map(|_| dl.next_global_batch()).collect();
        let mut resumed = Dataloader::resume(96, 12, state);
        let actual: Vec<Vec<usize>> = (0..6).map(|_| resumed.next_global_batch()).collect();
        assert_eq!(expected, actual);
    }

    #[test]
    fn elastic_resume_across_world_sizes() {
        // the paper's elasticity claim: consume with world=4, resume with
        // world=2 — the union of rank slices is identical either way.
        prop::check("elastic-dataloader", |rng| {
            let n = 32 + rng.below(8) * 16;
            let gb = 16;
            let seed = rng.next_u64();
            let consumed = rng.below(2 * n / gb);
            let mut dl = Dataloader::new(n, gb, seed);
            for _ in 0..consumed {
                dl.next_global_batch();
            }
            let state = dl.state();

            let collect = |world: usize, state: LoaderState| -> Vec<usize> {
                let mut dl = Dataloader::resume(n, gb, state);
                let mut all = Vec::new();
                for _ in 0..3 {
                    let batch = dl.next_global_batch();
                    for r in 0..world {
                        all.extend(Dataloader::rank_slice(&batch, r, world).unwrap());
                    }
                }
                all
            };
            let w4 = collect(4, state.clone());
            let w2 = collect(2, state.clone());
            let w8 = collect(8, state);
            crate::prop_assert!(w4 == w2 && w2 == w8, "world-size changed the stream");
            Ok(())
        });
    }

    #[test]
    fn rank_slices_partition_batch() {
        prop::check("rank-slices-partition", |rng| {
            let world = [1, 2, 4, 8][rng.below(4)];
            let gb: Vec<usize> = (0..16).map(|_| rng.below(1000)).collect();
            let mut union = Vec::new();
            for r in 0..world {
                union.extend(Dataloader::rank_slice(&gb, r, world).unwrap());
            }
            crate::prop_assert!(union == gb, "slices must partition in order");
            Ok(())
        });
    }

    #[test]
    fn bad_rank_and_indivisible_world_rejected() {
        let gb: Vec<usize> = (0..10).collect();
        assert!(Dataloader::rank_slice(&gb, 3, 3).is_err());
        assert!(Dataloader::rank_slice(&gb, 0, 3).is_err()); // 10 % 3 != 0
        assert!(Dataloader::rank_slice(&gb, 0, 0).is_err());
    }
}
