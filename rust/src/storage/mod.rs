//! Training-data storage substrate (paper §4.6): log-structured KV store
//! (FeatureKV/UnionDB analogue) + elastic checkpointable dataloader (§4.3).

pub mod dataloader;
pub mod kv;

pub use dataloader::{Dataloader, LoaderState};
pub use kv::KvStore;
