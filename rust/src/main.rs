//! `gcore` — the G-Core reproduction launcher.
//!
//! Subcommands:
//!   train              run RLHF training (config file or flags; in-proc or
//!                      TCP-loopback collectives via --collective)
//!   train-dist         multi-process training: hosts the rendezvous
//!                      service and spawns one worker process per rank
//!   train-worker       one rank of a train-dist job (internal)
//!   bench run          regenerate experiment tables (DESIGN.md §4) and
//!                      ingest every numeric cell into the bench database
//!   bench report       per-series trend tables over recorded commits
//!   bench gate         CI regression gate over the bench database
//!   bench bless        accept an intentional regression (baseline reset)
//!   simulate           run a placement simulation (colocate/coexist/dynamic)
//!   inspect-artifacts  print the manifest of an artifact set
//!   hlo-lint           statically verify an artifact set's HLO (shape/dtype
//!                      inference, def-use, manifest I/O contract) and print
//!                      the per-artifact analysis table; nonzero exit on any
//!                      diagnostic
//!   help

use std::net::SocketAddr;

use anyhow::{bail, Context, Result};

use gcore::checkpoint::CheckpointManager;
use gcore::config::{CollectiveMode, RecoverPolicy, RunConfig};
use gcore::experiments;
use gcore::launch::{self, TrainReport};
use gcore::placement::{run_coexist_static, run_colocate, run_dynamic, PlacementSpec};
use gcore::runtime::Manifest;
use gcore::util::cli::Args;
use gcore::util::json::Json;

const USAGE: &str = "\
gcore — G-Core RLHF trainer (reproduction)

USAGE:
  gcore train [--config <file.json>] [--artifacts tiny] [--world N]
              [--steps N] [--reward ground_truth|bt|generative]
              [--dynamic-sampling] [--checkpoint-dir DIR]
              [--collective inproc|tcp|ring] [--ring-chunk-bytes N]
              [--tombstone-capacity N] [--tombstone-ttl-ms N]
              [--allreduce-bucket-bytes N]
              [--kv-page-size N] [--kv-cache-pages N]
              [--rollout-cancel] [--rollout-cancel-grace N]
              (rollout scheduler: KV page geometry / pool size; --rollout-cancel
              preempts long-tail stragglers once a round has enough accepted
              rollouts — requires --dynamic-sampling)
  gcore train-dist [same flags as train] [--coord-port P]
              [--recover none|restart|shrink] [--max-restarts N]
              [--heartbeat-interval-ms N] [--lease-ttl-ms N]
              [--tcp-connect-timeout-ms N] [--tcp-io-timeout-ms N]
              spawns N=world OS processes; --collective tcp funnels
              collectives through the rank-0 rendezvous, --collective ring
              streams chunked frames rank-to-rank (bootstrap via the
              rendezvous, then O(payload)/rank; rank 0 prints the report).
              Workers heartbeat the rendezvous host; a rank silent past the
              lease TTL is declared dead and every survivor fails fast with
              a typed PeerDead status.  --recover restart respawns the job
              from the latest COMPLETE checkpoint (bit-identical replay);
              --recover shrink renegotiates the world down to a divisor.
              GCORE_CHAOS=kill:rank=R,step=S injects a one-shot crash
  gcore bench run [<id>... | all] [--full] [--json out.json] [--db FILE]
              [--commit SHA]
              regenerate experiment tables (ids: e1 e2 e3 e4 e5 e7 e8 e8c
              e9 e9a egen einterp echaos), print them, optionally write the JSON
              artifact, and ingest every numeric cell into the bench
              database (default db: .gcore-bench-db.jsonl; commit resolves
              from --commit, $GCORE_COMMIT, $GITHUB_SHA, then git)
  gcore bench report [--label L] [--format table|dat|latex] [--window K]
              [--db FILE] [--out FILE]
              per-series trend tables (per-commit medians) over the bench
              database; L matches an experiment label exactly or as a
              'L/...' prefix
  gcore bench gate [--threshold-pct N] [--window K] [--commit SHA]
              [--db FILE]
              exits nonzero when any directed metric regresses more than
              N% (default 10) against the rolling median of the last K
              (default 5) prior commits; series with no history bootstrap-
              pass
  gcore bench bless [--scope S] [--commit SHA] [--db FILE]
              accept an intentional regression: gate baselines restart at
              samples recorded after the bless (S empty = everything, else
              an experiment label or label prefix)
  gcore bench [--full] [--json out.json] [--db FILE]
              same as `gcore bench run all` (tables + DB ingest)
  gcore bench <id|all> [--full] [--json out.json]
              deprecated pre-subcommand spelling: still runs, but skips
              DB ingest and warns; use `gcore bench run <id>`
  gcore simulate [--placement colocate|coexist|dynamic] [--devices N]
                 [--steps N] [--dapo]
  gcore inspect-artifacts [--artifacts tiny]
  gcore hlo-lint [<artifacts-dir>] [--artifacts tiny]
              statically verify every artifact in the set (shape/dtype
              inference, def-use, reduce contracts, manifest I/O) and print
              instruction counts, unsupported-op and fusible-chain reports,
              and the static peak-live-bytes bound; exits nonzero if any
              diagnostic fires or decode_step exceeds the 3 MiB/token
              allocation budget asserted in tests/alloc_counts.rs
";

fn main() -> Result<()> {
    let args = Args::parse_env();
    match args.subcommand() {
        Some("train") => cmd_train(&args),
        Some("train-dist") => cmd_train_dist(&args),
        Some("train-worker") => cmd_train_worker(&args),
        Some("bench") => cmd_bench(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("inspect-artifacts") => cmd_inspect(&args),
        Some("hlo-lint") => cmd_hlo_lint(&args),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

/// Resolve a RunConfig from `--config` plus flag overrides (shared by
/// `train` and `train-dist`).
fn cfg_from_args(args: &Args) -> Result<RunConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => RunConfig::load(path)?,
        None => RunConfig::default(),
    };
    if let Some(a) = args.get("artifacts") {
        cfg.artifacts = a.to_string();
    }
    cfg.world = args.parse_or("world", cfg.world);
    cfg.steps = args.parse_or("steps", cfg.steps);
    cfg.sft_steps = args.parse_or("sft-steps", cfg.sft_steps);
    cfg.group_size = args.parse_or("group-size", cfg.group_size);
    cfg.lr = args.parse_or("lr", cfg.lr);
    cfg.seed = args.parse_or("seed", cfg.seed);
    cfg.coordinator_port = args.parse_or("coord-port", cfg.coordinator_port);
    cfg.ring_chunk_bytes = args.parse_or("ring-chunk-bytes", cfg.ring_chunk_bytes);
    cfg.rpc_tombstone_capacity =
        args.parse_or("tombstone-capacity", cfg.rpc_tombstone_capacity);
    cfg.rpc_tombstone_ttl_ms = args.parse_or("tombstone-ttl-ms", cfg.rpc_tombstone_ttl_ms);
    cfg.allreduce_bucket_bytes =
        args.parse_or("allreduce-bucket-bytes", cfg.allreduce_bucket_bytes);
    cfg.kv_page_size = args.parse_or("kv-page-size", cfg.kv_page_size);
    cfg.kv_cache_pages = args.parse_or("kv-cache-pages", cfg.kv_cache_pages);
    cfg.rollout_cancel_grace = args.parse_or("rollout-cancel-grace", cfg.rollout_cancel_grace);
    cfg.heartbeat_interval_ms =
        args.parse_or("heartbeat-interval-ms", cfg.heartbeat_interval_ms);
    cfg.lease_ttl_ms = args.parse_or("lease-ttl-ms", cfg.lease_ttl_ms);
    cfg.tcp_connect_timeout_ms =
        args.parse_or("tcp-connect-timeout-ms", cfg.tcp_connect_timeout_ms);
    cfg.tcp_io_timeout_ms = args.parse_or("tcp-io-timeout-ms", cfg.tcp_io_timeout_ms);
    cfg.max_restarts = args.parse_or("max-restarts", cfg.max_restarts);
    if let Some(r) = args.get("recover") {
        cfg.recover = RecoverPolicy::parse(r)?;
    }
    if let Some(s) = args.get("resume-step") {
        cfg.resume_step =
            Some(s.parse().context("--resume-step must be a checkpoint step number")?);
    }
    if args.has("rollout-cancel") {
        cfg.rollout_cancel = true;
    }
    if args.has("dynamic-sampling") {
        cfg.dynamic_sampling = true;
    }
    if let Some(c) = args.get("collective") {
        cfg.collective = CollectiveMode::parse(c)?;
    }
    if let Some(r) = args.get("reward") {
        cfg.reward = match r {
            "ground_truth" => gcore::reward::RewardKind::GroundTruth,
            "bt" | "bradley_terry" => gcore::reward::RewardKind::BradleyTerry,
            "generative" | "genrm" => gcore::reward::RewardKind::Generative,
            other => bail!("unknown reward '{other}'"),
        };
    }
    if let Some(d) = args.get("checkpoint-dir") {
        cfg.checkpoint_dir = Some(d.to_string());
        if cfg.checkpoint_every == 0 {
            cfg.checkpoint_every = 10;
        }
    }
    cfg.validate()?;
    Ok(cfg)
}

fn print_report(report: &TrainReport) {
    println!("\nstep | loss | kl | reward | accuracy | gen_len | rounds");
    println!("-----|------|----|--------|----------|---------|-------");
    for s in &report.steps {
        println!(
            "{:>4} | {:>6.4} | {:>6.4} | {:>5.3} | {:>5.3} | {:>6.1} | {:>4.1}",
            s.step, s.loss, s.kl, s.mean_reward, s.accuracy, s.mean_gen_len, s.gen_rounds
        );
    }
    println!(
        "\neval accuracy: before RLHF {:.3} → after {:.3}",
        report.eval_before, report.eval_after
    );
    println!("\nstage timers:\n{}", report.timers_markdown);
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = cfg_from_args(args)?;
    println!(
        "[gcore] training: artifacts={} world={} steps={} reward={:?} dapo={} collective={}",
        cfg.artifacts,
        cfg.world,
        cfg.steps,
        cfg.reward,
        cfg.dynamic_sampling,
        cfg.collective.name()
    );
    let report = launch::run_training(&cfg)?;
    print_report(&report);
    Ok(())
}

/// The shrink policy's new world size: the largest proper divisor, so the
/// surviving group keeps a balanced share of the old rank layout.
fn shrink_world(world: usize) -> Option<usize> {
    (1..world).rev().find(|w| world % w == 0)
}

/// Elastic `train-dist` supervisor: run attempts until one succeeds, the
/// restart budget runs out, or the recover policy says give up.  Every
/// recovery bumps the rendezvous epoch (frames from not-yet-dead processes
/// of the old generation are rejected as stale) and resumes from the
/// latest checkpoint step for which EVERY rank's shard landed.
fn cmd_train_dist(args: &Args) -> Result<()> {
    let mut cfg = cfg_from_args(args)?;
    println!(
        "[gcore] train-dist: world={} artifacts={} collective={} recover={}",
        cfg.world,
        cfg.artifacts,
        cfg.collective.name(),
        cfg.recover.name()
    );

    // hand each worker the fully-resolved config (rewritten per attempt:
    // recovery changes epoch / resume-step / possibly world)
    let dir = std::env::temp_dir().join(format!("gcore_dist_{}", std::process::id()));
    std::fs::create_dir_all(&dir)?;
    let cfg_path = dir.join("run.json");
    let exe = std::env::current_exe().context("locating gcore binary")?;

    let mut restarts_left = cfg.max_restarts;
    let mut recovering = false;
    let result = loop {
        match train_dist_attempt(&cfg, &cfg_path, &exe, recovering) {
            Ok(()) => break Ok(()),
            Err(err) if cfg.recover != RecoverPolicy::None && restarts_left > 0 => {
                restarts_left -= 1;
                recovering = true;
                cfg.coord_epoch += 1;
                if cfg.recover == RecoverPolicy::Shrink {
                    match shrink_world(cfg.world) {
                        Some(w) => {
                            println!(
                                "[gcore] train-dist: shrinking world {} -> {w}",
                                cfg.world
                            );
                            cfg.world = w;
                        }
                        None => break Err(err.context("cannot shrink a world of 1")),
                    }
                }
                // resume only from a step where ALL (new-)world shards
                // landed; no complete checkpoint ⇒ restart from scratch
                cfg.resume_step = cfg
                    .checkpoint_dir
                    .as_ref()
                    .and_then(|d| CheckpointManager::new(d).latest_complete_step(cfg.world));
                println!(
                    "[gcore] train-dist: attempt failed ({err:#}); recovering via {} at \
                     epoch {} from {} ({} restart(s) left)",
                    cfg.recover.name(),
                    cfg.coord_epoch,
                    match cfg.resume_step {
                        Some(s) => format!("checkpoint step {s}"),
                        None => "scratch (no complete checkpoint)".to_string(),
                    },
                    restarts_left
                );
            }
            Err(err) => break Err(err),
        }
    };
    std::fs::remove_dir_all(&dir).ok();
    result
}

/// One generation of a `train-dist` job: host the rendezvous (with the
/// current epoch + heartbeat leases), spawn every rank, reap in completion
/// order, and kill the survivors the moment anything fails (§4.2).
fn train_dist_attempt(
    cfg: &RunConfig,
    cfg_path: &std::path::Path,
    exe: &std::path::Path,
    suppress_chaos: bool,
) -> Result<()> {
    // the parent hosts the rendezvous service every worker coordinates
    // through (for --collective ring it is only the address bootstrap);
    // workers are full OS processes that never share memory
    let host = launch::serve_coordinator(
        cfg.world,
        cfg.coordinator_port,
        cfg.rpc_tombstone_capacity,
        cfg.rpc_tombstone_ttl_ms,
        cfg.coord_epoch,
        if cfg.heartbeat_interval_ms > 0 { cfg.lease_ttl_ms } else { 0 },
    )?;
    let addr = host.addr;
    println!(
        "[gcore] train-dist: coordinator={addr} epoch={}{}",
        cfg.coord_epoch,
        cfg.resume_step
            .map(|s| format!(" resume-step={s}"))
            .unwrap_or_default()
    );
    std::fs::write(cfg_path, cfg.to_json().to_string())?;

    let mut slots: Vec<Option<(usize, std::process::Child)>> = Vec::new();

    // Everything that can fail after the first spawn runs in this closure so
    // a mid-flight error (spawn failure, wait error, worker failure) always
    // reaches the cleanup below — no orphaned workers.
    let result = (|| -> Result<()> {
        for rank in 0..cfg.world {
            let mut cmd = std::process::Command::new(exe);
            cmd.arg("train-worker")
                .arg("--config")
                .arg(cfg_path)
                .arg("--rank")
                .arg(rank.to_string())
                .arg("--coord")
                .arg(addr.to_string());
            if suppress_chaos {
                // an injected one-shot crash (GCORE_CHAOS) must not
                // re-fire in the respawned generation — it would kill the
                // same rank at the same step forever
                cmd.env_remove("GCORE_CHAOS");
            }
            let child =
                cmd.spawn().with_context(|| format!("spawning worker {rank}"))?;
            slots.push(Some((rank, child)));
        }

        // Reap workers in completion order (not rank order): the first
        // failure — whichever rank it is — ends the job immediately, instead
        // of the surviving ranks stalling in a collective until its round
        // timeout and the parent blaming the wrong worker.
        let mut remaining = slots.len();
        while remaining > 0 {
            let mut progressed = false;
            for slot in slots.iter_mut() {
                let finished = match slot {
                    Some((rank, child)) => child
                        .try_wait()
                        .with_context(|| format!("waiting on worker {rank}"))?
                        .map(|status| (*rank, status)),
                    None => None,
                };
                if let Some((rank, status)) = finished {
                    *slot = None;
                    remaining -= 1;
                    progressed = true;
                    if !status.success() {
                        // decode the typed collective status the worker's
                        // exit code carries (launch::worker_exit_code)
                        let reason = launch::describe_worker_exit(status.code())
                            .map(|d| format!(": {d}"))
                            .unwrap_or_default();
                        bail!(
                            "worker {rank} failed ({status}){reason} — job \
                             terminated (fail-fast, §4.2)"
                        );
                    }
                }
            }
            if !progressed {
                std::thread::sleep(std::time::Duration::from_millis(50));
            }
        }
        Ok(())
    })();

    // fail fast (§4.2): one dead worker dooms the job — kill the rest
    for slot in slots.iter_mut().flatten() {
        slot.1.kill().ok();
        slot.1.wait().ok();
    }
    drop(host);
    result
}

fn cmd_train_worker(args: &Args) -> Result<()> {
    let cfg = RunConfig::load(args.require("config")?)?;
    let rank: usize = args.require_parse("rank")?;
    let coord: SocketAddr = args.require_parse("coord")?;
    if rank >= cfg.world {
        bail!("rank {rank} out of range for world {}", cfg.world);
    }
    match launch::run_worker(&cfg, rank, coord) {
        Ok(report) => {
            if rank == 0 {
                print_report(&report);
            }
            Ok(())
        }
        Err(err) => {
            // typed collective statuses become stable exit codes the parent
            // matches on (fail-fast, §4.2)
            eprintln!("[gcore] worker {rank} failed: {err:#}");
            std::process::exit(launch::worker_exit_code(&err));
        }
    }
}

/// Every experiment id `bench run all` expands to.
const BENCH_IDS: &[&str] = &[
    "e1", "e2", "e3", "e4", "e5", "e7", "e8", "e8c", "e9", "e9a", "egen", "einterp", "echaos",
];

/// Where bench samples accumulate unless `--db` says otherwise; CI caches
/// this file per branch so the gate sees a rolling commit history.
const DEFAULT_DB: &str = ".gcore-bench-db.jsonl";

fn cmd_bench(args: &Args) -> Result<()> {
    match args.positional.get(1).map(|s| s.as_str()) {
        Some("run") => bench_run(args),
        Some("report") => bench_report(args),
        Some("gate") => bench_gate(args),
        Some("bless") => bench_bless(args),
        // bare `gcore bench` means `bench run all` — the modern path with
        // DB ingest.  Only an explicit pre-subcommand id spelling
        // (`gcore bench e1`) takes the deprecated no-ingest path.
        None => bench_run(args),
        Some(which) => bench_legacy(args, which),
    }
}

fn expand_ids<'a>(ids: &[&'a str]) -> Result<Vec<&'a str>> {
    let mut out: Vec<&str> = Vec::new();
    for id in ids {
        if *id == "all" {
            out.extend_from_slice(BENCH_IDS);
        } else if BENCH_IDS.contains(id) {
            out.push(id);
        } else {
            bail!("unknown experiment '{id}' (e6/e10 are examples: genrm_vs_bt, rlhf_e2e)")
        }
    }
    Ok(out)
}

fn run_experiments<'a>(
    ids: &[&'a str],
    quick: bool,
) -> Result<Vec<(&'a str, experiments::Table)>> {
    let mut tables = Vec::new();
    for id in ids {
        match experiments::run(id, quick) {
            Some(t) => tables.push((*id, t)),
            None => bail!("experiment '{id}' failed to run"),
        }
    }
    Ok(tables)
}

/// Machine-readable results (the CI bench-smoke job uploads this file as
/// a workflow artifact, so perf trajectory is captured on every PR).
fn write_bench_json(args: &Args, tables: &[(&str, experiments::Table)]) -> Result<()> {
    if let Some(path) = args.get("json") {
        let doc = Json::Arr(tables.iter().map(|(_, t)| t.to_json()).collect());
        std::fs::write(path, doc.to_string_pretty())
            .with_context(|| format!("writing bench results to {path}"))?;
        println!("[gcore] wrote {} table(s) to {path}", tables.len());
    }
    Ok(())
}

/// The commit every ingested sample and every gate verdict is keyed by:
/// `--commit`, then $GCORE_COMMIT, then $GITHUB_SHA (both truncated to 12
/// chars), then `git rev-parse`, then the "local" sentinel.
fn resolve_commit(args: &Args) -> String {
    fn short12(s: &str) -> String {
        s.trim().chars().take(12).collect()
    }
    if let Some(c) = args.get("commit") {
        return c.to_string();
    }
    for var in ["GCORE_COMMIT", "GITHUB_SHA"] {
        if let Ok(c) = std::env::var(var) {
            if !c.trim().is_empty() {
                return short12(&c);
            }
        }
    }
    if let Ok(out) =
        std::process::Command::new("git").args(["rev-parse", "--short=12", "HEAD"]).output()
    {
        if out.status.success() {
            let c = String::from_utf8_lossy(&out.stdout).trim().to_string();
            if !c.is_empty() {
                return c;
            }
        }
    }
    "local".to_string()
}

fn now_unix() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}

/// `bench run <id>... `: run the tables, print them, write the optional
/// JSON artifact, and ingest every numeric cell into the bench database.
fn bench_run(args: &Args) -> Result<()> {
    let quick = !args.has("full");
    let raw: Vec<&str> = if args.positional.len() > 2 {
        args.positional[2..].iter().map(|s| s.as_str()).collect()
    } else {
        vec!["all"]
    };
    let ids = expand_ids(&raw)?;
    let tables = run_experiments(&ids, quick)?;
    write_bench_json(args, &tables)?;

    let db_path = args.get_or("db", DEFAULT_DB);
    let commit = resolve_commit(args);
    let ts = now_unix();
    let mut db = gcore::bench::BenchDb::open(db_path)?;
    let mut ingested = 0;
    for (id, t) in &tables {
        ingested +=
            gcore::bench::ingest_table(&mut db, id, t, experiments::key_columns(id), &commit, ts)?;
    }
    println!(
        "[gcore] bench run: ingested {ingested} sample(s) at commit {commit} into {db_path}"
    );
    Ok(())
}

/// The pre-subcommand spelling `gcore bench <id|all>`: still runs, never
/// ingests (so ad-hoc local runs don't pollute a cached CI database).
fn bench_legacy(args: &Args, which: &str) -> Result<()> {
    eprintln!(
        "[gcore] warning: `gcore bench {which}` is deprecated; use `gcore bench run {which}` \
         (and `bench report` / `bench gate` for trends and CI gating)"
    );
    let ids = expand_ids(&[which])?;
    let tables = run_experiments(&ids, !args.has("full"))?;
    write_bench_json(args, &tables)
}

fn bench_report(args: &Args) -> Result<()> {
    let db = gcore::bench::BenchDb::open(args.get_or("db", DEFAULT_DB))?;
    let format = gcore::bench::ReportFormat::parse(args.get_or("format", "table"))?;
    let window: usize = args.parse_or("window", 5);
    let rendered = gcore::bench::render_report(&db, args.get("label"), format, window);
    match args.get("out") {
        Some(path) => {
            std::fs::write(path, &rendered)
                .with_context(|| format!("writing bench report to {path}"))?;
            println!("[gcore] wrote bench report to {path}");
        }
        None => print!("{rendered}"),
    }
    Ok(())
}

fn bench_gate(args: &Args) -> Result<()> {
    let db_path = args.get_or("db", DEFAULT_DB);
    let db = gcore::bench::BenchDb::open(db_path)?;
    let threshold: f64 = args.parse_or("threshold-pct", 10.0);
    let window: usize = args.parse_or("window", 5);
    let commit = resolve_commit(args);
    let report = gcore::bench::gate(&db, &commit, threshold, window);

    let rows: Vec<Vec<String>> = report
        .series
        .iter()
        .map(|s| {
            vec![
                s.label.clone(),
                s.metric.clone(),
                s.direction.as_str().to_string(),
                s.baseline.map(|b| format!("{b:.4}")).unwrap_or_else(|| "-".to_string()),
                format!("{:.4}", s.current),
                s.regression_pct.map(|r| format!("{r:+.1}%")).unwrap_or_else(|| "-".to_string()),
                s.baseline_commits.to_string(),
                s.verdict.as_str().to_string(),
            ]
        })
        .collect();
    print!(
        "{}",
        gcore::util::bench::format_rows(
            &format!(
                "bench gate: commit {commit} vs rolling median of up to {window} prior \
                 commit(s), threshold {threshold}%"
            ),
            &[
                "series",
                "metric",
                "dir",
                "baseline",
                "current",
                "regression",
                "base commits",
                "verdict",
            ],
            &rows,
        )
    );

    if report.series.is_empty() {
        println!(
            "[gcore] bench gate: no samples recorded at commit {commit} in {db_path} — \
             nothing to gate (bootstrap pass)"
        );
        return Ok(());
    }
    let failures = report.failures();
    if !failures.is_empty() {
        for s in &failures {
            eprintln!(
                "[gcore] bench gate FAIL: {} [{}] regressed {:.1}% (current {:.4} vs baseline \
                 {:.4} over {} commit(s), threshold {threshold}%)",
                s.label,
                s.metric,
                s.regression_pct.unwrap_or(f64::NAN),
                s.current,
                s.baseline.unwrap_or(f64::NAN),
                s.baseline_commits,
            );
        }
        bail!(
            "bench gate: {} of {} series regressed more than {threshold}% at commit {commit} \
             (use `gcore bench bless` to accept an intentional regression)",
            failures.len(),
            report.series.len()
        );
    }
    println!(
        "[gcore] bench gate: {} series pass at commit {commit} (threshold {threshold}%, \
         window {window})",
        report.series.len()
    );
    Ok(())
}

fn bench_bless(args: &Args) -> Result<()> {
    let mut db = gcore::bench::BenchDb::open(args.get_or("db", DEFAULT_DB))?;
    let scope = args.get_or("scope", "");
    let commit = resolve_commit(args);
    db.bless(scope, &commit, now_unix())?;
    let what = if scope.is_empty() {
        "all series".to_string()
    } else {
        format!("scope '{scope}'")
    };
    println!(
        "[gcore] bench bless: {what} re-baselined at commit {commit} — the gate only \
         considers samples recorded after this bless"
    );
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let mut spec = PlacementSpec::paper_like();
    spec.n_devices = args.parse_or("devices", spec.n_devices);
    spec.steps = args.parse_or("steps", spec.steps);
    spec.batch = args.parse_or("batch", spec.batch);
    spec.dynamic_sampling = args.has("dapo");
    if spec.dynamic_sampling {
        spec.accept.p0 = 0.5;
    }
    let placement = args.get_or("placement", "dynamic");
    let report = match placement {
        "colocate" => run_colocate(&spec),
        "coexist" => run_coexist_static(&spec, args.parse_or("gen-frac", 0.5)),
        "dynamic" => {
            let d = run_dynamic(&spec);
            println!("ratio trace (step, gen_frac, util_gen, util_reward):");
            for (s, fr, ug, ur) in d.trace.iter().step_by((d.trace.len() / 12).max(1)) {
                println!("  {s:>4}  {fr:.3}  {ug:.3}  {ur:.3}");
            }
            d.report
        }
        other => bail!("unknown placement '{other}'"),
    };
    println!(
        "\n{placement}: makespan {:.0}s  util {:.1}%  swap {:.0} dev-s  bubble {:.0} dev-s  ({:.0} samples/h)",
        report.makespan_s,
        report.utilization * 100.0,
        report.swap_s,
        report.bubble_s,
        report.samples_per_hour()
    );
    Ok(())
}

/// Per-decode-step allocation budget asserted dynamically by
/// tests/alloc_counts.rs; the lint cross-checks the *static* peak-live
/// bound against the same number so planner/allocator drift fails here.
const DECODE_STEP_BUDGET: usize = 3 << 20;

fn cmd_hlo_lint(args: &Args) -> Result<()> {
    use gcore::runtime::hlo::verify::{lint_set, DiagKind};
    use gcore::util::bench::{fmt_bytes, format_rows};

    let dir = match args.positional.get(1) {
        Some(p) => std::path::PathBuf::from(p),
        None => gcore::runtime::artifacts_dir(args.get_or("artifacts", "tiny")),
    };
    let report =
        lint_set(&dir).with_context(|| format!("linting artifact set at {dir:?}"))?;

    let mut rows = Vec::new();
    let mut over_budget = Vec::new();
    for a in &report.artifacts {
        let unsupported = a
            .diagnostics
            .iter()
            .filter(|d| d.kind == DiagKind::UnsupportedOp)
            .count();
        let (chains, peak) = match &a.plan {
            Some(p) => (p.fusible_chains.len().to_string(), fmt_bytes(p.peak_live_bytes)),
            None => ("-".to_string(), "-".to_string()),
        };
        if a.name == "decode_step" {
            if let Some(p) = &a.plan {
                if p.peak_live_bytes > DECODE_STEP_BUDGET {
                    over_budget.push(format!(
                        "decode_step static peak-live bound {} exceeds the \
                         {} budget tests/alloc_counts.rs asserts per token",
                        fmt_bytes(p.peak_live_bytes),
                        fmt_bytes(DECODE_STEP_BUDGET)
                    ));
                }
            }
        }
        rows.push(vec![
            a.name.clone(),
            a.instrs.to_string(),
            unsupported.to_string(),
            chains,
            peak,
            a.diagnostics.len().to_string(),
        ]);
    }
    print!(
        "{}",
        format_rows(
            &format!("hlo-lint: {} ({})", report.set_name, dir.display()),
            &["artifact", "instrs", "unsupported", "fusible chains", "peak live", "diags"],
            &rows,
        )
    );

    let total = report.total_diagnostics();
    if total > 0 {
        println!("\ndiagnostics:");
        for a in &report.artifacts {
            for d in &a.diagnostics {
                println!("  {}: {d}", a.name);
            }
        }
    }
    for msg in &over_budget {
        println!("\nbudget: {msg}");
    }
    if total > 0 || !over_budget.is_empty() {
        bail!(
            "hlo-lint: {} diagnostic(s), {} budget violation(s) in set '{}'",
            total,
            over_budget.len(),
            report.set_name
        );
    }
    println!(
        "\nhlo-lint: {} artifact(s) verified clean in set '{}'",
        report.artifacts.len(),
        report.set_name
    );
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let name = args.get_or("artifacts", "tiny");
    let manifest = Manifest::load(gcore::runtime::artifacts_dir(name))?;
    let d = &manifest.dims;
    println!(
        "artifact set '{}': {:.2}M params (policy), {:.2}M (scalar), pallas={}",
        d.name,
        manifest.param_count as f64 / 1e6,
        manifest.scalar_param_count as f64 / 1e6,
        d.use_pallas
    );
    println!(
        "dims: vocab={} d_model={} layers={} heads={} seq={} prompt={} batch={}",
        d.vocab, d.d_model, d.n_layers, d.n_heads, d.max_seq, d.prompt_len, d.batch
    );
    println!("\n| artifact | inputs | outputs | HLO KB |");
    println!("|---|---|---|---|");
    for (name, a) in &manifest.artifacts {
        println!(
            "| {name} | {} | {} | {} |",
            a.inputs.len(),
            a.outputs.len(),
            a.hlo_bytes / 1024
        );
    }
    Ok(())
}
